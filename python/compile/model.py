"""L2: the JAX model of the paper's 2-layer TNN prototype (Fig. 19).

Composes the L1 Pallas kernels into the executable programs the rust
coordinator runs at runtime (after AOT lowering by aot.py):

  * ``layer_fwd``       — one multi-column layer forward pass.
  * ``layer_train_step``— forward + STDP in a single fused program, so the
    whole training step is one HLO module (one PJRT dispatch per layer per
    batch, donated weight buffer semantics on the TPU path).
  * ``column_fwd`` / ``column_train_step`` — single-column variants used by
    the quickstart example and the cross-validation tests against the
    gate-level simulator.

Python here is build-time only; nothing in this file runs on the request
path.  All functions are shape-monomorphic at lowering time (aot.py lowers
one HLO artifact per (B, C, p, q) the coordinator needs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import column_fwd as cf
from .kernels import ref
from .kernels import stdp as st


def layer_fwd(s, w, theta):
    """Layer forward: s[B,C,p], w[C,p,q], theta[1] -> (pre, post) [B,C,q]."""
    return cf.layer_fwd(s, w, theta)


def layer_train_step(s, w, theta, rand, params):
    """Fused forward + STDP for one layer.

    Args:
      s: [B,C,p] int32 input spike times.
      w: [C,p,q] int32 weights.
      theta: [1] int32 threshold.
      rand: [B,C,p,q,2] int32 uniform 16-bit draws.
      params: [ref.N_PARAMS] int32 STDP thresholds.
    Returns:
      (pre, post, new_w): [B,C,q], [B,C,q], [C,p,q] int32.
    """
    pre, post = cf.layer_fwd(s, w, theta)
    new_w = st.layer_stdp(s, post, w, rand, params)
    return pre, post, new_w


def column_fwd(s, w, theta):
    """Single-column forward: s[B,p], w[p,q], theta[1] -> (pre, post)."""
    return cf.column_fwd(s, w, theta)


def column_train_step(s, w, theta, rand, params):
    """Fused single-column forward + STDP (quickstart / cross-check)."""
    pre, post = cf.column_fwd(s, w, theta)
    new_w = st.stdp_update(s, post, w, rand, params)
    return pre, post, new_w


def prototype_fwd(s1, w1, theta1, w2, theta2, routing):
    """Full 2-layer prototype forward (inference only).

    Layer-1 post-WTA spike times are re-encoded into layer-2 inputs via a
    static ``routing`` gather: layer-2 column c reads the q1 outputs of
    layer-1 column ``routing[c]`` (the prototype wires layer-2 column c to
    layer-1 column c, so routing is typically identity, but the artifact
    keeps it general for receptive-field experiments).

    Args:
      s1: [B, C1, p1] layer-1 inputs; w1: [C1, p1, q1]; theta1: [1].
      w2: [C2, p2, q2] with p2 == q1; theta2: [1].
      routing: [C2] int32 — layer-1 column feeding each layer-2 column.
    Returns: (post1 [B,C1,q1], post2 [B,C2,q2]).
    """
    _, post1 = cf.layer_fwd(s1, w1, theta1)
    s2 = rebase_times(post1)
    s2 = jnp.take(s2, routing, axis=1)  # [B, C2, q1]
    _, post2 = cf.layer_fwd(s2, w2, theta2)
    return post1, post2


def rebase_times(post):
    """Re-encode a layer's post-WTA times as next-layer inputs.

    Spikes keep relative order, clipped into the [0, T_IN) input window;
    INF stays INF.  Standalone export so the coordinator can run
    layer-at-a-time training (layer 1 converges before layer 2, as in [2]).
    """
    return jnp.where(
        post == ref.INF, ref.INF, jnp.clip(post, 0, ref.T_IN - 1)
    ).astype(jnp.int32)
