"""Pallas kernel: TNN column forward pass (RNL + threshold + 1-WTA).

This is the compute hot-spot of the stack — the hardware analogue is the
``syn_output`` (RNL readout) + ``pac_adder`` (parallel accumulative
counter) + ``less_equal``/``pulse2edge`` (WTA) macro pipeline of the paper.

Hardware adaptation (DESIGN.md §Hardware-Adaptation)
----------------------------------------------------
The ASIC evaluates body potentials with a p-way accumulation per unit
cycle.  On TPU we express the same dataflow as a *thermometer matmul*: the
RNL contribution min(relu(t+1-s), w) decomposes over weight levels

    min(relu(t+1-s), w) = sum_{k=0}^{W_MAX-1} [s <= t-k] * [w > k]

so the per-cycle potential is  rho(t) = sum_k S_{t-k} @ W_k  with
S_tau[B,p] = (s <= tau) and W_k[p,q] = (w > k) — MXU contractions in f32
(values are tiny integers, exact in f32).  The weight thermometer planes
stay in VMEM across the whole temporal loop, exactly like the synapse
SRAM of the ASIC; one HBM read of the weight block per column tile.

Performance (EXPERIMENTS.md §Perf): the layer kernel tiles the column
axis — each grid step computes a [B, TC, p] x [TC, p, q] *batched*
contraction per (t, k) instead of one tiny matmul per column, collapsing
the interpret-mode op count by ~TC and mapping to one MXU dispatch per
level on real hardware.  Tile size is chosen so a tile's blocks fit
comfortably in VMEM (~4 MiB budget).

interpret=True is mandatory here: the CPU PJRT client cannot execute the
Mosaic custom-call a real TPU lowering would emit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VMEM budget per input tile (bytes) used to pick the column tile size.
VMEM_TILE_BUDGET = 4 << 20


def pick_tile(cols: int, bytes_per_col: int) -> int:
    """Largest divisor of `cols` whose tile stays under the VMEM budget."""
    best = 1
    for tc in range(1, cols + 1):
        if cols % tc == 0 and tc * bytes_per_col <= VMEM_TILE_BUDGET:
            best = max(best, tc)
    return best


def _fwd_tile_kernel(s_ref, w_ref, theta_ref, pre_ref, post_ref):
    """One column tile: s[B,TC,p], w[TC,p,q] -> pre/post [B,TC,q].

    Fully loop-free: ONE batched contraction computes the level responses
    for every (cycle, weight-level) pair, then W_MAX statically-unrolled
    shifted adds realize the temporal convolution rho(t) = sum_k S(t-k)@W_k
    and an argmax finds the first threshold crossing.  On TPU this is a
    single MXU dispatch per tile; under interpret=True it collapses the op
    count from O(T_STEPS * W_MAX) small dots to ~25 ops.
    """
    s = s_ref[...]  # [B,TC,p] int32
    w = w_ref[...]  # [TC,p,q] int32
    theta = theta_ref[0]
    B, TC, p = s.shape
    q = w.shape[2]

    # Thermometer planes of the weights: [TC, W_MAX, p, q] f32 (the
    # synapse-SRAM analogue, one HBM read per tile).
    levels = jnp.arange(ref.W_MAX, dtype=jnp.int32)
    w_thermo = (
        w[:, None, :, :] > levels[None, :, None, None]
    ).astype(jnp.float32)

    # Step-function planes of the inputs: SS[tau][B,TC,p] = (s <= tau).
    taus = jnp.arange(ref.T_STEPS, dtype=jnp.int32)
    ss = (s[None] <= taus[:, None, None, None]).astype(jnp.float32)

    # The one big contraction: R[t,b,c,k,q] = SS[t] @ W_k  (batch c,
    # contract p) — every level response for every cycle at once.
    r = jnp.einsum(
        "tbcp,ckpq->tbckq",
        ss,
        w_thermo,
        precision=jax.lax.Precision.HIGHEST,
    )

    # Temporal convolution rho(t) = sum_k R[t-k, ..., k, :], realized as
    # W_MAX statically-unrolled shifted adds (k is tiny and static).
    rho = jnp.zeros((ref.T_STEPS, B, TC, q), jnp.float32)
    for k in range(ref.W_MAX):
        rk = r[:, :, :, k, :]
        if k > 0:
            rk = jnp.pad(rk, ((k, 0), (0, 0), (0, 0), (0, 0)))[
                : ref.T_STEPS
            ]
        rho = rho + rk

    # First crossing: potentials are non-decreasing, so argmax over the
    # cycle axis of the threshold mask is the spike time.
    mask = rho.astype(jnp.int32) >= theta  # [T,B,TC,q]
    fired = jnp.any(mask, axis=0)
    idx = jnp.argmax(mask, axis=0).astype(jnp.int32)
    inf = jnp.int32(ref.INF)
    pre = jnp.where(fired, idx, inf)
    pre_ref[...] = pre

    # 1-WTA per column: earliest spike, lowest index on ties.
    winner = jnp.argmin(pre, axis=2)  # [B,TC]
    fired = jnp.min(pre, axis=2) != inf
    lanes = jax.lax.broadcasted_iota(jnp.int32, (B, TC, q), 2)
    post_ref[...] = jnp.where(
        (lanes == winner[..., None]) & fired[..., None], pre, inf
    )


def layer_fwd(s, w, theta):
    """Multi-column layer forward.

    Args:
      s: [B, C, p] int32 per-column input spike times.
      w: [C, p, q] int32 weights.
      theta: [1] int32 shared firing threshold.
    Returns: (pre, post) [B, C, q] int32.
    """
    B, C, p = s.shape
    q = w.shape[2]
    # Tile budget counts the biggest per-column block (s + w + thermo).
    bytes_per_col = 4 * (B * p + p * q * (1 + ref.W_MAX))
    tc = pick_tile(C, bytes_per_col)
    grid = (C // tc,)
    out_shape = (
        jax.ShapeDtypeStruct((B, C, q), jnp.int32),
        jax.ShapeDtypeStruct((B, C, q), jnp.int32),
    )
    return pl.pallas_call(
        _fwd_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, tc, p), lambda c: (0, c, 0)),
            pl.BlockSpec((tc, p, q), lambda c: (c, 0, 0)),
            pl.BlockSpec((1,), lambda c: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((B, tc, q), lambda c: (0, c, 0)),
            pl.BlockSpec((B, tc, q), lambda c: (0, c, 0)),
        ),
        out_shape=out_shape,
        interpret=True,
    )(s, w, theta)


def column_fwd(s, w, theta):
    """Single-column forward.  s:[B,p], w:[p,q], theta:[1] int32.

    Returns (pre, post) spike times, both [B,q] int32.
    """
    pre, post = layer_fwd(s[:, None, :], w[None], theta)
    return pre[:, 0, :], post[:, 0, :]
