"""Pallas kernel: TNN STDP weight update (the paper's learning macros).

Hardware analogue: per-synapse ``stdp_case_gen`` (the four timing cases) +
``stabilize_func`` (weight-indexed BRV selection, the 8:1 GDI mux) +
``incdec`` (saturating +/-1) + ``syn_weight_update`` (the 3-bit weight FSM).

Batch samples are applied *sequentially* (fori_loop over B) — the hardware
updates weights per computational wave, and sequential order is what the
gate-level netlist implements, so equivalence tests demand it.  All
randomness is supplied by the caller as 16-bit uniform draws (rust
generates them with the same LFSR the RTL uses), keeping the kernel
bit-deterministic.

Performance (EXPERIMENTS.md §Perf): the kernel tiles the column axis and
vectorizes each sequential batch step across the whole tile —
B iterations of [TC, p, q] element-wise work per grid step instead of
C x B iterations of [p, q] work.  The sequential dependency (weights feed
the stabilize_func select of the NEXT sample) is preserved exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .column_fwd import pick_tile


def _stdp_tile_kernel(s_ref, o_ref, w_ref, rand_ref, params_ref, out_ref):
    """One tile: s[B,TC,p], o[B,TC,q], w[TC,p,q], rand[B,TC,p,q,2]."""
    s = s_ref[...]
    o = o_ref[...]
    w0 = w_ref[...]
    rand = rand_ref[...]
    params = params_ref[...]
    B = s.shape[0]

    mu_c, mu_b, mu_s = params[0], params[1], params[2]
    stab_up_tbl = params[3:11]
    stab_dn_tbl = params[11:19]
    inf = jnp.int32(ref.INF)

    def sample(b, w):
        sb = jax.lax.dynamic_index_in_dim(s, b, 0, keepdims=False)  # [TC,p]
        ob = jax.lax.dynamic_index_in_dim(o, b, 0, keepdims=False)  # [TC,q]
        rb = jax.lax.dynamic_index_in_dim(rand, b, 0, keepdims=False)

        # stabilize_func: weight value selects the BRV threshold (8:1 mux).
        wc = jnp.clip(w, 0, 7)
        stab_up = stab_up_tbl[wc]  # [TC,p,q]
        stab_dn = stab_dn_tbl[wc]

        x = (sb != inf)[:, :, None]  # [TC,p,1]
        y = (ob != inf)[:, None, :]  # [TC,1,q]
        sle = sb[:, :, None] <= ob[:, None, :]
        r_case = rb[..., 0]
        r_stab = rb[..., 1]

        # stdp_case_gen: the four timing cases.
        capture = x & y & sle & (r_case < mu_c) & (r_stab < stab_up)
        backoff = x & y & (~sle) & (r_case < mu_b) & (r_stab < stab_dn)
        search = x & (~y) & (r_case < mu_s)
        minus = (~x) & y & (r_case < mu_b) & (r_stab < stab_dn)

        # incdec + syn_weight_update: saturating +/-1.
        delta = (capture | search).astype(jnp.int32) - (
            backoff | minus
        ).astype(jnp.int32)
        return jnp.clip(w + delta, 0, ref.W_MAX)

    out_ref[...] = jax.lax.fori_loop(0, B, sample, w0)


def layer_stdp(s, o, w, rand, params):
    """Multi-column STDP.

    Args:
      s: [B, C, p] input spike times; o: [B, C, q] post-WTA output times.
      w: [C, p, q] weights; rand: [B, C, p, q, 2] uniform draws.
      params: [19] int32 thresholds (ref.pack_params).
    Returns: new [C, p, q] int32 weights.
    """
    B, C, p = s.shape
    q = o.shape[2]
    # The rand block dominates the tile footprint.
    bytes_per_col = 4 * (B * p * q * 2 + 3 * p * q)
    tc = pick_tile(C, bytes_per_col)
    return pl.pallas_call(
        _stdp_tile_kernel,
        grid=(C // tc,),
        in_specs=[
            pl.BlockSpec((B, tc, p), lambda c: (0, c, 0)),
            pl.BlockSpec((B, tc, q), lambda c: (0, c, 0)),
            pl.BlockSpec((tc, p, q), lambda c: (c, 0, 0)),
            pl.BlockSpec((B, tc, p, q, 2), lambda c: (0, c, 0, 0, 0)),
            pl.BlockSpec((ref.N_PARAMS,), lambda c: (0,)),
        ],
        out_specs=pl.BlockSpec((tc, p, q), lambda c: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((C, p, q), jnp.int32),
        interpret=True,
    )(s, o, w, rand, params)


def stdp_update(s, o, w, rand, params):
    """Single-column STDP.  s:[B,p], o:[B,q], w:[p,q], rand:[B,p,q,2],
    params:[19] -> new weights [p,q] int32."""
    return layer_stdp(
        s[:, None, :],
        o[:, None, :],
        w[None],
        rand[:, None],
        params,
    )[0]
