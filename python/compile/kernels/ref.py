"""Pure-jnp oracles for the TNN column kernels.

These define the *architectural semantics* shared by every layer of the
stack: the Pallas kernels (column_fwd.py / stdp.py), the rust golden model
(rust/src/tnn/), and the gate-level netlists (rust/src/netlist/modules/)
are all tested for exact equivalence against the behaviour specified here.

Temporal code
-------------
Spike times are small non-negative integers; ``INF`` (= 2**30) encodes
"no spike".  Inputs are 3-bit times in [0, 8); weights are 3-bit in [0, 7].
The ramp-no-leak (RNL) response of synapse j with weight w and input spike
at time s contributes ``clamp(t + 1 - s, 0, w)`` to the body potential at
unit-cycle t (a spike at time s starts ramping on cycle s).  Potentials are
therefore non-decreasing and saturate by ``t = T_IN + W_MAX - 1``; the
output spike time of neuron i is the first cycle its potential crosses
theta, else INF.

WTA inhibition passes only the earliest output spike (lowest neuron index
breaks ties), matching the paper's less_equal/pulse2edge macros.

STDP (from [2], the predecessor paper)
--------------------------------------
Four timing cases per synapse (x = input spiked, y = (post-WTA) output
spiked, s/o their times), each gated by a Bernoulli random variable (BRV)
and a weight-indexed stabilization BRV (the stabilize_func 8:1 mux):

  capture : x and y and s <= o  ->  w += 1  with prob mu_capture * stab_up[w]
  backoff : x and y and s >  o  ->  w -= 1  with prob mu_backoff * stab_dn[w]
  search  : x and not y         ->  w += 1  with prob mu_search
  minus   : y and not x         ->  w -= 1  with prob mu_backoff * stab_dn[w]

Randomness is hardware-faithful: the caller supplies two uniform draws in
[0, 2**16) per synapse per sample (``r_case``, ``r_stab``); an event with
probability p fires iff ``r < round(p * 2**16)``.  The rust coordinator
generates these with the same 16-bit LFSR the RTL would use.
"""

from __future__ import annotations

import jax.numpy as jnp

INF = 1 << 30  # "no spike" sentinel (fits comfortably in int32)
T_IN = 8  # input temporal window (3-bit spike times)
W_MAX = 7  # 3-bit saturating weights
T_STEPS = T_IN + W_MAX  # potentials are constant after this many cycles
RAND_SCALE = 1 << 16  # BRV thresholds are 16-bit fixed point

# params vector layout for stdp_step: [mu_capture, mu_backoff, mu_search,
# stab_up[0..7], stab_dn[0..7]] -- all 16-bit fixed-point thresholds.
N_PARAMS = 3 + 8 + 8


def pack_params(mu_capture, mu_backoff, mu_search, stab_up, stab_dn):
    """Pack STDP probabilities (floats in [0,1]) into the int32 params vec."""

    def to_thr(p):
        return jnp.round(jnp.asarray(p, dtype=jnp.float32) * RAND_SCALE).astype(
            jnp.int32
        )

    return jnp.concatenate(
        [
            to_thr(jnp.asarray([mu_capture, mu_backoff, mu_search])),
            to_thr(jnp.asarray(stab_up)),
            to_thr(jnp.asarray(stab_dn)),
        ]
    )


def rnl_potential(s, w, t):
    """Body potentials at unit-cycle t.  s:[B,p] int32, w:[p,q] -> [B,q]."""
    ramp = jnp.clip(t + 1 - s, 0, None)  # [B,p]; INF times give 0
    contrib = jnp.minimum(ramp[:, :, None], w[None, :, :])  # [B,p,q]
    return contrib.sum(axis=1)


def column_fwd(s, w, theta):
    """Reference column forward pass.

    Args:
      s: [B, p] int32 input spike times (INF = none).
      w: [p, q] int32 weights in [0, W_MAX].
      theta: scalar int32 firing threshold (>= 1).
    Returns:
      (pre, post): [B, q] int32 spike times before / after WTA inhibition.
    """
    B, _ = s.shape
    q = w.shape[1]
    pre = jnp.full((B, q), INF, dtype=jnp.int32)
    for t in range(T_STEPS):
        rho = rnl_potential(s, w, t)
        pre = jnp.where((pre == INF) & (rho >= theta), t, pre)
    # 1-WTA: earliest spike wins, lowest index breaks ties.
    winner = jnp.argmin(pre, axis=1)  # argmin returns lowest index on ties
    fired = jnp.take_along_axis(pre, winner[:, None], axis=1) != INF
    post = jnp.where(
        (jnp.arange(q)[None, :] == winner[:, None]) & fired, pre, INF
    )
    return pre.astype(jnp.int32), post.astype(jnp.int32)


def stdp_step(s, o, w, rand, params):
    """Reference STDP update for ONE sample.

    Args:
      s: [p] input spike times, o: [q] post-WTA output spike times.
      w: [p, q] weights.  rand: [p, q, 2] uniform draws in [0, 2**16).
      params: [N_PARAMS] int32 thresholds (see pack_params).
    Returns: new [p, q] weights.
    """
    mu_c, mu_b, mu_s = params[0], params[1], params[2]
    stab_up = params[3:11][jnp.clip(w, 0, 7)]  # [p,q]
    stab_dn = params[11:19][jnp.clip(w, 0, 7)]
    x = (s != INF)[:, None]  # [p,1]
    y = (o != INF)[None, :]  # [1,q]
    sle = s[:, None] <= o[None, :]
    r_case, r_stab = rand[..., 0], rand[..., 1]

    capture = x & y & sle & (r_case < mu_c) & (r_stab < stab_up)
    backoff = x & y & ~sle & (r_case < mu_b) & (r_stab < stab_dn)
    search = x & ~y & (r_case < mu_s)
    minus = ~x & y & (r_case < mu_b) & (r_stab < stab_dn)

    delta = (capture | search).astype(jnp.int32) - (backoff | minus).astype(
        jnp.int32
    )
    return jnp.clip(w + delta, 0, W_MAX).astype(jnp.int32)


def stdp_batch(s, o, w, rand, params):
    """Sequential (hardware-order) STDP over a batch.

    s:[B,p], o:[B,q], w:[p,q], rand:[B,p,q,2] -> new [p,q] weights.
    """
    for b in range(s.shape[0]):
        w = stdp_step(s[b], o[b], w, rand[b], params)
    return w


def layer_fwd(s, w, theta):
    """Reference multi-column layer forward: s:[B,C,p], w:[C,p,q]."""
    C = w.shape[0]
    pres, posts = [], []
    for c in range(C):
        pre, post = column_fwd(s[:, c, :], w[c], theta)
        pres.append(pre)
        posts.append(post)
    return jnp.stack(pres, axis=1), jnp.stack(posts, axis=1)


def layer_stdp(s, o, w, rand, params):
    """Reference multi-column STDP: s:[B,C,p], o:[B,C,q], w:[C,p,q],
    rand:[B,C,p,q,2] -> new [C,p,q]."""
    C = w.shape[0]
    return jnp.stack(
        [
            stdp_batch(s[:, c], o[:, c], w[c], rand[:, c], params)
            for c in range(C)
        ],
        axis=0,
    )
