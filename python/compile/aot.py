"""AOT compile path: lower the L2 JAX programs to HLO *text* artifacts.

Run once by ``make artifacts``; the rust coordinator then loads
``artifacts/*.hlo.txt`` through the xla crate's PJRT CPU client and python
never runs again.  HLO text (NOT ``lowered.compile()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Every artifact is shape-monomorphic.  ``manifest.json`` records, for each
artifact: the program kind, the (B, C, p, q) geometry, and the exact
argument/result shapes — the rust runtime validates against it at load.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

# Geometry of every program the coordinator needs.
#   quickstart column: 8x4  (examples/quickstart.rs)
#   benchmark columns: 64x8, 128x10, 1024x16 (Table I cross-checks)
#   prototype layers:  625 columns of 32x12 and 12x10 (Fig. 19)
BATCH = 16

SPECS = [
    # (name, kind, B, C, p, q)
    ("col_fwd_8x4", "col_fwd", BATCH, 1, 8, 4),
    ("col_train_8x4", "col_train", BATCH, 1, 8, 4),
    ("col_fwd_64x8", "col_fwd", BATCH, 1, 64, 8),
    ("col_fwd_128x10", "col_fwd", BATCH, 1, 128, 10),
    ("col_fwd_1024x16", "col_fwd", BATCH, 1, 1024, 16),
    ("col_train_64x8", "col_train", BATCH, 1, 64, 8),
    ("l1_fwd", "layer_fwd", BATCH, 625, 32, 12),
    ("l1_train", "layer_train", BATCH, 625, 32, 12),
    ("l2_fwd", "layer_fwd", BATCH, 625, 12, 10),
    ("l2_train", "layer_train", BATCH, 625, 12, 10),
]

I32 = jnp.int32


def _spec_args(kind, B, C, p, q):
    """Example ShapeDtypeStructs for lowering."""
    S = jax.ShapeDtypeStruct
    if kind == "col_fwd":
        return (S((B, p), I32), S((p, q), I32), S((1,), I32))
    if kind == "col_train":
        return (
            S((B, p), I32),
            S((p, q), I32),
            S((1,), I32),
            S((B, p, q, 2), I32),
            S((ref.N_PARAMS,), I32),
        )
    if kind == "layer_fwd":
        return (S((B, C, p), I32), S((C, p, q), I32), S((1,), I32))
    if kind == "layer_train":
        return (
            S((B, C, p), I32),
            S((C, p, q), I32),
            S((1,), I32),
            S((B, C, p, q, 2), I32),
            S((ref.N_PARAMS,), I32),
        )
    raise ValueError(f"unknown kind {kind}")


FNS = {
    "col_fwd": model.column_fwd,
    "col_train": model.column_train_step,
    "layer_fwd": model.layer_fwd,
    "layer_train": model.layer_train_step,
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name, kind, B, C, p, q):
    args = _spec_args(kind, B, C, p, q)
    lowered = jax.jit(FNS[kind]).lower(*args)
    text = to_hlo_text(lowered)
    entry = {
        "name": name,
        "kind": kind,
        "file": f"{name}.hlo.txt",
        "batch": B,
        "cols": C,
        "p": p,
        "q": q,
        "n_params": ref.N_PARAMS,
        "inputs": [list(a.shape) for a in args],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact names"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {
        "batch": BATCH,
        "inf": ref.INF,
        "t_in": ref.T_IN,
        "w_max": ref.W_MAX,
        "t_steps": ref.T_STEPS,
        "rand_scale": ref.RAND_SCALE,
        "n_params": ref.N_PARAMS,
        "artifacts": [],
    }
    for name, kind, B, C, p, q in SPECS:
        if only and name not in only:
            continue
        text, entry = lower_one(name, kind, B, C, p, q)
        path = os.path.join(args.out_dir, entry["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(entry)
        print(f"  {name:<18} {kind:<12} B={B} C={C} p={p} q={q} "
              f"-> {len(text)//1024} KiB")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json "
          f"to {args.out_dir}")


if __name__ == "__main__":
    main()
