"""AOT path tests: lowering determinism, manifest integrity, tiling."""

from __future__ import annotations

import json

import pytest

from compile import aot
from compile.kernels import column_fwd as cf
from compile.kernels import ref


class TestTilePicker:
    def test_divides_columns(self):
        for cols in [1, 5, 25, 125, 625, 7, 49]:
            tc = cf.pick_tile(cols, 1000)
            assert cols % tc == 0

    def test_respects_budget(self):
        bytes_per_col = 1 << 20  # 1 MiB per column
        tc = cf.pick_tile(625, bytes_per_col)
        assert tc * bytes_per_col <= cf.VMEM_TILE_BUDGET
        # budget allows at least one column even when oversized
        assert cf.pick_tile(625, 1 << 30) == 1

    def test_monotone_in_budget_pressure(self):
        small = cf.pick_tile(625, 1 << 10)
        large = cf.pick_tile(625, 1 << 18)
        assert small >= large


class TestLowering:
    def test_hlo_text_deterministic(self):
        t1, e1 = aot.lower_one("col_fwd_8x4", "col_fwd", 16, 1, 8, 4)
        t2, e2 = aot.lower_one("col_fwd_8x4", "col_fwd", 16, 1, 8, 4)
        assert t1 == t2
        assert e1["sha256"] == e2["sha256"]

    def test_hlo_is_parseable_text(self):
        text, entry = aot.lower_one("x", "col_fwd", 4, 1, 8, 4)
        assert text.startswith("HloModule")
        assert "s32[4,8]" in text  # input spike tensor shape
        assert entry["inputs"][0] == [4, 8]

    def test_train_kind_has_five_inputs(self):
        _, entry = aot.lower_one("x", "layer_train", 4, 3, 8, 4)
        assert len(entry["inputs"]) == 5
        assert entry["inputs"][3] == [4, 3, 8, 4, 2]  # rand tensor
        assert entry["inputs"][4] == [ref.N_PARAMS]

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            aot._spec_args("bogus", 1, 1, 1, 1)


class TestManifestSchema:
    def test_manifest_fields_round_trip(self, tmp_path):
        import subprocess
        import sys

        # Build a single small artifact into a temp dir via the CLI.
        import pathlib

        py_dir = pathlib.Path(__file__).resolve().parent.parent
        out = subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out-dir",
                str(tmp_path),
                "--only",
                "col_fwd_8x4",
            ],
            capture_output=True,
            text=True,
            cwd=py_dir,
        )
        assert out.returncode == 0, out.stderr
        m = json.loads((tmp_path / "manifest.json").read_text())
        # Constants the rust Manifest::parse validates against.
        assert m["inf"] == ref.INF
        assert m["t_in"] == ref.T_IN
        assert m["w_max"] == ref.W_MAX
        assert m["t_steps"] == ref.T_STEPS
        assert m["rand_scale"] == ref.RAND_SCALE
        assert m["n_params"] == ref.N_PARAMS
        [a] = m["artifacts"]
        assert a["name"] == "col_fwd_8x4"
        assert (tmp_path / a["file"]).exists()
