"""Kernel-vs-reference equivalence — the core L1 correctness signal.

Every Pallas kernel must agree *exactly* (integer semantics) with the
pure-jnp oracle in kernels/ref.py, across a hypothesis sweep of geometries,
spike patterns, weights and thresholds.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as stst

import jax.numpy as jnp

from compile.kernels import column_fwd as cf
from compile.kernels import ref
from compile.kernels import stdp as st

RNG = np.random.default_rng


def make_inputs(seed, B, p, q, spike_prob=0.8):
    rng = RNG(seed)
    s = rng.integers(0, ref.T_IN, size=(B, p), dtype=np.int32)
    mask = rng.random((B, p)) < spike_prob
    s = np.where(mask, s, ref.INF).astype(np.int32)
    w = rng.integers(0, ref.W_MAX + 1, size=(p, q), dtype=np.int32)
    return jnp.asarray(s), jnp.asarray(w)


def default_params():
    return ref.pack_params(
        mu_capture=0.9,
        mu_backoff=0.5,
        mu_search=0.05,
        stab_up=[1.0, 1.0, 0.75, 0.5, 0.5, 0.25, 0.25, 0.125],
        stab_dn=[0.125, 0.25, 0.25, 0.5, 0.5, 0.75, 1.0, 1.0],
    )


geometries = stst.sampled_from(
    [(1, 4, 2), (2, 8, 4), (3, 7, 3), (4, 16, 8), (2, 32, 12), (1, 12, 10)]
)


class TestColumnFwd:
    @settings(max_examples=25, deadline=None)
    @given(
        geo=geometries,
        seed=stst.integers(0, 2**31 - 1),
        theta=stst.integers(1, 40),
        spike_prob=stst.floats(0.0, 1.0),
    )
    def test_matches_ref(self, geo, seed, theta, spike_prob):
        B, p, q = geo
        s, w = make_inputs(seed, B, p, q, spike_prob)
        th = jnp.asarray([theta], dtype=jnp.int32)
        pre_k, post_k = cf.column_fwd(s, w, th)
        pre_r, post_r = ref.column_fwd(s, w, theta)
        np.testing.assert_array_equal(np.asarray(pre_k), np.asarray(pre_r))
        np.testing.assert_array_equal(np.asarray(post_k), np.asarray(post_r))

    def test_no_input_no_spike(self):
        s = jnp.full((2, 8), ref.INF, dtype=jnp.int32)
        w = jnp.full((8, 4), ref.W_MAX, dtype=jnp.int32)
        pre, post = cf.column_fwd(s, w, jnp.asarray([1], jnp.int32))
        assert (np.asarray(pre) == ref.INF).all()
        assert (np.asarray(post) == ref.INF).all()

    def test_wta_single_winner(self):
        for seed in range(20):
            s, w = make_inputs(seed, 4, 16, 8)
            _, post = cf.column_fwd(s, w, jnp.asarray([8], jnp.int32))
            fired = (np.asarray(post) != ref.INF).sum(axis=1)
            assert (fired <= 1).all()

    def test_wta_lowest_index_tiebreak(self):
        # Two identical neurons -> index 0 must win.
        s = jnp.zeros((1, 4), dtype=jnp.int32)
        w = jnp.full((4, 2), 3, dtype=jnp.int32)
        _, post = cf.column_fwd(s, w, jnp.asarray([4], jnp.int32))
        post = np.asarray(post)[0]
        assert post[0] != ref.INF and post[1] == ref.INF

    def test_threshold_monotone(self):
        # Raising theta can only delay (or kill) the winning spike.
        s, w = make_inputs(7, 2, 16, 4)
        prev = None
        for theta in [1, 4, 8, 16, 32]:
            pre, _ = cf.column_fwd(s, w, jnp.asarray([theta], jnp.int32))
            pre = np.asarray(pre)
            if prev is not None:
                assert (pre >= prev).all()
            prev = pre

    def test_saturated_potential_value(self):
        # theta = sum(w) + 1 with all inputs at t=0 must never fire.
        s = jnp.zeros((1, 6), dtype=jnp.int32)
        w = jnp.asarray(RNG(3).integers(0, 8, (6, 3)), dtype=jnp.int32)
        theta = int(np.asarray(w).sum(axis=0).max()) + 1
        pre, _ = cf.column_fwd(s, w, jnp.asarray([theta], jnp.int32))
        assert (np.asarray(pre) == ref.INF).all()


class TestLayerFwd:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=stst.integers(0, 2**31 - 1),
        C=stst.integers(1, 6),
        theta=stst.integers(1, 24),
    )
    def test_matches_ref(self, seed, C, theta):
        B, p, q = 3, 8, 4
        rng = RNG(seed)
        s = rng.integers(0, ref.T_IN, size=(B, C, p), dtype=np.int32)
        s = np.where(rng.random((B, C, p)) < 0.8, s, ref.INF).astype(np.int32)
        w = rng.integers(0, 8, size=(C, p, q), dtype=np.int32)
        th = jnp.asarray([theta], jnp.int32)
        pre_k, post_k = cf.layer_fwd(jnp.asarray(s), jnp.asarray(w), th)
        pre_r, post_r = ref.layer_fwd(jnp.asarray(s), jnp.asarray(w), theta)
        np.testing.assert_array_equal(np.asarray(pre_k), np.asarray(pre_r))
        np.testing.assert_array_equal(np.asarray(post_k), np.asarray(post_r))

    def test_layer_equals_per_column(self):
        # layer_fwd(C columns) == stack of column_fwd per column.
        B, C, p, q = 2, 4, 8, 4
        rng = RNG(11)
        s = rng.integers(0, ref.T_IN, size=(B, C, p)).astype(np.int32)
        w = rng.integers(0, 8, size=(C, p, q)).astype(np.int32)
        th = jnp.asarray([6], jnp.int32)
        pre_l, post_l = cf.layer_fwd(jnp.asarray(s), jnp.asarray(w), th)
        for c in range(C):
            pre_c, post_c = cf.column_fwd(
                jnp.asarray(s[:, c]), jnp.asarray(w[c]), th
            )
            np.testing.assert_array_equal(
                np.asarray(pre_l)[:, c], np.asarray(pre_c)
            )
            np.testing.assert_array_equal(
                np.asarray(post_l)[:, c], np.asarray(post_c)
            )


class TestStdp:
    @settings(max_examples=25, deadline=None)
    @given(
        geo=geometries,
        seed=stst.integers(0, 2**31 - 1),
        spike_prob=stst.floats(0.0, 1.0),
    )
    def test_matches_ref(self, geo, seed, spike_prob):
        B, p, q = geo
        rng = RNG(seed)
        s, w = make_inputs(seed, B, p, q, spike_prob)
        o = rng.integers(0, ref.T_STEPS, size=(B, q), dtype=np.int32)
        o = np.where(rng.random((B, q)) < 0.5, o, ref.INF).astype(np.int32)
        rand = rng.integers(0, 1 << 16, size=(B, p, q, 2), dtype=np.int32)
        params = default_params()
        got = st.stdp_update(
            s, jnp.asarray(o), w, jnp.asarray(rand), params
        )
        want = ref.stdp_batch(s, jnp.asarray(o), w, jnp.asarray(rand), params)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_weights_stay_in_range(self):
        rng = RNG(5)
        B, p, q = 8, 8, 4
        s, w = make_inputs(5, B, p, q)
        o = rng.integers(0, ref.T_STEPS, size=(B, q), dtype=np.int32)
        rand = rng.integers(0, 1 << 16, size=(B, p, q, 2), dtype=np.int32)
        got = np.asarray(
            st.stdp_update(s, jnp.asarray(o), w, jnp.asarray(rand),
                           default_params())
        )
        assert got.min() >= 0 and got.max() <= ref.W_MAX

    def test_zero_prob_freezes_weights(self):
        rng = RNG(6)
        B, p, q = 4, 8, 4
        s, w = make_inputs(6, B, p, q)
        o = rng.integers(0, ref.T_STEPS, size=(B, q), dtype=np.int32)
        rand = rng.integers(0, 1 << 16, size=(B, p, q, 2), dtype=np.int32)
        params = ref.pack_params(0.0, 0.0, 0.0, [0.0] * 8, [0.0] * 8)
        got = st.stdp_update(s, jnp.asarray(o), w, jnp.asarray(rand), params)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(w))

    def test_capture_increments_with_prob_one(self):
        # x and y spike with s <= o, all probs 1 -> every weight < 7 bumps.
        p, q = 4, 3
        s = jnp.zeros((1, p), dtype=jnp.int32)
        o = jnp.full((1, q), 5, dtype=jnp.int32)
        w = jnp.asarray(RNG(7).integers(0, 7, (p, q)), dtype=jnp.int32)
        rand = jnp.zeros((1, p, q, 2), dtype=jnp.int32)
        params = ref.pack_params(1.0, 0.0, 0.0, [1.0] * 8, [0.0] * 8)
        got = st.stdp_update(s, o, w, rand, params)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(w) + 1)

    def test_sequential_batch_order_matters(self):
        # The kernel must apply samples in batch order (hardware waves):
        # construct a case where sample 0 saturates a weight so sample 1's
        # stabilization differs from any parallel application.
        p, q = 1, 1
        s = jnp.zeros((2, p), dtype=jnp.int32)
        o = jnp.full((2, q), 3, dtype=jnp.int32)
        w = jnp.asarray([[6]], dtype=jnp.int32)
        rand = jnp.zeros((2, p, q, 2), dtype=jnp.int32)
        # stab_up[6]=1 but stab_up[7]=0: first sample bumps 6->7, second
        # must then be blocked.  Parallel application would give 7 twice
        # too, so also check the reverse direction with stab_dn.
        params = ref.pack_params(1.0, 0.0, 0.0,
                                 [1, 1, 1, 1, 1, 1, 1, 0], [0] * 8)
        got = st.stdp_update(s, o, w, rand, params)
        assert int(np.asarray(got)[0, 0]) == 7

    @settings(max_examples=10, deadline=None)
    @given(seed=stst.integers(0, 2**31 - 1), C=stst.integers(1, 4))
    def test_layer_stdp_matches_ref(self, seed, C):
        B, p, q = 3, 8, 4
        rng = RNG(seed)
        s = rng.integers(0, ref.T_IN, size=(B, C, p), dtype=np.int32)
        o = rng.integers(0, ref.T_STEPS, size=(B, C, q), dtype=np.int32)
        o = np.where(rng.random((B, C, q)) < 0.6, o, ref.INF).astype(np.int32)
        w = rng.integers(0, 8, size=(C, p, q), dtype=np.int32)
        rand = rng.integers(0, 1 << 16, size=(B, C, p, q, 2), dtype=np.int32)
        params = default_params()
        got = st.layer_stdp(
            jnp.asarray(s), jnp.asarray(o), jnp.asarray(w),
            jnp.asarray(rand), params,
        )
        want = ref.layer_stdp(
            jnp.asarray(s), jnp.asarray(o), jnp.asarray(w),
            jnp.asarray(rand), params,
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
