"""L2 model-level tests: fused train step, prototype forward, rebasing."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng


def default_params():
    return ref.pack_params(
        0.9, 0.5, 0.05,
        [1.0, 1.0, 0.75, 0.5, 0.5, 0.25, 0.25, 0.125],
        [0.125, 0.25, 0.25, 0.5, 0.5, 0.75, 1.0, 1.0],
    )


def rand_layer(seed, B, C, p, q, spike_prob=0.8):
    rng = RNG(seed)
    s = rng.integers(0, ref.T_IN, size=(B, C, p), dtype=np.int32)
    s = np.where(rng.random((B, C, p)) < spike_prob, s, ref.INF)
    w = rng.integers(0, 8, size=(C, p, q), dtype=np.int32)
    rand = rng.integers(0, 1 << 16, size=(B, C, p, q, 2), dtype=np.int32)
    return (jnp.asarray(s.astype(np.int32)), jnp.asarray(w),
            jnp.asarray(rand))


class TestTrainStep:
    def test_fused_equals_composition(self):
        B, C, p, q = 4, 3, 8, 4
        s, w, rand = rand_layer(0, B, C, p, q)
        th = jnp.asarray([6], jnp.int32)
        params = default_params()
        pre_f, post_f, w_f = model.layer_train_step(s, w, th, rand, params)
        pre_r, post_r = ref.layer_fwd(s, w, 6)
        w_r = ref.layer_stdp(s, post_r, w, rand, params)
        np.testing.assert_array_equal(np.asarray(pre_f), np.asarray(pre_r))
        np.testing.assert_array_equal(np.asarray(post_f), np.asarray(post_r))
        np.testing.assert_array_equal(np.asarray(w_f), np.asarray(w_r))

    def test_column_train_step(self):
        B, p, q = 4, 8, 4
        rng = RNG(1)
        s = jnp.asarray(rng.integers(0, ref.T_IN, (B, p)).astype(np.int32))
        w = jnp.asarray(rng.integers(0, 8, (p, q)).astype(np.int32))
        rand = jnp.asarray(
            rng.integers(0, 1 << 16, (B, p, q, 2)).astype(np.int32))
        th = jnp.asarray([6], jnp.int32)
        params = default_params()
        pre, post, w2 = model.column_train_step(s, w, th, rand, params)
        pre_r, post_r = ref.column_fwd(s, w, 6)
        w_r = ref.stdp_batch(s, post_r, w, rand, params)
        np.testing.assert_array_equal(np.asarray(post), np.asarray(post_r))
        np.testing.assert_array_equal(np.asarray(w2), np.asarray(w_r))

    def test_training_moves_weights_toward_pattern(self):
        # Repeatedly presenting one pattern with capture-dominant STDP must
        # strengthen the winning neuron's active synapses (the basic STDP
        # convergence property the paper's prototype relies on).
        B, C, p, q = 16, 1, 16, 4
        rng = RNG(2)
        pattern = np.full(p, ref.INF, dtype=np.int32)
        pattern[:8] = 0  # first half active at t=0
        s = jnp.asarray(np.tile(pattern, (B, C, 1)).astype(np.int32))
        w = jnp.asarray(np.full((C, p, q), 3, dtype=np.int32))
        th = jnp.asarray([8], jnp.int32)
        params = ref.pack_params(1.0, 1.0, 0.0, [1.0] * 8, [1.0] * 8)
        for step in range(6):
            rand = jnp.asarray(
                rng.integers(0, 1 << 16, (B, C, p, q, 2)).astype(np.int32))
            _, post, w = model.layer_train_step(s, w, th, rand, params)
        w = np.asarray(w)[0]
        post = np.asarray(post)
        winners = post[post != ref.INF]
        assert winners.size > 0  # the column keeps firing
        # winning neuron's active weights saturate high, inactive go low
        win_idx = int(np.argmax((post[0, 0] != ref.INF)))
        assert w[:8, win_idx].mean() > 5.0
        assert w[8:, win_idx].mean() < 2.0


class TestPrototype:
    def test_prototype_fwd_shapes_and_semantics(self):
        B, C1, p1, q1 = 2, 4, 8, 3
        C2, p2, q2 = 4, 3, 5
        rng = RNG(3)
        s1 = jnp.asarray(rng.integers(0, ref.T_IN, (B, C1, p1)).astype(np.int32))
        w1 = jnp.asarray(rng.integers(0, 8, (C1, p1, q1)).astype(np.int32))
        w2 = jnp.asarray(rng.integers(0, 8, (C2, p2, q2)).astype(np.int32))
        routing = jnp.arange(C2, dtype=jnp.int32)
        post1, post2 = model.prototype_fwd(
            s1, w1, jnp.asarray([5], jnp.int32),
            w2, jnp.asarray([4], jnp.int32), routing)
        assert post1.shape == (B, C1, q1)
        assert post2.shape == (B, C2, q2)
        # layer-2 input must equal rebased layer-1 output (identity routing)
        _, post1_r = ref.layer_fwd(s1, w1, 5)
        s2 = np.asarray(model.rebase_times(post1_r))
        _, post2_r = ref.layer_fwd(jnp.asarray(s2), w2, 4)
        np.testing.assert_array_equal(np.asarray(post2), np.asarray(post2_r))

    def test_rebase_times(self):
        post = jnp.asarray([[0, 5, 9, 14, ref.INF]], dtype=jnp.int32)
        got = np.asarray(model.rebase_times(post))[0]
        assert list(got) == [0, 5, 7, 7, ref.INF]
