//! End-to-end driver: the paper's §III.C functional experiment.
//!
//! Trains the full Fig. 19 prototype (625 × 32x12 + 625 × 12x10 columns,
//! 13,750 neurons / 315,000 synapses) on the synthetic digit corpus
//! through the AOT HLO executables (python off the request path), then:
//!
//! * reports classification accuracy (paper: 93% on MNIST — see
//!   EXPERIMENTS.md for the corpus substitution),
//! * reports pipeline throughput/latency,
//! * measures the trained prototype's PPA through the gate-level flow
//!   (Table II numbers under the *trained*, not random, activity),
//! * cross-checks one live HLO batch against the golden model.
//!
//! Usage: make artifacts && cargo run --release --example mnist_e2e
//!        [-- --train N --test N --quick]

use tnn7::cells::{Library, TechParams};
use tnn7::config::TnnConfig;
use tnn7::coordinator::measure::prototype_ppa;
use tnn7::coordinator::Pipeline;
use tnn7::data::Dataset;
use tnn7::netlist::Flavor;
use tnn7::ppa::report::improvement_line;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = TnnConfig {
        // Thresholds from the design_space sweep (see EXPERIMENTS.md).
        theta1: 20,
        theta2: 2,
        w_init: 3,
        train_samples: arg("--train")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(if quick { 64 } else { 320 }),
        test_samples: arg("--test")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(if quick { 32 } else { 160 }),
        ..TnnConfig::default()
    };

    let train = Dataset::generate(cfg.train_samples, cfg.data_seed);
    let test = Dataset::generate(cfg.test_samples, cfg.data_seed + 1);

    println!("== tnn7 end-to-end: 2-layer prototype on synthetic digits ==");
    println!(
        "geometry: 625x(32x12) + 625x(12x10) = 13,750 neurons / 315,000 synapses"
    );
    println!(
        "train {} / test {} images, batch 16, theta=({}, {})\n",
        train.len(),
        test.len(),
        cfg.theta1,
        cfg.theta2
    );

    let mut pipe = Pipeline::new(cfg.clone())?;

    // Live HLO-vs-golden check on the first batch.
    print!("cross-check HLO vs golden model on one live batch ... ");
    pipe.cross_check_batch(&train.images[..pipe.batch()].to_vec())?;
    println!("OK");

    // Train (layer-at-a-time STDP + vote calibration).
    let metrics = pipe.train(&train)?;
    let acc = pipe.evaluate(&test)?;
    println!("\n-- functional results --");
    println!(
        "batches {:>4}   executor {:>6.1}s   wall {:>6.1}s",
        metrics.batches, metrics.exec_seconds, metrics.wall_seconds
    );
    println!(
        "training throughput : {:.2} images/s (interpret-mode CPU PJRT)",
        metrics.images_per_sec()
    );
    println!(
        "test accuracy       : {:.1}%  (paper: 93% on MNIST; chance 10%; \
         corpus substitution documented in EXPERIMENTS.md)",
        acc * 100.0
    );

    // Hardware PPA of the (now trained) prototype through the gate flow.
    if !quick {
        println!("\n-- hardware PPA of the prototype (gate-level flow) --");
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let mut mcfg = cfg.clone();
        // One packed pass over the full digit set: every training image
        // becomes a stimulus wave, 64 lanes per simulator tick
        // (DESIGN.md §7), so Table-II activity is measured under the
        // whole corpus instead of the default 8-wave sample.
        mcfg.sim_waves = train.len();
        mcfg.sim_lanes = 64;
        println!(
            "simulating {} waves through the 64-lane packed engine ...",
            mcfg.sim_waves
        );
        let (std_ppa, _, _) =
            prototype_ppa(&lib, &tech, Flavor::Std, &mcfg, &train)?;
        let (cus_ppa, _, _) =
            prototype_ppa(&lib, &tech, Flavor::Custom, &mcfg, &train)?;
        println!(
            "std    : {:.2} mW  {:.2} ns  {:.2} mm2   (paper: 2.54 / 24.14 / 2.36)",
            std_ppa.power_uw * 1e-3,
            std_ppa.time_ns,
            std_ppa.area_mm2
        );
        println!(
            "custom : {:.2} mW  {:.2} ns  {:.2} mm2   (paper: 1.69 / 19.15 / 1.56)",
            cus_ppa.power_uw * 1e-3,
            cus_ppa.time_ns,
            cus_ppa.area_mm2
        );
        println!("{}", improvement_line(&std_ppa, &cus_ppa));
        println!(
            "energy per image (custom): {:.1} pJ (paper: 32 pJ)",
            cus_ppa.power_uw * 1e-3 * cus_ppa.time_ns
        );
    }
    println!("\nmnist_e2e complete.");
    Ok(())
}
