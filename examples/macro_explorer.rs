//! Macro explorer: inspect the 11 custom cells the paper contributes.
//!
//! For each macro (Figs. 2–13): the GDI construction, characterized PPA,
//! the standard-cell twin's cost (elaborated through the real module
//! builders and counted from the netlist census), and a functional
//! mini-demo on the simulator.  This is the tour a library user would
//! take before adopting the extensions.
//!
//! Usage: cargo run --release --example macro_explorer

use tnn7::cells::{Library, MacroKind, TechParams};
use tnn7::netlist::modules::{
    edge2pulse::edge2pulse,
    incdec::incdec,
    less_equal::less_equal,
    mux::mux2,
    pac_adder::adder_slice,
    pulse2edge::{pulse2edge, P2eVariant},
    spike_gen::spike_gen,
    stabilize_func::stabilize_func,
    stdp_case_gen::stdp_case_gen,
    syn_output::syn_output,
    syn_weight_update::syn_weight_update,
};
use tnn7::netlist::{Builder, Flavor, Netlist};

/// Elaborate one macro standalone in the given flavour.
fn build_one(lib: &Library, kind: MacroKind, flavor: Flavor) -> Netlist {
    let mut b = Builder::new("m", lib);
    match kind {
        MacroKind::SynWeightUpdate => {
            let inc = b.input("inc");
            let dec = b.input("dec");
            let w = syn_weight_update(&mut b, flavor, inc, dec);
            for (i, &n) in w.iter().enumerate() {
                b.output(n, format!("w{i}"));
            }
        }
        MacroKind::SynOutput => {
            let c = b.input_bus("c", 3);
            let w = b.input_bus("w", 3);
            let p = b.input("pulse");
            let up = syn_output(
                &mut b,
                flavor,
                &[c[0], c[1], c[2]],
                &[w[0], w[1], w[2]],
                p,
            );
            b.output(up, "up");
        }
        MacroKind::PacAdder => {
            let a = b.input("a");
            let x = b.input("b");
            let ci = b.input("cin");
            let (s, co) = adder_slice(&mut b, flavor, a, x, ci);
            b.output(s, "sum");
            b.output(co, "cout");
        }
        MacroKind::LessEqual => {
            let a = b.input("a");
            let x = b.input("b");
            let le = less_equal(&mut b, flavor, a, x);
            b.output(le, "le");
        }
        MacroKind::Pulse2EdgePwr | MacroKind::Pulse2EdgeArea => {
            let d = b.input("d");
            let r = b.input("rst");
            let v = if kind == MacroKind::Pulse2EdgePwr {
                P2eVariant::PowerOpt
            } else {
                P2eVariant::AreaOpt
            };
            let q = pulse2edge(&mut b, flavor, v, d, r);
            b.output(q, "q");
        }
        MacroKind::StdpCaseGen => {
            let x = b.input("x");
            let y = b.input("y");
            let le = b.input("le");
            let c = stdp_case_gen(&mut b, flavor, x, y, le);
            b.output(c.capture, "capture");
            b.output(c.backoff, "backoff");
            b.output(c.search, "search");
            b.output(c.minus, "minus");
        }
        MacroKind::StabilizeFunc => {
            let brv = b.input_bus("brv", 8);
            let w = b.input_bus("w", 3);
            let y = stabilize_func(&mut b, flavor, &brv, &w);
            b.output(y, "sel");
        }
        MacroKind::IncDec => {
            let c = b.input("cap");
            let bk = b.input("back");
            let s = b.input("srch");
            let m = b.input("minus");
            let (inc, dec) = incdec(&mut b, flavor, c, bk, s, m);
            b.output(inc, "inc");
            b.output(dec, "dec");
        }
        MacroKind::Mux2Gdi => {
            let d0 = b.input("d0");
            let d1 = b.input("d1");
            let s = b.input("s");
            let y = mux2(&mut b, flavor, d0, d1, s);
            b.output(y, "y");
        }
        MacroKind::Edge2Pulse => {
            let d = b.input("d");
            let p = edge2pulse(&mut b, flavor, d);
            b.output(p, "pulse");
        }
        MacroKind::SpikeGen => {
            let d = b.input("d");
            let g = b.input("grst");
            let sg = spike_gen(&mut b, flavor, d, g);
            b.output(sg.pulse, "pulse");
            for (i, &c) in sg.count.iter().enumerate() {
                b.output(c, format!("c{i}"));
            }
        }
    }
    b.finish().expect("macro netlist")
}

fn main() -> anyhow::Result<()> {
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    println!(
        "{:<20} {:>7} {:>9} {:>10} {:>10} {:>9} {:>9} {:>8}",
        "macro (fig)",
        "T",
        "area um2",
        "energy fJ",
        "leak nW",
        "delay ps",
        "std T",
        "ratio"
    );
    let figs = [
        (MacroKind::SynWeightUpdate, "2"),
        (MacroKind::SynOutput, "3"),
        (MacroKind::PacAdder, "4"),
        (MacroKind::LessEqual, "5"),
        (MacroKind::Pulse2EdgePwr, "6"),
        (MacroKind::Pulse2EdgeArea, "7"),
        (MacroKind::StdpCaseGen, "8"),
        (MacroKind::StabilizeFunc, "9"),
        (MacroKind::IncDec, "10"),
        (MacroKind::Mux2Gdi, "11"),
        (MacroKind::SpikeGen, "12"),
        (MacroKind::Edge2Pulse, "13"),
    ];
    for (kind, fig) in figs {
        let cell = lib.cell(lib.id(kind.name())?);
        // Standard-cell twin cost from the real module builder (minus the
        // 2 tie cells every netlist carries).
        let std_nl = build_one(&lib, kind, Flavor::Std);
        let std_t = std_nl.census(&lib).transistors.saturating_sub(4);
        println!(
            "{:<20} {:>7} {:>9.4} {:>10.4} {:>10.4} {:>9.1} {:>9} {:>7.2}x",
            format!("{} ({})", kind.name(), fig),
            cell.transistors,
            tech.area_um2(cell),
            tech.energy_fj(cell),
            tech.leak_nw(cell),
            tech.delay_ps(cell),
            std_t,
            std_t as f64 / f64::from(cell.transistors.max(1)),
        );
    }

    println!("\nFunctional demo: custom spike_gen driving syn_output (w=5):");
    let mut b = Builder::new("demo", &lib);
    let d = b.input("d");
    let g = b.input("grst");
    let sg = spike_gen(&mut b, Flavor::Custom, d, g);
    let w_bits = [b.one(), b.zero(), b.one()]; // w = 5
    let up = syn_output(&mut b, Flavor::Custom, &sg.count, &w_bits, sg.pulse);
    b.output(up, "up");
    let nl = b.finish()?;
    let mut sim = tnn7::sim::Simulator::new(&nl, &lib)?;
    let mut ups = String::new();
    for cyc in 0..12 {
        sim.tick(&[(nl.inputs[0], cyc >= 2), (nl.inputs[1], false)], false);
        ups.push(if sim.get(nl.outputs[0]) { '1' } else { '0' });
    }
    println!("  input rises at cycle 2; up strobe: {ups}");
    println!("  (exactly w=5 cycles high -> RNL ramp of slope 1, height 5)");
    Ok(())
}
