//! Ablations over the measurement methodology itself.
//!
//! Three sensitivity studies defending choices DESIGN.md §5 calls out:
//!
//! 1. **Stimulus density vs power** — the power model must respond to
//!    workload activity (the reason calibration keeps a non-zero dynamic
//!    term instead of the better-fitting leakage-only model).
//! 2. **Wave-count convergence** — how many simulated waves until the
//!    power estimate stabilizes (justifies sim_waves = 8 default).
//! 3. **Node-scaling model vs measured 45nm ratios** — first-order
//!    constant-field scaling vs what the calibrated model predicts.
//!
//! Usage: cargo run --release --example ablation

use std::sync::Arc;

use tnn7::config::TnnConfig;
use tnn7::data::Dataset;
use tnn7::flow::compare::{run_sweep, SweepJob};
use tnn7::flow::{measure_with, Target};
use tnn7::netlist::column::{build_column, ColumnSpec};
use tnn7::netlist::Flavor;
use tnn7::ppa::scaling::{ratios, NodeScaling, COL_1024X16_45NM};
use tnn7::ppa::{power, timing};
use tnn7::sim::testbench::ColumnTestbench;
use tnn7::tech::{TechRegistry, ASAP7_TNN7};
use tnn7::tnn::stdp::RandPair;
use tnn7::tnn::Lfsr16;

fn main() -> anyhow::Result<()> {
    // One registry: every measurement below shares the same Arc'd
    // characterized library through the asap7-tnn7 backend.
    let registry = TechRegistry::builtin();
    let techctx = registry.get(ASAP7_TNN7)?;
    let lib = techctx.library();
    let tech = *techctx.params();
    let cfg = TnnConfig::default();
    let spec = ColumnSpec::benchmark(64, 8);

    // ---- 1. stimulus density vs power --------------------------------
    println!("== Ablation 1: input spike density vs column power (64x8 std) ==");
    println!("{:>10} {:>12} {:>14}", "density", "power uW", "dyn share");
    let (nl, ports) = build_column(lib, Flavor::Std, &spec)?;
    let t = timing::analyze(&nl, lib, &tech)?;
    let params = cfg.stdp_params();
    for density in [0.0f64, 0.25, 0.5, 0.75, 1.0] {
        let mut tb = ColumnTestbench::new(&nl, &ports, lib)?;
        let mut lfsr = Lfsr16::new(7);
        for wave in 0..6 {
            let s: Vec<i32> = (0..spec.p)
                .map(|j| {
                    let active = (j * 97 + wave * 13) % 100
                        < (density * 100.0) as usize;
                    if active {
                        (j % 8) as i32
                    } else {
                        tnn7::arch::INF
                    }
                })
                .collect();
            let rand: Vec<RandPair> =
                (0..spec.p * spec.q).map(|_| lfsr.draw_pair()).collect();
            tb.run_wave(&s, &rand, &params);
        }
        let pw = power::analyze(&nl, lib, &tech, tb.activity(), t.min_clock_ps);
        println!(
            "{:>9.0}% {:>12.3} {:>13.1}%",
            density * 100.0,
            pw.total_uw(),
            (pw.dynamic_uw + pw.clock_uw) / pw.total_uw() * 100.0
        );
    }
    println!("(leakage-only models would show a flat line — the dynamic");
    println!(" term is what lets Table I respond to real workloads)\n");

    // ---- 2. wave-count convergence ------------------------------------
    // The six wave counts are independent measurements of the same
    // target, so they run concurrently through the sweep executor;
    // deltas are computed from the in-order results afterwards.
    println!("== Ablation 2: power-estimate convergence vs simulated waves ==");
    println!("{:>8} {:>12} {:>10}", "waves", "power uW", "delta");
    let data = Arc::new(Dataset::generate(32, cfg.data_seed));
    let wave_counts = [1usize, 2, 4, 8, 16, 32];
    let jobs: Vec<SweepJob> = wave_counts
        .iter()
        .map(|&waves| {
            let mut c = cfg.clone();
            c.sim_waves = waves;
            SweepJob {
                label: format!("{waves} waves"),
                target: Target::column(Flavor::Std, spec),
                cfg: c,
            }
        })
        .collect();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4);
    let mut last = f64::NAN;
    for (&waves, res) in wave_counts
        .iter()
        .zip(run_sweep(&jobs, &registry, &data, threads))
    {
        let r = res.report?;
        let delta = if last.is_nan() {
            "-".to_string()
        } else {
            format!("{:+.1}%", (r.total.power_uw / last - 1.0) * 100.0)
        };
        println!("{:>8} {:>12.3} {:>10}", waves, r.total.power_uw, delta);
        last = r.total.power_uw;
    }
    println!("(default sim_waves = 8: within a few percent of the 32-wave\n\
              estimate at 4x less simulation time)\n");

    // ---- 3. node-scaling model vs measurement --------------------------
    println!("== Ablation 3: first-order 45nm->7nm scaling vs measured ==");
    let model = NodeScaling::n45_to_7();
    let spec1024 = ColumnSpec::benchmark(1024, 16);
    let r = measure_with(
        Target::column(Flavor::Custom, spec1024),
        &cfg,
        &techctx,
        &data,
    )?;
    let (rp, rt, ra) = ratios(&COL_1024X16_45NM, &r.total);
    println!(
        "{:<26} {:>9} {:>9} {:>9}",
        "", "power", "time", "area"
    );
    println!(
        "{:<26} {:>8.1}x {:>8.1}x {:>8.1}x",
        "constant-field model",
        model.power_factor(),
        model.delay_factor(),
        model.area_factor()
    );
    println!(
        "{:<26} {:>8.1}x {:>8.1}x {:>8.1}x",
        "measured (custom 1024x16)", rp, rt, ra
    );
    println!(
        "{:<26} {:>8.0}x {:>8.1}x {:>8.0}x",
        "paper-implied", 108.0, 1.4, 21.0
    );
    println!(
        "\n(the custom macros + architecture beat pure node scaling on power\n\
         — the paper's central 'custom cells matter' argument — while real\n\
         designs fall short of ideal s^2 area shrink)"
    );
    Ok(())
}
