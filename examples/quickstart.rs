//! Quickstart: load an AOT artifact, run a TNN column, watch it learn.
//!
//! Demonstrates the full three-layer stack on the smallest geometry
//! (8 synapses × 4 neurons, batch 16):
//!
//! 1. the rust runtime loads `artifacts/col_train_8x4.hlo.txt` (built
//!    once by `make artifacts`; python never runs here),
//! 2. a fixed input pattern is presented for a few waves,
//! 3. weights move toward the pattern (STDP capture) and the console
//!    shows spike times + the learned weight matrix,
//! 4. every step is cross-checked against the rust golden model.
//!
//! Usage: make artifacts && cargo run --release --example quickstart

use tnn7::arch::{INF, N_PARAMS};
use tnn7::runtime::Runtime;
use tnn7::tnn::column::column_fwd;
use tnn7::tnn::stdp::{stdp_step, StdpParams};
use tnn7::tnn::Lfsr16;

const P: usize = 8;
const Q: usize = 4;
const B: usize = 16;
const THETA: i32 = 6;

fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::load(std::path::Path::new("artifacts"))?;
    println!("PJRT platform: {}", rt.platform());

    // Input pattern: first half of the inputs spike early, rest silent.
    let mut s = vec![INF; B * P];
    for b in 0..B {
        for j in 0..P / 2 {
            s[b * P + j] = (j % 2) as i32; // spike at t=0 or t=1
        }
    }
    let mut w = vec![2i32; P * Q];
    let theta = [THETA];
    let params = StdpParams::from_probs(
        1.0,
        0.8,
        0.1,
        [1.0, 1.0, 0.75, 0.5, 0.5, 0.25, 0.25, 0.125],
        [0.125, 0.25, 0.25, 0.5, 0.5, 0.75, 1.0, 1.0],
    );
    let params_vec: Vec<i32> = params.to_vec();
    assert_eq!(params_vec.len(), N_PARAMS);
    let mut lfsr = Lfsr16::new(0x1234);

    println!("\ntraining a {P}x{Q} column on a fixed pattern:");
    for step in 0..6 {
        let mut rand = vec![0i32; B * P * Q * 2];
        lfsr.fill_i32(&mut rand);
        let out = rt.execute(
            "col_train_8x4",
            &[&s, &w, &theta, &rand, &params_vec],
        )?;
        let (pre, post, new_w) = (&out[0], &out[1], &out[2]);

        // Golden-model cross-check (batch semantics: forward frozen,
        // then sequential updates).
        let mut w_gold = w.clone();
        for b in 0..B {
            let sb = &s[b * P..(b + 1) * P];
            let (pre_g, post_g) = column_fwd(sb, &w, Q, THETA);
            assert_eq!(&pre[b * Q..(b + 1) * Q], &pre_g[..], "pre b={b}");
            assert_eq!(&post[b * Q..(b + 1) * Q], &post_g[..], "post b={b}");
            let pairs: Vec<(u16, u16)> = (0..P * Q)
                .map(|k| {
                    let base = (b * P * Q + k) * 2;
                    (rand[base] as u16, rand[base + 1] as u16)
                })
                .collect();
            stdp_step(sb, &post_g, &mut w_gold, &pairs, &params);
        }
        assert_eq!(new_w, &w_gold, "weights diverged from golden model");
        w = new_w.clone();

        let spike0: Vec<String> = (0..Q)
            .map(|i| {
                let t = post[i];
                if t == INF {
                    "-".into()
                } else {
                    t.to_string()
                }
            })
            .collect();
        println!(
            "  step {step}: post-WTA spikes (sample 0) = [{}]",
            spike0.join(", ")
        );
    }

    println!("\nlearned weights (rows = synapses, cols = neurons):");
    for j in 0..P {
        let row: Vec<String> =
            (0..Q).map(|i| w[j * Q + i].to_string()).collect();
        let active = if j < P / 2 { "active" } else { "silent" };
        println!("  syn {j} ({active}): [{}]", row.join(" "));
    }
    let active_sum: i32 = (0..P / 2).map(|j| w[j * Q]).sum();
    let silent_sum: i32 = (P / 2..P).map(|j| w[j * Q]).sum();
    println!(
        "\nSTDP captured the pattern: active-synapse weights {active_sum} vs silent {silent_sum}"
    );
    println!("quickstart OK (every step cross-checked against the golden model)");
    Ok(())
}
