//! Design-space exploration: the co-design loop the framework exists for.
//!
//! Two sweeps, both over the *behavioral* golden model (fast — no gate
//! sim, no PJRT), mirroring how [2] sized the prototype:
//!
//! 1. **Threshold sweep** — layer-1/layer-2 firing thresholds vs
//!    classification accuracy on the synthetic digit corpus.  Run with
//!    `--quick` for a coarse grid.
//! 2. **Column-geometry PPA sweep** — neurons-per-column vs area/power
//!    (gate-level, via the measurement driver) for a fixed input count:
//!    the hardware cost curve the threshold choice trades against.
//!    The design points run concurrently through the flow's parallel
//!    sweep executor (`--threads N`, default: up to 4 cores).
//! 3. **Utilization/aspect sweep** — the physical-design axis: one
//!    column placed at several floorplan utilization and aspect-ratio
//!    targets (the `place` stage, DESIGN.md §10), showing how die
//!    area, wirelength, and wire-aware PPA move as the floorplan
//!    tightens or stretches.
//!
//! Usage: cargo run --release --example design_space [-- --quick]
//!        [--threads N]

use std::sync::Arc;

use tnn7::config::TnnConfig;
use tnn7::data::Dataset;
use tnn7::flow::compare::{run_sweep, SweepJob};
use tnn7::flow::Target;
use tnn7::tech::TechRegistry;
use tnn7::netlist::column::ColumnSpec;
use tnn7::netlist::Flavor;
use tnn7::tnn::encoding::encode_image;
use tnn7::tnn::network::{rebase, Network};
use tnn7::tnn::{Lfsr16, StdpParams};

fn train_eval(
    theta1: i32,
    theta2: i32,
    w0: i32,
    epochs: usize,
    train: &Dataset,
    test: &Dataset,
    threshold: f32,
) -> f64 {
    let mut net = Network::prototype(theta1, theta2, w0);
    let params = StdpParams::default_training();
    let mut lfsr = Lfsr16::new(0xACE1);

    // Phase 1: layer-1 STDP.
    for _ in 0..epochs {
        for img in &train.images {
            let s1 = encode_image(img, threshold);
            let (_, post1) = net.l1.forward(&s1);
            net.l1.learn(&s1, &post1, &params, &mut lfsr);
        }
    }
    // Phase 2: layer-2 STDP (layer 1 frozen).
    for _ in 0..epochs {
        for img in &train.images {
            let s1 = encode_image(img, threshold);
            let (_, post1) = net.l1.forward(&s1);
            let s2 = rebase(&post1);
            let (_, post2) = net.l2.forward(&s2);
            net.l2.learn(&s2, &post2, &params, &mut lfsr);
        }
    }
    // Phase 3: vote calibration.
    for (img, &label) in train.images.iter().zip(&train.labels) {
        let s1 = encode_image(img, threshold);
        let post2 = net.forward(&s1);
        net.calibrate(&post2, label);
    }
    // Evaluate.
    let mut correct = 0;
    for (img, &label) in test.images.iter().zip(&test.labels) {
        let s1 = encode_image(img, threshold);
        let post2 = net.forward(&s1);
        if net.classify(&post2) == label {
            correct += 1;
        }
    }
    correct as f64 / test.len() as f64
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_train, n_test) = if quick { (120, 60) } else { (400, 200) };
    let train = Dataset::generate(n_train, 2020);
    let test = Dataset::generate(n_test, 2021);
    let threshold = 0.04f32;

    println!(
        "== Threshold sweep (behavioral prototype, {n_train} train / {n_test} test) =="
    );
    println!("{:>7} {:>7} {:>7} {:>9}", "theta1", "theta2", "w0", "accuracy");
    let t1s: &[i32] =
        if quick { &[12, 16, 20, 24] } else { &[8, 12, 16, 20, 28, 40] };
    let t2s: &[i32] = if quick { &[2, 3, 4, 6] } else { &[2, 3, 4, 6, 8] };
    let w0s: &[i32] = if quick { &[3, 5] } else { &[2, 3, 5] };
    let mut best = (0.0f64, 0i32, 0i32);
    for &t1 in t1s {
        for &t2 in t2s {
            for &w0 in w0s {
                let acc =
                    train_eval(t1, t2, w0, 2, &train, &test, threshold);
                println!(
                    "{:>7} {:>7} {:>7} {:>8.1}%",
                    t1, t2, w0, acc * 100.0
                );
                if acc > best.0 {
                    best = (acc, t1, t2);
                }
            }
        }
    }
    println!(
        "best: theta1={} theta2={} -> {:.1}% (paper: 93% on MNIST)",
        best.1,
        best.2,
        best.0 * 100.0
    );

    let threads = arg_value("--threads").unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(4)
    });
    println!(
        "\n== Column-geometry PPA sweep (gate-level, custom flavour, \
         {threads} threads) =="
    );
    println!(
        "{:>6} {:>6} {:>12} {:>12} {:>12}",
        "p", "q", "power uW", "time ns", "area mm2"
    );
    // One registry: all design points share the one characterized
    // asap7-tnn7 library behind an Arc.
    let registry = TechRegistry::builtin();
    let cfg = TnnConfig {
        sim_waves: if quick { 2 } else { 4 },
        ..TnnConfig::default()
    };
    let data = Arc::new(Dataset::generate(8, 7));
    // One flow run per design point — a sweep is a job list handed to
    // the parallel executor; reports come back in job order,
    // bit-identical to the serial loop.
    let qs = [4usize, 8, 12, 16];
    let jobs: Vec<SweepJob> = qs
        .iter()
        .map(|&q| {
            let spec = ColumnSpec::benchmark(32, q);
            SweepJob::of(Target::column(Flavor::Custom, spec), &cfg)
        })
        .collect();
    for (&q, res) in
        qs.iter().zip(run_sweep(&jobs, &registry, &data, threads))
    {
        let r = res.report?;
        println!(
            "{:>6} {:>6} {:>12.3} {:>12.2} {:>12.5}",
            32, q, r.total.power_uw, r.total.time_ns, r.total.area_mm2
        );
    }

    println!(
        "\n== Utilization / aspect sweep (placed 32x8 column, custom \
         flavour, {threads} threads) =="
    );
    println!(
        "{:>6} {:>7} {:>12} {:>12} {:>12} {:>12}",
        "util", "aspect", "die mm2", "hpwl mm", "power uW", "time ns"
    );
    let utils = if quick { vec![0.6, 0.8] } else { vec![0.6, 0.7, 0.8] };
    let aspects: Vec<f64> =
        if quick { vec![1.0] } else { vec![1.0, 2.0] };
    let points: Vec<(f64, f64)> = utils
        .iter()
        .flat_map(|&u| aspects.iter().map(move |&a| (u, a)))
        .collect();
    let spec = ColumnSpec::benchmark(32, 8);
    let jobs: Vec<SweepJob> = points
        .iter()
        .map(|&(u, a)| {
            let cfg = TnnConfig {
                place: true,
                place_util: u,
                place_aspect: a,
                ..cfg.clone()
            };
            SweepJob {
                label: format!("u{u:.2} a{a:.2}"),
                target: Target::column(Flavor::Custom, spec),
                cfg,
            }
        })
        .collect();
    for (&(u, a), res) in
        points.iter().zip(run_sweep(&jobs, &registry, &data, threads))
    {
        let r = res.report?;
        let placed = r.units[0].placed.expect("placed pipeline ran");
        println!(
            "{:>6.2} {:>7.2} {:>12.6} {:>12.3} {:>12.3} {:>12.2}",
            u,
            a,
            r.total.area_mm2,
            placed.hpwl_mm,
            r.total.power_uw,
            r.total.time_ns
        );
    }
    Ok(())
}

/// `--name N` lookup over the raw argv (tiny example-local parser).
fn arg_value(name: &str) -> Option<usize> {
    let args: Vec<String> = std::env::args().collect();
    let i = args.iter().position(|a| a == name)?;
    args.get(i + 1)?.parse().ok()
}
