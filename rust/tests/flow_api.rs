//! Flow API integration tests: target parsing, pipeline selection, and
//! the golden-snapshot shape of the per-stage JSON dumps for a tiny
//! 4x3 column.

use tnn7::config::TnnConfig;
use tnn7::flow::{parse_geometry, Flow, FlowContext, Target};
use tnn7::netlist::column::ColumnSpec;
use tnn7::netlist::Flavor;
use tnn7::runtime::json::Json;
use tnn7::tech::ASAP7_TNN7;

fn tiny_ctx() -> FlowContext {
    let cfg = TnnConfig { sim_waves: 2, ..TnnConfig::default() };
    let spec = ColumnSpec { p: 4, q: 3, theta: 7 };
    FlowContext::new(Target::column(Flavor::Custom, spec), cfg).unwrap()
}

#[test]
fn target_descriptor_round_trip() {
    let (p, q) = parse_geometry("32x12").unwrap();
    let t = Target::parse(
        "custom:7nm",
        tnn7::flow::Geometry::Column(ColumnSpec::benchmark(p, q)),
    )
    .unwrap();
    assert_eq!(t.flavor, Flavor::Custom);
    // Legacy node descriptors canonicalize to registry backends.
    assert_eq!(t.tech.as_str(), ASAP7_TNN7);
    assert_eq!(t.describe(), "custom:asap7-tnn7 32x12");
}

#[test]
fn pipeline_stage_ordering_is_enforced() {
    // The acceptance-criteria pipeline spells out to six stages.
    let flow = Flow::from_spec("elaborate,sta,sim,ppa").unwrap();
    assert_eq!(
        flow.stage_names(),
        vec!["elaborate", "sta", "simulate", "power", "area", "report"]
    );
    // Misordered and unknown specs fail before running anything.
    assert!(Flow::from_spec("ppa,elaborate").is_err());
    assert!(Flow::from_spec("elaborate,route").is_err());
}

#[test]
fn golden_stage_dump_snapshot_tiny_column() {
    let dir = std::env::temp_dir()
        .join(format!("tnn7_flow_dumps_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut ctx = tiny_ctx();
    let flow = Flow::from_spec("elaborate,sta,place,sim,ppa")
        .unwrap()
        .dump_dir(&dir);
    flow.run(&mut ctx).unwrap();

    // One artifact per stage, in pipeline order, carrying the backend
    // name so multi-technology sweeps into one directory never collide.
    // The place stage slots into the same NN_stage.BACKEND.json scheme.
    let expected = [
        "00_elaborate.asap7-tnn7.json",
        "01_sta.asap7-tnn7.json",
        "02_place.asap7-tnn7.json",
        "03_simulate.asap7-tnn7.json",
        "04_power.asap7-tnn7.json",
        "05_area.asap7-tnn7.json",
        "06_report.asap7-tnn7.json",
    ];
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    names.sort();
    assert_eq!(names, expected);

    let read = |name: &str| -> Json {
        let text = std::fs::read_to_string(dir.join(name)).unwrap();
        Json::parse(&text).unwrap()
    };

    // 00_elaborate: target + unit geometry + census.
    let j = read("00_elaborate.asap7-tnn7.json");
    assert_eq!(j.field("stage").unwrap().as_str().unwrap(), "elaborate");
    assert_eq!(
        j.field("target").unwrap().as_str().unwrap(),
        "custom:asap7-tnn7 4x3"
    );
    assert_eq!(j.field("tech").unwrap().as_str().unwrap(), "asap7-tnn7");
    let units = j.field("units").unwrap().as_arr().unwrap();
    assert_eq!(units.len(), 1);
    let u = &units[0];
    assert_eq!(u.field("p").unwrap().as_usize().unwrap(), 4);
    assert_eq!(u.field("q").unwrap().as_usize().unwrap(), 3);
    assert_eq!(u.field("replicas").unwrap().as_usize().unwrap(), 1);
    assert!(u.field("cells").unwrap().as_usize().unwrap() > 0);
    assert!(u.field("transistors").unwrap().as_usize().unwrap() > 100);

    // 01_sta: positive clock and wave time.
    let j = read("01_sta.asap7-tnn7.json");
    let u = &j.field("units").unwrap().as_arr().unwrap()[0];
    let dry_clock = u.field("min_clock_ps").unwrap().as_f64().unwrap();
    assert!(dry_clock > 0.0);
    assert!(u.field("wave_ns").unwrap().as_f64().unwrap() > 0.0);

    // 02_place: die dims, HPWL, congestion histogram, wire-aware clock.
    let j = read("02_place.asap7-tnn7.json");
    assert_eq!(j.field("stage").unwrap().as_str().unwrap(), "place");
    assert!(j.field("util").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.field("aspect").unwrap().as_f64().unwrap() > 0.0);
    let u = &j.field("units").unwrap().as_arr().unwrap()[0];
    let die_w = u.field("die_w_um").unwrap().as_f64().unwrap();
    let die_h = u.field("die_h_um").unwrap().as_f64().unwrap();
    let die_mm2 = u.field("die_mm2").unwrap().as_f64().unwrap();
    assert!(die_w > 0.0 && die_h > 0.0);
    assert!((die_mm2 - die_w * die_h * 1e-6).abs() < 1e-12);
    assert!(u.field("rows").unwrap().as_usize().unwrap() > 0);
    assert!(u.field("hpwl_mm").unwrap().as_f64().unwrap() > 0.0);
    let wet_clock =
        u.field("wire_min_clock_ps").unwrap().as_f64().unwrap();
    assert!(wet_clock > dry_clock, "wire delay must slow the clock");
    let cong = u.field("congestion").unwrap();
    let bins = cong.field("bins").unwrap().as_usize().unwrap();
    let counts = cong.field("counts").unwrap().as_arr().unwrap();
    assert_eq!(counts.len(), bins * bins);
    assert!(cong.field("max").unwrap().as_usize().unwrap() > 0);

    // 03_simulate: two waves of activity were recorded.
    let j = read("03_simulate.asap7-tnn7.json");
    assert_eq!(j.field("waves").unwrap().as_usize().unwrap(), 2);
    let u = &j.field("units").unwrap().as_arr().unwrap()[0];
    assert!(u.field("cycles").unwrap().as_usize().unwrap() > 0);
    assert!(u.field("toggles").unwrap().as_usize().unwrap() > 0);

    // 04_power: the split (wire included) adds up to the total.
    let j = read("04_power.asap7-tnn7.json");
    let u = &j.field("units").unwrap().as_arr().unwrap()[0];
    let total = u.field("total_uw").unwrap().as_f64().unwrap();
    let wire_uw = u.field("wire_uw").unwrap().as_f64().unwrap();
    let parts = u.field("dynamic_uw").unwrap().as_f64().unwrap()
        + u.field("clock_uw").unwrap().as_f64().unwrap()
        + u.field("leakage_uw").unwrap().as_f64().unwrap()
        + wire_uw;
    assert!(total > 0.0);
    assert!(wire_uw > 0.0, "placed run must attribute wire power");
    assert!((total - parts).abs() < 1e-9 * total.max(1.0));

    // 05_area: the placed die outline (matches the place artifact).
    let j = read("05_area.asap7-tnn7.json");
    let u = &j.field("units").unwrap().as_arr().unwrap()[0];
    assert!(u.field("cell_um2").unwrap().as_f64().unwrap() > 0.0);
    let area_die = u.field("die_mm2").unwrap().as_f64().unwrap();
    assert!((area_die - die_mm2).abs() < 1e-15);

    // 06_report: composed totals present, tagged with backend + node,
    // with the per-unit physical summary.
    let j = read("06_report.asap7-tnn7.json");
    assert_eq!(j.field("stage").unwrap().as_str().unwrap(), "report");
    assert_eq!(j.field("tech").unwrap().as_str().unwrap(), "asap7-tnn7");
    assert_eq!(j.field("node").unwrap().as_str().unwrap(), "7nm");
    let u = &j.field("units").unwrap().as_arr().unwrap()[0];
    let placed = u.field("placed").unwrap();
    assert!((placed.field("die_w_um").unwrap().as_f64().unwrap() - die_w)
        .abs()
        < 1e-12);
    assert!(placed.field("hpwl_mm").unwrap().as_f64().unwrap() > 0.0);
    let total = j.field("total").unwrap();
    assert!(total.field("power_uw").unwrap().as_f64().unwrap() > 0.0);
    assert!(total.field("time_ns").unwrap().as_f64().unwrap() > 0.0);
    assert!(total.field("area_mm2").unwrap().as_f64().unwrap() > 0.0);
    assert!(total.field("edp_nj_ns").unwrap().as_f64().unwrap() > 0.0);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn flow_report_matches_measure_wrapper() {
    // The coordinator wrapper is a thin shim over the same pipeline, so
    // identical inputs must give identical numbers.
    use std::sync::Arc;
    use tnn7::cells::{Library, TechParams};
    use tnn7::coordinator::measure::measure_column;
    use tnn7::data::Dataset;
    use tnn7::tech::TechRegistry;

    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    let cfg = TnnConfig { sim_waves: 2, ..TnnConfig::default() };
    let data = Dataset::generate(4, cfg.data_seed);
    let spec = ColumnSpec { p: 8, q: 4, theta: 10 };

    let m = measure_column(&lib, &tech, Flavor::Std, &spec, &cfg, &data)
        .unwrap();
    // The registry's asap7-tnn7 backend is the same substrate the
    // wrapper bundles ad hoc: identical characterized library, same
    // calibrated constants.
    let registry = TechRegistry::builtin();
    let techctx = registry.get(ASAP7_TNN7).unwrap();
    let r = tnn7::flow::measure_with(
        Target::column(Flavor::Std, spec),
        &cfg,
        &techctx,
        &Arc::new(data),
    )
    .unwrap();
    assert_eq!(m.ppa.power_uw, r.total.power_uw);
    assert_eq!(m.ppa.time_ns, r.total.time_ns);
    assert_eq!(m.ppa.area_mm2, r.total.area_mm2);
    assert_eq!(m.transistors, r.units[0].transistors);
}
