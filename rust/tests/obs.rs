//! Integration tests for the observability layer (DESIGN.md §15):
//! registry exactness under concurrency, the Prometheus exposition
//! golden snapshot, span parentage through the public API, and the
//! Chrome trace-event export schema.

use std::sync::Mutex;

use tnn7::obs::{
    self, chrome_trace, profile, set_tracing, take_spans, Registry,
};
use tnn7::runtime::json::Json;

/// Tracing is process-global; span tests serialize on this and run
/// their spans on dedicated threads with unique site names.
static TRACE_GUARD: Mutex<()> = Mutex::new(());

#[test]
fn concurrent_counters_and_histograms_are_exact() {
    let r = Registry::new();
    let threads = 8usize;
    let per_thread = 10_000u64;
    std::thread::scope(|s| {
        for t in 0..threads {
            let c = r.counter("tnn7_t_hits_total", "hits", &[]);
            let worker = t.to_string();
            let lc = r.counter(
                "tnn7_t_labeled_total",
                "labeled",
                &[("worker", worker.as_str())],
            );
            let h = r.histogram("tnn7_t_us", "latency", &[]);
            s.spawn(move || {
                for i in 0..per_thread {
                    c.inc();
                    lc.add(2);
                    h.observe(i % 7);
                }
            });
        }
    });
    let total = threads as u64 * per_thread;
    assert_eq!(r.counter_value("tnn7_t_hits_total", &[]), total);
    let series = r.counter_series("tnn7_t_labeled_total");
    assert_eq!(series.len(), threads);
    for (labels, v) in series {
        assert_eq!(v, 2 * per_thread, "series {labels:?}");
    }
    let h = r.histogram("tnn7_t_us", "latency", &[]);
    assert_eq!(h.count(), total);
    // sum of (0..7 cycling) over per_thread draws, times threads.
    let cycle: u64 = (0..per_thread).map(|i| i % 7).sum();
    assert_eq!(h.sum(), threads as u64 * cycle);
    // Buckets: 0 and 1 land in bucket 0, 2 in bucket 1, 3..=4 in
    // bucket 2, 5..=6 in bucket 3 — cumulative counts must cover all.
    let counts = h.bucket_counts();
    assert_eq!(counts.iter().sum::<u64>(), total);
    assert_eq!(counts[4..].iter().sum::<u64>(), 0, "nothing above 8us");
}

#[test]
fn prometheus_exposition_golden() {
    let r = Registry::new();
    r.counter("tnn7_demo_total", "Demo counter", &[("stage", "sta")])
        .add(3);
    r.counter("tnn7_demo_total", "Demo counter", &[("stage", "sim")])
        .inc();
    r.gauge("tnn7_demo_depth", "Demo gauge", &[]).set(-2);
    let h = r.histogram(
        "tnn7_demo_us",
        "Demo histogram",
        &[("endpoint", "/flow")],
    );
    for v in [1, 3, 100] {
        h.observe(v);
    }
    let mut expect = String::from(
        "# HELP tnn7_demo_depth Demo gauge\n\
         # TYPE tnn7_demo_depth gauge\n\
         tnn7_demo_depth -2\n\
         # HELP tnn7_demo_total Demo counter\n\
         # TYPE tnn7_demo_total counter\n\
         tnn7_demo_total{stage=\"sim\"} 1\n\
         tnn7_demo_total{stage=\"sta\"} 3\n\
         # HELP tnn7_demo_us Demo histogram\n\
         # TYPE tnn7_demo_us histogram\n",
    );
    // 25 finite power-of-two buckets then +Inf, cumulative: the 1us
    // observation fills le=1, 3us lands in (2,4], 100us in (64,128].
    for i in 0..25u32 {
        let le = 1u64 << i;
        let cum = match le {
            1 | 2 => 1,
            4..=64 => 2,
            _ => 3,
        };
        expect.push_str(&format!(
            "tnn7_demo_us_bucket{{endpoint=\"/flow\",le=\"{le}\"}} {cum}\n"
        ));
    }
    expect.push_str(
        "tnn7_demo_us_bucket{endpoint=\"/flow\",le=\"+Inf\"} 3\n\
         tnn7_demo_us_sum{endpoint=\"/flow\"} 104\n\
         tnn7_demo_us_count{endpoint=\"/flow\"} 3\n",
    );
    assert_eq!(r.prometheus_text(), expect);
}

#[test]
fn span_parentage_through_public_api() {
    let _g = TRACE_GUARD.lock().unwrap();
    set_tracing(true);
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut outer = obs::span("it.outer");
            outer.attr("point", "64x8");
            {
                let _inner = obs::span("it.inner");
            }
        })
        .join()
        .unwrap();
    });
    set_tracing(false);
    let spans = take_spans();
    let outer = spans.iter().find(|r| r.name == "it.outer").unwrap();
    let inner = spans.iter().find(|r| r.name == "it.inner").unwrap();
    assert_eq!(outer.parent, 0);
    assert_eq!(inner.parent, outer.id);
    assert_eq!(outer.attrs, vec![("point", "64x8".to_string())]);
    assert!(outer.dur_us >= inner.dur_us.saturating_sub(1));
    // The profile view sees both sites, each with one span.
    let rows = profile(&spans);
    assert!(rows
        .iter()
        .any(|r| r.name == "it.outer" && r.count == 1));
}

#[test]
fn chrome_trace_export_schema() {
    let _g = TRACE_GUARD.lock().unwrap();
    set_tracing(true);
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut sp = obs::span("ct.stage");
            sp.attr("stage", "simulate");
            let _child = obs::span("ct.worker");
        })
        .join()
        .unwrap();
    });
    set_tracing(false);
    let spans: Vec<_> = take_spans()
        .into_iter()
        .filter(|r| r.name.starts_with("ct."))
        .collect();
    assert_eq!(spans.len(), 2);
    // Round-trip through the parser, exactly as the CI smoke step
    // consumes `tnn7 flow --trace`.
    let doc = Json::parse(&chrome_trace(&spans).to_string_pretty())
        .expect("trace JSON parses");
    let events = doc.field("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), 3, "metadata event + 2 spans");
    assert_eq!(
        events[0].field("ph").unwrap().as_str().unwrap(),
        "M",
        "first event is process metadata"
    );
    let mut saw_stage_attr = false;
    for ev in &events[1..] {
        assert_eq!(ev.field("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(ev.field("cat").unwrap().as_str().unwrap(), "tnn7");
        assert!(ev.field("ts").unwrap().as_usize().is_ok());
        assert!(ev.field("dur").unwrap().as_usize().is_ok());
        assert!(ev.field("tid").unwrap().as_usize().is_ok());
        let args = ev.field("args").unwrap();
        assert!(args.field("span_id").unwrap().as_usize().unwrap() > 0);
        assert!(args.field("parent").is_ok());
        if ev.field("name").unwrap().as_str().unwrap() == "ct.stage" {
            assert_eq!(
                args.field("stage").unwrap().as_str().unwrap(),
                "simulate"
            );
            saw_stage_attr = true;
        }
    }
    assert!(saw_stage_attr, "attrs travel into event args");
}
