//! Differential conformance suite for the interop layer (DESIGN.md
//! §12): every netlist flavour × technology backend must survive
//! export → re-import → re-simulate **bit-identically** on the scalar,
//! packed, and sharded engines; VCD recorded by one engine must replay
//! into another with identical bytes and toggle counts; and the pure
//! [`column_wave_ticks`] schedule is pinned against the inline
//! testbench so the two descriptions of the wave protocol can never
//! drift.  Golden byte snapshots for the three builtin backends live
//! under `tests/golden/interop/` (regenerate with `TNN7_BLESS=1`).

use std::path::{Path, PathBuf};

use tnn7::arch::T_STEPS;
use tnn7::config::TnnConfig;
use tnn7::flow::cache::StageCache;
use tnn7::flow::{Flow, FlowContext, Target};
use tnn7::interop::vcd::column_wave_ticks;
use tnn7::interop::{
    export_blif, export_verilog, import_blif, parse_vcd, record_engine,
    text_digest,
};
use tnn7::netlist::column::{build_column, ColumnPorts, ColumnSpec};
use tnn7::netlist::layer::{build_layer_netlist, LayerSpec};
use tnn7::netlist::{Builder, Flavor, NetId, Netlist};
use tnn7::runtime::json::Json;
use tnn7::sim::testbench::{PackedColumnTestbench, WAVE_LEN};
use tnn7::sim::{
    PackedSimulator, ShardedSimulator, SimEngine, SimTick, Simulator,
};
use tnn7::tech::{
    resolve_standalone, ASAP7_BASELINE, ASAP7_TNN7, N45_PROJECTED,
};
use tnn7::tnn::stdp::{RandPair, StdpParams};
use tnn7::tnn::INF;

/// Builtin backends with the column flavours their libraries can
/// elaborate (the baseline library carries no custom macros).
fn backend_flavors() -> [(&'static str, &'static [Flavor]); 3] {
    [
        (ASAP7_BASELINE, &[Flavor::Std][..]),
        (ASAP7_TNN7, &[Flavor::Std, Flavor::Custom][..]),
        (N45_PROJECTED, &[Flavor::Std, Flavor::Custom][..]),
    ]
}

fn xorshift(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x
}

/// Random per-lane wave stimulus in the testbench's encoding: spike
/// times in `[0, 8)` with 1-in-8 "never spikes" (`INF`), and one raw
/// 16-bit pair per synapse for the Bernoulli random vector generator.
#[allow(clippy::type_complexity)]
fn wave_stimulus(
    p: usize,
    q: usize,
    lanes: usize,
    state: &mut u64,
) -> (Vec<Vec<i32>>, Vec<Vec<RandPair>>) {
    let stim = (0..lanes)
        .map(|_| {
            (0..p)
                .map(|_| {
                    let v = xorshift(state);
                    if v & 7 == 7 {
                        INF
                    } else {
                        (v % 8) as i32
                    }
                })
                .collect()
        })
        .collect();
    let rand = (0..lanes)
        .map(|_| {
            (0..p * q)
                .map(|_| {
                    let v = xorshift(state);
                    (v as u16, (v >> 16) as u16)
                })
                .collect()
        })
        .collect();
    (stim, rand)
}

/// `waves` consecutive random waves as one flat schedule (weights
/// carry across wave boundaries, exactly as in training).
fn wave_schedule(
    ports: &ColumnPorts,
    q: usize,
    waves: usize,
    lanes: usize,
    state: &mut u64,
) -> Vec<SimTick> {
    let params = StdpParams::default_training();
    let p = ports.x.len();
    let mut ticks = Vec::with_capacity(waves * WAVE_LEN);
    for _ in 0..waves {
        let (stim, rand) = wave_stimulus(p, q, lanes, state);
        ticks.extend(column_wave_ticks(ports, &stim, &rand, &params));
    }
    ticks
}

/// Committed 3-bit weight register of one lane, read through the port
/// map (valid on any engine that can observe arbitrary nets).
fn read_weights<E: SimEngine>(
    eng: &E,
    ports: &ColumnPorts,
    lane: usize,
) -> Vec<i32> {
    ports
        .weights
        .iter()
        .map(|bits| {
            (eng.lane_value(bits[0], lane) as i32)
                | (eng.lane_value(bits[1], lane) as i32) << 1
                | (eng.lane_value(bits[2], lane) as i32) << 2
        })
        .collect()
}

/// Assert two engines agree on **every** net in **every** lane.
fn assert_nets_identical<A: SimEngine, B: SimEngine>(
    a: &A,
    b: &B,
    nl: &Netlist,
    what: &str,
) {
    assert_eq!(a.lanes(), b.lanes());
    for id in 0..nl.n_nets() as u32 {
        for l in 0..a.lanes() {
            assert_eq!(
                a.lane_value(NetId(id), l),
                b.lane_value(NetId(id), l),
                "{what}: net n{id} lane {l} diverged"
            );
        }
    }
}

/// Tentpole headline: for every backend × flavour, a column netlist
/// exported to BLIF, re-imported, and re-simulated is bit-identical to
/// the original — on the packed engine (8 lanes, full-state compare +
/// byte-identical VCD + identical activity) and the scalar engine.
#[test]
fn blif_roundtrip_resimulates_bit_identically() {
    for (backend, flavors) in backend_flavors() {
        let tech = resolve_standalone(backend).unwrap();
        let lib = tech.library();
        for &flavor in flavors {
            let spec = ColumnSpec { p: 4, q: 3, theta: 7 };
            let (nl, ports) = build_column(lib, flavor, &spec).unwrap();
            let text = export_blif(&nl, lib);
            let back = import_blif(&text, lib).unwrap();
            assert_eq!(
                export_blif(&back, lib),
                text,
                "{backend}/{flavor:?}: export→import→export fixpoint"
            );

            let mut state = 0x7a11_ad00 ^ text_digest(backend);
            let ticks =
                wave_schedule(&ports, spec.q, 2, 8, &mut state);

            // Packed, 8 lanes: drive original and re-import through the
            // same schedule; recordings, full net state, per-lane
            // weights, and per-instance activity all match exactly.
            let mut p1 = PackedSimulator::new(&nl, lib, 8).unwrap();
            let mut p2 = PackedSimulator::new(&back, lib, 8).unwrap();
            let v1 = record_engine(&mut p1, &nl, &ticks);
            let v2 = record_engine(&mut p2, &back, &ticks);
            assert_eq!(v1, v2, "{backend}/{flavor:?}: packed VCD");
            assert_nets_identical(&p1, &p2, &nl, backend);
            for l in 0..8 {
                assert_eq!(
                    read_weights(&p1, &ports, l),
                    read_weights(&p2, &ports, l),
                    "{backend}/{flavor:?}: lane {l} weights"
                );
            }
            assert_eq!(p1.activity().toggles, p2.activity().toggles);
            assert_eq!(
                p1.activity().clock_ticks,
                p2.activity().clock_ticks
            );
            assert_eq!(p1.activity().cycles, p2.activity().cycles);

            // Scalar: lane-0 of the same program, byte-identical VCD.
            let scalar: Vec<SimTick> = ticks
                .iter()
                .map(|t| SimTick {
                    inputs: t
                        .inputs
                        .iter()
                        .map(|&(n, w)| (n, w & 1))
                        .collect(),
                    gclk_edge: t.gclk_edge,
                })
                .collect();
            let mut s1 = Simulator::new(&nl, lib).unwrap();
            let mut s2 = Simulator::new(&back, lib).unwrap();
            assert_eq!(
                record_engine(&mut s1, &nl, &scalar),
                record_engine(&mut s2, &back, &scalar),
                "{backend}/{flavor:?}: scalar VCD"
            );
            assert_nets_identical(&s1, &s2, &nl, backend);
        }
    }
}

/// The sharded engine closes the loop on a multi-column layer netlist
/// (region-tagged columns are its partition seams): the re-imported
/// netlist re-simulates bit-identically there too.
#[test]
fn blif_roundtrip_resimulates_on_the_sharded_engine() {
    let tech = resolve_standalone(ASAP7_TNN7).unwrap();
    let lib = tech.library();
    let spec = LayerSpec {
        cols: 2,
        column: ColumnSpec { p: 3, q: 2, theta: 5 },
    };
    let (nl, ports) =
        build_layer_netlist(lib, Flavor::Custom, &spec).unwrap();
    let text = export_blif(&nl, lib);
    let back = import_blif(&text, lib).unwrap();
    assert_eq!(export_blif(&back, lib), text);

    // Per-column wave schedules merged tick-by-tick into one layer
    // schedule (the columns share the wave clock).
    let mut state = 0x5eed_cafe_f00du64;
    let per_col: Vec<Vec<SimTick>> = ports
        .columns
        .iter()
        .map(|cp| wave_schedule(cp, spec.column.q, 2, 4, &mut state))
        .collect();
    let mut ticks = per_col[0].clone();
    for col in &per_col[1..] {
        for (t, extra) in ticks.iter_mut().zip(col) {
            assert_eq!(t.gclk_edge, extra.gclk_edge);
            t.inputs.extend(extra.inputs.iter().copied());
        }
    }

    let mut a = ShardedSimulator::new(&nl, lib, 4, 2, &[]).unwrap();
    let mut b = ShardedSimulator::new(&back, lib, 4, 2, &[]).unwrap();
    let va = record_engine(&mut a, &nl, &ticks);
    let vb = record_engine(&mut b, &back, &ticks);
    assert_eq!(va, vb, "sharded VCD of original vs re-import");
    assert_nets_identical(&a, &b, &nl, "sharded layer");
    assert_eq!(a.activity().toggles, b.activity().toggles);
    assert_eq!(a.activity().cycles, b.activity().cycles);

    // The recording watched the layer's voter outputs; votes toggled.
    let doc = parse_vcd(&va).unwrap();
    assert_eq!(doc.lanes, 4);
    assert_eq!(doc.ticks, ticks.len());
    assert!(
        doc.toggles().iter().sum::<u64>() > 0,
        "layer waves produced no observable switching"
    );
}

/// Satellite (d): a 64-lane packed recording re-ingested as stimulus
/// replays **byte-identically** on a fresh packed engine *and* on the
/// sharded engine — identical toggle counts per var and identical
/// committed weights (the classification-relevant state) per lane.
#[test]
fn vcd_replay_crosses_engines_at_64_lanes() {
    let tech = resolve_standalone(ASAP7_TNN7).unwrap();
    let lib = tech.library();
    let spec = ColumnSpec { p: 4, q: 3, theta: 7 };
    let (nl, ports) = build_column(lib, Flavor::Custom, &spec).unwrap();
    let mut state = 0xdead_beef_1234_5678u64;
    let ticks = wave_schedule(&ports, spec.q, 2, 64, &mut state);

    let mut rec = PackedSimulator::new(&nl, lib, 64).unwrap();
    let text = record_engine(&mut rec, &nl, &ticks);
    let doc = parse_vcd(&text).unwrap();
    assert_eq!((doc.lanes, doc.ticks), (64, ticks.len()));
    // Wave outputs made it into the recording.
    assert!(doc.var_index("lane0", "fire[0]").is_some());
    assert!(doc.var_index("lane63", "grant[2]").is_some());

    let replay = doc.stimulus(&nl).unwrap();
    assert_eq!(replay.len(), ticks.len());

    let mut packed = PackedSimulator::new(&nl, lib, 64).unwrap();
    let again = record_engine(&mut packed, &nl, &replay);
    assert_eq!(text, again, "packed replay must re-record identically");

    let mut sharded =
        ShardedSimulator::new(&nl, lib, 64, 3, &[]).unwrap();
    let cross = record_engine(&mut sharded, &nl, &replay);
    assert_eq!(text, cross, "sharded replay must re-record identically");
    assert_eq!(parse_vcd(&cross).unwrap().toggles(), doc.toggles());

    // Classification outputs: the weights every engine committed agree
    // lane-for-lane with the engine that produced the recording.
    for l in 0..64 {
        let w = read_weights(&rec, &ports, l);
        assert_eq!(w, read_weights(&packed, &ports, l), "lane {l}");
        assert_eq!(w, read_weights(&sharded, &ports, l), "lane {l}");
    }
}

/// Drift guard: [`column_wave_ticks`] (the wave protocol as data) and
/// `PackedColumnTestbench::run_wave_lanes` (the wave protocol inline)
/// drive byte-for-byte the same program — same spike times, same
/// committed weights, and the same per-instance activity counters over
/// a 3-wave training run, for both flavours.
#[test]
fn wave_schedule_matches_the_inline_testbench() {
    let tech = resolve_standalone(ASAP7_TNN7).unwrap();
    let lib = tech.library();
    let params = StdpParams::default_training();
    let lanes = 8;
    for flavor in [Flavor::Std, Flavor::Custom] {
        let spec = ColumnSpec { p: 5, q: 3, theta: 9 };
        let (nl, ports) = build_column(lib, flavor, &spec).unwrap();
        let mut tb =
            PackedColumnTestbench::new(&nl, &ports, lib, lanes).unwrap();
        let mut sim = PackedSimulator::new(&nl, lib, lanes).unwrap();
        let mut state = 0x0dd_ba11 ^ (flavor as u64 + 1);
        for wave in 0..3 {
            let (stim, rand) =
                wave_stimulus(spec.p, spec.q, lanes, &mut state);
            let results = tb.run_wave_lanes(&stim, &rand, &params);

            let ticks = column_wave_ticks(&ports, &stim, &rand, &params);
            assert_eq!(ticks.len(), WAVE_LEN);
            let mut pre = vec![vec![INF; spec.q]; lanes];
            let mut post = vec![vec![INF; spec.q]; lanes];
            for (cyc, tick) in ticks.iter().enumerate() {
                sim.tick(&tick.inputs, tick.gclk_edge);
                if cyc < T_STEPS as usize {
                    for (l, (pre_l, post_l)) in
                        pre.iter_mut().zip(post.iter_mut()).enumerate()
                    {
                        for i in 0..spec.q {
                            if pre_l[i] == INF
                                && sim.get(ports.fires[i], l)
                            {
                                pre_l[i] = cyc as i32;
                            }
                            if post_l[i] == INF
                                && sim.get(ports.grants[i], l)
                            {
                                post_l[i] = cyc as i32;
                            }
                        }
                    }
                }
            }
            for (l, res) in results.iter().enumerate() {
                assert_eq!(
                    res.pre, pre[l],
                    "{flavor:?} wave {wave} lane {l}: pre spikes"
                );
                assert_eq!(
                    res.post, post[l],
                    "{flavor:?} wave {wave} lane {l}: post spikes"
                );
                assert_eq!(
                    res.weights,
                    read_weights(&sim, &ports, l),
                    "{flavor:?} wave {wave} lane {l}: weights"
                );
            }
        }
        // Whole-run activity: identical stimulus ⇒ identical counters.
        let a = tb.activity();
        let b = SimEngine::activity(&sim);
        assert_eq!(a.toggles, b.toggles, "{flavor:?}: toggles");
        assert_eq!(a.clock_ticks, b.clock_ticks, "{flavor:?}");
        assert_eq!(a.cycles, b.cycles, "{flavor:?}");
    }
}

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/interop")
}

/// Satellite (c): committed byte snapshots of all three interchange
/// formats for every builtin backend.  The design is a tiny two-gate
/// netlist whose name carries the backend, so each snapshot pins the
/// full export path (headers, identifier mangling, model bodies,
/// change-only VCD emission) byte-for-byte.  `TNN7_BLESS=1` rewrites
/// the snapshots from the current exporters.
#[test]
fn golden_interchange_snapshots_are_byte_stable() {
    let dir = golden_dir();
    let bless = std::env::var_os("TNN7_BLESS").is_some();
    if bless {
        std::fs::create_dir_all(&dir).unwrap();
    }
    for (backend, _) in backend_flavors() {
        let tech = resolve_standalone(backend).unwrap();
        let lib = tech.library();
        let name = format!("golden_{}", backend.replace('-', "_"));
        let mut b = Builder::new(&name, lib);
        let a = b.input("a");
        let c = b.input("b");
        let x = b.nand2(a, c);
        let y = b.xor2(x, a);
        b.output(y, "y");
        let nl = b.finish().unwrap();

        let blif = export_blif(&nl, lib);
        let verilog = export_verilog(&nl, lib);
        let ticks: Vec<SimTick> = [(0u64, 0u64), (1, 0), (1, 1), (0, 1)]
            .iter()
            .map(|&(va, vb)| SimTick {
                inputs: vec![(a, va), (c, vb)],
                gclk_edge: false,
            })
            .collect();
        let mut sim = PackedSimulator::new(&nl, lib, 1).unwrap();
        let vcd = record_engine(&mut sim, &nl, &ticks);

        for (ext, text) in
            [("blif", &blif), ("v", &verilog), ("vcd", &vcd)]
        {
            let path = dir.join(format!("{backend}.{ext}"));
            if bless {
                std::fs::write(&path, text).unwrap();
                continue;
            }
            let want =
                std::fs::read_to_string(&path).unwrap_or_else(|e| {
                    panic!(
                        "missing golden {} ({e}); regenerate with \
                         TNN7_BLESS=1 cargo test",
                        path.display()
                    )
                });
            assert_eq!(
                text,
                &want,
                "golden {} drifted (TNN7_BLESS=1 regenerates)",
                path.display()
            );
        }

        // The snapshots themselves satisfy the interop contracts.
        let back = import_blif(&blif, lib).unwrap();
        assert_eq!(export_blif(&back, lib), blif);
        let doc = parse_vcd(&vcd).unwrap();
        assert_eq!((doc.lanes, doc.ticks), (1, 4));
        assert_eq!(doc.design, name);
        // y = nand(a,b) ^ a over the four input patterns.
        let yv = doc.var_index("lane0", "y").unwrap();
        let got: Vec<bool> =
            (0..4).map(|t| doc.samples[t][yv]).collect();
        assert_eq!(got, [true, false, true, true]);
    }
}

/// The optional `export` flow stage: opt-in only, dumps sizes and
/// FNV fingerprints (not megabytes of text), and participates in the
/// stage cache like any other pure stage.
#[test]
fn export_stage_dumps_fingerprints_and_caches() {
    // Opt-in: the standard pipelines never include it.
    assert!(!Flow::standard().stage_names().contains(&"export"));
    assert!(!Flow::placed().stage_names().contains(&"export"));

    let cfg = TnnConfig { sim_waves: 2, ..TnnConfig::default() };
    let spec = ColumnSpec { p: 4, q: 3, theta: 7 };
    let target = || Target::column(Flavor::Custom, spec);
    let dir = std::env::temp_dir()
        .join(format!("tnn7_conformance_dumps_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut ctx = FlowContext::new(target(), cfg.clone()).unwrap();
    let flow =
        Flow::from_spec("elaborate,export").unwrap().dump_dir(&dir);
    flow.run(&mut ctx).unwrap();
    assert_eq!(ctx.exported.len(), 1);
    let e = &ctx.exported[0];
    assert!(e.blif.starts_with("# tnn7 blif 1\n"));
    assert!(e.verilog.starts_with("// tnn7 structural verilog 1\n"));

    let text = std::fs::read_to_string(
        dir.join("01_export.asap7-tnn7.json"),
    )
    .unwrap();
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.field("stage").unwrap().as_str().unwrap(), "export");
    assert_eq!(
        j.field("format_version").unwrap().as_usize().unwrap(),
        1
    );
    let units = j.field("units").unwrap().as_arr().unwrap();
    assert_eq!(units.len(), 1);
    let u = &units[0];
    assert_eq!(u.field("label").unwrap().as_str().unwrap(), e.label);
    assert_eq!(
        u.field("blif_bytes").unwrap().as_usize().unwrap(),
        e.blif.len()
    );
    let want_fnv = format!("{:016x}", text_digest(&e.blif));
    assert_eq!(
        u.field("blif_fnv").unwrap().as_str().unwrap(),
        want_fnv.as_str()
    );
    assert_eq!(
        u.field("verilog_bytes").unwrap().as_usize().unwrap(),
        e.verilog.len()
    );
    assert_eq!(
        u.field("roundtrip").unwrap().as_str().unwrap(),
        "byte-fixpoint"
    );

    // Cache: a second context replays both stages from memory and
    // restores identical export artifacts.
    let cache = StageCache::in_memory(32);
    let flow2 = Flow::from_spec("elaborate,export").unwrap();
    let mut c1 = FlowContext::new(target(), cfg.clone()).unwrap();
    let t1 = flow2.run_cached(&mut c1, Some(&cache)).unwrap();
    assert_eq!(t1.executed(), 2);
    let mut c2 = FlowContext::new(target(), cfg).unwrap();
    let t2 = flow2.run_cached(&mut c2, Some(&cache)).unwrap();
    assert_eq!((t2.executed(), t2.mem_hits()), (0, 2));
    assert_eq!(c2.exported[0].blif, c1.exported[0].blif);
    assert_eq!(c2.exported[0].verilog, c1.exported[0].verilog);

    std::fs::remove_dir_all(&dir).ok();
}
