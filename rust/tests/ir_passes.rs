//! Semantics-preservation properties of the IR pass framework and the
//! compiled tape engine (DESIGN.md §14).
//!
//! Every pass — alone and composed into the full pipeline — must be
//! bit-exact against the packed interpreter: net values every tick,
//! spikes/weights of every wave, and the aggregated activity counters,
//! at any lane/thread/shard count.  The interpreters are the oracle;
//! the compiled engine never gets to be "close".
//!
//! Like `tests/proptests.rs`, these are seeded randomized sweeps (the
//! offline vendor set has no `proptest`): failure messages carry the
//! seed, making every case reproducible.

use tnn7::arch::INF;
use tnn7::cells::Library;
use tnn7::data::digits::XorShift;
use tnn7::fault::{
    fingerprint, run_campaign, CampaignEngine, CampaignSpec, FaultClass,
};
use tnn7::ir::PassManager;
use tnn7::netlist::column::{build_column, ColumnSpec};
use tnn7::netlist::{Builder, ClockDomain, Flavor, NetId, Netlist};
use tnn7::sim::testbench::{
    run_waves_parallel, run_waves_parallel_compiled, ColumnTestbench,
    CompiledColumnTestbench, PackedColumnTestbench,
};
use tnn7::sim::{CompiledSimulator, PackedSimulator, ShardedSimulator};
use tnn7::tnn::stdp::{RandPair, StdpParams};
use tnn7::tnn::Lfsr16;

/// Every pipeline the properties sweep: each pass alone, the empty
/// pipeline, the canonical full pipeline, and one partial composition.
const PIPELINES: [&str; 7] =
    ["none", "fold", "dce", "coalesce", "resched", "fold,dce", "all"];

fn rng(seed: u64) -> XorShift {
    XorShift::new(seed)
}

/// Random feed-forward netlist mixing combinational gates with aclk-
/// and gclk-domain registers (same shape as the proptests generator:
/// no combinational cycles by construction).
fn random_netlist(lib: &Library, seed: u64) -> Netlist {
    let mut r = rng(seed);
    let mut b = Builder::new("rnd", lib);
    let n_in = 2 + (r.next_u64() % 5) as usize;
    let mut pool: Vec<NetId> =
        (0..n_in).map(|i| b.input(format!("x{i}"))).collect();
    let ops = 10 + (r.next_u64() % 40) as usize;
    for _ in 0..ops {
        let a = pool[(r.next_u64() as usize) % pool.len()];
        let c = pool[(r.next_u64() as usize) % pool.len()];
        let d = pool[(r.next_u64() as usize) % pool.len()];
        let n = match r.next_u64() % 8 {
            0 => b.inv(a),
            1 => b.and2(a, c),
            2 => b.or2(a, c),
            3 => b.xor2(a, c),
            4 => b.maj3(a, c, d),
            5 => b.mux2(a, c, d),
            6 => b.dff(a, ClockDomain::Aclk),
            _ => b.dff(a, ClockDomain::Gclk),
        };
        pool.push(n);
    }
    let y = *pool.last().unwrap();
    b.output(y, "y");
    b.finish().unwrap()
}

/// Random column-wave stimulus + BRV schedules (the proptests shape).
#[allow(clippy::type_complexity)]
fn column_stimulus(
    spec: &ColumnSpec,
    n: usize,
    seed: u16,
) -> (Vec<Vec<i32>>, Vec<Vec<RandPair>>) {
    let mut stim = Lfsr16::new((seed.wrapping_mul(311) ^ 0x5a5a) | 1);
    let mut lfsr = Lfsr16::new(seed.wrapping_mul(977) | 1);
    let waves: Vec<Vec<i32>> = (0..n)
        .map(|_| {
            (0..spec.p)
                .map(|_| {
                    let v = stim.next_u16();
                    if v & 0x7 == 7 {
                        INF
                    } else {
                        i32::from(v % 8)
                    }
                })
                .collect()
        })
        .collect();
    let rands: Vec<Vec<RandPair>> = (0..n)
        .map(|_| (0..spec.p * spec.q).map(|_| lfsr.draw_pair()).collect())
        .collect();
    (waves, rands)
}

/// INVARIANT: each pass alone (and the full pipeline) preserves every
/// net value on every lane on every tick, plus the aggregated activity
/// counters, on random register-mixing netlists — the compiled tape vs
/// the packed interpreter.
#[test]
fn prop_each_pass_bit_identical_per_net_on_random_netlists() {
    let lib = Library::asap7_only();
    for seed in 0..6u64 {
        let nl = random_netlist(&lib, seed + 4200);
        for spec in PIPELINES {
            let pm = PassManager::parse(spec).unwrap();
            let mut r = rng(seed * 131 + 7);
            let lanes = 1 + (r.next_u64() % 64) as usize;
            let mut tape =
                CompiledSimulator::with_passes(&nl, &lib, lanes, &pm)
                    .unwrap();
            let mut packed =
                PackedSimulator::new(&nl, &lib, lanes).unwrap();
            for t in 0..30u32 {
                let gamma = r.next_u64() & 3 == 0;
                let words: Vec<(NetId, u64)> = nl
                    .inputs
                    .iter()
                    .map(|&n| (n, r.next_u64()))
                    .collect();
                tape.tick(&words, gamma);
                packed.tick(&words, gamma);
                for net in 0..nl.n_nets() {
                    let id = NetId(net as u32);
                    for l in 0..lanes {
                        assert_eq!(
                            tape.get(id, l),
                            packed.get(id, l),
                            "seed {seed} passes `{spec}` tick {t} \
                             net {net} lane {l}"
                        );
                    }
                }
            }
            assert_eq!(
                tape.activity().toggles,
                packed.activity.toggles,
                "seed {seed} passes `{spec}`: toggles"
            );
            assert_eq!(
                tape.activity().clock_ticks,
                packed.activity.clock_ticks,
                "seed {seed} passes `{spec}`: clock ticks"
            );
            assert_eq!(
                tape.activity().cycles,
                packed.activity.cycles,
                "seed {seed} passes `{spec}`: cycles"
            );
        }
    }
}

/// INVARIANT: on full learning columns (both flavours), every pipeline
/// reproduces the packed testbench bit-for-bit — spike times, committed
/// weights, result fingerprints, activity, and the final state of every
/// net on every lane.
#[test]
fn prop_column_testbench_compiled_equals_packed_per_pass() {
    let lib = Library::with_macros();
    let params = StdpParams::default_training();
    for seed in 0..2u64 {
        let mut r = rng(seed * 733 + 11);
        let p = 3 + (r.next_u64() % 5) as usize;
        let q = 2 + (r.next_u64() % 3) as usize;
        let spec = ColumnSpec { p, q, theta: (p + 1) as u64 };
        let (waves, rands) = column_stimulus(&spec, 7, seed as u16 + 40);
        let lanes = 3; // 7 waves over 3 lanes: exercises a partial batch
        for flavor in [Flavor::Std, Flavor::Custom] {
            let (nl, ports) = build_column(&lib, flavor, &spec).unwrap();
            let mut packed =
                PackedColumnTestbench::new(&nl, &ports, &lib, lanes)
                    .unwrap();
            let base = packed.run_waves(&waves, &rands, &params);
            for pspec in PIPELINES {
                let pm = PassManager::parse(pspec).unwrap();
                let mut tape = CompiledColumnTestbench::with_passes(
                    &nl, &ports, &lib, lanes, &pm,
                )
                .unwrap();
                let got = tape.run_waves(&waves, &rands, &params);
                assert_eq!(got.len(), base.len());
                for (w, (g, b)) in got.iter().zip(&base).enumerate() {
                    assert_eq!(
                        g.pre, b.pre,
                        "seed {seed} {flavor:?} `{pspec}` wave {w}: pre"
                    );
                    assert_eq!(
                        g.post, b.post,
                        "seed {seed} {flavor:?} `{pspec}` wave {w}: post"
                    );
                    assert_eq!(
                        g.weights, b.weights,
                        "seed {seed} {flavor:?} `{pspec}` wave {w}: w"
                    );
                }
                assert_eq!(
                    fingerprint(&got),
                    fingerprint(&base),
                    "seed {seed} {flavor:?} `{pspec}`: fingerprint"
                );
                assert_eq!(
                    tape.activity().toggles,
                    packed.activity().toggles,
                    "seed {seed} {flavor:?} `{pspec}`: toggles"
                );
                // Final committed state: every net, every lane.
                for net in 0..nl.n_nets() {
                    let id = NetId(net as u32);
                    for l in 0..lanes {
                        assert_eq!(
                            tape.engine().get(id, l),
                            packed.engine().get(id, l),
                            "seed {seed} {flavor:?} `{pspec}` \
                             net {net} lane {l}: final state"
                        );
                    }
                }
            }
        }
    }
}

/// INVARIANT: the thread-parallel compiled runner matches the packed
/// parallel runner AND the scalar testbench at every (lanes, threads)
/// combination — thread counts change who executes which lanes, never
/// the results.
#[test]
fn prop_parallel_compiled_matches_packed_and_scalar_any_dims() {
    let lib = Library::with_macros();
    let params = StdpParams::default_training();
    let spec = ColumnSpec { p: 5, q: 3, theta: 7 };
    let (waves, rands) = column_stimulus(&spec, 9, 77);
    let pm = PassManager::all();
    for flavor in [Flavor::Std, Flavor::Custom] {
        let (nl, ports) = build_column(&lib, flavor, &spec).unwrap();
        // Scalar ground truth.
        let mut scalar = ColumnTestbench::new(&nl, &ports, &lib).unwrap();
        let truth: Vec<_> = waves
            .iter()
            .zip(&rands)
            .map(|(s, rand)| scalar.run_wave(s, rand, &params))
            .collect();
        let truth_fp = fingerprint(&truth);
        for (lanes, threads) in [(1, 1), (4, 1), (4, 3), (8, 2)] {
            let (pk, pk_act) = run_waves_parallel(
                &nl, &ports, &lib, lanes, threads, &waves, &rands,
                &params,
            )
            .unwrap();
            let (cp, cp_act, stats) = run_waves_parallel_compiled(
                &nl, &ports, &lib, lanes, threads, &waves, &rands,
                &params, &pm, None,
            )
            .unwrap();
            assert_eq!(
                fingerprint(&pk),
                truth_fp,
                "{flavor:?} {lanes}x{threads}: packed vs scalar"
            );
            assert_eq!(
                fingerprint(&cp),
                truth_fp,
                "{flavor:?} {lanes}x{threads}: compiled vs scalar"
            );
            assert_eq!(
                cp_act.toggles, pk_act.toggles,
                "{flavor:?} {lanes}x{threads}: toggles"
            );
            assert_eq!(cp_act.clock_ticks, pk_act.clock_ticks);
            assert_eq!(cp_act.cycles, pk_act.cycles);
            // The shared optimization ran the full pipeline once.
            assert_eq!(stats.len(), pm.passes().len());
        }
    }
}

/// Random multi-block netlist with a voter (the region tree gives the
/// column-aligned partitioner real shard boundaries to cut).
fn random_blocked_netlist(
    lib: &Library,
    seed: u64,
    blocks: usize,
) -> Netlist {
    let mut r = rng(seed);
    let mut b = Builder::new("shard_rnd", lib);
    let n_in = 2 + (r.next_u64() % 4) as usize;
    let inputs: Vec<NetId> =
        (0..n_in).map(|i| b.input(format!("x{i}"))).collect();
    let mut block_outs = Vec::new();
    for k in 0..blocks {
        let reg = b.push(format!("col{k}"));
        let mut pool = inputs.clone();
        let ops = 6 + (r.next_u64() % 20) as usize;
        for _ in 0..ops {
            let a = pool[(r.next_u64() as usize) % pool.len()];
            let c = pool[(r.next_u64() as usize) % pool.len()];
            let d = pool[(r.next_u64() as usize) % pool.len()];
            let n = match r.next_u64() % 8 {
                0 => b.inv(a),
                1 => b.and2(a, c),
                2 => b.or2(a, c),
                3 => b.xor2(a, c),
                4 => b.maj3(a, c, d),
                5 => b.mux2(a, c, d),
                6 => b.dff(a, ClockDomain::Aclk),
                _ => b.dff(a, ClockDomain::Gclk),
            };
            pool.push(n);
        }
        block_outs.push(*pool.last().unwrap());
        b.pop(reg);
    }
    let reg = b.push("voter");
    let v = b.or_tree(&block_outs);
    let q = b.dff(v, ClockDomain::Gclk);
    b.output(q, "y");
    b.pop(reg);
    b.finish().unwrap()
}

/// INVARIANT: the compiled-sharded engine (per-partition tapes, no
/// coalescing across boundaries) is bit-identical per net/lane/tick to
/// the packed interpreter at any shard count, on random multi-block
/// netlists with registers.
#[test]
fn prop_compiled_sharded_matches_packed_per_net() {
    let lib = Library::asap7_only();
    let pm = PassManager::all();
    for seed in 0..6u64 {
        let mut r = rng(seed * 271 + 3);
        let blocks = 2 + (seed as usize % 4);
        let nl = random_blocked_netlist(&lib, seed + 8600, blocks);
        let lanes = 1 + (r.next_u64() % 64) as usize;
        let shards = 1 + (r.next_u64() % 6) as usize;
        let (mut sh, stats) = ShardedSimulator::new_compiled(
            &nl, &lib, lanes, shards, &[], &pm,
        )
        .unwrap();
        // The sharded backend must have dropped coalesce, nothing else.
        assert_eq!(stats.len(), pm.passes().len() - 1);
        assert!(stats.iter().all(|s| s.pass != "coalesce"));
        let mut pk = PackedSimulator::new(&nl, &lib, lanes).unwrap();
        for t in 0..30u32 {
            let gamma = r.next_u64() & 3 == 0;
            let words: Vec<(NetId, u64)> =
                nl.inputs.iter().map(|&n| (n, r.next_u64())).collect();
            sh.tick_lanes(&words, gamma);
            pk.tick(&words, gamma);
            for net in 0..nl.n_nets() {
                let id = NetId(net as u32);
                for l in 0..lanes {
                    assert_eq!(
                        sh.lane_value(id, l),
                        pk.get(id, l),
                        "seed {seed} tick {t} net {net} lane {l} \
                         ({shards} shards)"
                    );
                }
            }
        }
        assert_eq!(sh.activity().toggles, pk.activity.toggles);
        assert_eq!(sh.activity().clock_ticks, pk.activity.clock_ticks);
        assert_eq!(sh.activity().cycles, pk.activity.cycles);
    }
}

/// INVARIANT: a rate-0 fault campaign on the compiled engine is
/// bit-identical to the interpreter campaign — same baseline
/// fingerprint, every point bit-identical with zero injections, same
/// toggle totals (the fault overlay machinery itself perturbs nothing).
#[test]
fn prop_zero_rate_campaign_compiled_matches_auto() {
    let lib = Library::with_macros();
    let params = StdpParams::default_training();
    let spec = ColumnSpec { p: 4, q: 2, theta: 6 };
    let (nl, ports) = build_column(&lib, Flavor::Std, &spec).unwrap();
    let (waves, rands) = column_stimulus(&spec, 6, 9);
    let cspec = CampaignSpec {
        classes: FaultClass::ALL.to_vec(),
        rates: vec![0.0],
        seeds: vec![1, 9],
    };
    for (lanes, threads) in [(1, 1), (4, 2)] {
        let auto = run_campaign(
            &nl, &ports, &lib, &cspec, &waves, &rands, &params, lanes,
            threads, CampaignEngine::Auto,
        )
        .unwrap();
        let comp = run_campaign(
            &nl, &ports, &lib, &cspec, &waves, &rands, &params, lanes,
            threads, CampaignEngine::Compiled,
        )
        .unwrap();
        assert_eq!(
            comp.base_fingerprint, auto.base_fingerprint,
            "{lanes}x{threads}: baseline diverged"
        );
        assert_eq!(comp.base_toggles, auto.base_toggles);
        assert_eq!(comp.points.len(), auto.points.len());
        for (c, a) in comp.points.iter().zip(&auto.points) {
            let label = c.point.class.label();
            assert_eq!(c.injections, 0, "{label}: rate 0 injected");
            assert!(
                c.bit_identical,
                "{lanes}x{threads} {label}: not bit-identical"
            );
            assert_eq!(c.fingerprint, a.fingerprint, "{label}");
            assert_eq!(c.toggles, a.toggles, "{label}");
            assert_eq!(c.accuracy, a.accuracy, "{label}");
            assert_eq!(c.weight_l1, a.weight_l1, "{label}");
        }
    }
}

/// Per-pass statistics of a real column: fold specializes without
/// removing, dce retires the tie cells, the op count never grows, and
/// the engine reports the pipeline it ran.
#[test]
fn pass_stats_report_real_reductions() {
    let lib = Library::with_macros();
    let spec = ColumnSpec { p: 6, q: 3, theta: 8 };
    let (nl, _ports) = build_column(&lib, Flavor::Custom, &spec).unwrap();
    let sim = CompiledSimulator::new(&nl, &lib, 4).unwrap();
    assert_eq!(sim.passes(), "fold,dce,coalesce,resched");
    let stats = sim.pass_stats();
    assert_eq!(stats.len(), 4);
    for s in stats {
        assert!(
            s.ops_after <= s.ops_before,
            "pass {} grew the op list",
            s.pass
        );
    }
    let by = |name: &str| stats.iter().find(|s| s.pass == name).unwrap();
    assert_eq!(by("fold").ops_after, by("fold").ops_before);
    assert!(by("fold").rewritten > 0, "ties must specialize consumers");
    assert!(by("dce").rewritten >= 2, "ties must retire");
    assert!(
        by("coalesce").rewritten > 0,
        "a real column has fanout-free pairs"
    );
    // The optimized tape is strictly smaller than the unoptimized one.
    let raw = CompiledSimulator::with_passes(
        &nl,
        &lib,
        4,
        &PassManager::none(),
    )
    .unwrap();
    assert!(sim.n_ops() < raw.n_ops());
}
