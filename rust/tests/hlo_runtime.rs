//! HLO runtime integration: the AOT artifacts vs the golden model.
//!
//! Requires `make artifacts` (the Makefile `test` target guarantees it).
//! These tests prove that the python-built compute (Pallas kernels inside
//! jax programs, lowered to HLO text) produces bit-identical results to
//! the rust golden model when executed through the PJRT CPU client —
//! the L1/L2 ⇄ L3 contract of the whole architecture.

use std::path::Path;

use tnn7::arch::INF;
use tnn7::data::digits::XorShift;
use tnn7::runtime::Runtime;
use tnn7::tnn::column::column_fwd;
use tnn7::tnn::stdp::{stdp_step, StdpParams};

fn artifacts() -> Option<Runtime> {
    let dir = Path::new("artifacts");
    match Runtime::load(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            // Fail loudly in CI, but allow `cargo test` before artifacts
            // exist to skip rather than error cryptically.
            eprintln!("skipping HLO tests (run `make artifacts`): {e}");
            None
        }
    }
}

fn rand_spikes(rng: &mut XorShift, n: usize) -> Vec<i32> {
    (0..n)
        .map(|_| {
            let v = rng.next_u64();
            if v & 7 == 7 {
                INF
            } else {
                (v % 8) as i32
            }
        })
        .collect()
}

#[test]
fn col_fwd_matches_golden_on_all_benchmark_sizes() {
    let Some(mut rt) = artifacts() else { return };
    let mut rng = XorShift::new(0xC0FFEE);
    for (name, p, q, theta) in [
        ("col_fwd_8x4", 8usize, 4usize, 6i32),
        ("col_fwd_64x8", 64, 8, 40),
        ("col_fwd_128x10", 128, 10, 60),
        ("col_fwd_1024x16", 1024, 16, 300),
    ] {
        let b = rt.manifest.batch;
        let s = rand_spikes(&mut rng, b * p);
        let w: Vec<i32> = (0..p * q).map(|_| (rng.next_u64() % 8) as i32).collect();
        let out = rt.execute(name, &[&s, &w, &[theta]]).unwrap();
        let (pre, post) = (&out[0], &out[1]);
        for bi in 0..b {
            let sb = &s[bi * p..(bi + 1) * p];
            let (pre_g, post_g) = column_fwd(sb, &w, q, theta);
            assert_eq!(&pre[bi * q..(bi + 1) * q], &pre_g[..], "{name} pre b{bi}");
            assert_eq!(
                &post[bi * q..(bi + 1) * q],
                &post_g[..],
                "{name} post b{bi}"
            );
        }
    }
}

#[test]
fn col_train_matches_golden_including_weights() {
    let Some(mut rt) = artifacts() else { return };
    let mut rng = XorShift::new(0xBADDCAFE);
    let (p, q, theta) = (64usize, 8usize, 40i32);
    let b = rt.manifest.batch;
    let params = StdpParams::default_training();
    let params_vec = params.to_vec();
    let mut w: Vec<i32> = vec![3; p * q];
    // Several consecutive training steps: state must track exactly.
    for step in 0..3 {
        let s = rand_spikes(&mut rng, b * p);
        let rand: Vec<i32> = (0..b * p * q * 2)
            .map(|_| (rng.next_u64() & 0xFFFF) as i32)
            .collect();
        let out = rt
            .execute("col_train_64x8", &[&s, &w, &[theta], &rand, &params_vec])
            .unwrap();
        let (post, new_w) = (&out[1], &out[2]);
        // Golden: forward all with frozen w, then sequential updates.
        let mut w_gold = w.clone();
        for bi in 0..b {
            let sb = &s[bi * p..(bi + 1) * p];
            let (_, post_g) = column_fwd(sb, &w, q, theta);
            assert_eq!(
                &post[bi * q..(bi + 1) * q],
                &post_g[..],
                "step {step} post b{bi}"
            );
            let pairs: Vec<(u16, u16)> = (0..p * q)
                .map(|k| {
                    let base = (bi * p * q + k) * 2;
                    (rand[base] as u16, rand[base + 1] as u16)
                })
                .collect();
            stdp_step(sb, &post_g, &mut w_gold, &pairs, &params);
        }
        assert_eq!(new_w, &w_gold, "step {step} weights");
        w = new_w.clone();
    }
}

#[test]
fn layer_fwd_matches_per_column_golden() {
    let Some(mut rt) = artifacts() else { return };
    let info = rt.manifest.get("l1_fwd").unwrap().clone();
    let (b, c, p, q) = (info.batch, info.cols, info.p, info.q);
    let mut rng = XorShift::new(42);
    let s = rand_spikes(&mut rng, b * c * p);
    let w: Vec<i32> =
        (0..c * p * q).map(|_| (rng.next_u64() % 8) as i32).collect();
    let theta = 20i32;
    let out = rt.execute("l1_fwd", &[&s, &w, &[theta]]).unwrap();
    let post = &out[1];
    // Spot-check a deterministic subset of columns (full check lives in
    // Pipeline::cross_check_batch; this keeps test time bounded).
    for &ci in &[0usize, 1, 77, 311, 624] {
        for bi in [0usize, b - 1] {
            let sb: Vec<i32> =
                (0..p).map(|j| s[(bi * c + ci) * p + j]).collect();
            let wc: Vec<i32> =
                (0..p * q).map(|k| w[ci * p * q + k]).collect();
            let (_, post_g) = column_fwd(&sb, &wc, q, theta);
            let got: Vec<i32> =
                (0..q).map(|i| post[(bi * c + ci) * q + i]).collect();
            assert_eq!(got, post_g, "col {ci} b {bi}");
        }
    }
}

#[test]
fn manifest_constants_match_binary() {
    let Some(rt) = artifacts() else { return };
    assert_eq!(rt.manifest.batch, 16);
    assert!(rt.manifest.get("l1_train").is_ok());
    assert!(rt.manifest.get("l2_train").is_ok());
    assert!(rt.manifest.get("does_not_exist").is_err());
}

#[test]
fn execute_rejects_wrong_shapes() {
    let Some(mut rt) = artifacts() else { return };
    let bad = vec![0i32; 7];
    assert!(rt.execute("col_fwd_8x4", &[&bad, &bad, &bad]).is_err());
}
