//! HLO runtime contract: the manifest format and the golden model.
//!
//! The original seed executed AOT-compiled JAX/Pallas artifacts through
//! a PJRT CPU client and diffed them against the golden model.  This
//! build has no native XLA backend (see `runtime::client::NO_BACKEND`),
//! so the executable half of that contract is pinned from the other
//! side: the manifest format (architectural-constant validation, shape
//! declarations) is tested directly, the stub client's behavior is
//! pinned so a future live client slots in behind the same signatures,
//! and the golden programs the artifacts encode (`column_fwd`,
//! `stdp_step`) are property-tested natively.

use std::path::Path;

use tnn7::arch::{INF, N_PARAMS, RAND_SCALE, T_IN, T_STEPS, W_MAX};
use tnn7::data::digits::XorShift;
use tnn7::runtime::{Manifest, Runtime};
use tnn7::tnn::column::column_fwd;
use tnn7::tnn::stdp::{stdp_step, RandPair, StdpParams};

fn rand_spikes(rng: &mut XorShift, n: usize) -> Vec<i32> {
    (0..n)
        .map(|_| {
            let v = rng.next_u64();
            if v & 7 == 7 {
                INF
            } else {
                (v % 8) as i32
            }
        })
        .collect()
}

fn manifest_text(inf: i64) -> String {
    format!(
        r#"{{"inf": {inf}, "t_in": {T_IN}, "w_max": {W_MAX},
            "t_steps": {T_STEPS}, "rand_scale": {RAND_SCALE},
            "n_params": {N_PARAMS}, "batch": 16,
            "artifacts": [{{"name": "col_fwd_8x4", "kind": "col_fwd",
              "file": "col_fwd_8x4.hlo.txt", "batch": 16, "cols": 1,
              "p": 8, "q": 4,
              "inputs": [[16, 8], [8, 4], [1]]}}]}}"#
    )
}

#[test]
fn manifest_contract_validates_architectural_constants() {
    let dir = Path::new("artifacts");
    let m = Manifest::parse(&manifest_text(INF as i64), dir).unwrap();
    assert_eq!(m.batch, 16);
    let info = m.get("col_fwd_8x4").unwrap();
    assert_eq!((info.p, info.q), (8, 4));
    assert_eq!(info.inputs, vec![vec![16, 8], vec![8, 4], vec![1]]);
    assert!(m.get("does_not_exist").is_err());
    // A drifted artifact set is an error, not a silent miscompute.
    let err = Manifest::parse(&manifest_text(INF as i64 - 1), dir)
        .unwrap_err()
        .to_string();
    assert!(err.contains("re-run `make artifacts`"), "{err}");
}

#[test]
fn stub_client_validates_shapes_then_reports_the_backend() {
    let manifest =
        Manifest::parse(&manifest_text(INF as i64), Path::new("artifacts"))
            .unwrap();
    let mut rt = Runtime { manifest };
    let s = vec![0i32; 16 * 8];
    let w = vec![0i32; 8 * 4];
    // Wrong shapes surface as shape errors exactly as with a live
    // client ...
    let err = rt.execute("col_fwd_8x4", &[&s, &w]).unwrap_err().to_string();
    assert!(err.contains("2 inputs given"), "{err}");
    // ... well-formed calls report the missing backend.
    let err = rt
        .execute("col_fwd_8x4", &[&s, &w, &[6]])
        .unwrap_err()
        .to_string();
    assert!(err.contains("without a PJRT/XLA backend"), "{err}");
}

#[test]
fn loading_absent_artifacts_is_a_structured_error() {
    // The repo tracks no artifacts/ directory; if one is ever added the
    // stub must still load its manifest and refuse execution cleanly.
    match Runtime::load(Path::new("artifacts")) {
        Err(e) => {
            assert!(e.to_string().contains("manifest.json"), "{e}")
        }
        Ok(mut rt) => {
            let err =
                rt.compile("col_fwd_8x4").unwrap_err().to_string();
            assert!(err.contains("backend"), "{err}");
        }
    }
}

#[test]
fn col_fwd_golden_is_deterministic_and_theta_monotone() {
    let mut rng = XorShift::new(0xC0FFEE);
    for (p, q) in [(8usize, 4usize), (64, 8), (128, 10)] {
        let s = rand_spikes(&mut rng, p);
        let w: Vec<i32> =
            (0..p * q).map(|_| (rng.next_u64() % 8) as i32).collect();
        let theta = (p / 2) as i32;
        let (pre, post) = column_fwd(&s, &w, q, theta);
        assert_eq!(pre, column_fwd(&s, &w, q, theta).0, "deterministic");
        assert_eq!(pre.len(), q);
        assert_eq!(post.len(), q);
        // Spike times live in [0, T_STEPS) or are INF, and raising the
        // threshold can only delay (or kill) each neuron's first spike.
        let (pre_hi, _) = column_fwd(&s, &w, q, theta + 3);
        for i in 0..q {
            assert!(pre[i] == INF || (0..T_STEPS).contains(&pre[i]));
            assert!(pre_hi[i] >= pre[i], "neuron {i} fired earlier");
        }
        // WTA: at most one winner, and it spikes no earlier than its
        // own pre time.
        let winners = post.iter().filter(|&&t| t != INF).count();
        assert!(winners <= 1, "{winners} winners");
        for i in 0..q {
            assert!(post[i] == INF || post[i] >= pre[i]);
        }
    }
}

#[test]
fn stdp_step_golden_saturates_weights_in_range() {
    let mut rng = XorShift::new(0xBADDCAFE);
    let (p, q) = (16usize, 4usize);
    let params = StdpParams::default_training();
    assert_eq!(params.to_vec().len(), N_PARAMS);
    let mut w: Vec<i32> = (0..p * q).map(|_| (rng.next_u64() % 8) as i32).collect();
    for step in 0..10 {
        let s = rand_spikes(&mut rng, p);
        let (_, post) = column_fwd(&s, &w, q, (p / 2) as i32);
        let pairs: Vec<RandPair> = (0..p * q)
            .map(|_| {
                let v = rng.next_u64();
                (v as u16, (v >> 16) as u16)
            })
            .collect();
        stdp_step(&s, &post, &mut w, &pairs, &params);
        for (k, &wk) in w.iter().enumerate() {
            assert!(
                (0..=W_MAX).contains(&wk),
                "step {step}: w[{k}] = {wk} out of [0, {W_MAX}]"
            );
        }
    }
}
