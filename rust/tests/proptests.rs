//! Property-based invariant tests.
//!
//! The offline vendor set has no `proptest`, so these are seeded
//! randomized sweeps with explicit case counts: every failure message
//! carries the seed, making cases reproducible.  Each test states the
//! invariant it defends.

use tnn7::arch::{INF, T_STEPS, W_MAX};
use tnn7::cells::Library;
use tnn7::config::TnnConfig;
use tnn7::data::digits::XorShift;
use tnn7::netlist::column::{build_column, ColumnSpec};
use tnn7::netlist::{Builder, ClockDomain, Flavor, NetId, Netlist};
use tnn7::runtime::json::Json;
use tnn7::sim::testbench::{ColumnTestbench, PackedColumnTestbench};
use tnn7::sim::{
    Activity, PackedSimulator, ShardedSimulator, SimEngine, Simulator,
};
use tnn7::tnn::column::column_fwd;
use tnn7::tnn::stdp::{stdp_step, RandPair, StdpParams};
use tnn7::tnn::Lfsr16;

fn rng(seed: u64) -> XorShift {
    XorShift::new(seed)
}

/// INVARIANT: WTA emits at most one winner, and it is the earliest
/// pre-WTA spike with lowest-index tie-break.
#[test]
fn prop_wta_single_earliest_winner() {
    for seed in 0..200u64 {
        let mut r = rng(seed + 1);
        let p = 1 + (r.next_u64() % 24) as usize;
        let q = 1 + (r.next_u64() % 12) as usize;
        let theta = 1 + (r.next_u64() % 30) as i32;
        let s: Vec<i32> = (0..p)
            .map(|_| {
                if r.next_u64() & 3 == 0 {
                    INF
                } else {
                    (r.next_u64() % 8) as i32
                }
            })
            .collect();
        let w: Vec<i32> =
            (0..p * q).map(|_| (r.next_u64() % 8) as i32).collect();
        let (pre, post) = column_fwd(&s, &w, q, theta);
        let winners: Vec<usize> =
            (0..q).filter(|&i| post[i] != INF).collect();
        assert!(winners.len() <= 1, "seed {seed}: multiple winners");
        if let Some(&win) = winners.first() {
            let t_min = *pre.iter().min().unwrap();
            assert_eq!(post[win], t_min, "seed {seed}: not earliest");
            for i in 0..win {
                assert!(pre[i] > t_min, "seed {seed}: tie-break broken");
            }
        } else {
            assert!(pre.iter().all(|&t| t == INF), "seed {seed}");
        }
    }
}

/// INVARIANT: pre-WTA spike times are in [0, T_STEPS) ∪ {INF} and are
/// monotone non-decreasing in theta.
#[test]
fn prop_spike_times_bounded_and_monotone_in_theta() {
    for seed in 0..100u64 {
        let mut r = rng(seed + 77);
        let p = 2 + (r.next_u64() % 16) as usize;
        let q = 1 + (r.next_u64() % 6) as usize;
        let s: Vec<i32> =
            (0..p).map(|_| (r.next_u64() % 8) as i32).collect();
        let w: Vec<i32> =
            (0..p * q).map(|_| (r.next_u64() % 8) as i32).collect();
        let mut prev = vec![-1i32; q];
        for theta in [1, 3, 8, 20, 50] {
            let (pre, _) = column_fwd(&s, &w, q, theta);
            for i in 0..q {
                assert!(
                    pre[i] == INF || (0..T_STEPS).contains(&pre[i]),
                    "seed {seed}: out of range"
                );
                assert!(pre[i] >= prev[i], "seed {seed}: not monotone");
                prev[i] = pre[i];
            }
        }
    }
}

/// INVARIANT: STDP keeps weights in [0, W_MAX] and is a no-op when all
/// thresholds are zero.
#[test]
fn prop_stdp_bounds_and_zero_freeze() {
    let frozen = StdpParams::from_probs(0.0, 0.0, 0.0, [0.0; 8], [0.0; 8]);
    let active = StdpParams::default_training();
    for seed in 0..200u64 {
        let mut r = rng(seed + 1000);
        let p = 1 + (r.next_u64() % 12) as usize;
        let q = 1 + (r.next_u64() % 8) as usize;
        let s: Vec<i32> = (0..p)
            .map(|_| if r.next_u64() & 1 == 0 { INF } else { (r.next_u64() % 8) as i32 })
            .collect();
        let o: Vec<i32> = (0..q)
            .map(|_| if r.next_u64() & 1 == 0 { INF } else { (r.next_u64() % 15) as i32 })
            .collect();
        let mut w: Vec<i32> =
            (0..p * q).map(|_| (r.next_u64() % 8) as i32).collect();
        let w0 = w.clone();
        let rand: Vec<RandPair> = (0..p * q)
            .map(|_| (r.next_u64() as u16, r.next_u64() as u16))
            .collect();
        stdp_step(&s, &o, &mut w, &rand, &frozen);
        assert_eq!(w, w0, "seed {seed}: frozen params changed weights");
        stdp_step(&s, &o, &mut w, &rand, &active);
        assert!(
            w.iter().all(|&x| (0..=W_MAX).contains(&x)),
            "seed {seed}: weight out of range"
        );
        // Per-synapse move is at most ±1 per wave.
        assert!(
            w.iter().zip(&w0).all(|(a, b)| (a - b).abs() <= 1),
            "seed {seed}: step larger than 1"
        );
    }
}

/// INVARIANT: the gate-level column (both flavours) is bit-equivalent to
/// the golden model across random geometries and learning waves.
#[test]
fn prop_gate_column_equals_golden_random_geometries() {
    for seed in 0..6u64 {
        let mut r = rng(seed * 991 + 5);
        let p = 3 + (r.next_u64() % 8) as usize;
        let q = 2 + (r.next_u64() % 4) as usize;
        let theta = 2 + (r.next_u64() % (3 * p as u64)) as i32;
        let spec = ColumnSpec { p, q, theta: theta as u64 };
        let lib = Library::with_macros();
        let params = StdpParams::default_training();
        for flavor in [Flavor::Std, Flavor::Custom] {
            let (nl, ports) = build_column(&lib, flavor, &spec).unwrap();
            let mut tb = ColumnTestbench::new(&nl, &ports, &lib).unwrap();
            let mut golden =
                tnn7::tnn::column::ColumnState::new(p, q, theta);
            let mut lfsr = Lfsr16::new((seed as u16).wrapping_mul(2741) | 1);
            for wave in 0..8 {
                let s: Vec<i32> = (0..p)
                    .map(|_| {
                        if r.next_u64() & 7 == 0 {
                            INF
                        } else {
                            (r.next_u64() % 8) as i32
                        }
                    })
                    .collect();
                let rand: Vec<RandPair> =
                    (0..p * q).map(|_| lfsr.draw_pair()).collect();
                let hw = tb.run_wave(&s, &rand, &params);
                let (pre_g, post_g) = golden.forward(&s);
                stdp_step(&s, &post_g, &mut golden.weights, &rand, &params);
                assert_eq!(hw.pre, pre_g, "seed {seed} {flavor:?} w{wave} p{p} q{q}");
                assert_eq!(hw.post, post_g, "seed {seed} {flavor:?} w{wave}");
                assert_eq!(
                    hw.weights, golden.weights,
                    "seed {seed} {flavor:?} w{wave}"
                );
            }
        }
    }
}

/// Random feed-forward netlist mixing combinational gates with
/// aclk- and gclk-domain registers (no combinational cycles possible
/// by construction).
fn random_netlist(lib: &Library, seed: u64) -> Netlist {
    let mut r = rng(seed);
    let mut b = Builder::new("rnd", lib);
    let n_in = 2 + (r.next_u64() % 5) as usize;
    let mut pool: Vec<NetId> =
        (0..n_in).map(|i| b.input(format!("x{i}"))).collect();
    let ops = 10 + (r.next_u64() % 40) as usize;
    for _ in 0..ops {
        let a = pool[(r.next_u64() as usize) % pool.len()];
        let c = pool[(r.next_u64() as usize) % pool.len()];
        let d = pool[(r.next_u64() as usize) % pool.len()];
        let n = match r.next_u64() % 8 {
            0 => b.inv(a),
            1 => b.and2(a, c),
            2 => b.or2(a, c),
            3 => b.xor2(a, c),
            4 => b.maj3(a, c, d),
            5 => b.mux2(a, c, d),
            6 => b.dff(a, ClockDomain::Aclk),
            _ => b.dff(a, ClockDomain::Gclk),
        };
        pool.push(n);
    }
    let y = *pool.last().unwrap();
    b.output(y, "y");
    b.finish().unwrap()
}

/// INVARIANT: the word-packed engine is bit-identical, lane for lane,
/// to independent scalar runs on random netlists and random stimuli —
/// every net value every tick, and the aggregated toggle / clock-tick
/// / cycle counters — including randomly gamma-edge-flagged ticks.
#[test]
fn prop_packed_engine_equals_scalar_lanes() {
    let lib = Library::asap7_only();
    for seed in 0..10u64 {
        let mut r = rng(seed * 7919 + 13);
        let nl = random_netlist(&lib, seed + 500);
        let lanes = 1 + (r.next_u64() % 64) as usize;
        let mut packed = PackedSimulator::new(&nl, &lib, lanes).unwrap();
        let mut scalars: Vec<Simulator> = (0..lanes)
            .map(|_| Simulator::new(&nl, &lib).unwrap())
            .collect();
        for t in 0..30u32 {
            let gamma = r.next_u64() & 3 == 0;
            let words: Vec<(NetId, u64)> =
                nl.inputs.iter().map(|&n| (n, r.next_u64())).collect();
            for (l, s) in scalars.iter_mut().enumerate() {
                let iv: Vec<(NetId, bool)> = words
                    .iter()
                    .map(|&(n, w)| (n, w >> l & 1 == 1))
                    .collect();
                s.tick(&iv, gamma);
            }
            packed.tick(&words, gamma);
            for (l, s) in scalars.iter().enumerate() {
                for net in 0..nl.n_nets() {
                    let id = NetId(net as u32);
                    assert_eq!(
                        packed.get(id, l),
                        s.get(id),
                        "seed {seed} tick {t} lane {l} net {net}"
                    );
                }
            }
        }
        let mut total = Activity::new(nl.insts.len());
        for s in &scalars {
            total.merge(&s.activity);
        }
        assert_eq!(total.toggles, packed.activity.toggles, "seed {seed}");
        assert_eq!(
            total.clock_ticks, packed.activity.clock_ticks,
            "seed {seed}"
        );
        assert_eq!(total.cycles, packed.activity.cycles, "seed {seed}");
    }
}

/// INVARIANT: the packed column testbench's wave schedule (lane `l`
/// carries waves `l`, `l+lanes`, … with live STDP) is bit-identical —
/// spike times, weights, AND activity counters — to running each
/// lane's strided wave subsequence through a scalar testbench,
/// including the gamma-edge-flagged STDP-evaluation tick of every wave
/// and a final partial batch that exercises the lane mask.
#[test]
fn prop_packed_column_schedule_matches_strided_scalar() {
    let lib = Library::with_macros();
    let spec = ColumnSpec { p: 5, q: 3, theta: 7 };
    let params = StdpParams::default_training();
    for flavor in [Flavor::Std, Flavor::Custom] {
        let (nl, ports) = build_column(&lib, flavor, &spec).unwrap();
        for seed in 0..3u16 {
            let n = 10;
            let lanes = 4; // chunks of 4, 4, 2
            let mut stim =
                Lfsr16::new((seed.wrapping_mul(311) ^ 0x5a5a) | 1);
            let mut lfsr = Lfsr16::new(seed.wrapping_mul(977) | 1);
            let waves: Vec<Vec<i32>> = (0..n)
                .map(|_| {
                    (0..spec.p)
                        .map(|_| {
                            let v = stim.next_u16();
                            if v & 0x7 == 7 {
                                INF
                            } else {
                                i32::from(v % 8)
                            }
                        })
                        .collect()
                })
                .collect();
            let rands: Vec<Vec<RandPair>> = (0..n)
                .map(|_| {
                    (0..spec.p * spec.q)
                        .map(|_| lfsr.draw_pair())
                        .collect()
                })
                .collect();

            let mut ptb =
                PackedColumnTestbench::new(&nl, &ports, &lib, lanes)
                    .unwrap();
            let packed = ptb.run_waves(&waves, &rands, &params);
            assert_eq!(packed.len(), n);

            let mut total = Activity::new(nl.insts.len());
            for l in 0..lanes {
                let mut tb =
                    ColumnTestbench::new(&nl, &ports, &lib).unwrap();
                let mut w = l;
                while w < n {
                    let res = tb.run_wave(&waves[w], &rands[w], &params);
                    assert_eq!(
                        res, packed[w],
                        "{flavor:?} seed {seed} wave {w}"
                    );
                    w += lanes;
                }
                total.merge(tb.activity());
            }
            assert_eq!(
                total.toggles,
                ptb.activity().toggles,
                "{flavor:?} seed {seed}: toggle counts"
            );
            assert_eq!(
                total.clock_ticks,
                ptb.activity().clock_ticks,
                "{flavor:?} seed {seed}: clock ticks"
            );
            assert_eq!(
                total.cycles,
                ptb.activity().cycles,
                "{flavor:?} seed {seed}: cycles"
            );
        }
    }
}

/// Random multi-block netlist: `blocks` independent region-tagged
/// random blocks reading only the shared primary inputs, joined by a
/// voter block — the shape the column-aligned partitioner cuts into
/// shards plus a boundary-exchanged tail.
fn random_sharded_netlist(
    lib: &Library,
    seed: u64,
    blocks: usize,
) -> Netlist {
    let mut r = rng(seed);
    let mut b = Builder::new("shard_rnd", lib);
    let n_in = 2 + (r.next_u64() % 4) as usize;
    let inputs: Vec<NetId> =
        (0..n_in).map(|i| b.input(format!("x{i}"))).collect();
    let mut block_outs = Vec::new();
    for k in 0..blocks {
        let reg = b.push(format!("col{k}"));
        let mut pool = inputs.clone();
        let ops = 6 + (r.next_u64() % 20) as usize;
        for _ in 0..ops {
            let a = pool[(r.next_u64() as usize) % pool.len()];
            let c = pool[(r.next_u64() as usize) % pool.len()];
            let d = pool[(r.next_u64() as usize) % pool.len()];
            let n = match r.next_u64() % 8 {
                0 => b.inv(a),
                1 => b.and2(a, c),
                2 => b.or2(a, c),
                3 => b.xor2(a, c),
                4 => b.maj3(a, c, d),
                5 => b.mux2(a, c, d),
                6 => b.dff(a, ClockDomain::Aclk),
                _ => b.dff(a, ClockDomain::Gclk),
            };
            pool.push(n);
        }
        block_outs.push(*pool.last().unwrap());
        b.pop(reg);
    }
    let reg = b.push("voter");
    let v = b.or_tree(&block_outs);
    let q = b.dff(v, ClockDomain::Gclk);
    b.output(q, "y");
    b.pop(reg);
    b.finish().unwrap()
}

/// INVARIANT: the thread-parallel sharded engine is bit-identical to
/// the single-thread packed engine on random multi-block netlists at
/// random lane and shard counts — every net value in every lane every
/// tick, and the aggregated toggle / clock-tick / cycle counters
/// (therefore identical downstream power numbers).
#[test]
fn prop_sharded_engine_equals_packed_single_thread() {
    let lib = Library::asap7_only();
    for seed in 0..8u64 {
        let mut r = rng(seed * 6151 + 7);
        let blocks = 2 + (seed as usize % 4);
        let nl = random_sharded_netlist(&lib, seed + 900, blocks);
        let lanes = 1 + (r.next_u64() % 64) as usize;
        let shards = 1 + (r.next_u64() % 6) as usize;
        let mut sh =
            ShardedSimulator::new(&nl, &lib, lanes, shards, &[]).unwrap();
        let mut pk = PackedSimulator::new(&nl, &lib, lanes).unwrap();
        for t in 0..30u32 {
            let gamma = r.next_u64() & 3 == 0;
            let words: Vec<(NetId, u64)> =
                nl.inputs.iter().map(|&n| (n, r.next_u64())).collect();
            sh.tick_lanes(&words, gamma);
            pk.tick(&words, gamma);
            for net in 0..nl.n_nets() {
                let id = NetId(net as u32);
                for l in 0..lanes {
                    assert_eq!(
                        sh.lane_value(id, l),
                        pk.get(id, l),
                        "seed {seed} tick {t} net {net} lane {l} \
                         ({blocks} blocks, {shards} shards)"
                    );
                }
            }
        }
        assert_eq!(
            sh.activity().toggles,
            pk.activity.toggles,
            "seed {seed}: toggles"
        );
        assert_eq!(
            sh.activity().clock_ticks,
            pk.activity.clock_ticks,
            "seed {seed}: clock ticks"
        );
        assert_eq!(
            sh.activity().cycles,
            pk.activity.cycles,
            "seed {seed}: cycles"
        );
    }
}

/// INVARIANT: popcount netlists count exactly, for random widths.
#[test]
fn prop_popcount_exact() {
    let lib = Library::with_macros();
    for seed in 0..30u64 {
        let mut r = rng(seed + 31);
        let n = 1 + (r.next_u64() % 40) as usize;
        let mut b = Builder::new("pc", &lib);
        let ins = b.input_bus("x", n);
        let s = b.popcount(&ins);
        for (i, &bit) in s.iter().enumerate() {
            b.output(bit, format!("s{i}"));
        }
        let nl = b.finish().unwrap();
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for _ in 0..10 {
            let v: Vec<bool> = (0..n).map(|_| r.next_u64() & 1 == 1).collect();
            let iv: Vec<_> =
                (0..n).map(|i| (nl.inputs[i], v[i])).collect();
            sim.tick(&iv, false);
            let got: u32 = nl
                .outputs
                .iter()
                .enumerate()
                .map(|(k, &o)| (sim.get(o) as u32) << k)
                .sum();
            let want = v.iter().filter(|&&x| x).count() as u32;
            assert_eq!(got, want, "seed {seed} n {n}");
        }
    }
}

/// INVARIANT: geq/lt comparator netlists match integer comparison.
#[test]
fn prop_comparators_exact() {
    let lib = Library::with_macros();
    for seed in 0..20u64 {
        let mut r = rng(seed + 321);
        let w = 1 + (r.next_u64() % 12) as usize;
        let mut b = Builder::new("cmp", &lib);
        let a = b.input_bus("a", w);
        let c = b.input_bus("b", w);
        let ge = b.geq(&a, &c);
        let lt = b.lt(&a, &c);
        b.output(ge, "ge");
        b.output(lt, "lt");
        let nl = b.finish().unwrap();
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for _ in 0..20 {
            let av = r.next_u64() & ((1 << w) - 1);
            let bv = r.next_u64() & ((1 << w) - 1);
            let mut iv = Vec::new();
            for i in 0..w {
                iv.push((nl.inputs[i], av >> i & 1 == 1));
                iv.push((nl.inputs[w + i], bv >> i & 1 == 1));
            }
            sim.tick(&iv, false);
            assert_eq!(sim.get(nl.outputs[0]), av >= bv, "seed {seed}");
            assert_eq!(sim.get(nl.outputs[1]), av < bv, "seed {seed}");
        }
    }
}

/// INVARIANT: the JSON parser round-trips machine-generated documents
/// and never panics on mutated ones.
#[test]
fn prop_json_robustness() {
    let doc = r#"{"batch":16,"artifacts":[{"name":"x","p":32,"q":12,
        "inputs":[[16,625,32],[625,32,12],[1]],"kind":"layer_fwd"}]}"#;
    assert!(Json::parse(doc).is_ok());
    let mut r = rng(99);
    for _ in 0..500 {
        // Random single-byte mutations must parse-or-error, never panic.
        let mut bytes = doc.as_bytes().to_vec();
        let i = (r.next_u64() as usize) % bytes.len();
        bytes[i] = (r.next_u64() & 0x7F) as u8;
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(s);
        }
    }
}

/// INVARIANT: the TOML-subset parser never panics on mutated configs and
/// unknown keys are always rejected.
#[test]
fn prop_config_robustness() {
    let base = "[network]\ntheta1 = 20\n[training]\nmu_capture = 0.9\n";
    assert!(TnnConfig::from_toml(base).is_ok());
    let mut r = rng(123);
    for _ in 0..500 {
        let mut bytes = base.as_bytes().to_vec();
        let i = (r.next_u64() as usize) % bytes.len();
        bytes[i] = (r.next_u64() & 0x7F) as u8;
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = TnnConfig::from_toml(s);
        }
    }
    assert!(TnnConfig::from_toml("[network]\nbogus_key = 1\n").is_err());
}

/// INVARIANT: LFSR stream is reproducible and hits both halves of its
/// range at expected frequency (Bernoulli fairness of BRVs).
#[test]
fn prop_lfsr_fairness() {
    for seed in 1..20u16 {
        let mut l = Lfsr16::new(seed);
        let mut below = 0u32;
        const N: u32 = 20000;
        for _ in 0..N {
            if l.next_u16() < 32768 {
                below += 1;
            }
        }
        let frac = f64::from(below) / f64::from(N);
        assert!(
            (0.47..0.53).contains(&frac),
            "seed {seed}: P(below mid) = {frac}"
        );
    }
}

/// INVARIANT: for random column netlists (both flavours), the seeded
/// placer always produces a legal placement — no cell overlaps,
/// row-aligned y coordinates, every cell in-bounds inside a usable row
/// span — and a strictly positive wirelength.
#[test]
fn prop_placement_legal_random_columns() {
    use tnn7::phys::place::{place, PlacerConfig};
    use tnn7::phys::FloorplanSpec;
    use tnn7::tech::WireParams;
    let lib = Library::with_macros();
    let tech = tnn7::cells::TechParams::calibrated();
    for seed in 0..6u64 {
        let mut r = rng(seed * 733 + 11);
        let p = 2 + (r.next_u64() % 8) as usize;
        let q = 1 + (r.next_u64() % 5) as usize;
        let spec = ColumnSpec { p, q, theta: (p + q) as u64 };
        // Random-but-valid floorplan knobs.
        let util = 0.5 + (r.next_u64() % 5) as f64 * 0.1; // 0.5..0.9
        let aspect = 0.5 + (r.next_u64() % 8) as f64 * 0.5; // 0.5..4.0
        for flavor in [Flavor::Std, Flavor::Custom] {
            let (nl, _) = build_column(&lib, flavor, &spec).unwrap();
            let fspec = FloorplanSpec::new(
                util,
                aspect,
                &WireParams::asap7(),
            );
            let pl = place(
                &nl,
                &lib,
                &tech,
                &fspec,
                &PlacerConfig { seed, ..PlacerConfig::default() },
            )
            .unwrap();
            pl.validate().unwrap_or_else(|e| {
                panic!("seed {seed} {flavor:?} p{p} q{q}: {e}")
            });
            assert!(pl.hpwl_um > 0.0, "seed {seed} {flavor:?}");
            assert_eq!(pl.x_um.len(), nl.insts.len());
        }
    }
}

/// INVARIANT: placement is deterministic — the same seed produces a
/// bit-identical placement (coordinates, row assignment, HPWL).
#[test]
fn prop_placement_deterministic_same_seed() {
    use tnn7::phys::place::{place, PlacerConfig};
    use tnn7::phys::FloorplanSpec;
    use tnn7::tech::WireParams;
    let lib = Library::with_macros();
    let tech = tnn7::cells::TechParams::calibrated();
    let spec = ColumnSpec { p: 7, q: 3, theta: 10 };
    let (nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
    let fspec = FloorplanSpec::new(0.7, 1.0, &WireParams::asap7());
    for seed in [1u64, 17, 0xDEAD] {
        let cfg = PlacerConfig { seed, ..PlacerConfig::default() };
        let a = place(&nl, &lib, &tech, &fspec, &cfg).unwrap();
        let b = place(&nl, &lib, &tech, &fspec, &cfg).unwrap();
        let bits =
            |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.x_um), bits(&b.x_um), "seed {seed}");
        assert_eq!(bits(&a.y_um), bits(&b.y_um), "seed {seed}");
        assert_eq!(a.row_of, b.row_of, "seed {seed}");
        assert_eq!(
            a.hpwl_um.to_bits(),
            b.hpwl_um.to_bits(),
            "seed {seed}"
        );
        assert_eq!(bits(&a.pass_hpwl_um), bits(&b.pass_hpwl_um));
    }
}

/// INVARIANT: greedy refinement never increases HPWL — the recorded
/// per-pass trace is non-increasing from the initial placement on.
#[test]
fn prop_placement_hpwl_never_increases() {
    use tnn7::phys::place::{place, PlacerConfig};
    use tnn7::phys::FloorplanSpec;
    use tnn7::tech::WireParams;
    let lib = Library::with_macros();
    let tech = tnn7::cells::TechParams::calibrated();
    for seed in 0..5u64 {
        let mut r = rng(seed + 4242);
        let p = 3 + (r.next_u64() % 6) as usize;
        let q = 2 + (r.next_u64() % 4) as usize;
        let spec = ColumnSpec { p, q, theta: (2 * p) as u64 };
        let (nl, _) = build_column(&lib, Flavor::Std, &spec).unwrap();
        let fspec =
            FloorplanSpec::new(0.7, 1.0, &WireParams::asap7());
        let pl = place(
            &nl,
            &lib,
            &tech,
            &fspec,
            &PlacerConfig {
                seed,
                passes: 4,
                ..PlacerConfig::default()
            },
        )
        .unwrap();
        assert_eq!(pl.pass_hpwl_um.len(), 5);
        for w in pl.pass_hpwl_um.windows(2) {
            assert!(
                w[1] <= w[0] + 1e-9,
                "seed {seed}: HPWL increased {} -> {}",
                w[0],
                w[1]
            );
        }
        assert!(
            (pl.hpwl_um - pl.pass_hpwl_um.last().unwrap()).abs() < 1e-9
        );
    }
}

/// INVARIANT: export → BLIF → re-import reconstructs a netlist that
/// simulates bit-identically on all three engines — every net, every
/// lane, every tick, plus the aggregated toggle / clock-tick / cycle
/// counters — and the export is a byte fixpoint of the round trip.
#[test]
fn prop_reimported_netlist_simulates_identically() {
    use tnn7::interop::{export_blif, import_blif};
    let lib = Library::asap7_only();
    for seed in 0..6u64 {
        let mut r = rng(seed * 3571 + 17);
        let nl = random_netlist(&lib, seed + 2500);
        let blif = export_blif(&nl, &lib);
        let nl2 = import_blif(&blif, &lib).unwrap();
        assert_eq!(
            export_blif(&nl2, &lib),
            blif,
            "seed {seed}: re-export is not a byte fixpoint"
        );
        let lanes = 1 + (r.next_u64() % 64) as usize;
        let mut pk1 = PackedSimulator::new(&nl, &lib, lanes).unwrap();
        let mut pk2 = PackedSimulator::new(&nl2, &lib, lanes).unwrap();
        let mut sc1 = Simulator::new(&nl, &lib).unwrap();
        let mut sc2 = Simulator::new(&nl2, &lib).unwrap();
        for t in 0..30u32 {
            let gamma = r.next_u64() & 3 == 0;
            let words: Vec<(NetId, u64)> =
                nl.inputs.iter().map(|&n| (n, r.next_u64())).collect();
            pk1.tick(&words, gamma);
            pk2.tick(&words, gamma);
            let iv: Vec<(NetId, bool)> =
                words.iter().map(|&(n, w)| (n, w & 1 == 1)).collect();
            sc1.tick(&iv, gamma);
            sc2.tick(&iv, gamma);
            for net in 0..nl.n_nets() {
                let id = NetId(net as u32);
                for l in 0..lanes {
                    assert_eq!(
                        pk1.get(id, l),
                        pk2.get(id, l),
                        "seed {seed} tick {t} net {net} lane {l}"
                    );
                }
                assert_eq!(
                    sc1.get(id),
                    sc2.get(id),
                    "seed {seed} tick {t} net {net} (scalar)"
                );
            }
        }
        assert_eq!(
            pk1.activity.toggles, pk2.activity.toggles,
            "seed {seed}: toggles"
        );
        assert_eq!(pk1.activity.clock_ticks, pk2.activity.clock_ticks);
        assert_eq!(pk1.activity.cycles, pk2.activity.cycles);
        assert_eq!(sc1.activity.toggles, sc2.activity.toggles);
    }
    // The sharded engine over the region-blocked generator: re-import
    // preserves the region tree byte-for-byte, so the column-aligned
    // partitioner cuts identical shards on both sides.
    for seed in 0..4u64 {
        let mut r = rng(seed * 9013 + 3);
        let blocks = 2 + (seed as usize % 3);
        let nl = random_sharded_netlist(&lib, seed + 3100, blocks);
        let blif = export_blif(&nl, &lib);
        let nl2 = import_blif(&blif, &lib).unwrap();
        let lanes = 1 + (r.next_u64() % 64) as usize;
        let shards = 1 + (r.next_u64() % 4) as usize;
        let mut sh1 =
            ShardedSimulator::new(&nl, &lib, lanes, shards, &[]).unwrap();
        let mut sh2 =
            ShardedSimulator::new(&nl2, &lib, lanes, shards, &[]).unwrap();
        for t in 0..20u32 {
            let gamma = r.next_u64() & 3 == 0;
            let words: Vec<(NetId, u64)> =
                nl.inputs.iter().map(|&n| (n, r.next_u64())).collect();
            sh1.tick_lanes(&words, gamma);
            sh2.tick_lanes(&words, gamma);
            for net in 0..nl.n_nets() {
                let id = NetId(net as u32);
                for l in 0..lanes {
                    assert_eq!(
                        sh1.lane_value(id, l),
                        sh2.lane_value(id, l),
                        "seed {seed} tick {t} net {net} lane {l} \
                         ({blocks} blocks, {shards} shards)"
                    );
                }
            }
        }
        assert_eq!(
            sh1.activity().toggles,
            sh2.activity().toggles,
            "seed {seed}: sharded toggles"
        );
        assert_eq!(sh1.activity().cycles, sh2.activity().cycles);
    }
}

/// Random column-wave stimulus + BRV schedules for fault-campaign
/// properties.
#[allow(clippy::type_complexity)]
fn campaign_stimulus(
    spec: &ColumnSpec,
    n: usize,
    seed: u16,
) -> (Vec<Vec<i32>>, Vec<Vec<RandPair>>) {
    let mut stim = Lfsr16::new((seed.wrapping_mul(311) ^ 0x5a5a) | 1);
    let mut lfsr = Lfsr16::new(seed.wrapping_mul(977) | 1);
    let waves: Vec<Vec<i32>> = (0..n)
        .map(|_| {
            (0..spec.p)
                .map(|_| {
                    let v = stim.next_u16();
                    if v & 0x7 == 7 {
                        INF
                    } else {
                        i32::from(v % 8)
                    }
                })
                .collect()
        })
        .collect();
    let rands: Vec<Vec<RandPair>> = (0..n)
        .map(|_| {
            (0..spec.p * spec.q).map(|_| lfsr.draw_pair()).collect()
        })
        .collect();
    (waves, rands)
}

/// INVARIANT: a rate-0 campaign point of ANY fault class is
/// bit-identical to the fault-free baseline on all three engines —
/// same wave results (fingerprint), same toggle count, accuracy 1.0,
/// zero injections.
#[test]
fn prop_fault_campaign_zero_rate_bit_identical_all_engines() {
    use tnn7::fault::{
        run_campaign, CampaignEngine, CampaignSpec, FaultClass,
    };
    let lib = Library::with_macros();
    let params = StdpParams::default_training();
    for seed in 0..2u64 {
        let mut r = rng(seed * 577 + 29);
        let p = 3 + (r.next_u64() % 5) as usize;
        let q = 2 + (r.next_u64() % 3) as usize;
        let spec = ColumnSpec { p, q, theta: (p + 2) as u64 };
        let (nl, ports) =
            build_column(&lib, Flavor::Std, &spec).unwrap();
        let (waves, rands) =
            campaign_stimulus(&spec, 6, seed as u16 + 3);
        let cspec = CampaignSpec {
            classes: FaultClass::ALL.to_vec(),
            rates: vec![0.0],
            seeds: vec![1, 9],
        };
        let mut base_fp: Option<u64> = None;
        // Scalar, packed single-thread, sharded multi-thread.
        for (lanes, threads) in [(1, 1), (4, 1), (4, 3)] {
            let rep = run_campaign(
                &nl, &ports, &lib, &cspec, &waves, &rands, &params,
                lanes, threads, CampaignEngine::Auto,
            )
            .unwrap();
            // The fault-free baseline itself is engine-invariant.
            let fp = *base_fp.get_or_insert(rep.base_fingerprint);
            assert_eq!(
                rep.base_fingerprint, fp,
                "seed {seed} lanes {lanes} threads {threads}: baseline \
                 diverged across engines"
            );
            for pt in &rep.points {
                let label = pt.point.class.label();
                assert_eq!(
                    pt.injections, 0,
                    "seed {seed} {label}: rate 0 injected faults"
                );
                assert!(
                    pt.bit_identical,
                    "seed {seed} lanes {lanes} threads {threads} \
                     {label}: rate 0 not bit-identical"
                );
                assert_eq!(pt.fingerprint, rep.base_fingerprint);
                assert_eq!(pt.toggles, rep.base_toggles);
                assert_eq!(pt.accuracy, 1.0);
                assert_eq!(pt.weight_l1, 0);
            }
        }
    }
}

/// INVARIANT: a seeded campaign is deterministic across engines and
/// thread counts — every point's fingerprint, injection count,
/// accuracy, |dW| and toggle total is identical whether the schedule
/// ran scalar, packed, or sharded at any thread count.
#[test]
fn prop_fault_campaign_deterministic_across_engines_and_threads() {
    use tnn7::fault::{
        run_campaign, CampaignEngine, CampaignSpec, FaultClass,
    };
    let lib = Library::with_macros();
    let params = StdpParams::default_training();
    let spec = ColumnSpec { p: 6, q: 3, theta: 8 };
    let (nl, ports) = build_column(&lib, Flavor::Std, &spec).unwrap();
    let (waves, rands) = campaign_stimulus(&spec, 8, 41);
    let cspec = CampaignSpec {
        classes: FaultClass::ALL.to_vec(),
        rates: vec![0.05, 0.25],
        seeds: vec![3, 11],
    };
    let golden = run_campaign(
        &nl, &ports, &lib, &cspec, &waves, &rands, &params, 1, 1,
        CampaignEngine::Auto,
    )
    .unwrap();
    for (lanes, threads) in [(2, 1), (8, 1), (8, 2), (8, 5)] {
        let rep = run_campaign(
            &nl, &ports, &lib, &cspec, &waves, &rands, &params, lanes,
            threads, CampaignEngine::Auto,
        )
        .unwrap();
        assert_eq!(rep.base_fingerprint, golden.base_fingerprint);
        assert_eq!(rep.base_toggles, golden.base_toggles);
        assert_eq!(rep.points.len(), golden.points.len());
        for (pt, g) in rep.points.iter().zip(&golden.points) {
            let ctx = format!(
                "lanes {lanes} threads {threads} {} rate {} seed {}",
                g.point.class.label(),
                g.point.rate,
                g.point.seed
            );
            assert_eq!(pt.point.class, g.point.class, "{ctx}");
            assert_eq!(pt.injections, g.injections, "{ctx}");
            assert_eq!(pt.fingerprint, g.fingerprint, "{ctx}");
            assert_eq!(pt.accuracy, g.accuracy, "{ctx}");
            assert_eq!(pt.weight_l1, g.weight_l1, "{ctx}");
            assert_eq!(pt.toggles, g.toggles, "{ctx}");
            assert_eq!(pt.bit_identical, g.bit_identical, "{ctx}");
        }
    }
}

/// INVARIANT: stuck-at faults pinning a const-tied net to its tied
/// polarity are no-ops — and the campaign site enumerator never offers
/// the tie nets as injection sites in the first place.
#[test]
fn prop_stuck_faults_on_const_tied_nets_are_noops() {
    use tnn7::fault::{fault_sites, FaultOverlay};
    let lib = Library::with_macros();
    let params = StdpParams::default_training();
    for (seed, flavor) in
        [(0u64, Flavor::Std), (1, Flavor::Custom)]
    {
        let mut r = rng(seed * 449 + 97);
        let p = 3 + (r.next_u64() % 5) as usize;
        let q = 2 + (r.next_u64() % 3) as usize;
        let spec = ColumnSpec { p, q, theta: (p + 1) as u64 };
        let (nl, ports) = build_column(&lib, flavor, &spec).unwrap();

        let sites = fault_sites(&nl, &lib);
        assert!(
            !sites.outs.contains(&nl.const0)
                && !sites.outs.contains(&nl.const1),
            "{flavor:?}: tie nets offered as fault sites"
        );

        // Pin the ties to the value they already carry: stuck-at-0 on
        // const0, stuck-at-1 on const1, in every lane.
        let mut ov = FaultOverlay::new(nl.n_nets());
        ov.add_stuck0(nl.const0, !0);
        ov.add_stuck1(nl.const1, !0);

        let mut clean =
            ColumnTestbench::new(&nl, &ports, &lib).unwrap();
        let mut faulted =
            ColumnTestbench::new(&nl, &ports, &lib).unwrap();
        faulted.install_faults(ov);
        let (waves, rands) =
            campaign_stimulus(&spec, 6, seed as u16 + 19);
        for (w, (s, rand)) in waves.iter().zip(&rands).enumerate() {
            let a = clean.run_wave(s, rand, &params);
            let b = faulted.run_wave(s, rand, &params);
            assert_eq!(a, b, "{flavor:?} wave {w}: tied stuck-at \
                 perturbed the run");
        }
        assert_eq!(
            clean.activity().toggles,
            faulted.activity().toggles,
            "{flavor:?}: toggle counts"
        );
    }
}

/// INVARIANT: PPA is monotone in column size (more synapses never cost
/// less area or leakage).
#[test]
fn prop_ppa_monotone_in_size() {
    let lib = Library::with_macros();
    let tech = tnn7::cells::TechParams::calibrated();
    let mut last_area = 0.0;
    for p in [4usize, 8, 16, 32] {
        let spec = ColumnSpec::benchmark(p, 4);
        let (nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let a = tnn7::ppa::area::analyze(&nl, &lib, &tech).die_mm2;
        assert!(a > last_area, "p={p}");
        last_area = a;
    }
}
