// tnn7 structural verilog 1
// design golden_n45_projected
module golden_n45_projected (
  input n2, // a
  input n3, // b
  output n5 // y
);
  wire n0;
  wire n1;
  wire n4;
  TIELOx1 u0 (.o0(n0));
  TIEHIx1 u1 (.o0(n1));
  NAND2x1 u2 (.i0(n2), .i1(n3), .o0(n4));
  XOR2x1 u3 (.i0(n4), .i1(n2), .o0(n5));
endmodule

// Elaboration-only cell stubs (no behaviour).
module NAND2x1(input i0, input i1, output o0);
endmodule
module TIEHIx1(output o0);
endmodule
module TIELOx1(output o0);
endmodule
module XOR2x1(input i0, input i1, output o0);
endmodule
