//! Cross-module integration tests (no PJRT artifacts needed — see
//! `hlo_runtime.rs` for those).

use tnn7::cells::{liberty, Library, TechParams};
use tnn7::config::TnnConfig;
use tnn7::coordinator::activity_bridge::{spike_rate, stimulus};
use tnn7::coordinator::measure::{measure_column, table1_specs};
use tnn7::data::Dataset;
use tnn7::netlist::column::{build_column, ColumnSpec};
use tnn7::netlist::prototype::{PrototypeModel, PrototypeSpec};
use tnn7::netlist::Flavor;
use tnn7::ppa::{area, timing};
use tnn7::tnn::encoding::encode_image;
use tnn7::tnn::network::{rebase, Network};
use tnn7::tnn::{Lfsr16, StdpParams};

#[test]
fn config_file_round_trip() {
    let dir = std::env::temp_dir().join("tnn7_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tnn7.toml");
    std::fs::write(
        &path,
        "[network]\ntheta1 = 33\n[training]\ntrain_samples = 42\n",
    )
    .unwrap();
    let cfg = TnnConfig::load(&path).unwrap();
    assert_eq!(cfg.theta1, 33);
    assert_eq!(cfg.train_samples, 42);
    assert_eq!(cfg.theta2, TnnConfig::default().theta2);
}

#[test]
fn liberty_export_covers_whole_library() {
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    let text = liberty::emit(&lib, &tech, "it");
    let cells = liberty::parse(&text).unwrap();
    assert_eq!(cells.len(), lib.len());
    let n_macros = cells.iter().filter(|c| c.is_macro).count();
    assert_eq!(n_macros, 12);
}

#[test]
fn prototype_census_matches_paper_geometry() {
    let spec = PrototypeSpec::paper();
    assert_eq!(spec.neurons(), 13_750);
    assert_eq!(spec.synapses(), 315_000);
    let lib = Library::with_macros();
    let m = PrototypeModel::build(&lib, Flavor::Custom, spec).unwrap();
    let census = m.census(&lib);
    // Paper quotes 32M gates / 128M transistors for the prototype;
    // our elaboration must land in the same order of magnitude.
    assert!(census.cells > 1_000_000, "cells = {}", census.cells);
    assert!(
        census.transistors > 20_000_000 && census.transistors < 500_000_000,
        "transistors = {}",
        census.transistors
    );
}

#[test]
fn table1_direction_holds_for_all_columns() {
    // Reduced-wave version of the Table-I claim: custom wins all three
    // metrics on the benchmark geometries.
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    let cfg = TnnConfig { sim_waves: 2, ..TnnConfig::default() };
    let data = Dataset::generate(4, 1);
    for (label, spec) in table1_specs().into_iter().take(2) {
        let s = measure_column(&lib, &tech, Flavor::Std, &spec, &cfg, &data)
            .unwrap();
        let c =
            measure_column(&lib, &tech, Flavor::Custom, &spec, &cfg, &data)
                .unwrap();
        assert!(c.ppa.power_uw < s.ppa.power_uw, "{label} power");
        assert!(c.ppa.time_ns < s.ppa.time_ns, "{label} time");
        assert!(c.ppa.area_mm2 < s.ppa.area_mm2, "{label} area");
        // Deltas in the paper's ballpark (wide bands; the tight
        // comparison lives in EXPERIMENTS.md).
        let dp = 1.0 - c.ppa.power_uw / s.ppa.power_uw;
        let da = 1.0 - c.ppa.area_mm2 / s.ppa.area_mm2;
        assert!((0.15..0.60).contains(&dp), "{label} power delta {dp}");
        assert!((0.20..0.55).contains(&da), "{label} area delta {da}");
    }
}

#[test]
fn sta_and_area_agree_between_flat_and_census() {
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    let spec = ColumnSpec { p: 16, q: 4, theta: 14 };
    let (nl, _) = build_column(&lib, Flavor::Std, &spec).unwrap();
    let t = timing::analyze(&nl, &lib, &tech).unwrap();
    assert!(t.min_clock_ps > 100.0 && t.min_clock_ps < 10_000.0);
    let a_flat = area::analyze(&nl, &lib, &tech);
    let a_census = area::from_census(&nl.census(&lib), &lib, &tech);
    assert!((a_flat.die_mm2 - a_census.die_mm2).abs() < 1e-12);
}

#[test]
fn behavioral_network_learns_above_chance() {
    // Small end-to-end behavioral run: must beat chance comfortably.
    let train = Dataset::generate(80, 11);
    let test = Dataset::generate(40, 12);
    let mut net = Network::prototype(20, 3, 3);
    let params = StdpParams::default_training();
    let mut lfsr = Lfsr16::new(0xACE1);
    for img in &train.images {
        let s1 = encode_image(img, 0.04);
        let (_, post1) = net.l1.forward(&s1);
        net.l1.learn(&s1, &post1, &params, &mut lfsr);
    }
    for img in &train.images {
        let s1 = encode_image(img, 0.04);
        let (_, post1) = net.l1.forward(&s1);
        let s2 = rebase(&post1);
        let (_, post2) = net.l2.forward(&s2);
        net.l2.learn(&s2, &post2, &params, &mut lfsr);
    }
    for (img, &label) in train.images.iter().zip(&train.labels) {
        let s1 = encode_image(img, 0.04);
        let post2 = net.forward(&s1);
        net.calibrate(&post2, label);
    }
    let mut correct = 0;
    for (img, &label) in test.images.iter().zip(&test.labels) {
        let s1 = encode_image(img, 0.04);
        if net.classify(&net.forward(&s1)) == label {
            correct += 1;
        }
    }
    let acc = correct as f64 / test.len() as f64;
    assert!(acc > 0.2, "accuracy {acc} not above chance band");
}

#[test]
fn stimulus_bridge_feeds_all_benchmark_widths() {
    let data = Dataset::generate(6, 9);
    for p in [64usize, 128, 1024] {
        let stim = stimulus(&data, p, 3, 0.04);
        assert_eq!(stim.len(), 3);
        let rate = spike_rate(&stim);
        assert!(rate > 0.01 && rate < 0.95, "p={p} rate={rate}");
    }
}

#[test]
fn cli_binary_help_smoke() {
    // The tnn7 binary must at least print help (exercises arg parsing).
    let exe = env!("CARGO_BIN_EXE_tnn7");
    let out = std::process::Command::new(exe).arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("bench-table1"));
    assert!(text.contains("calibrate"));
}
