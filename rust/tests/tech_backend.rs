//! Technology-backend API integration tests: every built-in backend
//! through the full pipeline, the Liberty emit→reload round-trip
//! (bit-identical reports), and the golden equivalence between the
//! `n45-projected` backend and the pre-refactor 45nm projection.

use std::sync::Arc;

use tnn7::cells::liberty;
use tnn7::config::TnnConfig;
use tnn7::data::digits::XorShift;
use tnn7::data::Dataset;
use tnn7::flow::{measure_with, Target};
use tnn7::netlist::column::ColumnSpec;
use tnn7::netlist::Flavor;
use tnn7::ppa::scaling::NodeScaling;
use tnn7::tech::{
    from_liberty_text, BackendId, TechContext, TechRegistry, ASAP7_BASELINE,
    ASAP7_TNN7, N45_PROJECTED,
};

fn quick_cfg() -> TnnConfig {
    TnnConfig { sim_waves: 2, ..TnnConfig::default() }
}

/// Every built-in backend — plus an emitted-then-reloaded `.lib` as the
/// fourth (`liberty-file`) kind — measures a column through the full
/// pipeline.
#[test]
fn all_four_backend_kinds_run_the_full_pipeline() {
    let mut registry = TechRegistry::builtin();
    // Emit the tnn7 library and register it back as a liberty-file
    // backend.
    let tnn7 = registry.get(ASAP7_TNN7).unwrap();
    let text =
        liberty::emit(tnn7.library(), tnn7.params(), "tnn7_e2e");
    let path = std::env::temp_dir()
        .join(format!("tnn7_backend_e2e_{}.lib", std::process::id()));
    std::fs::write(&path, text).unwrap();
    let lib_spec = path.display().to_string();
    registry.resolve(&lib_spec).unwrap();

    let cfg = quick_cfg();
    let data = Arc::new(Dataset::generate(4, cfg.data_seed));
    let spec = ColumnSpec { p: 6, q: 3, theta: 8 };
    for name in
        [ASAP7_BASELINE, ASAP7_TNN7, N45_PROJECTED, lib_spec.as_str()]
    {
        let tech = registry.get(name).unwrap();
        let target = Target::column(Flavor::Std, spec)
            .with_tech(BackendId::new(name));
        let r = measure_with(target, &cfg, &tech, &data)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(r.total.power_uw > 0.0, "{name}");
        assert!(r.total.time_ns > 0.0, "{name}");
        assert!(r.total.area_mm2 > 0.0, "{name}");
        assert_eq!(r.tech_name, name);
    }
    std::fs::remove_file(&path).unwrap();
}

/// The baseline backend has no custom macros: custom-flavour targets
/// fail elaboration with a structured error instead of silently
/// borrowing another library.
#[test]
fn custom_flavour_fails_honestly_on_baseline_backend() {
    let registry = TechRegistry::builtin();
    let tech = registry.get(ASAP7_BASELINE).unwrap();
    let cfg = quick_cfg();
    let data = Arc::new(Dataset::generate(4, cfg.data_seed));
    let spec = ColumnSpec { p: 6, q: 3, theta: 8 };
    let std_ok = measure_with(
        Target::column(Flavor::Std, spec),
        &cfg,
        &tech,
        &data,
    );
    assert!(std_ok.is_ok());
    let custom = measure_with(
        Target::column(Flavor::Custom, spec),
        &cfg,
        &tech,
        &data,
    );
    assert!(custom.is_err());
}

/// PROPERTY: emit the characterized library with `cells::liberty`,
/// reload it through the `liberty-file` backend, and every area /
/// power / timing report is bit-identical to the in-memory backend —
/// across random geometries, both flavours, and per-unit detail.
/// Seeded randomized sweep (no proptest crate in the vendor set);
/// failure messages carry the seed.
#[test]
fn prop_liberty_roundtrip_reports_bit_identical() {
    let registry = TechRegistry::builtin();
    let mem = registry.get(ASAP7_TNN7).unwrap();
    let text = liberty::emit(mem.library(), mem.params(), "roundtrip");
    let reloaded = TechContext::new(
        from_liberty_text("roundtrip.lib", &text).unwrap(),
    );

    let cfg = quick_cfg();
    let data = Arc::new(Dataset::generate(4, cfg.data_seed));
    let mut r = XorShift::new(0xC0FFEE);
    for case in 0..4u32 {
        let p = 3 + (r.next_u64() % 8) as usize;
        let q = 2 + (r.next_u64() % 4) as usize;
        let spec = ColumnSpec { p, q, theta: (p + q) as u64 };
        for flavor in [Flavor::Std, Flavor::Custom] {
            let a = measure_with(
                Target::column(flavor, spec),
                &cfg,
                &mem,
                &data,
            )
            .unwrap();
            let b = measure_with(
                Target::column(flavor, spec)
                    .with_tech(BackendId::new("roundtrip.lib")),
                &cfg,
                &reloaded,
                &data,
            )
            .unwrap();
            let tag = format!("case {case} {flavor:?} {p}x{q}");
            assert_eq!(a.total.power_uw, b.total.power_uw, "{tag}");
            assert_eq!(a.total.time_ns, b.total.time_ns, "{tag}");
            assert_eq!(a.total.area_mm2, b.total.area_mm2, "{tag}");
            assert_eq!(a.units.len(), b.units.len(), "{tag}");
            for (ua, ub) in a.units.iter().zip(&b.units) {
                assert_eq!(ua.ppa.power_uw, ub.ppa.power_uw, "{tag}");
                assert_eq!(ua.ppa.time_ns, ub.ppa.time_ns, "{tag}");
                assert_eq!(ua.ppa.area_mm2, ub.ppa.area_mm2, "{tag}");
                assert_eq!(ua.clock_ps, ub.clock_ps, "{tag}");
                assert_eq!(ua.cells, ub.cells, "{tag}");
                assert_eq!(ua.transistors, ub.transistors, "{tag}");
            }
        }
    }
}

/// GOLDEN: the `n45-projected` backend reproduces the pre-refactor
/// 45nm path exactly — the old `TechNode::N45` target projected the
/// natively composed PPA through `NodeScaling::n45_to_7()` with
/// power×power_factor, time×delay_factor, area×area_factor, which the
/// old `scale45` stage exposed as its model factors.  Same factors,
/// same operation order, bit-identical results.
#[test]
fn n45_projected_matches_legacy_scale45_projection() {
    let registry = TechRegistry::builtin();
    let native = registry.get(ASAP7_TNN7).unwrap();
    let n45 = registry.get(N45_PROJECTED).unwrap();
    assert_eq!(n45.node_label(), "45nm");
    let m = n45.scaling().expect("n45 backend carries its model");

    // The model factors are the exact constants the old stage reported.
    let legacy = NodeScaling::n45_to_7();
    assert_eq!(m.power_factor(), legacy.power_factor());
    assert_eq!(m.delay_factor(), legacy.delay_factor());
    assert_eq!(m.area_factor(), legacy.area_factor());

    let cfg = quick_cfg();
    let data = Arc::new(Dataset::generate(4, cfg.data_seed));
    let spec = ColumnSpec { p: 8, q: 4, theta: 10 };
    for flavor in [Flavor::Std, Flavor::Custom] {
        let a = measure_with(
            Target::column(flavor, spec),
            &cfg,
            &native,
            &data,
        )
        .unwrap();
        let b = measure_with(
            Target::column(flavor, spec)
                .with_tech(BackendId::new(N45_PROJECTED)),
            &cfg,
            &n45,
            &data,
        )
        .unwrap();
        // Bit-identical to applying the legacy projection by hand.
        assert_eq!(
            b.total.power_uw,
            a.total.power_uw * legacy.power_factor(),
            "{flavor:?}"
        );
        assert_eq!(
            b.total.time_ns,
            a.total.time_ns * legacy.delay_factor(),
            "{flavor:?}"
        );
        assert_eq!(
            b.total.area_mm2,
            a.total.area_mm2 * legacy.area_factor(),
            "{flavor:?}"
        );
        // Per-unit reports stay native — only the composed total is
        // projected, exactly as before.
        assert_eq!(b.units[0].ppa.power_uw, a.units[0].ppa.power_uw);
        assert_eq!(b.node_label, "45nm");
    }
}

/// A `.lib` path works as a target's technology end to end through the
/// one-call `flow::measure` entry point (the `--tech path.lib` CLI
/// path).
#[test]
fn lib_path_resolves_through_one_call_measure() {
    let registry = TechRegistry::builtin();
    let tnn7 = registry.get(ASAP7_TNN7).unwrap();
    let text = liberty::emit(tnn7.library(), tnn7.params(), "onecall");
    let path = std::env::temp_dir()
        .join(format!("tnn7_onecall_{}.lib", std::process::id()));
    std::fs::write(&path, text).unwrap();

    let cfg = quick_cfg();
    let spec = ColumnSpec { p: 4, q: 2, theta: 4 };
    let target = Target::column(Flavor::Std, spec)
        .with_tech(BackendId::new(path.display().to_string()));
    let r = tnn7::flow::measure(target, &cfg).unwrap();
    assert!(r.total.power_uw > 0.0);
    std::fs::remove_file(&path).unwrap();
}
