//! Integration tests for the `tnn7 serve` daemon and the stage cache
//! (DESIGN.md §11): a real server on an ephemeral port, driven through
//! the same one-shot HTTP client the bench uses.
//!
//! The acceptance criteria live here: a repeated identical query is
//! served entirely from cache (`executed=0`, asserted via the
//! `X-Tnn7-Cache` header) with a byte-identical body, and changing
//! only the simulate config re-runs only simulate-and-later.

use std::sync::Arc;

use tnn7::config::TnnConfig;
use tnn7::data::digits::XorShift;
use tnn7::data::Dataset;
use tnn7::flow::cache::StageCache;
use tnn7::flow::{self, Target};
use tnn7::netlist::column::ColumnSpec;
use tnn7::netlist::Flavor;
use tnn7::runtime::json::Json;
use tnn7::serve::http::{
    fetch, fetch_with_retry, RetryPolicy, MAX_BODY_BYTES,
};
use tnn7::serve::{ServeConfig, Server, ServerHandle};
use tnn7::tech::TechRegistry;

/// A tiny-column query body: cheap enough that the whole suite runs in
/// seconds, real enough to exercise all six stages.
const TINY: &str = r#"{"target": "custom", "col": "8x4", "waves": 2}"#;

fn spawn(threads: usize, queue: usize, delay_ms: u64) -> ServerHandle {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        threads,
        queue,
        debug_flow_delay_ms: delay_ms,
        ..ServeConfig::default()
    };
    Server::spawn(cfg).expect("server spawns on an ephemeral port")
}

fn stop(handle: ServerHandle) {
    handle.shutdown();
    handle.join();
}

#[test]
fn repeated_query_is_all_cache_and_byte_identical() {
    let h = spawn(2, 16, 0);
    let cold = fetch(h.addr(), "POST", "/flow", TINY).unwrap();
    assert_eq!(cold.status, 200, "cold body: {}", cold.body);
    assert_eq!(
        cold.header("X-Tnn7-Cache").unwrap(),
        "executed=6 mem=0 disk=0",
        "cold run executes the full 6-stage pipeline"
    );
    assert_eq!(cold.header("X-Tnn7-Dedup"), Some("leader"));
    // The body is the report artifact with real totals.
    let j = Json::parse(&cold.body).unwrap();
    assert_eq!(j.field("stage").unwrap().as_str().unwrap(), "report");
    let total = j.field("total").unwrap();
    assert!(total.field("power_uw").unwrap().as_f64().unwrap() > 0.0);

    // THE acceptance criterion: the repeat executes zero stages and
    // serves the exact same bytes.
    let warm = fetch(h.addr(), "POST", "/flow", TINY).unwrap();
    assert_eq!(warm.status, 200);
    assert_eq!(
        warm.header("X-Tnn7-Cache").unwrap(),
        "executed=0 mem=6 disk=0",
        "warm run must be served entirely from the memory tier"
    );
    assert_eq!(warm.body, cold.body, "cached reply must be byte-identical");

    // lanes/threads are execution details: they join the same cache
    // chain and the same bytes.
    let parallel = fetch(
        h.addr(),
        "POST",
        "/flow",
        r#"{"target": "custom", "col": "8x4", "waves": 2,
            "lanes": 4, "threads": 2}"#,
    )
    .unwrap();
    assert_eq!(
        parallel.header("X-Tnn7-Cache").unwrap(),
        "executed=0 mem=6 disk=0"
    );
    assert_eq!(parallel.body, cold.body);
    stop(h);
}

#[test]
fn changing_simulate_config_reruns_only_downstream() {
    let h = spawn(2, 16, 0);
    let a = fetch(h.addr(), "POST", "/flow", TINY).unwrap();
    assert_eq!(a.status, 200);

    // Same netlist, different simulate config: elaborate and sta
    // replay from memory, simulate/power/area/report re-execute.
    let b = fetch(
        h.addr(),
        "POST",
        "/flow",
        r#"{"target": "custom", "col": "8x4", "waves": 3}"#,
    )
    .unwrap();
    assert_eq!(b.status, 200);
    assert_eq!(
        b.header("X-Tnn7-Cache").unwrap(),
        "executed=4 mem=2 disk=0",
        "a waves change must re-run only simulate-and-later"
    );
    assert_ne!(b.body, a.body, "different waves measure differently");
    stop(h);
}

/// Engine kind and pass pipeline are part of the simulate cache key: a
/// `compiled` request never rides a `packed`/`auto` entry (and vice
/// versa), a pass-pipeline change re-runs simulate-and-later, and the
/// canonical pass form (`all` vs the spelled-out list) aliases one
/// entry.  Because every engine is bit-identical, the recomputed
/// report bytes still match — the key separates *provenance*, not
/// results.
#[test]
fn engine_and_pass_requests_key_the_cache() {
    let h = spawn(2, 16, 0);
    let cold = fetch(h.addr(), "POST", "/flow", TINY).unwrap();
    assert_eq!(cold.status, 200, "cold body: {}", cold.body);
    assert_eq!(
        cold.header("X-Tnn7-Cache").unwrap(),
        "executed=6 mem=0 disk=0"
    );

    // Same design point on the compiled engine: elaborate/sta replay
    // from memory, simulate-and-later must re-execute.
    let compiled_body = r#"{"target": "custom", "col": "8x4",
        "waves": 2, "engine": "compiled"}"#;
    let compiled = fetch(h.addr(), "POST", "/flow", compiled_body).unwrap();
    assert_eq!(compiled.status, 200, "{}", compiled.body);
    assert_eq!(
        compiled.header("X-Tnn7-Cache").unwrap(),
        "executed=4 mem=2 disk=0",
        "an engine change must re-run simulate-and-later"
    );
    assert_eq!(
        compiled.body, cold.body,
        "engines are bit-identical: recomputation reproduces the bytes"
    );

    // Repeat compiled request: fully cached now.
    let warm = fetch(h.addr(), "POST", "/flow", compiled_body).unwrap();
    assert_eq!(
        warm.header("X-Tnn7-Cache").unwrap(),
        "executed=0 mem=6 disk=0"
    );

    // A different pass pipeline under the same engine is a different
    // simulate entry.
    let pruned = fetch(
        h.addr(),
        "POST",
        "/flow",
        r#"{"target": "custom", "col": "8x4", "waves": 2,
            "engine": "compiled", "passes": "fold,dce"}"#,
    )
    .unwrap();
    assert_eq!(
        pruned.header("X-Tnn7-Cache").unwrap(),
        "executed=4 mem=2 disk=0",
        "a pass-pipeline change must re-run simulate-and-later"
    );
    assert_eq!(pruned.body, cold.body);

    // ...but the canonical spelling of the full pipeline aliases the
    // `all` entry exactly.
    let spelled = fetch(
        h.addr(),
        "POST",
        "/flow",
        r#"{"target": "custom", "col": "8x4", "waves": 2,
            "engine": "compiled",
            "passes": "fold,dce,coalesce,resched"}"#,
    )
    .unwrap();
    assert_eq!(
        spelled.header("X-Tnn7-Cache").unwrap(),
        "executed=0 mem=6 disk=0",
        "canonical pass spelling must alias the `all` entry"
    );
    assert_eq!(spelled.body, cold.body);

    // /stats reports the per-request engine and pass-pipeline mix.
    let stats = fetch(h.addr(), "GET", "/stats", "").unwrap();
    let j = Json::parse(&stats.body).unwrap();
    let engines = j.field("engine_requests").unwrap();
    assert_eq!(engines.field("auto").unwrap().as_usize().unwrap(), 1);
    assert_eq!(
        engines.field("compiled").unwrap().as_usize().unwrap(),
        4
    );
    let passes = j.field("pass_requests").unwrap();
    assert_eq!(
        passes
            .field("fold,dce,coalesce,resched")
            .unwrap()
            .as_usize()
            .unwrap(),
        4,
        "`all` and the spelled-out pipeline aggregate into one row"
    );
    assert_eq!(
        passes.field("fold,dce").unwrap().as_usize().unwrap(),
        1
    );
    stop(h);
}

/// Disk-tier flavour of the same property: a restarted daemon replays
/// a same-engine pipeline from disk, but an engine change finds no
/// entry for its chain (the disk tier only answers whole-pipeline
/// hits) and recomputes — never serving another engine's artifacts.
#[test]
fn disk_tier_keys_on_the_engine_request() {
    let dir = std::env::temp_dir()
        .join(format!("tnn7_serve_engine_disk_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = |addr: &str| ServeConfig {
        addr: addr.into(),
        cache: tnn7::flow::cache::CacheConfig {
            mem_entries: 64,
            dir: Some(dir.clone()),
        },
        ..ServeConfig::default()
    };

    let a = Server::spawn(cfg("127.0.0.1:0")).unwrap();
    let cold = fetch(a.addr(), "POST", "/flow", TINY).unwrap();
    assert_eq!(cold.status, 200);
    stop(a);

    let b = Server::spawn(cfg("127.0.0.1:0")).unwrap();
    // Same request: whole pipeline replays from disk.
    let replay = fetch(b.addr(), "POST", "/flow", TINY).unwrap();
    assert_eq!(
        replay.header("X-Tnn7-Cache").unwrap(),
        "executed=0 mem=0 disk=6"
    );
    assert_eq!(replay.body, cold.body);
    // Engine change: its simulate key differs, so the requested chain
    // has no complete disk entry.  Disk hits are whole-pipeline-only
    // (and never populate the memory tier), so the daemon recomputes
    // everything rather than serve the packed chain's artifacts.
    let compiled_body = r#"{"target": "custom", "col": "8x4",
        "waves": 2, "engine": "compiled"}"#;
    let compiled = fetch(b.addr(), "POST", "/flow", compiled_body).unwrap();
    assert_eq!(compiled.status, 200, "{}", compiled.body);
    assert_eq!(
        compiled.header("X-Tnn7-Cache").unwrap(),
        "executed=6 mem=0 disk=0",
        "a compiled request must not ride the auto entry's disk chain"
    );
    assert_eq!(compiled.body, cold.body);
    // The compiled chain is now durable under its own keys: a third
    // daemon replays it from disk without touching the auto entry.
    stop(b);
    let c = Server::spawn(cfg("127.0.0.1:0")).unwrap();
    let replay_c = fetch(c.addr(), "POST", "/flow", compiled_body).unwrap();
    assert_eq!(
        replay_c.header("X-Tnn7-Cache").unwrap(),
        "executed=0 mem=0 disk=6"
    );
    assert_eq!(replay_c.body, cold.body);
    stop(c);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_duplicates_share_one_computation() {
    // A long leader delay so the followers deterministically arrive
    // while the computation is in flight.
    let h = spawn(4, 16, 500);
    let addr = h.addr();
    let first =
        std::thread::spawn(move || fetch(addr, "POST", "/flow", TINY).unwrap());
    std::thread::sleep(std::time::Duration::from_millis(150));
    let followers: Vec<_> = (0..2)
        .map(|_| {
            std::thread::spawn(move || {
                fetch(addr, "POST", "/flow", TINY).unwrap()
            })
        })
        .collect();
    let mut responses = vec![first.join().unwrap()];
    responses.extend(followers.into_iter().map(|t| t.join().unwrap()));

    let leaders = responses
        .iter()
        .filter(|r| r.header("X-Tnn7-Dedup") == Some("leader"))
        .count();
    let joined = responses
        .iter()
        .filter(|r| r.header("X-Tnn7-Dedup") == Some("joined"))
        .count();
    assert_eq!((leaders, joined), (1, 2), "one leader, two joiners");
    for r in &responses {
        assert_eq!(r.status, 200);
        assert_eq!(r.body, responses[0].body, "all duplicates share bytes");
    }

    let stats = fetch(addr, "GET", "/stats", "").unwrap();
    let j = Json::parse(&stats.body).unwrap();
    assert_eq!(j.field("dedup_joins").unwrap().as_usize().unwrap(), 2);
    assert_eq!(j.field("flow_requests").unwrap().as_usize().unwrap(), 1);
    stop(h);
}

#[test]
fn overload_answers_inline_503_with_retry_after() {
    // One worker, queue depth one: request 1 occupies the worker (held
    // by the debug delay), request 2 fills the queue, request 3 must
    // get an inline 503 from the accept thread.
    let h = spawn(1, 1, 700);
    let addr = h.addr();
    let r1 =
        std::thread::spawn(move || fetch(addr, "POST", "/flow", TINY).unwrap());
    std::thread::sleep(std::time::Duration::from_millis(200));
    let r2 =
        std::thread::spawn(move || fetch(addr, "POST", "/flow", TINY).unwrap());
    std::thread::sleep(std::time::Duration::from_millis(100));
    let r3 = fetch(addr, "POST", "/flow", TINY).unwrap();
    assert_eq!(r3.status, 503, "overflow must be answered inline");
    assert_eq!(r3.header("Retry-After"), Some("1"));
    assert!(r3.body.contains("queue is full"));

    // The queued requests still complete normally.
    assert_eq!(r1.join().unwrap().status, 200);
    assert_eq!(r2.join().unwrap().status, 200);
    let stats = fetch(addr, "GET", "/stats", "").unwrap();
    let j = Json::parse(&stats.body).unwrap();
    assert!(j.field("overloads").unwrap().as_usize().unwrap() >= 1);
    stop(h);
}

#[test]
fn disk_tier_replays_across_daemon_restarts() {
    let dir = std::env::temp_dir()
        .join(format!("tnn7_serve_disk_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = |addr: &str| ServeConfig {
        addr: addr.into(),
        cache: tnn7::flow::cache::CacheConfig {
            mem_entries: 64,
            dir: Some(dir.clone()),
        },
        ..ServeConfig::default()
    };

    let a = Server::spawn(cfg("127.0.0.1:0")).unwrap();
    let cold = fetch(a.addr(), "POST", "/flow", TINY).unwrap();
    assert_eq!(cold.status, 200);
    stop(a);

    // A fresh daemon process-equivalent: empty memory tier, same disk
    // root. The whole pipeline replays from disk, bytes identical.
    let b = Server::spawn(cfg("127.0.0.1:0")).unwrap();
    let replay = fetch(b.addr(), "POST", "/flow", TINY).unwrap();
    assert_eq!(replay.status, 200);
    assert_eq!(
        replay.header("X-Tnn7-Cache").unwrap(),
        "executed=0 mem=0 disk=6",
        "cold-start daemon must replay the full pipeline from disk"
    );
    assert_eq!(replay.body, cold.body);
    stop(b);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn routes_stats_health_and_errors() {
    let h = spawn(2, 16, 0);
    let addr = h.addr();

    let health = fetch(addr, "GET", "/healthz", "").unwrap();
    assert_eq!(health.status, 200);
    assert!(health.body.contains("\"ok\""));

    let stats = fetch(addr, "GET", "/stats", "").unwrap();
    let j = Json::parse(&stats.body).unwrap();
    for key in [
        "requests",
        "flow_requests",
        "errors",
        "overloads",
        "stalled_writes",
        "dedup_joins",
        "stages",
        "engine_requests",
        "pass_requests",
        "cache",
        "inflight",
    ] {
        assert!(j.get(key).is_some(), "stats must carry `{key}`");
    }

    // Structured client errors, counted.
    let bad = fetch(addr, "POST", "/flow", "{\"wavez\": 1}").unwrap();
    assert_eq!(bad.status, 400);
    assert!(bad.body.contains("error"));
    let missing = fetch(addr, "GET", "/nope", "").unwrap();
    assert_eq!(missing.status, 404);
    let method = fetch(addr, "DELETE", "/flow", "").unwrap();
    assert_eq!(method.status, 405);
    let stats = fetch(addr, "GET", "/stats", "").unwrap();
    let j = Json::parse(&stats.body).unwrap();
    assert!(j.field("errors").unwrap().as_usize().unwrap() >= 3);
    stop(h);
}

#[test]
fn post_shutdown_drains_and_exits() {
    let h = spawn(2, 16, 0);
    let addr = h.addr();
    assert_eq!(fetch(addr, "POST", "/flow", TINY).unwrap().status, 200);
    let bye = fetch(addr, "POST", "/shutdown", "").unwrap();
    assert_eq!(bye.status, 200);
    assert!(bye.body.contains("draining"));
    // A hung drain would hang the test here — joining IS the assertion.
    h.join();
}

/// A request whose declared body exceeds the daemon's bound is refused
/// with a structured 413 before any body byte is read — a live-daemon
/// check of the `read_request` limit, not just the unit test.
#[test]
fn oversized_request_body_answered_with_inline_413() {
    use std::io::{Read as _, Write as _};
    let h = spawn(2, 16, 0);
    let mut c = std::net::TcpStream::connect(h.addr()).unwrap();
    c.set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    // Declare a body one byte past the limit — and never send it.  The
    // daemon must answer from the headers alone and close.
    c.write_all(
        format!(
            "POST /flow HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        )
        .as_bytes(),
    )
    .unwrap();
    let mut raw = String::new();
    c.read_to_string(&mut raw).unwrap();
    assert!(
        raw.starts_with("HTTP/1.1 413 "),
        "oversized request must get an inline 413, got: {raw}"
    );
    assert!(raw.contains("too large"), "{raw}");

    // The daemon is still healthy afterwards.
    let ok = fetch(h.addr(), "GET", "/healthz", "").unwrap();
    assert_eq!(ok.status, 200);
    stop(h);
}

/// The retrying client turns a transient overload (inline 503 with
/// Retry-After) into an eventual 200 once the queue drains — the
/// end-to-end pairing of the daemon's backpressure and the client's
/// backoff.
#[test]
fn retry_client_rides_out_queue_overload() {
    // One worker, queue depth one, a leader slow enough that the
    // retry client's first attempts see a full queue.
    let h = spawn(1, 1, 500);
    let addr = h.addr();
    let r1 =
        std::thread::spawn(move || fetch(addr, "POST", "/flow", TINY).unwrap());
    std::thread::sleep(std::time::Duration::from_millis(150));
    let r2 =
        std::thread::spawn(move || fetch(addr, "POST", "/flow", TINY).unwrap());
    std::thread::sleep(std::time::Duration::from_millis(100));

    // Plain fetch would see the inline 503 here; the retry client
    // sleeps through it (Retry-After capped at max_delay_ms) and
    // lands once the worker frees up.
    let policy = RetryPolicy {
        attempts: 8,
        base_delay_ms: 50,
        max_delay_ms: 300,
        jitter_seed: 7,
    };
    let resp =
        fetch_with_retry(addr, "POST", "/flow", TINY, &policy).unwrap();
    assert_eq!(
        resp.status, 200,
        "retry client must outlast the overload: {}",
        resp.body
    );

    assert_eq!(r1.join().unwrap().status, 200);
    assert_eq!(r2.join().unwrap().status, 200);
    let stats = fetch(addr, "GET", "/stats", "").unwrap();
    let j = Json::parse(&stats.body).unwrap();
    assert!(j.field("overloads").unwrap().as_usize().unwrap() >= 1);
    stop(h);
}

/// Find one series value in a Prometheus text exposition: `labels` is
/// a `k="v"` fragment that must appear inside the label block (None
/// matches the unlabeled series exactly).
fn metric(text: &str, name: &str, labels: Option<&str>) -> u64 {
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let Some((series, value)) = line.rsplit_once(' ') else {
            continue;
        };
        let hit = match labels {
            None => series == name,
            Some(l) => {
                series.strip_prefix(name).is_some_and(|rest| {
                    rest.starts_with('{') && rest.contains(l)
                })
            }
        };
        if hit {
            return value.parse().unwrap_or_else(|_| {
                panic!("non-numeric value in `{line}`")
            });
        }
    }
    panic!("metric {name} {labels:?} not in exposition:\n{text}");
}

/// GET /metrics is well-formed Prometheus text, reads the same
/// registry as /stats, and its counters advance exactly across an
/// uncached/cached query pair: the cold run misses all six stages,
/// the warm run hits all six in the memory tier.
#[test]
fn metrics_exposition_tracks_cached_vs_uncached_pair() {
    let h = spawn(2, 16, 0);
    let addr = h.addr();

    let cold = fetch(addr, "POST", "/flow", TINY).unwrap();
    assert_eq!(cold.status, 200, "cold body: {}", cold.body);
    let m1 = fetch(addr, "GET", "/metrics", "").unwrap();
    assert_eq!(m1.status, 200);
    assert_eq!(
        m1.header("Content-Type"),
        Some("text/plain; version=0.0.4; charset=utf-8"),
        "Prometheus text content type"
    );
    // Well-formed 0.0.4 text: every non-comment line is
    // `name[{labels}] value` with a numeric value.
    for line in m1.body.lines() {
        if line.is_empty() || line.starts_with("# ") {
            continue;
        }
        let (series, value) =
            line.rsplit_once(' ').expect("`series value` line");
        assert!(
            value.parse::<i64>().is_ok(),
            "numeric value in `{line}`"
        );
        let name = series.split('{').next().unwrap();
        assert!(
            !name.is_empty()
                && name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_'),
            "metric name in `{line}`"
        );
        if series.contains('{') {
            assert!(series.ends_with('}'), "label block in `{line}`");
        }
    }
    assert_eq!(metric(&m1.body, "tnn7_cache_misses_total", None), 6);
    assert_eq!(metric(&m1.body, "tnn7_serve_flow_runs_total", None), 1);

    let warm = fetch(addr, "POST", "/flow", TINY).unwrap();
    assert_eq!(
        warm.header("X-Tnn7-Cache").unwrap(),
        "executed=0 mem=6 disk=0"
    );
    let m2 = fetch(addr, "GET", "/metrics", "").unwrap();
    assert_eq!(
        metric(&m2.body, "tnn7_cache_hits_total", Some("tier=\"mem\"")),
        6,
        "warm run hits all six stages in the memory tier"
    );
    assert_eq!(
        metric(&m2.body, "tnn7_cache_misses_total", None),
        6,
        "warm run adds no misses"
    );
    assert_eq!(metric(&m2.body, "tnn7_serve_flow_runs_total", None), 2);
    assert!(
        metric(
            &m2.body,
            "tnn7_serve_request_micros_count",
            Some("endpoint=\"/flow\"")
        ) >= 2,
        "per-endpoint latency histogram observes both flow requests"
    );
    assert_eq!(
        metric(
            &m2.body,
            "tnn7_flow_stage_runs_total",
            Some("stage=\"simulate\"")
        ),
        2,
        "stage counters count replays too: one executed, one mem hit"
    );
    assert_eq!(
        metric(
            &m2.body,
            "tnn7_flow_stage_outcomes_total",
            Some("outcome=\"executed\",stage=\"simulate\"")
        ),
        1
    );
    assert_eq!(
        metric(
            &m2.body,
            "tnn7_flow_stage_outcomes_total",
            Some("outcome=\"mem_hit\",stage=\"simulate\"")
        ),
        1
    );

    // /stats is a JSON view over the same registry — the two cannot
    // drift.
    let stats = fetch(addr, "GET", "/stats", "").unwrap();
    let j = Json::parse(&stats.body).unwrap();
    assert_eq!(
        j.field("flow_requests").unwrap().as_usize().unwrap() as u64,
        metric(&m2.body, "tnn7_serve_flow_runs_total", None)
    );
    stop(h);
}

/// PROPERTY: for random small design points, the cached measurement is
/// bit-identical to the uncached one, cold and warm — and the warm run
/// executes zero stages.  Seeded sweep; the seed is in every message.
#[test]
fn prop_warm_and_cold_cached_runs_match_uncached() {
    let registry = TechRegistry::builtin();
    let tech = registry.get(tnn7::tech::ASAP7_TNN7).unwrap();
    for seed in 0..6u64 {
        let mut r = XorShift::new(seed + 31);
        let p = 4 + (r.next_u64() % 12) as usize;
        let q = 2 + (r.next_u64() % 4) as usize;
        let waves = 2 + (r.next_u64() % 2) as usize;
        let cfg = TnnConfig {
            sim_waves: waves,
            ..TnnConfig::default()
        };
        let data = Arc::new(Dataset::generate(waves.max(4), cfg.data_seed));
        let target =
            Target::column(Flavor::Custom, ColumnSpec::benchmark(p, q));

        let plain =
            flow::measure_with(target.clone(), &cfg, &tech, &data).unwrap();
        let cache = StageCache::in_memory(64);
        let (cold, cold_trace) = flow::measure_cached(
            target.clone(),
            &cfg,
            &tech,
            &data,
            Some(&cache),
        )
        .unwrap();
        let (warm, warm_trace) =
            flow::measure_cached(target, &cfg, &tech, &data, Some(&cache))
                .unwrap();

        for (name, got) in [("cold", &cold), ("warm", &warm)] {
            assert_eq!(
                got.total.power_uw.to_bits(),
                plain.total.power_uw.to_bits(),
                "seed {seed} {p}x{q} w{waves}: {name} power differs"
            );
            assert_eq!(
                got.total.time_ns.to_bits(),
                plain.total.time_ns.to_bits(),
                "seed {seed} {p}x{q} w{waves}: {name} time differs"
            );
            assert_eq!(
                got.total.area_mm2.to_bits(),
                plain.total.area_mm2.to_bits(),
                "seed {seed} {p}x{q} w{waves}: {name} area differs"
            );
        }
        assert_eq!(
            cold_trace.executed(),
            cold_trace.stages.len(),
            "seed {seed}: cold run executes everything"
        );
        assert_eq!(
            warm_trace.executed(),
            0,
            "seed {seed}: warm run executes nothing"
        );
    }
}
