//! Unified error type for the framework.

use std::fmt;

/// Framework-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Framework-wide error enumeration.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / value problems.
    Config(String),
    /// Netlist elaboration errors (bad ports, width mismatches, cycles).
    Netlist(String),
    /// Simulation errors (X at a checked output, missing stimulus).
    Sim(String),
    /// Cell-library errors (unknown cell, bad characterization data).
    Cells(String),
    /// PPA engine errors.
    Ppa(String),
    /// PJRT / artifact-loading errors.
    Runtime(String),
    /// Workload / dataset errors.
    Data(String),
    /// I/O with context.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Netlist(m) => write!(f, "netlist error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Cells(m) => write!(f, "cell-library error: {m}"),
            Error::Ppa(m) => write!(f, "ppa error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

macro_rules! ctor {
    ($fn_name:ident, $variant:ident) => {
        impl Error {
            /// Construct the corresponding error variant from any message.
            pub fn $fn_name(msg: impl Into<String>) -> Self {
                Error::$variant(msg.into())
            }
        }
    };
}

ctor!(config, Config);
ctor!(netlist, Netlist);
ctor!(sim, Sim);
ctor!(cells, Cells);
ctor!(ppa, Ppa);
ctor!(runtime, Runtime);
ctor!(data, Data);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::netlist("port width mismatch");
        assert!(e.to_string().contains("netlist"));
        assert!(e.to_string().contains("port width mismatch"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("gone"));
    }
}
