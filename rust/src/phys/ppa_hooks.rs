//! Wire-aware PPA corrections: where the physical-design model feeds
//! back into [`crate::ppa`].
//!
//! * **Area** — [`placed_area`] replaces the census roll-up
//!   (`Σ cell / UTILIZATION`) with the placed floorplan's actual die
//!   outline (row-quantized, keep-outs included).
//! * **Power** — [`wire_power_uw`] charges each driver toggle the
//!   switching energy of its output nets' wire load (activity ×
//!   per-net wire energy, reusing the simulator's per-instance toggle
//!   counts), reported as the `wire_uw` split of
//!   [`crate::ppa::power::PowerReport`].
//! * **Timing** — [`wire_timing`] re-runs STA with the per-net
//!   Elmore-style wire delays added after every driving cell
//!   ([`crate::ppa::timing::analyze_with_wire`]).

use crate::cells::{Library, TechParams};
use crate::error::Result;
use crate::netlist::Netlist;
use crate::ppa::area::AreaReport;
use crate::ppa::timing::{analyze_with_wire, TimingReport};
use crate::sim::Activity;

use super::place::Placement;
use super::wire::WireModel;

/// Area report from a placed floorplan: `cell_um2` is the summed
/// placed cell area, `die_mm2` the actual (row-quantized) die outline.
pub fn placed_area(pl: &Placement) -> AreaReport {
    let cell_um2: f64 = pl
        .width_um
        .iter()
        .map(|w| w * pl.floorplan.row_height_um)
        .sum();
    AreaReport { cell_um2, die_mm2: pl.die_mm2() }
}

/// Wire switching power (µW): every output toggle of instance `i`
/// switches the wire load of its output nets.
///
/// `clock_ps` is the (wire-aware) clock period the design runs at;
/// the time base matches [`crate::ppa::power::analyze`] so the split
/// composes into one total.
pub fn wire_power_uw(
    nl: &Netlist,
    act: &Activity,
    wires: &WireModel,
    clock_ps: f64,
) -> f64 {
    assert!(act.cycles > 0, "simulate before computing wire power");
    let t_sim_s = act.cycles as f64 * clock_ps * 1e-12;
    let mut fj = 0.0f64;
    for i in 0..nl.insts.len() {
        if act.toggles[i] == 0 {
            continue;
        }
        let e: f64 = nl
            .inst_outs(i)
            .iter()
            .map(|o| wires.nets[o.0 as usize].energy_fj)
            .sum();
        fj += act.toggles[i] as f64 * e;
    }
    // fJ / s = 1e-15 W; report µW: factor 1e-9.
    fj * 1e-9 / t_sim_s
}

/// Wire-aware STA: the ordinary analysis with each net's wire delay
/// added after its driving cell.
pub fn wire_timing(
    nl: &Netlist,
    lib: &Library,
    tech: &TechParams,
    wires: &WireModel,
) -> Result<TimingReport> {
    analyze_with_wire(nl, lib, tech, &wires.net_delay_ps())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::column::{build_column, ColumnSpec};
    use crate::netlist::Flavor;
    use crate::phys::floorplan::FloorplanSpec;
    use crate::phys::place::{place, PlacerConfig};
    use crate::phys::wire::extract;
    use crate::ppa::timing;
    use crate::sim::testbench::ColumnTestbench;
    use crate::tech::WireParams;
    use crate::tnn::stdp::RandPair;
    use crate::tnn::{Lfsr16, StdpParams};

    fn fixture() -> (Netlist, Placement, WireModel, Library, TechParams)
    {
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let spec = ColumnSpec { p: 6, q: 3, theta: 9 };
        let (nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let fspec =
            FloorplanSpec::new(0.7, 1.0, &WireParams::asap7());
        let pl = place(&nl, &lib, &tech, &fspec, &PlacerConfig::default())
            .unwrap();
        let wires = extract(&pl, &WireParams::asap7());
        (nl, pl, wires, lib, tech)
    }

    #[test]
    fn placed_die_close_to_census_die() {
        let (nl, pl, _w, lib, tech) = fixture();
        let census = crate::ppa::area::analyze(&nl, &lib, &tech);
        let placed = placed_area(&pl);
        assert!(
            (placed.cell_um2 - census.cell_um2).abs()
                < 1e-6 * census.cell_um2
        );
        // Same order of magnitude; row quantization and whitespace
        // keep it within 2x of the census estimate.
        let ratio = placed.die_mm2 / census.die_mm2;
        assert!(ratio > 0.5 && ratio < 2.0, "ratio {ratio}");
    }

    #[test]
    fn wire_delay_slows_the_clock_wire_power_positive() {
        let (nl, pl, wires, lib, tech) = fixture();
        let dry = timing::analyze(&nl, &lib, &tech).unwrap();
        let wet = wire_timing(&nl, &lib, &tech, &wires).unwrap();
        assert!(wet.min_clock_ps > dry.min_clock_ps);
        assert!(wet.wave_ns > dry.wave_ns);

        // Simulate a couple of waves for real toggle counts.
        let spec = ColumnSpec { p: 6, q: 3, theta: 9 };
        let (nl2, ports) =
            build_column(&lib, Flavor::Custom, &spec).unwrap();
        let mut tb = ColumnTestbench::new(&nl2, &ports, &lib).unwrap();
        let params = StdpParams::default_training();
        let mut lfsr = Lfsr16::new(0xACE1);
        for w in 0..3 {
            let s: Vec<i32> =
                (0..spec.p).map(|j| ((j + w) % 8) as i32).collect();
            let rand: Vec<RandPair> = (0..spec.p * spec.q)
                .map(|_| lfsr.draw_pair())
                .collect();
            tb.run_wave(&s, &rand, &params);
        }
        let p = wire_power_uw(
            &nl2,
            tb.activity(),
            &wires,
            wet.min_clock_ps,
        );
        assert!(p > 0.0, "wire power {p}");
        // Wire power halves when the clock period doubles (same
        // charge over twice the time).
        let p2 = wire_power_uw(
            &nl2,
            tb.activity(),
            &wires,
            wet.min_clock_ps * 2.0,
        );
        assert!((p2 * 2.0 - p).abs() < 1e-9 * p);
    }
}
