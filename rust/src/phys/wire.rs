//! Placement-driven wire model: per-net HPWL → capacitance,
//! resistance, and Elmore-style delay.
//!
//! Each net's routed length is estimated as its half-perimeter
//! wirelength over the placed instance terminals (the standard
//! pre-route estimator).  The technology's [`WireParams`] then give:
//!
//! * `cap_ff = hpwl_mm × cap_ff_per_mm` — the physical load the net
//!   adds (reported per net and in total);
//! * `energy_fj = hpwl_mm × energy_fj_per_mm` — switching energy per
//!   output toggle in the library's fitted energy scale (consumed by
//!   [`super::ppa_hooks::wire_power_uw`]);
//! * `delay_ps = hpwl_mm × delay_ps_per_mm + 0.345 × R_wire × C_wire`
//!   — a linear driver-loading term plus the distributed-RC Elmore
//!   term (`0.69 × R × C / 2`, Ω·fF = 10⁻³ ps), consumed by the
//!   wire-aware STA ([`crate::ppa::timing::analyze_with_wire`]).
//!
//! Tie-cell constant nets are excluded throughout (see
//! [`super::place::net_instances`]); the per-net terminal lists are
//! computed once by the placer and reused here
//! ([`super::place::Placement::net_pins`]).

use crate::tech::WireParams;

use super::place::{net_bbox, Placement};

/// Wire quantities for one net.
#[derive(Debug, Clone, Copy, Default)]
pub struct NetWire {
    /// Half-perimeter wirelength (mm).
    pub hpwl_mm: f64,
    /// Wire capacitance (fF).
    pub cap_ff: f64,
    /// Wire resistance (Ω).
    pub res_ohm: f64,
    /// Switching energy per driver toggle (fJ, fitted scale).
    pub energy_fj: f64,
    /// Elmore-style wire delay added after the driving cell (ps).
    pub delay_ps: f64,
}

/// The extracted wire model of one placed netlist.
#[derive(Debug, Clone)]
pub struct WireModel {
    /// Per-net quantities, indexed by `NetId`.
    pub nets: Vec<NetWire>,
    /// Σ HPWL (mm).
    pub total_hpwl_mm: f64,
    /// Σ wire capacitance (fF).
    pub total_cap_ff: f64,
    /// The wire parameters used.
    pub params: WireParams,
}

impl WireModel {
    /// Per-net wire delay vector (ps), the STA input.
    pub fn net_delay_ps(&self) -> Vec<f64> {
        self.nets.iter().map(|n| n.delay_ps).collect()
    }
}

/// Extract the wire model from a placement.
pub fn extract(pl: &Placement, params: &WireParams) -> WireModel {
    let mut nets = Vec::with_capacity(pl.net_pins.len());
    let mut total_hpwl = 0.0f64;
    let mut total_cap = 0.0f64;
    for p in &pl.net_pins {
        let Some((x0, x1, y0, y1)) = net_bbox(p, &pl.x_um, &pl.y_um)
        else {
            nets.push(NetWire::default());
            continue;
        };
        let hpwl_mm = ((x1 - x0) + (y1 - y0)) * 1e-3;
        let cap_ff = hpwl_mm * params.cap_ff_per_mm;
        let res_ohm = hpwl_mm * params.res_ohm_per_mm;
        let energy_fj = hpwl_mm * params.energy_fj_per_mm;
        let delay_ps = hpwl_mm * params.delay_ps_per_mm
            + 0.345 * res_ohm * cap_ff * 1e-3;
        total_hpwl += hpwl_mm;
        total_cap += cap_ff;
        nets.push(NetWire {
            hpwl_mm,
            cap_ff,
            res_ohm,
            energy_fj,
            delay_ps,
        });
    }
    WireModel {
        nets,
        total_hpwl_mm: total_hpwl,
        total_cap_ff: total_cap,
        params: *params,
    }
}

/// Routing-congestion estimate: a `g × g` grid over the die where
/// each bin counts the net bounding boxes overlapping it (row-major,
/// bottom-left first).  The histogram the `place` stage dumps.
pub fn congestion_map(pl: &Placement, g: usize) -> Vec<u64> {
    let g = g.max(1);
    let mut bins = vec![0u64; g * g];
    let (dw, dh) =
        (pl.floorplan.die_w_um, pl.floorplan.die_h_um);
    if dw <= 0.0 || dh <= 0.0 {
        return bins;
    }
    let clamp = |v: f64, n: usize| -> usize {
        (v.max(0.0) as usize).min(n - 1)
    };
    for p in &pl.net_pins {
        let Some((x0, x1, y0, y1)) = net_bbox(p, &pl.x_um, &pl.y_um)
        else {
            continue;
        };
        let bx0 = clamp(x0 / dw * g as f64, g);
        let bx1 = clamp(x1 / dw * g as f64, g);
        let by0 = clamp(y0 / dh * g as f64, g);
        let by1 = clamp(y1 / dh * g as f64, g);
        for by in by0..=by1 {
            for bx in bx0..=bx1 {
                bins[by * g + bx] += 1;
            }
        }
    }
    bins
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::{Library, TechParams};
    use crate::netlist::column::{build_column, ColumnSpec};
    use crate::netlist::Flavor;
    use crate::phys::floorplan::FloorplanSpec;
    use crate::phys::place::{place, PlacerConfig};

    fn placed() -> Placement {
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let spec = ColumnSpec { p: 6, q: 3, theta: 9 };
        let (nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let fspec = FloorplanSpec::new(
            0.7,
            1.0,
            &crate::tech::WireParams::asap7(),
        );
        place(&nl, &lib, &tech, &fspec, &PlacerConfig::default())
            .unwrap()
    }

    #[test]
    fn extraction_scales_with_wire_params() {
        let pl = placed();
        let w7 = extract(&pl, &crate::tech::WireParams::asap7());
        assert!(w7.total_hpwl_mm > 0.0);
        assert!(w7.total_cap_ff > 0.0);
        assert!(
            (w7.total_cap_ff
                - w7.total_hpwl_mm * w7.params.cap_ff_per_mm)
                .abs()
                < 1e-9
        );
        // Same placement, 45nm wire stack: same HPWL, different RC.
        let w45 = extract(&pl, &crate::tech::WireParams::n45());
        assert!(
            (w45.total_hpwl_mm - w7.total_hpwl_mm).abs() < 1e-12
        );
        assert!(w45.total_cap_ff > w7.total_cap_ff);
        // Per-net delays are finite and non-negative.
        for n in &w7.nets {
            assert!(n.delay_ps >= 0.0 && n.delay_ps.is_finite());
        }
    }

    #[test]
    fn two_terminal_net_is_exact() {
        let pl = placed();
        let w = extract(&pl, &crate::tech::WireParams::asap7());
        let net = pl
            .net_pins
            .iter()
            .position(|p| p.len() == 2)
            .expect("a 2-terminal net exists");
        let (a, b) = (
            pl.net_pins[net][0] as usize,
            pl.net_pins[net][1] as usize,
        );
        let manual = ((pl.x_um[a] - pl.x_um[b]).abs()
            + (pl.y_um[a] - pl.y_um[b]).abs())
            * 1e-3;
        assert!((w.nets[net].hpwl_mm - manual).abs() < 1e-12);
    }

    #[test]
    fn congestion_counts_every_multi_terminal_net() {
        let pl = placed();
        let routed = pl
            .net_pins
            .iter()
            .filter(|p| p.len() >= 2)
            .count() as u64;
        // On a 1x1 grid every routed net lands in the single bin.
        let one = congestion_map(&pl, 1);
        assert_eq!(one, vec![routed]);
        // Finer grid: total count only grows (bbox spans bins).
        let g8 = congestion_map(&pl, 8);
        assert_eq!(g8.len(), 64);
        assert!(g8.iter().sum::<u64>() >= routed);
        assert!(g8.iter().any(|&c| c > 0));
    }
}
