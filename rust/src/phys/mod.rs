//! Physical design: floorplanning, row placement, and the wire model
//! behind wire-aware PPA.
//!
//! The paper's headline numbers (1.56 mm² / 1.69 mW for the Fig. 19
//! prototype) are *post-layout* results, and its follow-ups treat
//! place-and-route as a first-class stage (TNN7's placed-and-routed
//! macro comparisons, arXiv 2205.07410; the TNN design framework's PnR
//! stage, arXiv 2205.14248).  This module closes the same gap for the
//! reproduction: instead of a pure census sum of cell areas with zero
//! wire contribution, a design can be floorplanned, placed, and
//! charged for its wires:
//!
//! * [`floorplan`] — die outline from target utilization + aspect
//!   ratio, standard-cell rows at the backend's row height, macro
//!   keep-out regions splitting rows into usable spans.
//! * [`place`] — deterministic seeded placement: cluster-seeded
//!   initial placement by netlist hierarchy, greedy width-matched swap
//!   refinement minimizing half-perimeter wirelength, legal by
//!   construction with a from-scratch
//!   [`place::Placement::validate`] invariant check.
//! * [`wire`] — per-net HPWL → wire capacitance / resistance /
//!   Elmore-style delay through the backend's per-node
//!   [`crate::tech::WireParams`] (asap7 vs n45-projected see
//!   different wire RC), plus a grid congestion estimate.
//! * [`ppa_hooks`] — the corrections fed back into [`crate::ppa`]:
//!   placed die area into the area report, wire switching power
//!   (activity × wire energy) into the power split, and wire-delay
//!   STA into the timing report.
//!
//! The flow exposes all of this as the optional `place` stage between
//! `sta` and `simulate` (`tnn7 flow --place --util 0.7 --aspect 1.0`),
//! with a per-stage JSON dump carrying die dimensions, total HPWL, and
//! the congestion histogram.  DESIGN.md §10 documents the model and
//! what is (and is not) calibrated against the paper's numbers.

pub mod floorplan;
pub mod place;
pub mod ppa_hooks;
pub mod wire;

pub use floorplan::{Floorplan, FloorplanSpec, Rect};
pub use place::{Placement, PlacerConfig};
pub use wire::{congestion_map, NetWire, WireModel};
