//! Floorplanning: die outline, standard-cell rows, and keep-out
//! regions.
//!
//! A [`Floorplan`] is derived from the total placeable cell area, a
//! target utilization, and an aspect ratio: `core = cell_area / util`,
//! `w = sqrt(core × aspect)`, `h = sqrt(core / aspect)`, with the
//! height quantized up to a whole number of standard-cell rows of the
//! backend's row height ([`crate::tech::WireParams::row_height_um`]).
//! Macro keep-out regions ([`Rect`]) subtract usable span from the
//! rows they overlap, splitting each affected row into placement
//! [`Span`] segments — the slots the legalizer in
//! [`super::place`] packs cells into.

use crate::error::{Error, Result};

/// Floorplan construction parameters.
#[derive(Debug, Clone, Copy)]
pub struct FloorplanSpec {
    /// Target placement utilization (cell area / core area), in (0, 1].
    pub utilization: f64,
    /// Die aspect ratio width/height, > 0.
    pub aspect: f64,
    /// Standard-cell row height (µm).
    pub row_height_um: f64,
}

impl FloorplanSpec {
    /// Spec from a technology's wire/row parameters at the given
    /// utilization and aspect targets.
    pub fn new(
        utilization: f64,
        aspect: f64,
        wire: &crate::tech::WireParams,
    ) -> FloorplanSpec {
        FloorplanSpec {
            utilization,
            aspect,
            row_height_um: wire.row_height_um,
        }
    }

    fn validate(&self) -> Result<()> {
        if !(self.utilization > 0.0 && self.utilization <= 1.0) {
            return Err(Error::ppa(format!(
                "floorplan utilization must be in (0, 1], got {}",
                self.utilization
            )));
        }
        if !(self.aspect > 0.0 && self.aspect.is_finite()) {
            return Err(Error::ppa(format!(
                "floorplan aspect ratio must be positive, got {}",
                self.aspect
            )));
        }
        if !(self.row_height_um > 0.0) {
            return Err(Error::ppa(format!(
                "row height must be positive, got {}",
                self.row_height_um
            )));
        }
        Ok(())
    }
}

/// An axis-aligned keep-out rectangle (µm), e.g. a hard-macro
/// footprint or a reserved clock spine.
#[derive(Debug, Clone, Copy)]
pub struct Rect {
    pub x0_um: f64,
    pub y0_um: f64,
    pub x1_um: f64,
    pub y1_um: f64,
}

/// A usable horizontal span of one row, `[x0, x1)`.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub x0_um: f64,
    pub x1_um: f64,
}

impl Span {
    /// Usable width (µm).
    pub fn width_um(&self) -> f64 {
        self.x1_um - self.x0_um
    }
}

/// One standard-cell row: a y position plus its usable spans (full die
/// width minus any keep-out overlaps).
#[derive(Debug, Clone)]
pub struct Row {
    /// Bottom edge of the row (µm).
    pub y_um: f64,
    /// Usable placement spans, left to right, non-overlapping.
    pub spans: Vec<Span>,
}

impl Row {
    /// Vertical center of the row (cell centers sit here).
    pub fn center_y(&self, row_height_um: f64) -> f64 {
        self.y_um + row_height_um / 2.0
    }

    /// Total usable width (µm).
    pub fn usable_um(&self) -> f64 {
        self.spans.iter().map(Span::width_um).sum()
    }
}

/// Die outline + row grid + keep-outs.
#[derive(Debug, Clone)]
pub struct Floorplan {
    /// Die width (µm).
    pub die_w_um: f64,
    /// Die height (µm) — always `rows.len() × row_height_um`.
    pub die_h_um: f64,
    /// Row height (µm).
    pub row_height_um: f64,
    /// Target utilization the outline was sized for.
    pub utilization: f64,
    /// Aspect ratio the outline was sized for.
    pub aspect: f64,
    /// Standard-cell rows, bottom to top.
    pub rows: Vec<Row>,
    /// Keep-out regions already subtracted from the rows.
    pub keepouts: Vec<Rect>,
}

impl Floorplan {
    /// Floorplan for `cell_um2` of placeable cell area.  `max_cell_w_um`
    /// widens the die if a single cell would not fit a row (degenerate
    /// tiny-netlist case).
    pub fn for_area(
        cell_um2: f64,
        max_cell_w_um: f64,
        spec: &FloorplanSpec,
    ) -> Result<Floorplan> {
        spec.validate()?;
        if !(cell_um2 > 0.0) {
            return Err(Error::ppa(
                "floorplan needs positive placeable cell area",
            ));
        }
        let core_um2 = cell_um2 / spec.utilization;
        let mut die_w = (core_um2 * spec.aspect).sqrt();
        if die_w < max_cell_w_um {
            die_w = max_cell_w_um;
        }
        let ideal_h = core_um2 / die_w;
        let n_rows = (ideal_h / spec.row_height_um).ceil().max(1.0) as usize;
        let rows = (0..n_rows)
            .map(|r| Row {
                y_um: r as f64 * spec.row_height_um,
                spans: vec![Span { x0_um: 0.0, x1_um: die_w }],
            })
            .collect::<Vec<_>>();
        Ok(Floorplan {
            die_w_um: die_w,
            die_h_um: n_rows as f64 * spec.row_height_um,
            row_height_um: spec.row_height_um,
            utilization: spec.utilization,
            aspect: spec.aspect,
            rows,
            keepouts: Vec::new(),
        })
    }

    /// Subtract a keep-out rectangle from every row it overlaps,
    /// splitting their usable spans.  Slivers narrower than 1% of a row
    /// height are dropped (unplaceable).
    pub fn add_keepout(&mut self, rect: Rect) {
        let min_sliver = self.row_height_um * 0.01;
        for row in &mut self.rows {
            let ry0 = row.y_um;
            let ry1 = row.y_um + self.row_height_um;
            if rect.y1_um <= ry0 || rect.y0_um >= ry1 {
                continue;
            }
            let mut next = Vec::with_capacity(row.spans.len() + 1);
            for s in &row.spans {
                if rect.x1_um <= s.x0_um || rect.x0_um >= s.x1_um {
                    next.push(*s);
                    continue;
                }
                let left = Span { x0_um: s.x0_um, x1_um: rect.x0_um };
                let right = Span { x0_um: rect.x1_um, x1_um: s.x1_um };
                if left.width_um() > min_sliver {
                    next.push(left);
                }
                if right.width_um() > min_sliver {
                    next.push(right);
                }
            }
            row.spans = next;
        }
        self.keepouts.push(rect);
    }

    /// Append a fresh full-width row on top (legalizer overflow path:
    /// row quantization can leave slightly less capacity than the cell
    /// list needs).  Grows the die height.
    pub fn push_overflow_row(&mut self) {
        let y = self.rows.len() as f64 * self.row_height_um;
        self.rows.push(Row {
            y_um: y,
            spans: vec![Span { x0_um: 0.0, x1_um: self.die_w_um }],
        });
        self.die_h_um = self.rows.len() as f64 * self.row_height_um;
    }

    /// Die area (mm²).
    pub fn die_mm2(&self) -> f64 {
        self.die_w_um * self.die_h_um * 1e-6
    }

    /// Total usable placement capacity (µm²) across all rows.
    pub fn capacity_um2(&self) -> f64 {
        self.rows
            .iter()
            .map(|r| r.usable_um() * self.row_height_um)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::WireParams;

    fn spec() -> FloorplanSpec {
        FloorplanSpec::new(0.7, 1.0, &WireParams::asap7())
    }

    #[test]
    fn outline_matches_utilization_and_aspect() {
        let fp = Floorplan::for_area(700.0, 1.0, &spec()).unwrap();
        // core = 1000 µm²; square-ish die, height row-quantized up.
        assert!(fp.die_w_um >= 31.0 && fp.die_w_um <= 33.0);
        assert!(fp.die_h_um >= fp.die_w_um - fp.row_height_um);
        assert!((fp.die_h_um / fp.row_height_um).fract().abs() < 1e-9);
        // Capacity covers the cell area with the utilization margin.
        assert!(fp.capacity_um2() >= 700.0);
        // Wide aspect: w/h ≈ 4 (up to row quantization).
        let wide = Floorplan::for_area(
            700.0,
            1.0,
            &FloorplanSpec { aspect: 4.0, ..spec() },
        )
        .unwrap();
        let ratio = wide.die_w_um / wide.die_h_um;
        assert!(ratio > 2.5 && ratio < 5.0, "ratio {ratio}");
    }

    #[test]
    fn die_width_never_below_widest_cell() {
        let fp = Floorplan::for_area(10.0, 50.0, &spec()).unwrap();
        assert!(fp.die_w_um >= 50.0);
    }

    #[test]
    fn keepout_splits_row_spans() {
        let mut fp = Floorplan::for_area(700.0, 1.0, &spec()).unwrap();
        let before = fp.capacity_um2();
        let rect = Rect {
            x0_um: 10.0,
            y0_um: 0.0,
            x1_um: 20.0,
            y1_um: fp.row_height_um * 2.5,
        };
        fp.add_keepout(rect);
        // First three rows lose a 10 µm span; rows above are intact.
        for (r, row) in fp.rows.iter().enumerate() {
            if r < 3 {
                assert_eq!(row.spans.len(), 2, "row {r}");
                assert!(
                    (row.usable_um() - (fp.die_w_um - 10.0)).abs() < 1e-9
                );
            } else {
                assert_eq!(row.spans.len(), 1, "row {r}");
            }
        }
        assert!(fp.capacity_um2() < before);
        assert_eq!(fp.keepouts.len(), 1);
    }

    #[test]
    fn overflow_row_grows_die() {
        let mut fp = Floorplan::for_area(700.0, 1.0, &spec()).unwrap();
        let rows = fp.rows.len();
        let h = fp.die_h_um;
        fp.push_overflow_row();
        assert_eq!(fp.rows.len(), rows + 1);
        assert!((fp.die_h_um - (h + fp.row_height_um)).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_specs() {
        let w = WireParams::asap7();
        assert!(Floorplan::for_area(
            100.0,
            1.0,
            &FloorplanSpec::new(0.0, 1.0, &w)
        )
        .is_err());
        assert!(Floorplan::for_area(
            100.0,
            1.0,
            &FloorplanSpec::new(1.5, 1.0, &w)
        )
        .is_err());
        assert!(Floorplan::for_area(
            100.0,
            1.0,
            &FloorplanSpec::new(0.7, 0.0, &w)
        )
        .is_err());
        assert!(Floorplan::for_area(
            0.0,
            1.0,
            &FloorplanSpec::new(0.7, 1.0, &w)
        )
        .is_err());
    }
}
