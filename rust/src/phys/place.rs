//! Deterministic seeded row placement.
//!
//! Three phases, all reproducible from one seed:
//!
//! 1. **Cluster-seeded initial placement** — instances are ordered by
//!    their netlist hierarchy cluster (the top-level region each
//!    instance is tagged with: `sg3`, `syn7_2`, `wta`, …) and packed
//!    into the floorplan's row spans in serpentine order, so cells of
//!    one module land next to each other, exactly like a
//!    hierarchy-guided initial placement.
//! 2. **Greedy HPWL refinement** — seeded random width-matched cell
//!    swaps, accepted only when they reduce total half-perimeter
//!    wirelength; the per-pass HPWL trace is recorded and is
//!    non-increasing by construction.
//! 3. **Legalization by construction** — cells only ever occupy row
//!    spans (keep-outs excluded) with no overlap; width-matched swaps
//!    preserve legality, and [`Placement::validate`] re-checks the
//!    invariants from scratch.

use crate::cells::{Library, TechParams};
use crate::data::digits::XorShift;
use crate::error::{Error, Result};
use crate::netlist::ir::RegionId;
use crate::netlist::Netlist;

use super::floorplan::{Floorplan, FloorplanSpec};

/// Nets with more pins than this are kept out of swap-delta
/// evaluation (their bbox is effectively placement-invariant and
/// re-scanning them per candidate swap is the placer's only
/// super-linear cost).
const MAX_SWAP_NET_PINS: usize = 256;

/// Placement engine parameters (all defaulted; the flow only exposes
/// the seed).
#[derive(Debug, Clone, Copy)]
pub struct PlacerConfig {
    /// RNG seed — same seed ⇒ bit-identical placement and HPWL.
    pub seed: u64,
    /// Refinement passes over the design.
    pub passes: usize,
    /// Swap attempts per cell per pass.
    pub swaps_per_cell: usize,
    /// Hard cap on swap attempts per pass (keeps huge netlists
    /// CI-friendly).
    pub max_swaps_per_pass: usize,
}

impl Default for PlacerConfig {
    fn default() -> Self {
        PlacerConfig {
            seed: 1,
            passes: 2,
            swaps_per_cell: 8,
            max_swaps_per_pass: 200_000,
        }
    }
}

/// A legalized row placement of one netlist.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Cell-center x per instance (µm).
    pub x_um: Vec<f64>,
    /// Cell-center y per instance (µm) — always its row's center.
    pub y_um: Vec<f64>,
    /// Placement width per instance (µm) = cell area / row height.
    pub width_um: Vec<f64>,
    /// Row index per instance.
    pub row_of: Vec<u32>,
    /// The floorplan placed into (possibly grown by overflow rows).
    pub floorplan: Floorplan,
    /// Per-net instance terminals ([`net_instances`]), computed once
    /// here and reused by the wire model and congestion map.
    pub net_pins: Vec<Vec<u32>>,
    /// Total half-perimeter wirelength (µm), const nets excluded.
    pub hpwl_um: f64,
    /// HPWL trace: initial placement, then after each refinement
    /// pass.  Non-increasing (greedy acceptance).
    pub pass_hpwl_um: Vec<f64>,
}

/// Per-net instance terminals (deduped, ascending), with the tie-cell
/// constant nets mapped to empty pin lists: const0/const1 are locally
/// replicated in real layouts, so routing one giant constant net would
/// be pure model noise.  Shared with [`super::wire`].
pub fn net_instances(nl: &Netlist) -> Vec<Vec<u32>> {
    let mut pins: Vec<Vec<u32>> = vec![Vec::new(); nl.n_nets()];
    for i in 0..nl.insts.len() {
        for &n in nl.inst_ins(i).iter().chain(nl.inst_outs(i)) {
            pins[n.0 as usize].push(i as u32);
        }
    }
    for (n, list) in pins.iter_mut().enumerate() {
        if n == nl.const0.0 as usize || n == nl.const1.0 as usize {
            list.clear();
            continue;
        }
        list.sort_unstable();
        list.dedup();
    }
    pins
}

/// The hierarchy cluster of a region: the ancestor directly below the
/// root (or the root itself for top-level instances).
fn top_cluster(nl: &Netlist, mut r: RegionId) -> u32 {
    loop {
        let reg = &nl.regions[r.0 as usize];
        match reg.parent {
            None => return r.0,
            Some(p) if nl.regions[p.0 as usize].parent.is_none() => {
                return r.0
            }
            Some(p) => r = p,
        }
    }
}

/// Bounding box `(x0, x1, y0, y1)` of a net's instance terminals;
/// `None` for nets with < 2 terminals (nothing to route).
pub fn net_bbox(
    pins: &[u32],
    x: &[f64],
    y: &[f64],
) -> Option<(f64, f64, f64, f64)> {
    if pins.len() < 2 {
        return None;
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &i in pins {
        let (px, py) = (x[i as usize], y[i as usize]);
        x0 = x0.min(px);
        x1 = x1.max(px);
        y0 = y0.min(py);
        y1 = y1.max(py);
    }
    Some((x0, x1, y0, y1))
}

/// HPWL of one net over instance centers; nets with < 2 terminals
/// contribute nothing.
fn net_hpwl(pins: &[u32], x: &[f64], y: &[f64]) -> f64 {
    match net_bbox(pins, x, y) {
        Some((x0, x1, y0, y1)) => (x1 - x0) + (y1 - y0),
        None => 0.0,
    }
}

/// Place `nl` into a floorplan derived from the spec (row count from
/// the netlist's own cell area).  The one-call form the flow uses.
pub fn place(
    nl: &Netlist,
    lib: &Library,
    tech: &TechParams,
    spec: &FloorplanSpec,
    cfg: &PlacerConfig,
) -> Result<Placement> {
    let widths: Vec<f64> = nl
        .insts
        .iter()
        .map(|i| tech.area_um2(lib.cell(i.cell)) / spec.row_height_um)
        .collect();
    let cell_um2: f64 =
        widths.iter().map(|w| w * spec.row_height_um).sum();
    let max_w = widths.iter().cloned().fold(0.0f64, f64::max);
    let fp = Floorplan::for_area(cell_um2, max_w, spec)?;
    place_into(nl, lib, tech, fp, cfg)
}

/// Place `nl` into an explicit floorplan (keep-outs already applied).
pub fn place_into(
    nl: &Netlist,
    lib: &Library,
    tech: &TechParams,
    mut fp: Floorplan,
    cfg: &PlacerConfig,
) -> Result<Placement> {
    let n = nl.insts.len();
    if n == 0 {
        return Err(Error::ppa("cannot place an empty netlist"));
    }
    let widths: Vec<f64> = nl
        .insts
        .iter()
        .map(|i| tech.area_um2(lib.cell(i.cell)) / fp.row_height_um)
        .collect();
    // A cell wider than the die can never legalize — the overflow-row
    // path would append full-width rows forever.  ([`place`] sizes the
    // die around the widest cell; explicit floorplans must too.)
    let max_w = widths.iter().cloned().fold(0.0f64, f64::max);
    if max_w > fp.die_w_um + 1e-9 {
        return Err(Error::ppa(format!(
            "floorplan die width {:.3} µm is narrower than the widest \
             cell ({max_w:.3} µm) — widen the die or lower the row \
             height",
            fp.die_w_um
        )));
    }

    // Phase 1: cluster order, then serpentine row packing.
    let mut order: Vec<u32> = (0..n as u32).collect();
    let clusters: Vec<u32> = nl
        .insts
        .iter()
        .map(|i| top_cluster(nl, i.region))
        .collect();
    order.sort_by_key(|&i| (clusters[i as usize], i));

    let mut x_um = vec![0.0f64; n];
    let mut y_um = vec![0.0f64; n];
    let mut row_of = vec![0u32; n];
    let mut it = order.iter().copied().peekable();
    let mut row = 0usize;
    'rows: loop {
        if it.peek().is_none() {
            break;
        }
        if row >= fp.rows.len() {
            fp.push_overflow_row();
        }
        let rev = row % 2 == 1;
        let y = fp.rows[row].center_y(fp.row_height_um);
        let mut spans = fp.rows[row].spans.clone();
        if rev {
            spans.reverse();
        }
        for span in &spans {
            // Soft fill target spreads whitespace; the hard bound is
            // the span itself.
            let target = span.width_um() * fp.utilization;
            let mut used = 0.0f64;
            while let Some(&i) = it.peek() {
                let w = widths[i as usize];
                if used + w > span.width_um() + 1e-9 {
                    break; // cell does not fit this span at all
                }
                it.next();
                let x = if rev {
                    span.x1_um - used - w / 2.0
                } else {
                    span.x0_um + used + w / 2.0
                };
                x_um[i as usize] = x;
                y_um[i as usize] = y;
                row_of[i as usize] = row as u32;
                used += w;
                if used >= target {
                    break;
                }
            }
            if it.peek().is_none() {
                break 'rows;
            }
        }
        row += 1;
    }

    // Phase 2: greedy width-matched swap refinement.
    let pins = net_instances(nl);
    let mut inst_nets: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (net, list) in pins.iter().enumerate() {
        for &i in list {
            inst_nets[i as usize].push(net as u32);
        }
    }
    for nets in &mut inst_nets {
        nets.dedup(); // pins per net are ascending ⇒ already grouped
    }
    let mut total: f64 =
        pins.iter().map(|p| net_hpwl(p, &x_um, &y_um)).sum();
    let mut pass_hpwl = vec![total];
    let mut rng = XorShift::new(cfg.seed);
    let attempts =
        (n * cfg.swaps_per_cell).min(cfg.max_swaps_per_pass);
    for _pass in 0..cfg.passes {
        for _ in 0..attempts {
            let a = (rng.next_u64() as usize) % n;
            let mut b = a;
            for _ in 0..8 {
                let cand = (rng.next_u64() as usize) % n;
                if cand != a
                    && widths[cand].to_bits() == widths[a].to_bits()
                {
                    b = cand;
                    break;
                }
            }
            if b == a {
                continue;
            }
            let swap_ok = |i: usize| {
                inst_nets[i].iter().all(|&net| {
                    pins[net as usize].len() <= MAX_SWAP_NET_PINS
                })
            };
            if !swap_ok(a) || !swap_ok(b) {
                continue;
            }
            // Delta over the union of incident nets (exact: no other
            // net moves).
            let mut delta = 0.0f64;
            for &net in &inst_nets[a] {
                delta -= net_hpwl(&pins[net as usize], &x_um, &y_um);
            }
            for &net in &inst_nets[b] {
                if !inst_nets[a].contains(&net) {
                    delta -=
                        net_hpwl(&pins[net as usize], &x_um, &y_um);
                }
            }
            x_um.swap(a, b);
            y_um.swap(a, b);
            for &net in &inst_nets[a] {
                delta += net_hpwl(&pins[net as usize], &x_um, &y_um);
            }
            for &net in &inst_nets[b] {
                if !inst_nets[a].contains(&net) {
                    delta +=
                        net_hpwl(&pins[net as usize], &x_um, &y_um);
                }
            }
            if delta < -1e-12 {
                row_of.swap(a, b);
                total += delta;
            } else {
                // Reject: restore.
                x_um.swap(a, b);
                y_um.swap(a, b);
            }
        }
        pass_hpwl.push(total);
    }

    let placement = Placement {
        x_um,
        y_um,
        width_um: widths,
        row_of,
        floorplan: fp,
        net_pins: pins,
        hpwl_um: total,
        pass_hpwl_um: pass_hpwl,
    };
    placement.validate()?;
    Ok(placement)
}

impl Placement {
    /// Placed die area (mm²).
    pub fn die_mm2(&self) -> f64 {
        self.floorplan.die_mm2()
    }

    /// Check the legalization invariants from scratch: every cell is
    /// row-aligned (its y is its row's center), lies fully inside one
    /// usable span of that row (in-bounds, outside keep-outs), and no
    /// two cells of a row overlap.
    pub fn validate(&self) -> Result<()> {
        const EPS: f64 = 1e-6;
        let fp = &self.floorplan;
        let n = self.x_um.len();
        let mut by_row: Vec<Vec<u32>> = vec![Vec::new(); fp.rows.len()];
        for i in 0..n {
            let r = self.row_of[i] as usize;
            let row = fp.rows.get(r).ok_or_else(|| {
                Error::ppa(format!(
                    "placement: inst {i} on nonexistent row {r}"
                ))
            })?;
            if (self.y_um[i] - row.center_y(fp.row_height_um)).abs()
                > EPS
            {
                return Err(Error::ppa(format!(
                    "placement: inst {i} not row-aligned (y {} vs row \
                     center {})",
                    self.y_um[i],
                    row.center_y(fp.row_height_um)
                )));
            }
            let (lo, hi) = (
                self.x_um[i] - self.width_um[i] / 2.0,
                self.x_um[i] + self.width_um[i] / 2.0,
            );
            let inside = row.spans.iter().any(|s| {
                lo >= s.x0_um - EPS && hi <= s.x1_um + EPS
            });
            if !inside {
                return Err(Error::ppa(format!(
                    "placement: inst {i} [{lo}, {hi}] outside every \
                     span of row {r}"
                )));
            }
            by_row[r].push(i as u32);
        }
        for (r, insts) in by_row.iter_mut().enumerate() {
            insts.sort_by(|&a, &b| {
                self.x_um[a as usize]
                    .partial_cmp(&self.x_um[b as usize])
                    .expect("finite placement coordinates")
            });
            for w in insts.windows(2) {
                let (a, b) = (w[0] as usize, w[1] as usize);
                let a_hi = self.x_um[a] + self.width_um[a] / 2.0;
                let b_lo = self.x_um[b] - self.width_um[b] / 2.0;
                if a_hi > b_lo + EPS {
                    return Err(Error::ppa(format!(
                        "placement: insts {a} and {b} overlap on row \
                         {r} ({a_hi} > {b_lo})"
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::column::{build_column, ColumnSpec};
    use crate::netlist::Flavor;
    use crate::ppa::UTILIZATION;
    use crate::tech::WireParams;

    fn place_column(
        p: usize,
        q: usize,
        flavor: Flavor,
        seed: u64,
    ) -> Placement {
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let spec = ColumnSpec { p, q, theta: (p + q) as u64 };
        let (nl, _) = build_column(&lib, flavor, &spec).unwrap();
        let fspec = FloorplanSpec::new(
            UTILIZATION,
            1.0,
            &WireParams::asap7(),
        );
        place(
            &nl,
            &lib,
            &tech,
            &fspec,
            &PlacerConfig { seed, ..PlacerConfig::default() },
        )
        .unwrap()
    }

    #[test]
    fn placement_is_legal_and_covers_every_cell() {
        let pl = place_column(8, 4, Flavor::Custom, 7);
        pl.validate().unwrap();
        assert!(pl.hpwl_um > 0.0);
        // Placed cell area over die area lands near the target
        // utilization (row quantization costs a little).
        let cell_um2: f64 = pl
            .width_um
            .iter()
            .map(|w| w * pl.floorplan.row_height_um)
            .sum();
        let ratio = cell_um2 / (pl.die_mm2() * 1e6);
        assert!(
            ratio > 0.4 && ratio <= UTILIZATION + 1e-9,
            "placed utilization {ratio}"
        );
    }

    #[test]
    fn refinement_never_increases_hpwl() {
        let pl = place_column(8, 4, Flavor::Std, 3);
        assert_eq!(
            pl.pass_hpwl_um.len(),
            PlacerConfig::default().passes + 1
        );
        for w in pl.pass_hpwl_um.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "HPWL increased: {w:?}");
        }
        assert!(
            (pl.hpwl_um - *pl.pass_hpwl_um.last().unwrap()).abs()
                < 1e-9
        );
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let a = place_column(6, 3, Flavor::Custom, 42);
        let b = place_column(6, 3, Flavor::Custom, 42);
        assert_eq!(a.x_um, b.x_um);
        assert_eq!(a.y_um, b.y_um);
        assert_eq!(a.row_of, b.row_of);
        assert_eq!(a.hpwl_um.to_bits(), b.hpwl_um.to_bits());
    }

    #[test]
    fn keepout_floorplan_stays_legal() {
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let spec = ColumnSpec { p: 6, q: 3, theta: 9 };
        let (nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let fspec =
            FloorplanSpec::new(0.6, 1.0, &WireParams::asap7());
        let widths: f64 = nl
            .insts
            .iter()
            .map(|i| tech.area_um2(lib.cell(i.cell)))
            .sum();
        let mut fp =
            Floorplan::for_area(widths, 1.0, &fspec).unwrap();
        // Block out a central macro-sized rectangle.
        fp.add_keepout(super::super::floorplan::Rect {
            x0_um: fp.die_w_um * 0.3,
            y0_um: 0.0,
            x1_um: fp.die_w_um * 0.5,
            y1_um: fp.die_h_um * 0.5,
        });
        let pl = place_into(
            &nl,
            &lib,
            &tech,
            fp,
            &PlacerConfig::default(),
        )
        .unwrap();
        pl.validate().unwrap();
        // No cell center inside the keep-out.
        let ko = pl.floorplan.keepouts[0];
        for i in 0..pl.x_um.len() {
            let inside = pl.x_um[i] > ko.x0_um
                && pl.x_um[i] < ko.x1_um
                && pl.y_um[i] > ko.y0_um
                && pl.y_um[i] < ko.y1_um;
            assert!(!inside, "inst {i} inside keep-out");
        }
    }

    #[test]
    fn const_nets_are_excluded_from_wiring() {
        let lib = Library::with_macros();
        let spec = ColumnSpec { p: 4, q: 2, theta: 4 };
        let (nl, _) =
            build_column(&lib, Flavor::Custom, &spec).unwrap();
        let pins = net_instances(&nl);
        assert!(pins[nl.const0.0 as usize].is_empty());
        assert!(pins[nl.const1.0 as usize].is_empty());
        // Some real net has at least two terminals.
        assert!(pins.iter().any(|p| p.len() >= 2));
    }
}
