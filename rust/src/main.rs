//! `tnn7` — CLI for the 7nm TNN co-design framework.
//!
//! Subcommands map one-to-one onto the paper's artifacts (see DESIGN.md
//! §4 for the experiment index):
//!
//! ```text
//! tnn7 flow --target F[:N] --col PxQ|--proto [...]   run the staged design flow
//! tnn7 export --col PxQ|--proto --out DIR [...]      BLIF/Verilog/VCD export
//! tnn7 replay --vcd FILE --col PxQ [...]             re-simulate a recording
//! tnn7 faults --col PxQ|--proto [--smoke] [...]      fault-injection campaigns
//! tnn7 characterize [--lib FILE]      cell library table (+ .lib dump)
//! tnn7 layout-cmp [MACRO]             Figs. 14-18 structural comparisons
//! tnn7 complexity                     Fig. 19 gate/transistor census
//! tnn7 calibrate                      fit technology constants (DESIGN §5)
//! tnn7 bench-table1 [--with-45nm]     Table I (3 columns × 2 flavours)
//! tnn7 bench-table2                   Table II (prototype PPA + EDP)
//! tnn7 simulate --col PxQ [...]       gate-sim one column, report PPA
//! tnn7 train [--config FILE]          end-to-end HLO training + accuracy
//! tnn7 serve [--addr A] [...]         flow-as-a-service HTTP daemon
//! tnn7 profile [--col PxQ] [...]      traced flow run + hot-span table
//! ```
//!
//! Every measurement path goes through [`tnn7::flow`]; `simulate` and
//! the bench commands are thin presentations over the same pipeline
//! that `flow --pipeline ... --dump-dir ...` exposes stage by stage.

use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;

use tnn7::cells::{calibrate, liberty, Library, TechParams};
use tnn7::config::TnnConfig;
use tnn7::flow::cache::{CacheConfig, StageCache};
use tnn7::flow::{
    self, compare, parse_geometry, stages, table1_specs, Flow, FlowContext,
    Geometry, Stage, Target,
};
use tnn7::coordinator::Pipeline;
use tnn7::data::Dataset;
use tnn7::interop;
use tnn7::netlist::column::{build_column, ColumnSpec, BRV_PER_SYN};
use tnn7::netlist::prototype::PrototypeSpec;
use tnn7::netlist::Flavor;
use tnn7::ppa::report::{improvement_line, render_table1, render_table2, PpaRow};
use tnn7::ppa::scaling;
use tnn7::ppa::ColumnPpa;
use tnn7::runtime::json::Json;
use tnn7::serve::{ServeConfig, Server};
use tnn7::ir::PassManager;
use tnn7::sim::{
    CompiledSimulator, PackedSimulator, ShardedSimulator, Simulator,
};
use tnn7::tech::{self, TechContext, TechRegistry};
use tnn7::tnn::stdp::{RandPair, StdpParams};
use tnn7::tnn::INF;

/// Tiny argv helper (no clap offline): `--key value` and flags.
struct Args {
    rest: Vec<String>,
}

impl Args {
    fn new() -> Self {
        Args { rest: std::env::args().skip(1).collect() }
    }

    fn subcommand(&mut self) -> Option<String> {
        if self.rest.is_empty() || self.rest[0].starts_with('-') {
            None
        } else {
            Some(self.rest.remove(0))
        }
    }

    fn flag(&mut self, name: &str) -> bool {
        if let Some(i) = self.rest.iter().position(|a| a == name) {
            self.rest.remove(i);
            true
        } else {
            false
        }
    }

    /// `--key value` lookup.  A trailing `--key` without a value is a
    /// structured error (it used to `exit(2)` mid-parse).
    fn opt(&mut self, name: &str) -> anyhow::Result<Option<String>> {
        let i = match self.rest.iter().position(|a| a == name) {
            Some(i) => i,
            None => return Ok(None),
        };
        if i + 1 >= self.rest.len() {
            anyhow::bail!("{name} requires a value");
        }
        self.rest.remove(i);
        Ok(Some(self.rest.remove(i)))
    }

    fn positional(&mut self) -> Option<String> {
        self.subcommand()
    }

    /// `--help`/`-h` anywhere in a subcommand's arguments.
    fn help_requested(&mut self) -> bool {
        self.flag("--help") || self.flag("-h")
    }

    fn finish(&self) -> anyhow::Result<()> {
        if self.rest.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unrecognized arguments: {:?}", self.rest)
        }
    }
}

fn load_config(args: &mut Args) -> anyhow::Result<TnnConfig> {
    match args.opt("--config")? {
        Some(path) => Ok(TnnConfig::load(Path::new(&path))?),
        None => Ok(TnnConfig::default()),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::new();
    let sub = args.subcommand().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "flow" => cmd_flow(&mut args),
        "export" => cmd_export(&mut args),
        "replay" => cmd_replay(&mut args),
        "faults" => cmd_faults(&mut args),
        "characterize" => cmd_characterize(&mut args),
        "layout-cmp" => cmd_layout_cmp(&mut args),
        "complexity" => cmd_complexity(&mut args),
        "calibrate" => cmd_calibrate(&mut args),
        "bench-table1" => cmd_table1(&mut args),
        "bench-table2" => cmd_table2(&mut args),
        "simulate" => cmd_simulate(&mut args),
        "train" => cmd_train(&mut args),
        "serve" => cmd_serve(&mut args),
        "profile" => cmd_profile(&mut args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            println!("{}", pipeline_help());
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand `{other}` (try help)"),
    }
}

const HELP: &str = "tnn7 — 7nm TNN co-design framework (paper reproduction)

USAGE: tnn7 <SUBCOMMAND> [OPTIONS]     (tnn7 <SUBCOMMAND> --help for details)

SUBCOMMANDS:
  flow --target F (--col PxQ | --proto) [--tech T1,T2,..] [--pipeline S,..]
       [--place] [--util U1,U2,..] [--aspect A1,A2,..] [--export]
       [--dump-dir D] [--lanes N] [--threads N] [--smoke]
       [--engine auto|scalar|packed|compiled] [--passes P1,P2,..]
       [--trace FILE]
                              run the staged design flow on one or more
                              technology backends (names or .lib paths),
                              dump per-stage JSON; --targets A,B,.. sweeps
                              several flavours × technologies concurrently;
                              --place adds the physical-design stage
                              (floorplan, row placement, wire-aware PPA);
                              --export adds the interop export stage
  export --target F (--col PxQ | --proto) --out DIR [--vcd] [--lanes N]
         [--waves N] [--seed S]
                              lower the elaborated netlist to BLIF +
                              structural Verilog files (re-import checked
                              bit-identical); --vcd also records a seeded
                              packed wave run per unit (DESIGN.md §12)
  replay --vcd FILE --col PxQ [--target F]
         [--engine scalar|packed|sharded|compiled|compiled-sharded]
         [--threads N] [--out FILE]
                              re-ingest a recorded VCD as stimulus, re-run
                              it on any engine, and assert toggle counts
                              (byte-identical recording on a match)
  faults --target F (--col PxQ | --proto) [--tech T] [--smoke]
         [--classes C1,..] [--rates R1,..] [--seeds S1,..] [--waves N]
         [--lanes N] [--threads N] [--dump-dir D] [--cache-dir D]
         [--out FILE]
                              seeded fault-injection campaigns: sweep
                              class x rate x seed, report accuracy /
                              toggle / power degradation vs the
                              fault-free baseline (DESIGN.md \u{a7}13);
                              --out writes BENCH_faults.json
  characterize [--lib FILE]   print the characterized cell library
  layout-cmp [MACRO] [--json FILE]   Figs. 14-18 custom-vs-std comparisons
  complexity                  Fig. 19 prototype census (gates/transistors)
  calibrate                   fit the technology constants (DESIGN.md §5)
  bench-table1 [--with-45nm] [--waves N] [--threads N]   regenerate Table I
  bench-table2 [--waves N] [--threads N]                 regenerate Table II
  simulate --col PxQ [--flavor std|custom] [--waves N]
  train [--config FILE] [--samples N] [--check] [--metrics-json FILE]
  serve [--addr HOST:PORT] [--threads N] [--queue N] [--cache-dir D]
        [--mem-entries N]   flow-as-a-service daemon with a
                            content-addressed stage cache (DESIGN.md §11);
                            exposes GET /metrics (Prometheus text)
  profile [--col PxQ | --proto] [--target F] [--top N] [--trace FILE]
                            run the measurement pipeline with span
                            tracing on and print the hot-span
                            self/total-time table (DESIGN.md §15)
";

/// Generated from the stage registry, so help never drifts from the
/// implemented pipeline.
fn pipeline_help() -> String {
    let mut s = String::from("FLOW STAGES (for --pipeline):\n");
    for stage in stages::all() {
        s.push_str(&format!(
            "  {:<10} {}\n",
            stage.name(),
            stage.description()
        ));
    }
    s.push_str(
        "  aliases: sim = simulate, ppa = power,area,report\n",
    );
    s
}

fn help_flow() -> String {
    format!(
        "tnn7 flow — run the staged design flow on one or more targets

USAGE: tnn7 flow [OPTIONS]

OPTIONS:
  --target FLAVOR[:TECH]   flavour std|baseline or custom|gdi, optionally
                           pinned to a technology backend (legacy node
                           forms 7nm/45nm canonicalize to backends)
  --targets A,B,..         comma list of FLAVOR[:TECH] descriptors: run the
                           measurement pipeline for every flavour × --tech
                           combination concurrently (parallel sweep;
                           excludes --target/--pipeline/--dump-dir)
  --tech T1,T2,..          technology backends to measure on: registered
                           names (asap7-baseline, asap7-tnn7, n45-projected)
                           or .lib file paths loaded as liberty-file
                           backends (default: asap7-tnn7); with --target,
                           runs the full pipeline once per backend
  --col PxQ                single-column geometry (e.g. 32x12)
  --proto                  the Fig. 19 2-layer prototype instead of --col
  --place                  insert the physical-design stage between sta and
                           simulate: floorplan + seeded row placement + wire
                           extraction; area/power/timing become wire-aware
                           (DESIGN.md §10)
  --util U1,U2,..          floorplan target utilization(s) in (0, 1]; more
                           than one value sweeps the utilization axis
                           (implies --place; default from config: 0.70)
  --aspect A1,A2,..        die aspect ratio(s) width/height (implies
                           --place; default 1.0)
  --pipeline S1,S2,..      stage list (default: full canonical pipeline, or
                           the placed pipeline with --place; the two are
                           mutually exclusive)
  --export                 append the interop export stage: lower every
                           elaborated unit to BLIF + structural Verilog,
                           check the BLIF re-import is bit-identical, and
                           (with --dump-dir) write LABEL.BACKEND.blif/.v
                           next to the stage artifacts (DESIGN.md §12)
  --faults                 append the fault-injection campaign stage: sweep
                           the configured class x rate x seed grid and
                           report accuracy / toggle / power degradation
                           against the fault-free baseline (equivalent to
                           `[faults] enabled = true`; `tnn7 faults` is the
                           dedicated front-end; DESIGN.md §13)
  --dump-dir DIR           write one JSON artifact per stage, named
                           NN_stage.BACKEND.json (multi-tech runs into one
                           directory never collide)
  --cache-dir DIR          consult the content-addressed stage cache with a
                           disk tier rooted at DIR: unchanged upstream
                           stages replay instead of re-executing across
                           runs and sweeps (DESIGN.md §11; `[cache]
                           enabled = true` in the config gives the
                           memory tier alone)
  --smoke                  quick smoke run: at most 2 waves, geometry
                           defaults to 8x4 when --col/--proto are omitted
  --waves N                simulated waves (default from config)
  --lanes N                stimulus lanes per simulator tick: 1 = scalar
                           reference engine, 2..64 = word-packed engine
                           (default from config; DESIGN.md §7)
  --threads N              worker threads for the packed wave schedule and
                           for --targets sweeps; activity and PPA numbers
                           are identical at every thread count
                           (default from config; DESIGN.md §8)
  --engine E               simulation engine: auto | scalar | packed |
                           compiled (default auto: scalar at 1 lane, else
                           packed; compiled lowers the netlist through the
                           optimizing IR passes into a flat op tape —
                           results are bit-identical on every engine;
                           DESIGN.md §14)
  --passes P1,P2,..        IR pass pipeline for --engine compiled: `all`,
                           `none`, or a subset of fold,dce,coalesce,
                           resched (default all; selection only — the
                           run order is fixed)
  --trace FILE             record hierarchical spans for the whole run
                           and write them as Chrome trace-event JSON
                           (open in Perfetto or chrome://tracing; every
                           executed stage, sim worker, and shard gets a
                           span; DESIGN.md §15)
  --config FILE            tnn7.toml configuration

{}{}",
        backend_help(),
        pipeline_help()
    )
}

/// Generated from the built-in registry, so the backend list in help
/// never drifts from what `--tech` actually resolves.
fn backend_help() -> String {
    let mut s = String::from(
        "BUILT-IN TECHNOLOGY BACKENDS (for --tech; .lib paths also \
         accepted):\n",
    );
    for ctx in TechRegistry::builtin().contexts() {
        s.push_str(&format!("  {}\n", ctx.backend().describe()));
    }
    s
}

/// The paper's published 45nm anchor for a geometry, if one exists (the
/// 1024x16 column and the prototype) — printed as ratios against the
/// natively measured PPA after a full pipeline run.
fn anchor_for(geometry: &Geometry) -> Option<(&'static str, ColumnPpa)> {
    match geometry {
        Geometry::Column(s) if s.p == 1024 && s.q == 16 => Some((
            "45nm 1024x16 column (Table IV [2])",
            scaling::COL_1024X16_45NM,
        )),
        Geometry::Prototype(_) => Some((
            "45nm prototype (Table VI [2])",
            scaling::PROTOTYPE_45NM,
        )),
        _ => None,
    }
}

fn cmd_flow(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!("{}", help_flow());
        return Ok(());
    }
    let target_desc = args.opt("--target")?;
    let targets_desc = args.opt("--targets")?;
    let tech_desc = args.opt("--tech")?;
    let smoke = args.flag("--smoke");
    let proto = args.flag("--proto");
    let col = args.opt("--col")?;
    let pipeline = args.opt("--pipeline")?;
    let dump_dir = args.opt("--dump-dir")?;
    let cache_dir = args.opt("--cache-dir")?;
    let trace_out = args.opt("--trace")?;
    let place_flag = args.flag("--place");
    let export_flag = args.flag("--export");
    let faults_flag = args.flag("--faults");
    let util_desc = args.opt("--util")?;
    let aspect_desc = args.opt("--aspect")?;
    let mut cfg = load_config(args)?;
    if let Some(w) = args.opt("--waves")? {
        cfg.sim_waves = w.parse()?;
    }
    if let Some(l) = args.opt("--lanes")? {
        let lanes: usize = l.parse()?;
        if !(1..=64).contains(&lanes) {
            anyhow::bail!("--lanes must be in 1..=64, got {lanes}");
        }
        cfg.sim_lanes = lanes;
    }
    if let Some(t) = args.opt("--threads")? {
        let threads: usize = t.parse()?;
        if threads < 1 {
            anyhow::bail!("--threads must be >= 1, got {threads}");
        }
        cfg.sim_threads = threads;
    }
    if let Some(e) = args.opt("--engine")? {
        cfg.sim_engine = e;
        cfg.validate_engine()?;
    }
    if let Some(p) = args.opt("--passes")? {
        cfg.sim_passes = p;
        cfg.pass_manager()?;
    }
    args.finish()?;
    if smoke {
        cfg.sim_waves = cfg.sim_waves.min(2);
    }
    // `--faults` behaves like `[faults] enabled = true` in the config:
    // the campaign stage is appended after the canonical pipeline, so
    // the default six measurement stages are untouched.
    if faults_flag {
        cfg.faults = true;
    }
    if cfg.faults {
        cfg.fault_spec()?;
    }

    // `--cache-dir` turns caching on with a disk tier; `[cache]
    // enabled = true` alone gives the in-process memory tier (useful
    // for --util/--aspect sweeps sharing elaborate/sta).
    if let Some(dir) = &cache_dir {
        cfg.cache_enabled = true;
        cfg.cache_dir = dir.clone();
    }
    let cache: Option<StageCache> = if cfg.cache_enabled {
        Some(StageCache::new(CacheConfig {
            mem_entries: cfg.cache_mem_entries,
            dir: if cfg.cache_dir.is_empty() {
                None
            } else {
                Some(cfg.cache_dir.clone().into())
            },
        }))
    } else {
        None
    };

    // `--trace` flips the global span recorder on for the whole run;
    // span sites cost two `Instant::now()` calls when it stays off.
    if trace_out.is_some() {
        tnn7::obs::set_tracing(true);
    }

    // --util/--aspect imply the physical-design stage; each accepts a
    // comma list forming a sweep axis (cross product when both).
    let place_cli =
        place_flag || util_desc.is_some() || aspect_desc.is_some();
    if place_cli {
        cfg.place = true;
    }
    let utils = parse_f64_list("--util", &util_desc, cfg.place_util)?;
    let aspects =
        parse_f64_list("--aspect", &aspect_desc, cfg.place_aspect)?;
    for &u in &utils {
        if !(u > 0.0 && u <= 1.0) {
            anyhow::bail!("--util values must be in (0, 1], got {u}");
        }
    }
    for &a in &aspects {
        if !(a > 0.0 && a.is_finite()) {
            anyhow::bail!("--aspect values must be positive, got {a}");
        }
    }
    // Only the CLI flags conflict with an explicit stage list; a
    // config-file `[place] enabled = true` just stops selecting the
    // default pipeline (the explicit --pipeline wins).
    if place_cli && pipeline.is_some() {
        anyhow::bail!(
            "--place/--util/--aspect select the placed pipeline; with \
             an explicit --pipeline, list the `place` stage yourself \
             instead"
        );
    }

    if proto && col.is_some() {
        anyhow::bail!("--proto and --col are mutually exclusive");
    }
    let geometry = if proto {
        Geometry::Prototype(PrototypeSpec::paper())
    } else if let Some(col) = col {
        let (p, q) = parse_geometry(&col)?;
        Geometry::Column(ColumnSpec::benchmark(p, q))
    } else if smoke {
        Geometry::Column(ColumnSpec::benchmark(8, 4))
    } else {
        anyhow::bail!("--col PxQ or --proto required (see --help)");
    };

    // Resolve the technology backends to measure on.  Named backends
    // come from the built-in registry; `.lib` paths load liberty-file
    // backends and register under the path.
    let mut registry = TechRegistry::builtin();
    let techs: Vec<TechContext> = match &tech_desc {
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| registry.resolve(s))
            .collect::<Result<_, _>>()?,
        None => Vec::new(),
    };
    if tech_desc.is_some() && techs.is_empty() {
        anyhow::bail!("--tech needs at least one backend name or .lib path");
    }

    // Parallel multi-flavour sweep mode.
    if let Some(list) = targets_desc {
        if target_desc.is_some()
            || pipeline.is_some()
            || dump_dir.is_some()
            || export_flag
        {
            anyhow::bail!(
                "--targets runs the fixed measurement pipeline for every \
                 listed target; it excludes --target, --pipeline, \
                 --dump-dir, and --export"
            );
        }
        cmd_flow_sweep(
            &list,
            &techs,
            &mut registry,
            geometry,
            &cfg,
            &utils,
            &aspects,
            cache.as_ref(),
        )?;
        write_trace(&trace_out)?;
        return Ok(());
    }

    let desc = target_desc.as_deref().unwrap_or("std");
    if tech_desc.is_some() && desc.contains(':') {
        anyhow::bail!(
            "give the technology either in --target FLAVOR:TECH or via \
             --tech, not both"
        );
    }
    let base = Target::parse(desc, geometry)?;
    let runs: Vec<TechContext> = if techs.is_empty() {
        vec![registry.resolve(base.tech.as_str())?]
    } else {
        techs
    };

    if dump_dir.is_some() && utils.len() * aspects.len() > 1 {
        anyhow::bail!(
            "--dump-dir artifacts are named NN_stage.BACKEND.json; a \
             multi---util/--aspect run into one directory would \
             collide — dump one design point at a time"
        );
    }
    let data =
        Arc::new(Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed));
    // One pipeline run per technology × utilization × aspect point
    // (one point unless --util/--aspect were given lists).
    let phys_points: Vec<(f64, f64)> = utils
        .iter()
        .flat_map(|&u| aspects.iter().map(move |&a| (u, a)))
        .collect();
    let run_points: Vec<(&TechContext, f64, f64)> = runs
        .iter()
        .flat_map(|t| {
            phys_points.iter().map(move |&(u, a)| (t, u, a))
        })
        .collect();
    let mut n_artifacts = 0usize;
    for (techctx, util, aspect) in run_points {
        let mut cfg = cfg.clone();
        cfg.place_util = util;
        cfg.place_aspect = aspect;
        let target = base.clone().with_tech(techctx.id());
        let mut flow = match &pipeline {
            Some(spec) => Flow::from_spec(spec)?,
            None if cfg.place => Flow::placed(),
            None => Flow::standard(),
        };
        if export_flag && !flow.stage_names().contains(&"export") {
            for stage in stages::make("export")? {
                flow = flow.with_stage(stage);
            }
        }
        if cfg.faults && !flow.stage_names().contains(&"faults") {
            for stage in stages::make("faults")? {
                flow = flow.with_stage(stage);
            }
        }
        if let Some(dir) = &dump_dir {
            flow = flow.dump_dir(dir);
        }
        let names = flow.stage_names();
        n_artifacts += names.len();
        println!(
            "flow {} [{}] | stages: {}",
            target.describe(),
            techctx.node_label(),
            names.join(" -> ")
        );
        if cfg.place {
            println!(
                "  physical design: util {util:.2}  aspect {aspect:.2}  \
                 seed {}",
                cfg.place_seed
            );
        }
        if cfg.sim_lanes > 1 {
            println!(
                "  packed engine: {} stimulus lanes per tick",
                cfg.sim_lanes
            );
            if cfg.sim_threads > 1 {
                println!(
                    "  wave schedule cut across {} worker threads",
                    cfg.sim_threads
                );
            }
        }

        let mut ctx = FlowContext::with_tech(
            target,
            cfg.clone(),
            techctx.clone(),
            Arc::clone(&data),
        );
        let trace = flow.run_cached(&mut ctx, cache.as_ref())?;
        if cache.is_some() {
            println!("  cache: {}", trace.cache_line());
        }

        if export_flag && !ctx.exported.is_empty() {
            println!(
                "  export: {} unit(s) lowered to BLIF + structural \
                 Verilog (re-import checked bit-identical)",
                ctx.exported.len()
            );
            if let Some(dir) = &dump_dir {
                for eu in &ctx.exported {
                    let stem = format!(
                        "{}.{}",
                        interop::sanitize_ident(&eu.label),
                        techctx.id()
                    );
                    let dir = Path::new(dir);
                    std::fs::write(
                        dir.join(format!("{stem}.blif")),
                        &eu.blif,
                    )?;
                    std::fs::write(
                        dir.join(format!("{stem}.v")),
                        &eu.verilog,
                    )?;
                    println!("    wrote {stem}.blif / {stem}.v");
                }
            }
        }

        if !ctx.fault_reports.is_empty() {
            for (rep, u) in ctx.fault_reports.iter().zip(&ctx.elaborated)
            {
                let perturbed = rep
                    .points
                    .iter()
                    .filter(|p| !p.bit_identical)
                    .count();
                println!(
                    "  faults {}: {} campaign points over {} sites, \
                     {} perturbed vs baseline",
                    u.plan.label(),
                    rep.points.len(),
                    rep.net_sites + rep.seq_sites,
                    perturbed
                );
            }
        }

        // A full-pipeline disk replay serves the cached dump bytes
        // without rebuilding typed artifacts: the context stays empty
        // and the totals come from the report artifact itself.
        if ctx.report.is_none() && trace.executed() == 0 {
            if let Some(dump) = trace.dump_for("report") {
                print_replayed_total(&dump)?;
            }
        }

        if let Some(r) = &ctx.report {
            for (i, u) in r.units.iter().enumerate() {
                println!(
                    "  unit {:>8} x{:<4} cells {:>8}  transistors {:>10}  \
                     clock {:>7.1} ps",
                    u.label, u.replicas, u.cells, u.transistors, u.clock_ps
                );
                if let Some(p) = &u.placed {
                    let wire_uw = ctx
                        .power
                        .get(i)
                        .map(|pw| pw.wire_uw)
                        .unwrap_or(0.0);
                    println!(
                        "       placed: die {:.1} x {:.1} um ({} rows)  \
                         HPWL {:.3} mm  wire cap {:.1} fF  wire power \
                         {:.4} uW",
                        p.die_w_um,
                        p.die_h_um,
                        p.rows,
                        p.hpwl_mm,
                        p.wire_cap_ff,
                        wire_uw
                    );
                }
            }
            println!(
                "  total ({}): power {:.3} uW  time {:.2} ns  \
                 area {:.5} mm2  edp {:.3} nJ-ns",
                r.node_label,
                r.total.power_uw,
                r.total.time_ns,
                r.total.area_mm2,
                r.total.edp_nj_ns()
            );
            // Published 45nm anchors ratio against the native
            // (unprojected) measurement, exactly as the old scale45
            // stage did.
            if let Some((name, anchor)) = anchor_for(&ctx.target.geometry)
            {
                let native = ctx.compose_native()?;
                let (rp, rt, ra) = scaling::ratios(&anchor, &native);
                println!(
                    "  vs {name}: power {rp:.0}x  time {rt:.1}x  \
                     area {ra:.0}x"
                );
            }
        }
    }
    if let Some(dir) = &dump_dir {
        println!("wrote {n_artifacts} stage artifacts to {dir}/");
    }
    write_trace(&trace_out)?;
    Ok(())
}

/// Drain the recorded spans and write them as Chrome trace-event
/// JSON (`--trace FILE`); a no-op when the flag was not given.
fn write_trace(path: &Option<String>) -> anyhow::Result<()> {
    let Some(path) = path else { return Ok(()) };
    let spans = tnn7::obs::take_spans();
    std::fs::write(
        path,
        tnn7::obs::chrome_trace(&spans).to_string_pretty(),
    )?;
    println!(
        "wrote {} spans to {path} (Chrome trace-event JSON; load in \
         Perfetto)",
        spans.len()
    );
    Ok(())
}

/// Print the total-PPA summary out of a replayed report artifact: a
/// full-pipeline disk replay (cache hit across processes) serves dump
/// bytes without reconstructing the typed [`flow::TargetReport`], so
/// the summary line is read back from the JSON itself.
fn print_replayed_total(dump: &str) -> anyhow::Result<()> {
    let j = Json::parse(dump)?;
    let total = j.field("total")?;
    println!(
        "  total ({}): power {:.3} uW  time {:.2} ns  \
         area {:.5} mm2  edp {:.3} nJ-ns  [replayed]",
        j.field("node")?.as_str()?,
        total.field("power_uw")?.as_f64()?,
        total.field("time_ns")?.as_f64()?,
        total.field("area_mm2")?.as_f64()?,
        total.field("edp_nj_ns")?.as_f64()?,
    );
    Ok(())
}

/// Parse a comma-separated float list option; `default` when absent.
fn parse_f64_list(
    name: &str,
    desc: &Option<String>,
    default: f64,
) -> anyhow::Result<Vec<f64>> {
    let Some(list) = desc else {
        return Ok(vec![default]);
    };
    let vals: Vec<f64> = list
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("{name}: bad number `{s}`"))
        })
        .collect::<Result<_, _>>()?;
    if vals.is_empty() {
        anyhow::bail!("{name} needs at least one value");
    }
    Ok(vals)
}

fn help_export() -> String {
    "tnn7 export — lower elaborated netlists to external EDA formats

Runs the elaborate + export flow stages and writes one BLIF and one
structural Verilog file per target unit; the export stage checks
inline that re-importing the BLIF reconstructs a bit-identical
netlist.  With --vcd it additionally records a seeded packed wave run
of every unit to a VCD file that `tnn7 replay` (or any waveform
viewer) can consume.  DESIGN.md §12 documents the formats and the
identifier mangling.

USAGE: tnn7 export [OPTIONS] --out DIR

OPTIONS:
  --target FLAVOR[:TECH]   flavour std|baseline or custom|gdi, optionally
                           pinned to a technology backend (default std)
  --tech T                 technology backend name or .lib path
                           (default: the target's backend)
  --col PxQ                single-column geometry (e.g. 32x12)
  --proto                  the Fig. 19 2-layer prototype instead of --col
  --out DIR                output directory; files are named
                           LABEL.BACKEND.blif / .v / .vcd
  --vcd                    also record a seeded packed wave run per unit
  --lanes N                stimulus lanes for the VCD recording, 1..=64
                           (default 4)
  --waves N                waves to record into the VCD (default 2)
  --seed S                 stimulus seed for the VCD recording (default 7)
  --config FILE            tnn7.toml configuration
"
    .to_string()
}

/// Deterministic xorshift64 word stream for `export --vcd` stimulus.
fn xorshift64(state: &mut u64) -> u64 {
    let mut s = *state;
    s ^= s << 13;
    s ^= s >> 7;
    s ^= s << 17;
    *state = s;
    s
}

/// Seeded random wave stimulus in the testbench idiom: spike times
/// uniform over [0, 8) with 1-in-8 "no spike", BRV thresholds uniform
/// 16-bit.
fn random_wave_stimulus(
    p: usize,
    n_syn: usize,
    lanes: usize,
    state: &mut u64,
) -> (Vec<Vec<i32>>, Vec<Vec<RandPair>>) {
    let spikes = (0..lanes)
        .map(|_| {
            (0..p)
                .map(|_| {
                    let v = xorshift64(state);
                    if v & 7 == 7 {
                        INF
                    } else {
                        (v % 8) as i32
                    }
                })
                .collect()
        })
        .collect();
    let rand = (0..lanes)
        .map(|_| {
            (0..n_syn)
                .map(|_| {
                    let v = xorshift64(state);
                    (v as u16, (v >> 16) as u16)
                })
                .collect()
        })
        .collect();
    (spikes, rand)
}

fn cmd_export(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!("{}", help_export());
        return Ok(());
    }
    let target_desc = args.opt("--target")?;
    let tech_desc = args.opt("--tech")?;
    let col = args.opt("--col")?;
    let proto = args.flag("--proto");
    let out = args
        .opt("--out")?
        .ok_or_else(|| anyhow::anyhow!("--out DIR required (see --help)"))?;
    let vcd = args.flag("--vcd");
    let lanes: usize = match args.opt("--lanes")? {
        Some(l) => l.parse()?,
        None => 4,
    };
    if !(1..=64).contains(&lanes) {
        anyhow::bail!("--lanes must be in 1..=64, got {lanes}");
    }
    let waves: usize = match args.opt("--waves")? {
        Some(w) => w.parse()?,
        None => 2,
    };
    let seed: u64 = match args.opt("--seed")? {
        Some(s) => s.parse()?,
        None => 7,
    };
    let cfg = load_config(args)?;
    args.finish()?;

    if proto && col.is_some() {
        anyhow::bail!("--proto and --col are mutually exclusive");
    }
    let geometry = if proto {
        Geometry::Prototype(PrototypeSpec::paper())
    } else if let Some(col) = col {
        let (p, q) = parse_geometry(&col)?;
        Geometry::Column(ColumnSpec::benchmark(p, q))
    } else {
        anyhow::bail!("--col PxQ or --proto required (see --help)");
    };

    let desc = target_desc.as_deref().unwrap_or("std");
    if tech_desc.is_some() && desc.contains(':') {
        anyhow::bail!(
            "give the technology either in --target FLAVOR:TECH or via \
             --tech, not both"
        );
    }
    let base = Target::parse(desc, geometry)?;
    let mut registry = TechRegistry::builtin();
    let techctx = match &tech_desc {
        Some(name) => registry.resolve(name)?,
        None => registry.resolve(base.tech.as_str())?,
    };
    let target = base.with_tech(techctx.id());

    let data =
        Arc::new(Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed));
    let mut ctx = FlowContext::with_tech(
        target,
        cfg.clone(),
        techctx.clone(),
        Arc::clone(&data),
    );
    println!(
        "export {} [{}] -> {}/",
        ctx.target.describe(),
        techctx.node_label(),
        out
    );
    Flow::from_spec("elaborate,export")?.run(&mut ctx)?;

    std::fs::create_dir_all(&out)?;
    let dir = Path::new(&out);
    for eu in &ctx.exported {
        let stem =
            format!("{}.{}", interop::sanitize_ident(&eu.label), techctx.id());
        std::fs::write(dir.join(format!("{stem}.blif")), &eu.blif)?;
        std::fs::write(dir.join(format!("{stem}.v")), &eu.verilog)?;
        println!(
            "  {stem}.blif  {:>8} bytes  fnv {:016x}",
            eu.blif.len(),
            interop::text_digest(&eu.blif)
        );
        println!(
            "  {stem}.v     {:>8} bytes  fnv {:016x}",
            eu.verilog.len(),
            interop::text_digest(&eu.verilog)
        );
    }

    if vcd {
        let lib = techctx.library();
        let params = StdpParams::default_training();
        let mut state = seed | 1;
        for eu in &ctx.elaborated {
            let p = eu.ports.x.len();
            let n_syn = eu.ports.brv.len() / BRV_PER_SYN;
            let mut ticks = Vec::new();
            for _ in 0..waves.max(1) {
                let (spikes, rand) =
                    random_wave_stimulus(p, n_syn, lanes, &mut state);
                ticks.extend(interop::vcd::column_wave_ticks(
                    &eu.ports, &spikes, &rand, &params,
                ));
            }
            let mut sim = PackedSimulator::new(&eu.netlist, lib, lanes)?;
            let text = interop::record_engine(&mut sim, &eu.netlist, &ticks);
            let stem = format!(
                "{}.{}",
                interop::sanitize_ident(&eu.plan.label()),
                techctx.id()
            );
            std::fs::write(dir.join(format!("{stem}.vcd")), &text)?;
            println!(
                "  {stem}.vcd   {:>8} bytes  ({} waves x {} lanes, \
                 {} ticks)",
                text.len(),
                waves.max(1),
                lanes,
                ticks.len()
            );
        }
    }
    println!(
        "exported {} unit(s); BLIF re-import checked bit-identical",
        ctx.exported.len()
    );
    Ok(())
}

fn help_replay() -> String {
    "tnn7 replay — re-ingest a recorded VCD as simulator stimulus

Parses a VCD recorded by `tnn7 export --vcd` (or any writer using the
same lane-scope convention), converts it back into a packed stimulus
schedule, drives it through a freshly built engine, and re-records the
run.  A recording that replays on the same design is byte-identical —
the strongest possible equal-toggle-counts statement — and the command
fails if any per-var toggle count differs.  Replaying a recording from
one engine or flavour on another is the conformance suite's
cross-engine check (DESIGN.md §12).

USAGE: tnn7 replay --vcd FILE --col PxQ [OPTIONS]

OPTIONS:
  --vcd FILE               the recording to replay (required)
  --col PxQ                column geometry the recording was made from
  --target FLAVOR[:TECH]   flavour/backend to rebuild the netlist with
                           (default std; a different flavour than the
                           recording exercises cross-flavour equivalence)
  --tech T                 technology backend name or .lib path
  --engine E               scalar | packed | sharded | compiled |
                           compiled-sharded (default packed; scalar
                           accepts 1-lane recordings only; the compiled
                           engines run the optimized op tape and must
                           stay byte-identical too, DESIGN.md §14)
  --threads N              shard workers for the sharded engines
                           (default 2)
  --out FILE               write the re-recorded VCD
  --config FILE            tnn7.toml configuration
"
    .to_string()
}

fn cmd_replay(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!("{}", help_replay());
        return Ok(());
    }
    let vcd_path = args
        .opt("--vcd")?
        .ok_or_else(|| anyhow::anyhow!("--vcd FILE required (see --help)"))?;
    let col = args
        .opt("--col")?
        .ok_or_else(|| anyhow::anyhow!("--col PxQ required (see --help)"))?;
    let target_desc = args.opt("--target")?;
    let tech_desc = args.opt("--tech")?;
    let engine = args.opt("--engine")?.unwrap_or_else(|| "packed".into());
    let threads: usize = match args.opt("--threads")? {
        Some(t) => t.parse()?,
        None => 2,
    };
    let out = args.opt("--out")?;
    let _cfg = load_config(args)?;
    args.finish()?;

    let text = std::fs::read_to_string(&vcd_path)?;
    let doc = interop::parse_vcd(&text)?;
    println!(
        "replay {}: design `{}`  {} lanes  {} ticks  {} vars",
        vcd_path, doc.design, doc.lanes, doc.ticks, doc.vars.len()
    );

    let (p, q) = parse_geometry(&col)?;
    let spec = ColumnSpec::benchmark(p, q);
    let desc = target_desc.as_deref().unwrap_or("std");
    if tech_desc.is_some() && desc.contains(':') {
        anyhow::bail!(
            "give the technology either in --target FLAVOR:TECH or via \
             --tech, not both"
        );
    }
    let base = Target::parse(desc, Geometry::Column(spec))?;
    let mut registry = TechRegistry::builtin();
    let techctx = match &tech_desc {
        Some(name) => registry.resolve(name)?,
        None => registry.resolve(base.tech.as_str())?,
    };
    let lib = techctx.library();
    let (nl, _ports) = build_column(lib, base.flavor, &spec)?;
    let ticks = doc.stimulus(&nl)?;

    let replayed = match engine.as_str() {
        "scalar" => {
            if doc.lanes != 1 {
                anyhow::bail!(
                    "the scalar engine replays 1-lane recordings only \
                     (this one has {} lanes)",
                    doc.lanes
                );
            }
            let mut sim = Simulator::new(&nl, lib)?;
            interop::record_engine(&mut sim, &nl, &ticks)
        }
        "packed" => {
            let mut sim = PackedSimulator::new(&nl, lib, doc.lanes)?;
            interop::record_engine(&mut sim, &nl, &ticks)
        }
        "sharded" => {
            let mut sim =
                ShardedSimulator::new(&nl, lib, doc.lanes, threads.max(1), &[])?;
            interop::record_engine(&mut sim, &nl, &ticks)
        }
        "compiled" => {
            let mut sim = CompiledSimulator::new(&nl, lib, doc.lanes)?;
            interop::record_engine(&mut sim, &nl, &ticks)
        }
        "compiled-sharded" => {
            let (mut sim, _stats) = ShardedSimulator::new_compiled(
                &nl,
                lib,
                doc.lanes,
                threads.max(1),
                &[],
                &PassManager::all(),
            )?;
            interop::record_engine(&mut sim, &nl, &ticks)
        }
        other => anyhow::bail!(
            "unknown engine `{other}` (scalar | packed | sharded | \
             compiled | compiled-sharded)"
        ),
    };

    let redoc = interop::parse_vcd(&replayed)?;
    let toggles: u64 = doc.toggles().iter().sum();
    let retoggles: u64 = redoc.toggles().iter().sum();
    println!(
        "  {} engine: {} ticks re-simulated, {} toggles recorded \
         (original {})",
        engine,
        ticks.len(),
        retoggles,
        toggles
    );
    if let Some(path) = &out {
        std::fs::write(path, &replayed)?;
        println!("  wrote {path}");
    }
    if replayed == text {
        println!("  round-trip: byte-identical recording");
    } else if redoc.toggles() == doc.toggles() {
        println!(
            "  round-trip: toggle counts identical per var (text differs \
             in headers only — cross-design replay)"
        );
    } else {
        anyhow::bail!(
            "replay diverged: per-var toggle counts differ from the \
             recording ({} vs {} total)",
            retoggles,
            toggles
        );
    }
    Ok(())
}

fn help_faults() -> String {
    "tnn7 faults — seeded fault-injection campaigns against a design

Runs the elaborate + sta + faults flow stages: every campaign point
(fault class x rate x seed) re-simulates the full stimulus under a
deterministic fault overlay and is scored against the fault-free
baseline — classification accuracy (fraction of waves whose post-WTA
spike vector matches), summed |dW|, toggle count, and power priced at
the base STA clock.  Zero-rate points are bit-identical to the plain
simulate stage on every engine.  DESIGN.md §13 documents the fault
model and the artifact schema.

USAGE: tnn7 faults (--col PxQ | --proto) [OPTIONS]

OPTIONS:
  --target FLAVOR[:TECH]   flavour std|baseline or custom|gdi (default std)
  --tech T                 technology backend or .lib path
                           (default: asap7-tnn7)
  --col PxQ                single-column geometry (e.g. 32x12)
  --proto                  the Fig. 19 2-layer prototype instead of --col
  --smoke                  quick campaign: at most 2 waves, geometry
                           defaults to 8x4, grid stuck0,stuck1,seu x
                           rates 0,0.02 x seed 1 (explicit --classes/
                           --rates/--seeds still override)
  --classes C1,..          fault classes: stuck0|sa0, stuck1|sa1, seu,
                           delay, glitch (default from config)
  --rates R1,..            fault rates in [0, 1]; rate 0 is the control
                           point (default from config)
  --seeds S1,..            campaign PRNG seeds (default from config)
  --waves N                simulated waves (default from config)
  --lanes N                stimulus lanes per tick (1 = scalar engine,
                           2..64 = packed; results are engine-invariant)
  --threads N              worker threads for the packed wave schedule;
                           results are identical at every thread count
  --engine E               auto | scalar | packed | compiled: `compiled`
                           runs campaign points on the optimized op tape,
                           falling back to the interpreters for points
                           whose fault sites the passes optimized away
                           (DESIGN.md §14)
  --dump-dir DIR           write the stage artifacts, including
                           NN_faults.BACKEND.json
  --cache-dir DIR          consult the content-addressed stage cache
                           (campaign grid is part of the key; lanes and
                           threads are not)
  --out FILE               write the campaign report JSON (the faults
                           stage artifact) to FILE, e.g.
                           BENCH_faults.json
  --config FILE            tnn7.toml configuration
"
    .to_string()
}

fn cmd_faults(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!("{}", help_faults());
        return Ok(());
    }
    let target_desc = args.opt("--target")?;
    let tech_desc = args.opt("--tech")?;
    let smoke = args.flag("--smoke");
    let proto = args.flag("--proto");
    let col = args.opt("--col")?;
    let classes = args.opt("--classes")?;
    let rates = args.opt("--rates")?;
    let seeds = args.opt("--seeds")?;
    let dump_dir = args.opt("--dump-dir")?;
    let cache_dir = args.opt("--cache-dir")?;
    let out = args.opt("--out")?;
    let mut cfg = load_config(args)?;
    if let Some(w) = args.opt("--waves")? {
        cfg.sim_waves = w.parse()?;
    }
    if let Some(l) = args.opt("--lanes")? {
        let lanes: usize = l.parse()?;
        if !(1..=64).contains(&lanes) {
            anyhow::bail!("--lanes must be in 1..=64, got {lanes}");
        }
        cfg.sim_lanes = lanes;
    }
    if let Some(t) = args.opt("--threads")? {
        let threads: usize = t.parse()?;
        if threads < 1 {
            anyhow::bail!("--threads must be >= 1, got {threads}");
        }
        cfg.sim_threads = threads;
    }
    if let Some(e) = args.opt("--engine")? {
        cfg.sim_engine = e;
        cfg.validate_engine()?;
    }
    args.finish()?;

    if smoke {
        cfg.sim_waves = cfg.sim_waves.min(2);
        // The smoke grid matches `CampaignSpec::smoke()`: both
        // stuck-at polarities plus SEU, a zero-rate control point,
        // one seed.
        cfg.faults_classes = "stuck0,stuck1,seu".to_string();
        cfg.faults_rates = "0,0.02".to_string();
        cfg.faults_seeds = "1".to_string();
    }
    if let Some(v) = classes {
        cfg.faults_classes = v;
    }
    if let Some(v) = rates {
        cfg.faults_rates = v;
    }
    if let Some(v) = seeds {
        cfg.faults_seeds = v;
    }
    cfg.faults = true;
    // Validate the grid before elaborating anything.
    let spec = cfg.fault_spec()?;

    if proto && col.is_some() {
        anyhow::bail!("--proto and --col are mutually exclusive");
    }
    let geometry = if proto {
        Geometry::Prototype(PrototypeSpec::paper())
    } else if let Some(col) = col {
        let (p, q) = parse_geometry(&col)?;
        Geometry::Column(ColumnSpec::benchmark(p, q))
    } else if smoke {
        Geometry::Column(ColumnSpec::benchmark(8, 4))
    } else {
        anyhow::bail!("--col PxQ or --proto required (see --help)");
    };

    let desc = target_desc.as_deref().unwrap_or("std");
    if tech_desc.is_some() && desc.contains(':') {
        anyhow::bail!(
            "give the technology either in --target FLAVOR:TECH or via \
             --tech, not both"
        );
    }
    let base = Target::parse(desc, geometry)?;
    let mut registry = TechRegistry::builtin();
    let techctx = match &tech_desc {
        Some(name) => registry.resolve(name)?,
        None => registry.resolve(base.tech.as_str())?,
    };
    let target = base.with_tech(techctx.id());

    if let Some(dir) = &cache_dir {
        cfg.cache_enabled = true;
        cfg.cache_dir = dir.clone();
    }
    let cache: Option<StageCache> = if cfg.cache_enabled {
        Some(StageCache::new(CacheConfig {
            mem_entries: cfg.cache_mem_entries,
            dir: if cfg.cache_dir.is_empty() {
                None
            } else {
                Some(cfg.cache_dir.clone().into())
            },
        }))
    } else {
        None
    };

    let mut flow = Flow::from_spec("elaborate,sta,faults")?;
    if let Some(dir) = &dump_dir {
        flow = flow.dump_dir(dir);
    }
    println!(
        "fault campaign {} [{}] | {} classes x {} rates x {} seeds = \
         {} points/unit, {} waves",
        target.describe(),
        techctx.node_label(),
        spec.classes.len(),
        spec.rates.len(),
        spec.seeds.len(),
        spec.classes.len() * spec.rates.len() * spec.seeds.len(),
        cfg.sim_waves
    );

    let data =
        Arc::new(Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed));
    let mut ctx = FlowContext::with_tech(
        target,
        cfg.clone(),
        techctx.clone(),
        Arc::clone(&data),
    );
    let trace = flow.run_cached(&mut ctx, cache.as_ref())?;
    if cache.is_some() {
        println!("  cache: {}", trace.cache_line());
    }

    // A full disk replay serves cached dump bytes without rebuilding
    // the typed campaign reports — print (and write) from the JSON.
    if ctx.fault_reports.is_empty() && trace.executed() == 0 {
        if let Some(dump) = trace.dump_for("faults") {
            print_replayed_faults(&dump)?;
            if let Some(path) = &out {
                std::fs::write(path, dump.as_bytes())?;
                println!("wrote {path}");
            }
            return Ok(());
        }
    }

    for (rep, u) in ctx.fault_reports.iter().zip(&ctx.elaborated) {
        println!(
            "  unit {}: {} net sites + {} seq sites, base toggles {}",
            u.plan.label(),
            rep.net_sites,
            rep.seq_sites,
            rep.base_toggles
        );
        for p in &rep.points {
            let d_toggle = if rep.base_toggles > 0 {
                (p.toggles as f64 / rep.base_toggles as f64 - 1.0)
                    * 100.0
            } else {
                0.0
            };
            println!(
                "    {:<6} rate {:<6} seed {:<4} inj {:>5}  acc \
                 {:>5.1}%  d-toggle {:>+7.2}%  dW {:>6}{}",
                p.point.class.label(),
                p.point.rate,
                p.point.seed,
                p.injections,
                p.accuracy * 100.0,
                d_toggle,
                p.weight_l1,
                if p.bit_identical { "  [bit-identical]" } else { "" }
            );
        }
    }
    if let Some(path) = &out {
        std::fs::write(path, stages::Faults.dump(&ctx).to_string_pretty())?;
        println!("wrote {path}");
    }
    if let Some(dir) = &dump_dir {
        println!("wrote stage artifacts to {dir}/");
    }
    Ok(())
}

/// Per-point summary out of a replayed faults artifact (full-pipeline
/// disk cache hit: dump bytes exist, typed reports were not rebuilt).
fn print_replayed_faults(dump: &str) -> anyhow::Result<()> {
    let j = Json::parse(dump)?;
    for u in j.field("units")?.as_arr()? {
        println!(
            "  unit {}: {} net sites + {} seq sites, base toggles {} \
             [replayed]",
            u.field("label")?.as_str()?,
            u.field("net_sites")?.as_usize()?,
            u.field("seq_sites")?.as_usize()?,
            u.field("base_toggles")?.as_usize()?,
        );
        for p in u.field("points")?.as_arr()? {
            println!(
                "    {:<6} rate {:<6} seed {:<4} inj {:>5}  acc {:>5.1}%",
                p.field("class")?.as_str()?,
                p.field("rate")?.as_f64()?,
                p.field("seed")?.as_i64()?,
                p.field("injections")?.as_usize()?,
                p.field("accuracy")?.as_f64()? * 100.0,
            );
        }
    }
    Ok(())
}

/// `tnn7 flow --targets A,B,.. [--tech T1,T2,..] [--util U1,U2,..]`:
/// measure every flavour × technology (× utilization × aspect, with
/// `--place`) combination through the measurement pipeline
/// concurrently and print one summary row each.
fn cmd_flow_sweep(
    list: &str,
    techs: &[TechContext],
    registry: &mut TechRegistry,
    geometry: Geometry,
    cfg: &TnnConfig,
    utils: &[f64],
    aspects: &[f64],
    cache: Option<&StageCache>,
) -> anyhow::Result<()> {
    // In sweep mode --threads parallelizes ACROSS targets; each job
    // simulates single-threaded so the thread budget is not squared
    // (sweep workers × per-job wave threads would oversubscribe).
    let mut job_cfg = cfg.clone();
    job_cfg.sim_threads = 1;
    // The physical-design axes: one job per utilization × aspect point
    // (a single point when --util/--aspect are not swept).
    let phys_points: Vec<(f64, f64)> = utils
        .iter()
        .flat_map(|&u| aspects.iter().map(move |&a| (u, a)))
        .collect();
    let label_phys = cfg.place && phys_points.len() > 1;
    let mut jobs = Vec::new();
    let mut push_jobs = |base: Target, job_cfg: &TnnConfig| {
        for &(u, a) in &phys_points {
            let mut cfg = job_cfg.clone();
            cfg.place_util = u;
            cfg.place_aspect = a;
            let label = if label_phys {
                format!("{} u{u:.2} a{a:.2}", base.describe())
            } else {
                base.describe()
            };
            jobs.push(compare::SweepJob {
                label,
                target: base.clone(),
                cfg,
            });
        }
    };
    for d in list.split(',').map(str::trim).filter(|d| !d.is_empty()) {
        let base = Target::parse(d, geometry)?;
        if techs.is_empty() {
            // No --tech: each descriptor carries (or defaults) its own
            // technology; .lib paths load and register here.
            registry.resolve(base.tech.as_str())?;
            push_jobs(base, &job_cfg);
        } else {
            if d.contains(':') {
                anyhow::bail!(
                    "give the technology either in --targets FLAVOR:TECH \
                     entries or via --tech, not both (got `{d}`)"
                );
            }
            for t in techs {
                push_jobs(base.clone().with_tech(t.id()), &job_cfg);
            }
        }
    }
    if jobs.is_empty() {
        anyhow::bail!("--targets needs at least one FLAVOR[:TECH] entry");
    }
    let threads = cfg.sim_threads.max(1);
    println!(
        "flow sweep: {} targets on {} threads ({} waves, {} lanes)",
        jobs.len(),
        threads.min(jobs.len()),
        cfg.sim_waves,
        cfg.sim_lanes
    );
    let data =
        Arc::new(Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed));
    let results =
        compare::run_sweep_cached(&jobs, registry, &data, threads, cache);
    let mut failed = false;
    for r in &results {
        match &r.report {
            Ok(rep) => println!(
                "  {:<28} power {:>10.3} uW  time {:>8.2} ns  \
                 area {:>9.5} mm2  edp {:>9.3} nJ-ns",
                r.label,
                rep.total.power_uw,
                rep.total.time_ns,
                rep.total.area_mm2,
                rep.total.edp_nj_ns()
            ),
            Err(e) => {
                failed = true;
                println!("  {:<28} FAILED: {e}", r.label);
            }
        }
    }
    if let Some(cache) = cache {
        let (mem, disk, misses) = cache.counters();
        println!(
            "  cache: mem hits {mem}  disk hits {disk}  misses {misses}"
        );
    }
    if failed {
        anyhow::bail!("one or more sweep targets failed");
    }
    Ok(())
}

fn cmd_characterize(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!(
            "tnn7 characterize [--lib FILE] — print the characterized \
             cell library; optionally emit a Liberty .lib file"
        );
        return Ok(());
    }
    let lib_out = args.opt("--lib")?;
    args.finish()?;
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    println!(
        "{:<20} {:>6} {:>10} {:>10} {:>10} {:>9}  macro",
        "cell", "T", "area um2", "energy fJ", "leak nW", "delay ps"
    );
    for c in lib.cells() {
        println!(
            "{:<20} {:>6} {:>10.4} {:>10.4} {:>10.4} {:>9.1}  {}",
            c.name,
            c.transistors,
            tech.area_um2(c),
            tech.energy_fj(c),
            tech.leak_nw(c),
            tech.delay_ps(c),
            if c.is_custom_macro { "*" } else { "" }
        );
    }
    if let Some(path) = lib_out {
        let text = liberty::emit(&lib, &tech, "tnn7_rvt_tt_0p7v_25c");
        std::fs::write(&path, text)?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_layout_cmp(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!(
            "tnn7 layout-cmp [MACRO] [--json FILE] — Figs. 14-18 \
             structural comparisons (all rows, or one function/cell by \
             name); --json writes the rows as a flow-style artifact"
        );
        return Ok(());
    }
    let json_out = args.opt("--json")?;
    let which = args.positional();
    args.finish()?;
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    let wire = tech::WireParams::asap7();
    let rows = compare::layout_comparisons(
        &lib,
        &tech,
        &wire,
        which.as_deref(),
    )?;
    if rows.is_empty() {
        anyhow::bail!(
            "no comparison named `{}` (try less_equal, mux2to1, \
             stabilize_func)",
            which.unwrap_or_default()
        );
    }
    if let Some(path) = &json_out {
        std::fs::write(
            path,
            compare::to_json(&rows).to_string_pretty(),
        )?;
        println!("wrote {path}");
    }
    println!(
        "{:<12} {:<16} {:>8} {:>8} {:>12} {:>12} {:>12} {:>10}",
        "figure",
        "function",
        "std T",
        "custom T",
        "std um2",
        "custom um2",
        "placed um2",
        "hpwl um"
    );
    for r in rows {
        println!(
            "{:<12} {:<16} {:>8} {:>8} {:>12.4} {:>12.4} {:>12.4} {:>10.3}",
            r.figure,
            r.function,
            r.std_ref_transistors,
            r.macro_transistors,
            r.std_ref_area_um2,
            r.macro_area_um2,
            r.custom_placed_um2,
            r.custom_hpwl_um
        );
    }
    Ok(())
}

fn cmd_complexity(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!(
            "tnn7 complexity — Fig. 19 prototype census (cells and \
             transistors, both flavours) via the flow elaborate stage"
        );
        return Ok(());
    }
    args.finish()?;
    let spec = PrototypeSpec::paper();
    println!(
        "Fig. 19 prototype: {} neurons, {} synapses (paper: 13,750 / 315,000)",
        spec.neurons(),
        spec.synapses()
    );
    let registry = TechRegistry::builtin();
    let techctx = registry.get(tech::ASAP7_TNN7)?;
    let data = Arc::new(Dataset::generate(0, 0));
    for flavor in [Flavor::Std, Flavor::Custom] {
        // elaborate-only pipeline: no simulation, so no dataset needed.
        let mut ctx = FlowContext::with_tech(
            Target::prototype(flavor),
            TnnConfig::default(),
            techctx.clone(),
            Arc::clone(&data),
        );
        Flow::from_spec("elaborate")?.run(&mut ctx)?;
        let (cells, transistors) = ctx.total_census()?;
        println!(
            "{:<22} {:>12} cells {:>13} transistors (paper: 32M gates / 128M T)",
            flavor.label(),
            cells,
            transistors
        );
    }
    Ok(())
}

fn cmd_calibrate(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!(
            "tnn7 calibrate [--config FILE] — fit the four technology \
             constants against the paper's Table I std-cell rows"
        );
        return Ok(());
    }
    let cfg = load_config(args)?;
    args.finish()?;
    let lib = Library::with_macros();
    let data = Dataset::generate(16, cfg.data_seed);
    println!("evaluating Table-I std columns in relative units ...");
    let obs = tnn7::coordinator::measure::calibration_observations(
        &lib, &cfg, &data,
    )?;
    let fit = calibrate::fit(&obs);
    println!("fitted technology constants:");
    println!("  area_per_unit_um2  = {:.4e}", fit.tech.area_per_unit_um2);
    println!("  energy_per_unit_fj = {:.4e}", fit.tech.energy_per_unit_fj);
    println!("  leak_per_unit_nw   = {:.4e}", fit.tech.leak_per_unit_nw);
    println!("  fo4_ps             = {:.4}", fit.tech.fo4_ps);
    println!(
        "rms relative residuals: area {:.1}%  time {:.1}%  power {:.1}%",
        fit.resid_area * 100.0,
        fit.resid_time * 100.0,
        fit.resid_power * 100.0
    );
    println!(
        "\n(current TechParams::calibrated(): {:?})",
        TechParams::calibrated()
    );
    Ok(())
}

/// Paper Table I values for side-by-side display.
fn paper_table1(flavor: Flavor, label: &str) -> Option<ColumnPpa> {
    let v = match (flavor, label) {
        (Flavor::Std, "64x8") => (3.89, 26.92, 0.004),
        (Flavor::Std, "128x10") => (10.27, 28.52, 0.009),
        (Flavor::Std, "1024x16") => (131.46, 36.52, 0.124),
        (Flavor::Custom, "64x8") => (2.73, 20.59, 0.003),
        (Flavor::Custom, "128x10") => (5.76, 22.79, 0.006),
        (Flavor::Custom, "1024x16") => (73.73, 29.49, 0.079),
        _ => return None,
    };
    Some(ColumnPpa { power_uw: v.0, time_ns: v.1, area_mm2: v.2 })
}

fn cmd_table1(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!(
            "tnn7 bench-table1 [--with-45nm] [--waves N] [--threads N] \
             [--config FILE] — regenerate Table I through the flow API \
             (the six design points run as a parallel sweep)"
        );
        return Ok(());
    }
    let with_45 = args.flag("--with-45nm");
    let mut cfg = load_config(args)?;
    if let Some(w) = args.opt("--waves")? {
        cfg.sim_waves = w.parse()?;
    }
    if let Some(t) = args.opt("--threads")? {
        let threads: usize = t.parse()?;
        if threads < 1 {
            anyhow::bail!("--threads must be >= 1, got {threads}");
        }
        cfg.sim_threads = threads;
    }
    args.finish()?;
    // One registry for the whole bench: the asap7-tnn7 library is
    // characterized exactly once and Arc-shared by every design point
    // (the old path cloned the library per measurement).
    let registry = TechRegistry::builtin();
    let data =
        Arc::new(Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed));
    // The 6 Table-I design points as one parallel sweep (numbers are
    // bit-identical to the serial loop; only wall time changes).
    // --threads parallelizes across design points, so each job
    // simulates single-threaded (no worker × wave-thread squaring).
    let mut job_cfg = cfg.clone();
    job_cfg.sim_threads = 1;
    let mut jobs = Vec::new();
    for flavor in [Flavor::Std, Flavor::Custom] {
        for (label, spec) in table1_specs() {
            jobs.push(compare::SweepJob {
                label: format!("{flavor:?} {label}"),
                target: Target::column(flavor, spec),
                cfg: job_cfg.clone(),
            });
        }
    }
    let sweep = compare::run_sweep(
        &jobs,
        &registry,
        &data,
        cfg.sim_threads.max(1),
    );
    let mut rows = Vec::new();
    let mut pairs = Vec::new();
    let mut sweep_it = sweep.into_iter();
    for flavor in [Flavor::Std, Flavor::Custom] {
        for (label, _spec) in table1_specs() {
            let res = sweep_it.next().expect("one result per job");
            let r = res.report?;
            rows.push(PpaRow {
                flavor: flavor.label(),
                label: label.to_string(),
                ppa: r.total,
                paper: paper_table1(flavor, label),
            });
            pairs.push((flavor, label, r.total));
            eprintln!("  measured {flavor:?} {label}");
        }
    }
    println!("\nTable I — standard vs custom PPA, 7nm (measured vs paper)\n");
    println!("{}", render_table1(&rows));
    for (label, _) in table1_specs().iter() {
        let std = pairs
            .iter()
            .find(|(f, l, _)| *f == Flavor::Std && l == label)
            .unwrap()
            .2;
        let cus = pairs
            .iter()
            .find(|(f, l, _)| *f == Flavor::Custom && l == label)
            .unwrap()
            .2;
        println!("{label:>9}: {}", improvement_line(&std, &cus));
    }
    if with_45 {
        let cus1024 = pairs
            .iter()
            .find(|(f, l, _)| *f == Flavor::Custom && *l == "1024x16")
            .unwrap()
            .2;
        let (rp, rt, ra) =
            scaling::ratios(&scaling::COL_1024X16_45NM, &cus1024);
        println!(
            "\n45nm Table IV [2] vs measured custom 7nm 1024x16: \
             power {rp:.0}x  time {rt:.1}x  area {ra:.0}x \
             (paper: ~108x, ~1.4x, ~21x)"
        );
    }
    Ok(())
}

fn cmd_table2(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!(
            "tnn7 bench-table2 [--waves N] [--threads N] [--config FILE] \
             — regenerate Table II (prototype PPA + EDP) through the \
             flow API (both flavours run as a parallel sweep)"
        );
        return Ok(());
    }
    let mut cfg = load_config(args)?;
    if let Some(w) = args.opt("--waves")? {
        cfg.sim_waves = w.parse()?;
    }
    if let Some(t) = args.opt("--threads")? {
        let threads: usize = t.parse()?;
        if threads < 1 {
            anyhow::bail!("--threads must be >= 1, got {threads}");
        }
        cfg.sim_threads = threads;
    }
    args.finish()?;
    let paper = [
        (Flavor::Std, ColumnPpa { power_uw: 2540.0, time_ns: 24.14, area_mm2: 2.36 }),
        (Flavor::Custom, ColumnPpa { power_uw: 1690.0, time_ns: 19.15, area_mm2: 1.56 }),
    ];
    // One registry: both flavours share the one characterized library.
    let registry = TechRegistry::builtin();
    let data =
        Arc::new(Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed));
    // --threads parallelizes across the two flavours; each job
    // simulates single-threaded (no worker × wave-thread squaring).
    let mut job_cfg = cfg.clone();
    job_cfg.sim_threads = 1;
    let jobs: Vec<compare::SweepJob> = paper
        .iter()
        .map(|&(flavor, _)| {
            compare::SweepJob::of(Target::prototype(flavor), &job_cfg)
        })
        .collect();
    let sweep = compare::run_sweep(
        &jobs,
        &registry,
        &data,
        cfg.sim_threads.max(1),
    );
    let mut rows = Vec::new();
    let mut measured = Vec::new();
    for ((flavor, paper_ppa), res) in paper.into_iter().zip(sweep) {
        let r = res.report?;
        eprintln!(
            "  {flavor:?}: L1 col {:.2} uW, L2 col {:.2} uW",
            r.units[0].ppa.power_uw, r.units[1].ppa.power_uw
        );
        rows.push(PpaRow {
            flavor: flavor.label(),
            label: "prototype".into(),
            ppa: r.total,
            paper: Some(paper_ppa),
        });
        measured.push(r.total);
    }
    println!("\nTable II — prototype PPA + EDP (measured vs paper)\n");
    println!("{}", render_table2(&rows));
    println!("{}", improvement_line(&measured[0], &measured[1]));
    let (rp, rt, ra) =
        scaling::ratios(&scaling::PROTOTYPE_45NM, &measured[0]);
    println!(
        "vs 45nm Table VI [2]: power {rp:.0}x  time {rt:.1}x  area {ra:.0}x \
         (paper: ~60x, ~2x, ~14x)"
    );
    Ok(())
}

fn cmd_simulate(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!(
            "tnn7 simulate --col PxQ [--flavor std|custom] [--waves N] \
             [--config FILE] — measure one column through the flow"
        );
        return Ok(());
    }
    let col = args
        .opt("--col")?
        .ok_or_else(|| anyhow::anyhow!("--col PxQ required"))?;
    let flavor = match args.opt("--flavor")?.as_deref() {
        Some("custom") => Flavor::Custom,
        Some("std") | None => Flavor::Std,
        Some(o) => anyhow::bail!("unknown flavor {o}"),
    };
    let mut cfg = load_config(args)?;
    if let Some(w) = args.opt("--waves")? {
        cfg.sim_waves = w.parse()?;
    }
    args.finish()?;
    let (p, q) = parse_geometry(&col)?;
    let spec = ColumnSpec::benchmark(p, q);
    let r = flow::measure(Target::column(flavor, spec), &cfg)?;
    let u = &r.units[0];
    println!("column {col} ({flavor:?}, theta={})", spec.theta);
    println!("  cells        : {}", u.cells);
    println!("  transistors  : {}", u.transistors);
    println!("  min clock    : {:.1} ps", u.clock_ps);
    println!("  power        : {:.3} uW", u.ppa.power_uw);
    println!("  wave time    : {:.2} ns", u.ppa.time_ns);
    println!("  area         : {:.5} mm2", u.ppa.area_mm2);
    Ok(())
}

fn cmd_train(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!(
            "tnn7 train [--config FILE] [--samples N] [--check] \
             [--metrics-json FILE] — end-to-end HLO training + accuracy"
        );
        return Ok(());
    }
    let mut cfg = load_config(args)?;
    if let Some(n) = args.opt("--samples")? {
        cfg.train_samples = n.parse()?;
    }
    let check = args.flag("--check");
    let metrics_json = args.opt("--metrics-json")?;
    args.finish()?;
    let train = Dataset::generate(cfg.train_samples, cfg.data_seed);
    let test = Dataset::generate(cfg.test_samples, cfg.data_seed + 1);
    println!(
        "training 2-layer prototype on {} synthetic digits ...",
        train.len(),
    );
    let mut pipe = Pipeline::new(cfg)?;
    if check {
        println!("cross-checking one HLO batch against the golden model ...");
        pipe.cross_check_batch(&train.images[..pipe.batch()].to_vec())?;
        println!("  HLO == golden: OK");
    }
    let metrics = pipe.train(&train)?;
    let acc = pipe.evaluate(&test)?;
    println!(
        "batches {}  exec {:.1}s  wall {:.1}s  throughput {:.1} img/s",
        metrics.batches,
        metrics.exec_seconds,
        metrics.wall_seconds,
        metrics.images_per_sec()
    );
    println!(
        "test accuracy: {:.1}% on {} samples (paper: 93% on MNIST; \
         chance 10%)",
        acc * 100.0,
        (test.len() / pipe.batch()) * pipe.batch()
    );
    if let Some(path) = metrics_json {
        std::fs::write(&path, metrics.to_json().to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn help_serve() -> String {
    "tnn7 serve — flow-as-a-service HTTP daemon (DESIGN.md §11)

Keeps the characterized technology backends and the content-addressed
stage cache warm across requests: repeated design-point queries are
served entirely from cache, and changed queries re-run only the stages
whose inputs changed.

USAGE: tnn7 serve [OPTIONS]

OPTIONS:
  --addr HOST:PORT   bind address (default 127.0.0.1:7411; port 0
                     picks an ephemeral port and prints it)
  --threads N        worker threads, one request each (default 4)
  --queue N          bounded request queue depth; overflow answers
                     503 + Retry-After inline (default 64)
  --cache-dir DIR    add the disk cache tier rooted at DIR so warm
                     state survives daemon restarts (default: memory
                     tier only)
  --mem-entries N    memory-tier capacity in stage entries, LRU
                     (default 256)
  --config FILE      tnn7.toml ([serve] and [cache] sections supply
                     the same settings; CLI flags win)

HTTP API (one request per connection, JSON bodies):
  POST /flow      measure a design point, e.g.
                  {\"target\": \"custom\", \"col\": \"64x8\", \"waves\": 8}
                  response body = the report-stage artifact, plus
                  X-Tnn7-Cache: executed=N mem=N disk=N and
                  X-Tnn7-Dedup: leader|joined headers
  GET  /stats     request/cache/stage-timing counters (JSON view over
                  the same registry /metrics renders)
  GET  /metrics   Prometheus text exposition of every daemon counter,
                  gauge, and latency histogram (DESIGN.md §15)
  GET  /healthz   liveness probe
  POST /shutdown  drain queued requests, then exit
"
    .to_string()
}

fn cmd_serve(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!("{}", help_serve());
        return Ok(());
    }
    let addr = args.opt("--addr")?;
    let threads = args.opt("--threads")?;
    let queue = args.opt("--queue")?;
    let cache_dir = args.opt("--cache-dir")?;
    let mem_entries = args.opt("--mem-entries")?;
    let cfg = load_config(args)?;
    args.finish()?;

    let mut serve = ServeConfig::from_config(&cfg);
    if let Some(a) = addr {
        serve.addr = a;
    }
    if let Some(t) = threads {
        let t: usize = t.parse()?;
        if t < 1 {
            anyhow::bail!("--threads must be >= 1, got {t}");
        }
        serve.threads = t;
    }
    if let Some(q) = queue {
        let q: usize = q.parse()?;
        if q < 1 {
            anyhow::bail!("--queue must be >= 1, got {q}");
        }
        serve.queue = q;
    }
    if let Some(d) = cache_dir {
        serve.cache.dir = Some(d.into());
    }
    if let Some(m) = mem_entries {
        let m: usize = m.parse()?;
        if m < 1 {
            anyhow::bail!("--mem-entries must be >= 1, got {m}");
        }
        serve.cache.mem_entries = m;
    }

    let disk = serve
        .cache
        .dir
        .as_deref()
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "off".into());
    let handle = Server::spawn(serve.clone())?;
    println!("tnn7 serve listening on http://{}", handle.addr());
    println!(
        "  workers {}  queue {}  cache: {} mem entries, disk {}",
        serve.threads.max(1),
        serve.queue.max(1),
        serve.cache.mem_entries,
        disk
    );
    println!(
        "  POST /flow  GET /stats  GET /metrics  GET /healthz  \
         POST /shutdown"
    );
    handle.join();
    println!("tnn7 serve: drained and stopped");
    Ok(())
}

fn cmd_profile(args: &mut Args) -> anyhow::Result<()> {
    if args.help_requested() {
        println!(
            "tnn7 profile [--col PxQ | --proto] [--target F] [--waves N] \
             [--lanes N] [--threads N] [--engine E] [--top N] \
             [--trace FILE] [--config FILE] — run the measurement \
             pipeline with span tracing enabled and print the hot-span \
             self-time/total-time table (DESIGN.md §15); geometry \
             defaults to the 8x4 smoke column"
        );
        return Ok(());
    }
    let target_desc = args.opt("--target")?;
    let proto = args.flag("--proto");
    let col = args.opt("--col")?;
    let top: usize = match args.opt("--top")? {
        Some(t) => t.parse()?,
        None => 12,
    };
    let trace_out = args.opt("--trace")?;
    let mut cfg = load_config(args)?;
    if let Some(w) = args.opt("--waves")? {
        cfg.sim_waves = w.parse()?;
    }
    if let Some(l) = args.opt("--lanes")? {
        let lanes: usize = l.parse()?;
        if !(1..=64).contains(&lanes) {
            anyhow::bail!("--lanes must be in 1..=64, got {lanes}");
        }
        cfg.sim_lanes = lanes;
    }
    if let Some(t) = args.opt("--threads")? {
        let threads: usize = t.parse()?;
        if threads < 1 {
            anyhow::bail!("--threads must be >= 1, got {threads}");
        }
        cfg.sim_threads = threads;
    }
    if let Some(e) = args.opt("--engine")? {
        cfg.sim_engine = e;
        cfg.validate_engine()?;
    }
    args.finish()?;
    if proto && col.is_some() {
        anyhow::bail!("--proto and --col are mutually exclusive");
    }
    let geometry = if proto {
        Geometry::Prototype(PrototypeSpec::paper())
    } else if let Some(col) = col {
        let (p, q) = parse_geometry(&col)?;
        Geometry::Column(ColumnSpec::benchmark(p, q))
    } else {
        Geometry::Column(ColumnSpec::benchmark(8, 4))
    };
    let mut registry = TechRegistry::builtin();
    let base =
        Target::parse(target_desc.as_deref().unwrap_or("std"), geometry)?;
    let techctx = registry.resolve(base.tech.as_str())?;
    let target = base.with_tech(techctx.id());
    let data =
        Arc::new(Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed));
    tnn7::obs::set_tracing(true);
    let mut ctx = FlowContext::with_tech(
        target,
        cfg.clone(),
        techctx.clone(),
        Arc::clone(&data),
    );
    println!(
        "profiling flow {} [{}] ...\n",
        ctx.target.describe(),
        techctx.node_label()
    );
    Flow::standard().run(&mut ctx)?;
    let spans = tnn7::obs::take_spans();
    let rows = tnn7::obs::profile(&spans);
    print!("{}", tnn7::obs::profile_table(&rows, top));
    if let Some(path) = &trace_out {
        std::fs::write(
            path,
            tnn7::obs::chrome_trace(&spans).to_string_pretty(),
        )?;
        println!("\nwrote {} spans to {path}", spans.len());
    }
    Ok(())
}
