//! `tnn7` — CLI for the 7nm TNN co-design framework.
//!
//! Subcommands map one-to-one onto the paper's artifacts (see DESIGN.md
//! §4 for the experiment index):
//!
//! ```text
//! tnn7 characterize [--lib FILE]      cell library table (+ .lib dump)
//! tnn7 layout-cmp [MACRO]             Figs. 14-18 structural comparisons
//! tnn7 complexity                     Fig. 19 gate/transistor census
//! tnn7 calibrate                      fit technology constants (DESIGN §5)
//! tnn7 bench-table1 [--with-45nm]     Table I (3 columns × 2 flavours)
//! tnn7 bench-table2                   Table II (prototype PPA + EDP)
//! tnn7 simulate --col PxQ [...]       gate-sim one column, report PPA
//! tnn7 train [--config FILE]          end-to-end HLO training + accuracy
//! ```

use std::path::Path;
use std::process::ExitCode;

use tnn7::cells::{calibrate, liberty, Library, TechParams};
use tnn7::config::TnnConfig;
use tnn7::coordinator::measure::{
    measure_column, parse_geometry, prototype_ppa, table1_specs,
};
use tnn7::coordinator::Pipeline;
use tnn7::data::Dataset;
use tnn7::netlist::column::ColumnSpec;
use tnn7::netlist::prototype::PrototypeSpec;
use tnn7::netlist::Flavor;
use tnn7::ppa::report::{improvement_line, render_table1, render_table2, PpaRow};
use tnn7::ppa::scaling;
use tnn7::ppa::ColumnPpa;

/// Tiny argv helper (no clap offline): `--key value` and flags.
struct Args {
    rest: Vec<String>,
}

impl Args {
    fn new() -> Self {
        Args { rest: std::env::args().skip(1).collect() }
    }

    fn subcommand(&mut self) -> Option<String> {
        if self.rest.is_empty() || self.rest[0].starts_with('-') {
            None
        } else {
            Some(self.rest.remove(0))
        }
    }

    fn flag(&mut self, name: &str) -> bool {
        if let Some(i) = self.rest.iter().position(|a| a == name) {
            self.rest.remove(i);
            true
        } else {
            false
        }
    }

    fn opt(&mut self, name: &str) -> Option<String> {
        let i = self.rest.iter().position(|a| a == name)?;
        if i + 1 >= self.rest.len() {
            eprintln!("{name} requires a value");
            std::process::exit(2);
        }
        self.rest.remove(i);
        Some(self.rest.remove(i))
    }

    fn positional(&mut self) -> Option<String> {
        self.subcommand()
    }

    fn finish(&self) -> anyhow::Result<()> {
        if self.rest.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unrecognized arguments: {:?}", self.rest)
        }
    }
}

fn load_config(args: &mut Args) -> anyhow::Result<TnnConfig> {
    match args.opt("--config") {
        Some(path) => Ok(TnnConfig::load(Path::new(&path))?),
        None => Ok(TnnConfig::default()),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::new();
    let sub = args.subcommand().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "characterize" => cmd_characterize(&mut args),
        "layout-cmp" => cmd_layout_cmp(&mut args),
        "complexity" => cmd_complexity(&mut args),
        "calibrate" => cmd_calibrate(&mut args),
        "bench-table1" => cmd_table1(&mut args),
        "bench-table2" => cmd_table2(&mut args),
        "simulate" => cmd_simulate(&mut args),
        "train" => cmd_train(&mut args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand `{other}` (try help)"),
    }
}

const HELP: &str = "tnn7 — 7nm TNN co-design framework (paper reproduction)

USAGE: tnn7 <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
  characterize [--lib FILE]   print the characterized cell library
  layout-cmp [MACRO]          Figs. 14-18 custom-vs-std cell comparisons
  complexity                  Fig. 19 prototype census (gates/transistors)
  calibrate                   fit the technology constants (DESIGN.md §5)
  bench-table1 [--with-45nm] [--waves N]   regenerate Table I
  bench-table2 [--waves N]                 regenerate Table II
  simulate --col PxQ [--flavor std|custom] [--waves N]
  train [--config FILE] [--samples N] [--check]
";

fn cmd_characterize(args: &mut Args) -> anyhow::Result<()> {
    let lib_out = args.opt("--lib");
    args.finish()?;
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    println!(
        "{:<20} {:>6} {:>10} {:>10} {:>10} {:>9}  macro",
        "cell", "T", "area um2", "energy fJ", "leak nW", "delay ps"
    );
    for c in lib.cells() {
        println!(
            "{:<20} {:>6} {:>10.4} {:>10.4} {:>10.4} {:>9.1}  {}",
            c.name,
            c.transistors,
            tech.area_um2(c),
            tech.energy_fj(c),
            tech.leak_nw(c),
            tech.delay_ps(c),
            if c.is_custom_macro { "*" } else { "" }
        );
    }
    if let Some(path) = lib_out {
        let text = liberty::emit(&lib, &tech, "tnn7_rvt_tt_0p7v_25c");
        std::fs::write(&path, text)?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_layout_cmp(args: &mut Args) -> anyhow::Result<()> {
    let which = args.positional();
    args.finish()?;
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    let rows: Vec<(&str, &str, &str)> = vec![
        // (figure, function, custom macro cell)
        ("Fig. 14/15", "less_equal", "less_equal"),
        ("Fig. 16/17", "mux2to1", "mux2to1gdi"),
        ("Fig. 18", "stabilize_func", "stabilize_func"),
    ];
    println!(
        "{:<12} {:<16} {:>8} {:>8} {:>12} {:>12}",
        "figure", "function", "std T", "custom T", "std um2", "custom um2"
    );
    for (fig, func, cell) in rows {
        if let Some(w) = &which {
            if w != func && w != cell {
                continue;
            }
        }
        let (std_t, _desc) = tnn7::cells::gdi::cmos_reference(func)
            .ok_or_else(|| anyhow::anyhow!("no reference for {func}"))?;
        let c = lib.cell(lib.id(cell)?);
        let std_area = f64::from(std_t) * tech.area_per_unit_um2;
        println!(
            "{:<12} {:<16} {:>8} {:>8} {:>12.4} {:>12.4}",
            fig,
            func,
            std_t,
            c.transistors,
            std_area,
            tech.area_um2(c)
        );
    }
    Ok(())
}

fn cmd_complexity(args: &mut Args) -> anyhow::Result<()> {
    args.finish()?;
    let lib = Library::with_macros();
    let spec = PrototypeSpec::paper();
    println!(
        "Fig. 19 prototype: {} neurons, {} synapses (paper: 13,750 / 315,000)",
        spec.neurons(),
        spec.synapses()
    );
    for flavor in [Flavor::Std, Flavor::Custom] {
        let m = tnn7::netlist::prototype::PrototypeModel::build(
            &lib, flavor, spec,
        )?;
        let c = m.census(&lib);
        println!(
            "{:<22} {:>12} cells {:>13} transistors (paper: 32M gates / 128M T)",
            flavor.label(),
            c.cells,
            c.transistors
        );
    }
    Ok(())
}

fn cmd_calibrate(args: &mut Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    args.finish()?;
    let lib = Library::with_macros();
    let data = Dataset::generate(16, cfg.data_seed);
    println!("evaluating Table-I std columns in relative units ...");
    let obs = tnn7::coordinator::measure::calibration_observations(
        &lib, &cfg, &data,
    )?;
    let fit = calibrate::fit(&obs);
    println!("fitted technology constants:");
    println!("  area_per_unit_um2  = {:.4e}", fit.tech.area_per_unit_um2);
    println!("  energy_per_unit_fj = {:.4e}", fit.tech.energy_per_unit_fj);
    println!("  leak_per_unit_nw   = {:.4e}", fit.tech.leak_per_unit_nw);
    println!("  fo4_ps             = {:.4}", fit.tech.fo4_ps);
    println!(
        "rms relative residuals: area {:.1}%  time {:.1}%  power {:.1}%",
        fit.resid_area * 100.0,
        fit.resid_time * 100.0,
        fit.resid_power * 100.0
    );
    println!(
        "\n(current TechParams::calibrated(): {:?})",
        TechParams::calibrated()
    );
    Ok(())
}

/// Paper Table I values for side-by-side display.
fn paper_table1(flavor: Flavor, label: &str) -> Option<ColumnPpa> {
    let v = match (flavor, label) {
        (Flavor::Std, "64x8") => (3.89, 26.92, 0.004),
        (Flavor::Std, "128x10") => (10.27, 28.52, 0.009),
        (Flavor::Std, "1024x16") => (131.46, 36.52, 0.124),
        (Flavor::Custom, "64x8") => (2.73, 20.59, 0.003),
        (Flavor::Custom, "128x10") => (5.76, 22.79, 0.006),
        (Flavor::Custom, "1024x16") => (73.73, 29.49, 0.079),
        _ => return None,
    };
    Some(ColumnPpa { power_uw: v.0, time_ns: v.1, area_mm2: v.2 })
}

fn cmd_table1(args: &mut Args) -> anyhow::Result<()> {
    let with_45 = args.flag("--with-45nm");
    let mut cfg = load_config(args)?;
    if let Some(w) = args.opt("--waves") {
        cfg.sim_waves = w.parse()?;
    }
    args.finish()?;
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    let data = Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed);
    let mut rows = Vec::new();
    let mut pairs = Vec::new();
    for flavor in [Flavor::Std, Flavor::Custom] {
        for (label, spec) in table1_specs() {
            let m = measure_column(&lib, &tech, flavor, &spec, &cfg, &data)?;
            rows.push(PpaRow {
                flavor: flavor.label(),
                label: label.to_string(),
                ppa: m.ppa,
                paper: paper_table1(flavor, label),
            });
            pairs.push((flavor, label, m.ppa));
            eprintln!("  measured {flavor:?} {label}");
        }
    }
    println!("\nTable I — standard vs custom PPA, 7nm (measured vs paper)\n");
    println!("{}", render_table1(&rows));
    for (label, _) in table1_specs().iter() {
        let std = pairs
            .iter()
            .find(|(f, l, _)| *f == Flavor::Std && l == label)
            .unwrap()
            .2;
        let cus = pairs
            .iter()
            .find(|(f, l, _)| *f == Flavor::Custom && l == label)
            .unwrap()
            .2;
        println!("{label:>9}: {}", improvement_line(&std, &cus));
    }
    if with_45 {
        let cus1024 = pairs
            .iter()
            .find(|(f, l, _)| *f == Flavor::Custom && *l == "1024x16")
            .unwrap()
            .2;
        let (rp, rt, ra) =
            scaling::ratios(&scaling::COL_1024X16_45NM, &cus1024);
        println!(
            "\n45nm Table IV [2] vs measured custom 7nm 1024x16: \
             power {rp:.0}x  time {rt:.1}x  area {ra:.0}x \
             (paper: ~108x, ~1.4x, ~21x)"
        );
    }
    Ok(())
}

fn cmd_table2(args: &mut Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(w) = args.opt("--waves") {
        cfg.sim_waves = w.parse()?;
    }
    args.finish()?;
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    let data = Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed);
    let paper = [
        (Flavor::Std, ColumnPpa { power_uw: 2540.0, time_ns: 24.14, area_mm2: 2.36 }),
        (Flavor::Custom, ColumnPpa { power_uw: 1690.0, time_ns: 19.15, area_mm2: 1.56 }),
    ];
    let mut rows = Vec::new();
    let mut measured = Vec::new();
    for (flavor, paper_ppa) in paper {
        let (total, m1, m2) = prototype_ppa(&lib, &tech, flavor, &cfg, &data)?;
        eprintln!(
            "  {flavor:?}: L1 col {:.2} uW, L2 col {:.2} uW",
            m1.ppa.power_uw, m2.ppa.power_uw
        );
        rows.push(PpaRow {
            flavor: flavor.label(),
            label: "prototype".into(),
            ppa: total,
            paper: Some(paper_ppa),
        });
        measured.push(total);
    }
    println!("\nTable II — prototype PPA + EDP (measured vs paper)\n");
    println!("{}", render_table2(&rows));
    println!("{}", improvement_line(&measured[0], &measured[1]));
    let (rp, rt, ra) =
        scaling::ratios(&scaling::PROTOTYPE_45NM, &measured[0]);
    println!(
        "vs 45nm Table VI [2]: power {rp:.0}x  time {rt:.1}x  area {ra:.0}x \
         (paper: ~60x, ~2x, ~14x)"
    );
    Ok(())
}

fn cmd_simulate(args: &mut Args) -> anyhow::Result<()> {
    let col = args
        .opt("--col")
        .ok_or_else(|| anyhow::anyhow!("--col PxQ required"))?;
    let flavor = match args.opt("--flavor").as_deref() {
        Some("custom") => Flavor::Custom,
        Some("std") | None => Flavor::Std,
        Some(o) => anyhow::bail!("unknown flavor {o}"),
    };
    let mut cfg = load_config(args)?;
    if let Some(w) = args.opt("--waves") {
        cfg.sim_waves = w.parse()?;
    }
    args.finish()?;
    let (p, q) = parse_geometry(&col);
    let spec = ColumnSpec::benchmark(p, q);
    let lib = Library::with_macros();
    let tech = TechParams::calibrated();
    let data = Dataset::generate(cfg.sim_waves.max(4), cfg.data_seed);
    let m = measure_column(&lib, &tech, flavor, &spec, &cfg, &data)?;
    println!("column {col} ({flavor:?}, theta={})", spec.theta);
    println!("  cells        : {}", m.cells);
    println!("  transistors  : {}", m.transistors);
    println!("  min clock    : {:.1} ps", m.clock_ps);
    println!("  power        : {:.3} uW", m.ppa.power_uw);
    println!("  wave time    : {:.2} ns", m.ppa.time_ns);
    println!("  area         : {:.5} mm2", m.ppa.area_mm2);
    Ok(())
}

fn cmd_train(args: &mut Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(n) = args.opt("--samples") {
        cfg.train_samples = n.parse()?;
    }
    let check = args.flag("--check");
    args.finish()?;
    let train = Dataset::generate(cfg.train_samples, cfg.data_seed);
    let test = Dataset::generate(cfg.test_samples, cfg.data_seed + 1);
    println!(
        "training 2-layer prototype on {} synthetic digits ...",
        train.len(),
    );
    let mut pipe = Pipeline::new(cfg)?;
    if check {
        println!("cross-checking one HLO batch against the golden model ...");
        pipe.cross_check_batch(&train.images[..pipe.batch()].to_vec())?;
        println!("  HLO == golden: OK");
    }
    let metrics = pipe.train(&train)?;
    let acc = pipe.evaluate(&test)?;
    println!(
        "batches {}  exec {:.1}s  wall {:.1}s  throughput {:.1} img/s",
        metrics.batches,
        metrics.exec_seconds,
        metrics.wall_seconds,
        metrics.images_per_sec()
    );
    println!(
        "test accuracy: {:.1}% on {} samples (paper: 93% on MNIST; \
         chance 10%)",
        acc * 100.0,
        (test.len() / pipe.batch()) * pipe.batch()
    );
    Ok(())
}
