//! TOML-subset configuration system.
//!
//! The framework is configured by a `tnn7.toml` file (`tnn7 --config`).
//! The vendored offline dependency set has no `toml` crate, so a small
//! parser for the subset we use is implemented here: `[section]` headers,
//! `key = value` with string / integer / float / boolean values, `#`
//! comments.  Unknown keys are rejected (typo safety).

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// Parsed raw TOML subset: section → key → value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Toml {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

/// A TOML scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    fn parse(raw: &str) -> Result<Value> {
        let t = raw.trim();
        if let Some(s) = t.strip_prefix('"').and_then(|r| r.strip_suffix('"')) {
            return Ok(Value::Str(s.to_string()));
        }
        if t == "true" {
            return Ok(Value::Bool(true));
        }
        if t == "false" {
            return Ok(Value::Bool(false));
        }
        if let Ok(i) = t.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = t.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(Error::config(format!("unparsable value `{t}`")))
    }
}

impl Toml {
    /// Parse the subset grammar.
    pub fn parse(text: &str) -> Result<Toml> {
        let mut out = Toml::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            // Strip a '#' comment unless it sits inside a quoted string
            // (i.e. an odd number of '"' precede it).
            let line = match raw.find('#') {
                Some(i)
                    if raw[..i].matches('"').count() % 2 == 0 =>
                {
                    &raw[..i]
                }
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) =
                line.strip_prefix('[').and_then(|r| r.strip_suffix(']'))
            {
                section = name.trim().to_string();
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::config(format!("line {}: expected key = value", lineno + 1))
            })?;
            out.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), Value::parse(v)?);
        }
        Ok(out)
    }

    fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    fn take_known(&self, known: &[(&str, &[&str])]) -> Result<()> {
        for (sec, keys) in self.sections.iter().map(|(s, m)| {
            (s.as_str(), m.keys().map(|k| k.as_str()).collect::<Vec<_>>())
        }) {
            let allowed = known
                .iter()
                .find(|(s, _)| *s == sec)
                .map(|(_, k)| *k)
                .ok_or_else(|| Error::config(format!("unknown section [{sec}]")))?;
            for k in keys {
                if !allowed.contains(&k) {
                    return Err(Error::config(format!(
                        "unknown key `{k}` in [{sec}]"
                    )));
                }
            }
        }
        Ok(())
    }
}

/// Framework configuration (defaults reproduce the paper's setup).
#[derive(Debug, Clone, PartialEq)]
pub struct TnnConfig {
    /// Directory holding the AOT artifacts + manifest.
    pub artifacts_dir: String,
    /// Layer-1 firing threshold.
    pub theta1: i32,
    /// Layer-2 firing threshold.
    pub theta2: i32,
    /// Initial synaptic weight.
    pub w_init: i32,
    /// Training samples.
    pub train_samples: usize,
    /// Test samples.
    pub test_samples: usize,
    /// Dataset seed.
    pub data_seed: u64,
    /// LFSR seed for BRVs.
    pub brv_seed: u16,
    /// Encoder threshold.
    pub encode_threshold: f64,
    /// STDP probabilities.
    pub mu_capture: f64,
    pub mu_backoff: f64,
    pub mu_search: f64,
    /// Gate-level simulation waves per Table-I measurement.
    pub sim_waves: usize,
    /// Stimulus lanes per simulator tick (1 = scalar reference engine,
    /// 2..=64 = word-packed engine; see DESIGN.md §7).
    pub sim_lanes: usize,
    /// Worker threads for the `simulate` stage's packed wave schedule
    /// and for parallel target sweeps (1 = serial; DESIGN.md §8).
    /// Thread count never changes measured activity — only wall time.
    pub sim_threads: usize,
    /// Simulation engine for the `simulate`/`faults` wave schedules:
    /// `auto` (interpreter selection by lanes/threads), `scalar`,
    /// `packed`, or `compiled` (optimized tape; DESIGN.md §14).
    /// Engine choice never changes results — only wall time.
    pub sim_engine: String,
    /// IR pass pipeline for the compiled engine: `all`, `none`, or a
    /// comma-separated ordered subset of
    /// `fold`, `dce`, `coalesce`, `resched`.
    pub sim_passes: String,
    /// Run the physical-design `place` stage (floorplan + placement +
    /// wire-aware PPA; `tnn7 flow --place`, DESIGN.md §10).
    pub place: bool,
    /// Floorplan target utilization in (0, 1].
    pub place_util: f64,
    /// Floorplan aspect ratio (width / height), > 0.
    pub place_aspect: f64,
    /// Placement RNG seed — same seed ⇒ bit-identical placement.
    pub place_seed: u64,
    /// Run the fault-injection `faults` stage (`tnn7 flow --faults`,
    /// DESIGN.md §13).
    pub faults: bool,
    /// Fault classes to sweep, comma-separated
    /// ([`crate::fault::FaultClass::parse`] tokens).
    pub faults_classes: String,
    /// Fault rates to sweep, comma-separated non-negative floats.
    pub faults_rates: String,
    /// Campaign sampling seeds, comma-separated unsigned integers.
    pub faults_seeds: String,
    /// `tnn7 serve` bind address.
    pub serve_addr: String,
    /// Daemon worker threads (each runs one flow at a time).
    pub serve_threads: usize,
    /// Bounded request queue depth; overflow answers 503.
    pub serve_queue: usize,
    /// Enable the content-addressed stage cache for batch `tnn7 flow`
    /// runs (the daemon always caches; DESIGN.md §11).
    pub cache_enabled: bool,
    /// Disk tier directory ("" = memory tier only).
    pub cache_dir: String,
    /// Memory-tier capacity in stage snapshots (LRU beyond this).
    pub cache_mem_entries: usize,
}

impl Default for TnnConfig {
    fn default() -> Self {
        TnnConfig {
            artifacts_dir: "artifacts".into(),
            theta1: 20,
            theta2: 2,
            w_init: 3,
            train_samples: 600,
            test_samples: 200,
            data_seed: 2020,
            brv_seed: 0xACE1,
            encode_threshold: 0.04,
            mu_capture: 0.9,
            mu_backoff: 0.5,
            mu_search: 0.05,
            sim_waves: 8,
            sim_lanes: 1,
            sim_threads: 1,
            sim_engine: "auto".into(),
            sim_passes: "all".into(),
            place: false,
            place_util: 0.70,
            place_aspect: 1.0,
            place_seed: 1,
            faults: false,
            faults_classes: "stuck0,stuck1,seu".into(),
            faults_rates: "0,0.02".into(),
            faults_seeds: "1".into(),
            serve_addr: "127.0.0.1:7411".into(),
            serve_threads: 4,
            serve_queue: 64,
            cache_enabled: false,
            cache_dir: String::new(),
            cache_mem_entries: 256,
        }
    }
}

impl TnnConfig {
    /// Load from a TOML file (missing keys fall back to defaults).
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let t = Toml::parse(text)?;
        t.take_known(&[
            ("paths", &["artifacts_dir"]),
            (
                "network",
                &["theta1", "theta2", "w_init", "encode_threshold"],
            ),
            (
                "training",
                &[
                    "train_samples",
                    "test_samples",
                    "data_seed",
                    "brv_seed",
                    "mu_capture",
                    "mu_backoff",
                    "mu_search",
                ],
            ),
            (
                "sim",
                &[
                    "sim_waves",
                    "sim_lanes",
                    "sim_threads",
                    "sim_engine",
                    "sim_passes",
                ],
            ),
            (
                "place",
                &["enabled", "utilization", "aspect", "seed"],
            ),
            (
                "faults",
                &["enabled", "classes", "rates", "seeds"],
            ),
            ("serve", &["addr", "threads", "queue"]),
            ("cache", &["enabled", "dir", "mem_entries"]),
        ])?;
        let mut c = TnnConfig::default();
        let geti = |v: &Value| -> Result<i64> {
            match v {
                Value::Int(i) => Ok(*i),
                _ => Err(Error::config("expected integer")),
            }
        };
        let getf = |v: &Value| -> Result<f64> {
            match v {
                Value::Float(f) => Ok(*f),
                Value::Int(i) => Ok(*i as f64),
                _ => Err(Error::config("expected float")),
            }
        };
        if let Some(v) = t.get("paths", "artifacts_dir") {
            match v {
                Value::Str(s) => c.artifacts_dir = s.clone(),
                _ => return Err(Error::config("artifacts_dir must be a string")),
            }
        }
        if let Some(v) = t.get("network", "theta1") {
            c.theta1 = geti(v)? as i32;
        }
        if let Some(v) = t.get("network", "theta2") {
            c.theta2 = geti(v)? as i32;
        }
        if let Some(v) = t.get("network", "w_init") {
            c.w_init = geti(v)? as i32;
        }
        if let Some(v) = t.get("network", "encode_threshold") {
            c.encode_threshold = getf(v)?;
        }
        if let Some(v) = t.get("training", "train_samples") {
            c.train_samples = geti(v)? as usize;
        }
        if let Some(v) = t.get("training", "test_samples") {
            c.test_samples = geti(v)? as usize;
        }
        if let Some(v) = t.get("training", "data_seed") {
            c.data_seed = geti(v)? as u64;
        }
        if let Some(v) = t.get("training", "brv_seed") {
            c.brv_seed = geti(v)? as u16;
        }
        if let Some(v) = t.get("training", "mu_capture") {
            c.mu_capture = getf(v)?;
        }
        if let Some(v) = t.get("training", "mu_backoff") {
            c.mu_backoff = getf(v)?;
        }
        if let Some(v) = t.get("training", "mu_search") {
            c.mu_search = getf(v)?;
        }
        if let Some(v) = t.get("sim", "sim_waves") {
            c.sim_waves = geti(v)? as usize;
        }
        if let Some(v) = t.get("sim", "sim_lanes") {
            let lanes = geti(v)?;
            if !(1..=64).contains(&lanes) {
                return Err(Error::config(format!(
                    "sim_lanes must be in 1..=64, got {lanes}"
                )));
            }
            c.sim_lanes = lanes as usize;
        }
        if let Some(v) = t.get("sim", "sim_threads") {
            let threads = geti(v)?;
            if threads < 1 {
                return Err(Error::config(format!(
                    "sim_threads must be >= 1, got {threads}"
                )));
            }
            c.sim_threads = threads as usize;
        }
        if let Some(v) = t.get("sim", "sim_engine") {
            match v {
                Value::Str(s) => c.sim_engine = s.clone(),
                _ => {
                    return Err(Error::config(
                        "sim_engine must be a string",
                    ))
                }
            }
        }
        if let Some(v) = t.get("sim", "sim_passes") {
            match v {
                Value::Str(s) => c.sim_passes = s.clone(),
                _ => {
                    return Err(Error::config(
                        "sim_passes must be a string",
                    ))
                }
            }
        }
        // Validate engine/pipeline tokens up front — a typo should
        // fail at config load, not mid-flow.
        c.validate_engine()?;
        c.pass_manager()?;
        if let Some(v) = t.get("place", "enabled") {
            match v {
                Value::Bool(b) => c.place = *b,
                _ => {
                    return Err(Error::config(
                        "place.enabled must be a boolean",
                    ))
                }
            }
        }
        if let Some(v) = t.get("place", "utilization") {
            let u = getf(v)?;
            if !(u > 0.0 && u <= 1.0) {
                return Err(Error::config(format!(
                    "place.utilization must be in (0, 1], got {u}"
                )));
            }
            c.place_util = u;
        }
        if let Some(v) = t.get("place", "aspect") {
            let a = getf(v)?;
            if !(a > 0.0 && a.is_finite()) {
                return Err(Error::config(format!(
                    "place.aspect must be positive, got {a}"
                )));
            }
            c.place_aspect = a;
        }
        if let Some(v) = t.get("place", "seed") {
            let s = geti(v)?;
            if s < 0 {
                return Err(Error::config(format!(
                    "place.seed must be non-negative, got {s}"
                )));
            }
            c.place_seed = s as u64;
        }
        if let Some(v) = t.get("faults", "enabled") {
            match v {
                Value::Bool(b) => c.faults = *b,
                _ => {
                    return Err(Error::config(
                        "faults.enabled must be a boolean",
                    ))
                }
            }
        }
        for (key, field) in [
            ("classes", &mut c.faults_classes as &mut String),
            ("rates", &mut c.faults_rates),
            ("seeds", &mut c.faults_seeds),
        ] {
            if let Some(v) = t.get("faults", key) {
                match v {
                    Value::Str(s) => *field = s.clone(),
                    _ => {
                        return Err(Error::config(format!(
                            "faults.{key} must be a string"
                        )))
                    }
                }
            }
        }
        // Validate the campaign grammar up front — a bad class token
        // should fail at config load, not mid-flow.
        c.fault_spec()?;
        if let Some(v) = t.get("serve", "addr") {
            match v {
                Value::Str(s) => c.serve_addr = s.clone(),
                _ => {
                    return Err(Error::config(
                        "serve.addr must be a string",
                    ))
                }
            }
        }
        if let Some(v) = t.get("serve", "threads") {
            let n = geti(v)?;
            if n < 1 {
                return Err(Error::config(format!(
                    "serve.threads must be >= 1, got {n}"
                )));
            }
            c.serve_threads = n as usize;
        }
        if let Some(v) = t.get("serve", "queue") {
            let n = geti(v)?;
            if n < 1 {
                return Err(Error::config(format!(
                    "serve.queue must be >= 1, got {n}"
                )));
            }
            c.serve_queue = n as usize;
        }
        if let Some(v) = t.get("cache", "enabled") {
            match v {
                Value::Bool(b) => c.cache_enabled = *b,
                _ => {
                    return Err(Error::config(
                        "cache.enabled must be a boolean",
                    ))
                }
            }
        }
        if let Some(v) = t.get("cache", "dir") {
            match v {
                Value::Str(s) => c.cache_dir = s.clone(),
                _ => {
                    return Err(Error::config(
                        "cache.dir must be a string",
                    ))
                }
            }
        }
        if let Some(v) = t.get("cache", "mem_entries") {
            let n = geti(v)?;
            if n < 1 {
                return Err(Error::config(format!(
                    "cache.mem_entries must be >= 1, got {n}"
                )));
            }
            c.cache_mem_entries = n as usize;
        }
        Ok(c)
    }

    /// Validate the `sim_engine` token.
    pub fn validate_engine(&self) -> Result<()> {
        match self.sim_engine.as_str() {
            "auto" | "scalar" | "packed" | "compiled" => Ok(()),
            other => Err(Error::config(format!(
                "sim_engine must be one of auto, scalar, packed, \
                 compiled — got `{other}`"
            ))),
        }
    }

    /// Pass pipeline parsed from `sim_passes`.
    pub fn pass_manager(&self) -> Result<crate::ir::PassManager> {
        crate::ir::PassManager::parse(&self.sim_passes)
    }

    /// Campaign grid parsed from the `[faults]` class/rate/seed lists.
    pub fn fault_spec(&self) -> Result<crate::fault::CampaignSpec> {
        crate::fault::CampaignSpec::parse(
            &self.faults_classes,
            &self.faults_rates,
            &self.faults_seeds,
        )
    }

    /// STDP parameters from the configured probabilities.
    pub fn stdp_params(&self) -> crate::tnn::StdpParams {
        crate::tnn::StdpParams::from_probs(
            self.mu_capture,
            self.mu_backoff,
            self.mu_search,
            [1.0, 1.0, 0.75, 0.5, 0.5, 0.25, 0.25, 0.125],
            [0.125, 0.25, 0.25, 0.5, 0.5, 0.75, 1.0, 1.0],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_round_trip_empty_toml() {
        let c = TnnConfig::from_toml("").unwrap();
        assert_eq!(c, TnnConfig::default());
    }

    #[test]
    fn parses_all_sections() {
        let text = r#"
# comment
[paths]
artifacts_dir = "my_artifacts"   # trailing comment

[network]
theta1 = 40
theta2 = 16
encode_threshold = 0.08

[training]
train_samples = 100
mu_capture = 0.75

[sim]
sim_waves = 3
sim_lanes = 16
sim_threads = 4
"#;
        let c = TnnConfig::from_toml(text).unwrap();
        assert_eq!(c.artifacts_dir, "my_artifacts");
        assert_eq!(c.theta1, 40);
        assert_eq!(c.theta2, 16);
        assert!((c.encode_threshold - 0.08).abs() < 1e-12);
        assert_eq!(c.train_samples, 100);
        assert!((c.mu_capture - 0.75).abs() < 1e-12);
        assert_eq!(c.sim_waves, 3);
        assert_eq!(c.sim_lanes, 16);
        assert_eq!(c.sim_threads, 4);
        // untouched defaults survive
        assert_eq!(c.test_samples, TnnConfig::default().test_samples);
    }

    #[test]
    fn rejects_out_of_range_threads() {
        assert!(TnnConfig::from_toml("[sim]\nsim_threads = 0").is_err());
        assert!(TnnConfig::from_toml("[sim]\nsim_threads = -3").is_err());
        let c = TnnConfig::from_toml("[sim]\nsim_threads = 8").unwrap();
        assert_eq!(c.sim_threads, 8);
    }

    #[test]
    fn rejects_out_of_range_lanes() {
        assert!(TnnConfig::from_toml("[sim]\nsim_lanes = 0").is_err());
        assert!(TnnConfig::from_toml("[sim]\nsim_lanes = 65").is_err());
        let c = TnnConfig::from_toml("[sim]\nsim_lanes = 64").unwrap();
        assert_eq!(c.sim_lanes, 64);
    }

    #[test]
    fn parses_and_validates_place_section() {
        let c = TnnConfig::from_toml(
            "[place]\nenabled = true\nutilization = 0.6\naspect = 2.0\nseed = 9",
        )
        .unwrap();
        assert!(c.place);
        assert!((c.place_util - 0.6).abs() < 1e-12);
        assert!((c.place_aspect - 2.0).abs() < 1e-12);
        assert_eq!(c.place_seed, 9);
        // Defaults: place off, util 0.70, square die.
        let d = TnnConfig::default();
        assert!(!d.place);
        assert!((d.place_util - 0.70).abs() < 1e-12);
        assert!((d.place_aspect - 1.0).abs() < 1e-12);
        // Out-of-range values are rejected.
        assert!(
            TnnConfig::from_toml("[place]\nutilization = 0.0").is_err()
        );
        assert!(
            TnnConfig::from_toml("[place]\nutilization = 1.5").is_err()
        );
        assert!(TnnConfig::from_toml("[place]\naspect = -1.0").is_err());
        assert!(TnnConfig::from_toml("[place]\nseed = -4").is_err());
        assert!(TnnConfig::from_toml("[place]\nenabled = 3").is_err());
    }

    #[test]
    fn parses_and_validates_serve_and_cache_sections() {
        let c = TnnConfig::from_toml(
            "[serve]\naddr = \"0.0.0.0:8080\"\nthreads = 2\nqueue = 16\n\
             [cache]\nenabled = true\ndir = \"/tmp/tnn7-cache\"\nmem_entries = 32",
        )
        .unwrap();
        assert_eq!(c.serve_addr, "0.0.0.0:8080");
        assert_eq!(c.serve_threads, 2);
        assert_eq!(c.serve_queue, 16);
        assert!(c.cache_enabled);
        assert_eq!(c.cache_dir, "/tmp/tnn7-cache");
        assert_eq!(c.cache_mem_entries, 32);
        // Defaults: local bind, cache off, memory tier only.
        let d = TnnConfig::default();
        assert_eq!(d.serve_addr, "127.0.0.1:7411");
        assert_eq!(d.serve_threads, 4);
        assert_eq!(d.serve_queue, 64);
        assert!(!d.cache_enabled);
        assert!(d.cache_dir.is_empty());
        assert_eq!(d.cache_mem_entries, 256);
        // Out-of-range values are rejected.
        assert!(TnnConfig::from_toml("[serve]\nthreads = 0").is_err());
        assert!(TnnConfig::from_toml("[serve]\nqueue = 0").is_err());
        assert!(TnnConfig::from_toml("[serve]\naddr = 7411").is_err());
        assert!(
            TnnConfig::from_toml("[cache]\nmem_entries = 0").is_err()
        );
        assert!(TnnConfig::from_toml("[cache]\nenabled = 1").is_err());
        assert!(TnnConfig::from_toml("[cache]\ndir = true").is_err());
    }

    #[test]
    fn parses_and_validates_faults_section() {
        let c = TnnConfig::from_toml(
            "[faults]\nenabled = true\nclasses = \"sa0,glitch\"\n\
             rates = \"0,0.1\"\nseeds = \"7,8\"",
        )
        .unwrap();
        assert!(c.faults);
        let spec = c.fault_spec().unwrap();
        assert_eq!(spec.rates, vec![0.0, 0.1]);
        assert_eq!(spec.seeds, vec![7, 8]);
        // Defaults: stage off, smoke-ish grid that parses cleanly.
        let d = TnnConfig::default();
        assert!(!d.faults);
        assert!(d.fault_spec().is_ok());
        // Bad grammar fails at config load, not mid-flow.
        assert!(TnnConfig::from_toml(
            "[faults]\nclasses = \"meltdown\""
        )
        .is_err());
        assert!(
            TnnConfig::from_toml("[faults]\nrates = \"-1\"").is_err()
        );
        assert!(
            TnnConfig::from_toml("[faults]\nseeds = \"\"").is_err()
        );
        assert!(
            TnnConfig::from_toml("[faults]\nenabled = 1").is_err()
        );
        assert!(
            TnnConfig::from_toml("[faults]\nclasses = 3").is_err()
        );
    }

    #[test]
    fn parses_and_validates_engine_and_passes() {
        let c = TnnConfig::from_toml(
            "[sim]\nsim_engine = \"compiled\"\nsim_passes = \"fold,dce\"",
        )
        .unwrap();
        assert_eq!(c.sim_engine, "compiled");
        assert_eq!(c.pass_manager().unwrap().canonical(), "fold,dce");
        // Defaults: auto engine, full pipeline.
        let d = TnnConfig::default();
        assert_eq!(d.sim_engine, "auto");
        assert_eq!(
            d.pass_manager().unwrap().canonical(),
            "fold,dce,coalesce,resched"
        );
        // Typos fail at config load, not mid-flow.
        assert!(TnnConfig::from_toml(
            "[sim]\nsim_engine = \"warp-drive\""
        )
        .is_err());
        assert!(TnnConfig::from_toml(
            "[sim]\nsim_passes = \"fold,fold\""
        )
        .is_err());
        assert!(TnnConfig::from_toml("[sim]\nsim_engine = 3").is_err());
        assert!(TnnConfig::from_toml("[sim]\nsim_passes = 3").is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_sections() {
        assert!(TnnConfig::from_toml("[bogus]\nx = 1").is_err());
        assert!(TnnConfig::from_toml("[network]\ntheta9 = 1").is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(TnnConfig::from_toml("[network]\ntheta1").is_err());
        assert!(TnnConfig::from_toml("[network]\ntheta1 = oops").is_err());
    }

    #[test]
    fn value_types() {
        assert_eq!(Value::parse("\"s\"").unwrap(), Value::Str("s".into()));
        assert_eq!(Value::parse("42").unwrap(), Value::Int(42));
        assert_eq!(Value::parse("4.5").unwrap(), Value::Float(4.5));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert!(Value::parse("nope").is_err());
    }
}
