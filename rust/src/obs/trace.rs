//! Hierarchical span tracing with bounded per-thread ring buffers.
//!
//! A [`SpanGuard`] measures one region of work: it captures
//! `Instant::now()` at construction and, when dropped (or explicitly
//! [`SpanGuard::finish_micros`]ed), records name, start, duration,
//! parent span, thread, and `key=value` attributes.
//!
//! **Two-speed design.**  Tracing is globally off by default.  A
//! disabled guard still measures elapsed time (one `Instant::now()`
//! at each end — the flow layer uses that single measurement as the
//! source for `FlowTrace` micros, so traced and untraced runs report
//! identical timing), but it allocates nothing, touches no
//! thread-local state beyond one atomic load, and records nothing.
//! That keeps the enabled-but-unsampled cost well under the 2%
//! budget on the simulator smoke bench, where spans only wrap whole
//! waves runs and shard workers, never per-tick work.
//!
//! **Storage.**  Each thread lazily registers one [`Ring`] — a
//! mutex-guarded `Vec` bounded at [`RING_CAP`] records — in a global
//! list.  Only the owning thread writes to its ring, so the mutex is
//! uncontended except during a drain.  Rings outlive their threads
//! (the registry holds an `Arc`), which matters because scoped sim
//! workers exit before the CLI collects the trace.  When a ring is
//! full new records are counted in `dropped` rather than pushed, so
//! a runaway span site degrades the trace instead of memory.
//!
//! Parentage is a per-thread stack of active span ids: spans are
//! strictly LIFO within a thread (guards are scope-bound), and
//! cross-thread work simply starts a new root per worker — the
//! Chrome-trace view groups by thread id, which is how Perfetto
//! renders fork/join parallelism anyway.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread ring capacity, in span records.
pub const RING_CAP: usize = 1 << 16;

/// One completed span.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// Static site name, e.g. `"flow.stage"` or `"sim.shard"`.
    pub name: &'static str,
    /// Unique id (process-wide, never 0).
    pub id: u64,
    /// Enclosing span's id on the same thread, 0 for roots.
    pub parent: u64,
    /// Small dense thread id assigned by this module (not the OS tid).
    pub tid: u64,
    /// Start offset from the process trace epoch, microseconds.
    pub start_us: u64,
    /// Duration, microseconds.
    pub dur_us: u64,
    /// Attributes attached via [`SpanGuard::attr`].
    pub attrs: Vec<(&'static str, String)>,
}

/// One thread's bounded span buffer.
#[derive(Debug)]
struct Ring {
    buf: Mutex<Vec<SpanRecord>>,
    dropped: AtomicU64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static RINGS: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();

struct Local {
    ring: Arc<Ring>,
    tid: u64,
    stack: Vec<u64>,
}

thread_local! {
    static LOCAL: RefCell<Option<Local>> = const { RefCell::new(None) };
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Turn span recording on or off, process-wide.  Guards check this
/// once at construction; spans already in flight keep the mode they
/// started with.
pub fn set_tracing(on: bool) {
    // Pin the epoch before the first recorded span so timestamps are
    // small positive offsets.
    epoch();
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether span recording is currently on.
pub fn tracing_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Total records discarded because a thread's ring was full.
pub fn dropped_total() -> u64 {
    let rings = RINGS.lock().expect("trace ring registry lock");
    rings.iter().map(|r| r.dropped.load(Ordering::Relaxed)).sum()
}

fn with_local<T>(f: impl FnOnce(&mut Local) -> T) -> T {
    LOCAL.with(|cell| {
        let mut slot = cell.borrow_mut();
        let local = slot.get_or_insert_with(|| {
            let ring = Arc::new(Ring {
                buf: Mutex::new(Vec::new()),
                dropped: AtomicU64::new(0),
            });
            let mut rings =
                RINGS.lock().expect("trace ring registry lock");
            rings.push(ring.clone());
            Local {
                ring,
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                stack: Vec::new(),
            }
        });
        f(local)
    })
}

/// Start a span.  Cheap when tracing is off (see module docs); the
/// returned guard records on drop.
pub fn span(name: &'static str) -> SpanGuard {
    let active = ENABLED.load(Ordering::Relaxed);
    let (id, parent, tid) = if active {
        with_local(|local| {
            let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
            let parent = local.stack.last().copied().unwrap_or(0);
            local.stack.push(id);
            (id, parent, local.tid)
        })
    } else {
        (0, 0, 0)
    };
    SpanGuard {
        name,
        start: Instant::now(),
        id,
        parent,
        tid,
        attrs: Vec::new(),
        active,
        done: false,
    }
}

/// Live span handle; records itself when dropped.
#[derive(Debug)]
pub struct SpanGuard {
    name: &'static str,
    start: Instant,
    id: u64,
    parent: u64,
    tid: u64,
    attrs: Vec<(&'static str, String)>,
    active: bool,
    done: bool,
}

impl SpanGuard {
    /// Attach a `key=value` attribute.  No-op (and no allocation)
    /// when the span is not being recorded.
    pub fn attr(&mut self, key: &'static str, value: impl ToString) {
        if self.active {
            self.attrs.push((key, value.to_string()));
        }
    }

    /// Elapsed time so far, microseconds.
    pub fn elapsed_micros(&self) -> u128 {
        self.start.elapsed().as_micros()
    }

    /// Finish now and return the measured duration in microseconds.
    /// This is the single timing source the flow layer feeds into
    /// `FlowTrace`, so trace spans and stage micros can never
    /// disagree.
    pub fn finish_micros(mut self) -> u128 {
        let us = self.start.elapsed().as_micros();
        self.record();
        us
    }

    fn record(&mut self) {
        if self.done {
            return;
        }
        self.done = true;
        if !self.active {
            return;
        }
        let start_us =
            self.start.duration_since(epoch()).as_micros() as u64;
        let rec = SpanRecord {
            name: self.name,
            id: self.id,
            parent: self.parent,
            tid: self.tid,
            start_us,
            dur_us: self.start.elapsed().as_micros() as u64,
            attrs: std::mem::take(&mut self.attrs),
        };
        with_local(|local| {
            // Pop our own id; tolerate (and repair) unbalanced drops
            // rather than corrupting later parentage.
            while let Some(top) = local.stack.pop() {
                if top == self.id {
                    break;
                }
            }
            let mut buf =
                local.ring.buf.lock().expect("trace ring lock");
            if buf.len() < RING_CAP {
                buf.push(rec);
            } else {
                local.ring.dropped.fetch_add(1, Ordering::Relaxed);
            }
        });
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        self.record();
    }
}

fn collect(drain: bool) -> Vec<SpanRecord> {
    let rings = RINGS.lock().expect("trace ring registry lock");
    let mut out = Vec::new();
    for ring in rings.iter() {
        let mut buf = ring.buf.lock().expect("trace ring lock");
        if drain {
            out.append(&mut buf);
        } else {
            out.extend(buf.iter().cloned());
        }
    }
    out.sort_by_key(|r| (r.start_us, r.id));
    out
}

/// Drain all recorded spans (every thread's ring), sorted by start
/// time.  The rings are left empty.
pub fn take_spans() -> Vec<SpanRecord> {
    collect(true)
}

/// Copy all recorded spans without draining (test helper).
pub fn snapshot_spans() -> Vec<SpanRecord> {
    collect(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is process-global; tests that toggle it
    // serialize on this lock and run their spans on dedicated
    // threads, filtering collected records by that thread's spans,
    // so parallel test threads cannot interleave parentage.
    static TEST_GUARD: Mutex<()> = Mutex::new(());

    fn on_fresh_thread<T: Send>(f: impl FnOnce() -> T + Send) -> T {
        std::thread::scope(|s| s.spawn(f).join().expect("test thread"))
    }

    #[test]
    fn disabled_spans_record_nothing_but_still_time() {
        let _g = TEST_GUARD.lock().unwrap();
        set_tracing(false);
        let sp = span("idle.unique");
        let us = sp.finish_micros();
        assert!(us < 10_000_000, "sane elapsed measurement");
        let ghosts = snapshot_spans()
            .iter()
            .filter(|r| r.name == "idle.unique")
            .count();
        assert_eq!(ghosts, 0, "disabled span must not be recorded");
    }

    #[test]
    fn nesting_and_parentage() {
        let _g = TEST_GUARD.lock().unwrap();
        set_tracing(true);
        let ids = on_fresh_thread(|| {
            let outer = span("outer");
            let mid_id;
            {
                let mut mid = span("mid");
                mid.attr("k", "v");
                {
                    let _inner = span("inner");
                }
                mid_id = snapshot_spans()
                    .iter()
                    .find(|r| r.name == "inner")
                    .map(|r| r.parent)
                    .unwrap_or(0);
                drop(mid);
            }
            let sibling = span("sibling");
            drop(sibling);
            drop(outer);
            mid_id
        });
        set_tracing(false);
        let spans = take_spans();
        let find = |n: &str| {
            spans
                .iter()
                .find(|r| r.name == n)
                .unwrap_or_else(|| panic!("span {n} missing"))
        };
        let outer = find("outer");
        let mid = find("mid");
        let inner = find("inner");
        let sibling = find("sibling");
        assert_eq!(outer.parent, 0, "outer is a root");
        assert_eq!(mid.parent, outer.id);
        assert_eq!(inner.parent, mid.id);
        assert_eq!(sibling.parent, outer.id);
        assert_eq!(ids, mid.id, "inner recorded mid as parent");
        assert_eq!(mid.attrs, vec![("k", "v".to_string())]);
        // All spans ran on the same (fresh) thread.
        assert_eq!(outer.tid, inner.tid);
        assert_eq!(outer.tid, sibling.tid);
    }

    #[test]
    fn spans_survive_worker_thread_exit() {
        let _g = TEST_GUARD.lock().unwrap();
        set_tracing(true);
        on_fresh_thread(|| {
            let _sp = span("worker.unit");
        });
        set_tracing(false);
        let spans = take_spans();
        assert!(
            spans.iter().any(|r| r.name == "worker.unit"),
            "record outlives its thread"
        );
    }
}
