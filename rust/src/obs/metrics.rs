//! Process-wide metrics: counters, gauges, and fixed-bucket
//! histograms behind a [`Registry`].
//!
//! Design constraints, in order:
//!
//! 1. **Hot-path cost is one atomic RMW.** Registration (name + label
//!    lookup under a mutex) happens once, at construction time of the
//!    instrumented component; the returned [`Arc<Counter>`] /
//!    [`Arc<Gauge>`] / [`Arc<Histogram>`] handle is then pure
//!    `fetch_add` with `Relaxed` ordering — no lock, no allocation,
//!    no formatting.  Relaxed is sound because metric reads are
//!    statistical: exposition never synchronizes-with increments.
//! 2. **Zero dependencies.** The exposition format is Prometheus
//!    text 0.0.4, rendered by hand; the JSON views reuse
//!    [`crate::runtime::json::Json`].
//! 3. **Instantiable, not only global.** A process-wide registry
//!    ([`super::global`]) serves the CLI; the serve daemon and unit
//!    tests construct private registries so concurrent daemons in one
//!    test process cannot pollute each other's exact counts.
//!
//! Histograms use fixed log-scale buckets: powers of two from
//! 1 µs to 2^24 µs (≈16.8 s), plus `+Inf`.  Power-of-two bounds make
//! bucket selection a `leading_zeros` instruction instead of a search,
//! and every registry in the process shares one bucket layout so
//! series are always comparable.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::json::Json;

/// Number of finite histogram buckets (`le = 2^0 .. 2^24`).
pub const FINITE_BUCKETS: usize = 25;
/// Total buckets including the `+Inf` overflow slot.
pub const TOTAL_BUCKETS: usize = FINITE_BUCKETS + 1;

/// Upper bound (inclusive) of finite bucket `i`, in the histogram's
/// native unit (by convention microseconds everywhere in this repo).
pub fn bucket_bound(i: usize) -> u64 {
    1u64 << i
}

/// Index of the bucket a raw observation lands in.
///
/// Observations `<= 1` land in bucket 0; otherwise the bucket is the
/// position of the highest set bit of `v - 1` plus one, clamped into
/// the `+Inf` slot.  This gives half-open power-of-two ranges:
/// bucket 1 covers `(1, 2]`, bucket 2 covers `(2, 4]`, and so on.
pub fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        let b = (64 - (v - 1).leading_zeros()) as usize;
        b.min(FINITE_BUCKETS)
    }
}

/// Monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by one, returning the previous value (atomic unique
    /// sequence numbers, e.g. quarantine file suffixes).
    pub fn inc_fetch(&self) -> u64 {
        self.v.fetch_add(1, Ordering::Relaxed)
    }

    /// Increment by `n` (batch flush from a private tally).
    pub fn add(&self, n: u64) {
        if n != 0 {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (queue depths, in-flight counts).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    /// Set to an absolute value.
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    /// Decrement by one.
    pub fn dec(&self) {
        self.v.fetch_sub(1, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Fixed log-scale histogram (see module docs for the bucket layout).
///
/// Buckets store *per-bucket* counts; the cumulative `le` form
/// Prometheus wants is computed at exposition time, so `observe` is a
/// single `fetch_add` on the owning bucket plus one on the sum.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; TOTAL_BUCKETS],
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Record one observation (microseconds by repo convention).
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum()
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket (non-cumulative) counts, for tests and JSON views.
    pub fn bucket_counts(&self) -> [u64; TOTAL_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }
}

/// What a registered series holds.
#[derive(Debug, Clone)]
enum Value {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Value {
    fn kind(&self) -> &'static str {
        match self {
            Value::Counter(_) => "counter",
            Value::Gauge(_) => "gauge",
            Value::Histogram(_) => "histogram",
        }
    }
}

/// One labeled series inside a family.
#[derive(Debug)]
struct Series {
    labels: Vec<(String, String)>,
    value: Value,
}

/// All series sharing one metric name.
#[derive(Debug)]
struct Family {
    help: String,
    /// Keyed by the canonical rendered label string, so lookup and
    /// exposition order agree.
    series: BTreeMap<String, Series>,
}

/// A set of named metric families.  See the module docs for the
/// global-vs-instance policy.
#[derive(Debug, Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

/// Canonical label key: sorted `k=v` pairs joined by `\x1f` (a byte
/// that cannot appear in a sane label), empty for the unlabeled
/// series.
fn label_key(labels: &[(String, String)]) -> String {
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}\x1f{v}")).collect();
    parts.sort();
    parts.join("\x1f\x1f")
}

fn own_labels(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    let mut v: Vec<(String, String)> = labels
        .iter()
        .map(|(k, val)| (k.to_string(), val.to_string()))
        .collect();
    v.sort();
    v
}

/// Escape a label value for the text exposition format.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render a `{k="v",...}` block; extra pairs are appended after the
/// series labels (used for histogram `le`).
fn render_labels(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

impl Registry {
    /// Fresh, empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn register(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Value,
    ) -> Value {
        let labels = own_labels(labels);
        let key = label_key(&labels);
        let mut fams = self.families.lock().expect("metrics registry lock");
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            series: BTreeMap::new(),
        });
        let series = fam
            .series
            .entry(key)
            .or_insert_with(|| Series { labels, value: make() });
        series.value.clone()
    }

    /// Get-or-create a counter series.  Registering the same
    /// (name, labels) twice returns the same underlying counter, so
    /// independently constructed components share one series.
    ///
    /// Panics if the name is already registered with a different
    /// metric kind — that is a programming error, not a runtime
    /// condition.
    pub fn counter(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Arc<Counter> {
        match self.register(name, help, labels, || {
            Value::Counter(Arc::new(Counter::default()))
        }) {
            Value::Counter(c) => c,
            other => panic!(
                "metric `{name}` already registered as {}",
                other.kind()
            ),
        }
    }

    /// Get-or-create a gauge series (see [`Registry::counter`]).
    pub fn gauge(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Arc<Gauge> {
        match self.register(name, help, labels, || {
            Value::Gauge(Arc::new(Gauge::default()))
        }) {
            Value::Gauge(g) => g,
            other => panic!(
                "metric `{name}` already registered as {}",
                other.kind()
            ),
        }
    }

    /// Get-or-create a histogram series (see [`Registry::counter`]).
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Arc<Histogram> {
        match self.register(name, help, labels, || {
            Value::Histogram(Arc::new(Histogram::default()))
        }) {
            Value::Histogram(h) => h,
            other => panic!(
                "metric `{name}` already registered as {}",
                other.kind()
            ),
        }
    }

    /// Value of one counter series, 0 if never registered.  Used by
    /// the daemon's `/stats` view so JSON and `/metrics` can never
    /// disagree.
    pub fn counter_value(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        let key = label_key(&own_labels(labels));
        let fams = self.families.lock().expect("metrics registry lock");
        match fams.get(name).and_then(|f| f.series.get(&key)) {
            Some(Series { value: Value::Counter(c), .. }) => c.get(),
            _ => 0,
        }
    }

    /// All series of one counter family as `(labels, value)` rows,
    /// sorted by label key.  Powers map-shaped `/stats` sections
    /// (per-engine and per-pass request counts).
    pub fn counter_series(
        &self,
        name: &str,
    ) -> Vec<(Vec<(String, String)>, u64)> {
        let fams = self.families.lock().expect("metrics registry lock");
        let mut out = Vec::new();
        if let Some(fam) = fams.get(name) {
            for s in fam.series.values() {
                if let Value::Counter(c) = &s.value {
                    out.push((s.labels.clone(), c.get()));
                }
            }
        }
        out
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (0.0.4): `# HELP` / `# TYPE` per family, one line per series,
    /// cumulative `_bucket`/`_sum`/`_count` for histograms.  Families
    /// and series are emitted in sorted order so the output is
    /// deterministic and snapshot-testable.
    pub fn prometheus_text(&self) -> String {
        let fams = self.families.lock().expect("metrics registry lock");
        let mut out = String::new();
        for (name, fam) in fams.iter() {
            let kind = match fam.series.values().next() {
                Some(s) => s.value.kind(),
                None => continue,
            };
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            for s in fam.series.values() {
                match &s.value {
                    Value::Counter(c) => {
                        out.push_str(&format!(
                            "{name}{} {}\n",
                            render_labels(&s.labels, None),
                            c.get()
                        ));
                    }
                    Value::Gauge(g) => {
                        out.push_str(&format!(
                            "{name}{} {}\n",
                            render_labels(&s.labels, None),
                            g.get()
                        ));
                    }
                    Value::Histogram(h) => {
                        let counts = h.bucket_counts();
                        let mut cum = 0u64;
                        for (i, c) in counts.iter().enumerate() {
                            cum += c;
                            let le = if i < FINITE_BUCKETS {
                                bucket_bound(i).to_string()
                            } else {
                                "+Inf".to_string()
                            };
                            out.push_str(&format!(
                                "{name}_bucket{} {cum}\n",
                                render_labels(&s.labels, Some(("le", &le))),
                            ));
                        }
                        out.push_str(&format!(
                            "{name}_sum{} {}\n",
                            render_labels(&s.labels, None),
                            h.sum()
                        ));
                        out.push_str(&format!(
                            "{name}_count{} {cum}\n",
                            render_labels(&s.labels, None),
                        ));
                    }
                }
            }
        }
        out
    }

    /// JSON snapshot of every counter and gauge (histograms are
    /// summarized as `{sum, count}`), for debugging and the profile
    /// subcommand's footer.
    pub fn snapshot_json(&self) -> Json {
        let fams = self.families.lock().expect("metrics registry lock");
        let mut root = BTreeMap::new();
        for (name, fam) in fams.iter() {
            let mut rows = Vec::new();
            for s in fam.series.values() {
                let mut row = BTreeMap::new();
                for (k, v) in &s.labels {
                    row.insert(k.clone(), Json::str(v));
                }
                match &s.value {
                    Value::Counter(c) => {
                        row.insert("value".into(), Json::int(c.get()));
                    }
                    Value::Gauge(g) => {
                        row.insert(
                            "value".into(),
                            Json::Num(g.get() as f64),
                        );
                    }
                    Value::Histogram(h) => {
                        row.insert("sum".into(), Json::int(h.sum()));
                        row.insert("count".into(), Json::int(h.count()));
                    }
                }
                rows.push(Json::Obj(row));
            }
            root.insert(name.clone(), Json::Arr(rows));
        }
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        // Each finite bound lands in its own bucket; bound+1 spills
        // into the next.
        for i in 1..FINITE_BUCKETS {
            assert_eq!(bucket_index(bucket_bound(i)), i, "bound {i}");
            assert_eq!(
                bucket_index(bucket_bound(i) + 1),
                (i + 1).min(FINITE_BUCKETS),
                "bound {i} + 1"
            );
        }
        assert_eq!(bucket_index(u64::MAX), FINITE_BUCKETS);
    }

    #[test]
    fn counter_identity_and_kinds() {
        let r = Registry::new();
        let a = r.counter("x_total", "x", &[("stage", "sta")]);
        let b = r.counter("x_total", "x", &[("stage", "sta")]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(r.counter_value("x_total", &[("stage", "sta")]), 3);
        assert_eq!(r.counter_value("x_total", &[("stage", "other")]), 0);
        assert_eq!(r.counter_value("absent", &[]), 0);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter("x", "x", &[]);
        let _ = r.gauge("x", "x", &[]);
    }

    #[test]
    fn label_order_is_canonical() {
        let r = Registry::new();
        let a = r.counter("y", "y", &[("b", "2"), ("a", "1")]);
        let b = r.counter("y", "y", &[("a", "1"), ("b", "2")]);
        a.inc();
        assert_eq!(b.get(), 1);
    }

    #[test]
    fn histogram_sum_count() {
        let h = Histogram::default();
        for v in [0, 1, 2, 100, 1 << 30] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1 + 2 + 100 + (1 << 30));
        let counts = h.bucket_counts();
        assert_eq!(counts[0], 2); // 0 and 1
        assert_eq!(counts[1], 1); // 2
        assert_eq!(counts[bucket_index(100)], 1);
        assert_eq!(counts[FINITE_BUCKETS], 1); // +Inf
    }
}
