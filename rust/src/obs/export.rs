//! Span exports: Chrome trace-event JSON and the self-time profile
//! table.
//!
//! The Chrome format is the `chrome://tracing` / Perfetto "JSON
//! object" flavour: a `traceEvents` array of complete (`"ph": "X"`)
//! events with microsecond timestamps.  Every span becomes one event
//! carrying its attributes (plus span/parent ids) in `args`, and a
//! metadata event names the process, so a flow trace drops straight
//! into Perfetto with stages on the main thread and sim workers on
//! their own rows.
//!
//! The profile view aggregates spans by site name: *total* time is
//! the sum of span durations; *self* time subtracts the duration of
//! each span's direct children, so a stage that spends its life
//! waiting on instrumented sub-work shows near-zero self time.  This
//! is the `tnn7 profile` hot-span table.

use std::collections::BTreeMap;
use std::collections::HashMap;

use crate::obs::trace::SpanRecord;
use crate::runtime::json::Json;

/// Render spans as a Chrome trace-event JSON document.
pub fn chrome_trace(spans: &[SpanRecord]) -> Json {
    let mut events = Vec::with_capacity(spans.len() + 1);
    events.push(Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::int(1)),
        ("tid", Json::int(0)),
        (
            "args",
            Json::obj(vec![("name", Json::str("tnn7"))]),
        ),
    ]));
    for s in spans {
        let mut args = BTreeMap::new();
        args.insert("span_id".to_string(), Json::int(s.id));
        args.insert("parent".to_string(), Json::int(s.parent));
        for (k, v) in &s.attrs {
            args.insert((*k).to_string(), Json::str(v.clone()));
        }
        events.push(Json::obj(vec![
            ("name", Json::str(s.name)),
            ("cat", Json::str("tnn7")),
            ("ph", Json::str("X")),
            ("ts", Json::int(s.start_us)),
            ("dur", Json::int(s.dur_us)),
            ("pid", Json::int(1)),
            ("tid", Json::int(s.tid)),
            ("args", Json::Obj(args)),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// One row of the aggregated profile.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileRow {
    /// Span site name.
    pub name: &'static str,
    /// Number of spans recorded at this site.
    pub count: u64,
    /// Sum of span durations, microseconds.
    pub total_us: u64,
    /// Total minus time spent in direct child spans, microseconds.
    pub self_us: u64,
}

/// Aggregate spans into per-site rows, hottest self-time first.
pub fn profile(spans: &[SpanRecord]) -> Vec<ProfileRow> {
    // Sum each span's direct children so self-time can be derived
    // without re-walking the forest per row.
    let mut child_us: HashMap<u64, u64> = HashMap::new();
    for s in spans {
        if s.parent != 0 {
            *child_us.entry(s.parent).or_insert(0) += s.dur_us;
        }
    }
    let mut rows: BTreeMap<&'static str, ProfileRow> = BTreeMap::new();
    for s in spans {
        let children = child_us.get(&s.id).copied().unwrap_or(0);
        let row = rows.entry(s.name).or_insert_with(|| ProfileRow {
            name: s.name,
            count: 0,
            total_us: 0,
            self_us: 0,
        });
        row.count += 1;
        row.total_us += s.dur_us;
        // Clamp: a child can report marginally more time than its
        // parent when both round to microseconds.
        row.self_us += s.dur_us.saturating_sub(children);
    }
    let mut out: Vec<ProfileRow> = rows.into_values().collect();
    out.sort_by(|a, b| {
        b.self_us.cmp(&a.self_us).then(a.name.cmp(b.name))
    });
    out
}

/// Format profile rows as the fixed-width table `tnn7 profile`
/// prints.
pub fn profile_table(rows: &[ProfileRow], top: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:>7} {:>12} {:>12} {:>6}\n",
        "span", "count", "self(us)", "total(us)", "self%"
    ));
    let grand: u64 = rows.iter().map(|r| r.self_us).sum();
    for r in rows.iter().take(top) {
        let pct = if grand == 0 {
            0.0
        } else {
            100.0 * r.self_us as f64 / grand as f64
        };
        out.push_str(&format!(
            "{:<28} {:>7} {:>12} {:>12} {:>5.1}%\n",
            r.name, r.count, r.self_us, r.total_us, pct
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(
        name: &'static str,
        id: u64,
        parent: u64,
        start_us: u64,
        dur_us: u64,
    ) -> SpanRecord {
        SpanRecord {
            name,
            id,
            parent,
            tid: 1,
            start_us,
            dur_us,
            attrs: Vec::new(),
        }
    }

    #[test]
    fn chrome_trace_shape() {
        let mut a = rec("flow.stage", 1, 0, 0, 100);
        a.attrs.push(("stage", "sta".to_string()));
        let spans = vec![a, rec("sim.worker", 2, 1, 10, 50)];
        let doc = chrome_trace(&spans);
        let events = doc.field("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3, "metadata + 2 spans");
        let ev = &events[1];
        assert_eq!(ev.field("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(ev.field("ts").unwrap().as_usize().unwrap(), 0);
        assert_eq!(ev.field("dur").unwrap().as_usize().unwrap(), 100);
        let args = ev.field("args").unwrap();
        assert_eq!(args.field("stage").unwrap().as_str().unwrap(), "sta");
        // Round-trips through the parser (what the CI smoke step does).
        let text = doc.to_string_pretty();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn profile_self_vs_total() {
        // parent (100us) -> child (60us) -> grandchild (10us), plus a
        // second lone parent span of 40us.
        let spans = vec![
            rec("parent", 1, 0, 0, 100),
            rec("child", 2, 1, 10, 60),
            rec("grandchild", 3, 2, 20, 10),
            rec("parent", 4, 0, 200, 40),
        ];
        let rows = profile(&spans);
        let get = |n: &str| {
            rows.iter().find(|r| r.name == n).expect("row").clone()
        };
        let parent = get("parent");
        assert_eq!(parent.count, 2);
        assert_eq!(parent.total_us, 140);
        assert_eq!(parent.self_us, 80, "100-60 plus lone 40");
        let child = get("child");
        assert_eq!(child.self_us, 50);
        assert_eq!(child.total_us, 60);
        assert_eq!(get("grandchild").self_us, 10);
        // Hottest self-time first.
        assert_eq!(rows[0].name, "parent");
        let table = profile_table(&rows, 10);
        assert!(table.contains("self(us)"));
        assert!(table.contains("parent"));
    }

    #[test]
    fn profile_clamps_rounding() {
        // Child reports 1us more than its parent; self time clamps
        // to zero instead of wrapping.
        let spans =
            vec![rec("p", 1, 0, 0, 10), rec("c", 2, 1, 0, 11)];
        let rows = profile(&spans);
        let p = rows.iter().find(|r| r.name == "p").unwrap();
        assert_eq!(p.self_us, 0);
    }
}
