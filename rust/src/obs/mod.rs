//! Unified observability: metrics registry, span tracer, and their
//! exposition formats.
//!
//! This module is the measurement substrate for the whole pipeline.
//! Every layer reports through it instead of keeping private tallies:
//!
//! * **Metrics** ([`metrics`]) — counters, gauges, and log-bucket
//!   histograms in a [`Registry`].  The process-wide [`global`]
//!   registry serves CLI runs; the serve daemon owns a registry per
//!   instance (injected into its flow contexts and stage cache), so
//!   `GET /metrics` and the `/stats` JSON view read the *same*
//!   atomics and can never drift apart — and so concurrent daemons
//!   inside one test process keep exact, independent counts.
//! * **Spans** ([`trace`]) — a guard-based hierarchical tracer with
//!   bounded per-thread rings.  Flow stages, serve requests, fault
//!   campaigns, and sim workers time themselves through one span
//!   guard each; `FlowTrace` micros are the guard's own measurement,
//!   so the trace and the stage report always agree.
//! * **Exports** ([`export`]) — Chrome trace-event JSON
//!   (`tnn7 flow --trace out.json`, loadable in Perfetto) and the
//!   self-time/total-time table behind `tnn7 profile`.  The registry
//!   renders itself as Prometheus text for the daemon's
//!   `GET /metrics`.
//!
//! Overhead budget: with tracing disabled a span site costs two
//! `Instant::now()` calls and one relaxed atomic load; a counter
//! increment is one relaxed `fetch_add`.  Nothing here runs per tick
//! or per gate — engines batch their tallies locally and flush once
//! per run (see `sim::sharded` and `sim::compiled`), which keeps the
//! measured overhead on the `sim_throughput` smoke bench below the
//! 2% acceptance budget.

pub mod export;
pub mod metrics;
pub mod trace;

use std::sync::{Arc, OnceLock};

pub use export::{chrome_trace, profile, profile_table, ProfileRow};
pub use metrics::{Counter, Gauge, Histogram, Registry};
pub use trace::{
    set_tracing, snapshot_spans, span, take_spans, tracing_enabled,
    SpanGuard, SpanRecord,
};

static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();

/// The process-wide registry used by CLI entry points and any
/// component not constructed with an explicit registry.
pub fn global() -> Arc<Registry> {
    GLOBAL.get_or_init(|| Arc::new(Registry::new())).clone()
}
