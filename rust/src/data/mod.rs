//! Workload data: the procedural MNIST-like digit corpus.
//!
//! The sandbox has no dataset access, so the paper's MNIST workload is
//! substituted with a *procedurally generated* 28×28 grayscale digit
//! corpus (stroke-rasterized glyphs with translation/shape jitter and
//! pixel noise — see [`digits`]).  The substitution preserves what the
//! experiment needs from MNIST: 10 visually distinct classes, spatially
//! local stroke structure for the receptive-field encoding, and
//! intra-class variability for STDP generalization.  DESIGN.md §1
//! documents the argument and why absolute accuracy is not comparable
//! to the paper's MNIST number.

pub mod digits;

pub use digits::{Dataset, DigitGen};
