//! Procedural 28×28 digit glyphs (the MNIST substitute).
//!
//! Digits are drawn as anti-aliased line strokes on a seven-segment-plus-
//! diagonals skeleton, then perturbed: global translation (±2 px),
//! per-endpoint jitter (±1 px), stroke-width variation and additive
//! pixel noise.  Generation is fully deterministic in the seed (xorshift
//! PRNG), so every layer of the stack trains on byte-identical data.

use crate::tnn::encoding::IMG;

/// Deterministic xorshift64* PRNG (no external rand crate offline).
#[derive(Debug, Clone)]
pub struct XorShift {
    s: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        XorShift { s: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.s = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [lo, hi].
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        lo + (self.next_u64() % (hi - lo + 1) as u64) as i32
    }
}

/// Segment endpoints in a normalized 1×1 glyph box.
type Seg = ((f32, f32), (f32, f32));

/// Stroke skeleton per digit (seven-segment + diagonals where it reads
/// better).  Coordinates are (x, y) with y growing downward.
fn skeleton(digit: usize) -> Vec<Seg> {
    const A: Seg = ((0.15, 0.05), (0.85, 0.05)); // top
    const B: Seg = ((0.85, 0.05), (0.85, 0.50)); // top right
    const C: Seg = ((0.85, 0.50), (0.85, 0.95)); // bottom right
    const D: Seg = ((0.15, 0.95), (0.85, 0.95)); // bottom
    const E: Seg = ((0.15, 0.50), (0.15, 0.95)); // bottom left
    const F: Seg = ((0.15, 0.05), (0.15, 0.50)); // top left
    const G: Seg = ((0.15, 0.50), (0.85, 0.50)); // middle
    match digit {
        0 => vec![A, B, C, D, E, F],
        1 => vec![((0.5, 0.05), (0.5, 0.95)), ((0.3, 0.2), (0.5, 0.05))],
        2 => vec![A, B, G, E, D],
        3 => vec![A, B, G, C, D],
        4 => vec![F, G, B, C],
        5 => vec![A, F, G, C, D],
        6 => vec![A, F, E, D, C, G],
        7 => vec![A, ((0.85, 0.05), (0.4, 0.95))],
        8 => vec![A, B, C, D, E, F, G],
        9 => vec![G, F, A, B, C, D],
        _ => panic!("digit out of range"),
    }
}

/// Digit-image generator.
#[derive(Debug, Clone)]
pub struct DigitGen {
    rng: XorShift,
}

impl DigitGen {
    pub fn new(seed: u64) -> Self {
        DigitGen { rng: XorShift::new(seed) }
    }

    /// Render one digit with jitter + noise; returns IMG*IMG grayscale
    /// in [0, 1].
    pub fn render(&mut self, digit: usize) -> Vec<f32> {
        let mut img = vec![0.0f32; IMG * IMG];
        // Glyph box: 16x20 px placed with global jitter.
        let (gw, gh) = (14.0f32, 18.0f32);
        let ox = 7.0 + self.rng.range_i32(-1, 1) as f32;
        let oy = 5.0 + self.rng.range_i32(-1, 1) as f32;
        let thick = 1.4 + 0.25 * self.rng.next_f32();
        for &((x0, y0), (x1, y1)) in &skeleton(digit) {
            let j = |r: &mut XorShift| (r.next_f32() - 0.5) * 0.8;
            let (ax, ay) = (
                ox + x0 * gw + j(&mut self.rng),
                oy + y0 * gh + j(&mut self.rng),
            );
            let (bx, by) = (
                ox + x1 * gw + j(&mut self.rng),
                oy + y1 * gh + j(&mut self.rng),
            );
            draw_line(&mut img, ax, ay, bx, by, thick);
        }
        // Additive noise.
        for p in img.iter_mut() {
            *p = (*p + 0.06 * (self.rng.next_f32() - 0.5)).clamp(0.0, 1.0);
        }
        img
    }

    /// Next labeled sample (labels cycle through a shuffled order).
    pub fn sample(&mut self) -> (Vec<f32>, usize) {
        let label = (self.rng.next_u64() % 10) as usize;
        (self.render(label), label)
    }
}

/// Soft-brush line rasterizer.
fn draw_line(img: &mut [f32], x0: f32, y0: f32, x1: f32, y1: f32, thick: f32) {
    let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt().max(1e-3);
    let steps = (len * 3.0).ceil() as usize;
    for s in 0..=steps {
        let t = s as f32 / steps as f32;
        let (cx, cy) = (x0 + t * (x1 - x0), y0 + t * (y1 - y0));
        let r = thick.ceil() as i32;
        for dy in -r..=r {
            for dx in -r..=r {
                let (px, py) = (cx + dx as f32, cy + dy as f32);
                let (ix, iy) = (px.round() as i32, py.round() as i32);
                if ix < 0 || iy < 0 || ix >= IMG as i32 || iy >= IMG as i32 {
                    continue;
                }
                let d = ((px - cx).powi(2) + (py - cy).powi(2)).sqrt();
                let v = (1.0 - (d / thick)).clamp(0.0, 1.0);
                let idx = iy as usize * IMG + ix as usize;
                img[idx] = img[idx].max(v);
            }
        }
    }
}

/// A labeled dataset split.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
}

impl Dataset {
    /// Generate `n` samples deterministically from `seed`.
    pub fn generate(n: usize, seed: u64) -> Self {
        let mut g = DigitGen::new(seed);
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            // Balanced classes: round-robin labels, jitter from the RNG.
            let label = i % 10;
            images.push(g.render(label));
            labels.push(label);
        }
        Dataset { images, labels }
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::generate(20, 7);
        let b = Dataset::generate(20, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = Dataset::generate(20, 8);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn images_are_nontrivial_and_bounded() {
        let d = Dataset::generate(30, 1);
        for img in &d.images {
            assert_eq!(img.len(), IMG * IMG);
            let on = img.iter().filter(|&&p| p > 0.5).count();
            assert!(on > 18, "glyph too sparse: {on}");
            assert!(on < 400, "glyph too dense: {on}");
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean intra-class pixel distance must be well below inter-class
        // distance (the property STDP needs to separate them).
        let mut g = DigitGen::new(42);
        let per_class: Vec<Vec<Vec<f32>>> = (0..10)
            .map(|d| (0..8).map(|_| g.render(d)).collect())
            .collect();
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum()
        };
        let mut intra = 0.0;
        let mut n_intra = 0.0;
        let mut inter = 0.0;
        let mut n_inter = 0.0;
        for c1 in 0..10 {
            for i in 0..8 {
                for j in (i + 1)..8 {
                    intra += dist(&per_class[c1][i], &per_class[c1][j]);
                    n_intra += 1.0;
                }
                for c2 in (c1 + 1)..10 {
                    inter += dist(&per_class[c1][i], &per_class[c2][i]);
                    n_inter += 1.0;
                }
            }
        }
        let (intra, inter) = (intra / n_intra, inter / n_inter);
        assert!(
            inter > 1.5 * intra,
            "classes not separable: intra={intra} inter={inter}"
        );
    }

    #[test]
    fn rng_is_uniformish() {
        let mut r = XorShift::new(9);
        let mut buckets = [0u32; 10];
        for _ in 0..10000 {
            buckets[r.range_i32(0, 9) as usize] += 1;
        }
        for &b in &buckets {
            assert!((700..1300).contains(&b), "{buckets:?}");
        }
    }
}
