//! Column testbench: drive an elaborated TNN column through
//! computational waves and decode spike times / weights back out.
//!
//! Wave protocol (WAVE_CYCLES = T_STEPS + 2 = 17 unit cycles):
//!
//! | cycles        | activity                                            |
//! |---------------|-----------------------------------------------------|
//! | 0 .. 14       | compute: input level `x[j]` rises at its encoded    |
//! |               | spike time; RNL accumulation, threshold, WTA        |
//! | 15            | STDP evaluate: BRV lanes driven, gamma-domain       |
//! |               | commit (weight registers update)                    |
//! | 16            | gamma reset: `gclk` level rises, edge2pulse emits   |
//! |               | `grst`, per-wave state clears                       |
//!
//! The testbench records pre-WTA spike times (first cycle each `fire`
//! level is high), post-WTA times (grant cycles) and the committed
//! weights — the exact observables of the golden model, enabling
//! bit-exact gate-vs-golden equivalence tests and activity extraction
//! for Table I power.
//!
//! Two drivers share the wave protocol: [`ColumnTestbench`] replays
//! one wave at a time on the scalar engine, and the lane-batched
//! [`WordTestbench`] batches up to 64 waves per pass on any word-level
//! engine implementing [`LaneEngine`] — the packed interpreter
//! ([`PackedColumnTestbench`]) or the compiled tape engine
//! ([`CompiledColumnTestbench`]), bit-identically.  [`lane_batches`]
//! chunks a wave list so lane `l` carries waves `l`, `l+lanes`, … with
//! its own STDP weight state (DESIGN.md §7).  [`run_waves_parallel`]
//! and [`run_waves_parallel_compiled`] additionally cut the lane axis
//! across worker threads — bit-identical to the single-thread schedule,
//! because lanes never exchange data (DESIGN.md §8).

use crate::arch::T_STEPS;
use crate::cells::Library;
use crate::error::Result;
use crate::fault::{CompiledFaults, FaultOverlay, FaultProgram, SeuFlip};
use crate::ir::{lower, PassManager, PassStats};
use crate::netlist::column::{ColumnPorts, BRV_PER_SYN};
use crate::netlist::{NetId, Netlist};
use crate::tnn::stdp::{brv_lanes, RandPair, StdpParams};
use crate::tnn::INF;

use super::compiled::CompiledSimulator;
use super::packed::{PackedSimulator, MAX_LANES};
use super::Simulator;

/// Cycles per wave (keep in sync with ppa::WAVE_CYCLES).
pub const WAVE_LEN: usize = T_STEPS as usize + 2;

/// Result of one wave.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WaveResult {
    /// Pre-WTA spike time per neuron (INF = none).
    pub pre: Vec<i32>,
    /// Post-WTA spike time per neuron.
    pub post: Vec<i32>,
    /// Weights after the gamma commit, row-major `w[j*q+i]`.
    pub weights: Vec<i32>,
}

/// Testbench over a column netlist.
pub struct ColumnTestbench<'n> {
    nl: &'n Netlist,
    ports: &'n ColumnPorts,
    sim: Simulator<'n>,
    p: usize,
    q: usize,
    inputs: Vec<(NetId, bool)>,
}

impl<'n> ColumnTestbench<'n> {
    /// Attach to an elaborated column.
    pub fn new(
        nl: &'n Netlist,
        ports: &'n ColumnPorts,
        lib: &'n Library,
    ) -> Result<Self> {
        let sim = Simulator::new(nl, lib)?;
        Ok(ColumnTestbench {
            nl,
            ports,
            p: ports.x.len(),
            q: ports.fires.len(),
            sim,
            inputs: Vec::new(),
        })
    }

    /// Immutable access to the activity counters.
    pub fn activity(&self) -> &super::Activity {
        &self.sim.activity
    }

    /// Underlying netlist.
    pub fn netlist(&self) -> &Netlist {
        self.nl
    }

    /// Install a fault overlay on the underlying engine (static
    /// stuck/delay masks; lane bit 0 is the live one).
    pub fn install_faults(&mut self, overlay: crate::fault::FaultOverlay) {
        self.sim.install_faults(overlay);
    }

    /// Run one wave: `spike_times[p]` (INF = no spike, else 0..7),
    /// `rand[p*q]` per-synapse BRV draw pairs, `params` the STDP config.
    pub fn run_wave(
        &mut self,
        spike_times: &[i32],
        rand: &[RandPair],
        params: &StdpParams,
    ) -> WaveResult {
        self.run_wave_inner(spike_times, rand, params, None)
    }

    /// [`ColumnTestbench::run_wave`] under a transient fault schedule:
    /// `wave` is this wave's global index into the campaign's
    /// [`FaultProgram`], whose events for `(wave, cycle)` are staged
    /// before the matching tick.
    pub fn run_wave_faulted(
        &mut self,
        wave: u32,
        spike_times: &[i32],
        rand: &[RandPair],
        params: &StdpParams,
        program: &FaultProgram,
    ) -> WaveResult {
        self.run_wave_inner(spike_times, rand, params, Some((wave, program)))
    }

    fn run_wave_inner(
        &mut self,
        spike_times: &[i32],
        rand: &[RandPair],
        params: &StdpParams,
        fault: Option<(u32, &FaultProgram)>,
    ) -> WaveResult {
        assert_eq!(spike_times.len(), self.p);
        assert_eq!(rand.len(), self.p * self.q);
        let mut pre = vec![INF; self.q];
        let mut post = vec![INF; self.q];

        for cyc in 0..WAVE_LEN {
            self.inputs.clear();
            let compute = cyc < T_STEPS as usize;
            let stdp_eval = cyc == T_STEPS as usize; // cycle 15
            let reset = cyc == WAVE_LEN - 1; // cycle 16
            // Input levels: high from the spike time through the STDP
            // evaluation cycle, low on the reset cycle.
            for j in 0..self.p {
                let s = spike_times[j];
                let high = !reset && s != INF && (cyc as i32) >= s;
                self.inputs.push((self.ports.x[j], high));
            }
            self.inputs.push((self.ports.gclk, reset));
            // BRV lanes valid on the STDP evaluation cycle.
            for (syn, &pair) in rand.iter().enumerate() {
                if stdp_eval {
                    let lanes = brv_lanes(pair, params);
                    for (k, &v) in lanes.iter().enumerate() {
                        self.inputs
                            .push((self.ports.brv[syn * BRV_PER_SYN + k], v));
                    }
                } else if cyc == 0 || reset {
                    for k in 0..BRV_PER_SYN {
                        self.inputs
                            .push((self.ports.brv[syn * BRV_PER_SYN + k], false));
                    }
                }
            }
            if let Some((wave, prog)) = fault {
                stage_scalar_events(&mut self.sim, wave, cyc as u16, prog);
            }
            self.sim.tick(&self.inputs, stdp_eval);
            // Record spike times during the compute window.
            if compute {
                for i in 0..self.q {
                    if pre[i] == INF && self.sim.get(self.ports.fires[i]) {
                        pre[i] = cyc as i32;
                    }
                    if post[i] == INF && self.sim.get(self.ports.grants[i]) {
                        post[i] = cyc as i32;
                    }
                }
            }
        }
        WaveResult { pre, post, weights: self.read_weights() }
    }

    /// Read the committed weight registers.
    pub fn read_weights(&self) -> Vec<i32> {
        self.ports
            .weights
            .iter()
            .map(|bits| {
                (self.sim.get(bits[0]) as i32)
                    | (self.sim.get(bits[1]) as i32) << 1
                    | (self.sim.get(bits[2]) as i32) << 2
            })
            .collect()
    }
}

/// Stage the scalar engine's transient fault events for `(wave, cycle)`.
fn stage_scalar_events(
    sim: &mut Simulator<'_>,
    wave: u32,
    cycle: u16,
    prog: &FaultProgram,
) {
    if prog.is_empty() {
        return;
    }
    let glitches: Vec<(NetId, u64)> =
        prog.glitches_at(wave, cycle).map(|n| (n, 1)).collect();
    let seus: Vec<SeuFlip> = prog
        .seus_at(wave, cycle)
        .map(|(inst, bit)| SeuFlip { inst, bit, lanes: 1 })
        .collect();
    if !glitches.is_empty() || !seus.is_empty() {
        sim.set_tick_faults(&glitches, &seus);
    }
}

/// Collect the lane-masked transient events of cycle `cycle` for lanes
/// `0..k`, lane `l` carrying global wave `base_wave + l` (the packed
/// wave→lane placement — the same on every engine and thread count).
fn lane_events(
    base_wave: u32,
    k: usize,
    cycle: u16,
    prog: &FaultProgram,
) -> (Vec<(NetId, u64)>, Vec<SeuFlip>) {
    let mut glitches: Vec<(NetId, u64)> = Vec::new();
    let mut seus: Vec<SeuFlip> = Vec::new();
    for l in 0..k {
        let w = base_wave + l as u32;
        for n in prog.glitches_at(w, cycle) {
            match glitches.iter_mut().find(|(g, _)| *g == n) {
                Some((_, m)) => *m |= 1 << l,
                None => glitches.push((n, 1 << l)),
            }
        }
        for (inst, bit) in prog.seus_at(w, cycle) {
            match seus.iter_mut().find(|s| s.inst == inst && s.bit == bit) {
                Some(s) => s.lanes |= 1 << l,
                None => seus.push(SeuFlip { inst, bit, lanes: 1 << l }),
            }
        }
    }
    (glitches, seus)
}

/// Iterate a stimulus set in lane-sized batches.
///
/// Yields `(first_wave_index, chunk)` pairs of at most `lanes` waves
/// (clamped to `1..=`[`MAX_LANES`]).  Feeding consecutive chunks to
/// [`WordTestbench::run_wave_lanes`] gives every lane a strided
/// subsequence of the waves (lane `l` sees waves `l`, `l+lanes`, …), so
/// per-lane state such as STDP weights evolves sequentially *within*
/// each lane.
pub fn lane_batches<'a>(
    stim: &'a [Vec<i32>],
    lanes: usize,
) -> impl Iterator<Item = (usize, &'a [Vec<i32>])> + 'a {
    let lanes = lanes.clamp(1, MAX_LANES);
    stim.chunks(lanes)
        .enumerate()
        .map(move |(c, chunk)| (c * lanes, chunk))
}

/// Word-level lane-parallel engine the lane-batched testbench can
/// drive: the seam that lets [`WordTestbench`] run the identical wave
/// schedule on the packed interpreter or the compiled tape engine.
pub trait LaneEngine {
    /// Lane capacity the engine was built for.
    fn lanes(&self) -> usize;
    /// Shrink the activity-counted lane set to the first `n` lanes.
    fn set_active_lanes(&mut self, n: usize);
    /// Run one `aclk` cycle across all lanes.
    fn tick(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool);
    /// Current value of a net in one lane.
    fn get(&self, net: NetId, lane: usize) -> bool;
    /// Aggregated switching-activity counters.
    fn activity(&self) -> &super::Activity;
    /// Install a static fault overlay, or refuse it when the engine
    /// cannot force a site faithfully (compiled tapes after folding).
    fn install_overlay(&mut self, overlay: FaultOverlay) -> Result<()>;
    /// Stage transient events (glitches, SEUs) for the next tick.
    fn stage_tick_events(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
    );
    /// Stable engine label for metrics series and span attributes.
    fn engine_label(&self) -> &'static str;
    /// Drain any internal observability tallies into `obs` (the
    /// compiled tape reports quiescence gating and ops retired;
    /// interpreters have nothing to drain).  Called once per run by
    /// the parallel wave drivers — never inside the tick loop.
    fn obs_flush(&mut self, _obs: &crate::obs::Registry) {}
}

impl LaneEngine for PackedSimulator<'_> {
    fn lanes(&self) -> usize {
        PackedSimulator::lanes(self)
    }

    fn set_active_lanes(&mut self, n: usize) {
        PackedSimulator::set_active_lanes(self, n);
    }

    fn tick(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool) {
        PackedSimulator::tick(self, inputs, gclk_edge);
    }

    fn get(&self, net: NetId, lane: usize) -> bool {
        PackedSimulator::get(self, net, lane)
    }

    fn activity(&self) -> &super::Activity {
        &self.activity
    }

    fn install_overlay(&mut self, overlay: FaultOverlay) -> Result<()> {
        PackedSimulator::install_faults(self, overlay);
        Ok(())
    }

    fn stage_tick_events(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
    ) {
        PackedSimulator::set_tick_faults(self, glitches, seus);
    }

    fn engine_label(&self) -> &'static str {
        "packed"
    }
}

impl LaneEngine for CompiledSimulator {
    fn lanes(&self) -> usize {
        CompiledSimulator::lanes(self)
    }

    fn set_active_lanes(&mut self, n: usize) {
        CompiledSimulator::set_active_lanes(self, n);
    }

    fn tick(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool) {
        CompiledSimulator::tick(self, inputs, gclk_edge);
    }

    fn get(&self, net: NetId, lane: usize) -> bool {
        CompiledSimulator::get(self, net, lane)
    }

    fn activity(&self) -> &super::Activity {
        CompiledSimulator::activity(self)
    }

    fn install_overlay(&mut self, overlay: FaultOverlay) -> Result<()> {
        CompiledSimulator::install_faults(self, overlay)
    }

    fn stage_tick_events(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
    ) {
        CompiledSimulator::set_tick_faults(self, glitches, seus);
    }

    fn engine_label(&self) -> &'static str {
        "compiled"
    }

    fn obs_flush(&mut self, obs: &crate::obs::Registry) {
        CompiledSimulator::obs_flush(self, obs);
    }
}

/// Lane-batched testbench over a column netlist: the word-level
/// counterpart of [`ColumnTestbench`], driving up to 64 waves per pass
/// on any [`LaneEngine`].
pub struct WordTestbench<'n, E: LaneEngine> {
    nl: &'n Netlist,
    ports: &'n ColumnPorts,
    sim: E,
    p: usize,
    q: usize,
    inputs: Vec<(NetId, u64)>,
}

/// [`WordTestbench`] over the packed interpreter.
pub type PackedColumnTestbench<'n> = WordTestbench<'n, PackedSimulator<'n>>;

/// [`WordTestbench`] over the compiled tape engine.
pub type CompiledColumnTestbench<'n> = WordTestbench<'n, CompiledSimulator>;

impl<'n> PackedColumnTestbench<'n> {
    /// Attach to an elaborated column with `lanes` (1..=64) stimulus
    /// lanes on the packed interpreter.
    pub fn new(
        nl: &'n Netlist,
        ports: &'n ColumnPorts,
        lib: &'n Library,
        lanes: usize,
    ) -> Result<Self> {
        Ok(WordTestbench::attach(nl, ports, PackedSimulator::new(nl, lib, lanes)?))
    }
}

impl<'n> CompiledColumnTestbench<'n> {
    /// Attach to an elaborated column with `lanes` (1..=64) stimulus
    /// lanes on the compiled tape engine (full pass pipeline).
    pub fn new(
        nl: &'n Netlist,
        ports: &'n ColumnPorts,
        lib: &Library,
        lanes: usize,
    ) -> Result<Self> {
        Ok(WordTestbench::attach(
            nl,
            ports,
            CompiledSimulator::new(nl, lib, lanes)?,
        ))
    }

    /// Like [`CompiledColumnTestbench::new`] with an explicit pass
    /// pipeline.
    pub fn with_passes(
        nl: &'n Netlist,
        ports: &'n ColumnPorts,
        lib: &Library,
        lanes: usize,
        pm: &PassManager,
    ) -> Result<Self> {
        Ok(WordTestbench::attach(
            nl,
            ports,
            CompiledSimulator::with_passes(nl, lib, lanes, pm)?,
        ))
    }
}

impl<'n, E: LaneEngine> WordTestbench<'n, E> {
    /// Attach a prebuilt engine to its elaborated column.
    pub fn attach(nl: &'n Netlist, ports: &'n ColumnPorts, sim: E) -> Self {
        WordTestbench {
            nl,
            ports,
            p: ports.x.len(),
            q: ports.fires.len(),
            sim,
            inputs: Vec::new(),
        }
    }

    /// Immutable access to the aggregated activity counters.
    pub fn activity(&self) -> &super::Activity {
        self.sim.activity()
    }

    /// Underlying engine.
    pub fn engine(&self) -> &E {
        &self.sim
    }

    /// Underlying netlist.
    pub fn netlist(&self) -> &Netlist {
        self.nl
    }

    /// Lane capacity of the underlying engine.
    pub fn lanes(&self) -> usize {
        self.sim.lanes()
    }

    /// Install a fault overlay on the underlying engine (static
    /// stuck/delay masks shared by all lanes).  Fails when the engine
    /// cannot force a site faithfully — the compiled engine after a
    /// site was folded away; callers then fall back to an interpreter.
    pub fn install_faults(
        &mut self,
        overlay: crate::fault::FaultOverlay,
    ) -> Result<()> {
        self.sim.install_overlay(overlay)
    }

    /// Run one wave across `k ≤ lanes` stimuli in parallel: lane `l`
    /// is driven by `spike_times[l]` / `rand[l]`, exactly the schedule
    /// of [`ColumnTestbench::run_wave`], and gets its own
    /// [`WaveResult`].  Lanes `k..` are masked out of activity.
    pub fn run_wave_lanes(
        &mut self,
        spike_times: &[Vec<i32>],
        rand: &[Vec<RandPair>],
        params: &StdpParams,
    ) -> Vec<WaveResult> {
        self.run_wave_lanes_inner(spike_times, rand, params, None)
    }

    /// [`WordTestbench::run_wave_lanes`] under a transient
    /// fault schedule: lane `l` carries global wave `base_wave + l`,
    /// and the [`FaultProgram`]'s events for those waves are staged
    /// lane-masked before the matching tick.
    pub fn run_wave_lanes_faulted(
        &mut self,
        base_wave: u32,
        spike_times: &[Vec<i32>],
        rand: &[Vec<RandPair>],
        params: &StdpParams,
        program: &FaultProgram,
    ) -> Vec<WaveResult> {
        self.run_wave_lanes_inner(
            spike_times,
            rand,
            params,
            Some((base_wave, program)),
        )
    }

    fn run_wave_lanes_inner(
        &mut self,
        spike_times: &[Vec<i32>],
        rand: &[Vec<RandPair>],
        params: &StdpParams,
        fault: Option<(u32, &FaultProgram)>,
    ) -> Vec<WaveResult> {
        let k = spike_times.len();
        assert!(
            (1..=self.sim.lanes()).contains(&k),
            "1..={} waves per pass",
            self.sim.lanes()
        );
        assert_eq!(rand.len(), k);
        for s in spike_times {
            assert_eq!(s.len(), self.p);
        }
        for r in rand {
            assert_eq!(r.len(), self.p * self.q);
        }
        self.sim.set_active_lanes(k);
        let mut pre = vec![vec![INF; self.q]; k];
        let mut post = vec![vec![INF; self.q]; k];

        for cyc in 0..WAVE_LEN {
            self.inputs.clear();
            let compute = cyc < T_STEPS as usize;
            let stdp_eval = cyc == T_STEPS as usize; // cycle 15
            let reset = cyc == WAVE_LEN - 1; // cycle 16
            // Input levels, one word per input: bit l = lane l's level.
            for j in 0..self.p {
                let mut w = 0u64;
                if !reset {
                    for (l, s) in spike_times.iter().enumerate() {
                        let t = s[j];
                        if t != INF && (cyc as i32) >= t {
                            w |= 1 << l;
                        }
                    }
                }
                self.inputs.push((self.ports.x[j], w));
            }
            self.inputs
                .push((self.ports.gclk, if reset { !0u64 } else { 0 }));
            // BRV lanes valid on the STDP evaluation cycle.
            if stdp_eval {
                for syn in 0..self.p * self.q {
                    let mut words = [0u64; BRV_PER_SYN];
                    for (l, r) in rand.iter().enumerate() {
                        let lanes = brv_lanes(r[syn], params);
                        for (b, &v) in lanes.iter().enumerate() {
                            words[b] |= (v as u64) << l;
                        }
                    }
                    for (b, &w) in words.iter().enumerate() {
                        self.inputs
                            .push((self.ports.brv[syn * BRV_PER_SYN + b], w));
                    }
                }
            } else if cyc == 0 || reset {
                for syn in 0..self.p * self.q {
                    for b in 0..BRV_PER_SYN {
                        self.inputs
                            .push((self.ports.brv[syn * BRV_PER_SYN + b], 0));
                    }
                }
            }
            if let Some((base, prog)) = fault {
                if !prog.is_empty() {
                    let (g, s) = lane_events(base, k, cyc as u16, prog);
                    if !g.is_empty() || !s.is_empty() {
                        self.sim.stage_tick_events(&g, &s);
                    }
                }
            }
            self.sim.tick(&self.inputs, stdp_eval);
            // Record spike times during the compute window.
            if compute {
                for (l, (pre_l, post_l)) in
                    pre.iter_mut().zip(post.iter_mut()).enumerate()
                {
                    for i in 0..self.q {
                        if pre_l[i] == INF
                            && self.sim.get(self.ports.fires[i], l)
                        {
                            pre_l[i] = cyc as i32;
                        }
                        if post_l[i] == INF
                            && self.sim.get(self.ports.grants[i], l)
                        {
                            post_l[i] = cyc as i32;
                        }
                    }
                }
            }
        }
        pre.into_iter()
            .zip(post)
            .enumerate()
            .map(|(l, (pre, post))| WaveResult {
                pre,
                post,
                weights: self.read_weights(l),
            })
            .collect()
    }

    /// Run a whole stimulus set through lane-sized batches
    /// ([`lane_batches`]): chunk `c` drives waves `c*lanes ..` in
    /// parallel, so lane `l` carries its weight state through waves
    /// `l`, `l+lanes`, … — the packed wave schedule (DESIGN.md §7).
    /// Returns one [`WaveResult`] per wave, in wave order.
    pub fn run_waves(
        &mut self,
        stim: &[Vec<i32>],
        rand: &[Vec<RandPair>],
        params: &StdpParams,
    ) -> Vec<WaveResult> {
        assert_eq!(stim.len(), rand.len());
        let lanes = self.sim.lanes();
        let mut out = Vec::with_capacity(stim.len());
        for ((_, s), r) in lane_batches(stim, lanes).zip(rand.chunks(lanes)) {
            out.extend(self.run_wave_lanes(s, r, params));
        }
        out
    }

    /// [`WordTestbench::run_waves`] under a transient fault
    /// schedule: chunk `c`'s first wave index (`c*lanes`) keys the
    /// lookup, so event placement matches the scalar wave order.
    pub fn run_waves_faulted(
        &mut self,
        stim: &[Vec<i32>],
        rand: &[Vec<RandPair>],
        params: &StdpParams,
        program: &FaultProgram,
    ) -> Vec<WaveResult> {
        assert_eq!(stim.len(), rand.len());
        let lanes = self.sim.lanes();
        let mut out = Vec::with_capacity(stim.len());
        for ((base, s), r) in
            lane_batches(stim, lanes).zip(rand.chunks(lanes))
        {
            out.extend(
                self.run_wave_lanes_faulted(base as u32, s, r, params, program),
            );
        }
        out
    }

    /// Read the committed weight registers of one lane.
    pub fn read_weights(&self, lane: usize) -> Vec<i32> {
        self.ports
            .weights
            .iter()
            .map(|bits| {
                (self.sim.get(bits[0], lane) as i32)
                    | (self.sim.get(bits[1], lane) as i32) << 1
                    | (self.sim.get(bits[2], lane) as i32) << 2
            })
            .collect()
    }
}

/// Run a whole stimulus set through the packed wave schedule on
/// `threads` worker threads, bit-identically to a single-thread
/// [`WordTestbench::run_waves`] with the same `lanes`.
///
/// The canonical schedule assigns wave `w` to chunk `w / lanes`, lane
/// `w % lanes`, and lanes never exchange data — so the lane axis can be
/// cut across threads: worker `t` owns a contiguous lane range and runs
/// its own packed engine over *its lanes of every chunk*.  Each lane
/// still carries its strided wave subsequence (`l`, `l+lanes`, …) with
/// live STDP state, exactly as in the single-thread schedule, so
/// per-wave results are identical and the merged [`Activity`] — a sum
/// over lanes either way — is **bit-identical**, independent of the
/// thread count (DESIGN.md §8).  Returns one [`WaveResult`] per wave in
/// wave order plus the aggregated activity.
#[allow(clippy::too_many_arguments)] // mirrors run_wave's argument set + execution knobs
pub fn run_waves_parallel(
    nl: &Netlist,
    ports: &ColumnPorts,
    lib: &Library,
    lanes: usize,
    threads: usize,
    stim: &[Vec<i32>],
    rand: &[Vec<RandPair>],
    params: &StdpParams,
) -> Result<(Vec<WaveResult>, super::Activity)> {
    run_waves_parallel_inner(
        nl,
        ports,
        lanes,
        threads,
        stim,
        rand,
        params,
        None,
        |w| PackedSimulator::new(nl, lib, w),
    )
}

/// [`run_waves_parallel`] under a compiled fault campaign: every worker
/// installs a clone of the static overlay, and transient events are
/// staged by global wave index — so the faulted results are identical
/// at every thread count, too.
#[allow(clippy::too_many_arguments)] // run_waves_parallel's set + the campaign
pub fn run_waves_parallel_faulted(
    nl: &Netlist,
    ports: &ColumnPorts,
    lib: &Library,
    lanes: usize,
    threads: usize,
    stim: &[Vec<i32>],
    rand: &[Vec<RandPair>],
    params: &StdpParams,
    faults: &CompiledFaults,
) -> Result<(Vec<WaveResult>, super::Activity)> {
    run_waves_parallel_inner(
        nl,
        ports,
        lanes,
        threads,
        stim,
        rand,
        params,
        Some(faults),
        |w| PackedSimulator::new(nl, lib, w),
    )
}

/// [`run_waves_parallel`] on the compiled tape engine: the netlist is
/// lowered and optimized by `pm` **once**, then every worker compiles
/// its own tape from the shared IR — so thread counts only change who
/// executes which lanes, never the tape.  Returns the per-wave results,
/// the aggregated activity, and the pass statistics of the shared
/// optimization run.  With `faults`, installation fails (no silent
/// fallback) when a forced site was optimized away — precheck with
/// [`CompiledSimulator::fault_site_lost`] and use an interpreter
/// engine for such campaigns.
#[allow(clippy::too_many_arguments)] // run_waves_parallel's set + the pipeline
pub fn run_waves_parallel_compiled(
    nl: &Netlist,
    ports: &ColumnPorts,
    lib: &Library,
    lanes: usize,
    threads: usize,
    stim: &[Vec<i32>],
    rand: &[Vec<RandPair>],
    params: &StdpParams,
    pm: &PassManager,
    faults: Option<&CompiledFaults>,
) -> Result<(Vec<WaveResult>, super::Activity, Vec<PassStats>)> {
    let mut ir = lower(nl, lib)?;
    let stats = pm.run(&mut ir);
    let ir = &ir;
    let passes = pm.canonical();
    let (results, activity) = run_waves_parallel_inner(
        nl,
        ports,
        lanes,
        threads,
        stim,
        rand,
        params,
        faults,
        |w| CompiledSimulator::from_ir(ir, Vec::new(), passes.clone(), w),
    )?;
    Ok((results, activity, stats))
}

#[allow(clippy::too_many_arguments)]
fn run_waves_parallel_inner<E, F>(
    nl: &Netlist,
    ports: &ColumnPorts,
    lanes: usize,
    threads: usize,
    stim: &[Vec<i32>],
    rand: &[Vec<RandPair>],
    params: &StdpParams,
    faults: Option<&CompiledFaults>,
    make: F,
) -> Result<(Vec<WaveResult>, super::Activity)>
where
    E: LaneEngine,
    F: Fn(usize) -> Result<E> + Sync,
{
    assert_eq!(stim.len(), rand.len());
    let lanes = lanes.clamp(1, MAX_LANES);
    let threads = threads.max(1).min(lanes);
    let n = stim.len();
    if threads == 1 || n == 0 {
        let mut tb = WordTestbench::attach(nl, ports, make(lanes)?);
        let mut sp = crate::obs::span("sim.worker");
        sp.attr("engine", tb.sim.engine_label());
        sp.attr("worker", 0);
        sp.attr("lanes", format!("0..{lanes}"));
        sp.attr("waves", n);
        let results = match faults {
            Some(f) => {
                tb.install_faults(f.overlay.clone())?;
                tb.run_waves_faulted(stim, rand, params, &f.program)
            }
            None => tb.run_waves(stim, rand, params),
        };
        drop(sp);
        flush_engine_obs(&crate::obs::global(), &mut tb, n as u64);
        return Ok((results, tb.activity().clone()));
    }
    // Lane ranges: the first `lanes % threads` workers get one extra.
    let base = lanes / threads;
    let extra = lanes % threads;
    let mut out: Vec<Option<WaveResult>> = (0..n).map(|_| None).collect();
    let mut activity = super::Activity::new(nl.insts.len());
    let make = &make;
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(threads);
        let mut lo = 0usize;
        for t in 0..threads {
            let width = base + usize::from(t < extra);
            let my_lo = lo;
            lo += width;
            type WorkerOut =
                (Vec<(usize, Vec<WaveResult>)>, super::Activity);
            handles.push(scope.spawn(move || -> Result<WorkerOut> {
                let mut tb = WordTestbench::attach(nl, ports, make(width)?);
                let mut sp = crate::obs::span("sim.worker");
                sp.attr("engine", tb.sim.engine_label());
                sp.attr("worker", t);
                sp.attr("lanes", format!("{my_lo}..{}", my_lo + width));
                if let Some(f) = faults {
                    tb.install_faults(f.overlay.clone())?;
                }
                let mut parts: Vec<(usize, Vec<WaveResult>)> = Vec::new();
                let mut chunk = 0usize;
                loop {
                    // Worker lane j of this chunk carries global wave
                    // s0 + j — the key transient events are placed by.
                    let s0 = chunk * lanes + my_lo;
                    if s0 >= n {
                        break;
                    }
                    let e0 = (s0 + width).min(n);
                    let res = match faults {
                        Some(f) => tb.run_wave_lanes_faulted(
                            s0 as u32,
                            &stim[s0..e0],
                            &rand[s0..e0],
                            params,
                            &f.program,
                        ),
                        None => tb.run_wave_lanes(
                            &stim[s0..e0],
                            &rand[s0..e0],
                            params,
                        ),
                    };
                    parts.push((s0, res));
                    chunk += 1;
                }
                let waves: u64 =
                    parts.iter().map(|(_, r)| r.len() as u64).sum();
                sp.attr("waves", waves);
                drop(sp);
                flush_engine_obs(&crate::obs::global(), &mut tb, waves);
                Ok((parts, tb.activity().clone()))
            }));
        }
        for h in handles {
            let worker = h.join().map_err(|_| {
                crate::error::Error::sim("wave worker panicked")
            })?;
            let (parts, act) = worker?;
            activity.merge(&act);
            for (s0, res) in parts {
                for (k, r) in res.into_iter().enumerate() {
                    out[s0 + k] = Some(r);
                }
            }
        }
        Ok(())
    })?;
    let results = out
        .into_iter()
        .map(|o| o.expect("every wave covered by a lane range"))
        .collect();
    Ok((results, activity))
}

/// Flush one worker's engine-level tallies: waves and ticks retired by
/// the engine itself (counted here so replay, bench and fault paths
/// that bypass the flow's `Simulate` stage still register), plus
/// whatever the engine drains internally — the compiled tape reports
/// quiescence gating and ops retired.  One call per worker per run;
/// nothing here executes inside the tick loop.
fn flush_engine_obs<E: LaneEngine>(
    obs: &crate::obs::Registry,
    tb: &mut WordTestbench<'_, E>,
    waves: u64,
) {
    let engine = tb.sim.engine_label();
    obs.counter(
        "tnn7_sim_engine_waves_total",
        "Waves retired by wave-parallel engine workers",
        &[("engine", engine)],
    )
    .add(waves);
    obs.counter(
        "tnn7_sim_engine_ticks_total",
        "Gclk lane-ticks retired, by engine",
        &[("engine", engine)],
    )
    .add(tb.activity().cycles);
    tb.sim.obs_flush(obs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::column::{build_column, ColumnSpec};
    use crate::netlist::Flavor;
    use crate::tnn::column::ColumnState;
    use crate::tnn::stdp::stdp_step;
    use crate::tnn::Lfsr16;

    /// Gate-level column ≡ golden model over several learning waves —
    /// THE cross-layer correctness theorem of this reproduction.
    fn check_equivalence(flavor: Flavor, seed: u16, waves: usize) {
        let lib = Library::with_macros();
        let spec = ColumnSpec { p: 6, q: 3, theta: 8 };
        let (nl, ports) = build_column(&lib, flavor, &spec).unwrap();
        let mut tb = ColumnTestbench::new(&nl, &ports, &lib).unwrap();
        let mut golden = ColumnState::new(spec.p, spec.q, spec.theta as i32);
        let params = StdpParams::default_training();
        let mut lfsr = Lfsr16::new(seed);
        let mut stim = Lfsr16::new(seed ^ 0x5a5a);

        for wave in 0..waves {
            // Random spike pattern (some inputs silent).
            let s: Vec<i32> = (0..spec.p)
                .map(|_| {
                    let v = stim.next_u16();
                    if v & 0x7 == 7 {
                        INF
                    } else {
                        i32::from(v % 8)
                    }
                })
                .collect();
            let rand: Vec<RandPair> =
                (0..spec.p * spec.q).map(|_| lfsr.draw_pair()).collect();

            let hw = tb.run_wave(&s, &rand, &params);
            let (pre_g, post_g) = golden.forward(&s);
            stdp_step(&s, &post_g, &mut golden.weights, &rand, &params);

            assert_eq!(hw.pre, pre_g, "{flavor:?} wave {wave}: pre");
            assert_eq!(hw.post, post_g, "{flavor:?} wave {wave}: post");
            assert_eq!(
                hw.weights, golden.weights,
                "{flavor:?} wave {wave}: weights"
            );
        }
    }

    #[test]
    fn std_column_matches_golden_model() {
        check_equivalence(Flavor::Std, 0xBEEF, 25);
    }

    #[test]
    fn custom_column_matches_golden_model() {
        check_equivalence(Flavor::Custom, 0xBEEF, 25);
    }

    #[test]
    fn flavours_match_each_other_with_different_seed() {
        check_equivalence(Flavor::Std, 0x1111, 10);
        check_equivalence(Flavor::Custom, 0x1111, 10);
    }

    fn random_waves(
        spec: &ColumnSpec,
        n: usize,
        seed: u16,
    ) -> (Vec<Vec<i32>>, Vec<Vec<RandPair>>) {
        let mut stim = Lfsr16::new(seed ^ 0x5a5a);
        let mut lfsr = Lfsr16::new(seed);
        let waves = (0..n)
            .map(|_| {
                (0..spec.p)
                    .map(|_| {
                        let v = stim.next_u16();
                        if v & 0x7 == 7 {
                            INF
                        } else {
                            i32::from(v % 8)
                        }
                    })
                    .collect()
            })
            .collect();
        let rands = (0..n)
            .map(|_| {
                (0..spec.p * spec.q).map(|_| lfsr.draw_pair()).collect()
            })
            .collect();
        (waves, rands)
    }

    /// A single-lane packed testbench replays the exact scalar wave
    /// schedule: identical results AND identical activity counters,
    /// live STDP included.
    #[test]
    fn packed_single_lane_matches_scalar_sequence() {
        let lib = Library::with_macros();
        let spec = ColumnSpec { p: 5, q: 3, theta: 7 };
        let (nl, ports) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let params = StdpParams::default_training();
        let (waves, rands) = random_waves(&spec, 6, 0x1d0b);

        let mut tb = ColumnTestbench::new(&nl, &ports, &lib).unwrap();
        let scalar: Vec<WaveResult> = waves
            .iter()
            .zip(&rands)
            .map(|(s, r)| tb.run_wave(s, r, &params))
            .collect();

        let mut ptb =
            PackedColumnTestbench::new(&nl, &ports, &lib, 1).unwrap();
        let packed = ptb.run_waves(&waves, &rands, &params);

        assert_eq!(scalar, packed);
        assert_eq!(tb.activity().toggles, ptb.activity().toggles);
        assert_eq!(tb.activity().clock_ticks, ptb.activity().clock_ticks);
        assert_eq!(tb.activity().cycles, ptb.activity().cycles);
    }

    /// One multi-lane pass equals the same waves run through
    /// independent single-wave scalar testbenches, lane for lane —
    /// results and summed activity.
    #[test]
    fn packed_parallel_lanes_match_independent_scalar_runs() {
        let lib = Library::with_macros();
        let spec = ColumnSpec { p: 6, q: 3, theta: 8 };
        let (nl, ports) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let params = StdpParams::default_training();
        let (waves, rands) = random_waves(&spec, 5, 0x77a1);

        let mut ptb =
            PackedColumnTestbench::new(&nl, &ports, &lib, 8).unwrap();
        let packed = ptb.run_wave_lanes(&waves, &rands, &params);

        let mut total = crate::sim::Activity::new(nl.insts.len());
        for (l, (s, r)) in waves.iter().zip(&rands).enumerate() {
            let mut tb = ColumnTestbench::new(&nl, &ports, &lib).unwrap();
            let res = tb.run_wave(s, r, &params);
            assert_eq!(res, packed[l], "lane {l}");
            total.merge(tb.activity());
        }
        assert_eq!(total.toggles, ptb.activity().toggles);
        assert_eq!(total.clock_ticks, ptb.activity().clock_ticks);
        assert_eq!(total.cycles, ptb.activity().cycles);
    }

    /// The thread-parallel wave executor is bit-identical — results and
    /// activity — to the single-thread packed schedule at every thread
    /// count, including a final partial chunk.
    #[test]
    fn parallel_waves_match_single_thread_schedule() {
        let lib = Library::with_macros();
        let spec = ColumnSpec { p: 5, q: 3, theta: 7 };
        let (nl, ports) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let params = StdpParams::default_training();
        let (waves, rands) = random_waves(&spec, 11, 0x2f3d);
        let lanes = 6;

        let mut tb =
            PackedColumnTestbench::new(&nl, &ports, &lib, lanes).unwrap();
        let canonical = tb.run_waves(&waves, &rands, &params);

        for threads in [1usize, 2, 3, 6, 16] {
            let (results, activity) = run_waves_parallel(
                &nl, &ports, &lib, lanes, threads, &waves, &rands, &params,
            )
            .unwrap();
            assert_eq!(results, canonical, "threads {threads}");
            assert_eq!(
                activity.toggles,
                tb.activity().toggles,
                "threads {threads}: toggles"
            );
            assert_eq!(
                activity.clock_ticks,
                tb.activity().clock_ticks,
                "threads {threads}: clock ticks"
            );
            assert_eq!(
                activity.cycles,
                tb.activity().cycles,
                "threads {threads}: cycles"
            );
        }
    }

    #[test]
    fn lane_batches_chunk_and_index() {
        let stim: Vec<Vec<i32>> = (0..10).map(|i| vec![i]).collect();
        let got: Vec<(usize, usize)> = lane_batches(&stim, 4)
            .map(|(base, chunk)| (base, chunk.len()))
            .collect();
        assert_eq!(got, vec![(0, 4), (4, 4), (8, 2)]);
        // Clamped to at least one lane.
        assert_eq!(lane_batches(&stim, 0).count(), 10);
    }

    #[test]
    fn weights_learn_a_repeated_pattern() {
        // Present one pattern repeatedly: winner's active synapses
        // strengthen (the STDP convergence property).
        let lib = Library::with_macros();
        let spec = ColumnSpec { p: 8, q: 2, theta: 6 };
        let (nl, ports) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let mut tb = ColumnTestbench::new(&nl, &ports, &lib).unwrap();
        let params = StdpParams::from_probs(
            1.0,
            1.0,
            0.3,
            [1.0; 8],
            [1.0; 8],
        );
        let mut lfsr = Lfsr16::new(3);
        let s: Vec<i32> = (0..8).map(|j| if j < 4 { 0 } else { INF }).collect();
        let mut last = Vec::new();
        for _ in 0..20 {
            let rand: Vec<RandPair> =
                (0..16).map(|_| lfsr.draw_pair()).collect();
            last = tb.run_wave(&s, &rand, &params).weights;
        }
        // Active synapses (j<4) of some neuron must exceed inactive ones.
        let active: i32 = (0..4).map(|j| last[j * 2]).sum();
        let inactive: i32 = (4..8).map(|j| last[j * 2]).sum();
        assert!(
            active > inactive,
            "active {active} !> inactive {inactive}: {last:?}"
        );
    }
}
