//! Levelized cycle-accurate two-clock gate-level simulator.
//!
//! The Cadence-simulation analogue: executes a [`crate::netlist::Netlist`]
//! cycle by cycle on the unit clock (`aclk`), with gamma-clock (`gclk`)
//! domain state committing only on end-of-wave ticks, and counts per-net
//! toggles — the switching-activity input to [`crate::ppa::power`].
//!
//! * [`eval`] — pure cell semantics: combinational output functions and
//!   sequential next-state functions for every [`crate::cells::CellKind`],
//!   including the behavioral models of the 11 custom macros.  These
//!   definitions are the single source of truth the netlist *module
//!   builders* are tested against (std-flavour gates ≡ macro behavior).
//! * [`simulator`] — levelization (comb-sensitivity-aware topological
//!   order), eval loop, commit, toggle counting.
//! * [`activity`] — per-instance toggle/clock counters → activity factors.
//! * [`testbench`] — drives TNN columns with encoded spike waves and
//!   decodes spike times back out (the bridge to the golden model).
//! * [`vcd`] — waveform dump for debugging.

pub mod activity;
pub mod eval;
pub mod simulator;
pub mod testbench;
pub mod vcd;

pub use activity::Activity;
pub use simulator::Simulator;
