//! Levelized cycle-accurate two-clock gate-level simulation.
//!
//! The Cadence-simulation analogue: executes a [`crate::netlist::Netlist`]
//! cycle by cycle on the unit clock (`aclk`), with gamma-clock (`gclk`)
//! domain state committing only on end-of-wave ticks, and counts per-net
//! toggles — the switching-activity input to [`crate::ppa::power`].
//! Two engines share one levelized evaluation plan and one activity
//! accounting rule (DESIGN.md §7):
//!
//! * [`eval`] — pure cell semantics: combinational output functions and
//!   sequential next-state functions for every [`crate::cells::CellKind`],
//!   including the behavioral models of the 11 custom macros, in both a
//!   scalar-`bool` reference form and a branch-free word-packed (`u64`,
//!   64 lanes) form.  The scalar definitions are the single source of
//!   truth the netlist *module builders* are tested against
//!   (std-flavour gates ≡ macro behavior), and the packed kernels are
//!   exhaustively swept against the scalar ones.
//! * [`simulator`] — levelization (comb-sensitivity-aware topological
//!   order) and the scalar reference engine [`Simulator`]: one stimulus
//!   per tick, eval loop, commit, toggle counting.
//! * [`packed`] — the production engine [`PackedSimulator`]: 64
//!   independent stimulus lanes per tick over `u64` words, with
//!   popcount toggle accounting that keeps aggregated activity equal to
//!   the sum of the per-lane scalar runs.
//! * [`sharded`] — the thread-parallel [`ShardedSimulator`]: the
//!   column-aligned partition of [`crate::netlist::partition`] run as
//!   one quiescence-gated packed shard per worker thread, with a
//!   boundary-net exchange into the tail (voter/output) part and
//!   activity aggregation bit-identical to the packed engine
//!   (DESIGN.md §8).
//! * [`tables`] — the single-source combinational truth tables: one
//!   ON-set definition per simple cell kind, shared by the eval
//!   kernels, the BLIF `.names` writer and the IR lowering, plus the
//!   closed tape-opcode set [`tables::Gate`].
//! * [`compiled`] — the compiled tape engine [`CompiledSimulator`]:
//!   the optimized word-level IR of [`crate::ir`] flattened into a
//!   straight-line, quiescence-gated op tape (DESIGN.md §14).
//! * [`engine`] — the [`SimEngine`] trait all engines implement; the
//!   seam the cross-engine equivalence tests drive through.
//! * [`activity`] — per-instance toggle/clock counters → activity
//!   factors, with [`Activity::merge`] as the cross-lane/cross-run
//!   aggregation rule.
//! * [`testbench`] — drives TNN columns with encoded spike waves and
//!   decodes spike times back out (the bridge to the golden model), in
//!   scalar ([`testbench::ColumnTestbench`]) and lane-batched
//!   ([`testbench::WordTestbench`], generic over packed or compiled
//!   engines) forms.
//! * [`vcd`] — waveform dump for debugging.

pub mod activity;
pub mod compiled;
pub mod engine;
pub mod eval;
pub mod packed;
pub mod sharded;
pub mod simulator;
pub mod tables;
pub mod testbench;
pub mod vcd;

pub use activity::Activity;
pub use compiled::CompiledSimulator;
pub use engine::SimEngine;
pub use packed::PackedSimulator;
pub use sharded::{ShardedSimulator, SimTick, TickPart};
pub use simulator::Simulator;
