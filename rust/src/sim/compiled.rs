//! The compiled tape engine: the optimized word-level IR of
//! [`crate::ir`] flattened into a straight-line op tape.
//!
//! [`Tape`] copies everything it needs out of a [`WordIr`] — op list,
//! level schedule, dependency CSR, sequential commit records, retired
//! constants — into a self-contained (lifetime-free) structure, then
//! executes ticks with the exact discipline of the interpreters
//! (DESIGN.md §14):
//!
//! * **Branch-free gate ops** — every [`Body::Gate`] /
//!   [`Body::Fused`] step is one [`eval_gate_word`] dispatch over up to
//!   four `u64` operand words; wide macros and sequential-Q evaluations
//!   go through the same packed kernels the interpreters use.
//! * **Quiescence gating** — ops are grouped by combinational level
//!   with per-level dirty flags and a net→reader-level CSR, exactly as
//!   in the sharded engine's parts: a level whose combinational inputs
//!   and state did not change is skipped, which is exact (unchanged
//!   inputs reproduce the stored outputs and zero toggles).
//! * **Reset prologue** — constants retired by dead-cell elimination
//!   are written once on the first tick after reset, crediting the
//!   producing instance `popcount((old ^ new) & mask)` toggles — the
//!   same first-tick settle the interpreters count for constant cones.
//! * **Activity** — toggles are counted per instance with the shared
//!   `popcount((old ^ new) & mask)` rule at every (forced) write, and
//!   `clock_ticks` per commit; a compiled run's [`Activity`] is
//!   bit-identical to the packed engine's.
//! * **Fault-site preservation** — the fault overlay forces values at
//!   the tape's write sites just like the interpreters.  Slots whose
//!   write site was optimized away ([`WordIr::fault_site_lost`]) can no
//!   longer be forced faithfully: [`CompiledSimulator::install_faults`]
//!   returns an error for static faults on them (callers fall back to
//!   an interpreter), and scheduling a glitch there panics — campaign
//!   drivers precheck via [`CompiledSimulator::fault_site_lost`].
//!
//! [`CompiledSimulator`] wraps one full-netlist tape behind the
//! [`SimEngine`] trait; the sharded engine builds one part-filtered
//! tape per shard through [`Tape::for_part`].

use crate::cells::Library;
use crate::error::{Error, Result};
use crate::fault::{FaultOverlay, SeuFlip};
use crate::ir::{
    lower, Body, ConstCell, GateOp, PassManager, PassStats, WideOp, WordIr,
    MAX_SEQ_INS,
};
use crate::netlist::{ClockDomain, NetId, Netlist};

use super::activity::Activity;
use super::engine::SimEngine;
use super::eval::{eval_comb_packed, next_state_packed};
use super::packed::MAX_LANES;
use super::tables::eval_gate_word;

/// One flattened tape step (a copy of the IR op body).
#[derive(Debug, Clone)]
enum TapeOp {
    /// One simple gate.
    Gate(GateOp),
    /// A fused producer/consumer pair (both outputs written).
    Fused(GateOp, GateOp),
    /// A wide macro / sequential-Q evaluation.
    Wide(WideOp),
}

/// One sequential commit record of the tape.
#[derive(Debug, Clone)]
struct TapeSeq {
    kind: crate::cells::CellKind,
    inst: u32,
    ins: [u32; MAX_SEQ_INS],
    n_ins: u8,
    state_off: u32,
    n_state: u8,
    domain: ClockDomain,
    /// Level bucket of the instance's comb op (re-armed on state change).
    bucket: u32,
}

fn mask_for(lanes: usize) -> u64 {
    if lanes >= MAX_LANES {
        !0
    } else {
        (1u64 << lanes) - 1
    }
}

/// Mark every level bucket that combinationally reads `slot` as dirty.
fn mark(dirty: &mut [bool], off: &[u32], lvls: &[u32], slot: usize) {
    for &b in &lvls[off[slot] as usize..off[slot + 1] as usize] {
        dirty[b as usize] = true;
    }
}

/// A compiled, self-contained, quiescence-gated op tape.
///
/// Holds owned copies of everything a tick needs (no netlist borrow),
/// so tapes can be built once from a shared [`WordIr`] and moved into
/// worker threads.  Slot indices are netlist net ids throughout.
pub struct Tape {
    /// Flattened ops, grouped by ascending level bucket.
    ops: Vec<TapeOp>,
    /// Op-range boundaries per level bucket (`len = n_buckets + 1`).
    level_start: Vec<u32>,
    /// Per-bucket dirty flags; a clean bucket is skipped wholesale.
    dirty: Vec<bool>,
    /// Global instance index → level bucket (`u32::MAX` = no op here).
    bucket_of_inst: Vec<u32>,
    /// CSR: slot → buckets that comb-read it.
    reader_off: Vec<u32>,
    reader_lvls: Vec<u32>,
    /// Slot is read by any pin of this tape.
    reads_any: Vec<bool>,
    /// Slot → bucket writing it (`u32::MAX` = not written here).
    driver_level: Vec<u32>,
    /// Slots whose fault site was optimized away (see [`WordIr`]).
    folded: Vec<bool>,
    /// Current slot values (bit `k` = lane `k`).
    values: Vec<u64>,
    /// Packed per-instance state.
    state: Vec<u64>,
    next: Vec<u64>,
    state_off: Vec<u32>,
    state_bits: Vec<u8>,
    /// Sequential commit records of this tape.
    seqs: Vec<TapeSeq>,
    /// Retired constants, written by the reset prologue.
    consts: Vec<ConstCell>,
    /// Prologue already ran since the last reset.
    primed: bool,
    /// Per-instance counters (`cycles` is counted by the wrapper).
    activity: Activity,
    /// Observability tallies since the last [`Tape::obs_drain`]:
    /// level buckets evaluated / skipped by quiescence gating, and
    /// ops retired.  Plain integers bumped inside the tick loop and
    /// flushed to the metrics registry once per run by the owning
    /// engine — per-tick work never touches an atomic.
    obs_levels_eval: u64,
    obs_levels_skip: u64,
    obs_ops_retired: u64,
    scratch_ins: [u64; 16],
    scratch_outs: [u64; 8],
    faults: Option<Box<FaultOverlay>>,
}

impl Tape {
    /// Compile the whole IR into one tape.
    pub fn new(ir: &WordIr) -> Tape {
        Tape::for_part(ir, None)
    }

    /// Compile the subset of `ir` whose instances `keep` selects (the
    /// sharded engine builds one tape per partition part; `None` keeps
    /// everything).  Retired constants credit their prologue toggles
    /// only on the tape that owns the producing instance.
    pub(crate) fn for_part(ir: &WordIr, keep: Option<&[bool]>) -> Tape {
        let included = |inst: u32| keep.map_or(true, |k| k[inst as usize]);
        let n_slots = ir.n_slots;

        let mut ops: Vec<TapeOp> = Vec::new();
        let mut level_start: Vec<u32> = Vec::new();
        let mut bucket_of_inst = vec![u32::MAX; ir.n_insts];
        let mut last_level = u32::MAX;
        let mut reads_any = vec![false; n_slots];
        let mut driver_level = vec![u32::MAX; n_slots];
        let mut pairs: Vec<(u32, u32)> = Vec::new();
        let mut buf = Vec::new();
        let mut outs = Vec::new();
        for op in &ir.ops {
            let inst = match &op.body {
                Body::Gate(g) => g.inst,
                Body::Fused(a, b) => {
                    debug_assert!(
                        included(a.inst) == included(b.inst),
                        "fused pair split across parts"
                    );
                    a.inst
                }
                Body::Wide(w) => w.inst,
            };
            if !included(inst) {
                continue;
            }
            if op.level != last_level || level_start.is_empty() {
                level_start.push(ops.len() as u32);
                last_level = op.level;
            }
            let bucket = level_start.len() as u32 - 1;
            op.dep_slots(&mut buf);
            for &s in &buf {
                pairs.push((s, bucket));
            }
            op.read_slots(&mut buf);
            for &s in &buf {
                reads_any[s as usize] = true;
            }
            op.out_slots(&mut outs);
            for &(s, i) in &outs {
                driver_level[s as usize] = bucket;
                bucket_of_inst[i as usize] = bucket;
            }
            ops.push(match &op.body {
                Body::Gate(g) => TapeOp::Gate(*g),
                Body::Fused(a, b) => TapeOp::Fused(*a, *b),
                Body::Wide(w) => TapeOp::Wide(w.clone()),
            });
        }
        level_start.push(ops.len() as u32);
        let n_buckets = level_start.len() - 1;

        pairs.sort_unstable();
        pairs.dedup();
        let mut reader_off = vec![0u32; n_slots + 1];
        for &(s, _) in &pairs {
            reader_off[s as usize + 1] += 1;
        }
        for i in 0..n_slots {
            reader_off[i + 1] += reader_off[i];
        }
        let reader_lvls: Vec<u32> = pairs.iter().map(|&(_, b)| b).collect();

        let seqs: Vec<TapeSeq> = ir
            .seqs
            .iter()
            .filter(|s| included(s.inst))
            .map(|s| TapeSeq {
                kind: s.kind,
                inst: s.inst,
                ins: s.ins,
                n_ins: s.n_ins,
                state_off: s.state_off,
                n_state: s.n_state,
                domain: s.domain,
                bucket: bucket_of_inst[s.inst as usize],
            })
            .collect();
        debug_assert!(
            seqs.iter().all(|s| s.bucket != u32::MAX),
            "sequential instance without a comb op"
        );
        let consts: Vec<ConstCell> = ir
            .consts
            .iter()
            .filter(|c| included(c.inst))
            .copied()
            .collect();

        Tape {
            ops,
            level_start,
            dirty: vec![true; n_buckets],
            bucket_of_inst,
            reader_off,
            reader_lvls,
            reads_any,
            driver_level,
            folded: ir.folded.clone(),
            values: vec![0; n_slots],
            state: vec![0; ir.total_state],
            next: vec![0; ir.total_state],
            state_off: ir.state_off.clone(),
            state_bits: ir.state_bits.clone(),
            seqs,
            consts,
            primed: false,
            activity: Activity::new(ir.n_insts),
            obs_levels_eval: 0,
            obs_levels_skip: 0,
            obs_ops_retired: 0,
            scratch_ins: [0; 16],
            scratch_outs: [0; 8],
            faults: None,
        }
    }

    /// Take and reset the quiescence/throughput tallies:
    /// `(levels_evaluated, levels_skipped, ops_retired)`.
    pub(crate) fn obs_drain(&mut self) -> (u64, u64, u64) {
        let out = (
            self.obs_levels_eval,
            self.obs_levels_skip,
            self.obs_ops_retired,
        );
        self.obs_levels_eval = 0;
        self.obs_levels_skip = 0;
        self.obs_ops_retired = 0;
        out
    }

    /// Slot (net) count.
    pub fn n_slots(&self) -> usize {
        self.values.len()
    }

    /// Tape op count (post-optimization; the bench-reported quantity).
    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// True when a fault on `net` has no live forcing site here.
    pub fn fault_site_lost(&self, net: usize) -> bool {
        self.folded[net]
    }

    /// Current value word of a slot.
    pub(crate) fn word(&self, slot: usize) -> u64 {
        self.values[slot]
    }

    /// All slot values (the sharded observer view borrows this).
    pub(crate) fn values(&self) -> &[u64] {
        &self.values
    }

    /// Per-instance counters (`cycles` owned by the driving wrapper).
    pub(crate) fn activity(&self) -> &Activity {
        &self.activity
    }

    pub(crate) fn activity_mut(&mut self) -> &mut Activity {
        &mut self.activity
    }

    /// Install a fault overlay.  Panics when a static site was folded
    /// away — [`CompiledSimulator::install_faults`] and the campaign
    /// driver precheck via [`Tape::fault_site_lost`] and fall back to
    /// an interpreter instead of ever hitting this.
    pub(crate) fn install_faults(&mut self, overlay: FaultOverlay) {
        assert_eq!(overlay.n_nets(), self.values.len(), "overlay size");
        if let Some(n) =
            overlay.static_nets().find(|&n| self.folded[n])
        {
            panic!(
                "static fault on net {n}: write site folded away \
                 (precheck with fault_site_lost / use an interpreter)"
            );
        }
        self.faults = Some(Box::new(overlay));
    }

    pub(crate) fn clear_faults(&mut self) {
        self.faults = None;
    }

    /// Stage the transient fault events of one tick that this tape
    /// owns, mirroring the sharded parts: glitches re-arm the driving
    /// bucket, SEUs queue for the post-commit phase.  A glitch on a
    /// folded slot panics (no write site left to force it at).
    pub(crate) fn stage_tick_faults(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
        mask: u64,
    ) {
        for &(n, l) in glitches {
            assert!(
                l & mask == 0 || !self.folded[n.0 as usize],
                "glitch on net {}: write site folded away \
                 (precheck with fault_site_lost / use an interpreter)",
                n.0
            );
        }
        let owns = glitches.iter().any(|&(n, l)| {
            l & mask != 0 && self.driver_level[n.0 as usize] != u32::MAX
        }) || seus.iter().any(|s| {
            s.lanes & mask != 0
                && self.bucket_of_inst[s.inst as usize] != u32::MAX
        });
        if !owns {
            return;
        }
        if self.faults.is_none() {
            self.faults =
                Some(Box::new(FaultOverlay::new(self.values.len())));
        }
        let f = self.faults.as_deref_mut().expect("just installed");
        for &(net, lanes) in glitches {
            let lvl = self.driver_level[net.0 as usize];
            if lanes & mask != 0 && lvl != u32::MAX {
                f.add_glitch(net, lanes & mask);
                self.dirty[lvl as usize] = true;
            }
        }
        for &seu in seus {
            if seu.lanes & mask != 0
                && self.bucket_of_inst[seu.inst as usize] != u32::MAX
            {
                f.push_seu(SeuFlip { lanes: seu.lanes & mask, ..seu });
            }
        }
    }

    /// Apply input words.  With `filter`, slots no pin of this tape
    /// reads are skipped (shard tapes); without, every word is stored.
    pub(crate) fn apply_inputs(
        &mut self,
        inputs: &[(NetId, u64)],
        filter: bool,
    ) {
        let Tape { reads_any, values, dirty, reader_off, reader_lvls, .. } =
            self;
        for &(n, w) in inputs {
            let ni = n.0 as usize;
            if filter && !reads_any[ni] {
                continue;
            }
            if values[ni] != w {
                values[ni] = w;
                mark(dirty, reader_off, reader_lvls, ni);
            }
        }
    }

    /// Apply published boundary words (always stored; sharded use).
    pub(crate) fn apply_words(&mut self, nets: &[NetId], words: &[u64]) {
        let Tape { values, dirty, reader_off, reader_lvls, .. } = self;
        for (&n, &w) in nets.iter().zip(words) {
            let ni = n.0 as usize;
            if values[ni] != w {
                values[ni] = w;
                mark(dirty, reader_off, reader_lvls, ni);
            }
        }
    }

    /// Run the prologue (first tick after reset), the gated tape, the
    /// per-domain sequential commit and the post-commit fault phase —
    /// one engine tick.  Mirrors the sharded parts' `settle_commit`
    /// step for step, so gated and ungated runs are bit-identical.
    pub(crate) fn settle_commit(&mut self, gclk_edge: bool, mask: u64) {
        let Tape {
            ops,
            level_start,
            dirty,
            bucket_of_inst,
            reader_off,
            reader_lvls,
            values,
            state,
            next,
            state_off,
            state_bits,
            seqs,
            consts,
            primed,
            activity,
            obs_levels_eval,
            obs_levels_skip,
            obs_ops_retired,
            scratch_ins,
            scratch_outs,
            faults,
            ..
        } = self;

        // Forced-write + toggle-count discipline shared by every write
        // site below: force through the overlay (a diverging force
        // re-arms the bucket so the site is re-forced next tick), count
        // masked toggles, store and wake readers on any change.
        macro_rules! store {
            ($b:expr, $out:expr, $inst:expr, $raw:expr) => {{
                let out: usize = $out;
                let raw: u64 = $raw;
                let v = match faults.as_deref_mut() {
                    Some(f) => {
                        let fv = f.force(out, raw);
                        if fv != raw {
                            dirty[$b] = true;
                        }
                        fv
                    }
                    None => raw,
                };
                let diff = (values[out] ^ v) & mask;
                if values[out] != v {
                    values[out] = v;
                    mark(dirty, reader_off, reader_lvls, out);
                }
                if diff != 0 {
                    activity.toggles[$inst] += u64::from(diff.count_ones());
                }
            }};
        }

        // Reset prologue: retired constants settle exactly once, with
        // the same first-tick toggle credit the interpreters count for
        // constant cones (overlays never touch these slots — folded
        // statics and glitches are rejected at installation).
        if !*primed {
            *primed = true;
            for c in consts.iter() {
                let w = if c.value { !0u64 } else { 0 };
                let slot = c.slot as usize;
                let diff = (values[slot] ^ w) & mask;
                if values[slot] != w {
                    values[slot] = w;
                    mark(dirty, reader_off, reader_lvls, slot);
                }
                if diff != 0 {
                    activity.toggles[c.inst as usize] +=
                        u64::from(diff.count_ones());
                }
            }
        }

        // The tape proper: dirty buckets in depth order.
        for b in 0..dirty.len() {
            if !dirty[b] {
                *obs_levels_skip += 1;
                continue;
            }
            *obs_levels_eval += 1;
            dirty[b] = false;
            let start = level_start[b] as usize;
            let end = level_start[b + 1] as usize;
            *obs_ops_retired += (end - start) as u64;
            for op in &ops[start..end] {
                match op {
                    TapeOp::Gate(g) => {
                        let x = [
                            values[g.ins[0] as usize],
                            values[g.ins[1] as usize],
                            values[g.ins[2] as usize],
                            values[g.ins[3] as usize],
                        ];
                        let v = eval_gate_word(g.g, x);
                        store!(b, g.out as usize, g.inst as usize, v);
                    }
                    TapeOp::Fused(a, c) => {
                        let x = [
                            values[a.ins[0] as usize],
                            values[a.ins[1] as usize],
                            values[a.ins[2] as usize],
                            values[a.ins[3] as usize],
                        ];
                        let v = eval_gate_word(a.g, x);
                        store!(b, a.out as usize, a.inst as usize, v);
                        // The consumer reads the *stored* (possibly
                        // forced) producer value, as the interpreters do.
                        let y = [
                            values[c.ins[0] as usize],
                            values[c.ins[1] as usize],
                            values[c.ins[2] as usize],
                            values[c.ins[3] as usize],
                        ];
                        let w = eval_gate_word(c.g, y);
                        store!(b, c.out as usize, c.inst as usize, w);
                    }
                    TapeOp::Wide(w) => {
                        let n_in = w.n_ins as usize;
                        let n_out = w.n_outs as usize;
                        let ns = w.n_state as usize;
                        for k in 0..n_in {
                            scratch_ins[k] = values[w.ins[k] as usize];
                        }
                        let off = w.state_off as usize;
                        eval_comb_packed(
                            w.kind,
                            &scratch_ins[..n_in],
                            &state[off..off + ns],
                            &mut scratch_outs[..n_out],
                        );
                        for k in 0..n_out {
                            store!(
                                b,
                                w.outs[k] as usize,
                                w.inst as usize,
                                scratch_outs[k]
                            );
                        }
                    }
                }
            }
        }

        // Next-state + commit per domain; a state change re-arms the
        // owner's bucket so its Q output is recomputed next tick.
        let active = u64::from(mask.count_ones());
        let mut sins = [0u64; MAX_SEQ_INS];
        for s in seqs.iter() {
            let commit = match s.domain {
                ClockDomain::Aclk => true,
                ClockDomain::Gclk => gclk_edge,
                ClockDomain::Comb => false,
            };
            if !commit {
                continue;
            }
            let n_in = s.n_ins as usize;
            for k in 0..n_in {
                sins[k] = values[s.ins[k] as usize];
            }
            let off = s.state_off as usize;
            let ns = s.n_state as usize;
            {
                let (cur, nxt) =
                    (&state[off..off + ns], &mut next[off..off + ns]);
                next_state_packed(s.kind, &sins[..n_in], cur, nxt);
            }
            if state[off..off + ns] != next[off..off + ns] {
                state[off..off + ns]
                    .copy_from_slice(&next[off..off + ns]);
                dirty[s.bucket as usize] = true;
            }
            activity.clock_ticks[s.inst as usize] += active;
        }

        // SEUs land after the commit (visible next tick); the upset
        // instance's bucket is re-armed so the flip propagates.
        if let Some(f) = faults.as_deref_mut() {
            for seu in f.take_seus() {
                let i = seu.inst as usize;
                if bucket_of_inst[i] == u32::MAX {
                    continue;
                }
                if (seu.bit as usize) < state_bits[i] as usize {
                    let off = state_off[i] as usize;
                    state[off + seu.bit as usize] ^= seu.lanes;
                    dirty[bucket_of_inst[i] as usize] = true;
                }
            }
            f.end_tick();
        }
    }

    /// Zero values and state; re-arm every bucket and the prologue.
    pub(crate) fn reset(&mut self) {
        self.values.iter_mut().for_each(|v| *v = 0);
        self.state.iter_mut().for_each(|v| *v = 0);
        self.dirty.iter_mut().for_each(|d| *d = true);
        self.primed = false;
    }
}

/// Record drained tape tallies in a metrics registry: the
/// quiescence-skip ratio (`tnn7_sim_levels_total{outcome=...}`) and
/// ops retired (`tnn7_sim_tape_ops_total`), labeled by engine so the
/// standalone compiled engine and the sharded engine's per-part tapes
/// stay distinguishable.
pub(crate) fn flush_tape_obs(
    obs: &crate::obs::Registry,
    engine: &str,
    eval: u64,
    skip: u64,
    ops: u64,
) {
    obs.counter(
        "tnn7_sim_levels_total",
        "Level buckets visited, by quiescence-gating outcome",
        &[("engine", engine), ("outcome", "evaluated")],
    )
    .add(eval);
    obs.counter(
        "tnn7_sim_levels_total",
        "Level buckets visited, by quiescence-gating outcome",
        &[("engine", engine), ("outcome", "skipped")],
    )
    .add(skip);
    obs.counter(
        "tnn7_sim_tape_ops_total",
        "Compiled-tape ops retired",
        &[("engine", engine)],
    )
    .add(ops);
}

/// Compiled-tape simulation instance over a netlist: lower → optimize
/// → flatten, then tick like the packed engine (bit-identically).
pub struct CompiledSimulator {
    tape: Tape,
    stats: Vec<PassStats>,
    passes: String,
    lanes: usize,
    mask: u64,
    cycle: u64,
}

impl CompiledSimulator {
    /// Compile `nl` with the full pass pipeline for `lanes` (1..=64)
    /// stimulus lanes.
    pub fn new(
        nl: &Netlist,
        lib: &Library,
        lanes: usize,
    ) -> Result<CompiledSimulator> {
        CompiledSimulator::with_passes(nl, lib, lanes, &PassManager::all())
    }

    /// Compile `nl` with an explicit pass pipeline.
    pub fn with_passes(
        nl: &Netlist,
        lib: &Library,
        lanes: usize,
        pm: &PassManager,
    ) -> Result<CompiledSimulator> {
        let mut ir = lower(nl, lib)?;
        let stats = pm.run(&mut ir);
        CompiledSimulator::from_ir(&ir, stats, pm.canonical(), lanes)
    }

    /// Build from an already-optimized IR (parallel drivers compile the
    /// IR once and build one tape per worker).
    pub fn from_ir(
        ir: &WordIr,
        stats: Vec<PassStats>,
        passes: String,
        lanes: usize,
    ) -> Result<CompiledSimulator> {
        if !(1..=MAX_LANES).contains(&lanes) {
            return Err(Error::sim(format!(
                "compiled engine supports 1..={MAX_LANES} lanes, got {lanes}"
            )));
        }
        Ok(CompiledSimulator {
            tape: Tape::new(ir),
            stats,
            passes,
            lanes,
            mask: mask_for(lanes),
            cycle: 0,
        })
    }

    /// Number of lanes the engine was built for.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Number of currently-active (activity-counted) lanes.
    pub fn active_lanes(&self) -> usize {
        self.mask.count_ones() as usize
    }

    /// Shrink the active-lane set to the first `n` lanes (`n ≤ lanes`);
    /// inactive lanes keep simulating but are excluded from activity.
    pub fn set_active_lanes(&mut self, n: usize) {
        assert!(
            (1..=self.lanes).contains(&n),
            "active lanes 1..={}",
            self.lanes
        );
        self.mask = mask_for(n);
    }

    /// Per-pass statistics of the compile.
    pub fn pass_stats(&self) -> &[PassStats] {
        &self.stats
    }

    /// Canonical pass-pipeline spec this engine was compiled with.
    pub fn passes(&self) -> &str {
        &self.passes
    }

    /// Tape op count after optimization.
    pub fn n_ops(&self) -> usize {
        self.tape.n_ops()
    }

    /// Drain the tape's quiescence/throughput tallies into `obs`
    /// (one batched flush per run; see [`flush_tape_obs`]).
    pub fn obs_flush(&mut self, obs: &crate::obs::Registry) {
        let (eval, skip, ops) = self.tape.obs_drain();
        flush_tape_obs(obs, "compiled", eval, skip, ops);
    }

    /// True when a fault on `net` could not be forced faithfully here
    /// (the campaign precheck; installation would be refused).
    pub fn fault_site_lost(&self, net: NetId) -> bool {
        self.tape.fault_site_lost(net.0 as usize)
    }

    /// True when every static site of `overlay` still has a live
    /// forcing site ([`CompiledSimulator::install_faults`] would
    /// succeed).
    pub fn supports_overlay(&self, overlay: &FaultOverlay) -> bool {
        overlay.static_nets().all(|n| !self.tape.fault_site_lost(n))
    }

    /// Install a fault overlay, or refuse it when a static site was
    /// optimized away (the caller falls back to an interpreter).
    pub fn install_faults(&mut self, overlay: FaultOverlay) -> Result<()> {
        if overlay.n_nets() != self.tape.n_slots() {
            return Err(Error::sim(format!(
                "fault overlay sized for {} nets, netlist has {}",
                overlay.n_nets(),
                self.tape.n_slots()
            )));
        }
        if let Some(n) = overlay
            .static_nets()
            .find(|&n| self.tape.fault_site_lost(n))
        {
            return Err(Error::sim(format!(
                "compiled engine cannot force net {n}: its write site \
                 was optimized away (run with fewer passes or an \
                 interpreter engine)"
            )));
        }
        self.tape.install_faults(overlay);
        Ok(())
    }

    /// Remove the fault overlay.
    pub fn clear_faults(&mut self) {
        self.tape.clear_faults();
    }

    /// Schedule transient faults for the next tick (glitches and
    /// post-commit SEUs, restricted to active lanes).  Panics on a
    /// glitch whose write site was optimized away — precheck with
    /// [`CompiledSimulator::fault_site_lost`].
    pub fn set_tick_faults(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
    ) {
        self.tape.stage_tick_faults(glitches, seus, self.mask);
    }

    /// Current value of a net in one lane.
    pub fn get(&self, net: NetId, lane: usize) -> bool {
        debug_assert!(lane < self.lanes);
        self.tape.word(net.0 as usize) >> lane & 1 == 1
    }

    /// Current value word of a net (bit `k` = lane `k`).
    pub fn get_word(&self, net: NetId) -> u64 {
        self.tape.word(net.0 as usize)
    }

    /// Ticks executed since construction or the last reset.
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Reset all state and net values to 0, clear the cycle counter,
    /// re-arm the prologue, and restore the full active-lane mask.
    /// Activity counters are preserved, as in the other engines.
    pub fn reset(&mut self) {
        self.tape.reset();
        self.cycle = 0;
        self.mask = mask_for(self.lanes);
    }

    /// Aggregated switching-activity counters.
    pub fn activity(&self) -> &Activity {
        self.tape.activity()
    }

    /// Run one `aclk` cycle across all lanes (packed-tick semantics).
    pub fn tick(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool) {
        self.tape.apply_inputs(inputs, false);
        self.tape.settle_commit(gclk_edge, self.mask);
        self.cycle += 1;
        self.tape.activity_mut().cycles +=
            u64::from(self.mask.count_ones());
    }
}

impl SimEngine for CompiledSimulator {
    fn lanes(&self) -> usize {
        self.lanes
    }

    fn tick_lanes(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool) {
        self.tick(inputs, gclk_edge);
    }

    fn lane_value(&self, net: NetId, lane: usize) -> bool {
        self.get(net, lane)
    }

    fn activity(&self) -> &Activity {
        self.tape.activity()
    }

    fn activity_mut(&mut self) -> &mut Activity {
        self.tape.activity_mut()
    }

    fn ticks(&self) -> u64 {
        self.cycle
    }

    fn reset_state(&mut self) {
        self.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::fault_sites;
    use crate::netlist::column::{build_column, ColumnSpec};
    use crate::netlist::Flavor;
    use crate::sim::PackedSimulator;

    fn column(flavor: Flavor) -> (Library, Netlist) {
        let lib = Library::with_macros();
        let spec = ColumnSpec { p: 4, q: 2, theta: 6 };
        let (nl, _) = build_column(&lib, flavor, &spec).unwrap();
        (lib, nl)
    }

    fn drive_both(
        nl: &Netlist,
        cs: &mut CompiledSimulator,
        pk: &mut PackedSimulator,
        ticks: u32,
        seed: u64,
    ) {
        let mut rng = seed;
        for t in 0..ticks {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
            let gamma = rng >> 60 & 3 == 0;
            let inputs: Vec<(NetId, u64)> = nl
                .inputs
                .iter()
                .map(|&n| {
                    rng = rng
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1);
                    (n, rng)
                })
                .collect();
            cs.tick(&inputs, gamma);
            pk.tick(&inputs, gamma);
            for net in 0..nl.n_nets() {
                let id = NetId(net as u32);
                for lane in 0..cs.lanes() {
                    assert_eq!(
                        cs.get(id, lane),
                        pk.get(id, lane),
                        "tick {t} net {net} lane {lane}"
                    );
                }
            }
        }
        assert_eq!(cs.activity().toggles, pk.activity.toggles);
        assert_eq!(cs.activity().clock_ticks, pk.activity.clock_ticks);
        assert_eq!(cs.activity().cycles, pk.activity.cycles);
    }

    /// Fully-optimized tape vs the packed interpreter: every net, every
    /// lane, every tick, and the complete activity — both flavours.
    #[test]
    fn compiled_matches_packed_on_columns() {
        for flavor in [Flavor::Std, Flavor::Custom] {
            let (lib, nl) = column(flavor);
            let mut cs = CompiledSimulator::new(&nl, &lib, 8).unwrap();
            let mut pk = PackedSimulator::new(&nl, &lib, 8).unwrap();
            assert!(cs.n_ops() < nl.insts.len(), "passes reduced ops");
            drive_both(&nl, &mut cs, &mut pk, 40, 0x9e37_79b9_7f4a_7c15);
        }
    }

    /// The unoptimized tape (passes = none) is also bit-identical.
    #[test]
    fn unoptimized_tape_matches_packed() {
        let (lib, nl) = column(Flavor::Custom);
        let mut cs = CompiledSimulator::with_passes(
            &nl,
            &lib,
            4,
            &PassManager::none(),
        )
        .unwrap();
        assert_eq!(cs.n_ops(), nl.insts.len());
        assert_eq!(cs.passes(), "none");
        let mut pk = PackedSimulator::new(&nl, &lib, 4).unwrap();
        drive_both(&nl, &mut cs, &mut pk, 30, 0x1234_5678_9abc_def0);
    }

    /// Static + transient faults stay bit-identical when every site
    /// survives (coalesce/resched keep all write sites).
    #[test]
    fn faulted_compiled_matches_faulted_packed() {
        let (lib, nl) = column(Flavor::Custom);
        let pm = PassManager::parse("coalesce,resched").unwrap();
        let mut cs =
            CompiledSimulator::with_passes(&nl, &lib, 8, &pm).unwrap();
        let mut pk = PackedSimulator::new(&nl, &lib, 8).unwrap();
        let sites = fault_sites(&nl, &lib);
        let net_a = sites.outs[0];
        let net_b = sites.outs[sites.outs.len() / 2];
        let net_c = *sites.outs.last().unwrap();
        let (seu_inst, seu_bit) = sites.seq[0];
        let mut overlay = FaultOverlay::new(nl.n_nets());
        overlay.add_stuck0(net_a, !0);
        overlay.add_stuck1(net_b, 0b1010);
        overlay.add_delay(net_c, !0);
        cs.install_faults(overlay.clone()).unwrap();
        pk.install_faults(overlay);
        let mut rng = 0xfeed_beef_dead_cafeu64;
        for t in 0..30u32 {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
            let gamma = rng >> 60 & 3 == 0;
            if t == 12 {
                let g = [(net_b, 0b0101u64)];
                let s = [SeuFlip {
                    inst: seu_inst,
                    bit: seu_bit,
                    lanes: 0b11,
                }];
                cs.set_tick_faults(&g, &s);
                pk.set_tick_faults(&g, &s);
            }
            let inputs: Vec<(NetId, u64)> = nl
                .inputs
                .iter()
                .map(|&n| {
                    rng = rng
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1);
                    (n, rng)
                })
                .collect();
            cs.tick(&inputs, gamma);
            pk.tick(&inputs, gamma);
            for net in 0..nl.n_nets() {
                let id = NetId(net as u32);
                for lane in 0..8 {
                    assert_eq!(
                        cs.get(id, lane),
                        pk.get(id, lane),
                        "tick {t} net {net} lane {lane}"
                    );
                }
            }
        }
        assert_eq!(cs.activity().toggles, pk.activity.toggles);
        assert_eq!(cs.activity().clock_ticks, pk.activity.clock_ticks);
    }

    /// A static fault on an optimized-away site is refused (the caller
    /// falls back to an interpreter), and the lost site is visible
    /// through the precheck.
    #[test]
    fn folded_static_sites_are_rejected() {
        let (lib, nl) = column(Flavor::Custom);
        let mut ir = lower(&nl, &lib).unwrap();
        let pm = PassManager::all();
        let stats = pm.run(&mut ir);
        let lost = ir.consts[0].slot;
        let mut cs =
            CompiledSimulator::from_ir(&ir, stats, pm.canonical(), 4)
                .unwrap();
        assert!(cs.fault_site_lost(NetId(lost)));
        let mut overlay = FaultOverlay::new(nl.n_nets());
        overlay.add_stuck1(NetId(lost), !0);
        assert!(!cs.supports_overlay(&overlay));
        assert!(cs.install_faults(overlay).is_err());
        // A supported overlay still installs.
        let sites = fault_sites(&nl, &lib);
        let live = sites
            .outs
            .iter()
            .find(|&&n| !cs.fault_site_lost(n))
            .copied()
            .unwrap();
        let mut ok = FaultOverlay::new(nl.n_nets());
        ok.add_stuck0(live, 1);
        assert!(cs.supports_overlay(&ok));
        cs.install_faults(ok).unwrap();
    }

    /// Reset re-arms the prologue: a second measurement window counts
    /// the constant cones' first-tick toggles again, like the packed
    /// engine does.
    #[test]
    fn reset_reprimes_the_prologue() {
        let (lib, nl) = column(Flavor::Custom);
        let mut cs = CompiledSimulator::new(&nl, &lib, 4).unwrap();
        let mut pk = PackedSimulator::new(&nl, &lib, 4).unwrap();
        drive_both(&nl, &mut cs, &mut pk, 10, 0xabcd_ef01_2345_6789);
        cs.reset();
        pk.reset();
        assert_eq!(cs.cycle(), 0);
        drive_both(&nl, &mut cs, &mut pk, 10, 0x0f0f_0f0f_0f0f_0f0f);
    }

    #[test]
    fn lane_count_bounds_are_enforced() {
        let (lib, nl) = column(Flavor::Std);
        assert!(CompiledSimulator::new(&nl, &lib, 0).is_err());
        assert!(CompiledSimulator::new(&nl, &lib, 65).is_err());
        assert!(CompiledSimulator::new(&nl, &lib, 64).is_ok());
    }
}
