//! The common engine interface over the scalar and packed simulators.
//!
//! [`SimEngine`] is the lane-oriented contract every engine satisfies:
//! the scalar [`Simulator`] is the single-lane reference
//! implementation, [`PackedSimulator`] the 64-lane production engine,
//! and [`super::ShardedSimulator`] the thread-parallel sharded engine
//! (implemented in [`super::sharded`]).  Code written against the
//! trait (testbenches, equivalence tests, benches) runs unchanged on
//! any of them, which is what makes the cross-engine equivalence tests
//! possible (DESIGN.md §7–8).
//!
//! Method names are chosen not to collide with the engines' inherent
//! APIs: `tick_lanes` takes word-packed inputs (bit `k` = lane `k`;
//! the scalar engine reads bit 0 only), `lane_value` reads one lane of
//! one net.

use crate::netlist::NetId;

use super::activity::Activity;
use super::packed::PackedSimulator;
use super::Simulator;

/// A cycle-based simulation engine evaluating one or more independent
/// stimulus lanes per tick.
pub trait SimEngine {
    /// Number of independent stimulus lanes evaluated per tick.
    fn lanes(&self) -> usize;

    /// Run one `aclk` cycle.  Each input word carries one bit per lane
    /// (bit `k` = lane `k`; lanes at and above [`SimEngine::lanes`] are
    /// ignored).  `gclk_edge` flags an end-of-wave tick (gamma-domain
    /// commit) shared by every lane.
    fn tick_lanes(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool);

    /// Current value of `net` in `lane`.
    fn lane_value(&self, net: NetId, lane: usize) -> bool;

    /// Aggregated switching-activity counters (summed over lanes).
    fn activity(&self) -> &Activity;

    /// Mutable access to the activity counters (e.g. to reset between
    /// measurement phases).
    fn activity_mut(&mut self) -> &mut Activity;

    /// Ticks executed since construction or the last reset.
    fn ticks(&self) -> u64;

    /// Reset all net values and state to 0 (activity is preserved).
    fn reset_state(&mut self);
}

impl SimEngine for Simulator<'_> {
    fn lanes(&self) -> usize {
        1
    }

    fn tick_lanes(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool) {
        // Reuse the simulator's scratch buffer instead of collecting a
        // fresh Vec every tick (taken out and restored around `tick`,
        // which borrows `self` mutably).
        let mut scalar = std::mem::take(&mut self.lane_scratch);
        scalar.clear();
        scalar.extend(inputs.iter().map(|&(n, w)| (n, w & 1 == 1)));
        self.tick(&scalar, gclk_edge);
        self.lane_scratch = scalar;
    }

    fn lane_value(&self, net: NetId, lane: usize) -> bool {
        debug_assert_eq!(lane, 0, "scalar engine has a single lane");
        self.get(net)
    }

    fn activity(&self) -> &Activity {
        &self.activity
    }

    fn activity_mut(&mut self) -> &mut Activity {
        &mut self.activity
    }

    fn ticks(&self) -> u64 {
        self.cycle()
    }

    fn reset_state(&mut self) {
        self.reset();
    }
}

impl SimEngine for PackedSimulator<'_> {
    fn lanes(&self) -> usize {
        self.lanes()
    }

    fn tick_lanes(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool) {
        self.tick(inputs, gclk_edge);
    }

    fn lane_value(&self, net: NetId, lane: usize) -> bool {
        self.get(net, lane)
    }

    fn activity(&self) -> &Activity {
        &self.activity
    }

    fn activity_mut(&mut self) -> &mut Activity {
        &mut self.activity
    }

    fn ticks(&self) -> u64 {
        self.cycle()
    }

    fn reset_state(&mut self) {
        self.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;
    use crate::netlist::Builder;

    /// The same trait-level drive produces the same lane-0 trace on
    /// both engines.
    #[test]
    fn trait_drive_is_engine_agnostic() {
        let lib = Library::asap7_only();
        let mut b = Builder::new("chain", &lib);
        let x = b.input("x");
        let mut n = x;
        for _ in 0..5 {
            n = b.inv(n);
        }
        b.output(n, "y");
        let nl = b.finish().unwrap();

        fn drive<E: SimEngine>(e: &mut E, nl: &crate::netlist::Netlist) -> Vec<bool> {
            let mut out = Vec::new();
            for t in 0..8u64 {
                e.tick_lanes(&[(nl.inputs[0], t & 1)], t % 4 == 3);
                out.push(e.lane_value(nl.outputs[0], 0));
            }
            out
        }

        let mut s = crate::sim::Simulator::new(&nl, &lib).unwrap();
        let mut p = PackedSimulator::new(&nl, &lib, 4).unwrap();
        assert_eq!(SimEngine::lanes(&s), 1);
        assert_eq!(SimEngine::lanes(&p), 4);
        let ts = drive(&mut s, &nl);
        let tp = drive(&mut p, &nl);
        assert_eq!(ts, tp);
        // Scalar counted 1 lane per tick, packed 4.
        assert_eq!(SimEngine::activity(&s).cycles, 8);
        assert_eq!(SimEngine::activity(&p).cycles, 32);
    }
}
