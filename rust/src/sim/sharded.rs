//! The thread-parallel sharded simulation engine.
//!
//! [`ShardedSimulator`] runs the column-aligned partition produced by
//! [`crate::netlist::partition`] as a three-phase tick on
//! `std::thread::scope` worker threads (no external dependencies):
//!
//! 1. **head** — the coordinating thread evaluates the zero-input
//!    constant drivers and broadcasts their outputs together with the
//!    tick's primary-input words to every shard.
//! 2. **shards** — one worker per shard evaluates its instances in
//!    level order and commits its own sequential state, then publishes
//!    the settled words of its *boundary nets* (tail-read nets, primary
//!    outputs, and any caller-watched nets).
//! 3. **tail** — the coordinating thread applies the published
//!    boundary words and evaluates the join logic (the voter/output
//!    layer of a multi-column netlist).
//!
//! Every instance is evaluated exactly once per tick with exactly the
//! values the single-thread [`super::PackedSimulator`] would produce —
//! shards read only global and own nets, the tail reads boundary nets
//! post-settle — and each part counts toggles with the same
//! `popcount((old ^ new) & mask)` rule, so the aggregated
//! [`Activity`] is **bit-identical** to the packed engine's
//! (`prop_sharded_engine_equals_packed_single_thread` in
//! `tests/proptests.rs` is the correctness anchor; DESIGN.md §8).
//!
//! Each part is additionally **quiescence-gated**: nodes are grouped
//! by combinational depth, and a level is skipped whenever none of the
//! nets its nodes depend on combinationally (including committed state)
//! changed since the level last ran.  Skipping is exact, not
//! approximate — a level with unchanged inputs and state reproduces its
//! stored outputs and contributes zero toggles, so gated and ungated
//! runs have identical counters.  On sparse temporal-coding stimulus,
//! where most columns sit idle between spikes, whole shards go quiet
//! for most of a wave.

use std::sync::mpsc;
use std::sync::Arc;

use crate::cells::Library;
use crate::error::{Error, Result};
use crate::fault::{FaultOverlay, SeuFlip};
use crate::ir::{lower, PassId, PassManager, PassStats};
use crate::netlist::partition::{partition, Partition};
use crate::netlist::{ClockDomain, NetId, Netlist};

use super::activity::Activity;
use super::compiled::Tape;
use super::eval::{comb_deps, eval_comb_packed, next_state_packed};
use super::packed::MAX_LANES;
use super::simulator::{comb_levels, plan, EvalNode};

/// One scheduled simulator tick: primary-input words plus the shared
/// gamma-edge flag.
#[derive(Debug, Clone)]
pub struct SimTick {
    /// Primary-input assignments (bit `k` = lane `k`).
    pub inputs: Vec<(NetId, u64)>,
    /// End-of-wave flag shared by every lane (gamma-domain commit).
    pub gclk_edge: bool,
}

/// Read-only view handed to [`ShardedSimulator::run_ticks_observe`]
/// after each tick completes.
///
/// Valid for every net the coordinating thread holds: primary inputs,
/// head (tie) outputs, published boundary nets — which always include
/// the netlist's primary outputs and the constructor's watch list —
/// and tail-driven nets.  Reading an unpublished shard-internal net
/// returns its stale pre-run value.
pub struct MainView<'a> {
    values: &'a [u64],
}

impl MainView<'_> {
    /// Current value word of a net (bit `k` = lane `k`).
    pub fn word(&self, net: NetId) -> u64 {
        self.values[net.0 as usize]
    }

    /// Current value of a net in one lane.
    pub fn get(&self, net: NetId, lane: usize) -> bool {
        self.word(net) >> lane & 1 == 1
    }
}

/// Work order sent to a shard worker for one tick.
#[derive(Clone)]
struct Job {
    inputs: Arc<Vec<(NetId, u64)>>,
    gclk_edge: bool,
    mask: u64,
    /// Transient fault events for this tick (each part applies only
    /// the events it owns).
    faults: Option<Arc<TickFaults>>,
}

/// Transient fault events staged for exactly one tick.
#[derive(Debug, Default)]
struct TickFaults {
    glitches: Vec<(NetId, u64)>,
    seus: Vec<SeuFlip>,
}

fn mask_for(lanes: usize) -> u64 {
    if lanes >= MAX_LANES {
        !0
    } else {
        (1u64 << lanes) - 1
    }
}

/// Mark every level that combinationally reads `net` as dirty.
fn mark(dirty: &mut [bool], off: &[u32], lvls: &[u32], net: usize) {
    for &b in &lvls[off[net] as usize..off[net + 1] as usize] {
        dirty[b as usize] = true;
    }
}

/// One partition part: a quiescence-gated packed evaluator over a
/// subset of the netlist's instances.
struct PartSim<'n> {
    nl: &'n Netlist,
    lib: &'n Library,
    /// This part's nodes, sorted by combinational depth.
    nodes: Vec<EvalNode>,
    /// Node-range boundaries per depth level (`len = n_levels + 1`).
    level_start: Vec<u32>,
    /// Per-level dirty flags; a clean level is skipped wholesale.
    dirty: Vec<bool>,
    /// Global instance index → this part's level index.
    bucket_of_inst: Vec<u32>,
    /// CSR: net → levels of this part that comb-read it.
    reader_off: Vec<u32>,
    reader_lvls: Vec<u32>,
    /// Net is read by any pin (comb or sequential) of this part.
    reads_any: Vec<bool>,
    /// Full-size net/state images (only this part's slots are live).
    values: Vec<u64>,
    state: Vec<u64>,
    next: Vec<u64>,
    state_off: Vec<u32>,
    /// This part's sequential instances.
    seq: Vec<u32>,
    /// Full-size counters; `cycles` stays 0 (counted once globally).
    activity: Activity,
    scratch_ins: Vec<u64>,
    scratch_outs: Vec<u64>,
    /// Net → this part's level driving it (`u32::MAX` = not driven
    /// here); used to route fault events to their owning part.
    driver_level: Vec<u32>,
    /// Installed fault overlay (`None` keeps the hot path fault-free).
    faults: Option<Box<FaultOverlay>>,
    /// Observability tallies — plain integers bumped on the hot path
    /// and drained once per run by the coordinator: levels evaluated,
    /// levels skipped by quiescence gating, node evals retired.
    obs_levels_eval: u64,
    obs_levels_skip: u64,
    obs_ops_retired: u64,
}

impl<'n> PartSim<'n> {
    fn new(
        nl: &'n Netlist,
        lib: &'n Library,
        insts: &[u32],
        levels: &[u32],
        state_off: Vec<u32>,
        total_state: u32,
    ) -> PartSim<'n> {
        let n_insts = nl.insts.len();
        let n_nets = nl.n_nets();
        let mut ids: Vec<u32> = insts.to_vec();
        ids.sort_unstable_by_key(|&i| (levels[i as usize], i));

        let mut nodes = Vec::with_capacity(ids.len());
        let mut level_start: Vec<u32> = Vec::new();
        let mut bucket_of_inst = vec![u32::MAX; n_insts];
        let mut seq = Vec::new();
        let mut last_level = u32::MAX;
        for (k, &i) in ids.iter().enumerate() {
            let iu = i as usize;
            let inst = nl.insts[iu];
            let kind = lib.cell(inst.cell).kind;
            let (_, _, n_state) = kind.pins();
            if levels[iu] != last_level || level_start.is_empty() {
                level_start.push(k as u32);
                last_level = levels[iu];
            }
            bucket_of_inst[iu] = level_start.len() as u32 - 1;
            if n_state > 0 {
                seq.push(i);
            }
            nodes.push(EvalNode {
                kind,
                pin_start: inst.pin_start,
                state_off: state_off[iu],
                n_ins: inst.n_ins,
                n_outs: inst.n_outs,
                n_state: n_state as u8,
                inst: i,
            });
        }
        level_start.push(ids.len() as u32);
        let n_levels = level_start.len() - 1;

        let mut reads_any = vec![false; n_nets];
        let mut driver_level = vec![u32::MAX; n_nets];
        let mut pairs: Vec<(u32, u32)> = Vec::new();
        for node in &nodes {
            let bucket = bucket_of_inst[node.inst as usize];
            let deps = comb_deps(node.kind);
            for pin in 0..node.n_ins as usize {
                let net = nl.pins[node.pin_start as usize + pin].0;
                reads_any[net as usize] = true;
                if deps >> pin & 1 == 1 {
                    pairs.push((net, bucket));
                }
            }
            for &o in nl.inst_outs(node.inst as usize) {
                driver_level[o.0 as usize] = bucket;
            }
        }
        pairs.sort_unstable();
        pairs.dedup();
        let mut reader_off = vec![0u32; n_nets + 1];
        for &(n, _) in &pairs {
            reader_off[n as usize + 1] += 1;
        }
        for i in 0..n_nets {
            reader_off[i + 1] += reader_off[i];
        }
        let reader_lvls: Vec<u32> =
            pairs.iter().map(|&(_, b)| b).collect();

        PartSim {
            nl,
            lib,
            nodes,
            level_start,
            dirty: vec![true; n_levels],
            bucket_of_inst,
            reader_off,
            reader_lvls,
            reads_any,
            values: vec![0; n_nets],
            state: vec![0; total_state as usize],
            next: vec![0; total_state as usize],
            state_off,
            seq,
            activity: Activity::new(n_insts),
            scratch_ins: vec![0; 16],
            scratch_outs: vec![0; 8],
            driver_level,
            faults: None,
            obs_levels_eval: 0,
            obs_levels_skip: 0,
            obs_ops_retired: 0,
        }
    }

    /// Take and reset the observability tallies.
    fn obs_drain(&mut self) -> (u64, u64, u64) {
        let t = (
            self.obs_levels_eval,
            self.obs_levels_skip,
            self.obs_ops_retired,
        );
        self.obs_levels_eval = 0;
        self.obs_levels_skip = 0;
        self.obs_ops_retired = 0;
        t
    }

    /// Install a fault overlay (the part forces only its own writes).
    fn install_faults(&mut self, overlay: FaultOverlay) {
        self.faults = Some(Box::new(overlay));
    }

    /// Remove the fault overlay.
    fn clear_faults(&mut self) {
        self.faults = None;
    }

    /// Stage the transient fault events of one tick that this part
    /// owns: glitches on nets it drives (re-arming the driver's level)
    /// and SEUs on sequential instances it evaluates.
    fn stage_tick_faults(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
        mask: u64,
    ) {
        let owns = glitches.iter().any(|&(n, l)| {
            l & mask != 0 && self.driver_level[n.0 as usize] != u32::MAX
        }) || seus.iter().any(|s| {
            s.lanes & mask != 0
                && self.bucket_of_inst[s.inst as usize] != u32::MAX
        });
        if !owns {
            return;
        }
        if self.faults.is_none() {
            self.faults =
                Some(Box::new(FaultOverlay::new(self.nl.n_nets())));
        }
        let f = self.faults.as_deref_mut().expect("just installed");
        for &(net, lanes) in glitches {
            let lvl = self.driver_level[net.0 as usize];
            if lanes & mask != 0 && lvl != u32::MAX {
                f.add_glitch(net, lanes & mask);
                self.dirty[lvl as usize] = true;
            }
        }
        for &seu in seus {
            if seu.lanes & mask != 0
                && self.bucket_of_inst[seu.inst as usize] != u32::MAX
            {
                f.push_seu(SeuFlip { lanes: seu.lanes & mask, ..seu });
            }
        }
    }

    /// Apply input words.  With `filter`, nets no pin of this part
    /// reads are skipped (shards); without, every word is stored (the
    /// tail, which also serves observation reads).
    fn apply_inputs(&mut self, inputs: &[(NetId, u64)], filter: bool) {
        let PartSim {
            reads_any, values, dirty, reader_off, reader_lvls, ..
        } = self;
        for &(n, w) in inputs {
            let ni = n.0 as usize;
            if filter && !reads_any[ni] {
                continue;
            }
            if values[ni] != w {
                values[ni] = w;
                mark(dirty, reader_off, reader_lvls, ni);
            }
        }
    }

    /// Apply published boundary words (always stored).
    fn apply_words(&mut self, nets: &[NetId], words: &[u64]) {
        let PartSim { values, dirty, reader_off, reader_lvls, .. } = self;
        for (&n, &w) in nets.iter().zip(words) {
            let ni = n.0 as usize;
            if values[ni] != w {
                values[ni] = w;
                mark(dirty, reader_off, reader_lvls, ni);
            }
        }
    }

    /// Evaluate dirty levels in depth order, then compute and commit
    /// next-state per clock domain — one engine tick for this part.
    fn settle_commit(&mut self, gclk_edge: bool, mask: u64) {
        let PartSim {
            nl,
            lib,
            nodes,
            level_start,
            dirty,
            bucket_of_inst,
            reader_off,
            reader_lvls,
            values,
            state,
            next,
            state_off,
            seq,
            activity,
            scratch_ins,
            scratch_outs,
            faults,
            obs_levels_eval,
            obs_levels_skip,
            obs_ops_retired,
            ..
        } = self;
        let pins = &nl.pins;
        let n_levels = dirty.len();
        for b in 0..n_levels {
            if !dirty[b] {
                *obs_levels_skip += 1;
                continue;
            }
            *obs_levels_eval += 1;
            dirty[b] = false;
            let start = level_start[b] as usize;
            let end = level_start[b + 1] as usize;
            *obs_ops_retired += (end - start) as u64;
            for node in &nodes[start..end] {
                use crate::cells::CellKind as K;
                let ps = node.pin_start as usize;
                let n_in = node.n_ins as usize;
                // Inline fast path for stateless 1-output gates,
                // mirroring the packed engine's hot loop.
                let fast = match node.kind {
                    K::Inv => Some(!values[pins[ps].0 as usize]),
                    K::Buf => Some(values[pins[ps].0 as usize]),
                    K::And2 => Some(
                        values[pins[ps].0 as usize]
                            & values[pins[ps + 1].0 as usize],
                    ),
                    K::Or2 => Some(
                        values[pins[ps].0 as usize]
                            | values[pins[ps + 1].0 as usize],
                    ),
                    K::Nand2 => Some(
                        !(values[pins[ps].0 as usize]
                            & values[pins[ps + 1].0 as usize]),
                    ),
                    K::Xor2 => Some(
                        values[pins[ps].0 as usize]
                            ^ values[pins[ps + 1].0 as usize],
                    ),
                    K::And3 => Some(
                        values[pins[ps].0 as usize]
                            & values[pins[ps + 1].0 as usize]
                            & values[pins[ps + 2].0 as usize],
                    ),
                    K::Xor3 => Some(
                        values[pins[ps].0 as usize]
                            ^ values[pins[ps + 1].0 as usize]
                            ^ values[pins[ps + 2].0 as usize],
                    ),
                    K::Maj3 => {
                        let a = values[pins[ps].0 as usize];
                        let b = values[pins[ps + 1].0 as usize];
                        let c = values[pins[ps + 2].0 as usize];
                        Some((a & b) | (b & c) | (a & c))
                    }
                    K::Mux2 => {
                        let d0 = values[pins[ps].0 as usize];
                        let d1 = values[pins[ps + 1].0 as usize];
                        let s = values[pins[ps + 2].0 as usize];
                        Some((s & d1) | (!s & d0))
                    }
                    _ => None,
                };
                if let Some(v) = fast {
                    let out_net = pins[ps + n_in].0 as usize;
                    // A forced value that diverges from the raw eval
                    // re-arms this level so the site is re-forced next
                    // tick (keeps delay shadows and releases current).
                    let v = match faults.as_deref_mut() {
                        Some(f) => {
                            let fv = f.force(out_net, v);
                            if fv != v {
                                dirty[b] = true;
                            }
                            fv
                        }
                        None => v,
                    };
                    let diff = (values[out_net] ^ v) & mask;
                    if values[out_net] != v {
                        values[out_net] = v;
                        mark(dirty, reader_off, reader_lvls, out_net);
                    }
                    if diff != 0 {
                        activity.toggles[node.inst as usize] +=
                            u64::from(diff.count_ones());
                    }
                    continue;
                }
                // General path (multi-output cells, sequential, macros).
                let n_out = node.n_outs as usize;
                let n_state = node.n_state as usize;
                for k in 0..n_in {
                    scratch_ins[k] = values[pins[ps + k].0 as usize];
                }
                let off = node.state_off as usize;
                {
                    let (ins, outs) = (
                        &scratch_ins[..n_in],
                        &mut scratch_outs[..n_out],
                    );
                    eval_comb_packed(
                        node.kind,
                        ins,
                        &state[off..off + n_state],
                        outs,
                    );
                }
                let mut toggles = 0u32;
                for k in 0..n_out {
                    let mut v = scratch_outs[k];
                    let out_net = pins[ps + n_in + k].0 as usize;
                    if let Some(f) = faults.as_deref_mut() {
                        let fv = f.force(out_net, v);
                        if fv != v {
                            dirty[b] = true;
                        }
                        v = fv;
                    }
                    toggles += ((values[out_net] ^ v) & mask).count_ones();
                    if values[out_net] != v {
                        values[out_net] = v;
                        mark(dirty, reader_off, reader_lvls, out_net);
                    }
                }
                if toggles > 0 {
                    activity.toggles[node.inst as usize] +=
                        u64::from(toggles);
                }
            }
        }
        // Next-state + commit per domain (shared edge across lanes).
        // An actual state change re-arms the owner's level so its eval
        // output is recomputed next tick.
        let active = u64::from(mask.count_ones());
        for &si in seq.iter() {
            let i = si as usize;
            let inst = nl.insts[i];
            let commit = match inst.domain {
                ClockDomain::Aclk => true,
                ClockDomain::Gclk => gclk_edge,
                ClockDomain::Comb => false,
            };
            if !commit {
                continue;
            }
            let kind = lib.cell(inst.cell).kind;
            let (n_in, _, n_state) = kind.pins();
            for (k, &nn) in nl.inst_ins(i).iter().enumerate() {
                scratch_ins[k] = values[nn.0 as usize];
            }
            let off = state_off[i] as usize;
            {
                let (cur, nxt) = (
                    &state[off..off + n_state],
                    &mut next[off..off + n_state],
                );
                next_state_packed(kind, &scratch_ins[..n_in], cur, nxt);
            }
            if state[off..off + n_state] != next[off..off + n_state] {
                state[off..off + n_state]
                    .copy_from_slice(&next[off..off + n_state]);
                dirty[bucket_of_inst[i] as usize] = true;
            }
            activity.clock_ticks[i] += active;
        }
        // SEUs land after the commit (visible next tick), exactly as
        // in the scalar/packed engines; the upset instance's level is
        // re-armed so the flip propagates.
        if let Some(f) = faults.as_deref_mut() {
            for seu in f.take_seus() {
                let i = seu.inst as usize;
                if bucket_of_inst[i] == u32::MAX {
                    continue;
                }
                let bits = lib.cell(nl.insts[i].cell).kind.pins().2;
                if (seu.bit as usize) < bits {
                    let off = state_off[i] as usize;
                    state[off + seu.bit as usize] ^= seu.lanes;
                    dirty[bucket_of_inst[i] as usize] = true;
                }
            }
            f.end_tick();
        }
    }

    /// Zero values and state; re-arm every level.
    fn reset(&mut self) {
        self.values.iter_mut().for_each(|v| *v = 0);
        self.state.iter_mut().for_each(|v| *v = 0);
        self.dirty.iter_mut().for_each(|d| *d = true);
    }
}

/// One partition part runnable on a shard worker thread: the seam that
/// lets [`ShardedSimulator`] drive either interpreted parts
/// ([`PartSim`], the default) or compiled tapes
/// ([`super::compiled::Tape`], one per part) through the identical
/// three-phase tick protocol.
pub trait TickPart: Send {
    /// Install a fault overlay (the part forces only its own writes).
    fn install_faults(&mut self, overlay: FaultOverlay);
    /// Remove the fault overlay.
    fn clear_faults(&mut self);
    /// Stage this tick's transient events the part owns.
    fn stage_tick_faults(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
        mask: u64,
    );
    /// Apply input words (`filter` skips nets no pin here reads).
    fn apply_inputs(&mut self, inputs: &[(NetId, u64)], filter: bool);
    /// Apply published boundary words (always stored).
    fn apply_words(&mut self, nets: &[NetId], words: &[u64]);
    /// Evaluate dirty levels and commit sequential state — one tick.
    fn settle_commit(&mut self, gclk_edge: bool, mask: u64);
    /// Zero values and state; re-arm everything.
    fn reset(&mut self);
    /// Full-size net-value image (only this part's slots are live).
    fn values(&self) -> &[u64];
    /// Per-instance counters (drained by the coordinator's fold).
    fn activity_mut(&mut self) -> &mut Activity;
    /// Take and reset observability tallies since the last drain:
    /// `(levels_evaluated, levels_skipped, ops_retired)`.
    fn obs_drain(&mut self) -> (u64, u64, u64) {
        (0, 0, 0)
    }
}

impl TickPart for PartSim<'_> {
    fn install_faults(&mut self, overlay: FaultOverlay) {
        PartSim::install_faults(self, overlay);
    }

    fn clear_faults(&mut self) {
        PartSim::clear_faults(self);
    }

    fn stage_tick_faults(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
        mask: u64,
    ) {
        PartSim::stage_tick_faults(self, glitches, seus, mask);
    }

    fn apply_inputs(&mut self, inputs: &[(NetId, u64)], filter: bool) {
        PartSim::apply_inputs(self, inputs, filter);
    }

    fn apply_words(&mut self, nets: &[NetId], words: &[u64]) {
        PartSim::apply_words(self, nets, words);
    }

    fn settle_commit(&mut self, gclk_edge: bool, mask: u64) {
        PartSim::settle_commit(self, gclk_edge, mask);
    }

    fn reset(&mut self) {
        PartSim::reset(self);
    }

    fn values(&self) -> &[u64] {
        &self.values
    }

    fn activity_mut(&mut self) -> &mut Activity {
        &mut self.activity
    }

    fn obs_drain(&mut self) -> (u64, u64, u64) {
        PartSim::obs_drain(self)
    }
}

impl TickPart for Tape {
    fn install_faults(&mut self, overlay: FaultOverlay) {
        Tape::install_faults(self, overlay);
    }

    fn clear_faults(&mut self) {
        Tape::clear_faults(self);
    }

    fn stage_tick_faults(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
        mask: u64,
    ) {
        Tape::stage_tick_faults(self, glitches, seus, mask);
    }

    fn apply_inputs(&mut self, inputs: &[(NetId, u64)], filter: bool) {
        Tape::apply_inputs(self, inputs, filter);
    }

    fn apply_words(&mut self, nets: &[NetId], words: &[u64]) {
        Tape::apply_words(self, nets, words);
    }

    fn settle_commit(&mut self, gclk_edge: bool, mask: u64) {
        Tape::settle_commit(self, gclk_edge, mask);
    }

    fn reset(&mut self) {
        Tape::reset(self);
    }

    fn values(&self) -> &[u64] {
        Tape::values(self)
    }

    fn activity_mut(&mut self) -> &mut Activity {
        Tape::activity_mut(self)
    }

    fn obs_drain(&mut self) -> (u64, u64, u64) {
        Tape::obs_drain(self)
    }
}

/// Thread-parallel sharded simulation instance over a netlist.
///
/// Generic over the per-part engine `P`: interpreted [`PartSim`]s by
/// default ([`ShardedSimulator::new`]) or one compiled [`Tape`] per
/// part ([`ShardedSimulator::new_compiled`]); the tick protocol and
/// activity accounting are shared and bit-identical.
pub struct ShardedSimulator<'n, P: TickPart = PartSim<'n>> {
    nl: &'n Netlist,
    head: P,
    shards: Vec<P>,
    tail: P,
    /// Per shard: the nets it publishes at the tick barrier.
    publish: Vec<Vec<NetId>>,
    /// Head (tie) outputs, broadcast with the primary inputs.
    head_outs: Vec<NetId>,
    /// Net → holder of its settled value: 0 tail, 1 head, 2+s shard s.
    owner: Vec<u32>,
    source_atoms: usize,
    lanes: usize,
    mask: u64,
    cycle: u64,
    /// Lane-cycles accumulated since the last activity fold.
    cycles_pending: u64,
    /// Transient fault events staged for the first tick of the next
    /// `run_ticks` call.
    staged_faults: Option<Arc<TickFaults>>,
    /// Aggregated counters (parts are drained into this after every
    /// run, so it is always the complete bit-identical total).
    agg: Activity,
}

/// Validate lane/thread counts shared by both constructors.
fn check_dims(lanes: usize, threads: usize) -> Result<()> {
    if !(1..=MAX_LANES).contains(&lanes) {
        return Err(Error::sim(format!(
            "sharded engine supports 1..={MAX_LANES} lanes, got {lanes}"
        )));
    }
    if threads < 1 {
        return Err(Error::sim(format!(
            "sharded engine needs threads >= 1, got {threads}"
        )));
    }
    Ok(())
}

impl<'n> ShardedSimulator<'n, PartSim<'n>> {
    /// Partition, levelize, and allocate for `lanes` (1..=64) stimulus
    /// lanes and at most `threads` shard workers.  `watch` nets are
    /// published every tick in addition to the netlist's primary
    /// outputs (for mid-run observation through [`MainView`]).
    pub fn new(
        nl: &'n Netlist,
        lib: &'n Library,
        lanes: usize,
        threads: usize,
        watch: &[NetId],
    ) -> Result<Self> {
        check_dims(lanes, threads)?;
        let part = partition(nl, lib, threads)?;
        let levels = comb_levels(nl, lib)?;
        let p = plan(nl, lib)?;
        let state_off = p.state_off;
        let total_state = p.total_state;

        let head = PartSim::new(
            nl, lib, &part.head, &levels, state_off.clone(), total_state,
        );
        let tail = PartSim::new(
            nl, lib, &part.tail, &levels, state_off.clone(), total_state,
        );
        let shards: Vec<PartSim<'n>> = part
            .shards
            .iter()
            .map(|s| {
                PartSim::new(
                    nl, lib, s, &levels, state_off.clone(), total_state,
                )
            })
            .collect();

        Ok(Self::assemble(nl, &part, watch, head, shards, tail, lanes))
    }
}

impl<'n> ShardedSimulator<'n, Tape> {
    /// Like [`ShardedSimulator::new`], but every partition part runs a
    /// compiled [`Tape`]: the whole netlist is lowered to word-level IR
    /// once, optimized by `pm` **minus the coalesce pass** (a fused
    /// producer/consumer pair may not straddle a partition boundary),
    /// and each part compiles the instances it owns.  Returns the
    /// per-pass statistics of the shared optimization run.
    pub fn new_compiled(
        nl: &'n Netlist,
        lib: &Library,
        lanes: usize,
        threads: usize,
        watch: &[NetId],
        pm: &PassManager,
    ) -> Result<(Self, Vec<PassStats>)> {
        check_dims(lanes, threads)?;
        let part = partition(nl, lib, threads)?;
        let mut ir = lower(nl, lib)?;
        let stats = pm.without(PassId::Coalesce).run(&mut ir);

        let mut keep = vec![false; ir.n_insts];
        let mut tape_for = |insts: &[u32]| {
            keep.iter_mut().for_each(|k| *k = false);
            for &i in insts {
                keep[i as usize] = true;
            }
            Tape::for_part(&ir, Some(&keep))
        };
        let head = tape_for(&part.head);
        let shards: Vec<Tape> =
            part.shards.iter().map(|s| tape_for(s)).collect();
        let tail = tape_for(&part.tail);

        let sim = Self::assemble(nl, &part, watch, head, shards, tail, lanes);
        Ok((sim, stats))
    }

    /// True when a forced fault on `net` can no longer be represented
    /// faithfully by the compiled tapes (the pass pipeline folded its
    /// write site or specialized its readers); callers must check this
    /// before installing overlays or staging glitches, and fall back to
    /// an interpreter engine when it fires.
    pub fn fault_site_lost(&self, net: NetId) -> bool {
        self.tail.fault_site_lost(net.0 as usize)
    }
}

impl<'n, P: TickPart> ShardedSimulator<'n, P> {
    /// Shared back half of the constructors: net-ownership, head
    /// broadcast, and shard publication wiring over the partition.
    fn assemble(
        nl: &'n Netlist,
        part: &Partition,
        watch: &[NetId],
        head: P,
        shards: Vec<P>,
        tail: P,
        lanes: usize,
    ) -> Self {
        let n_nets = nl.n_nets();
        let mut want = vec![false; n_nets];
        for &b in &part.boundary {
            want[b.0 as usize] = true;
        }
        for &o in &nl.outputs {
            want[o.0 as usize] = true;
        }
        for &w in watch {
            want[w.0 as usize] = true;
        }
        let mut owner = vec![0u32; n_nets];
        let mut head_outs = Vec::new();
        for &h in &part.head {
            for &o in nl.inst_outs(h as usize) {
                owner[o.0 as usize] = 1;
                head_outs.push(o);
            }
        }
        let mut publish = Vec::with_capacity(part.shards.len());
        for (s, insts) in part.shards.iter().enumerate() {
            let mut pubs = Vec::new();
            for &i in insts {
                for &o in nl.inst_outs(i as usize) {
                    owner[o.0 as usize] = s as u32 + 2;
                    if want[o.0 as usize] {
                        pubs.push(o);
                    }
                }
            }
            pubs.sort_unstable();
            publish.push(pubs);
        }

        ShardedSimulator {
            nl,
            head,
            shards,
            tail,
            publish,
            head_outs,
            owner,
            source_atoms: part.source_atoms,
            lanes,
            mask: mask_for(lanes),
            cycle: 0,
            cycles_pending: 0,
            staged_faults: None,
            agg: Activity::new(nl.insts.len()),
        }
    }

    /// Install a fault overlay; every part receives a clone and forces
    /// only the nets it writes, so per-net overlay state (the delay
    /// shadow) advances exactly once per tick, on the owner part.
    pub fn install_faults(&mut self, overlay: FaultOverlay) {
        assert_eq!(overlay.n_nets(), self.nl.n_nets(), "overlay size");
        self.head.install_faults(overlay.clone());
        for s in &mut self.shards {
            s.install_faults(overlay.clone());
        }
        self.tail.install_faults(overlay);
    }

    /// Remove all fault overlays and discard staged events.
    pub fn clear_faults(&mut self) {
        self.head.clear_faults();
        for s in &mut self.shards {
            s.clear_faults();
        }
        self.tail.clear_faults();
        self.staged_faults = None;
    }

    /// Stage transient fault events (single-tick glitches, post-commit
    /// SEUs) for the **first tick of the next run**; the per-tick
    /// [`super::SimEngine::tick_lanes`] driver therefore applies them
    /// to exactly the tick it is about to run.  Events on inactive
    /// lanes are dropped.
    pub fn set_tick_faults(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
    ) {
        let mask = self.mask;
        let tf = TickFaults {
            glitches: glitches
                .iter()
                .filter(|&&(_, l)| l & mask != 0)
                .map(|&(n, l)| (n, l & mask))
                .collect(),
            seus: seus
                .iter()
                .filter(|s| s.lanes & mask != 0)
                .map(|s| SeuFlip { lanes: s.lanes & mask, ..*s })
                .collect(),
        };
        self.staged_faults =
            if tf.glitches.is_empty() && tf.seus.is_empty() {
                None
            } else {
                Some(Arc::new(tf))
            };
    }

    /// Number of lanes the engine was built for.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Underlying netlist.
    pub fn netlist(&self) -> &Netlist {
        self.nl
    }

    /// Worker shards actually running (≤ the requested thread count).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shard-eligible groups the partitioner found (the available
    /// parallelism, independent of the requested thread count).
    pub fn source_atoms(&self) -> usize {
        self.source_atoms
    }

    /// Number of currently-active (activity-counted) lanes.
    pub fn active_lanes(&self) -> usize {
        self.mask.count_ones() as usize
    }

    /// Shrink the active-lane set to the first `n` lanes (`n ≤ lanes`);
    /// inactive lanes keep simulating but are excluded from activity.
    pub fn set_active_lanes(&mut self, n: usize) {
        assert!(
            (1..=self.lanes).contains(&n),
            "active lanes 1..={}",
            self.lanes
        );
        self.mask = mask_for(n);
    }

    /// Ticks executed since construction or the last reset.
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Current value of a net in one lane (valid for every net; reads
    /// the part that owns the net's settled value).
    pub fn get(&self, net: NetId, lane: usize) -> bool {
        debug_assert!(lane < self.lanes);
        let ni = net.0 as usize;
        let word = match self.owner[ni] {
            0 => self.tail.values()[ni],
            1 => self.head.values()[ni],
            o => self.shards[o as usize - 2].values()[ni],
        };
        word >> lane & 1 == 1
    }

    /// Reset all state and net values to 0 in every lane, clear the
    /// cycle counter, and restore the full active-lane mask.  Activity
    /// counters are preserved, as in the other engines.
    pub fn reset(&mut self) {
        self.head.reset();
        for s in &mut self.shards {
            s.reset();
        }
        self.tail.reset();
        self.cycle = 0;
        self.mask = mask_for(self.lanes);
    }

    /// Run a tick schedule (no observation).
    pub fn run_ticks(&mut self, ticks: &[SimTick]) {
        self.run_ticks_observe(ticks, |_, _| {});
    }

    /// Run a tick schedule inside one thread scope, invoking `observe`
    /// on the coordinating thread after each tick completes.
    ///
    /// This is the hot entry point: the shard workers persist across
    /// the whole schedule, so thread-spawn cost is amortized over every
    /// tick of a wave batch.  [`SimEngine::tick_lanes`] wraps a
    /// single-tick schedule for trait-driven callers.
    pub fn run_ticks_observe<F>(&mut self, ticks: &[SimTick], mut observe: F)
    where
        F: FnMut(usize, &MainView<'_>),
    {
        if ticks.is_empty() {
            return;
        }
        let mask = self.mask;
        let active = u64::from(mask.count_ones());
        let staged = self.staged_faults.take();
        let head = &mut self.head;
        let tail = &mut self.tail;
        let shards = &mut self.shards;
        let publish = &self.publish;
        let head_outs = &self.head_outs;
        let n_shards = shards.len();
        let mut cycle = self.cycle;
        let mut pending = 0u64;
        // Coordinator idle time per shard, waiting on boundary words.
        let mut wait_us: Vec<u64> = vec![0; n_shards];

        std::thread::scope(|scope| {
            let (res_tx, res_rx) = mpsc::channel::<(usize, Vec<u64>)>();
            let mut job_txs: Vec<mpsc::Sender<Job>> =
                Vec::with_capacity(n_shards);
            for (s, (shard, pub_nets)) in
                shards.iter_mut().zip(publish.iter()).enumerate()
            {
                let (tx, rx) = mpsc::channel::<Job>();
                job_txs.push(tx);
                let res_tx = res_tx.clone();
                scope.spawn(move || {
                    let mut sp = crate::obs::span("sim.shard");
                    sp.attr("shard", s);
                    let mut jobs = 0u64;
                    while let Ok(job) = rx.recv() {
                        shard.apply_inputs(&job.inputs, true);
                        if let Some(tf) = &job.faults {
                            shard.stage_tick_faults(
                                &tf.glitches,
                                &tf.seus,
                                job.mask,
                            );
                        }
                        shard.settle_commit(job.gclk_edge, job.mask);
                        jobs += 1;
                        let vals = shard.values();
                        let out: Vec<u64> = pub_nets
                            .iter()
                            .map(|n| vals[n.0 as usize])
                            .collect();
                        if res_tx.send((s, out)).is_err() {
                            break;
                        }
                    }
                    sp.attr("ticks", jobs);
                });
            }
            drop(res_tx);

            for (t, tick) in ticks.iter().enumerate() {
                let tf = if t == 0 { staged.clone() } else { None };
                if let Some(tf) = &tf {
                    head.stage_tick_faults(&tf.glitches, &tf.seus, mask);
                    tail.stage_tick_faults(&tf.glitches, &tf.seus, mask);
                }
                head.settle_commit(tick.gclk_edge, mask);
                let mut broadcast = Vec::with_capacity(
                    tick.inputs.len() + head_outs.len(),
                );
                broadcast.extend_from_slice(&tick.inputs);
                for &hn in head_outs {
                    broadcast.push((hn, head.values()[hn.0 as usize]));
                }
                let job = Job {
                    inputs: Arc::new(broadcast),
                    gclk_edge: tick.gclk_edge,
                    mask,
                    faults: tf,
                };
                for tx in &job_txs {
                    tx.send(job.clone()).expect("shard worker alive");
                }
                tail.apply_inputs(&job.inputs, false);
                for _ in 0..n_shards {
                    let t0 = std::time::Instant::now();
                    let (s, words) =
                        res_rx.recv().expect("shard worker result");
                    wait_us[s] += t0.elapsed().as_micros() as u64;
                    tail.apply_words(&publish[s], &words);
                }
                tail.settle_commit(tick.gclk_edge, mask);
                cycle += 1;
                pending += active;
                let view = MainView { values: tail.values() };
                observe(t, &view);
            }
            drop(job_txs);
        });

        self.cycle = cycle;
        self.cycles_pending += pending;
        self.fold();
        self.flush_obs(&wait_us, pending);
    }

    /// Flush the run's observability tallies to the global registry:
    /// quiescence gating and ops retired across all parts, lane-ticks,
    /// and the coordinator's per-shard boundary-exchange wait.  Called
    /// once per `run_ticks` batch, never inside the tick loop.
    fn flush_obs(&mut self, wait_us: &[u64], lane_ticks: u64) {
        let obs = crate::obs::global();
        let mut eval = 0u64;
        let mut skip = 0u64;
        let mut ops = 0u64;
        for (e, s, o) in std::iter::once(self.head.obs_drain())
            .chain(self.shards.iter_mut().map(|p| p.obs_drain()))
            .chain(std::iter::once(self.tail.obs_drain()))
        {
            eval += e;
            skip += s;
            ops += o;
        }
        super::compiled::flush_tape_obs(&obs, "sharded", eval, skip, ops);
        obs.counter(
            "tnn7_sim_engine_ticks_total",
            "Gclk lane-ticks retired, by engine",
            &[("engine", "sharded")],
        )
        .add(lane_ticks);
        for (s, &w) in wait_us.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let shard = s.to_string();
            obs.counter(
                "tnn7_sim_boundary_wait_micros_total",
                "Coordinator wait for shard boundary words, microseconds",
                &[("shard", shard.as_str())],
            )
            .add(w);
        }
    }

    /// Drain the per-part counters into the aggregate, so
    /// [`ShardedSimulator::activity`] always returns complete totals
    /// and external resets through `activity_mut` stay consistent.
    fn fold(&mut self) {
        self.agg.merge(self.head.activity_mut());
        self.head.activity_mut().reset();
        for s in &mut self.shards {
            self.agg.merge(s.activity_mut());
            s.activity_mut().reset();
        }
        self.agg.merge(self.tail.activity_mut());
        self.tail.activity_mut().reset();
        self.agg.cycles += self.cycles_pending;
        self.cycles_pending = 0;
    }

    /// Aggregated switching-activity counters.
    pub fn activity(&self) -> &Activity {
        &self.agg
    }

    /// Mutable access to the aggregated counters.
    pub fn activity_mut(&mut self) -> &mut Activity {
        &mut self.agg
    }
}

impl<P: TickPart> super::SimEngine for ShardedSimulator<'_, P> {
    fn lanes(&self) -> usize {
        self.lanes
    }

    fn tick_lanes(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool) {
        // One-tick schedule: correct but spawn-per-tick; batch callers
        // should use `run_ticks` directly.
        let tick = SimTick { inputs: inputs.to_vec(), gclk_edge };
        self.run_ticks(std::slice::from_ref(&tick));
    }

    fn lane_value(&self, net: NetId, lane: usize) -> bool {
        self.get(net, lane)
    }

    fn activity(&self) -> &Activity {
        &self.agg
    }

    fn activity_mut(&mut self) -> &mut Activity {
        &mut self.agg
    }

    fn ticks(&self) -> u64 {
        self.cycle
    }

    fn reset_state(&mut self) {
        self.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;
    use crate::netlist::{Builder, ClockDomain};
    use crate::sim::{PackedSimulator, SimEngine};

    /// Three independent blocks + a joining voter, region-tagged the
    /// way the partitioner cuts.
    fn blocks_and_voter(lib: &Library) -> Netlist {
        let mut b = Builder::new("bv", lib);
        let x0 = b.input("x0");
        let x1 = b.input("x1");
        let mut outs = Vec::new();
        for k in 0..3 {
            let reg = b.push(format!("col{k}"));
            let a = b.xor2(x0, x1);
            let n = b.nand2(a, x0);
            let q = b.dff(n, ClockDomain::Aclk);
            let g = b.dff(a, ClockDomain::Gclk);
            let y = b.and2(q, g);
            outs.push(y);
            b.pop(reg);
        }
        let reg = b.push("voter");
        let v = b.or_tree(&outs);
        let vq = b.dff(v, ClockDomain::Aclk);
        b.output(vq, "v");
        b.pop(reg);
        b.finish().unwrap()
    }

    /// Sharded vs packed: every net, every lane, every tick, and the
    /// aggregated activity — on a boundary-exchanging netlist.
    #[test]
    fn sharded_matches_packed_engine_on_voter_netlist() {
        let lib = Library::asap7_only();
        let nl = blocks_and_voter(&lib);
        for threads in [1usize, 2, 3, 8] {
            let mut sh =
                ShardedSimulator::new(&nl, &lib, 8, threads, &[]).unwrap();
            let mut pk = PackedSimulator::new(&nl, &lib, 8).unwrap();
            assert_eq!(sh.source_atoms(), 3);
            let mut rng = 0x9e37_79b9_7f4a_7c15u64;
            for t in 0..25u32 {
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
                let gamma = rng >> 60 & 3 == 0;
                let w0 = rng;
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
                let w1 = rng;
                let inputs =
                    [(nl.inputs[0], w0), (nl.inputs[1], w1)];
                sh.tick_lanes(&inputs, gamma);
                pk.tick(&inputs, gamma);
                for net in 0..nl.n_nets() {
                    let id = NetId(net as u32);
                    for lane in 0..8 {
                        assert_eq!(
                            sh.get(id, lane),
                            pk.get(id, lane),
                            "threads {threads} tick {t} net {net} \
                             lane {lane}"
                        );
                    }
                }
            }
            assert_eq!(sh.activity().toggles, pk.activity.toggles);
            assert_eq!(sh.activity().clock_ticks, pk.activity.clock_ticks);
            assert_eq!(sh.activity().cycles, pk.activity.cycles);
        }
    }

    /// Compiled-sharded (one optimized tape per partition part) vs
    /// packed: every net, every lane, every tick, plus activity.  The
    /// coalesce pass must be dropped automatically — fused pairs may
    /// not straddle a partition boundary.
    #[test]
    fn compiled_sharded_matches_packed_engine() {
        let lib = Library::asap7_only();
        let nl = blocks_and_voter(&lib);
        let pm = crate::ir::PassManager::all();
        for threads in [1usize, 3] {
            let (mut sh, stats) = ShardedSimulator::new_compiled(
                &nl, &lib, 8, threads, &[], &pm,
            )
            .unwrap();
            assert!(
                stats.iter().all(|s| s.pass != "coalesce"),
                "coalesce must be dropped for sharded tapes"
            );
            let mut pk = PackedSimulator::new(&nl, &lib, 8).unwrap();
            let mut rng = 0x9e37_79b9_7f4a_7c15u64;
            for t in 0..25u32 {
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
                let gamma = rng >> 60 & 3 == 0;
                let w0 = rng;
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
                let w1 = rng;
                let inputs = [(nl.inputs[0], w0), (nl.inputs[1], w1)];
                sh.tick_lanes(&inputs, gamma);
                pk.tick(&inputs, gamma);
                for net in 0..nl.n_nets() {
                    let id = NetId(net as u32);
                    for lane in 0..8 {
                        assert_eq!(
                            sh.get(id, lane),
                            pk.get(id, lane),
                            "threads {threads} tick {t} net {net} \
                             lane {lane}"
                        );
                    }
                }
            }
            assert_eq!(sh.activity().toggles, pk.activity.toggles);
            assert_eq!(sh.activity().clock_ticks, pk.activity.clock_ticks);
            assert_eq!(sh.activity().cycles, pk.activity.cycles);
        }
    }

    /// Faulted runs stay bit-identical to the packed engine: static
    /// stuck/delay masks force at part write sites with quiescence
    /// re-arming, and staged glitch/SEU events land on the owning part
    /// of the right tick.
    #[test]
    fn faulted_sharded_matches_faulted_packed() {
        let lib = Library::asap7_only();
        let nl = blocks_and_voter(&lib);
        let sites = crate::fault::fault_sites(&nl, &lib);
        let net_a = sites.outs[0];
        let net_b = sites.outs[sites.outs.len() / 2];
        let net_c = *sites.outs.last().unwrap();
        let (seu_inst, seu_bit) = sites.seq[0];
        for threads in [1usize, 3] {
            let mut overlay = FaultOverlay::new(nl.n_nets());
            overlay.add_stuck0(net_a, !0);
            overlay.add_stuck1(net_b, 0b1010);
            overlay.add_delay(net_c, !0);
            let mut sh =
                ShardedSimulator::new(&nl, &lib, 8, threads, &[]).unwrap();
            let mut pk = PackedSimulator::new(&nl, &lib, 8).unwrap();
            sh.install_faults(overlay.clone());
            pk.install_faults(overlay);
            let mut rng = 0x1234_5678_9abc_def0u64;
            for t in 0..25u32 {
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
                let gamma = rng >> 60 & 3 == 0;
                let w0 = rng;
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
                let w1 = rng;
                if t == 10 {
                    let g = [(net_b, 0b0101u64)];
                    let s = [SeuFlip {
                        inst: seu_inst,
                        bit: seu_bit,
                        lanes: 0b11,
                    }];
                    sh.set_tick_faults(&g, &s);
                    pk.set_tick_faults(&g, &s);
                }
                let inputs = [(nl.inputs[0], w0), (nl.inputs[1], w1)];
                sh.tick_lanes(&inputs, gamma);
                pk.tick(&inputs, gamma);
                for net in 0..nl.n_nets() {
                    let id = NetId(net as u32);
                    for lane in 0..8 {
                        assert_eq!(
                            sh.get(id, lane),
                            pk.get(id, lane),
                            "threads {threads} tick {t} net {net} \
                             lane {lane}"
                        );
                    }
                }
            }
            assert_eq!(sh.activity().toggles, pk.activity.toggles);
            assert_eq!(sh.activity().clock_ticks, pk.activity.clock_ticks);
        }
    }

    /// Quiescence gating is exact: holding the inputs constant, the
    /// gated engine's counters keep matching the ungated packed
    /// engine's (levels are skipped only when they provably cannot
    /// toggle).
    #[test]
    fn quiescent_stretch_keeps_counters_identical() {
        let lib = Library::asap7_only();
        let nl = blocks_and_voter(&lib);
        let mut sh = ShardedSimulator::new(&nl, &lib, 4, 2, &[]).unwrap();
        let mut pk = PackedSimulator::new(&nl, &lib, 4).unwrap();
        let inputs = [(nl.inputs[0], 0b1010u64), (nl.inputs[1], 0b0110u64)];
        for t in 0..30u32 {
            let gamma = t % 5 == 4;
            sh.tick_lanes(&inputs, gamma);
            pk.tick(&inputs, gamma);
        }
        assert_eq!(sh.activity().toggles, pk.activity.toggles);
        assert_eq!(sh.activity().clock_ticks, pk.activity.clock_ticks);
        assert_eq!(sh.activity().cycles, pk.activity.cycles);
        assert_eq!(sh.cycle(), 30);
    }

    /// Batched `run_ticks` equals per-tick trait driving, and the
    /// observer view exposes primary outputs after every tick.
    #[test]
    fn run_ticks_batch_matches_single_ticks_and_observes() {
        let lib = Library::asap7_only();
        let nl = blocks_and_voter(&lib);
        let ticks: Vec<SimTick> = (0..12u64)
            .map(|t| SimTick {
                inputs: vec![
                    (nl.inputs[0], t.wrapping_mul(0x5DEECE66D)),
                    (nl.inputs[1], !t),
                ],
                gclk_edge: t % 4 == 3,
            })
            .collect();

        let mut a = ShardedSimulator::new(&nl, &lib, 4, 2, &[]).unwrap();
        let mut seen = Vec::new();
        a.run_ticks_observe(&ticks, |t, view| {
            seen.push((t, view.get(nl.outputs[0], 1)));
        });
        assert_eq!(seen.len(), 12);
        assert_eq!(seen[11].0, 11);

        let mut b = ShardedSimulator::new(&nl, &lib, 4, 2, &[]).unwrap();
        let mut trace = Vec::new();
        for tick in &ticks {
            b.tick_lanes(&tick.inputs, tick.gclk_edge);
            trace.push(b.get(nl.outputs[0], 1));
        }
        // The observer saw exactly the per-tick output trace.
        for (t, &(seen_t, v)) in seen.iter().enumerate() {
            assert_eq!(t, seen_t);
            assert_eq!(v, trace[t], "observer trace tick {t}");
        }
        assert_eq!(a.activity().toggles, b.activity().toggles);
        assert_eq!(a.activity().cycles, b.activity().cycles);
        for net in 0..nl.n_nets() {
            let id = NetId(net as u32);
            assert_eq!(a.get(id, 2), b.get(id, 2), "net {net}");
        }
    }

    #[test]
    fn lane_and_thread_bounds_are_enforced() {
        let lib = Library::asap7_only();
        let nl = blocks_and_voter(&lib);
        assert!(ShardedSimulator::new(&nl, &lib, 0, 2, &[]).is_err());
        assert!(ShardedSimulator::new(&nl, &lib, 65, 2, &[]).is_err());
        assert!(ShardedSimulator::new(&nl, &lib, 8, 0, &[]).is_err());
        let sh = ShardedSimulator::new(&nl, &lib, 64, 16, &[]).unwrap();
        // Only 3 column atoms exist, so at most 3 workers run.
        assert_eq!(sh.shard_count(), 3);
    }
}
