//! The levelized cycle-based simulator (scalar reference engine).
//!
//! Construction levelizes the netlist once: instances are topologically
//! ordered by *combinational sensitivity* ([`super::eval::comb_deps`]),
//! so registered feedback (Q → logic → D) is legal while true
//! combinational loops are rejected.  Each [`Simulator::tick`] then:
//!
//! 1. applies primary-input values,
//! 2. evaluates every instance once in level order (zero-delay settle),
//! 3. counts per-net toggles against the previous cycle (the activity
//!    source for [`crate::ppa::power`]),
//! 4. computes next-state for all sequential instances and commits —
//!    `aclk`-domain always, `gclk`-domain only when the tick is flagged
//!    as a gamma edge.
//!
//! This engine evaluates one stimulus per tick and is kept as the
//! plainly-written reference; [`super::packed::PackedSimulator`] runs
//! 64 lanes per tick over the same levelized evaluation plan
//! (`EvalPlan`, crate-internal) and is tested bit-for-bit against this
//! one (DESIGN.md §7).  Both implement [`super::SimEngine`].
//!
//! ```
//! use tnn7::cells::Library;
//! use tnn7::netlist::Builder;
//! use tnn7::sim::Simulator;
//!
//! let lib = Library::asap7_only();
//! let mut b = Builder::new("demo", &lib);
//! let x = b.input("x");
//! let y = b.inv(x);
//! b.output(y, "y");
//! let nl = b.finish().unwrap();
//!
//! let mut sim = Simulator::new(&nl, &lib).unwrap();
//! sim.tick(&[(nl.inputs[0], true)], false);
//! assert!(!sim.get(nl.outputs[0])); // nets power up at 0: no toggle yet
//! sim.tick(&[(nl.inputs[0], false)], false);
//! assert!(sim.get(nl.outputs[0]));
//! assert_eq!(sim.activity.cycles, 2);
//! assert_eq!(sim.activity.toggles.iter().sum::<u64>(), 1);
//! ```

use crate::cells::Library;
use crate::error::{Error, Result};
use crate::fault::{FaultOverlay, SeuFlip};
use crate::netlist::{ClockDomain, NetId, Netlist};

use super::activity::Activity;
use super::eval::{comb_deps, eval_comb, next_state};

/// Flat evaluation node: everything the hot loop needs for one instance,
/// laid out contiguously in level order (avoids chasing `Instance` →
/// `Library` indirections 20M times per big-column measurement).
#[derive(Clone, Copy)]
pub(crate) struct EvalNode {
    pub(crate) kind: crate::cells::CellKind,
    pub(crate) pin_start: u32,
    pub(crate) state_off: u32,
    pub(crate) n_ins: u8,
    pub(crate) n_outs: u8,
    pub(crate) n_state: u8,
    /// Original instance index (activity attribution).
    pub(crate) inst: u32,
}

/// Levelized evaluation plan shared by the scalar and packed engines:
/// flat nodes in level order plus the state-bit layout.
pub(crate) struct EvalPlan {
    pub(crate) nodes: Vec<EvalNode>,
    pub(crate) state_off: Vec<u32>,
    /// Sequential instance indices (for the commit phase).
    pub(crate) seq: Vec<u32>,
    pub(crate) total_state: u32,
}

/// Build the shared [`EvalPlan`] for a netlist (levelize + flatten).
pub(crate) fn plan(nl: &Netlist, lib: &Library) -> Result<EvalPlan> {
    let n_insts = nl.insts.len();
    let order = levelize(nl, lib)?;
    // State allocation.
    let mut state_off = vec![0u32; n_insts];
    let mut total_state = 0u32;
    let mut seq = Vec::new();
    for i in 0..n_insts {
        let kind = lib.cell(nl.insts[i].cell).kind;
        let bits = kind.pins().2 as u32;
        state_off[i] = total_state;
        total_state += bits;
        if bits > 0 {
            seq.push(i as u32);
        }
    }
    // Flatten the hot-loop metadata in level order.
    let nodes = order
        .iter()
        .map(|&oi| {
            let i = oi as usize;
            let inst = nl.insts[i];
            let kind = lib.cell(inst.cell).kind;
            let (_, _, n_state) = kind.pins();
            EvalNode {
                kind,
                pin_start: inst.pin_start,
                state_off: state_off[i],
                n_ins: inst.n_ins,
                n_outs: inst.n_outs,
                n_state: n_state as u8,
                inst: oi,
            }
        })
        .collect();
    Ok(EvalPlan { nodes, state_off, seq, total_state })
}

/// Ready-to-run simulation instance over a netlist.
pub struct Simulator<'n> {
    nl: &'n Netlist,
    lib: &'n Library,
    /// Evaluation nodes in combinational level order.
    nodes: Vec<EvalNode>,
    /// Current net values.
    values: Vec<bool>,
    /// Per-instance state storage.
    state: Vec<bool>,
    next: Vec<bool>,
    state_off: Vec<u32>,
    /// Sequential instance indices (for the commit phase).
    seq: Vec<u32>,
    /// Activity counters.
    pub activity: Activity,
    cycle: u64,
    scratch_ins: Vec<bool>,
    scratch_outs: Vec<bool>,
    /// Reused input buffer for the [`super::SimEngine`] lane shim
    /// (avoids a fresh `Vec` per `tick_lanes` call).
    pub(crate) lane_scratch: Vec<(NetId, bool)>,
    /// Optional fault overlay forcing stored output values
    /// ([`crate::fault`], lane bit 0); `None` keeps the hot loop
    /// fault-free.
    faults: Option<Box<FaultOverlay>>,
}

/// Topologically order instances by combinational sensitivity.
///
/// Shared by the simulator and the STA ([`crate::ppa::timing`]); fails on
/// true combinational cycles (registered feedback is fine).
pub fn levelize(nl: &Netlist, lib: &Library) -> Result<Vec<u32>> {
    let n_insts = nl.insts.len();
    // Map: net -> driving instance; primary inputs stay u32::MAX (sources).
    let mut driver_of: Vec<u32> = vec![u32::MAX; nl.n_nets()];
    for i in 0..n_insts {
        for &o in nl.inst_outs(i) {
            driver_of[o.0 as usize] = i as u32;
        }
    }
    let mut indeg = vec![0u32; n_insts];
    let mut fanout: Vec<Vec<u32>> = vec![Vec::new(); n_insts];
    for i in 0..n_insts {
        let kind = lib.cell(nl.insts[i].cell).kind;
        let deps = comb_deps(kind);
        for (pin, &inp) in nl.inst_ins(i).iter().enumerate() {
            if deps >> pin & 1 == 0 {
                continue;
            }
            let d = driver_of[inp.0 as usize];
            if d != u32::MAX {
                fanout[d as usize].push(i as u32);
                indeg[i] += 1;
            }
        }
    }
    let mut order = Vec::with_capacity(n_insts);
    let mut queue: Vec<u32> = (0..n_insts as u32)
        .filter(|&i| indeg[i as usize] == 0)
        .collect();
    while let Some(i) = queue.pop() {
        order.push(i);
        for &f in &fanout[i as usize] {
            indeg[f as usize] -= 1;
            if indeg[f as usize] == 0 {
                queue.push(f);
            }
        }
    }
    if order.len() != n_insts {
        return Err(Error::sim(format!(
            "combinational cycle: {} of {} instances unordered",
            n_insts - order.len(),
            n_insts
        )));
    }
    Ok(order)
}

/// Combinational depth of every instance: 0 for instances whose
/// outputs depend on no driven comb-sensitive input, else 1 + the max
/// depth over those drivers.  Shared with the sharded engine, whose
/// quiescence gating skips whole depth levels per tick (DESIGN.md §8).
pub(crate) fn comb_levels(nl: &Netlist, lib: &Library) -> Result<Vec<u32>> {
    let order = levelize(nl, lib)?;
    let n = nl.insts.len();
    let mut driver_of: Vec<u32> = vec![u32::MAX; nl.n_nets()];
    for i in 0..n {
        for &o in nl.inst_outs(i) {
            driver_of[o.0 as usize] = i as u32;
        }
    }
    let mut level = vec![0u32; n];
    for &oi in &order {
        let i = oi as usize;
        let kind = lib.cell(nl.insts[i].cell).kind;
        let deps = comb_deps(kind);
        let mut l = 0u32;
        for (pin, &inp) in nl.inst_ins(i).iter().enumerate() {
            if deps >> pin & 1 == 0 {
                continue;
            }
            let d = driver_of[inp.0 as usize];
            if d != u32::MAX {
                l = l.max(level[d as usize] + 1);
            }
        }
        level[i] = l;
    }
    Ok(level)
}

impl<'n> Simulator<'n> {
    /// Levelize and allocate. Fails on combinational cycles.
    pub fn new(nl: &'n Netlist, lib: &'n Library) -> Result<Self> {
        let n_insts = nl.insts.len();
        let p = plan(nl, lib)?;
        Ok(Simulator {
            nl,
            lib,
            nodes: p.nodes,
            values: vec![false; nl.n_nets()],
            state: vec![false; p.total_state as usize],
            next: vec![false; p.total_state as usize],
            state_off: p.state_off,
            seq: p.seq,
            activity: Activity::new(n_insts),
            cycle: 0,
            scratch_ins: vec![false; 16],
            scratch_outs: vec![false; 8],
            lane_scratch: Vec::new(),
            faults: None,
        })
    }

    /// Current value of a net.
    pub fn get(&self, net: NetId) -> bool {
        self.values[net.0 as usize]
    }

    /// Cycle counter.
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Peek at an instance's state bits (testing / debug).
    pub fn inst_state(&self, inst: usize) -> &[bool] {
        let off = self.state_off[inst] as usize;
        let bits = self
            .lib
            .cell(self.nl.insts[inst].cell)
            .kind
            .pins()
            .2;
        &self.state[off..off + bits]
    }

    /// Reset all state and net values to 0 and clear the cycle counter
    /// (activity counters are preserved; call `activity.reset()` too for
    /// a fresh measurement).
    pub fn reset(&mut self) {
        self.values.iter_mut().for_each(|v| *v = false);
        self.state.iter_mut().for_each(|v| *v = false);
        self.cycle = 0;
    }

    /// Install a fault overlay: every cell-output store is forced
    /// through it from the next tick on (lane mask bit 0).
    pub fn install_faults(&mut self, overlay: FaultOverlay) {
        assert_eq!(overlay.n_nets(), self.nl.n_nets(), "overlay size");
        self.faults = Some(Box::new(overlay));
    }

    /// Remove the fault overlay (back to the fault-free hot loop).
    pub fn clear_faults(&mut self) {
        self.faults = None;
    }

    /// Schedule transient faults for the next [`Simulator::tick`]:
    /// single-tick XOR glitches on nets and post-commit SEU state
    /// flips.  Lane masks with bit 0 clear are ignored (this engine is
    /// lane 0).  Installs an empty overlay on demand.
    pub fn set_tick_faults(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
    ) {
        if self.faults.is_none() {
            self.faults = Some(Box::new(FaultOverlay::new(self.nl.n_nets())));
        }
        let f = self.faults.as_deref_mut().expect("just installed");
        for &(net, lanes) in glitches {
            if lanes & 1 != 0 {
                f.add_glitch(net, 1);
            }
        }
        for &seu in seus {
            if seu.lanes & 1 != 0 {
                f.push_seu(seu);
            }
        }
    }

    /// Run one `aclk` cycle.
    ///
    /// `set_inputs` assigns the primary-input values for this cycle;
    /// `gclk_edge` marks an end-of-wave tick (gamma-domain commit).
    pub fn tick(&mut self, inputs: &[(NetId, bool)], gclk_edge: bool) {
        for &(n, v) in inputs {
            let old = self.values[n.0 as usize];
            if old != v {
                self.values[n.0 as usize] = v;
            }
        }
        // Evaluate in level order, counting output toggles.  The flat
        // node array + single-output fast path are the scalar hot-loop
        // optimizations (DESIGN.md §7 discusses the engine lineup).
        let pins = &self.nl.pins;
        for node in &self.nodes {
            use crate::cells::CellKind as K;
            let ps = node.pin_start as usize;
            let n_in = node.n_ins as usize;
            // Fast path: stateless 1-output gates evaluated inline.
            let fast = match node.kind {
                K::Inv => Some(!self.values[pins[ps].0 as usize]),
                K::Buf => Some(self.values[pins[ps].0 as usize]),
                K::And2 => Some(
                    self.values[pins[ps].0 as usize]
                        & self.values[pins[ps + 1].0 as usize],
                ),
                K::Or2 => Some(
                    self.values[pins[ps].0 as usize]
                        | self.values[pins[ps + 1].0 as usize],
                ),
                K::Nand2 => Some(
                    !(self.values[pins[ps].0 as usize]
                        & self.values[pins[ps + 1].0 as usize]),
                ),
                K::Xor2 => Some(
                    self.values[pins[ps].0 as usize]
                        ^ self.values[pins[ps + 1].0 as usize],
                ),
                K::And3 => Some(
                    self.values[pins[ps].0 as usize]
                        & self.values[pins[ps + 1].0 as usize]
                        & self.values[pins[ps + 2].0 as usize],
                ),
                K::Xor3 => Some(
                    self.values[pins[ps].0 as usize]
                        ^ self.values[pins[ps + 1].0 as usize]
                        ^ self.values[pins[ps + 2].0 as usize],
                ),
                K::Maj3 => {
                    let a = self.values[pins[ps].0 as usize];
                    let b = self.values[pins[ps + 1].0 as usize];
                    let c = self.values[pins[ps + 2].0 as usize];
                    Some((a & b) | (b & c) | (a & c))
                }
                K::Mux2 => {
                    let s = self.values[pins[ps + 2].0 as usize];
                    Some(self.values[pins[ps + (s as usize)].0 as usize])
                }
                _ => None,
            };
            if let Some(v) = fast {
                let out_net = pins[ps + n_in].0 as usize;
                let v = match self.faults.as_deref_mut() {
                    Some(f) => f.force_bool(out_net, v),
                    None => v,
                };
                if self.values[out_net] != v {
                    self.values[out_net] = v;
                    self.activity.toggles[node.inst as usize] += 1;
                }
                continue;
            }
            // General path (multi-output cells, sequential, macros).
            let n_out = node.n_outs as usize;
            let n_state = node.n_state as usize;
            for k in 0..n_in {
                self.scratch_ins[k] = self.values[pins[ps + k].0 as usize];
            }
            let off = node.state_off as usize;
            {
                let (ins, outs) = (
                    &self.scratch_ins[..n_in],
                    &mut self.scratch_outs[..n_out],
                );
                eval_comb(node.kind, ins, &self.state[off..off + n_state], outs);
            }
            let mut toggles = 0u32;
            for k in 0..n_out {
                let mut v = self.scratch_outs[k];
                let out_net = pins[ps + n_in + k].0 as usize;
                if let Some(f) = self.faults.as_deref_mut() {
                    v = f.force_bool(out_net, v);
                }
                let slot = &mut self.values[out_net];
                if *slot != v {
                    *slot = v;
                    toggles += 1;
                }
            }
            if toggles > 0 {
                self.activity.toggles[node.inst as usize] += u64::from(toggles);
            }
        }
        // Next-state + commit per domain.
        for &si in &self.seq {
            let i = si as usize;
            let inst = self.nl.insts[i];
            let commit = match inst.domain {
                ClockDomain::Aclk => true,
                ClockDomain::Gclk => gclk_edge,
                ClockDomain::Comb => false,
            };
            if !commit {
                continue;
            }
            let kind = self.lib.cell(inst.cell).kind;
            let (n_in, _, n_state) = kind.pins();
            let ins_nets = self.nl.inst_ins(i);
            for (k, &n) in ins_nets.iter().enumerate() {
                self.scratch_ins[k] = self.values[n.0 as usize];
            }
            let off = self.state_off[i] as usize;
            // Write next into `next`, then copy back (no aliasing).
            {
                let (cur, nxt) = (
                    &self.state[off..off + n_state],
                    &mut self.next[off..off + n_state],
                );
                next_state(kind, &self.scratch_ins[..n_in], cur, nxt);
            }
            self.state[off..off + n_state]
                .copy_from_slice(&self.next[off..off + n_state]);
            self.activity.clock_ticks[i] += 1;
        }
        // Post-commit fault phase: queued SEUs flip committed state
        // bits (visible from the next tick's evaluation) and one-tick
        // glitch pulses retire.
        if let Some(f) = self.faults.as_deref_mut() {
            for seu in f.take_seus() {
                if seu.lanes & 1 == 0 {
                    continue;
                }
                let i = seu.inst as usize;
                let bits =
                    self.lib.cell(self.nl.insts[i].cell).kind.pins().2;
                if (seu.bit as usize) < bits {
                    let off = self.state_off[i] as usize;
                    self.state[off + seu.bit as usize] ^= true;
                }
            }
            f.end_tick();
        }
        self.cycle += 1;
        self.activity.cycles += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::{CellKind, Library};
    use crate::netlist::Builder;

    #[test]
    fn inverter_chain_settles_in_one_tick() {
        let lib = Library::asap7_only();
        let mut b = Builder::new("chain", &lib);
        let x = b.input("x");
        let mut n = x;
        for _ in 0..10 {
            n = b.inv(n);
        }
        b.output(n, "y");
        let nl = b.finish().unwrap();
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        let y = nl.outputs[0];
        sim.tick(&[(nl.inputs[0], true)], false);
        assert!(sim.get(y)); // even number of inversions
        sim.tick(&[(nl.inputs[0], false)], false);
        assert!(!sim.get(y));
    }

    #[test]
    fn registered_feedback_is_legal_toggle_flop() {
        // q -> inv -> d: divide-by-two toggler.
        let lib = Library::asap7_only();
        let mut b = Builder::new("tff", &lib);
        // manual feedback: allocate q net by building dff on a placeholder
        let d = b.net();
        let q = {
            let cell = lib.id_of_kind(CellKind::Dff).unwrap();
            let q = b.net();
            b.nl.push_inst(
                cell,
                &[d],
                &[q],
                crate::netlist::ClockDomain::Aclk,
                b.region(),
            );
            q
        };
        let nq = b.inv(q);
        // tie d to nq by an identity buffer onto the SAME net is not
        // possible in this IR; instead build dff input as buf(nq) -> d.
        // Re-do: d net must be driven; use a Buf.
        let cell = lib.id_of_kind(CellKind::Buf).unwrap();
        b.nl.push_inst(
            cell,
            &[nq],
            &[d],
            crate::netlist::ClockDomain::Comb,
            b.region(),
        );
        b.output(q, "q");
        let nl = b.finish().unwrap();
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        let mut seen = Vec::new();
        for _ in 0..4 {
            sim.tick(&[], false);
            seen.push(sim.get(q));
        }
        // Q is visible one cycle after the commit: 0,1,0,1.
        assert_eq!(seen, vec![false, true, false, true]);
    }

    #[test]
    fn combinational_cycle_is_rejected() {
        let lib = Library::asap7_only();
        let mut b = Builder::new("loop", &lib);
        let a = b.net();
        let y = {
            let cell = lib.id_of_kind(CellKind::Inv).unwrap();
            let y = b.net();
            b.nl.push_inst(cell, &[a], &[y], crate::netlist::ClockDomain::Comb, b.region());
            y
        };
        let cell = lib.id_of_kind(CellKind::Inv).unwrap();
        b.nl.push_inst(cell, &[y], &[a], crate::netlist::ClockDomain::Comb, b.region());
        let nl = b.nl;
        assert!(Simulator::new(&nl, &lib).is_err());
    }

    #[test]
    fn gclk_domain_commits_only_on_gamma_edge() {
        let lib = Library::asap7_only();
        let mut b = Builder::new("g", &lib);
        let d = b.input("d");
        let q = b.dff(d, crate::netlist::ClockDomain::Gclk);
        b.output(q, "q");
        let nl = b.finish().unwrap();
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        let din = nl.inputs[0];
        sim.tick(&[(din, true)], false);
        sim.tick(&[(din, true)], false);
        assert!(!sim.get(q), "no commit before gamma edge");
        sim.tick(&[(din, true)], true);
        sim.tick(&[(din, false)], false);
        assert!(sim.get(q), "gamma edge committed");
    }

    #[test]
    fn toggle_counting_attributes_to_instances() {
        let lib = Library::asap7_only();
        let mut b = Builder::new("t", &lib);
        let x = b.input("x");
        let y = b.inv(x);
        b.output(y, "y");
        let nl = b.finish().unwrap();
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        let xin = nl.inputs[0];
        for i in 0..10 {
            sim.tick(&[(xin, i % 2 == 0)], false);
        }
        // Inverter output toggles every cycle except the first (nets
        // power up at 0 and x=1 keeps the output at 0 on cycle 0).
        let inv_idx = nl.insts.len() - 1;
        assert_eq!(sim.activity.toggles[inv_idx], 9);
    }
}
