//! Minimal VCD waveform writer for debugging netlists.
//!
//! Dumps the *named* nets of a netlist (everything created through
//! [`crate::netlist::Builder::named`] / `input` / `output`) so a wave of
//! a misbehaving column can be inspected in GTKWave.

use std::io::Write;

use crate::error::Result;
use crate::netlist::{NetId, Netlist};
use crate::sim::Simulator;

/// Incremental VCD recorder over a simulation.
pub struct VcdWriter<W: Write> {
    out: W,
    nets: Vec<(NetId, String)>,
    last: Vec<Option<bool>>,
}

impl<W: Write> VcdWriter<W> {
    /// Write the header; tracks all named nets of `nl`.
    pub fn new(mut out: W, nl: &Netlist) -> Result<Self> {
        writeln!(out, "$timescale 1ns $end")?;
        writeln!(out, "$scope module {} $end", nl.name)?;
        let mut nets = Vec::new();
        for (net, name) in &nl.net_names {
            let id = Self::code(nets.len());
            writeln!(out, "$var wire 1 {id} {name} $end")?;
            nets.push((*net, id));
        }
        writeln!(out, "$upscope $end")?;
        writeln!(out, "$enddefinitions $end")?;
        let n = nets.len();
        Ok(VcdWriter { out, nets, last: vec![None; n] })
    }

    fn code(i: usize) -> String {
        // Printable short identifiers: base-94 starting at '!'.
        let mut s = String::new();
        let mut v = i;
        loop {
            s.push((33 + (v % 94)) as u8 as char);
            v /= 94;
            if v == 0 {
                break;
            }
        }
        s
    }

    /// Record the current simulator values at time `t` (only changes are
    /// emitted, per the VCD format).
    pub fn sample(&mut self, t: u64, sim: &Simulator<'_>) -> Result<()> {
        writeln!(self.out, "#{t}")?;
        for (k, (net, id)) in self.nets.iter().enumerate() {
            let v = sim.get(*net);
            if self.last[k] != Some(v) {
                writeln!(self.out, "{}{id}", if v { 1 } else { 0 })?;
                self.last[k] = Some(v);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;
    use crate::netlist::Builder;

    #[test]
    fn vcd_emits_header_and_changes() {
        let lib = Library::asap7_only();
        let mut b = Builder::new("v", &lib);
        let x = b.input("x");
        let y = b.inv(x);
        b.output(y, "y");
        let nl = b.finish().unwrap();
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        let mut buf = Vec::new();
        {
            let mut vcd = VcdWriter::new(&mut buf, &nl).unwrap();
            for i in 0..4u64 {
                sim.tick(&[(nl.inputs[0], i % 2 == 0)], false);
                vcd.sample(i, &sim).unwrap();
            }
        }
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("$var wire 1"));
        assert!(text.contains("#0"));
        assert!(text.contains("#3"));
    }
}
