//! Single-source combinational truth tables.
//!
//! Every place that needs to know what a simple combinational cell
//! *computes* — the scalar/packed kernels in [`super::eval`], the BLIF
//! `.names` covers in [`crate::interop::blif`], and the word-level IR
//! lowering in [`crate::ir`] — derives it from one definition here:
//! [`Gate::truth`].  A [`Truth`] is an ON-set bitmask over input
//! minterms (input `j` contributes bit `j` of the minterm index), so a
//! table is a single `u16` for up to four inputs.
//!
//! [`Gate`] is the closed opcode set of the compiled tape engine
//! ([`crate::sim::compiled`]).  *Closed* means: cofactoring any gate's
//! truth table against a constant input — after dropping inputs the
//! residue no longer depends on — lands back in the set (possibly with
//! reordered operands).  The IR constant-folding pass relies on this:
//! it specializes ops with [`Truth::cofactor`] + [`from_truth`] and
//! never has to invent an op the tape cannot execute.  Closure is
//! enforced by an exhaustive test below, not by convention.

use crate::cells::{CellKind, MacroKind};

/// Truth table of a combinational function of up to 4 inputs.
///
/// Bit `m` of `on` is the output for the input minterm `m`, where input
/// `j` contributes bit `j` of `m` (input 0 is the least-significant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Truth {
    /// Input count (0..=4).
    pub n_ins: u8,
    /// ON-set mask over the `2^n_ins` minterms.
    pub on: u16,
}

impl Truth {
    /// Build a table, masking `on` to the valid minterm range.
    pub fn new(n_ins: u8, on: u16) -> Truth {
        assert!(n_ins <= 4, "truth tables cover at most 4 inputs");
        let full = if n_ins == 4 { !0 } else { (1u16 << (1 << n_ins)) - 1 };
        Truth { n_ins, on: on & full }
    }

    /// Output for one minterm.
    #[inline]
    pub fn eval(&self, minterm: usize) -> bool {
        (self.on >> minterm) & 1 == 1
    }

    /// Restrict input `pos` to the constant `val` (one fewer input).
    pub fn cofactor(&self, pos: usize, val: bool) -> Truth {
        let n = self.n_ins as usize;
        assert!(pos < n);
        let mut on = 0u16;
        for m in 0..1usize << (n - 1) {
            // Re-expand the reduced minterm with `val` inserted at `pos`.
            let low = m & ((1 << pos) - 1);
            let high = (m >> pos) << (pos + 1);
            let full = low | high | ((val as usize) << pos);
            if self.eval(full) {
                on |= 1 << m;
            }
        }
        Truth::new(self.n_ins - 1, on)
    }

    /// Does the output depend on input `pos` at all?
    pub fn depends_on(&self, pos: usize) -> bool {
        self.cofactor(pos, false) != self.cofactor(pos, true)
    }
}

/// Drop inputs the function does not depend on, removing the matching
/// entries of the caller's operand list in lock-step.
pub fn reduce<T>(mut t: Truth, ins: &mut Vec<T>) -> Truth {
    let mut pos = 0;
    while pos < t.n_ins as usize {
        if t.depends_on(pos) {
            pos += 1;
        } else {
            t = t.cofactor(pos, false);
            ins.remove(pos);
        }
    }
    t
}

/// Opcode set of the compiled tape engine: every simple combinational
/// cell, plus the operand-negated 2-input forms that cofactoring can
/// produce (`AndN2` = `a & !b`, `OrN2` = `a | !b`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Gate {
    /// Constant 0 (lowered `Tie0`).
    Const0,
    /// Constant 1 (lowered `Tie1`).
    Const1,
    /// `a`
    Buf,
    /// `!a`
    Inv,
    /// `a & b`
    And2,
    /// `!(a & b)`
    Nand2,
    /// `a | b`
    Or2,
    /// `!(a | b)`
    Nor2,
    /// `a ^ b`
    Xor2,
    /// `!(a ^ b)`
    Xnor2,
    /// `a & !b`
    AndN2,
    /// `a | !b` (also the `LessEqual` macro)
    OrN2,
    /// `a & b & c`
    And3,
    /// `!(a & b & c)`
    Nand3,
    /// `a | b | c`
    Or3,
    /// `!(a | b | c)`
    Nor3,
    /// `a ^ b ^ c`
    Xor3,
    /// `(a & b) | (b & c) | (a & c)`
    Maj3,
    /// `!((a & b) | c)`
    Aoi21,
    /// `!((a | b) & c)`
    Oai21,
    /// `s ? d1 : d0` with operands `(d0, d1, s)` (also `Mux2Gdi`)
    Mux2,
    /// `!(a & b & c & d)`
    Nand4,
}

impl Gate {
    /// Every opcode, in a fixed canonical order ([`from_truth`] prefers
    /// earlier entries).
    pub const ALL: [Gate; 22] = [
        Gate::Const0,
        Gate::Const1,
        Gate::Buf,
        Gate::Inv,
        Gate::And2,
        Gate::Nand2,
        Gate::Or2,
        Gate::Nor2,
        Gate::Xor2,
        Gate::Xnor2,
        Gate::AndN2,
        Gate::OrN2,
        Gate::And3,
        Gate::Nand3,
        Gate::Or3,
        Gate::Nor3,
        Gate::Xor3,
        Gate::Maj3,
        Gate::Aoi21,
        Gate::Oai21,
        Gate::Mux2,
        Gate::Nand4,
    ];

    /// The defining truth table — the single source every consumer
    /// derives from.
    pub fn truth(self) -> Truth {
        let (n, on) = match self {
            Gate::Const0 => (0, 0b0),
            Gate::Const1 => (0, 0b1),
            Gate::Buf => (1, 0b10),
            Gate::Inv => (1, 0b01),
            Gate::And2 => (2, 0x8),
            Gate::Nand2 => (2, 0x7),
            Gate::Or2 => (2, 0xE),
            Gate::Nor2 => (2, 0x1),
            Gate::Xor2 => (2, 0x6),
            Gate::Xnor2 => (2, 0x9),
            Gate::AndN2 => (2, 0x2),
            Gate::OrN2 => (2, 0xB),
            Gate::And3 => (3, 0x80),
            Gate::Nand3 => (3, 0x7F),
            Gate::Or3 => (3, 0xFE),
            Gate::Nor3 => (3, 0x01),
            Gate::Xor3 => (3, 0x96),
            Gate::Maj3 => (3, 0xE8),
            Gate::Aoi21 => (3, 0x07),
            Gate::Oai21 => (3, 0x1F),
            Gate::Mux2 => (3, 0xCA),
            Gate::Nand4 => (4, 0x7FFF),
        };
        Truth::new(n, on)
    }

    /// Input count.
    #[inline]
    pub fn n_ins(self) -> usize {
        self.truth().n_ins as usize
    }

    /// Stable token (bench reports, debug output).
    pub fn label(self) -> &'static str {
        match self {
            Gate::Const0 => "const0",
            Gate::Const1 => "const1",
            Gate::Buf => "buf",
            Gate::Inv => "inv",
            Gate::And2 => "and2",
            Gate::Nand2 => "nand2",
            Gate::Or2 => "or2",
            Gate::Nor2 => "nor2",
            Gate::Xor2 => "xor2",
            Gate::Xnor2 => "xnor2",
            Gate::AndN2 => "andn2",
            Gate::OrN2 => "orn2",
            Gate::And3 => "and3",
            Gate::Nand3 => "nand3",
            Gate::Or3 => "or3",
            Gate::Nor3 => "nor3",
            Gate::Xor3 => "xor3",
            Gate::Maj3 => "maj3",
            Gate::Aoi21 => "aoi21",
            Gate::Oai21 => "oai21",
            Gate::Mux2 => "mux2",
            Gate::Nand4 => "nand4",
        }
    }
}

/// The opcode a simple combinational cell lowers to, with operands in
/// pin order.  `None` for sequential cells and the wide macros.
pub fn gate_for(kind: CellKind) -> Option<Gate> {
    use CellKind::*;
    Some(match kind {
        Tie0 => Gate::Const0,
        Tie1 => Gate::Const1,
        Inv => Gate::Inv,
        Buf => Gate::Buf,
        Nand2 => Gate::Nand2,
        Nand3 => Gate::Nand3,
        Nand4 => Gate::Nand4,
        Nor2 => Gate::Nor2,
        Nor3 => Gate::Nor3,
        And2 => Gate::And2,
        And3 => Gate::And3,
        Or2 => Gate::Or2,
        Or3 => Gate::Or3,
        Xor2 => Gate::Xor2,
        Xnor2 => Gate::Xnor2,
        Xor3 => Gate::Xor3,
        Maj3 => Gate::Maj3,
        Aoi21 => Gate::Aoi21,
        Oai21 => Gate::Oai21,
        Mux2 => Gate::Mux2,
        Macro(MacroKind::LessEqual) => Gate::OrN2,
        Macro(MacroKind::Mux2Gdi) => Gate::Mux2,
        _ => return None,
    })
}

/// Truth table of a simple combinational cell (see [`gate_for`]).
pub fn comb_truth(kind: CellKind) -> Option<Truth> {
    gate_for(kind).map(Gate::truth)
}

/// Recognize a truth table as an opcode plus an operand order.
///
/// Returns `(g, perm)` such that operand `k` of `g` is the caller's
/// input `perm[k]`; `perm` entries beyond the gate's arity are unused.
/// Inputs the table does not depend on must already be dropped (see
/// [`reduce`]).  The search prefers earlier [`Gate::ALL`] entries and
/// the identity operand order, so recognition is deterministic.
pub fn from_truth(t: &Truth) -> Option<(Gate, [usize; 4])> {
    let n = t.n_ins as usize;
    for g in Gate::ALL {
        let gt = g.truth();
        if gt.n_ins != t.n_ins {
            continue;
        }
        for perm in permutations(n) {
            // Candidate matches when feeding caller input `perm[k]` to
            // gate operand `k` reproduces `t` on every minterm.
            let mut ok = true;
            for m in 0..1usize << n {
                let mut gm = 0usize;
                for (k, &p) in perm.iter().take(n).enumerate() {
                    gm |= ((m >> p) & 1) << k;
                }
                if gt.eval(gm) != t.eval(m) {
                    ok = false;
                    break;
                }
            }
            if ok {
                return Some((g, perm));
            }
        }
    }
    None
}

/// All operand orders of `n <= 4` inputs, identity first.
fn permutations(n: usize) -> Vec<[usize; 4]> {
    let mut out = Vec::new();
    let mut cur = [0usize; 4];
    let mut used = [false; 4];
    fn rec(
        n: usize,
        depth: usize,
        cur: &mut [usize; 4],
        used: &mut [bool; 4],
        out: &mut Vec<[usize; 4]>,
    ) {
        if depth == n {
            out.push(*cur);
            return;
        }
        for v in 0..n {
            if !used[v] {
                used[v] = true;
                cur[depth] = v;
                rec(n, depth + 1, cur, used, out);
                used[v] = false;
            }
        }
    }
    if n == 0 {
        out.push(cur);
    } else {
        rec(n, 0, &mut cur, &mut used, &mut out);
    }
    out
}

// ---------------------------------------------------------------------
// Word kernels: 64 lanes per u64, bit k = lane k.

/// Evaluate a gate over packed lane words (unused operands ignored).
///
/// Branch-free per opcode; the tape engine's inner loop compiles each
/// arm to a handful of bitwise ops.
#[inline(always)]
pub fn eval_gate_word(g: Gate, x: [u64; 4]) -> u64 {
    let [a, b, c, d] = x;
    match g {
        Gate::Const0 => 0,
        Gate::Const1 => !0,
        Gate::Buf => a,
        Gate::Inv => !a,
        Gate::And2 => a & b,
        Gate::Nand2 => !(a & b),
        Gate::Or2 => a | b,
        Gate::Nor2 => !(a | b),
        Gate::Xor2 => a ^ b,
        Gate::Xnor2 => !(a ^ b),
        Gate::AndN2 => a & !b,
        Gate::OrN2 => a | !b,
        Gate::And3 => a & b & c,
        Gate::Nand3 => !(a & b & c),
        Gate::Or3 => a | b | c,
        Gate::Nor3 => !(a | b | c),
        Gate::Xor3 => a ^ b ^ c,
        Gate::Maj3 => (a & b) | (b & c) | (a & c),
        Gate::Aoi21 => !((a & b) | c),
        Gate::Oai21 => !((a | b) & c),
        Gate::Mux2 => (c & b) | (!c & a),
        Gate::Nand4 => !(a & b & c & d),
    }
}

/// Scalar gate evaluation via the word kernel (tests, BLIF covers).
pub fn eval_gate_scalar(g: Gate, ins: &[bool]) -> bool {
    let mut x = [0u64; 4];
    for (w, &v) in x.iter_mut().zip(ins.iter()) {
        *w = if v { !0 } else { 0 };
    }
    eval_gate_word(g, x) & 1 == 1
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The word kernel is a second implementation of every opcode;
    /// sweep it against the defining truth table on every minterm.
    #[test]
    fn word_kernels_match_truth_tables_exhaustively() {
        for g in Gate::ALL {
            let t = g.truth();
            let n = t.n_ins as usize;
            for m in 0..1usize << n {
                let ins: Vec<bool> = (0..n).map(|j| (m >> j) & 1 == 1).collect();
                assert_eq!(
                    eval_gate_scalar(g, &ins),
                    t.eval(m),
                    "{} minterm {m}",
                    g.label()
                );
            }
        }
    }

    /// `comb_truth` must agree with the scalar cell reference for every
    /// kind it covers — this anchors the single-source claim.
    #[test]
    fn comb_truth_matches_eval_comb_reference() {
        use crate::sim::eval::eval_comb;
        for kind in [
            CellKind::Tie0,
            CellKind::Tie1,
            CellKind::Inv,
            CellKind::Buf,
            CellKind::Nand2,
            CellKind::Nand3,
            CellKind::Nand4,
            CellKind::Nor2,
            CellKind::Nor3,
            CellKind::And2,
            CellKind::And3,
            CellKind::Or2,
            CellKind::Or3,
            CellKind::Xor2,
            CellKind::Xnor2,
            CellKind::Xor3,
            CellKind::Maj3,
            CellKind::Aoi21,
            CellKind::Oai21,
            CellKind::Mux2,
            CellKind::Macro(MacroKind::LessEqual),
            CellKind::Macro(MacroKind::Mux2Gdi),
        ] {
            let t = comb_truth(kind).expect("simple comb kind");
            let (n_in, n_out, n_state) = kind.pins();
            assert_eq!(n_out, 1, "{kind:?}");
            assert_eq!(n_state, 0, "{kind:?}");
            assert_eq!(t.n_ins as usize, n_in, "{kind:?}");
            for m in 0..1usize << n_in {
                let ins: Vec<bool> =
                    (0..n_in).map(|j| (m >> j) & 1 == 1).collect();
                let mut outs = [false];
                eval_comb(kind, &ins, &[], &mut outs);
                assert_eq!(t.eval(m), outs[0], "{kind:?} minterm {m}");
            }
        }
        assert!(comb_truth(CellKind::Dff).is_none());
        assert!(comb_truth(CellKind::Macro(MacroKind::SynOutput)).is_none());
    }

    #[test]
    fn from_truth_recognizes_every_gate_identically() {
        for g in Gate::ALL {
            let (rg, perm) = from_truth(&g.truth()).expect("in set");
            assert_eq!(rg, g, "{}", g.label());
            for (k, &p) in perm.iter().take(g.n_ins()).enumerate() {
                assert_eq!(k, p, "{} identity order", g.label());
            }
        }
    }

    #[test]
    fn from_truth_handles_swapped_negated_operands() {
        // !a & b — AndN2 with swapped operands.
        let (g, perm) = from_truth(&Truth::new(2, 0x4)).unwrap();
        assert_eq!(g, Gate::AndN2);
        assert_eq!(&perm[..2], &[1, 0]);
        // !a | b — OrN2 with swapped operands.
        let (g, perm) = from_truth(&Truth::new(2, 0xD)).unwrap();
        assert_eq!(g, Gate::OrN2);
        assert_eq!(&perm[..2], &[1, 0]);
    }

    /// The opcode set is closed under constant cofactoring: whatever a
    /// constant input reduces a gate to (after dropping inputs the
    /// residue ignores) is again a gate.  The fold pass depends on it.
    #[test]
    fn gate_set_is_closed_under_cofactoring() {
        for g in Gate::ALL {
            let t = g.truth();
            for pos in 0..t.n_ins as usize {
                for val in [false, true] {
                    let mut ins: Vec<usize> =
                        (0..t.n_ins as usize - 1).collect();
                    let r = reduce(t.cofactor(pos, val), &mut ins);
                    assert!(
                        from_truth(&r).is_some(),
                        "{} cofactor pos={pos} val={val} escapes the set",
                        g.label()
                    );
                }
            }
        }
    }

    #[test]
    fn cofactor_and_dependence_basics() {
        let mux = Gate::Mux2.truth();
        // s = 1 selects d1; s = 0 selects d0.
        assert_eq!(mux.cofactor(2, true), Truth::new(2, 0xC)); // = d1
        assert_eq!(mux.cofactor(2, false), Truth::new(2, 0xA)); // = d0
        assert!(mux.depends_on(0) && mux.depends_on(1) && mux.depends_on(2));
        // Aoi21 with a = 0 ignores b: residue reduces to Inv(c).
        let mut ins = vec!["b", "c"];
        let r = reduce(Gate::Aoi21.truth().cofactor(0, false), &mut ins);
        assert_eq!(ins, vec!["c"]);
        assert_eq!(from_truth(&r).unwrap().0, Gate::Inv);
    }

    #[test]
    fn reduce_drops_constant_functions_to_arity_zero() {
        let mut ins = vec![7u32, 9];
        let r = reduce(Truth::new(2, 0xF), &mut ins);
        assert!(ins.is_empty());
        assert_eq!(from_truth(&r).unwrap().0, Gate::Const1);
    }
}
