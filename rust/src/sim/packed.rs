//! The word-packed 64-lane simulation engine.
//!
//! [`PackedSimulator`] evaluates up to 64 *independent stimulus lanes*
//! per tick by storing every net, state bit, and next-state bit as one
//! `u64` word (bit `k` = lane `k`) and running the branch-free kernels
//! of [`super::eval::eval_comb_packed`] / [`super::eval::next_state_packed`]
//! over the same levelized evaluation plan (`EvalPlan`, crate-internal)
//! the scalar [`super::Simulator`] uses.  Per-lane semantics are
//! bit-for-bit those
//! of the scalar engine (DESIGN.md §7; the equivalence proptest in
//! `tests/proptests.rs` is the correctness anchor):
//!
//! * **Lane independence** — lanes never exchange data; lane `k` of a
//!   packed run equals a scalar run driven with lane `k`'s stimulus.
//! * **Shared clocking** — all lanes advance on the same `aclk` tick
//!   and see the same `gclk_edge` flag, which fits the TNN wave
//!   protocol where the gamma edge falls on a fixed wave cycle.
//! * **Activity equivalence** — toggle counters advance by
//!   `popcount((old ^ new) & lane_mask)` per output net, and
//!   `clock_ticks` / `cycles` by the active-lane count per commit/tick,
//!   so a packed run's [`Activity`] equals the *sum* of the per-lane
//!   scalar activities.  Inactive lanes (when fewer than 64 stimuli
//!   remain) are masked out of every counter.

use crate::cells::Library;
use crate::error::{Error, Result};
use crate::fault::{FaultOverlay, SeuFlip};
use crate::netlist::{ClockDomain, NetId, Netlist};

use super::activity::Activity;
use super::eval::{eval_comb_packed, next_state_packed};
use super::simulator::{plan, EvalNode};

/// Maximum number of lanes a packed engine can carry (bits per word).
pub const MAX_LANES: usize = 64;

/// Ready-to-run 64-lane simulation instance over a netlist.
pub struct PackedSimulator<'n> {
    nl: &'n Netlist,
    lib: &'n Library,
    /// Evaluation nodes in combinational level order.
    nodes: Vec<EvalNode>,
    /// Current net values, one word (64 lanes) per net.
    values: Vec<u64>,
    /// Per-instance state storage, one word per state bit.
    state: Vec<u64>,
    next: Vec<u64>,
    state_off: Vec<u32>,
    /// Sequential instance indices (for the commit phase).
    seq: Vec<u32>,
    /// Activity counters, aggregated over active lanes.
    pub activity: Activity,
    cycle: u64,
    /// Lanes the engine was built for (counter/capacity bound).
    lanes: usize,
    /// Mask of currently-active lanes (counted in activity).
    mask: u64,
    scratch_ins: Vec<u64>,
    scratch_outs: Vec<u64>,
    /// Optional fault overlay forcing stored output values per lane
    /// ([`crate::fault`]); `None` keeps the hot loop fault-free.
    faults: Option<Box<FaultOverlay>>,
}

fn mask_for(lanes: usize) -> u64 {
    if lanes >= MAX_LANES {
        !0
    } else {
        (1u64 << lanes) - 1
    }
}

impl<'n> PackedSimulator<'n> {
    /// Levelize and allocate for `lanes` (1..=64) stimulus lanes.
    /// Fails on combinational cycles or an out-of-range lane count.
    pub fn new(nl: &'n Netlist, lib: &'n Library, lanes: usize) -> Result<Self> {
        if !(1..=MAX_LANES).contains(&lanes) {
            return Err(Error::sim(format!(
                "packed engine supports 1..={MAX_LANES} lanes, got {lanes}"
            )));
        }
        let p = plan(nl, lib)?;
        Ok(PackedSimulator {
            nl,
            lib,
            nodes: p.nodes,
            values: vec![0; nl.n_nets()],
            state: vec![0; p.total_state as usize],
            next: vec![0; p.total_state as usize],
            state_off: p.state_off,
            seq: p.seq,
            activity: Activity::new(nl.insts.len()),
            cycle: 0,
            lanes,
            mask: mask_for(lanes),
            scratch_ins: vec![0; 16],
            scratch_outs: vec![0; 8],
            faults: None,
        })
    }

    /// Number of lanes the engine was built for.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Number of currently-active (activity-counted) lanes.
    pub fn active_lanes(&self) -> usize {
        self.mask.count_ones() as usize
    }

    /// Shrink the active-lane set to the first `n` lanes (`n ≤ lanes`),
    /// e.g. for a final stimulus batch smaller than the lane width.
    /// Inactive lanes keep simulating but are excluded from activity.
    pub fn set_active_lanes(&mut self, n: usize) {
        assert!(
            (1..=self.lanes).contains(&n),
            "active lanes 1..={}",
            self.lanes
        );
        self.mask = mask_for(n);
    }

    /// Current value of a net in one lane.
    pub fn get(&self, net: NetId, lane: usize) -> bool {
        debug_assert!(lane < self.lanes);
        self.values[net.0 as usize] >> lane & 1 == 1
    }

    /// Current value word of a net (bit `k` = lane `k`).
    pub fn get_word(&self, net: NetId) -> u64 {
        self.values[net.0 as usize]
    }

    /// Cycle counter (packed ticks, not lane-cycles; see
    /// [`Activity::cycles`] for the aggregated count).
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Reset all state and net values to 0 in every lane, clear the
    /// cycle counter, and restore the active-lane mask to the full
    /// lane count (undoing any [`PackedSimulator::set_active_lanes`]
    /// shrink).  Activity counters are preserved; call
    /// `activity.reset()` too for a fresh measurement.
    pub fn reset(&mut self) {
        self.values.iter_mut().for_each(|v| *v = 0);
        self.state.iter_mut().for_each(|v| *v = 0);
        self.cycle = 0;
        self.mask = mask_for(self.lanes);
    }

    /// Install a fault overlay: every cell-output store is forced
    /// through it from the next tick on, per lane.
    pub fn install_faults(&mut self, overlay: FaultOverlay) {
        assert_eq!(overlay.n_nets(), self.nl.n_nets(), "overlay size");
        self.faults = Some(Box::new(overlay));
    }

    /// Remove the fault overlay (back to the fault-free hot loop).
    pub fn clear_faults(&mut self) {
        self.faults = None;
    }

    /// Schedule transient faults for the next [`PackedSimulator::tick`]:
    /// single-tick XOR glitches on nets and post-commit SEU state
    /// flips, each restricted to the currently-active lane mask.
    /// Installs an empty overlay on demand.
    pub fn set_tick_faults(
        &mut self,
        glitches: &[(NetId, u64)],
        seus: &[SeuFlip],
    ) {
        if self.faults.is_none() {
            self.faults = Some(Box::new(FaultOverlay::new(self.nl.n_nets())));
        }
        let mask = self.mask;
        let f = self.faults.as_deref_mut().expect("just installed");
        for &(net, lanes) in glitches {
            if lanes & mask != 0 {
                f.add_glitch(net, lanes & mask);
            }
        }
        for &seu in seus {
            if seu.lanes & mask != 0 {
                f.push_seu(SeuFlip { lanes: seu.lanes & mask, ..seu });
            }
        }
    }

    /// Run one `aclk` cycle across all lanes.
    ///
    /// `inputs` assigns primary-input words (bit `k` = lane `k`) for
    /// this cycle; `gclk_edge` marks an end-of-wave tick (gamma-domain
    /// commit) shared by every lane.
    pub fn tick(&mut self, inputs: &[(NetId, u64)], gclk_edge: bool) {
        let mask = self.mask;
        for &(n, w) in inputs {
            self.values[n.0 as usize] = w;
        }
        // Evaluate in level order, counting per-lane output toggles.
        // Mirrors the scalar hot loop: inline fast path for stateless
        // 1-output gates, general path through the packed kernels.
        let pins = &self.nl.pins;
        for node in &self.nodes {
            use crate::cells::CellKind as K;
            let ps = node.pin_start as usize;
            let n_in = node.n_ins as usize;
            let fast = match node.kind {
                K::Inv => Some(!self.values[pins[ps].0 as usize]),
                K::Buf => Some(self.values[pins[ps].0 as usize]),
                K::And2 => Some(
                    self.values[pins[ps].0 as usize]
                        & self.values[pins[ps + 1].0 as usize],
                ),
                K::Or2 => Some(
                    self.values[pins[ps].0 as usize]
                        | self.values[pins[ps + 1].0 as usize],
                ),
                K::Nand2 => Some(
                    !(self.values[pins[ps].0 as usize]
                        & self.values[pins[ps + 1].0 as usize]),
                ),
                K::Xor2 => Some(
                    self.values[pins[ps].0 as usize]
                        ^ self.values[pins[ps + 1].0 as usize],
                ),
                K::And3 => Some(
                    self.values[pins[ps].0 as usize]
                        & self.values[pins[ps + 1].0 as usize]
                        & self.values[pins[ps + 2].0 as usize],
                ),
                K::Xor3 => Some(
                    self.values[pins[ps].0 as usize]
                        ^ self.values[pins[ps + 1].0 as usize]
                        ^ self.values[pins[ps + 2].0 as usize],
                ),
                K::Maj3 => {
                    let a = self.values[pins[ps].0 as usize];
                    let b = self.values[pins[ps + 1].0 as usize];
                    let c = self.values[pins[ps + 2].0 as usize];
                    Some((a & b) | (b & c) | (a & c))
                }
                K::Mux2 => {
                    let d0 = self.values[pins[ps].0 as usize];
                    let d1 = self.values[pins[ps + 1].0 as usize];
                    let s = self.values[pins[ps + 2].0 as usize];
                    Some((s & d1) | (!s & d0))
                }
                _ => None,
            };
            if let Some(v) = fast {
                let out_net = pins[ps + n_in].0 as usize;
                let v = match self.faults.as_deref_mut() {
                    Some(f) => f.force(out_net, v),
                    None => v,
                };
                let diff = (self.values[out_net] ^ v) & mask;
                self.values[out_net] = v;
                if diff != 0 {
                    self.activity.toggles[node.inst as usize] +=
                        u64::from(diff.count_ones());
                }
                continue;
            }
            // General path (multi-output cells, sequential, macros).
            let n_out = node.n_outs as usize;
            let n_state = node.n_state as usize;
            for k in 0..n_in {
                self.scratch_ins[k] = self.values[pins[ps + k].0 as usize];
            }
            let off = node.state_off as usize;
            {
                let (ins, outs) = (
                    &self.scratch_ins[..n_in],
                    &mut self.scratch_outs[..n_out],
                );
                eval_comb_packed(
                    node.kind,
                    ins,
                    &self.state[off..off + n_state],
                    outs,
                );
            }
            let mut toggles = 0u32;
            for k in 0..n_out {
                let mut v = self.scratch_outs[k];
                let out_net = pins[ps + n_in + k].0 as usize;
                if let Some(f) = self.faults.as_deref_mut() {
                    v = f.force(out_net, v);
                }
                let slot = &mut self.values[out_net];
                toggles += ((*slot ^ v) & mask).count_ones();
                *slot = v;
            }
            if toggles > 0 {
                self.activity.toggles[node.inst as usize] += u64::from(toggles);
            }
        }
        // Next-state + commit per domain (shared edge across lanes).
        let active = u64::from(mask.count_ones());
        for &si in &self.seq {
            let i = si as usize;
            let inst = self.nl.insts[i];
            let commit = match inst.domain {
                ClockDomain::Aclk => true,
                ClockDomain::Gclk => gclk_edge,
                ClockDomain::Comb => false,
            };
            if !commit {
                continue;
            }
            let kind = self.lib.cell(inst.cell).kind;
            let (n_in, _, n_state) = kind.pins();
            let ins_nets = self.nl.inst_ins(i);
            for (k, &n) in ins_nets.iter().enumerate() {
                self.scratch_ins[k] = self.values[n.0 as usize];
            }
            let off = self.state_off[i] as usize;
            // Write next into `next`, then copy back (no aliasing).
            {
                let (cur, nxt) = (
                    &self.state[off..off + n_state],
                    &mut self.next[off..off + n_state],
                );
                next_state_packed(kind, &self.scratch_ins[..n_in], cur, nxt);
            }
            self.state[off..off + n_state]
                .copy_from_slice(&self.next[off..off + n_state]);
            self.activity.clock_ticks[i] += active;
        }
        // Post-commit fault phase: queued SEUs flip committed state
        // bits per lane (visible from the next tick's evaluation) and
        // one-tick glitch pulses retire.
        if let Some(f) = self.faults.as_deref_mut() {
            for seu in f.take_seus() {
                let i = seu.inst as usize;
                let bits =
                    self.lib.cell(self.nl.insts[i].cell).kind.pins().2;
                if (seu.bit as usize) < bits {
                    let off = self.state_off[i] as usize;
                    self.state[off + seu.bit as usize] ^= seu.lanes;
                }
            }
            f.end_tick();
        }
        self.cycle += 1;
        self.activity.cycles += active;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;
    use crate::netlist::Builder;
    use crate::sim::Simulator;

    /// Drive the same 3 stimulus streams through 3 scalar engines and
    /// one 3-lane packed engine; values and activity must agree.
    #[test]
    fn packed_lanes_match_independent_scalar_runs() {
        let lib = Library::asap7_only();
        let mut b = Builder::new("mix", &lib);
        let x0 = b.input("x0");
        let x1 = b.input("x1");
        let a = b.xor2(x0, x1);
        let n = b.nand2(a, x0);
        let q = b.dff(n, crate::netlist::ClockDomain::Aclk);
        let g = b.dff(a, crate::netlist::ClockDomain::Gclk);
        let y = b.and2(q, g);
        b.output(y, "y");
        let nl = b.finish().unwrap();

        const LANES: usize = 3;
        let mut packed = PackedSimulator::new(&nl, &lib, LANES).unwrap();
        let mut scalars: Vec<Simulator> = (0..LANES)
            .map(|_| Simulator::new(&nl, &lib).unwrap())
            .collect();

        // Deterministic per-lane stimulus + a gamma edge every 5 ticks.
        let mut rng = 0x1234_5678_9abc_def0u64;
        for t in 0..40u32 {
            let gamma = t % 5 == 4;
            let mut w0 = 0u64;
            let mut w1 = 0u64;
            for l in 0..LANES {
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
                let v0 = rng >> 17 & 1 == 1;
                let v1 = rng >> 43 & 1 == 1;
                w0 |= (v0 as u64) << l;
                w1 |= (v1 as u64) << l;
                scalars[l].tick(
                    &[(nl.inputs[0], v0), (nl.inputs[1], v1)],
                    gamma,
                );
            }
            packed.tick(&[(nl.inputs[0], w0), (nl.inputs[1], w1)], gamma);
            for (l, s) in scalars.iter().enumerate() {
                for net in 0..nl.n_nets() {
                    let id = crate::netlist::NetId(net as u32);
                    assert_eq!(
                        packed.get(id, l),
                        s.get(id),
                        "tick {t} lane {l} net {net}"
                    );
                }
            }
        }
        let mut toggles = vec![0u64; nl.insts.len()];
        let mut ticks = vec![0u64; nl.insts.len()];
        let mut cycles = 0;
        for s in &scalars {
            for i in 0..nl.insts.len() {
                toggles[i] += s.activity.toggles[i];
                ticks[i] += s.activity.clock_ticks[i];
            }
            cycles += s.activity.cycles;
        }
        assert_eq!(packed.activity.toggles, toggles);
        assert_eq!(packed.activity.clock_ticks, ticks);
        assert_eq!(packed.activity.cycles, cycles);
    }

    /// Masked-out lanes contribute nothing to any activity counter.
    #[test]
    fn inactive_lanes_are_excluded_from_activity() {
        let lib = Library::asap7_only();
        let mut b = Builder::new("t", &lib);
        let x = b.input("x");
        let y = b.inv(x);
        b.output(y, "y");
        let nl = b.finish().unwrap();
        let mut packed = PackedSimulator::new(&nl, &lib, 8).unwrap();
        packed.set_active_lanes(2);
        assert_eq!(packed.active_lanes(), 2);
        // Toggle all 8 lanes every tick; only 2 lanes may count.
        for t in 0..10u64 {
            let w = if t % 2 == 0 { !0u64 } else { 0 };
            packed.tick(&[(nl.inputs[0], w)], false);
        }
        assert_eq!(packed.activity.cycles, 20);
        // Inverter output toggles every cycle except the first, in each
        // of the 2 active lanes (same argument as the scalar test).
        let inv_idx = nl.insts.len() - 1;
        assert_eq!(packed.activity.toggles[inv_idx], 18);
        // reset() restores the full active-lane set.
        packed.reset();
        assert_eq!(packed.active_lanes(), 8);
    }

    #[test]
    fn lane_count_bounds_are_enforced() {
        let lib = Library::asap7_only();
        let mut b = Builder::new("t", &lib);
        let x = b.input("x");
        let y = b.inv(x);
        b.output(y, "y");
        let nl = b.finish().unwrap();
        assert!(PackedSimulator::new(&nl, &lib, 0).is_err());
        assert!(PackedSimulator::new(&nl, &lib, 65).is_err());
        assert!(PackedSimulator::new(&nl, &lib, 64).is_ok());
    }
}
