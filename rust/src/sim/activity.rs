//! Per-instance switching-activity counters.
//!
//! [`Activity`] is filled by the simulator (output toggles + clock ticks
//! per instance) and consumed by [`crate::ppa::power`]:
//! `P_dyn = Σ_i toggles_i · E_cell(i) / T  +  Σ_seq ticks_i · E_clk(i) / T`.

/// Switching-activity record for one simulation run.
#[derive(Debug, Clone)]
pub struct Activity {
    /// Output toggles per instance.
    pub toggles: Vec<u64>,
    /// Clock commits per sequential instance (clock-pin energy).
    pub clock_ticks: Vec<u64>,
    /// Total aclk cycles simulated.
    pub cycles: u64,
}

impl Activity {
    /// Zeroed counters for `n` instances.
    pub fn new(n: usize) -> Self {
        Activity {
            toggles: vec![0; n],
            clock_ticks: vec![0; n],
            cycles: 0,
        }
    }

    /// Clear all counters.
    pub fn reset(&mut self) {
        self.toggles.iter_mut().for_each(|t| *t = 0);
        self.clock_ticks.iter_mut().for_each(|t| *t = 0);
        self.cycles = 0;
    }

    /// Accumulate another run's counters into this one (same netlist).
    ///
    /// This is the per-lane aggregation rule of the packed engine made
    /// explicit: the activity of a 64-lane packed run equals `merge`
    /// over the 64 individual scalar runs — the equivalence the
    /// scalar-vs-packed proptest asserts.
    pub fn merge(&mut self, other: &Activity) {
        assert_eq!(
            self.toggles.len(),
            other.toggles.len(),
            "merging activity of different netlists"
        );
        for (t, o) in self.toggles.iter_mut().zip(&other.toggles) {
            *t += o;
        }
        for (t, o) in self.clock_ticks.iter_mut().zip(&other.clock_ticks) {
            *t += o;
        }
        self.cycles += other.cycles;
    }

    /// Mean output-toggle rate per instance per cycle.
    pub fn mean_toggle_rate(&self) -> f64 {
        if self.cycles == 0 || self.toggles.is_empty() {
            return 0.0;
        }
        let total: u64 = self.toggles.iter().sum();
        total as f64 / (self.cycles as f64 * self.toggles.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_rate() {
        let mut a = Activity::new(4);
        a.cycles = 10;
        a.toggles = vec![10, 0, 5, 5];
        assert!((a.mean_toggle_rate() - 0.5).abs() < 1e-12);
        a.reset();
        assert_eq!(a.mean_toggle_rate(), 0.0);
    }

    #[test]
    fn merge_sums_counters() {
        let mut a = Activity::new(2);
        a.toggles = vec![1, 2];
        a.clock_ticks = vec![3, 0];
        a.cycles = 5;
        let mut b = Activity::new(2);
        b.toggles = vec![10, 20];
        b.clock_ticks = vec![0, 7];
        b.cycles = 11;
        a.merge(&b);
        assert_eq!(a.toggles, vec![11, 22]);
        assert_eq!(a.clock_ticks, vec![3, 7]);
        assert_eq!(a.cycles, 16);
    }
}
