//! Pure cell semantics: the single source of truth for what every cell
//! *does*.
//!
//! Two independent implementations live here (see DESIGN.md §7):
//!
//! * **Scalar reference** — [`eval_comb`] / [`next_state`] over `bool`s,
//!   written in the most obvious style (branches, integer compares).
//!   This is the correctness anchor everything else is tested against.
//! * **Word-packed kernels** — [`eval_comb_packed`] /
//!   [`next_state_packed`] over `u64` words, where bit `k` of every
//!   word is simulation lane `k`.  These are branch-free bitwise
//!   translations of the same functions, evaluating 64 independent
//!   stimulus lanes per call; [`super::packed::PackedSimulator`] builds
//!   its hot loop on them.
//!
//! Shared by both: [`comb_deps`] — which input pins the outputs depend
//! on *combinationally* (levelization must order only those; e.g. a
//! plain DFF's Q depends on no input, so Q→logic→D loops are legal).
//!
//! The behavioral models of the custom macros here are what the
//! std-flavour gate builders in [`crate::netlist::modules`] are proven
//! equivalent to (their unit tests sweep both through the simulator),
//! and the packed kernels are exhaustively swept against the scalar
//! reference in this module's tests.
//!
//! Simple combinational kinds (one output, no state) are not written
//! out per-kind here: both the scalar and the packed path route them
//! through the single-source truth tables in [`super::tables`], the
//! same definitions the BLIF writer and the IR lowering consume.

use crate::cells::{CellKind, MacroKind};

use super::tables;

/// Evaluate combinational outputs.
///
/// `ins` are current net values, `state` the instance's current state
/// bits, `outs` is written in pin order.
pub fn eval_comb(kind: CellKind, ins: &[bool], state: &[bool], outs: &mut [bool]) {
    use CellKind::*;
    if let Some(g) = tables::gate_for(kind) {
        outs[0] = tables::eval_gate_scalar(g, ins);
        return;
    }
    match kind {
        Dff => outs[0] = state[0],
        // Async active-high reset shows at Q immediately.
        DffR => outs[0] = !ins[1] & state[0],
        // Sync active-low reset: Q is just the state.
        DffRn => outs[0] = state[0],
        // Transparent-high latch.
        Latch => outs[0] = if ins[1] { ins[0] } else { state[0] },
        Macro(m) => eval_macro(m, ins, state, outs),
        _ => unreachable!("{kind:?} is covered by the gate tables"),
    }
}

fn eval_macro(m: MacroKind, ins: &[bool], state: &[bool], outs: &mut [bool]) {
    match m {
        // Fig. 2: weight register drives its value; update is sequential.
        MacroKind::SynWeightUpdate => {
            outs[0] = state[0];
            outs[1] = state[1];
            outs[2] = state[2];
        }
        // Fig. 3: up = pulse & (count < weight), both 3-bit LSB-first.
        MacroKind::SynOutput => {
            let c = bits3(ins[0], ins[1], ins[2]);
            let w = bits3(ins[3], ins[4], ins[5]);
            outs[0] = ins[6] && c < w;
        }
        // Fig. 4: full-adder slice.
        MacroKind::PacAdder => {
            outs[0] = ins[0] ^ ins[1] ^ ins[2];
            outs[1] = (ins[0] & ins[1]) | (ins[1] & ins[2]) | (ins[0] & ins[2]);
        }
        // Fig. 5 (LessEqual) and Fig. 11 (Mux2Gdi) are pure gates and
        // never reach here — `eval_comb` dispatches them through the
        // shared truth tables.
        MacroKind::LessEqual | MacroKind::Mux2Gdi => {
            unreachable!("{m:?} is covered by the gate tables")
        }
        // Fig. 6: async reset visible at output immediately.
        MacroKind::Pulse2EdgePwr => outs[0] = !ins[1] & state[0],
        // Fig. 7: sync reset; output is the registered level.
        MacroKind::Pulse2EdgeArea => outs[0] = state[0],
        // Fig. 8: the four STDP timing cases from (x, y, le).
        MacroKind::StdpCaseGen => {
            let (x, y, le) = (ins[0], ins[1], ins[2]);
            outs[0] = x & y & le; // capture
            outs[1] = x & y & !le; // backoff
            outs[2] = x & !y; // search
            outs[3] = !x & y; // minus
        }
        // Fig. 9: 8:1 BRV select by 3-bit weight (s LSB-first at ins[8..11]).
        MacroKind::StabilizeFunc => {
            let sel = bits3(ins[8], ins[9], ins[10]) as usize;
            outs[0] = ins[sel];
        }
        // Fig. 10: inc = capture|search, dec = backoff|minus.
        MacroKind::IncDec => {
            outs[0] = ins[0] | ins[2];
            outs[1] = ins[1] | ins[3];
        }
        // Fig. 13: one-cycle pulse on rising edge.
        MacroKind::Edge2Pulse => outs[0] = ins[0] & !state[0],
        // Fig. 12: pulse = d & count<8; count exported (3 LSBs).
        MacroKind::SpikeGen => {
            let done = state[3];
            outs[0] = ins[0] & !done;
            outs[1] = state[0];
            outs[2] = state[1];
            outs[3] = state[2];
        }
    }
}

/// Compute sequential next-state (called after combinational settle).
pub fn next_state(kind: CellKind, ins: &[bool], state: &[bool], next: &mut [bool]) {
    use CellKind::*;
    match kind {
        Dff => next[0] = ins[0],
        DffR => next[0] = !ins[1] & ins[0],
        DffRn => next[0] = ins[1] & ins[0],
        Latch => next[0] = if ins[1] { ins[0] } else { state[0] },
        Macro(m) => next_state_macro(m, ins, state, next),
        _ => {}
    }
}

fn next_state_macro(m: MacroKind, ins: &[bool], state: &[bool], next: &mut [bool]) {
    match m {
        MacroKind::SynWeightUpdate => {
            let w = bits3(state[0], state[1], state[2]);
            let (inc, dec) = (ins[0], ins[1]);
            // inc has priority; saturate at [0, 7] — identical to the
            // std-flavour sat_updown3 logic.
            let nw = if inc && w < 7 {
                w + 1
            } else if dec && !inc && w > 0 {
                w - 1
            } else {
                w
            };
            next[0] = nw & 1 != 0;
            next[1] = nw & 2 != 0;
            next[2] = nw & 4 != 0;
        }
        MacroKind::Pulse2EdgePwr | MacroKind::Pulse2EdgeArea => {
            next[0] = !ins[1] & (state[0] | ins[0]);
        }
        MacroKind::Edge2Pulse => next[0] = ins[0],
        MacroKind::SpikeGen => {
            // 4-bit saturating cycle counter, cleared by rst (ins[1]);
            // counts while the input level is high and count < 8.
            let c = bits3(state[0], state[1], state[2]) + if state[3] { 8 } else { 0 };
            let nc = if ins[1] {
                0
            } else if ins[0] && c < 8 {
                c + 1
            } else {
                c
            };
            next[0] = nc & 1 != 0;
            next[1] = nc & 2 != 0;
            next[2] = nc & 4 != 0;
            next[3] = nc & 8 != 0;
        }
        _ => {}
    }
}

/// Bitmask of input pins that outputs depend on *combinationally*.
pub fn comb_deps(kind: CellKind) -> u16 {
    use CellKind::*;
    match kind {
        Tie0 | Tie1 => 0,
        Dff | DffRn => 0,               // Q = state only
        DffR => 0b10,                   // Q sees async rst (pin 1)
        Latch => 0b11,                  // transparent path
        Macro(m) => match m {
            MacroKind::SynWeightUpdate => 0,
            MacroKind::Pulse2EdgeArea => 0,
            MacroKind::Pulse2EdgePwr => 0b10, // async rst
            MacroKind::Edge2Pulse => 0b1,     // out = d & !prev
            MacroKind::SpikeGen => 0b01,      // pulse = d & !done
            _ => all_ins(kind),
        },
        _ => all_ins(kind),
    }
}

fn all_ins(kind: CellKind) -> u16 {
    let (n, _, _) = kind.pins();
    ((1u32 << n) - 1) as u16
}

fn bits3(b0: bool, b1: bool, b2: bool) -> u8 {
    (b0 as u8) | ((b1 as u8) << 1) | ((b2 as u8) << 2)
}

// ---------------------------------------------------------------------
// Word-packed kernels: 64 lanes per u64, bit k = lane k.

/// Branch-free 2:1 select per lane: `s ? a1 : a0`.
#[inline(always)]
fn sel(s: u64, a1: u64, a0: u64) -> u64 {
    (s & a1) | (!s & a0)
}

/// Per-lane unsigned `a < b` over 3-bit LSB-first operands.
#[inline(always)]
fn lt3(a0: u64, a1: u64, a2: u64, b0: u64, b1: u64, b2: u64) -> u64 {
    let e2 = !(a2 ^ b2);
    let e1 = !(a1 ^ b1);
    (!a2 & b2) | (e2 & ((!a1 & b1) | (e1 & !a0 & b0)))
}

/// Evaluate combinational outputs for 64 lanes at once.
///
/// Word-for-word the semantics of [`eval_comb`], applied independently
/// to every bit position: `ins`/`state`/`outs` hold one `u64` per pin
/// or state bit, with bit `k` carrying lane `k`'s value.
pub fn eval_comb_packed(kind: CellKind, ins: &[u64], state: &[u64], outs: &mut [u64]) {
    use CellKind::*;
    if let Some(g) = tables::gate_for(kind) {
        let mut x = [0u64; 4];
        for (w, &v) in x.iter_mut().zip(ins.iter()) {
            *w = v;
        }
        outs[0] = tables::eval_gate_word(g, x);
        return;
    }
    match kind {
        Dff => outs[0] = state[0],
        DffR => outs[0] = !ins[1] & state[0],
        DffRn => outs[0] = state[0],
        Latch => outs[0] = sel(ins[1], ins[0], state[0]),
        Macro(m) => eval_macro_packed(m, ins, state, outs),
        _ => unreachable!("{kind:?} is covered by the gate tables"),
    }
}

fn eval_macro_packed(m: MacroKind, ins: &[u64], state: &[u64], outs: &mut [u64]) {
    match m {
        MacroKind::SynWeightUpdate => {
            outs[0] = state[0];
            outs[1] = state[1];
            outs[2] = state[2];
        }
        MacroKind::SynOutput => {
            outs[0] = ins[6]
                & lt3(ins[0], ins[1], ins[2], ins[3], ins[4], ins[5]);
        }
        MacroKind::PacAdder => {
            outs[0] = ins[0] ^ ins[1] ^ ins[2];
            outs[1] = (ins[0] & ins[1]) | (ins[1] & ins[2]) | (ins[0] & ins[2]);
        }
        MacroKind::LessEqual | MacroKind::Mux2Gdi => {
            unreachable!("{m:?} is covered by the gate tables")
        }
        MacroKind::Pulse2EdgePwr => outs[0] = !ins[1] & state[0],
        MacroKind::Pulse2EdgeArea => outs[0] = state[0],
        MacroKind::StdpCaseGen => {
            let (x, y, le) = (ins[0], ins[1], ins[2]);
            outs[0] = x & y & le;
            outs[1] = x & y & !le;
            outs[2] = x & !y;
            outs[3] = !x & y;
        }
        MacroKind::StabilizeFunc => {
            let (s0, s1, s2) = (ins[8], ins[9], ins[10]);
            let mut acc = 0u64;
            for (i, &d) in ins[..8].iter().enumerate() {
                let m0 = if i & 1 != 0 { s0 } else { !s0 };
                let m1 = if i & 2 != 0 { s1 } else { !s1 };
                let m2 = if i & 4 != 0 { s2 } else { !s2 };
                acc |= d & m0 & m1 & m2;
            }
            outs[0] = acc;
        }
        MacroKind::IncDec => {
            outs[0] = ins[0] | ins[2];
            outs[1] = ins[1] | ins[3];
        }
        MacroKind::Edge2Pulse => outs[0] = ins[0] & !state[0],
        MacroKind::SpikeGen => {
            let done = state[3];
            outs[0] = ins[0] & !done;
            outs[1] = state[0];
            outs[2] = state[1];
            outs[3] = state[2];
        }
    }
}

/// Compute sequential next-state for 64 lanes at once (the packed
/// counterpart of [`next_state`]).
pub fn next_state_packed(kind: CellKind, ins: &[u64], state: &[u64], next: &mut [u64]) {
    use CellKind::*;
    match kind {
        Dff => next[0] = ins[0],
        DffR => next[0] = !ins[1] & ins[0],
        DffRn => next[0] = ins[1] & ins[0],
        Latch => next[0] = sel(ins[1], ins[0], state[0]),
        Macro(m) => next_state_macro_packed(m, ins, state, next),
        _ => {}
    }
}

fn next_state_macro_packed(m: MacroKind, ins: &[u64], state: &[u64], next: &mut [u64]) {
    match m {
        MacroKind::SynWeightUpdate => {
            // Saturating ±1 on a 3-bit counter, inc priority — the
            // branch-free form of the scalar arithmetic.
            let (w0, w1, w2) = (state[0], state[1], state[2]);
            let (inc, dec) = (ins[0], ins[1]);
            let at_max = w0 & w1 & w2;
            let at_min = !(w0 | w1 | w2);
            let up = inc & !at_max;
            let down = dec & !inc & !at_min;
            // +1 ripple.
            let i0 = !w0;
            let i1 = w1 ^ w0;
            let i2 = w2 ^ (w1 & w0);
            // -1 borrow ripple.
            let d0 = !w0;
            let d1 = w1 ^ !w0;
            let d2 = w2 ^ (!w1 & !w0);
            let hold = !(up | down);
            next[0] = (up & i0) | (down & d0) | (hold & w0);
            next[1] = (up & i1) | (down & d1) | (hold & w1);
            next[2] = (up & i2) | (down & d2) | (hold & w2);
        }
        MacroKind::Pulse2EdgePwr | MacroKind::Pulse2EdgeArea => {
            next[0] = !ins[1] & (state[0] | ins[0]);
        }
        MacroKind::Edge2Pulse => next[0] = ins[0],
        MacroKind::SpikeGen => {
            // 4-bit counter saturating at 8 (state[3] is the done bit),
            // cleared by rst, counting while the input level is high.
            let (s0, s1, s2, s3) = (state[0], state[1], state[2], state[3]);
            let up = ins[0] & !s3;
            let i0 = !s0;
            let c0 = s0;
            let i1 = s1 ^ c0;
            let c1 = s1 & c0;
            let i2 = s2 ^ c1;
            let c2 = s2 & c1;
            let i3 = s3 ^ c2;
            let live = !ins[1];
            next[0] = live & ((up & i0) | (!up & s0));
            next[1] = live & ((up & i1) | (!up & s1));
            next[2] = live & ((up & i2) | (!up & s2));
            next[3] = live & ((up & i3) | (!up & s3));
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: CellKind, ins: &[bool], state: &[bool], n_out: usize) -> Vec<bool> {
        let mut o = vec![false; n_out];
        eval_comb(kind, ins, state, &mut o);
        o
    }

    #[test]
    fn basic_gates_truth_tables() {
        for a in [false, true] {
            for b in [false, true] {
                assert_eq!(ev(CellKind::Nand2, &[a, b], &[], 1)[0], !(a & b));
                assert_eq!(ev(CellKind::Xor2, &[a, b], &[], 1)[0], a ^ b);
                assert_eq!(ev(CellKind::Nor2, &[a, b], &[], 1)[0], !(a | b));
                for c in [false, true] {
                    assert_eq!(
                        ev(CellKind::Maj3, &[a, b, c], &[], 1)[0],
                        (a & b) | (b & c) | (a & c)
                    );
                    assert_eq!(
                        ev(CellKind::Xor3, &[a, b, c], &[], 1)[0],
                        a ^ b ^ c
                    );
                    assert_eq!(
                        ev(CellKind::Mux2, &[a, b, c], &[], 1)[0],
                        if c { b } else { a }
                    );
                    assert_eq!(
                        ev(CellKind::Aoi21, &[a, b, c], &[], 1)[0],
                        !((a & b) | c)
                    );
                }
            }
        }
    }

    #[test]
    fn full_adder_macro_matches_arithmetic() {
        for v in 0..8u8 {
            let ins = [(v & 1) != 0, (v & 2) != 0, (v & 4) != 0];
            let o = ev(CellKind::Macro(MacroKind::PacAdder), &ins, &[], 2);
            let sum = (v & 1) + ((v >> 1) & 1) + ((v >> 2) & 1);
            assert_eq!(o[0], sum & 1 != 0);
            assert_eq!(o[1], sum >= 2);
        }
    }

    #[test]
    fn syn_output_compares_count_weight() {
        for c in 0..8u8 {
            for w in 0..8u8 {
                let ins = [
                    c & 1 != 0, c & 2 != 0, c & 4 != 0,
                    w & 1 != 0, w & 2 != 0, w & 4 != 0,
                    true,
                ];
                let o = ev(CellKind::Macro(MacroKind::SynOutput), &ins, &[], 1);
                assert_eq!(o[0], c < w, "c={c} w={w}");
            }
        }
    }

    #[test]
    fn syn_weight_update_saturates() {
        let m = CellKind::Macro(MacroKind::SynWeightUpdate);
        let mut next = [false; 3];
        // inc at w=7 holds
        next_state(m, &[true, false], &[true, true, true], &mut next);
        assert_eq!(next, [true, true, true]);
        // dec at w=0 holds
        next_state(m, &[false, true], &[false, false, false], &mut next);
        assert_eq!(next, [false, false, false]);
        // inc beats dec
        next_state(m, &[true, true], &[true, false, false], &mut next);
        assert_eq!(next, [false, true, false]); // 1 -> 2
    }

    #[test]
    fn stabilize_func_selects_by_weight() {
        let m = CellKind::Macro(MacroKind::StabilizeFunc);
        for sel in 0..8usize {
            let mut ins = vec![false; 11];
            ins[sel] = true;
            ins[8] = sel & 1 != 0;
            ins[9] = sel & 2 != 0;
            ins[10] = sel & 4 != 0;
            assert!(ev(m, &ins, &[], 1)[0], "sel={sel}");
        }
    }

    #[test]
    fn spike_gen_counts_eight_cycles() {
        let m = CellKind::Macro(MacroKind::SpikeGen);
        let mut state = [false; 4];
        let mut pulses = 0;
        for _ in 0..20 {
            let mut o = [false; 4];
            eval_comb(m, &[true, false], &state, &mut o);
            if o[0] {
                pulses += 1;
            }
            let mut next = [false; 4];
            next_state(m, &[true, false], &state, &mut next);
            state = next;
        }
        assert_eq!(pulses, 8);
        // reset clears the counter
        let mut next = [false; 4];
        next_state(m, &[false, true], &state, &mut next);
        assert_eq!(next, [false; 4]);
    }

    #[test]
    fn edge2pulse_single_cycle() {
        let m = CellKind::Macro(MacroKind::Edge2Pulse);
        let mut state = [false];
        let mut seen = Vec::new();
        for d in [false, true, true, true, false, true] {
            let mut o = [false];
            eval_comb(m, &[d], &state, &mut o);
            seen.push(o[0]);
            let mut n = [false];
            next_state(m, &[d], &state, &mut n);
            state = n;
        }
        assert_eq!(seen, vec![false, true, false, false, false, true]);
    }

    #[test]
    fn pulse2edge_latches_until_reset() {
        for m in [MacroKind::Pulse2EdgePwr, MacroKind::Pulse2EdgeArea] {
            let k = CellKind::Macro(m);
            let mut state = [false];
            // pulse then hold
            let mut n = [false];
            next_state(k, &[true, false], &state, &mut n);
            state = n;
            let mut o = [false];
            eval_comb(k, &[false, false], &state, &mut o);
            assert!(o[0], "{m:?} holds");
            // reset clears
            next_state(k, &[false, true], &state, &mut n);
            assert!(!n[0]);
        }
    }

    fn all_kinds() -> Vec<CellKind> {
        use CellKind::*;
        let mut v = vec![
            Tie0, Tie1, Inv, Buf, Nand2, Nand3, Nand4, Nor2, Nor3, And2,
            And3, Or2, Or3, Xor2, Xnor2, Xor3, Maj3, Aoi21, Oai21, Mux2,
            Dff, DffR, DffRn, Latch,
        ];
        for m in [
            MacroKind::SynWeightUpdate,
            MacroKind::SynOutput,
            MacroKind::PacAdder,
            MacroKind::LessEqual,
            MacroKind::Pulse2EdgePwr,
            MacroKind::Pulse2EdgeArea,
            MacroKind::StdpCaseGen,
            MacroKind::StabilizeFunc,
            MacroKind::IncDec,
            MacroKind::Mux2Gdi,
            MacroKind::Edge2Pulse,
            MacroKind::SpikeGen,
        ] {
            v.push(Macro(m));
        }
        v
    }

    /// The packed kernels are a second, branch-free implementation of
    /// the cell semantics; sweep EVERY (input, state) assignment of
    /// every cell kind against the scalar reference, 64 cases per word.
    #[test]
    fn packed_kernels_match_scalar_reference_exhaustively() {
        for kind in all_kinds() {
            let (n_in, n_out, n_state) = kind.pins();
            let bits = n_in + n_state;
            let total: u64 = 1 << bits;
            let mut case = 0u64;
            while case < total {
                let lanes = (total - case).min(64) as usize;
                let mut wi = vec![0u64; n_in];
                let mut ws = vec![0u64; n_state];
                for l in 0..lanes {
                    let a = case + l as u64;
                    for (k, w) in wi.iter_mut().enumerate() {
                        *w |= ((a >> k) & 1) << l;
                    }
                    for (k, w) in ws.iter_mut().enumerate() {
                        *w |= ((a >> (n_in + k)) & 1) << l;
                    }
                }
                let mut wo = vec![0u64; n_out];
                let mut wn = vec![0u64; n_state];
                eval_comb_packed(kind, &wi, &ws, &mut wo);
                next_state_packed(kind, &wi, &ws, &mut wn);
                for l in 0..lanes {
                    let a = case + l as u64;
                    let ins: Vec<bool> =
                        (0..n_in).map(|k| (a >> k) & 1 == 1).collect();
                    let st: Vec<bool> = (0..n_state)
                        .map(|k| (a >> (n_in + k)) & 1 == 1)
                        .collect();
                    let mut outs = vec![false; n_out];
                    eval_comb(kind, &ins, &st, &mut outs);
                    let mut nx = vec![false; n_state];
                    next_state(kind, &ins, &st, &mut nx);
                    for k in 0..n_out {
                        assert_eq!(
                            wo[k] >> l & 1 == 1,
                            outs[k],
                            "{kind:?} case {a} out {k}"
                        );
                    }
                    for k in 0..n_state {
                        assert_eq!(
                            wn[k] >> l & 1 == 1,
                            nx[k],
                            "{kind:?} case {a} next-state {k}"
                        );
                    }
                }
                case += lanes as u64;
            }
        }
    }

    #[test]
    fn comb_deps_break_dff_feedback() {
        assert_eq!(comb_deps(CellKind::Dff), 0);
        assert_eq!(comb_deps(CellKind::DffR), 0b10);
        assert_eq!(
            comb_deps(CellKind::Macro(MacroKind::SynWeightUpdate)),
            0
        );
        assert_eq!(comb_deps(CellKind::Nand2), 0b11);
    }
}
