//! Pure cell semantics: the single source of truth for what every cell
//! *does*.
//!
//! Three functions per cell kind:
//! * [`eval_comb`] — output values from current input nets + current state.
//! * [`next_state`] — sequential next-state from settled inputs + state.
//! * [`comb_deps`] — which input pins the outputs depend on
//!   *combinationally* (levelization must order only those; e.g. a plain
//!   DFF's Q depends on no input, so Q→logic→D loops are legal).
//!
//! The behavioral models of the custom macros here are what the
//! std-flavour gate builders in [`crate::netlist::modules`] are proven
//! equivalent to (their unit tests sweep both through the simulator).

use crate::cells::{CellKind, MacroKind};

/// Evaluate combinational outputs.
///
/// `ins` are current net values, `state` the instance's current state
/// bits, `outs` is written in pin order.
pub fn eval_comb(kind: CellKind, ins: &[bool], state: &[bool], outs: &mut [bool]) {
    use CellKind::*;
    match kind {
        Tie0 => outs[0] = false,
        Tie1 => outs[0] = true,
        Inv => outs[0] = !ins[0],
        Buf => outs[0] = ins[0],
        Nand2 => outs[0] = !(ins[0] & ins[1]),
        Nand3 => outs[0] = !(ins[0] & ins[1] & ins[2]),
        Nand4 => outs[0] = !(ins[0] & ins[1] & ins[2] & ins[3]),
        Nor2 => outs[0] = !(ins[0] | ins[1]),
        Nor3 => outs[0] = !(ins[0] | ins[1] | ins[2]),
        And2 => outs[0] = ins[0] & ins[1],
        And3 => outs[0] = ins[0] & ins[1] & ins[2],
        Or2 => outs[0] = ins[0] | ins[1],
        Or3 => outs[0] = ins[0] | ins[1] | ins[2],
        Xor2 => outs[0] = ins[0] ^ ins[1],
        Xnor2 => outs[0] = !(ins[0] ^ ins[1]),
        Xor3 => outs[0] = ins[0] ^ ins[1] ^ ins[2],
        Maj3 => {
            outs[0] = (ins[0] & ins[1]) | (ins[1] & ins[2]) | (ins[0] & ins[2])
        }
        Aoi21 => outs[0] = !((ins[0] & ins[1]) | ins[2]),
        Oai21 => outs[0] = !((ins[0] | ins[1]) & ins[2]),
        Mux2 => outs[0] = if ins[2] { ins[1] } else { ins[0] },
        Dff => outs[0] = state[0],
        // Async active-high reset shows at Q immediately.
        DffR => outs[0] = !ins[1] & state[0],
        // Sync active-low reset: Q is just the state.
        DffRn => outs[0] = state[0],
        // Transparent-high latch.
        Latch => outs[0] = if ins[1] { ins[0] } else { state[0] },
        Macro(m) => eval_macro(m, ins, state, outs),
    }
}

fn eval_macro(m: MacroKind, ins: &[bool], state: &[bool], outs: &mut [bool]) {
    match m {
        // Fig. 2: weight register drives its value; update is sequential.
        MacroKind::SynWeightUpdate => {
            outs[0] = state[0];
            outs[1] = state[1];
            outs[2] = state[2];
        }
        // Fig. 3: up = pulse & (count < weight), both 3-bit LSB-first.
        MacroKind::SynOutput => {
            let c = bits3(ins[0], ins[1], ins[2]);
            let w = bits3(ins[3], ins[4], ins[5]);
            outs[0] = ins[6] && c < w;
        }
        // Fig. 4: full-adder slice.
        MacroKind::PacAdder => {
            outs[0] = ins[0] ^ ins[1] ^ ins[2];
            outs[1] = (ins[0] & ins[1]) | (ins[1] & ins[2]) | (ins[0] & ins[2]);
        }
        // Fig. 5: monotone-level "arrived no later": le = a | !b.
        MacroKind::LessEqual => outs[0] = ins[0] | !ins[1],
        // Fig. 6: async reset visible at output immediately.
        MacroKind::Pulse2EdgePwr => outs[0] = !ins[1] & state[0],
        // Fig. 7: sync reset; output is the registered level.
        MacroKind::Pulse2EdgeArea => outs[0] = state[0],
        // Fig. 8: the four STDP timing cases from (x, y, le).
        MacroKind::StdpCaseGen => {
            let (x, y, le) = (ins[0], ins[1], ins[2]);
            outs[0] = x & y & le; // capture
            outs[1] = x & y & !le; // backoff
            outs[2] = x & !y; // search
            outs[3] = !x & y; // minus
        }
        // Fig. 9: 8:1 BRV select by 3-bit weight (s LSB-first at ins[8..11]).
        MacroKind::StabilizeFunc => {
            let sel = bits3(ins[8], ins[9], ins[10]) as usize;
            outs[0] = ins[sel];
        }
        // Fig. 10: inc = capture|search, dec = backoff|minus.
        MacroKind::IncDec => {
            outs[0] = ins[0] | ins[2];
            outs[1] = ins[1] | ins[3];
        }
        // Fig. 11: GDI mux.
        MacroKind::Mux2Gdi => outs[0] = if ins[2] { ins[1] } else { ins[0] },
        // Fig. 13: one-cycle pulse on rising edge.
        MacroKind::Edge2Pulse => outs[0] = ins[0] & !state[0],
        // Fig. 12: pulse = d & count<8; count exported (3 LSBs).
        MacroKind::SpikeGen => {
            let done = state[3];
            outs[0] = ins[0] & !done;
            outs[1] = state[0];
            outs[2] = state[1];
            outs[3] = state[2];
        }
    }
}

/// Compute sequential next-state (called after combinational settle).
pub fn next_state(kind: CellKind, ins: &[bool], state: &[bool], next: &mut [bool]) {
    use CellKind::*;
    match kind {
        Dff => next[0] = ins[0],
        DffR => next[0] = !ins[1] & ins[0],
        DffRn => next[0] = ins[1] & ins[0],
        Latch => next[0] = if ins[1] { ins[0] } else { state[0] },
        Macro(m) => next_state_macro(m, ins, state, next),
        _ => {}
    }
}

fn next_state_macro(m: MacroKind, ins: &[bool], state: &[bool], next: &mut [bool]) {
    match m {
        MacroKind::SynWeightUpdate => {
            let w = bits3(state[0], state[1], state[2]);
            let (inc, dec) = (ins[0], ins[1]);
            // inc has priority; saturate at [0, 7] — identical to the
            // std-flavour sat_updown3 logic.
            let nw = if inc && w < 7 {
                w + 1
            } else if dec && !inc && w > 0 {
                w - 1
            } else {
                w
            };
            next[0] = nw & 1 != 0;
            next[1] = nw & 2 != 0;
            next[2] = nw & 4 != 0;
        }
        MacroKind::Pulse2EdgePwr | MacroKind::Pulse2EdgeArea => {
            next[0] = !ins[1] & (state[0] | ins[0]);
        }
        MacroKind::Edge2Pulse => next[0] = ins[0],
        MacroKind::SpikeGen => {
            // 4-bit saturating cycle counter, cleared by rst (ins[1]);
            // counts while the input level is high and count < 8.
            let c = bits3(state[0], state[1], state[2]) + if state[3] { 8 } else { 0 };
            let nc = if ins[1] {
                0
            } else if ins[0] && c < 8 {
                c + 1
            } else {
                c
            };
            next[0] = nc & 1 != 0;
            next[1] = nc & 2 != 0;
            next[2] = nc & 4 != 0;
            next[3] = nc & 8 != 0;
        }
        _ => {}
    }
}

/// Bitmask of input pins that outputs depend on *combinationally*.
pub fn comb_deps(kind: CellKind) -> u16 {
    use CellKind::*;
    match kind {
        Tie0 | Tie1 => 0,
        Dff | DffRn => 0,               // Q = state only
        DffR => 0b10,                   // Q sees async rst (pin 1)
        Latch => 0b11,                  // transparent path
        Macro(m) => match m {
            MacroKind::SynWeightUpdate => 0,
            MacroKind::Pulse2EdgeArea => 0,
            MacroKind::Pulse2EdgePwr => 0b10, // async rst
            MacroKind::Edge2Pulse => 0b1,     // out = d & !prev
            MacroKind::SpikeGen => 0b01,      // pulse = d & !done
            _ => all_ins(kind),
        },
        _ => all_ins(kind),
    }
}

fn all_ins(kind: CellKind) -> u16 {
    let (n, _, _) = kind.pins();
    ((1u32 << n) - 1) as u16
}

fn bits3(b0: bool, b1: bool, b2: bool) -> u8 {
    (b0 as u8) | ((b1 as u8) << 1) | ((b2 as u8) << 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: CellKind, ins: &[bool], state: &[bool], n_out: usize) -> Vec<bool> {
        let mut o = vec![false; n_out];
        eval_comb(kind, ins, state, &mut o);
        o
    }

    #[test]
    fn basic_gates_truth_tables() {
        for a in [false, true] {
            for b in [false, true] {
                assert_eq!(ev(CellKind::Nand2, &[a, b], &[], 1)[0], !(a & b));
                assert_eq!(ev(CellKind::Xor2, &[a, b], &[], 1)[0], a ^ b);
                assert_eq!(ev(CellKind::Nor2, &[a, b], &[], 1)[0], !(a | b));
                for c in [false, true] {
                    assert_eq!(
                        ev(CellKind::Maj3, &[a, b, c], &[], 1)[0],
                        (a & b) | (b & c) | (a & c)
                    );
                    assert_eq!(
                        ev(CellKind::Xor3, &[a, b, c], &[], 1)[0],
                        a ^ b ^ c
                    );
                    assert_eq!(
                        ev(CellKind::Mux2, &[a, b, c], &[], 1)[0],
                        if c { b } else { a }
                    );
                    assert_eq!(
                        ev(CellKind::Aoi21, &[a, b, c], &[], 1)[0],
                        !((a & b) | c)
                    );
                }
            }
        }
    }

    #[test]
    fn full_adder_macro_matches_arithmetic() {
        for v in 0..8u8 {
            let ins = [(v & 1) != 0, (v & 2) != 0, (v & 4) != 0];
            let o = ev(CellKind::Macro(MacroKind::PacAdder), &ins, &[], 2);
            let sum = (v & 1) + ((v >> 1) & 1) + ((v >> 2) & 1);
            assert_eq!(o[0], sum & 1 != 0);
            assert_eq!(o[1], sum >= 2);
        }
    }

    #[test]
    fn syn_output_compares_count_weight() {
        for c in 0..8u8 {
            for w in 0..8u8 {
                let ins = [
                    c & 1 != 0, c & 2 != 0, c & 4 != 0,
                    w & 1 != 0, w & 2 != 0, w & 4 != 0,
                    true,
                ];
                let o = ev(CellKind::Macro(MacroKind::SynOutput), &ins, &[], 1);
                assert_eq!(o[0], c < w, "c={c} w={w}");
            }
        }
    }

    #[test]
    fn syn_weight_update_saturates() {
        let m = CellKind::Macro(MacroKind::SynWeightUpdate);
        let mut next = [false; 3];
        // inc at w=7 holds
        next_state(m, &[true, false], &[true, true, true], &mut next);
        assert_eq!(next, [true, true, true]);
        // dec at w=0 holds
        next_state(m, &[false, true], &[false, false, false], &mut next);
        assert_eq!(next, [false, false, false]);
        // inc beats dec
        next_state(m, &[true, true], &[true, false, false], &mut next);
        assert_eq!(next, [false, true, false]); // 1 -> 2
    }

    #[test]
    fn stabilize_func_selects_by_weight() {
        let m = CellKind::Macro(MacroKind::StabilizeFunc);
        for sel in 0..8usize {
            let mut ins = vec![false; 11];
            ins[sel] = true;
            ins[8] = sel & 1 != 0;
            ins[9] = sel & 2 != 0;
            ins[10] = sel & 4 != 0;
            assert!(ev(m, &ins, &[], 1)[0], "sel={sel}");
        }
    }

    #[test]
    fn spike_gen_counts_eight_cycles() {
        let m = CellKind::Macro(MacroKind::SpikeGen);
        let mut state = [false; 4];
        let mut pulses = 0;
        for _ in 0..20 {
            let mut o = [false; 4];
            eval_comb(m, &[true, false], &state, &mut o);
            if o[0] {
                pulses += 1;
            }
            let mut next = [false; 4];
            next_state(m, &[true, false], &state, &mut next);
            state = next;
        }
        assert_eq!(pulses, 8);
        // reset clears the counter
        let mut next = [false; 4];
        next_state(m, &[false, true], &state, &mut next);
        assert_eq!(next, [false; 4]);
    }

    #[test]
    fn edge2pulse_single_cycle() {
        let m = CellKind::Macro(MacroKind::Edge2Pulse);
        let mut state = [false];
        let mut seen = Vec::new();
        for d in [false, true, true, true, false, true] {
            let mut o = [false];
            eval_comb(m, &[d], &state, &mut o);
            seen.push(o[0]);
            let mut n = [false];
            next_state(m, &[d], &state, &mut n);
            state = n;
        }
        assert_eq!(seen, vec![false, true, false, false, false, true]);
    }

    #[test]
    fn pulse2edge_latches_until_reset() {
        for m in [MacroKind::Pulse2EdgePwr, MacroKind::Pulse2EdgeArea] {
            let k = CellKind::Macro(m);
            let mut state = [false];
            // pulse then hold
            let mut n = [false];
            next_state(k, &[true, false], &state, &mut n);
            state = n;
            let mut o = [false];
            eval_comb(k, &[false, false], &state, &mut o);
            assert!(o[0], "{m:?} holds");
            // reset clears
            next_state(k, &[false, true], &state, &mut n);
            assert!(!n[0]);
        }
    }

    #[test]
    fn comb_deps_break_dff_feedback() {
        assert_eq!(comb_deps(CellKind::Dff), 0);
        assert_eq!(comb_deps(CellKind::DffR), 0b10);
        assert_eq!(
            comb_deps(CellKind::Macro(MacroKind::SynWeightUpdate)),
            0
        );
        assert_eq!(comb_deps(CellKind::Nand2), 0b11);
    }
}
