//! First-class technology backends: pluggable cell libraries behind one
//! trait.
//!
//! The paper's contribution is a *library* — a custom 7nm macro suite
//! layered on ASAP7, with 45nm comparisons — and its follow-ups (TNN7,
//! the TNN design framework) treat the cell library as a swappable
//! input to one design flow.  This module makes that the code's shape
//! too: everything PPA and elaboration used to pull from three places
//! (the characterized [`Library`], the [`TechParams`] constants, and
//! the node-scaling projection that was hard-wired into the flow as a
//! `TechNode` enum) is bundled behind one [`TechBackend`] trait, and
//! backends are resolved by name through a [`TechRegistry`]:
//!
//! * [`TechBackend`] — the trait: identity (`name`, `node_label`,
//!   `voltage_v`), the characterized [`Library`], the [`TechParams`]
//!   scale constants, and the node projection applied to natively
//!   measured PPA ([`TechBackend::project`], identity unless the
//!   backend wraps another node).
//! * [`TechContext`] — a cheaply-cloneable `Arc<dyn TechBackend>`
//!   handle; the one value the flow stages carry instead of
//!   `(lib, tech)` pairs.  Sweeps that share a context share one
//!   characterized library — no per-job re-characterization.
//! * [`TechRegistry`] — name → backend resolution, including loading
//!   `.lib` files on demand via [`backends::load_liberty`].
//!
//! Four built-in backends ship (see [`backends`]):
//!
//! | name             | library                    | node  | projection |
//! |------------------|----------------------------|-------|------------|
//! | `asap7-baseline` | ASAP7 RVT subset only      | 7nm   | identity   |
//! | `asap7-tnn7`     | ASAP7 + 11 custom macros   | 7nm   | identity   |
//! | `n45-projected`  | wraps `asap7-tnn7`         | 45nm  | [`NodeScaling::n45_to_7`] |
//! | `liberty-file`   | parsed from any tnn7 `.lib`| as characterized | identity |
//!
//! `n45-projected` replaces the old bolt-on `scale45` flow stage: the
//! 45nm comparison is now just a backend whose [`TechBackend::project`]
//! applies the first-order scaling model to the natively composed PPA —
//! bit-identical to what the pre-refactor 45nm target node produced.
//! Comparing the paper's Table I flavours is the degenerate case of
//! sweeping any set of registered technologies, including user-supplied
//! libraries (`tnn7 flow --tech path/to/own.lib`).
//!
//! See DESIGN.md §9 for the trait contract and how to add a backend.

pub mod backends;
pub mod registry;

pub use backends::{
    asap7_baseline, asap7_tnn7, from_liberty_text, load_liberty,
    n45_projected, ProjectedBackend, StaticBackend,
};
pub use registry::{resolve_standalone, TechRegistry};

use std::fmt;
use std::sync::Arc;

use crate::cells::{Library, TechParams};
use crate::ppa::report::ColumnPpa;
use crate::ppa::scaling::NodeScaling;

/// Registry name of the plain-ASAP7 built-in backend.
pub const ASAP7_BASELINE: &str = "asap7-baseline";
/// Registry name of the ASAP7 + custom-macro built-in backend (the
/// default technology everywhere).
pub const ASAP7_TNN7: &str = "asap7-tnn7";
/// Registry name of the 45nm node-projection backend.
pub const N45_PROJECTED: &str = "n45-projected";

/// Map legacy node descriptors to backend names (`std:45nm` targets
/// keep working) and strip the explicit `liberty-file:` prefix —
/// liberty backends register under the bare path, so both spec forms
/// resolve to the same entry.  Registered names and bare `.lib` paths
/// pass through untouched.
pub fn canonical_name(name: &str) -> &str {
    let name = name.trim();
    if let Some(path) = name.strip_prefix("liberty-file:") {
        return path;
    }
    match name {
        "7nm" | "7" => ASAP7_TNN7,
        "45nm" | "45" => N45_PROJECTED,
        other => other,
    }
}

/// Name of a registered technology backend, as carried by a
/// [`crate::flow::Target`].  Legacy node aliases (`7nm`, `45nm`) are
/// canonicalized at construction.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BackendId(String);

impl BackendId {
    /// Id from a backend name, `.lib` path, or legacy node alias.
    pub fn new(name: impl AsRef<str>) -> BackendId {
        BackendId(canonical_name(name.as_ref()).to_string())
    }

    /// The backend name this id resolves through the registry.
    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl Default for BackendId {
    fn default() -> Self {
        BackendId(ASAP7_TNN7.to_string())
    }
}

impl fmt::Display for BackendId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// Per-node wire and row geometry parameters — the physical-design
/// substrate [`crate::phys`] pulls from a backend (floorplan row
/// height, wire RC, and the wire-energy/delay slopes the placed-design
/// PPA corrections use).
///
/// Lengths are in mm so the per-net half-perimeter wirelengths the
/// placer produces multiply in directly.  `energy_fj_per_mm` is
/// expressed in the same *fitted* energy scale as the cell library
/// (the calibrated constants absorb the paper's post-layout wiring, so
/// the wire term is a differential attribution, not an independent
/// physical extraction — DESIGN.md §10).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireParams {
    /// Standard-cell row height (µm) — sets the floorplan row grid and
    /// converts cell areas to placement widths.
    pub row_height_um: f64,
    /// Physical wire capacitance per mm of routed net (fF/mm).
    pub cap_ff_per_mm: f64,
    /// Physical wire resistance per mm of routed net (Ω/mm).
    pub res_ohm_per_mm: f64,
    /// Wire switching energy per output toggle per mm of net, in the
    /// library's fitted energy scale (fJ/mm).
    pub energy_fj_per_mm: f64,
    /// Driver-loading delay slope: extra driver delay per mm of driven
    /// net (ps/mm), the linear term of the Elmore model.
    pub delay_ps_per_mm: f64,
}

impl WireParams {
    /// 7nm (ASAP7-like) wire stack: 270nm rows (7.5-track), fine-pitch
    /// high-resistance metal.
    pub fn asap7() -> WireParams {
        WireParams {
            row_height_um: 0.27,
            cap_ff_per_mm: 200.0,
            res_ohm_per_mm: 40_000.0,
            energy_fj_per_mm: 0.40,
            delay_ps_per_mm: 800.0,
        }
    }

    /// 45nm wire stack: tall rows, fatter/less-resistive wires, more
    /// capacitance and a slower driver-loading slope per mm.
    pub fn n45() -> WireParams {
        WireParams {
            row_height_um: 1.40,
            cap_ff_per_mm: 240.0,
            res_ohm_per_mm: 2_500.0,
            energy_fj_per_mm: 0.90,
            delay_ps_per_mm: 1_600.0,
        }
    }
}

/// A technology backend: one characterized cell library plus the
/// metadata and projection needed to report PPA in its node.
///
/// Implementations must be cheap to *borrow from* (the flow queries
/// `library()`/`params()` per stage) and are shared across sweep
/// worker threads behind an `Arc` — hence `Send + Sync`.
pub trait TechBackend: Send + Sync {
    /// Registry name (`asap7-tnn7`, a `.lib` path, …).
    fn name(&self) -> &str;

    /// Human node label (`7nm`, `45nm`, `as-characterized`).
    fn node_label(&self) -> &str;

    /// Nominal supply voltage in volts (0.7 for the paper's corner).
    fn voltage_v(&self) -> f64;

    /// The characterized cell library elaboration and PPA consume.
    fn library(&self) -> &Library;

    /// The technology scale constants mapping the library's relative
    /// quantities to absolute µm² / fJ / nW / ps.
    fn params(&self) -> &TechParams;

    /// Wire and row parameters for the physical-design model
    /// ([`crate::phys`]).  Defaults to the 7nm ASAP7-like stack;
    /// backends reporting in another node override this so asap7 vs
    /// n45-projected see different wire RC.
    fn wire_params(&self) -> WireParams {
        WireParams::asap7()
    }

    /// The node-scaling model behind [`TechBackend::project`], if this
    /// backend reports in a different node than it measures in.
    fn scaling(&self) -> Option<NodeScaling> {
        None
    }

    /// Project natively measured PPA into this backend's reporting
    /// node.  Identity for native backends; wrapping backends apply
    /// their [`NodeScaling`] factors.
    fn project(&self, ppa: ColumnPpa) -> ColumnPpa {
        ppa
    }

    /// One-line description for `--help` and docs.
    fn describe(&self) -> String {
        format!("{} [{}]", self.name(), self.node_label())
    }
}

/// Shared handle to a [`TechBackend`] — the one value the flow carries
/// instead of `(lib, tech)` pairs.
///
/// Cloning is an `Arc` bump: a registry, N sweep workers, and M flow
/// contexts all share the same characterized library.
#[derive(Clone)]
pub struct TechContext {
    backend: Arc<dyn TechBackend>,
}

impl TechContext {
    /// Wrap a backend implementation.
    pub fn new(backend: impl TechBackend + 'static) -> TechContext {
        TechContext { backend: Arc::new(backend) }
    }

    /// Ad-hoc backend from explicit parts (calibration fits use
    /// unit-scale [`TechParams`]; tests substitute their own libraries).
    pub fn from_parts(
        name: impl Into<String>,
        node_label: impl Into<String>,
        lib: Library,
        params: TechParams,
    ) -> TechContext {
        TechContext::new(StaticBackend::new(name, node_label, 0.7, lib, params))
    }

    /// Borrow the backend as a trait object.
    pub fn backend(&self) -> &dyn TechBackend {
        &*self.backend
    }

    /// Backend name.
    pub fn name(&self) -> &str {
        self.backend.name()
    }

    /// Node label.
    pub fn node_label(&self) -> &str {
        self.backend.node_label()
    }

    /// Supply voltage (V).
    pub fn voltage_v(&self) -> f64 {
        self.backend.voltage_v()
    }

    /// The backend's characterized library.
    pub fn library(&self) -> &Library {
        self.backend.library()
    }

    /// The backend's technology constants.
    pub fn params(&self) -> &TechParams {
        self.backend.params()
    }

    /// The backend's wire/row parameters (physical-design model).
    pub fn wire_params(&self) -> WireParams {
        self.backend.wire_params()
    }

    /// The backend's node-scaling model, if any.
    pub fn scaling(&self) -> Option<NodeScaling> {
        self.backend.scaling()
    }

    /// Project natively measured PPA to the backend's reporting node.
    pub fn project(&self, ppa: ColumnPpa) -> ColumnPpa {
        self.backend.project(ppa)
    }

    /// The [`BackendId`] targets use to name this backend.
    pub fn id(&self) -> BackendId {
        BackendId::new(self.backend.name())
    }
}

impl fmt::Debug for TechContext {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("TechContext")
            .field("name", &self.backend.name())
            .field("node", &self.backend.node_label())
            .field("cells", &self.backend.library().len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_id_canonicalizes_legacy_aliases() {
        assert_eq!(BackendId::new("7nm").as_str(), ASAP7_TNN7);
        assert_eq!(BackendId::new("45").as_str(), N45_PROJECTED);
        assert_eq!(BackendId::new("asap7-baseline").as_str(), ASAP7_BASELINE);
        assert_eq!(BackendId::new("out.lib").as_str(), "out.lib");
        // The explicit liberty-file: prefix canonicalizes to the bare
        // path the registry registers the backend under.
        assert_eq!(
            BackendId::new("liberty-file:/tmp/x.lib").as_str(),
            "/tmp/x.lib"
        );
        assert_eq!(BackendId::default().as_str(), ASAP7_TNN7);
    }

    #[test]
    fn context_shares_one_library_across_clones() {
        let ctx = TechContext::new(asap7_tnn7());
        let other = ctx.clone();
        assert!(std::ptr::eq(ctx.library(), other.library()));
        assert_eq!(ctx.name(), ASAP7_TNN7);
        assert_eq!(ctx.node_label(), "7nm");
        assert!(ctx.scaling().is_none());
    }

    #[test]
    fn wire_params_differ_per_node() {
        let native = TechContext::new(asap7_tnn7());
        assert_eq!(native.wire_params(), WireParams::asap7());
        let n45 = TechContext::new(n45_projected(native.clone()));
        assert_eq!(n45.wire_params(), WireParams::n45());
        assert!(
            n45.wire_params().row_height_um
                > native.wire_params().row_height_um
        );
        assert!(
            n45.wire_params().res_ohm_per_mm
                < native.wire_params().res_ohm_per_mm
        );
    }

    #[test]
    fn identity_projection_by_default() {
        let ctx = TechContext::new(asap7_baseline());
        let ppa = ColumnPpa { power_uw: 1.0, time_ns: 2.0, area_mm2: 3.0 };
        let p = ctx.project(ppa);
        assert_eq!(p.power_uw, 1.0);
        assert_eq!(p.time_ns, 2.0);
        assert_eq!(p.area_mm2, 3.0);
    }
}
