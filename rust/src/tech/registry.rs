//! Name → backend resolution.
//!
//! A [`TechRegistry`] owns one [`TechContext`] per registered backend;
//! every lookup hands out an `Arc` clone of the same characterized
//! library, so a sweep over N targets on the same technology
//! characterizes it exactly once.  `.lib` paths resolve by loading a
//! `liberty-file` backend on first use and registering it under the
//! path, making user-supplied libraries first-class sweep axes.

use std::path::Path;

use crate::error::{Error, Result};

use super::{backends, canonical_name, TechContext};

/// The set of resolvable technology backends.
pub struct TechRegistry {
    contexts: Vec<TechContext>,
}

impl TechRegistry {
    /// An empty registry (tests compose their own backends).
    pub fn empty() -> TechRegistry {
        TechRegistry { contexts: Vec::new() }
    }

    /// The built-in set: `asap7-baseline`, `asap7-tnn7`, and
    /// `n45-projected` wrapping `asap7-tnn7`.  Each library is
    /// characterized once, here.
    pub fn builtin() -> TechRegistry {
        let mut r = TechRegistry::empty();
        let tnn7 = TechContext::new(backends::asap7_tnn7());
        r.contexts.push(TechContext::new(backends::asap7_baseline()));
        r.contexts.push(tnn7.clone());
        r.contexts.push(TechContext::new(backends::n45_projected(tnn7)));
        r
    }

    /// Register a backend; its name must be unique.
    pub fn register(&mut self, ctx: TechContext) -> Result<()> {
        if self.contexts.iter().any(|c| c.name() == ctx.name()) {
            return Err(Error::config(format!(
                "technology backend `{}` is already registered",
                ctx.name()
            )));
        }
        self.contexts.push(ctx);
        Ok(())
    }

    /// Look a backend up by name (legacy node aliases `7nm`/`45nm` and
    /// the `liberty-file:` prefix canonicalize first).
    ///
    /// `get` never touches the filesystem: `.lib` paths must have been
    /// loaded with [`TechRegistry::resolve`] first (sweep callers
    /// resolve every job's backend before handing the registry to
    /// [`crate::flow::compare::run_sweep`]).
    pub fn get(&self, name: &str) -> Result<TechContext> {
        let canon = canonical_name(name);
        self.contexts
            .iter()
            .find(|c| c.name() == canon)
            .cloned()
            .ok_or_else(|| {
                Error::config(format!(
                    "unknown technology backend `{name}` (registered: {}; \
                     `.lib` paths load via TechRegistry::resolve / the \
                     --tech flag)",
                    self.names().join(", ")
                ))
            })
    }

    /// Resolve a `--tech` spec: a registered name, a legacy node alias,
    /// or a `.lib` path (`liberty-file:PATH`, any spec ending in
    /// `.lib`, or an unregistered name that is an existing file),
    /// loading and registering the file on first use.
    pub fn resolve(&mut self, spec: &str) -> Result<TechContext> {
        let spec = spec.trim();
        let explicit = spec.strip_prefix("liberty-file:");
        let bare = explicit.unwrap_or(spec);
        if let Ok(existing) = self.get(bare) {
            return Ok(existing);
        }
        // Not a registered name: treat as a liberty file when marked as
        // one (prefix or .lib suffix) or when it names a real file —
        // covers `liberty-file:` paths whose extension isn't .lib after
        // BackendId canonicalization stripped the prefix.
        let is_lib = explicit.is_some()
            || bare.ends_with(".lib")
            || Path::new(bare).is_file();
        if is_lib {
            let ctx =
                TechContext::new(backends::load_liberty(Path::new(bare))?);
            self.register(ctx.clone())?;
            return Ok(ctx);
        }
        self.get(bare)
    }

    /// All registered backends.
    pub fn contexts(&self) -> &[TechContext] {
        &self.contexts
    }

    /// Registered backend names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.contexts.iter().map(|c| c.name()).collect()
    }
}

impl Default for TechRegistry {
    fn default() -> Self {
        TechRegistry::builtin()
    }
}

/// Resolve one spec to a backend *without* materializing the whole
/// builtin registry — only the named backend's library is
/// characterized.  Used by one-off contexts
/// ([`crate::flow::FlowContext::new`]); sweeps and the CLI keep a
/// shared [`TechRegistry`] instead so repeated lookups reuse one
/// library.
pub fn resolve_standalone(spec: &str) -> Result<TechContext> {
    let spec = spec.trim();
    let bare = spec.strip_prefix("liberty-file:").unwrap_or(spec);
    match canonical_name(bare) {
        super::ASAP7_BASELINE => {
            Ok(TechContext::new(backends::asap7_baseline()))
        }
        super::ASAP7_TNN7 => Ok(TechContext::new(backends::asap7_tnn7())),
        super::N45_PROJECTED => {
            let inner = TechContext::new(backends::asap7_tnn7());
            Ok(TechContext::new(backends::n45_projected(inner)))
        }
        path if path.ends_with(".lib") || Path::new(path).is_file() => Ok(
            TechContext::new(backends::load_liberty(Path::new(path))?),
        ),
        other => Err(Error::config(format!(
            "unknown technology backend `{other}` (built-in: {}, {}, {}; \
             or a `.lib` path)",
            super::ASAP7_BASELINE,
            super::ASAP7_TNN7,
            super::N45_PROJECTED
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ASAP7_BASELINE, ASAP7_TNN7, N45_PROJECTED};
    use super::*;
    use crate::cells::{liberty, Library, TechParams};

    #[test]
    fn builtin_names_and_alias_lookup() {
        let r = TechRegistry::builtin();
        assert_eq!(
            r.names(),
            vec![ASAP7_BASELINE, ASAP7_TNN7, N45_PROJECTED]
        );
        assert_eq!(r.get("7nm").unwrap().name(), ASAP7_TNN7);
        assert_eq!(r.get("45nm").unwrap().name(), N45_PROJECTED);
        assert!(r.get("intel4").is_err());
    }

    #[test]
    fn builtin_backends_share_libraries_not_copies() {
        let r = TechRegistry::builtin();
        let a = r.get(ASAP7_TNN7).unwrap();
        let b = r.get(ASAP7_TNN7).unwrap();
        assert!(std::ptr::eq(a.library(), b.library()));
        // n45 wraps the same characterized tnn7 library.
        let n45 = r.get(N45_PROJECTED).unwrap();
        assert!(std::ptr::eq(a.library(), n45.library()));
    }

    #[test]
    fn duplicate_registration_is_rejected() {
        let mut r = TechRegistry::builtin();
        let dup = TechContext::from_parts(
            ASAP7_TNN7,
            "7nm",
            Library::asap7_only(),
            TechParams::calibrated(),
        );
        assert!(r.register(dup).is_err());
    }

    #[test]
    fn standalone_resolution_builds_only_named_backend() {
        assert_eq!(resolve_standalone("7nm").unwrap().name(), ASAP7_TNN7);
        assert_eq!(
            resolve_standalone(ASAP7_BASELINE).unwrap().name(),
            ASAP7_BASELINE
        );
        assert_eq!(
            resolve_standalone(N45_PROJECTED).unwrap().node_label(),
            "45nm"
        );
        assert!(resolve_standalone("bogus").is_err());
        assert!(resolve_standalone("/nope/x.lib").is_err());
    }

    #[test]
    fn resolve_loads_and_caches_lib_files() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "tnn7_registry_{}.lib",
            std::process::id()
        ));
        let lib = Library::with_macros();
        let text =
            liberty::emit(&lib, &TechParams::calibrated(), "tmp_reg");
        std::fs::write(&path, text).unwrap();
        let spec = path.display().to_string();

        let mut r = TechRegistry::builtin();
        let a = r.resolve(&spec).unwrap();
        assert_eq!(a.name(), spec);
        assert_eq!(a.library().len(), lib.len());
        // Second resolve reuses the registered backend.
        let b = r.resolve(&spec).unwrap();
        assert!(std::ptr::eq(a.library(), b.library()));
        // And the prefixed form hits the same entry.
        let c = r.resolve(&format!("liberty-file:{spec}")).unwrap();
        assert!(std::ptr::eq(a.library(), c.library()));

        assert!(r.resolve("/nonexistent/nowhere.lib").is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
