//! The built-in [`TechBackend`] implementations.
//!
//! * [`StaticBackend`] — a self-contained (library, params, node)
//!   bundle; covers `asap7-baseline`, `asap7-tnn7`, ad-hoc test
//!   backends, and libraries loaded from `.lib` files.
//! * [`ProjectedBackend`] — wraps another backend and reports its
//!   natively measured PPA through a [`NodeScaling`] projection
//!   (`n45-projected`).
//! * [`from_liberty_text`] / [`load_liberty`] — the `liberty-file`
//!   backend kind: construct a [`StaticBackend`] from any `.lib` in the
//!   dialect [`crate::cells::liberty::emit`] writes.  Absolute units
//!   are baked into the per-cell quantities with unit scale constants,
//!   so an emitted-then-reloaded library reports bit-identical PPA to
//!   the in-memory backend it came from.

use std::path::Path;

use crate::cells::cell::Cell;
use crate::cells::{liberty, Library, TechParams};
use crate::error::{Error, Result};
use crate::ppa::report::ColumnPpa;
use crate::ppa::scaling::NodeScaling;

use super::{TechBackend, TechContext, ASAP7_BASELINE, ASAP7_TNN7, N45_PROJECTED};

/// A self-contained backend: owns its library, scale constants, and
/// node metadata.
pub struct StaticBackend {
    name: String,
    node_label: String,
    voltage_v: f64,
    lib: Library,
    params: TechParams,
}

impl StaticBackend {
    /// Bundle explicit parts into a backend.
    pub fn new(
        name: impl Into<String>,
        node_label: impl Into<String>,
        voltage_v: f64,
        lib: Library,
        params: TechParams,
    ) -> StaticBackend {
        StaticBackend {
            name: name.into(),
            node_label: node_label.into(),
            voltage_v,
            lib,
            params,
        }
    }
}

impl TechBackend for StaticBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn node_label(&self) -> &str {
        &self.node_label
    }

    fn voltage_v(&self) -> f64 {
        self.voltage_v
    }

    fn library(&self) -> &Library {
        &self.lib
    }

    fn params(&self) -> &TechParams {
        &self.params
    }
}

/// A backend that measures in another backend's library and reports in
/// a different node through a [`NodeScaling`] projection.
pub struct ProjectedBackend {
    name: String,
    node_label: String,
    voltage_v: f64,
    inner: TechContext,
    scaling: NodeScaling,
}

impl ProjectedBackend {
    /// Wrap `inner` behind a scaling projection.
    pub fn new(
        name: impl Into<String>,
        node_label: impl Into<String>,
        voltage_v: f64,
        inner: TechContext,
        scaling: NodeScaling,
    ) -> ProjectedBackend {
        ProjectedBackend {
            name: name.into(),
            node_label: node_label.into(),
            voltage_v,
            inner,
            scaling,
        }
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &TechContext {
        &self.inner
    }
}

impl TechBackend for ProjectedBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn node_label(&self) -> &str {
        &self.node_label
    }

    fn voltage_v(&self) -> f64 {
        self.voltage_v
    }

    fn library(&self) -> &Library {
        self.inner.library()
    }

    fn params(&self) -> &TechParams {
        self.inner.params()
    }

    fn scaling(&self) -> Option<NodeScaling> {
        Some(self.scaling)
    }

    /// Wire RC of the *reporting* node: the placement runs on the
    /// native library's cell geometry, but the wire stack (row height,
    /// RC per mm, energy/delay slopes) is the projected node's — the
    /// first-order cross-node model DESIGN.md §10 describes.
    fn wire_params(&self) -> super::WireParams {
        super::WireParams::n45()
    }

    /// Apply the scaling factors exactly as the pre-refactor 45nm
    /// target node did (same factors, same operation order), so
    /// projected reports stay bit-identical across the redesign.
    fn project(&self, ppa: ColumnPpa) -> ColumnPpa {
        let m = self.scaling;
        ColumnPpa {
            power_uw: ppa.power_uw * m.power_factor(),
            time_ns: ppa.time_ns * m.delay_factor(),
            area_mm2: ppa.area_mm2 * m.area_factor(),
        }
    }

    fn describe(&self) -> String {
        format!(
            "{} [{}] = {} × NodeScaling",
            self.name,
            self.node_label,
            self.inner.name()
        )
    }
}

/// `asap7-baseline`: the plain ASAP7 RVT subset (standard-cell flavour
/// only — custom-macro targets fail elaboration honestly).
pub fn asap7_baseline() -> StaticBackend {
    StaticBackend::new(
        ASAP7_BASELINE,
        "7nm",
        0.7,
        Library::asap7_only(),
        TechParams::calibrated(),
    )
}

/// `asap7-tnn7`: ASAP7 plus the paper's 11 custom GDI macros — the
/// default technology, characterization-identical to the substrate
/// every pre-redesign measurement used.
pub fn asap7_tnn7() -> StaticBackend {
    StaticBackend::new(
        ASAP7_TNN7,
        "7nm",
        0.7,
        Library::with_macros(),
        TechParams::calibrated(),
    )
}

/// `n45-projected`: measure in `inner` (normally `asap7-tnn7`), report
/// through the first-order 45nm↔7nm scaling model.
pub fn n45_projected(inner: TechContext) -> ProjectedBackend {
    ProjectedBackend::new(
        N45_PROJECTED,
        "45nm",
        1.0,
        inner,
        NodeScaling::n45_to_7(),
    )
}

/// Construct a `liberty-file` backend from `.lib` text in the dialect
/// [`crate::cells::liberty::emit`] writes (cell kinds and setup times
/// included).  Per-cell quantities carry the file's absolute units;
/// the scale constants are unit, so PPA equals the file verbatim.
pub fn from_liberty_text(
    name: impl Into<String>,
    text: &str,
) -> Result<StaticBackend> {
    let name = name.into();
    let parsed = liberty::parse_library(text)?;
    let mut lib = Library::new();
    for c in &parsed.cells {
        let kind = c.kind.ok_or_else(|| {
            Error::cells(format!(
                "cell `{}` has no cell_kind attribute — the liberty-file \
                 backend needs the tnn7 dialect written by `tnn7 \
                 characterize --lib`",
                c.name
            ))
        })?;
        if lib.id(&c.name).is_ok() {
            return Err(Error::cells(format!(
                "duplicate cell `{}` in liberty file `{name}`",
                c.name
            )));
        }
        let cell = Cell {
            name: c.name.clone(),
            kind,
            transistors: c.transistors,
            rel_area: c.area_um2,
            rel_energy: c.energy_fj,
            rel_leak: c.leak_nw,
            rel_delay: c.delay_ps,
            rel_setup: c.setup_ps,
            is_custom_macro: c.is_macro,
        };
        cell.validate()?;
        lib.add(cell);
    }
    Ok(StaticBackend::new(
        name,
        "as-characterized",
        parsed.voltage_v,
        lib,
        TechParams::unit(),
    ))
}

/// Load a `liberty-file` backend from disk; the backend's registry
/// name is the path as given.
pub fn load_liberty(path: &Path) -> Result<StaticBackend> {
    let text = std::fs::read_to_string(path)?;
    from_liberty_text(path.display().to_string(), &text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_backends_have_expected_shapes() {
        let base = asap7_baseline();
        let tnn7 = asap7_tnn7();
        assert!(base.library().len() < tnn7.library().len());
        assert!(base.library().id("mux2to1gdi").is_err());
        assert!(tnn7.library().id("mux2to1gdi").is_ok());
        assert_eq!(base.voltage_v(), 0.7);
    }

    #[test]
    fn n45_projection_applies_scaling_factors_exactly() {
        let inner = TechContext::new(asap7_tnn7());
        let n45 = n45_projected(inner);
        assert_eq!(n45.node_label(), "45nm");
        let m = NodeScaling::n45_to_7();
        let ppa = ColumnPpa { power_uw: 2.0, time_ns: 3.0, area_mm2: 5.0 };
        let p = n45.project(ppa);
        assert_eq!(p.power_uw, 2.0 * m.power_factor());
        assert_eq!(p.time_ns, 3.0 * m.delay_factor());
        assert_eq!(p.area_mm2, 5.0 * m.area_factor());
        // library/params delegate to the wrapped backend
        assert!(n45.library().id("mux2to1gdi").is_ok());
        assert_eq!(*n45.params(), TechParams::calibrated());
    }

    #[test]
    fn liberty_backend_round_trips_every_cell_quantity() {
        let lib = Library::with_macros();
        let params = TechParams::calibrated();
        let text = liberty::emit(&lib, &params, "roundtrip");
        let back = from_liberty_text("mem.lib", &text).unwrap();
        assert_eq!(back.library().len(), lib.len());
        assert_eq!(back.node_label(), "as-characterized");
        for (orig, got) in lib.cells().iter().zip(back.library().cells()) {
            assert_eq!(orig.name, got.name);
            assert_eq!(orig.kind, got.kind, "{}", orig.name);
            assert_eq!(orig.transistors, got.transistors);
            assert_eq!(orig.is_custom_macro, got.is_custom_macro);
            // Absolute quantities are exact: emit prints the shortest
            // round-trip float, params are unit on reload.
            let p = back.params();
            assert_eq!(p.area_um2(got), params.area_um2(orig), "{}", orig.name);
            assert_eq!(p.energy_fj(got), params.energy_fj(orig));
            assert_eq!(p.leak_nw(got), params.leak_nw(orig));
            assert_eq!(p.delay_ps(got), params.delay_ps(orig));
            assert_eq!(p.setup_ps(got), params.setup_ps(orig));
        }
    }

    #[test]
    fn liberty_backend_rejects_kindless_files() {
        // A minimal foreign .lib without the tnn7 cell_kind attribute.
        let text = "library (x) {\n  cell (A) {\n    area : 1;\n  }\n}\n";
        assert!(from_liberty_text("x", text).is_err());
    }
}
