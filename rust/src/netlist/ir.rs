//! Compact flat netlist IR.
//!
//! Sized for the largest Table-I column (1024x16 ≈ 0.6M instances): pin
//! lists live in one shared pool and an [`Instance`] is 20 bytes.  Hierarchy
//! is represented by *regions* (a tree of labels each instance is tagged
//! with), which is what the per-macro census (`tnn7 layout-cmp`,
//! `tnn7 complexity`) and the hierarchical PPA roll-up consume.

use crate::cells::{CellId, Library};
use crate::error::{Error, Result};

/// Index of a net in a [`Netlist`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NetId(pub u32);

/// Index of a region label.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RegionId(pub u32);

/// Clock domain of a sequential instance.
///
/// TNN designs use two clocks (§II.C): the unit clock `aclk` for temporal
/// encoding and the gamma clock `gclk` separating computational waves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClockDomain {
    /// Combinational (no clock).
    Comb,
    /// Unit clock: state commits every simulator tick.
    Aclk,
    /// Gamma clock: state commits on end-of-wave ticks only.
    Gclk,
}

/// One cell instance (compact: pins are a slice of the shared pool).
#[derive(Debug, Clone, Copy)]
pub struct Instance {
    /// Library cell.
    pub cell: CellId,
    /// Offset of this instance's pins in [`Netlist::pins`]
    /// (inputs first, then outputs).
    pub pin_start: u32,
    /// Input pin count.
    pub n_ins: u8,
    /// Output pin count.
    pub n_outs: u8,
    /// Clock domain (Comb for combinational cells).
    pub domain: ClockDomain,
    /// Region tag for census / roll-up.
    pub region: RegionId,
}

/// A region label node (tree via `parent`).
#[derive(Debug, Clone)]
pub struct Region {
    pub name: String,
    pub parent: Option<RegionId>,
}

/// Flat gate-level netlist.
#[derive(Debug, Clone)]
pub struct Netlist {
    /// Design name.
    pub name: String,
    /// Number of nets (ids are dense).
    n_nets: u32,
    /// Optional net names (debug / VCD); indexed sparsely.
    pub net_names: Vec<(NetId, String)>,
    /// Shared pin pool; see [`Instance::pin_start`].
    pub pins: Vec<NetId>,
    /// All instances.
    pub insts: Vec<Instance>,
    /// Primary inputs.
    pub inputs: Vec<NetId>,
    /// Primary outputs.
    pub outputs: Vec<NetId>,
    /// Region label tree.
    pub regions: Vec<Region>,
    /// Constant-0 / constant-1 nets (driven by tie cells).
    pub const0: NetId,
    pub const1: NetId,
}

impl Netlist {
    /// New netlist with tie-cell constants pre-created.
    pub fn new(name: impl Into<String>, lib: &Library) -> Self {
        let mut nl = Netlist {
            name: name.into(),
            n_nets: 0,
            net_names: Vec::new(),
            pins: Vec::new(),
            insts: Vec::new(),
            inputs: Vec::new(),
            outputs: Vec::new(),
            regions: vec![Region { name: "top".into(), parent: None }],
            const0: NetId(0),
            const1: NetId(0),
        };
        let c0 = nl.new_net();
        let c1 = nl.new_net();
        nl.const0 = c0;
        nl.const1 = c1;
        let tie0 = lib.id("TIELOx1").expect("tie cells in library");
        let tie1 = lib.id("TIEHIx1").expect("tie cells in library");
        nl.push_inst(tie0, &[], &[c0], ClockDomain::Comb, RegionId(0));
        nl.push_inst(tie1, &[], &[c1], ClockDomain::Comb, RegionId(0));
        nl
    }

    /// Allocate a fresh net.
    pub fn new_net(&mut self) -> NetId {
        let id = NetId(self.n_nets);
        self.n_nets += 1;
        id
    }

    /// Total net count.
    pub fn n_nets(&self) -> usize {
        self.n_nets as usize
    }

    /// Attach a debug name to a net.
    pub fn name_net(&mut self, net: NetId, name: impl Into<String>) {
        self.net_names.push((net, name.into()));
    }

    /// Add a region label under `parent`.
    pub fn add_region(
        &mut self,
        name: impl Into<String>,
        parent: RegionId,
    ) -> RegionId {
        let id = RegionId(self.regions.len() as u32);
        self.regions.push(Region { name: name.into(), parent: Some(parent) });
        id
    }

    /// Full path of a region ("top/col/syn_0_3/...").
    pub fn region_path(&self, mut r: RegionId) -> String {
        let mut parts = Vec::new();
        loop {
            let reg = &self.regions[r.0 as usize];
            parts.push(reg.name.clone());
            match reg.parent {
                Some(p) => r = p,
                None => break,
            }
        }
        parts.reverse();
        parts.join("/")
    }

    /// Append an instance.
    pub fn push_inst(
        &mut self,
        cell: CellId,
        ins: &[NetId],
        outs: &[NetId],
        domain: ClockDomain,
        region: RegionId,
    ) -> usize {
        let pin_start = self.pins.len() as u32;
        self.pins.extend_from_slice(ins);
        self.pins.extend_from_slice(outs);
        self.insts.push(Instance {
            cell,
            pin_start,
            n_ins: ins.len() as u8,
            n_outs: outs.len() as u8,
            domain,
            region,
        });
        self.insts.len() - 1
    }

    /// Input pins of instance `i`.
    pub fn inst_ins(&self, i: usize) -> &[NetId] {
        let inst = &self.insts[i];
        let s = inst.pin_start as usize;
        &self.pins[s..s + inst.n_ins as usize]
    }

    /// Output pins of instance `i`.
    pub fn inst_outs(&self, i: usize) -> &[NetId] {
        let inst = &self.insts[i];
        let s = inst.pin_start as usize + inst.n_ins as usize;
        &self.pins[s..s + inst.n_outs as usize]
    }

    /// Validate structural invariants: every net has exactly one driver
    /// (tie/instance output or primary input), pin widths match the
    /// library, and no net is read before existing.
    pub fn validate(&self, lib: &Library) -> Result<()> {
        let mut drivers = vec![0u8; self.n_nets()];
        for &n in &self.inputs {
            drivers[n.0 as usize] = drivers[n.0 as usize].saturating_add(1);
        }
        for i in 0..self.insts.len() {
            let inst = &self.insts[i];
            let cell = lib.cell(inst.cell);
            let (ci, co, _) = cell.kind.pins();
            if ci != inst.n_ins as usize || co != inst.n_outs as usize {
                return Err(Error::netlist(format!(
                    "inst {i} ({}) pin mismatch: has {}/{}, cell wants {ci}/{co}",
                    cell.name, inst.n_ins, inst.n_outs
                )));
            }
            let seq = cell.kind.is_sequential();
            if seq && inst.domain == ClockDomain::Comb {
                return Err(Error::netlist(format!(
                    "sequential inst {i} ({}) in Comb domain",
                    cell.name
                )));
            }
            if !seq && inst.domain != ClockDomain::Comb {
                return Err(Error::netlist(format!(
                    "combinational inst {i} ({}) assigned a clock",
                    cell.name
                )));
            }
            for &o in self.inst_outs(i) {
                drivers[o.0 as usize] = drivers[o.0 as usize].saturating_add(1);
            }
        }
        for (n, &d) in drivers.iter().enumerate() {
            if d == 0 {
                // Undriven nets are only legal if also unread.
                let read = self.insts.iter().enumerate().any(|(i, _)| {
                    self.inst_ins(i).contains(&NetId(n as u32))
                }) || self.outputs.contains(&NetId(n as u32));
                if read {
                    return Err(Error::netlist(format!(
                        "net {n} is read but has no driver"
                    )));
                }
            } else if d > 1 {
                return Err(Error::netlist(format!(
                    "net {n} has {d} drivers"
                )));
            }
        }
        Ok(())
    }

    /// Census: per-cell instance counts, total transistors, total cells.
    pub fn census(&self, lib: &Library) -> Census {
        let mut per_cell = vec![0u64; lib.len()];
        for inst in &self.insts {
            per_cell[inst.cell] += 1;
        }
        let transistors = per_cell
            .iter()
            .enumerate()
            .map(|(c, &n)| n * u64::from(lib.cell(c).transistors))
            .sum();
        Census {
            cells: self.insts.len() as u64,
            transistors,
            nets: self.n_nets() as u64,
            per_cell,
        }
    }
}

/// Elaboration census (for `tnn7 complexity`, Fig. 19's "32M gates /
/// 128M transistors" claim).
#[derive(Debug, Clone)]
pub struct Census {
    pub cells: u64,
    pub transistors: u64,
    pub nets: u64,
    /// Instance count per library cell id.
    pub per_cell: Vec<u64>,
}

impl Census {
    /// Scale all counts by `k` (hierarchical roll-up of identical blocks).
    pub fn scaled(&self, k: u64) -> Census {
        Census {
            cells: self.cells * k,
            transistors: self.transistors * k,
            nets: self.nets * k,
            per_cell: self.per_cell.iter().map(|&n| n * k).collect(),
        }
    }

    /// Merge another census into this one.
    pub fn add(&mut self, other: &Census) {
        self.cells += other.cells;
        self.transistors += other.transistors;
        self.nets += other.nets;
        if self.per_cell.len() < other.per_cell.len() {
            self.per_cell.resize(other.per_cell.len(), 0);
        }
        for (i, &n) in other.per_cell.iter().enumerate() {
            self.per_cell[i] += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;

    #[test]
    fn new_netlist_has_tie_constants() {
        let lib = Library::asap7_only();
        let nl = Netlist::new("t", &lib);
        assert_eq!(nl.insts.len(), 2);
        assert!(nl.validate(&lib).is_ok());
    }

    #[test]
    fn validate_catches_double_driver() {
        let lib = Library::asap7_only();
        let mut nl = Netlist::new("t", &lib);
        let a = nl.new_net();
        let inv = lib.id("INVx1").unwrap();
        nl.inputs.push(a);
        let y = nl.new_net();
        nl.push_inst(inv, &[a], &[y], ClockDomain::Comb, RegionId(0));
        nl.push_inst(inv, &[a], &[y], ClockDomain::Comb, RegionId(0));
        assert!(nl.validate(&lib).is_err());
    }

    #[test]
    fn validate_catches_undriven_read() {
        let lib = Library::asap7_only();
        let mut nl = Netlist::new("t", &lib);
        let ghost = nl.new_net();
        let y = nl.new_net();
        let inv = lib.id("INVx1").unwrap();
        nl.push_inst(inv, &[ghost], &[y], ClockDomain::Comb, RegionId(0));
        assert!(nl.validate(&lib).is_err());
    }

    #[test]
    fn validate_catches_domain_misuse() {
        let lib = Library::asap7_only();
        let mut nl = Netlist::new("t", &lib);
        let a = nl.new_net();
        nl.inputs.push(a);
        let y = nl.new_net();
        let inv = lib.id("INVx1").unwrap();
        nl.push_inst(inv, &[a], &[y], ClockDomain::Aclk, RegionId(0));
        assert!(nl.validate(&lib).is_err());
    }

    #[test]
    fn census_counts_transistors() {
        let lib = Library::asap7_only();
        let mut nl = Netlist::new("t", &lib);
        let a = nl.new_net();
        nl.inputs.push(a);
        let y = nl.new_net();
        let inv = lib.id("INVx1").unwrap();
        nl.push_inst(inv, &[a], &[y], ClockDomain::Comb, RegionId(0));
        let c = nl.census(&lib);
        assert_eq!(c.cells, 3); // 2 ties + inv
        assert_eq!(c.transistors, 2 + 2 + 2);
        let s = c.scaled(10);
        assert_eq!(s.transistors, 60);
    }

    #[test]
    fn region_paths_compose() {
        let lib = Library::asap7_only();
        let mut nl = Netlist::new("t", &lib);
        let a = nl.add_region("col", RegionId(0));
        let b = nl.add_region("syn", a);
        assert_eq!(nl.region_path(b), "top/col/syn");
    }
}
