//! Gate-level netlist IR and elaboration — the Genus-analogue.
//!
//! The paper's central experiment is a *netlist substitution*: the same TNN
//! RTL implemented once with plain ASAP7 standard cells and once with the
//! custom GDI macro extensions.  This module provides exactly that:
//!
//! * [`ir`] — a compact flat netlist IR (nets, cell instances, regions).
//! * [`builder`] — elaboration helpers (gates, buses, registers, adders).
//! * [`modules`] — one builder per paper macro (Figs. 2–13), each in BOTH
//!   flavours: [`Flavor::Std`] elaborates ASAP7 gates, [`Flavor::Custom`]
//!   instantiates the hard macro cell.
//! * [`column`] — the p×q TNN column (synapses + neurons + WTA + STDP).
//! * [`layer`] / [`prototype`] — hierarchical roll-up for the Fig. 19
//!   2-layer prototype (synaptic scaling, as in the paper's §III.C),
//!   plus the flat multi-column layer netlist
//!   ([`layer::build_layer_netlist`]) the sharded simulator runs.
//! * [`partition`] — the column-aligned head/shards/tail partitioner
//!   behind [`crate::sim::ShardedSimulator`] (DESIGN.md §8).

pub mod builder;
pub mod column;
pub mod ir;
pub mod layer;
pub mod modules;
pub mod partition;
pub mod prototype;

pub use builder::Builder;
pub use ir::{ClockDomain, Instance, NetId, Netlist, RegionId};
pub use partition::{partition, Partition};

/// Implementation flavour of a module: the paper's two columns of Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Flavor {
    /// Plain ASAP7 standard cells (what Genus elaborates from RTL).
    Std,
    /// The custom GDI macro extensions (the paper's contribution).
    Custom,
}

impl Flavor {
    /// Label used in reports ("Standard Cell-Based" / "Custom Macro-Based").
    pub fn label(self) -> &'static str {
        match self {
            Flavor::Std => "Standard Cell-Based",
            Flavor::Custom => "Custom Macro-Based",
        }
    }
}
