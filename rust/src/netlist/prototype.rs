//! The Fig. 19 2-layer TNN prototype.
//!
//! "625 32x12 columns in first layer, and 625 12x10 columns in second
//! layer" — 13,750 neurons, 315,000 synapses, quoted at 32M gates /
//! 128M transistors.  PPA is assessed by synaptic scaling of the two
//! representative columns (exactly the paper's §III.C methodology).

use crate::cells::Library;
use crate::error::Result;
use crate::netlist::ir::Census;
use crate::netlist::Flavor;

use super::column::ColumnSpec;
use super::layer::{LayerModel, LayerSpec};

/// Prototype geometry.
#[derive(Debug, Clone, Copy)]
pub struct PrototypeSpec {
    pub l1: LayerSpec,
    pub l2: LayerSpec,
}

impl PrototypeSpec {
    /// The paper's Fig. 19 prototype.
    pub fn paper() -> Self {
        PrototypeSpec {
            l1: LayerSpec {
                cols: 625,
                column: ColumnSpec { p: 32, q: 12, theta: 56 },
            },
            l2: LayerSpec {
                cols: 625,
                column: ColumnSpec { p: 12, q: 10, theta: 21 },
            },
        }
    }

    /// Total neurons (paper: 13,750).
    pub fn neurons(&self) -> usize {
        self.l1.neurons() + self.l2.neurons()
    }

    /// Total synapses (paper: 315,000).
    pub fn synapses(&self) -> usize {
        self.l1.synapses() + self.l2.synapses()
    }
}

/// Elaborated prototype model: two representative columns + scales.
pub struct PrototypeModel {
    pub spec: PrototypeSpec,
    pub l1: LayerModel,
    pub l2: LayerModel,
}

impl PrototypeModel {
    /// Build both representative columns.
    pub fn build(lib: &Library, flavor: Flavor, spec: PrototypeSpec) -> Result<Self> {
        Ok(PrototypeModel {
            spec,
            l1: LayerModel::build(lib, flavor, spec.l1)?,
            l2: LayerModel::build(lib, flavor, spec.l2)?,
        })
    }

    /// Whole-prototype census (Fig. 19's complexity claim).
    pub fn census(&self, lib: &Library) -> Census {
        let mut c = self.l1.census(lib);
        c.add(&self.l2.census(lib));
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry_matches_abstract() {
        let s = PrototypeSpec::paper();
        assert_eq!(s.neurons(), 13_750);
        assert_eq!(s.synapses(), 315_000);
    }

    #[test]
    fn prototype_census_is_sum_of_layers() {
        let lib = Library::with_macros();
        // Scaled-down spec for test speed; same structure.
        let spec = PrototypeSpec {
            l1: LayerSpec {
                cols: 3,
                column: ColumnSpec { p: 8, q: 3, theta: 10 },
            },
            l2: LayerSpec {
                cols: 3,
                column: ColumnSpec { p: 3, q: 2, theta: 4 },
            },
        };
        let m = PrototypeModel::build(&lib, Flavor::Custom, spec).unwrap();
        let c = m.census(&lib);
        let c1 = m.l1.census(&lib);
        let c2 = m.l2.census(&lib);
        assert_eq!(c.transistors, c1.transistors + c2.transistors);
        assert!(c.cells > 0);
    }
}
