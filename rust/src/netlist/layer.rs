//! Multi-column layer roll-up.
//!
//! The paper assesses the multi-column/multi-layer prototype "using
//! synaptic scaling" (§III.C): identical columns are characterized once
//! and rolled up by count.  This module provides that hierarchy level —
//! a layer is `cols` identical [`ColumnSpec`] columns plus its share of
//! the gamma-clock distribution.

use crate::cells::Library;
use crate::error::Result;
use crate::netlist::ir::Census;
use crate::netlist::{Flavor, Netlist};

use super::column::{build_column, ColumnPorts, ColumnSpec};

/// A layer: `cols` identical columns.
#[derive(Debug, Clone, Copy)]
pub struct LayerSpec {
    /// Number of identical columns.
    pub cols: usize,
    /// Per-column geometry.
    pub column: ColumnSpec,
}

impl LayerSpec {
    /// Neurons in the layer.
    pub fn neurons(&self) -> usize {
        self.cols * self.column.q
    }

    /// Synapses in the layer.
    pub fn synapses(&self) -> usize {
        self.cols * self.column.p * self.column.q
    }
}

/// One elaborated representative column + the scale factor.
pub struct LayerModel {
    pub spec: LayerSpec,
    pub netlist: Netlist,
    pub ports: ColumnPorts,
    pub flavor: Flavor,
}

impl LayerModel {
    /// Elaborate the representative column for this layer.
    pub fn build(lib: &Library, flavor: Flavor, spec: LayerSpec) -> Result<Self> {
        let (netlist, ports) = build_column(lib, flavor, &spec.column)?;
        Ok(LayerModel { spec, netlist, ports, flavor })
    }

    /// Layer census = column census × cols (synaptic scaling).
    pub fn census(&self, lib: &Library) -> Census {
        self.netlist.census(lib).scaled(self.spec.cols as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_scale_linearly() {
        let lib = Library::with_macros();
        let spec = LayerSpec {
            cols: 5,
            column: ColumnSpec { p: 4, q: 2, theta: 6 },
        };
        let m = LayerModel::build(&lib, Flavor::Std, spec).unwrap();
        let col = m.netlist.census(&lib);
        let lay = m.census(&lib);
        assert_eq!(lay.transistors, col.transistors * 5);
        assert_eq!(spec.neurons(), 10);
        assert_eq!(spec.synapses(), 40);
    }
}
