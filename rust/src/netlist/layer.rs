//! Multi-column layer roll-up.
//!
//! The paper assesses the multi-column/multi-layer prototype "using
//! synaptic scaling" (§III.C): identical columns are characterized once
//! and rolled up by count.  This module provides that hierarchy level —
//! a layer is `cols` identical [`ColumnSpec`] columns plus its share of
//! the gamma-clock distribution.
//!
//! Two granularities coexist:
//!
//! * [`LayerModel`] — the synaptic-scaling roll-up (one representative
//!   column × `cols`), which is what Table II measurement uses.
//! * [`build_layer_netlist`] — a *flat multi-column netlist*: `cols`
//!   real columns elaborated side by side, each under its own `colK`
//!   region, joined by a voter/output block that ORs the post-WTA lock
//!   levels across columns.  This is the workload the column-aligned
//!   partitioner ([`super::partition`]) cuts into thread-parallel
//!   shards: every column is an independent shard and the voter is the
//!   boundary-exchanged tail (DESIGN.md §8).

use crate::cells::Library;
use crate::error::Result;
use crate::netlist::ir::Census;
use crate::netlist::{Flavor, NetId, Netlist};

use super::column::{build_column, column, ColumnPorts, ColumnSpec};

/// A layer: `cols` identical columns.
#[derive(Debug, Clone, Copy)]
pub struct LayerSpec {
    /// Number of identical columns.
    pub cols: usize,
    /// Per-column geometry.
    pub column: ColumnSpec,
}

impl LayerSpec {
    /// Neurons in the layer.
    pub fn neurons(&self) -> usize {
        self.cols * self.column.q
    }

    /// Synapses in the layer.
    pub fn synapses(&self) -> usize {
        self.cols * self.column.p * self.column.q
    }
}

/// One elaborated representative column + the scale factor.
pub struct LayerModel {
    pub spec: LayerSpec,
    pub netlist: Netlist,
    pub ports: ColumnPorts,
    pub flavor: Flavor,
}

impl LayerModel {
    /// Elaborate the representative column for this layer.
    pub fn build(lib: &Library, flavor: Flavor, spec: LayerSpec) -> Result<Self> {
        let (netlist, ports) = build_column(lib, flavor, &spec.column)?;
        Ok(LayerModel { spec, netlist, ports, flavor })
    }

    /// Layer census = column census × cols (synaptic scaling).
    pub fn census(&self, lib: &Library) -> Census {
        self.netlist.census(lib).scaled(self.spec.cols as u64)
    }
}

/// Ports of a flat multi-column layer netlist.
pub struct LayerNetlistPorts {
    /// Per-column ports, in column order (each with its own `x`,
    /// `gclk`, and `brv` primary inputs).
    pub columns: Vec<ColumnPorts>,
    /// Voter outputs: per neuron index, the OR across columns of that
    /// neuron's post-WTA lock level.
    pub votes: Vec<NetId>,
    /// OR over all vote nets (the "some neuron spiked" flag).
    pub any_fire: NetId,
}

/// Elaborate `spec.cols` real columns plus a voter/output block into
/// one flat netlist.
///
/// Each column lives under its own top-level `colK` region and touches
/// only its own primary inputs, so the netlist is embarrassingly
/// parallel up to the voter — the shape
/// [`super::partition::partition`] cuts along, one shard per column
/// with the voter in the boundary-exchanged tail.
pub fn build_layer_netlist(
    lib: &Library,
    flavor: Flavor,
    spec: &LayerSpec,
) -> Result<(Netlist, LayerNetlistPorts)> {
    assert!(spec.cols >= 1, "a layer needs at least one column");
    let name = format!(
        "layer_{}x{}x{}_{flavor:?}",
        spec.cols, spec.column.p, spec.column.q
    );
    let mut b = super::Builder::new(&name, lib);
    let mut columns = Vec::with_capacity(spec.cols);
    for k in 0..spec.cols {
        let reg = b.push(format!("col{k}"));
        columns.push(column(&mut b, flavor, &spec.column));
        b.pop(reg);
    }
    let reg = b.push("voter");
    let mut votes = Vec::with_capacity(spec.column.q);
    for i in 0..spec.column.q {
        let locks: Vec<NetId> =
            columns.iter().map(|c| c.locks[i]).collect();
        let v = b.or_tree(&locks);
        b.output(v, format!("vote[{i}]"));
        votes.push(v);
    }
    let any_fire = b.or_tree(&votes);
    b.output(any_fire, "any_fire");
    b.pop(reg);
    let nl = b.finish()?;
    Ok((nl, LayerNetlistPorts { columns, votes, any_fire }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_scale_linearly() {
        let lib = Library::with_macros();
        let spec = LayerSpec {
            cols: 5,
            column: ColumnSpec { p: 4, q: 2, theta: 6 },
        };
        let m = LayerModel::build(&lib, Flavor::Std, spec).unwrap();
        let col = m.netlist.census(&lib);
        let lay = m.census(&lib);
        assert_eq!(lay.transistors, col.transistors * 5);
        assert_eq!(spec.neurons(), 10);
        assert_eq!(spec.synapses(), 40);
    }

    #[test]
    fn flat_layer_netlist_validates_and_scales() {
        let lib = Library::with_macros();
        let col = ColumnSpec { p: 4, q: 2, theta: 6 };
        let spec = LayerSpec { cols: 3, column: col };
        let (nl, ports) =
            build_layer_netlist(&lib, Flavor::Custom, &spec).unwrap();
        assert_eq!(ports.columns.len(), 3);
        assert_eq!(ports.votes.len(), 2);
        // Roughly 3 columns' worth of instances plus the voter.
        let (single, _) =
            build_column(&lib, Flavor::Custom, &col).unwrap();
        assert!(nl.insts.len() > 3 * (single.insts.len() - 2));
        // Each column keeps its own input set.
        assert_eq!(
            nl.inputs.len(),
            3 * single.inputs.len(),
            "per-column x/gclk/brv inputs"
        );
        // Region tags are column-aligned for the partitioner.
        let path = nl.region_path(nl.insts[5].region);
        assert!(path.starts_with("top/col0"), "{path}");
    }
}
