//! One builder per paper macro (Figs. 2–13), each in both flavours.
//!
//! Every function takes the elaboration [`Builder`](crate::netlist::Builder)
//! plus a [`Flavor`](crate::netlist::Flavor):
//!
//! * `Flavor::Std` elaborates the function from plain ASAP7 cells — what
//!   Genus produces from the RTL (the paper's "standard cell-based" rows).
//! * `Flavor::Custom` instantiates the corresponding hard macro cell from
//!   [`crate::cells::macros`] (the paper's "custom macro-based" rows).
//!
//! The unit tests in each file sweep both flavours through the simulator
//! and assert **bit-exact equivalence** — the property that makes the
//! Table I / II comparison an apples-to-apples netlist substitution.

pub mod edge2pulse;
pub mod incdec;
pub mod less_equal;
pub mod mux;
pub mod pac_adder;
pub mod pulse2edge;
pub mod spike_gen;
pub mod stabilize_func;
pub mod stdp_case_gen;
pub mod syn_output;
pub mod syn_weight_update;
pub mod wta;

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared equivalence-test harness: build a module in both flavours,
    //! drive identical stimulus, compare all outputs every cycle.

    use crate::cells::Library;
    use crate::error::Result;
    use crate::netlist::{Builder, Flavor, NetId, Netlist};
    use crate::sim::Simulator;

    /// Build `f` into a standalone netlist with the given flavour.
    pub fn build<F>(lib: &Library, flavor: Flavor, f: F) -> Netlist
    where
        F: FnOnce(&mut Builder<'_>, Flavor) -> (Vec<NetId>, Vec<NetId>),
    {
        let mut b = Builder::new("mod", lib);
        let (ins, outs) = f(&mut b, flavor);
        for (i, &n) in ins.iter().enumerate() {
            // inputs were created inside f via b.input(); just check order
            assert_eq!(b.nl.inputs[i], n);
        }
        for (i, &o) in outs.iter().enumerate() {
            b.output(o, format!("o{i}"));
        }
        b.finish().expect("module validates")
    }

    /// Drive both flavours with the same stimulus; assert identical
    /// outputs on every cycle.  `stimulus[cycle]` = (input bits, gclk).
    pub fn assert_equiv<F>(f: F, stimulus: &[(Vec<bool>, bool)]) -> Result<()>
    where
        F: Fn(&mut Builder<'_>, Flavor) -> (Vec<NetId>, Vec<NetId>) + Copy,
    {
        let lib = Library::with_macros();
        let nl_std = build(&lib, Flavor::Std, f);
        let nl_cus = build(&lib, Flavor::Custom, f);
        assert_eq!(nl_std.inputs.len(), nl_cus.inputs.len());
        assert_eq!(nl_std.outputs.len(), nl_cus.outputs.len());
        let mut s1 = Simulator::new(&nl_std, &lib)?;
        let mut s2 = Simulator::new(&nl_cus, &lib)?;
        for (cyc, (bits, gclk)) in stimulus.iter().enumerate() {
            let iv1: Vec<_> = nl_std
                .inputs
                .iter()
                .zip(bits)
                .map(|(&n, &v)| (n, v))
                .collect();
            let iv2: Vec<_> = nl_cus
                .inputs
                .iter()
                .zip(bits)
                .map(|(&n, &v)| (n, v))
                .collect();
            s1.tick(&iv1, *gclk);
            s2.tick(&iv2, *gclk);
            for (k, (&o1, &o2)) in
                nl_std.outputs.iter().zip(&nl_cus.outputs).enumerate()
            {
                assert_eq!(
                    s1.get(o1),
                    s2.get(o2),
                    "cycle {cyc} output {k}: std != custom"
                );
            }
        }
        Ok(())
    }

    /// Simple deterministic stimulus generator (xorshift).
    pub fn random_stimulus(
        n_inputs: usize,
        cycles: usize,
        seed: u64,
        gclk_period: usize,
    ) -> Vec<(Vec<bool>, bool)> {
        let mut s = seed.max(1);
        let mut next = || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        };
        (0..cycles)
            .map(|c| {
                let bits = (0..n_inputs).map(|_| next() & 1 == 1).collect();
                let gclk = gclk_period > 0 && (c + 1) % gclk_period == 0;
                (bits, gclk)
            })
            .collect()
    }
}
