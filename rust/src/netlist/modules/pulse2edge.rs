//! `pulse2edge` (Figs. 6–7): convert a spike pulse into a latched level
//! "asserted until a gamma reset".
//!
//! Two variants as in the paper:
//! * **power-optimized** (Fig. 6) — async active-high reset register; the
//!   reset is visible at the output combinationally.
//! * **area-optimized** (Fig. 7) — sync active-low reset register;
//!   smallest layout, reset takes effect at the next clock.

use crate::cells::{CellKind, MacroKind};
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// Which of the two paper variants to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum P2eVariant {
    PowerOpt,
    AreaOpt,
}

/// Build pulse2edge; returns the latched level.
pub fn pulse2edge(
    b: &mut Builder<'_>,
    flavor: Flavor,
    variant: P2eVariant,
    d: NetId,
    rst: NetId,
) -> NetId {
    match (flavor, variant) {
        (Flavor::Std, P2eVariant::PowerOpt) => {
            // q = DFFR(d = q | d, rst): async reset.
            let q = b.net();
            let dn = b.or2(q, d);
            b.inst_with_outs(CellKind::DffR, &[dn, rst], &[q], ClockDomain::Aclk);
            // async reset gates the output inside DffR's eval (Q = !rst & state)
            q
        }
        (Flavor::Std, P2eVariant::AreaOpt) => {
            // q = DFFRN(d = q | d, rstn = !rst): sync reset.
            let q = b.net();
            let dn = b.or2(q, d);
            let rstn = b.inv(rst);
            b.inst_with_outs(CellKind::DffRn, &[dn, rstn], &[q], ClockDomain::Aclk);
            q
        }
        (Flavor::Custom, P2eVariant::PowerOpt) => {
            b.macro_cell(MacroKind::Pulse2EdgePwr, &[d, rst], ClockDomain::Aclk)[0]
        }
        (Flavor::Custom, P2eVariant::AreaOpt) => {
            b.macro_cell(MacroKind::Pulse2EdgeArea, &[d, rst], ClockDomain::Aclk)[0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;
    use crate::cells::Library;
    use crate::sim::Simulator;

    fn module_pwr(b: &mut Builder<'_>, f: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let d = b.input("d");
        let r = b.input("rst");
        let q = pulse2edge(b, f, P2eVariant::PowerOpt, d, r);
        (vec![d, r], vec![q])
    }

    fn module_area(b: &mut Builder<'_>, f: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let d = b.input("d");
        let r = b.input("rst");
        let q = pulse2edge(b, f, P2eVariant::AreaOpt, d, r);
        (vec![d, r], vec![q])
    }

    #[test]
    fn power_variant_flavours_equivalent() {
        let stim = testutil::random_stimulus(2, 500, 0x1234, 0);
        testutil::assert_equiv(module_pwr, &stim).unwrap();
    }

    #[test]
    fn area_variant_flavours_equivalent() {
        let stim = testutil::random_stimulus(2, 500, 0x4321, 0);
        testutil::assert_equiv(module_area, &stim).unwrap();
    }

    #[test]
    fn latches_pulse_until_reset() {
        let lib = Library::with_macros();
        for (f, build) in [
            (Flavor::Std, module_pwr as fn(&mut Builder<'_>, Flavor) -> _),
            (Flavor::Custom, module_pwr),
        ] {
            let nl = testutil::build(&lib, f, build);
            let mut sim = Simulator::new(&nl, &lib).unwrap();
            let (d, r) = (nl.inputs[0], nl.inputs[1]);
            let q = nl.outputs[0];
            sim.tick(&[(d, true), (r, false)], false); // pulse
            sim.tick(&[(d, false), (r, false)], false);
            assert!(sim.get(q), "{f:?} latched");
            sim.tick(&[(d, false), (r, false)], false);
            assert!(sim.get(q), "{f:?} holds");
            sim.tick(&[(d, false), (r, true)], false); // async reset
            assert!(!sim.get(q), "{f:?} reset visible immediately");
        }
    }

    #[test]
    fn async_vs_sync_reset_timing_differs() {
        // The two variants are NOT identical: async reset shows at the
        // output in the same cycle, sync at the next.  This is the PPA
        // tradeoff the paper ships two variants for.
        let lib = Library::with_macros();
        let np = testutil::build(&lib, Flavor::Custom, module_pwr);
        let na = testutil::build(&lib, Flavor::Custom, module_area);
        let mut sp = Simulator::new(&np, &lib).unwrap();
        let mut sa = Simulator::new(&na, &lib).unwrap();
        for s in [&mut sp, &mut sa] {
            // latch a pulse first
            s.tick(&[], false);
        }
        sp.tick(&[(np.inputs[0], true), (np.inputs[1], false)], false);
        sa.tick(&[(na.inputs[0], true), (na.inputs[1], false)], false);
        // assert reset: power sees 0 now, area still 1 until next commit
        sp.tick(&[(np.inputs[0], false), (np.inputs[1], true)], false);
        sa.tick(&[(na.inputs[0], false), (na.inputs[1], true)], false);
        assert!(!sp.get(np.outputs[0]));
        assert!(sa.get(na.outputs[0]));
    }
}
