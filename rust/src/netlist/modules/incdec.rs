//! `incdec` (Fig. 10): fold the BRV-gated STDP cases into the weight
//! update strobes: `inc = capture_g | search_g`, `dec = backoff_g | minus_g`.

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// Build incdec; returns `(inc, dec)`.
pub fn incdec(
    b: &mut Builder<'_>,
    flavor: Flavor,
    capture_g: NetId,
    backoff_g: NetId,
    search_g: NetId,
    minus_g: NetId,
) -> (NetId, NetId) {
    match flavor {
        Flavor::Std => {
            (b.or2(capture_g, search_g), b.or2(backoff_g, minus_g))
        }
        Flavor::Custom => {
            let o = b.macro_cell(
                MacroKind::IncDec,
                &[capture_g, backoff_g, search_g, minus_g],
                ClockDomain::Comb,
            );
            (o[0], o[1])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    fn module(b: &mut Builder<'_>, flavor: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let c = b.input("cap");
        let bk = b.input("back");
        let s = b.input("srch");
        let m = b.input("minus");
        let (inc, dec) = incdec(b, flavor, c, bk, s, m);
        (vec![c, bk, s, m], vec![inc, dec])
    }

    #[test]
    fn flavours_equivalent_exhaustive() {
        let stim: Vec<(Vec<bool>, bool)> = (0..16u8)
            .map(|v| {
                (
                    (0..4).map(|i| v >> i & 1 == 1).collect::<Vec<_>>(),
                    false,
                )
            })
            .collect();
        testutil::assert_equiv(module, &stim).unwrap();
    }
}
