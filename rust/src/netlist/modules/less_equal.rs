//! `less_equal` (Fig. 5 / Figs. 14–15): the WTA time comparator.
//!
//! On monotone spike *levels* (a net that rises at the spike time and
//! stays high for the rest of the wave), "a spiked no later than b" is the
//! pointwise implication `le = a | !b`: sampled at b's rising edge it
//! yields exactly `t_a <= t_b`.  The paper's custom macro realizes this
//! with a 4-transistor pass-gate network; the standard-cell twin is the
//! INVx1 + OR2x2 pair Genus maps the expression to (Fig. 14 vs Fig. 15).

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// Build `le = a | !b` in the requested flavour.
pub fn less_equal(b: &mut Builder<'_>, flavor: Flavor, a: NetId, bb: NetId) -> NetId {
    match flavor {
        Flavor::Std => {
            let nb = b.inv(bb);
            b.or2(a, nb)
        }
        Flavor::Custom => {
            b.macro_cell(MacroKind::LessEqual, &[a, bb], ClockDomain::Comb)[0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    fn module(
        b: &mut Builder<'_>,
        flavor: Flavor,
    ) -> (Vec<NetId>, Vec<NetId>) {
        let a = b.input("a");
        let bb = b.input("b");
        let le = less_equal(b, flavor, a, bb);
        (vec![a, bb], vec![le])
    }

    #[test]
    fn flavours_equivalent_exhaustive() {
        let stim: Vec<(Vec<bool>, bool)> = (0..4u8)
            .map(|v| (vec![v & 1 != 0, v & 2 != 0], false))
            .collect();
        testutil::assert_equiv(module, &stim).unwrap();
    }

    #[test]
    fn truth_table_is_implication() {
        use crate::cells::Library;
        use crate::sim::Simulator;
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, Flavor::Custom, module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for (a, b, want) in [
            (false, false, true),
            (true, false, true),
            (true, true, true),
            (false, true, false),
        ] {
            sim.tick(&[(nl.inputs[0], a), (nl.inputs[1], b)], false);
            assert_eq!(sim.get(nl.outputs[0]), want, "a={a} b={b}");
        }
    }

    #[test]
    fn custom_is_structurally_smaller() {
        use crate::cells::Library;
        let lib = Library::with_macros();
        let std = testutil::build(&lib, Flavor::Std, module);
        let cus = testutil::build(&lib, Flavor::Custom, module);
        assert!(
            cus.census(&lib).transistors < std.census(&lib).transistors,
            "Fig. 14/15: custom less_equal must be smaller"
        );
    }
}
