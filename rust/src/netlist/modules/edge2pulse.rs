//! `edge2pulse` (Fig. 13): one-cycle pulse on a rising edge.
//!
//! Generates the gamma reset strobes (`grst`) from `gclk` "for performing
//! essential computational reset between consecutive computational
//! cycles", and the sample strobes for the STDP `less_equal` register.

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// Build edge2pulse; returns the pulse net.
pub fn edge2pulse(b: &mut Builder<'_>, flavor: Flavor, d: NetId) -> NetId {
    match flavor {
        Flavor::Std => {
            let prev = b.dff(d, ClockDomain::Aclk);
            let nprev = b.inv(prev);
            b.and2(d, nprev)
        }
        Flavor::Custom => {
            b.macro_cell(MacroKind::Edge2Pulse, &[d], ClockDomain::Aclk)[0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    fn module(b: &mut Builder<'_>, f: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let d = b.input("d");
        let p = edge2pulse(b, f, d);
        (vec![d], vec![p])
    }

    #[test]
    fn flavours_equivalent_random() {
        let stim = testutil::random_stimulus(1, 400, 0x9e37, 0);
        testutil::assert_equiv(module, &stim).unwrap();
    }

    #[test]
    fn emits_one_pulse_per_edge() {
        use crate::cells::Library;
        use crate::sim::Simulator;
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, Flavor::Std, module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        let pattern = [false, true, true, true, false, false, true, true];
        let mut pulses = 0;
        for v in pattern {
            sim.tick(&[(nl.inputs[0], v)], false);
            pulses += sim.get(nl.outputs[0]) as u32;
        }
        assert_eq!(pulses, 2);
    }
}
