//! `spike_gen` (Fig. 12): input spike edge → 8-cycle pulse + cycle count.
//!
//! The input is a monotone level that rises at the encoded spike time and
//! stays high for the rest of the wave.  The module emits
//! * `pulse` — high for exactly 8 unit cycles starting at the rise
//!   ("8-cycle wide pulses for spikes required by syn_output"), and
//! * `count[3]` — cycles elapsed since the rise (the RNL phase the
//!   synapses compare their weight against).
//!
//! Implementation: a 4-bit saturating cycle counter enabled by
//! `d & !count[3]`, cleared by `grst` between waves.

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// spike_gen ports.
pub struct SpikeGenPorts {
    /// 8-cycle wide spike pulse.
    pub pulse: NetId,
    /// Cycles since the spike (3 LSBs of the counter).
    pub count: [NetId; 3],
}

/// Build spike_gen in the requested flavour.
pub fn spike_gen(
    b: &mut Builder<'_>,
    flavor: Flavor,
    d: NetId,
    grst: NetId,
) -> SpikeGenPorts {
    match flavor {
        Flavor::Std => {
            // 4-bit counter registers with feedback.
            let q: Vec<NetId> = (0..4).map(|_| b.net()).collect();
            let done = q[3];
            let ndone = b.inv(done);
            let en = b.and2(d, ndone);
            // increment-by-en half-adder chain
            let (s0, c0) = b.half_adder(q[0], en);
            let (s1, c1) = b.half_adder(q[1], c0);
            let (s2, c2) = b.half_adder(q[2], c1);
            let s3 = b.xor2(q[3], c2);
            // synchronous clear: d & !grst
            let ngrst = b.inv(grst);
            for (k, s) in [s0, s1, s2, s3].into_iter().enumerate() {
                let dk = b.and2(s, ngrst);
                b.inst_with_outs(
                    crate::cells::CellKind::Dff,
                    &[dk],
                    &[q[k]],
                    ClockDomain::Aclk,
                );
            }
            SpikeGenPorts { pulse: en, count: [q[0], q[1], q[2]] }
        }
        Flavor::Custom => {
            let o = b.macro_cell(
                MacroKind::SpikeGen,
                &[d, grst],
                ClockDomain::Aclk,
            );
            SpikeGenPorts { pulse: o[0], count: [o[1], o[2], o[3]] }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;
    use crate::cells::Library;
    use crate::sim::Simulator;

    fn module(b: &mut Builder<'_>, flavor: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let d = b.input("d");
        let grst = b.input("grst");
        let p = spike_gen(b, flavor, d, grst);
        (vec![d, grst], vec![p.pulse, p.count[0], p.count[1], p.count[2]])
    }

    /// Wave stimulus: level rises at cycle `s`, wave of 17 cycles with
    /// grst at the last cycle.
    fn wave(s: usize) -> Vec<(Vec<bool>, bool)> {
        (0..17)
            .map(|c| (vec![c >= s && c < 16, c == 16], c == 15))
            .collect()
    }

    #[test]
    fn flavours_equivalent_all_spike_times() {
        for s in 0..8 {
            let mut stim = wave(s);
            stim.extend(wave(7 - s)); // second wave after reset
            testutil::assert_equiv(module, &stim).unwrap();
        }
    }

    #[test]
    fn pulse_is_exactly_eight_cycles_and_count_tracks() {
        let lib = Library::with_macros();
        for flavor in [Flavor::Std, Flavor::Custom] {
            let nl = testutil::build(&lib, flavor, module);
            let mut sim = Simulator::new(&nl, &lib).unwrap();
            let s = 3usize;
            let mut pulse_cycles = Vec::new();
            for c in 0..17 {
                sim.tick(
                    &[(nl.inputs[0], c >= s && c < 16), (nl.inputs[1], c == 16)],
                    c == 15,
                );
                if sim.get(nl.outputs[0]) {
                    let cnt = (sim.get(nl.outputs[1]) as u8)
                        | (sim.get(nl.outputs[2]) as u8) << 1
                        | (sim.get(nl.outputs[3]) as u8) << 2;
                    assert_eq!(cnt as usize, c - s, "{flavor:?} count");
                    pulse_cycles.push(c);
                }
            }
            assert_eq!(pulse_cycles, (s..s + 8).collect::<Vec<_>>(), "{flavor:?}");
        }
    }

    #[test]
    fn grst_clears_for_next_wave() {
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, Flavor::Std, module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        // Wave 1: spike at 0 (counter saturates); wave 2: spike at 2.
        for c in 0..17 {
            sim.tick(&[(nl.inputs[0], c < 16), (nl.inputs[1], c == 16)], c == 15);
        }
        let mut pulses = 0;
        for c in 0..16 {
            sim.tick(&[(nl.inputs[0], c >= 2), (nl.inputs[1], false)], false);
            pulses += sim.get(nl.outputs[0]) as u32;
        }
        assert_eq!(pulses, 8, "counter must be clear after grst");
    }
}
