//! Winner-Take-All inhibition (§II.C): pass the first spiking neuron's
//! output intact, nullify the rest, break ties by lowest index.
//!
//! Temporal semantics make this a *first-arrival lock*: on the earliest
//! cycle any `fire` level is high, the lowest-index firing neuron wins and
//! its `pulse2edge` lock is set; the lock fans back as inhibition so no
//! later (or same-cycle higher-index) neuron can ever be granted.  The
//! earliest-arrival comparisons are the role the paper's pass-transistor
//! `less_equal` macro plays in inhibition; the same-cycle tie-break is the
//! priority chain.

use crate::netlist::{Builder, Flavor, NetId};

use super::pulse2edge::{pulse2edge, P2eVariant};

/// WTA ports.
pub struct WtaPorts {
    /// One-cycle grant pulse per neuron (at its winning spike time).
    pub grants: Vec<NetId>,
    /// Latched post-WTA spike level per neuron (asserted until grst).
    pub locks: Vec<NetId>,
}

/// Build the WTA over the q neuron `fires` levels.
pub fn wta(
    b: &mut Builder<'_>,
    flavor: Flavor,
    fires: &[NetId],
    grst: NetId,
) -> WtaPorts {
    let q = fires.len();
    // Lock registers (allocated up-front: they feed back as inhibition).
    // Both flavours use the power-optimized pulse2edge (async reset) so
    // inhibition takes effect identically.
    let locks: Vec<NetId> = (0..q).map(|_| b.net()).collect();
    let locked_any = b.or_tree(&locks);
    let free = b.inv(locked_any);

    let mut grants = Vec::with_capacity(q);
    let mut prefix: Option<NetId> = None; // OR of fires[0..i]
    for i in 0..q {
        let grant = match prefix {
            None => b.and2(fires[i], free),
            Some(p) => {
                let np = b.inv(p);
                b.and3(fires[i], free, np)
            }
        };
        grants.push(grant);
        prefix = Some(match prefix {
            None => fires[i],
            Some(p) => b.or2(p, fires[i]),
        });
    }
    // Latch grants into locks (drives the pre-allocated lock nets).
    for i in 0..q {
        let lock_out = pulse2edge(b, flavor, P2eVariant::PowerOpt, grants[i], grst);
        // pulse2edge allocated its own output; alias it onto locks[i]
        // through a buffer to keep single-driver invariants.
        b.inst_with_outs(
            crate::cells::CellKind::Buf,
            &[lock_out],
            &[locks[i]],
            crate::netlist::ClockDomain::Comb,
        );
    }
    WtaPorts { grants, locks }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;
    use crate::cells::Library;
    use crate::sim::Simulator;

    fn module(b: &mut Builder<'_>, f: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let fires = b.input_bus("fire", 4);
        let grst = b.input("grst");
        let w = wta(b, f, &fires, grst);
        let mut ins = fires;
        ins.push(grst);
        let mut outs = w.grants;
        outs.extend(w.locks);
        (ins, outs)
    }

    #[test]
    fn flavours_equivalent_random_waves() {
        let mut stim = Vec::new();
        let mut seed = 0x77u64;
        for _ in 0..30 {
            let mut rise = [17usize; 4];
            for r in rise.iter_mut() {
                seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                let v = (seed >> 33) % 20;
                *r = v as usize; // >15 = never fires
            }
            for c in 0..17 {
                let mut bits: Vec<bool> =
                    (0..4).map(|i| c >= rise[i] && c < 16).collect();
                bits.push(c == 16);
                stim.push((bits, c == 15));
            }
        }
        testutil::assert_equiv(module, &stim).unwrap();
    }

    /// Drive fires rising at `rise[i]`; return (winner, grant cycle).
    fn run_wave(rise: &[usize; 4], flavor: Flavor) -> Option<(usize, usize)> {
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, flavor, module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        let mut won = None;
        for c in 0..16 {
            let mut iv: Vec<_> = (0..4)
                .map(|i| (nl.inputs[i], c >= rise[i]))
                .collect();
            iv.push((nl.inputs[4], false));
            sim.tick(&iv, false);
            for i in 0..4 {
                if sim.get(nl.outputs[i]) {
                    assert!(won.is_none(), "double grant");
                    won = Some((i, c));
                }
            }
        }
        won
    }

    #[test]
    fn earliest_spike_wins() {
        for flavor in [Flavor::Std, Flavor::Custom] {
            assert_eq!(run_wave(&[5, 2, 9, 4], flavor), Some((1, 2)), "{flavor:?}");
        }
    }

    #[test]
    fn ties_break_to_lowest_index() {
        for flavor in [Flavor::Std, Flavor::Custom] {
            assert_eq!(run_wave(&[3, 3, 3, 3], flavor), Some((0, 3)), "{flavor:?}");
        }
    }

    #[test]
    fn no_fire_no_grant() {
        for flavor in [Flavor::Std, Flavor::Custom] {
            assert_eq!(run_wave(&[17, 17, 17, 17], flavor), None, "{flavor:?}");
        }
    }

    #[test]
    fn at_most_one_winner_locked() {
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, Flavor::Std, module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for c in 0..16 {
            let mut iv: Vec<_> =
                (0..4).map(|i| (nl.inputs[i], c >= i + 2)).collect();
            iv.push((nl.inputs[4], false));
            sim.tick(&iv, false);
        }
        let locked: u32 =
            (4..8).map(|k| sim.get(nl.outputs[k]) as u32).sum();
        assert_eq!(locked, 1);
    }
}
