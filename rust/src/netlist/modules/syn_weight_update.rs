//! `syn_weight_update` (Fig. 2): the 3-bit saturating weight FSM.
//!
//! Holds the synaptic weight and applies the STDP `inc`/`dec` strobes on
//! the gamma-clock edge (end of computational wave).  `inc` has priority
//! and both directions saturate — matching `ref.py`'s
//! `clip(w + delta, 0, 7)` and the behavioral macro model in
//! [`crate::sim::eval`].

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// Build the weight FSM; returns the 3 weight bits (LSB first).
pub fn syn_weight_update(
    b: &mut Builder<'_>,
    flavor: Flavor,
    inc: NetId,
    dec: NetId,
) -> [NetId; 3] {
    match flavor {
        Flavor::Std => {
            let q = [b.net(), b.net(), b.net()];
            let next = b.sat_updown3(&q, inc, dec);
            for k in 0..3 {
                b.inst_with_outs(
                    crate::cells::CellKind::Dff,
                    &[next[k]],
                    &[q[k]],
                    ClockDomain::Gclk,
                );
            }
            q
        }
        Flavor::Custom => {
            let o = b.macro_cell(
                MacroKind::SynWeightUpdate,
                &[inc, dec],
                ClockDomain::Gclk,
            );
            [o[0], o[1], o[2]]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;
    use crate::cells::Library;
    use crate::sim::Simulator;

    fn module(b: &mut Builder<'_>, flavor: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let inc = b.input("inc");
        let dec = b.input("dec");
        let w = syn_weight_update(b, flavor, inc, dec);
        (vec![inc, dec], w.to_vec())
    }

    #[test]
    fn flavours_equivalent_random_waves() {
        // Strobes held across a short wave; commit on gamma edges.
        let stim = testutil::random_stimulus(2, 600, 0xabcd, 4);
        testutil::assert_equiv(module, &stim).unwrap();
    }

    fn read_w(sim: &Simulator<'_>, nl: &crate::netlist::Netlist) -> u8 {
        (sim.get(nl.outputs[0]) as u8)
            | (sim.get(nl.outputs[1]) as u8) << 1
            | (sim.get(nl.outputs[2]) as u8) << 2
    }

    #[test]
    fn saturating_walk_both_flavours() {
        let lib = Library::with_macros();
        for flavor in [Flavor::Std, Flavor::Custom] {
            let nl = testutil::build(&lib, flavor, module);
            let mut sim = Simulator::new(&nl, &lib).unwrap();
            // 10 increments: must stop at 7.
            for _ in 0..10 {
                sim.tick(&[(nl.inputs[0], true), (nl.inputs[1], false)], true);
            }
            sim.tick(&[(nl.inputs[0], false), (nl.inputs[1], false)], false);
            assert_eq!(read_w(&sim, &nl), 7, "{flavor:?} saturates high");
            // 10 decrements: must stop at 0.
            for _ in 0..10 {
                sim.tick(&[(nl.inputs[0], false), (nl.inputs[1], true)], true);
            }
            sim.tick(&[(nl.inputs[0], false), (nl.inputs[1], false)], false);
            assert_eq!(read_w(&sim, &nl), 0, "{flavor:?} saturates low");
            // inc priority over dec.
            sim.tick(&[(nl.inputs[0], true), (nl.inputs[1], true)], true);
            sim.tick(&[(nl.inputs[0], false), (nl.inputs[1], false)], false);
            assert_eq!(read_w(&sim, &nl), 1, "{flavor:?} inc wins");
        }
    }

    #[test]
    fn holds_without_gamma_edge() {
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, Flavor::Std, module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for _ in 0..5 {
            sim.tick(&[(nl.inputs[0], true), (nl.inputs[1], false)], false);
        }
        assert_eq!(read_w(&sim, &nl), 0, "no commit without gclk");
    }
}
