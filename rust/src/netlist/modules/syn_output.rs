//! `syn_output` (Fig. 3): the ramp-no-leak readout.
//!
//! A synapse contributes +1 to its neuron's parallel accumulative counter
//! on every cycle where `count < weight` during the 8-cycle spike pulse:
//! `up = pulse & (count < w)`.  Accumulated over cycles this is exactly
//! the RNL response `min(t+1-s, w)` of `ref.py`.
//!
//! Std flavour: 3-bit magnitude comparator (borrow chain of F1 terms) +
//! output AND, as Genus maps it.  Custom flavour: the GDI hard macro.

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// Build syn_output; returns the `up` strobe.
pub fn syn_output(
    b: &mut Builder<'_>,
    flavor: Flavor,
    count: &[NetId; 3],
    w: &[NetId; 3],
    pulse: NetId,
) -> NetId {
    match flavor {
        Flavor::Std => {
            let lt = b.lt(&count[..], &w[..]);
            b.and2(pulse, lt)
        }
        Flavor::Custom => {
            b.macro_cell(
                MacroKind::SynOutput,
                &[count[0], count[1], count[2], w[0], w[1], w[2], pulse],
                ClockDomain::Comb,
            )[0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    fn module(b: &mut Builder<'_>, flavor: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let c = b.input_bus("c", 3);
        let w = b.input_bus("w", 3);
        let p = b.input("pulse");
        let up = syn_output(
            b,
            flavor,
            &[c[0], c[1], c[2]],
            &[w[0], w[1], w[2]],
            p,
        );
        let mut ins = c;
        ins.extend(w);
        ins.push(p);
        (ins, vec![up])
    }

    #[test]
    fn flavours_equivalent_exhaustive() {
        let stim: Vec<(Vec<bool>, bool)> = (0..128u8)
            .map(|v| ((0..7).map(|i| v >> i & 1 == 1).collect(), false))
            .collect();
        testutil::assert_equiv(module, &stim).unwrap();
    }

    #[test]
    fn up_matches_rnl_semantics() {
        use crate::cells::Library;
        use crate::sim::Simulator;
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, Flavor::Std, module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for c in 0..8u8 {
            for w in 0..8u8 {
                let mut iv = Vec::new();
                for i in 0..3 {
                    iv.push((nl.inputs[i], c >> i & 1 == 1));
                }
                for i in 0..3 {
                    iv.push((nl.inputs[3 + i], w >> i & 1 == 1));
                }
                iv.push((nl.inputs[6], true));
                sim.tick(&iv, false);
                assert_eq!(sim.get(nl.outputs[0]), c < w, "c={c} w={w}");
            }
        }
    }
}
