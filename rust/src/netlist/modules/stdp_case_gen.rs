//! `stdp_case_gen` (Fig. 8): decode the four STDP timing cases.
//!
//! Inputs are the end-of-wave levels `x` (input spiked), `y` (post-WTA
//! output spiked) and `le` (input no later than output, from the
//! `less_equal` sample register).  Outputs follow `ref.py`:
//! capture = x·y·le, backoff = x·y·!le, search = x·!y, minus = !x·y.

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// Case outputs, in (capture, backoff, search, minus) order.
pub struct StdpCases {
    pub capture: NetId,
    pub backoff: NetId,
    pub search: NetId,
    pub minus: NetId,
}

/// Build the case decoder in the requested flavour.
pub fn stdp_case_gen(
    b: &mut Builder<'_>,
    flavor: Flavor,
    x: NetId,
    y: NetId,
    le: NetId,
) -> StdpCases {
    match flavor {
        Flavor::Std => {
            let nx = b.inv(x);
            let ny = b.inv(y);
            let nle = b.inv(le);
            StdpCases {
                capture: b.and3(x, y, le),
                backoff: b.and3(x, y, nle),
                search: b.and2(x, ny),
                minus: b.and2(nx, y),
            }
        }
        Flavor::Custom => {
            let o = b.macro_cell(
                MacroKind::StdpCaseGen,
                &[x, y, le],
                ClockDomain::Comb,
            );
            StdpCases {
                capture: o[0],
                backoff: o[1],
                search: o[2],
                minus: o[3],
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    fn module(b: &mut Builder<'_>, flavor: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let x = b.input("x");
        let y = b.input("y");
        let le = b.input("le");
        let c = stdp_case_gen(b, flavor, x, y, le);
        (vec![x, y, le], vec![c.capture, c.backoff, c.search, c.minus])
    }

    #[test]
    fn flavours_equivalent_exhaustive() {
        let stim: Vec<(Vec<bool>, bool)> = (0..8u8)
            .map(|v| (vec![v & 1 != 0, v & 2 != 0, v & 4 != 0], false))
            .collect();
        testutil::assert_equiv(module, &stim).unwrap();
    }

    #[test]
    fn cases_are_mutually_exclusive() {
        use crate::cells::Library;
        use crate::sim::Simulator;
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, Flavor::Std, module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for v in 0..8u8 {
            let iv: Vec<_> = (0..3)
                .map(|i| (nl.inputs[i], v >> i & 1 == 1))
                .collect();
            sim.tick(&iv, false);
            let active: u32 = nl
                .outputs
                .iter()
                .map(|&o| sim.get(o) as u32)
                .sum();
            assert!(active <= 1, "v={v}: {active} cases active");
        }
    }
}
