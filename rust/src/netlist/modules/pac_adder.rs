//! `pac_adder` (Fig. 4) and the neuron body built from it.
//!
//! The SRM0 neuron body is a *parallel accumulative counter*: every unit
//! cycle it adds the number of active `up` strobes (one per synapse whose
//! RNL ramp is still rising) into a body-potential register and fires when
//! the potential crosses theta.  Structurally: a popcount tree over the p
//! `up` bits, a ripple-carry accumulate ("architectural use of
//! ripple-carry adder chain propagation provides noticeable optimization"),
//! and a threshold comparator.
//!
//! The adder slice is the paper's Fig. 4 single-bit adder: ASAP7 XOR3
//! (sum) + MAJ3 (carry) in the std flavour — exactly what Genus infers —
//! and the diffusion-shared `pac_adder` hard slice in the custom flavour.
//! The threshold comparator has no macro in the paper's set and is
//! synthesized from standard cells in both flavours.

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// One single-bit adder slice; returns `(sum, carry)`.
pub fn adder_slice(
    b: &mut Builder<'_>,
    flavor: Flavor,
    a: NetId,
    bb: NetId,
    cin: NetId,
) -> (NetId, NetId) {
    match flavor {
        Flavor::Std => b.full_adder(a, bb, cin),
        Flavor::Custom => {
            let o = b.macro_cell(
                MacroKind::PacAdder,
                &[a, bb, cin],
                ClockDomain::Comb,
            );
            (o[0], o[1])
        }
    }
}

/// Ripple-carry add of equal-width buses from slices; returns (sum, cout).
pub fn ripple_add(
    b: &mut Builder<'_>,
    flavor: Flavor,
    a: &[NetId],
    bb: &[NetId],
) -> (Vec<NetId>, NetId) {
    assert_eq!(a.len(), bb.len());
    let mut carry = b.zero();
    let mut out = Vec::with_capacity(a.len());
    for i in 0..a.len() {
        let (s, c) = adder_slice(b, flavor, a[i], bb[i], carry);
        out.push(s);
        carry = c;
    }
    (out, carry)
}

/// Popcount of `bits` from adder slices (LSB-first).
pub fn popcount(b: &mut Builder<'_>, flavor: Flavor, bits: &[NetId]) -> Vec<NetId> {
    match bits.len() {
        0 => vec![b.zero()],
        1 => vec![bits[0]],
        2 => {
            let z = b.zero();
            let (s, c) = adder_slice(b, flavor, bits[0], bits[1], z);
            vec![s, c]
        }
        3 => {
            let (s, c) = adder_slice(b, flavor, bits[0], bits[1], bits[2]);
            vec![s, c]
        }
        n => {
            let mid = n / 2;
            let mut l = popcount(b, flavor, &bits[..mid]);
            let mut r = popcount(b, flavor, &bits[mid..]);
            let w = l.len().max(r.len());
            let zero = b.zero();
            l.resize(w, zero);
            r.resize(w, zero);
            let (mut s, c) = ripple_add(b, flavor, &l, &r);
            s.push(c);
            s
        }
    }
}

/// Neuron-body ports.
pub struct NeuronBody {
    /// Fires (level) the cycle the potential first reaches theta.
    pub fire: NetId,
    /// Current accumulator bits (debug / tests).
    pub acc: Vec<NetId>,
}

/// Build the parallel accumulative counter + threshold compare.
///
/// `ups` are the p synapse strobes, `theta` the firing threshold
/// (elaboration constant, as in the RTL), `grst` clears the accumulator
/// between waves.  `fire` is combinational on the *incoming* sum so the
/// spike is visible in the same unit cycle the potential crosses theta
/// (matching `ref.py`).
pub fn neuron_body(
    b: &mut Builder<'_>,
    flavor: Flavor,
    ups: &[NetId],
    theta: u64,
    grst: NetId,
) -> NeuronBody {
    let p = ups.len();
    // Accumulator wide enough for the worst-case potential 7p.
    let max_pot = 7 * p as u64;
    let width = (64 - max_pot.leading_zeros()) as usize;
    let pop = popcount(b, flavor, ups);

    // Accumulator registers with feedback.
    let acc: Vec<NetId> = (0..width).map(|_| b.net()).collect();
    let zero = b.zero();
    let mut pop_ext = pop.clone();
    pop_ext.resize(width, zero);
    let (total, _ovf) = ripple_add(b, flavor, &acc, &pop_ext);
    let ngrst = b.inv(grst);
    for k in 0..width {
        let d = b.and2(total[k], ngrst);
        b.inst_with_outs(
            crate::cells::CellKind::Dff,
            &[d],
            &[acc[k]],
            ClockDomain::Aclk,
        );
    }
    // fire = (acc + pop) >= theta, combinational.
    let theta_bus = b.const_bus(theta, width);
    let fire = b.geq(&total, &theta_bus);
    NeuronBody { fire, acc }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;
    use crate::cells::Library;
    use crate::sim::Simulator;

    fn slice_module(b: &mut Builder<'_>, f: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let a = b.input("a");
        let x = b.input("b");
        let c = b.input("cin");
        let (s, co) = adder_slice(b, f, a, x, c);
        (vec![a, x, c], vec![s, co])
    }

    #[test]
    fn slice_flavours_equivalent_exhaustive() {
        let stim: Vec<(Vec<bool>, bool)> = (0..8u8)
            .map(|v| ((0..3).map(|i| v >> i & 1 == 1).collect(), false))
            .collect();
        testutil::assert_equiv(slice_module, &stim).unwrap();
    }

    fn pop9(b: &mut Builder<'_>, f: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let ins = b.input_bus("x", 9);
        let s = popcount(b, f, &ins);
        (ins, s)
    }

    #[test]
    fn popcount_counts_correctly_both_flavours() {
        let lib = Library::with_macros();
        for flavor in [Flavor::Std, Flavor::Custom] {
            let nl = testutil::build(&lib, flavor, pop9);
            let mut sim = Simulator::new(&nl, &lib).unwrap();
            for v in [0u16, 1, 0b101, 0b111111111, 0b10101, 0b110011] {
                let iv: Vec<_> = (0..9)
                    .map(|i| (nl.inputs[i], v >> i & 1 == 1))
                    .collect();
                sim.tick(&iv, false);
                let got: u32 = nl
                    .outputs
                    .iter()
                    .enumerate()
                    .map(|(k, &o)| (sim.get(o) as u32) << k)
                    .sum();
                assert_eq!(got, v.count_ones(), "{flavor:?} v={v:b}");
            }
        }
    }

    fn body_module(b: &mut Builder<'_>, f: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let ups = b.input_bus("up", 6);
        let grst = b.input("grst");
        let nb = neuron_body(b, f, &ups, 10, grst);
        let mut ins = ups;
        ins.push(grst);
        (ins, vec![nb.fire])
    }

    #[test]
    fn body_flavours_equivalent_random_waves() {
        let mut stim = Vec::new();
        for wave in 0..12 {
            for c in 0..16 {
                let mut bits: Vec<bool> =
                    (0..6).map(|i| (wave * 31 + c * 7 + i) % 3 == 0).collect();
                bits.push(c == 15); // grst on last cycle
                stim.push((bits, false));
            }
        }
        testutil::assert_equiv(body_module, &stim).unwrap();
    }

    #[test]
    fn fires_when_potential_crosses_theta() {
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, Flavor::Std, body_module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        // 4 ups/cycle, theta=10 -> potential 4,8,12: fires on 3rd cycle.
        let mut fire_cycle = None;
        for c in 0..5 {
            let mut iv: Vec<_> =
                (0..6).map(|i| (nl.inputs[i], i < 4)).collect();
            iv.push((nl.inputs[6], false));
            sim.tick(&iv, false);
            if fire_cycle.is_none() && sim.get(nl.outputs[0]) {
                fire_cycle = Some(c);
            }
        }
        assert_eq!(fire_cycle, Some(2));
    }

    #[test]
    fn grst_clears_potential() {
        let lib = Library::with_macros();
        let nl = testutil::build(&lib, Flavor::Custom, body_module);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        // Accumulate 8, then reset, then verify fresh accumulation.
        for _ in 0..2 {
            let mut iv: Vec<_> = (0..6).map(|i| (nl.inputs[i], i < 4)).collect();
            iv.push((nl.inputs[6], false));
            sim.tick(&iv, false);
        }
        let mut iv: Vec<_> = (0..6).map(|i| (nl.inputs[i], false)).collect();
        iv.push((nl.inputs[6], true)); // grst
        sim.tick(&iv, false);
        // Now 2 ups/cycle: should NOT fire within 4 cycles (8 < 10).
        for _ in 0..4 {
            let mut iv: Vec<_> = (0..6).map(|i| (nl.inputs[i], i < 2)).collect();
            iv.push((nl.inputs[6], false));
            sim.tick(&iv, false);
            assert!(!sim.get(nl.outputs[0]));
        }
    }
}
