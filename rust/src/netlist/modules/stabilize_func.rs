//! `stabilize_func` (Figs. 9 / 18): weight-indexed BRV selection.
//!
//! Selects one of 8 Bernoulli lines by the 3-bit synaptic weight — the
//! stabilization function of [2] that slows updates near the weight rails
//! so STDP converges.  Functionally an 8:1 mux; the custom flavour is the
//! paper's hard macro (seven `mux2to1gdi` cells, Fig. 18), the standard
//! flavour is the 7×MUX2 tree Genus elaborates.

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

use super::mux;

/// Build the 8:1 BRV select.  `brv` has 8 lines, `w` the 3 weight bits
/// (LSB first).
pub fn stabilize_func(
    b: &mut Builder<'_>,
    flavor: Flavor,
    brv: &[NetId],
    w: &[NetId],
) -> NetId {
    assert_eq!(brv.len(), 8);
    assert_eq!(w.len(), 3);
    match flavor {
        Flavor::Std => mux::mux_tree(b, Flavor::Std, brv, w),
        Flavor::Custom => {
            let mut ins = brv.to_vec();
            ins.extend_from_slice(w);
            b.macro_cell(MacroKind::StabilizeFunc, &ins, ClockDomain::Comb)[0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    fn module(b: &mut Builder<'_>, flavor: Flavor) -> (Vec<NetId>, Vec<NetId>) {
        let brv = b.input_bus("brv", 8);
        let w = b.input_bus("w", 3);
        let y = stabilize_func(b, flavor, &brv, &w);
        let mut ins = brv;
        ins.extend(w);
        (ins, vec![y])
    }

    #[test]
    fn flavours_equivalent_random() {
        let stim = testutil::random_stimulus(11, 400, 0xfeed, 0);
        testutil::assert_equiv(module, &stim).unwrap();
    }

    #[test]
    fn complexity_similar_to_single_std_mux() {
        // Fig. 18's claim, at netlist level.
        use crate::cells::Library;
        let lib = Library::with_macros();
        let cus = testutil::build(&lib, Flavor::Custom, module);
        let t = cus.census(&lib).transistors;
        let std_mux = lib.cell(lib.id("MUX2x1").unwrap()).transistors as u64;
        // minus the 4T of tie cells present in every netlist
        assert!(t - 4 <= 2 * std_mux, "{t}T vs mux {std_mux}T");
    }
}
