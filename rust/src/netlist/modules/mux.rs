//! 2:1 mux (Figs. 11 / 16–17) and the GDI mux tree.
//!
//! The paper's flagship cell comparison: the ASAP7 standard-cell mux is a
//! 12-transistor static gate; the custom `mux2to1gdi` is a bare 2T GDI
//! pair.  Seven of them compose the 8:1 multiplexing logic of
//! `stabilize_func` (Fig. 18).

use crate::cells::MacroKind;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId};

/// `y = s ? d1 : d0` in the requested flavour.
pub fn mux2(
    b: &mut Builder<'_>,
    flavor: Flavor,
    d0: NetId,
    d1: NetId,
    s: NetId,
) -> NetId {
    match flavor {
        Flavor::Std => b.mux2(d0, d1, s),
        Flavor::Custom => {
            b.macro_cell(MacroKind::Mux2Gdi, &[d0, d1, s], ClockDomain::Comb)[0]
        }
    }
}

/// 2^k : 1 mux tree from 2:1 muxes (sel LSB-first).  With
/// `Flavor::Custom` this is the Fig. 18 construction (seven `mux2to1gdi`
/// cells for 8:1).
pub fn mux_tree(
    b: &mut Builder<'_>,
    flavor: Flavor,
    data: &[NetId],
    sel: &[NetId],
) -> NetId {
    assert_eq!(data.len(), 1 << sel.len(), "mux tree width");
    let mut level: Vec<NetId> = data.to_vec();
    for &s in sel {
        level = level
            .chunks(2)
            .map(|pair| mux2(b, flavor, pair[0], pair[1], s))
            .collect();
    }
    level[0]
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    fn module8(
        b: &mut Builder<'_>,
        flavor: Flavor,
    ) -> (Vec<NetId>, Vec<NetId>) {
        let data = b.input_bus("d", 8);
        let sel = b.input_bus("s", 3);
        let y = mux_tree(b, flavor, &data, &sel);
        let mut ins = data;
        ins.extend(sel);
        (ins, vec![y])
    }

    #[test]
    fn tree_flavours_equivalent_random() {
        let stim = testutil::random_stimulus(11, 300, 0x5eed, 0);
        testutil::assert_equiv(module8, &stim).unwrap();
    }

    #[test]
    fn selects_every_lane() {
        use crate::cells::Library;
        use crate::sim::Simulator;
        let lib = Library::with_macros();
        for flavor in [Flavor::Std, Flavor::Custom] {
            let nl = testutil::build(&lib, flavor, module8);
            let mut sim = Simulator::new(&nl, &lib).unwrap();
            for lane in 0..8usize {
                let mut iv: Vec<_> = (0..8)
                    .map(|i| (nl.inputs[i], i == lane))
                    .collect();
                for k in 0..3 {
                    iv.push((nl.inputs[8 + k], lane >> k & 1 == 1));
                }
                sim.tick(&iv, false);
                assert!(sim.get(nl.outputs[0]), "{flavor:?} lane {lane}");
            }
        }
    }

    #[test]
    fn custom_tree_is_7x_smaller_in_transistors() {
        // Fig. 18: 7 GDI muxes ~ the complexity of ONE std mux.
        use crate::cells::Library;
        let lib = Library::with_macros();
        let std = testutil::build(&lib, Flavor::Std, module8);
        let cus = testutil::build(&lib, Flavor::Custom, module8);
        let st = std.census(&lib).transistors;
        let ct = cus.census(&lib).transistors;
        // 7x12=84 vs 7x2=14 (+4T of ties in both).
        assert!(ct * 4 < st, "custom {ct}T vs std {st}T");
    }
}
