//! The p×q TNN column (Fig. 1): the paper's benchmark unit.
//!
//! A column is q excitatory SRM0 neurons sharing p temporally-coded
//! inputs, with WTA lateral inhibition and per-synapse STDP learning —
//! assembled from the Figs. 2–13 macros exactly as §II.C describes:
//!
//! ```text
//!  x[p] ──spike_gen──► pulse/count ──syn_output(w)──► up[p] ─┐
//!                                                            ▼
//!                  pac_adder popcount + accumulate + θ-compare ──► fire[q]
//!                                                            │
//!       WTA (priority + pulse2edge locks) ◄─────────────────┘
//!        │ grants/locks
//!        ▼
//!  less_equal sample ─ stdp_case_gen ─ stabilize_func ─ incdec
//!        └────────────► syn_weight_update (gclk) ──► w[p][q]
//! ```
//!
//! Both flavours share this structure; the [`Flavor`] parameter selects
//! per-module standard-cell vs custom-macro realizations (the Table I
//! substitution).

use crate::cells::CellKind;
use crate::error::Result;
use crate::netlist::{Builder, ClockDomain, Flavor, NetId, Netlist};

use super::modules::edge2pulse::edge2pulse;
use super::modules::incdec::incdec;
use super::modules::less_equal::less_equal;
use super::modules::mux::mux2;
use super::modules::pac_adder::neuron_body;
use super::modules::spike_gen::spike_gen;
use super::modules::stabilize_func::stabilize_func;
use super::modules::stdp_case_gen::stdp_case_gen;
use super::modules::syn_output::syn_output;
use super::modules::syn_weight_update::syn_weight_update;
use super::modules::wta::wta;

/// Per-synapse BRV input lanes (drive order within the 19-bit group):
/// `[b_capture, b_backoff, b_search, stab_up[0..8], stab_dn[0..8]]`.
pub const BRV_PER_SYN: usize = 19;

/// Column geometry + elaboration parameters.
#[derive(Debug, Clone, Copy)]
pub struct ColumnSpec {
    /// Synapses per neuron (inputs).
    pub p: usize,
    /// Neurons.
    pub q: usize,
    /// Firing threshold (elaboration constant, as in the RTL).
    pub theta: u64,
}

impl ColumnSpec {
    /// The paper's three Table-I benchmark columns.  Thresholds follow
    /// [2]'s sizing rule theta ≈ p/2 (half the inputs at mid weight).
    pub fn benchmark(p: usize, q: usize) -> Self {
        ColumnSpec { p, q, theta: (p as u64 * 7) / 4 }
    }

    /// The canonical "PxQ" geometry label — the one formatting shared
    /// by reports, dump artifacts, and target descriptors.
    pub fn label(&self) -> String {
        format!("{}x{}", self.p, self.q)
    }
}

/// Elaborated column ports (all primary I/O nets).
#[derive(Debug, Clone)]
pub struct ColumnPorts {
    /// p input spike levels (rise at the encoded time, hold until grst).
    pub x: Vec<NetId>,
    /// Gamma-clock level (pulse high on the reset cycle of each wave).
    pub gclk: NetId,
    /// BRV lanes: `p*q*BRV_PER_SYN` bits, synapse-major
    /// (`syn = j*q + i`, then the 19 lanes of that synapse).
    pub brv: Vec<NetId>,
    /// Pre-WTA fire levels per neuron.
    pub fires: Vec<NetId>,
    /// WTA grant pulses per neuron.
    pub grants: Vec<NetId>,
    /// Post-WTA latched spike levels per neuron.
    pub locks: Vec<NetId>,
    /// Weight register bits per synapse (`[j*q+i] -> [w0,w1,w2]`),
    /// exposed for testbench readback.
    pub weights: Vec<[NetId; 3]>,
}

/// Elaborate a column into `b`.
pub fn column(b: &mut Builder<'_>, flavor: Flavor, spec: &ColumnSpec) -> ColumnPorts {
    let (p, q) = (spec.p, spec.q);
    let x = b.input_bus("x", p);
    let gclk = b.input("gclk");
    let brv = b.input_bus("brv", p * q * BRV_PER_SYN);

    // Gamma reset strobe from the gclk level (Fig. 13).
    let reg = b.push("ctl");
    let grst = edge2pulse(b, flavor, gclk);
    b.pop(reg);

    // Input front-end: one spike_gen per input (Fig. 12).
    let mut pulses = Vec::with_capacity(p);
    let mut counts = Vec::with_capacity(p);
    for j in 0..p {
        let reg = b.push(format!("sg{j}"));
        let sg = spike_gen(b, flavor, x[j], grst);
        b.pop(reg);
        pulses.push(sg.pulse);
        counts.push(sg.count);
    }

    // Weight registers first (they feed both the RNL readout and STDP).
    // inc/dec nets are allocated now and driven by the STDP logic below.
    let mut incs = vec![NetId(0); p * q];
    let mut decs = vec![NetId(0); p * q];
    let mut weights = Vec::with_capacity(p * q);
    for j in 0..p {
        for i in 0..q {
            let reg = b.push(format!("syn{j}_{i}"));
            let inc = b.net();
            let dec = b.net();
            let w = syn_weight_update_feedthrough(b, flavor, inc, dec);
            incs[j * q + i] = inc;
            decs[j * q + i] = dec;
            weights.push(w);
            b.pop(reg);
        }
    }

    // Neuron bodies: RNL readouts + parallel accumulative counters.
    let mut fires = Vec::with_capacity(q);
    for i in 0..q {
        let reg = b.push(format!("neuron{i}"));
        let ups: Vec<NetId> = (0..p)
            .map(|j| {
                syn_output(b, flavor, &counts[j], &weights[j * q + i], pulses[j])
            })
            .collect();
        let body = neuron_body(b, flavor, &ups, spec.theta, grst);
        fires.push(body.fire);
        b.pop(reg);
    }

    // WTA inhibition.
    let reg = b.push("wta");
    let w = wta(b, flavor, &fires, grst);
    b.pop(reg);

    // STDP per synapse.
    for j in 0..p {
        for i in 0..q {
            let reg = b.push(format!("stdp{j}_{i}"));
            let syn = j * q + i;
            let lanes = &brv[syn * BRV_PER_SYN..(syn + 1) * BRV_PER_SYN];
            let (b_c, b_b, b_s) = (lanes[0], lanes[1], lanes[2]);
            let stab_up_brv = &lanes[3..11];
            let stab_dn_brv = &lanes[11..19];

            // Timing sample: le = (x arrived no later than y), captured at
            // the grant cycle through the less_equal macro (Fig. 5).
            let le_q = b.net();
            let le_comb = less_equal(b, flavor, x[j], w.grants[i]);
            let le_d = mux2(b, flavor, le_q, le_comb, w.grants[i]);
            b.inst_with_outs(CellKind::Dff, &[le_d], &[le_q], ClockDomain::Aclk);

            // Case decode + stochastic gating + weight update strobes.
            let cases = stdp_case_gen(b, flavor, x[j], w.locks[i], le_q);
            let wbits = weights[syn];
            let su = stabilize_func(b, flavor, stab_up_brv, &wbits);
            let sd = stabilize_func(b, flavor, stab_dn_brv, &wbits);
            let cap_g = b.and3(cases.capture, b_c, su);
            let back_g = b.and3(cases.backoff, b_b, sd);
            let srch_g = b.and2(cases.search, b_s);
            let min_g = b.and3(cases.minus, b_b, sd);
            let (inc, dec) = incdec(b, flavor, cap_g, back_g, srch_g, min_g);
            // Drive the pre-allocated strobe nets.
            b.inst_with_outs(CellKind::Buf, &[inc], &[incs[syn]], ClockDomain::Comb);
            b.inst_with_outs(CellKind::Buf, &[dec], &[decs[syn]], ClockDomain::Comb);
            b.pop(reg);
        }
    }

    for (i, &f) in fires.iter().enumerate() {
        b.output(f, format!("fire[{i}]"));
    }
    for (i, &g) in w.grants.iter().enumerate() {
        b.output(g, format!("grant[{i}]"));
    }
    for (i, &l) in w.locks.iter().enumerate() {
        b.output(l, format!("lock[{i}]"));
    }

    ColumnPorts {
        x,
        gclk,
        brv,
        fires,
        grants: w.grants.clone(),
        locks: w.locks.clone(),
        weights,
    }
}

/// Weight FSM with caller-visible inc/dec nets (wrapper that lets the
/// RNL readout consume weights elaborated before the STDP logic exists).
fn syn_weight_update_feedthrough(
    b: &mut Builder<'_>,
    flavor: Flavor,
    inc: NetId,
    dec: NetId,
) -> [NetId; 3] {
    syn_weight_update(b, flavor, inc, dec)
}

/// Convenience: elaborate a standalone column netlist.
pub fn build_column(
    lib: &crate::cells::Library,
    flavor: Flavor,
    spec: &ColumnSpec,
) -> Result<(Netlist, ColumnPorts)> {
    let name = format!("column_{}x{}_{:?}", spec.p, spec.q, flavor);
    let mut b = Builder::new(&name, lib);
    let ports = column(&mut b, flavor, spec);
    let nl = b.finish()?;
    Ok((nl, ports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;

    #[test]
    fn small_column_validates_both_flavours() {
        let lib = Library::with_macros();
        for flavor in [Flavor::Std, Flavor::Custom] {
            let spec = ColumnSpec { p: 4, q: 2, theta: 6 };
            let (nl, ports) = build_column(&lib, flavor, &spec).unwrap();
            assert_eq!(ports.x.len(), 4);
            assert_eq!(ports.weights.len(), 8);
            assert_eq!(ports.brv.len(), 8 * BRV_PER_SYN);
            assert!(nl.insts.len() > 50);
        }
    }

    #[test]
    fn custom_column_uses_fewer_transistors() {
        // The Table-I direction at elaboration level.
        let lib = Library::with_macros();
        let spec = ColumnSpec::benchmark(8, 4);
        let (std_nl, _) = build_column(&lib, Flavor::Std, &spec).unwrap();
        let (cus_nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let st = std_nl.census(&lib).transistors;
        let ct = cus_nl.census(&lib).transistors;
        assert!(ct < st, "custom {ct} !< std {st}");
    }

    #[test]
    fn benchmark_spec_thresholds_scale_with_p() {
        assert!(ColumnSpec::benchmark(1024, 16).theta
            > ColumnSpec::benchmark(64, 8).theta);
    }
}
