//! Column-aligned netlist partitioning for multi-core simulation.
//!
//! [`partition`] cuts a netlist into the three-phase execution shape
//! the sharded simulator ([`crate::sim::ShardedSimulator`]) runs:
//!
//! * **head** — the zero-input constant drivers (tie cells).  They are
//!   evaluated first each tick and their outputs are broadcast to every
//!   other part, exactly like primary inputs.
//! * **shards** — groups of instances that read *only* global nets
//!   (primary inputs and head outputs) besides their own.  Shards never
//!   observe each other's nets, so they can be evaluated on separate
//!   threads with no intra-tick synchronization.
//! * **tail** — everything downstream of a shard: instances that read
//!   nets driven by another group (the voter / output layer of a
//!   multi-column netlist).  The tail is evaluated after all shards
//!   finish, from the *boundary nets* the shards publish.
//!
//! The cut is **column-aligned**: candidate groups are the top-level
//! region children (`top/col3/...` → group `col3`), which is how the
//! multi-column layer netlist ([`super::layer::build_layer_netlist`])
//! tags its columns.  Instances elaborated directly in the root region
//! become singleton groups, so the partitioner still works (it just
//! finds finer atoms) on netlists without region structure.
//!
//! A group is shard-eligible exactly when it has no incoming
//! inter-group dependency: any net driven by group A and read by group
//! B (any pin, combinational or sequential) is an edge A→B, and every
//! group with an in-edge is demoted to the tail.  This is conservative
//! — mutually-dependent groups (a cycle) all have in-edges and all land
//! in the tail, where the ordinary levelized evaluation handles their
//! coupling — and it is what makes the three-phase schedule bit-exact:
//! a shard's inputs are fully settled before it runs, and the tail sees
//! every boundary net post-settle, so each instance is evaluated once
//! per tick with exactly the values the single-thread engine would
//! produce (DESIGN.md §8).

use crate::cells::Library;
use crate::error::{Error, Result};
use crate::netlist::{NetId, Netlist};

/// Result of [`partition`]: instance sets per part plus the boundary.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Zero-input constant drivers, evaluated before the shards.
    pub head: Vec<u32>,
    /// Parallel instance groups (each sorted ascending).  May be empty
    /// (`max_shards <= 1` or no shard-eligible group).
    pub shards: Vec<Vec<u32>>,
    /// Instances evaluated after the boundary exchange (sorted).
    pub tail: Vec<u32>,
    /// Nets driven inside a shard and read by the tail, in ascending
    /// net order — the values exchanged at the tick barrier.
    pub boundary: Vec<NetId>,
    /// Shard-eligible groups found before bin-packing (diagnostics:
    /// the available parallelism, independent of `max_shards`).
    pub source_atoms: usize,
}

impl Partition {
    /// Total instances across all parts (must equal the netlist's).
    pub fn n_insts(&self) -> usize {
        self.head.len()
            + self.tail.len()
            + self.shards.iter().map(Vec::len).sum::<usize>()
    }

    /// Check the structural invariants the sharded simulator relies on:
    /// every instance in exactly one part, and no shard instance reads
    /// a net driven outside the global set and its own shard.
    pub fn validate(&self, nl: &Netlist) -> Result<()> {
        let n = nl.insts.len();
        const UNASSIGNED: u32 = u32::MAX;
        const HEAD: u32 = u32::MAX - 1;
        const TAIL: u32 = u32::MAX - 2;
        let mut part = vec![UNASSIGNED; n];
        let set = |list: &[u32], tag: u32, part: &mut Vec<u32>| {
            for &i in list {
                if part[i as usize] != UNASSIGNED {
                    return Err(Error::netlist(format!(
                        "instance {i} assigned to two parts"
                    )));
                }
                part[i as usize] = tag;
            }
            Ok(())
        };
        set(&self.head, HEAD, &mut part)?;
        set(&self.tail, TAIL, &mut part)?;
        for (s, insts) in self.shards.iter().enumerate() {
            set(insts, s as u32, &mut part)?;
        }
        if part.iter().any(|&p| p == UNASSIGNED) {
            return Err(Error::netlist("partition does not cover netlist"));
        }
        // Net ownership: primary inputs and head outputs are global.
        let mut owner = vec![UNASSIGNED; nl.n_nets()];
        let mut global = vec![false; nl.n_nets()];
        for &i in &nl.inputs {
            global[i.0 as usize] = true;
        }
        for i in 0..n {
            for &o in nl.inst_outs(i) {
                if part[i] == HEAD {
                    global[o.0 as usize] = true;
                } else {
                    owner[o.0 as usize] = part[i];
                }
            }
        }
        for i in 0..n {
            if part[i] >= TAIL {
                continue; // head reads nothing; tail may read anything
            }
            for &inp in nl.inst_ins(i) {
                let ni = inp.0 as usize;
                if global[ni] || owner[ni] == UNASSIGNED {
                    continue;
                }
                if owner[ni] != part[i] {
                    return Err(Error::netlist(format!(
                        "shard {} instance {i} reads net {} owned by \
                         part {}",
                        part[i], ni, owner[ni]
                    )));
                }
            }
        }
        Ok(())
    }
}

/// Map every region to its top-level ancestor (the child of the root
/// region on its parent path), or `None` for the root itself.
fn top_children(nl: &Netlist) -> Vec<Option<u32>> {
    let n = nl.regions.len();
    let mut top: Vec<Option<u32>> = vec![None; n];
    for (r, slot) in top.iter_mut().enumerate() {
        let mut cur = r as u32;
        let mut child = None;
        while let Some(p) = nl.regions[cur as usize].parent {
            child = Some(cur);
            cur = p.0;
        }
        *slot = child;
    }
    top
}

/// Partition `nl` into head / at most `max_shards` shards / tail.
///
/// `max_shards <= 1` puts every non-head instance in the tail (the
/// serial, still quiescence-gated schedule).  The function never fails
/// on a valid netlist — a netlist with no parallel structure simply
/// yields empty shards.
pub fn partition(
    nl: &Netlist,
    lib: &Library,
    max_shards: usize,
) -> Result<Partition> {
    let _ = lib; // pin widths already flattened into the instances
    let n = nl.insts.len();
    let n_nets = nl.n_nets();

    // --- classify instances into head / candidate groups --------------
    const HEAD: u32 = u32::MAX;
    let top = top_children(nl);
    // Group key per region top-child, allocated lazily; root-region
    // instances get fresh singleton groups.
    let mut region_group: Vec<u32> = vec![u32::MAX; nl.regions.len()];
    let mut group_of: Vec<u32> = vec![HEAD; n];
    let mut n_groups: u32 = 0;
    let mut head = Vec::new();
    for i in 0..n {
        if nl.insts[i].n_ins == 0 {
            head.push(i as u32);
            continue;
        }
        let g = match top[nl.insts[i].region.0 as usize] {
            Some(r) => {
                if region_group[r as usize] == u32::MAX {
                    region_group[r as usize] = n_groups;
                    n_groups += 1;
                }
                region_group[r as usize]
            }
            None => {
                let g = n_groups;
                n_groups += 1;
                g
            }
        };
        group_of[i] = g;
    }

    // --- global nets and drivers ---------------------------------------
    let mut global = vec![false; n_nets];
    for &i in &nl.inputs {
        global[i.0 as usize] = true;
    }
    for &h in &head {
        for &o in nl.inst_outs(h as usize) {
            global[o.0 as usize] = true;
        }
    }
    let mut driver: Vec<u32> = vec![u32::MAX; n_nets];
    for i in 0..n {
        for &o in nl.inst_outs(i) {
            driver[o.0 as usize] = i as u32;
        }
    }

    // --- inter-group edges → shard eligibility -------------------------
    // A group with any incoming edge (it reads a net driven by another
    // group) cannot be a shard; cycles demote all members.
    let mut has_in_edge = vec![false; n_groups as usize];
    for i in 0..n {
        if group_of[i] == HEAD {
            continue;
        }
        for &inp in nl.inst_ins(i) {
            let ni = inp.0 as usize;
            if global[ni] {
                continue;
            }
            let d = driver[ni];
            if d == u32::MAX || group_of[d as usize] == HEAD {
                continue;
            }
            if group_of[d as usize] != group_of[i] {
                has_in_edge[group_of[i] as usize] = true;
            }
        }
    }

    // --- collect atoms and bin-pack into shards ------------------------
    let mut atom_insts: Vec<Vec<u32>> =
        vec![Vec::new(); n_groups as usize];
    let mut tail = Vec::new();
    for i in 0..n {
        let g = group_of[i];
        if g == HEAD {
            continue;
        }
        if has_in_edge[g as usize] {
            tail.push(i as u32);
        } else {
            atom_insts[g as usize].push(i as u32);
        }
    }
    let mut atoms: Vec<Vec<u32>> = atom_insts
        .into_iter()
        .filter(|a| !a.is_empty())
        .collect();
    let source_atoms = atoms.len();

    let n_bins = if max_shards <= 1 { 0 } else { max_shards.min(atoms.len()) };
    let mut shards: Vec<Vec<u32>> = vec![Vec::new(); n_bins];
    if n_bins == 0 {
        for a in atoms.drain(..) {
            tail.extend(a);
        }
    } else {
        // Largest atom first into the least-loaded bin.
        atoms.sort_by_key(|a| std::cmp::Reverse(a.len()));
        for a in atoms.drain(..) {
            let bin = (0..n_bins)
                .min_by_key(|&b| shards[b].len())
                .expect("n_bins > 0");
            shards[bin].extend(a);
        }
        shards.retain(|s| !s.is_empty());
        for s in &mut shards {
            s.sort_unstable();
        }
    }
    tail.sort_unstable();

    // --- boundary: shard-driven nets read by the tail ------------------
    let mut in_shard = vec![false; n];
    for s in &shards {
        for &i in s {
            in_shard[i as usize] = true;
        }
    }
    let mut is_boundary = vec![false; n_nets];
    for &i in &tail {
        for &inp in nl.inst_ins(i as usize) {
            let ni = inp.0 as usize;
            if global[ni] {
                continue;
            }
            let d = driver[ni];
            if d != u32::MAX && in_shard[d as usize] {
                is_boundary[ni] = true;
            }
        }
    }
    let boundary: Vec<NetId> = (0..n_nets)
        .filter(|&ni| is_boundary[ni])
        .map(|ni| NetId(ni as u32))
        .collect();

    let part = Partition { head, shards, tail, boundary, source_atoms };
    debug_assert_eq!(part.n_insts(), n);
    Ok(part)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;
    use crate::netlist::layer::build_layer_netlist;
    use crate::netlist::column::ColumnSpec;
    use crate::netlist::layer::LayerSpec;
    use crate::netlist::{Builder, ClockDomain, Flavor};

    /// Boundary-heavy hand-built netlist: 4 region-tagged blocks each
    /// driving several nets consumed by a join block.
    fn boundary_heavy(lib: &Library) -> Netlist {
        let mut b = Builder::new("bh", lib);
        let x0 = b.input("x0");
        let x1 = b.input("x1");
        let mut feeds = Vec::new();
        for k in 0..4 {
            let reg = b.push(format!("col{k}"));
            let a = b.xor2(x0, x1);
            let c = b.and2(a, x0);
            let q = b.dff(c, ClockDomain::Aclk);
            let d = b.or2(q, a);
            // Three nets cross into the join block.
            feeds.push(a);
            feeds.push(q);
            feeds.push(d);
            b.pop(reg);
        }
        let reg = b.push("voter");
        let v = b.or_tree(&feeds);
        let vq = b.dff(v, ClockDomain::Gclk);
        b.output(vq, "v");
        b.pop(reg);
        b.finish().unwrap()
    }

    #[test]
    fn column_blocks_become_shards_and_voter_becomes_tail() {
        let lib = Library::asap7_only();
        let nl = boundary_heavy(&lib);
        let p = partition(&nl, &lib, 4).unwrap();
        p.validate(&nl).unwrap();
        assert_eq!(p.n_insts(), nl.insts.len());
        assert_eq!(p.head.len(), 2, "TIELO + TIEHI");
        assert_eq!(p.source_atoms, 4);
        assert_eq!(p.shards.len(), 4);
        // Each block contributes its 3 crossing nets to the boundary.
        assert_eq!(p.boundary.len(), 12);
        assert!(!p.tail.is_empty(), "voter instances in the tail");
        // Every boundary net is driven by a shard and read by the tail.
        let shard_insts: Vec<u32> =
            p.shards.iter().flatten().copied().collect();
        for &bnet in &p.boundary {
            let driven = shard_insts.iter().any(|&i| {
                nl.inst_outs(i as usize).contains(&bnet)
            });
            let read = p.tail.iter().any(|&i| {
                nl.inst_ins(i as usize).contains(&bnet)
            });
            assert!(driven && read, "net {bnet:?}");
        }
    }

    #[test]
    fn fewer_bins_than_atoms_balances_by_size() {
        let lib = Library::asap7_only();
        let nl = boundary_heavy(&lib);
        let p = partition(&nl, &lib, 2).unwrap();
        p.validate(&nl).unwrap();
        assert_eq!(p.shards.len(), 2);
        // 4 equal atoms over 2 bins → 2 atoms each.
        assert_eq!(p.shards[0].len(), p.shards[1].len());
    }

    #[test]
    fn single_thread_partition_is_all_tail() {
        let lib = Library::asap7_only();
        let nl = boundary_heavy(&lib);
        let p = partition(&nl, &lib, 1).unwrap();
        p.validate(&nl).unwrap();
        assert!(p.shards.is_empty());
        assert!(p.boundary.is_empty());
        assert_eq!(p.tail.len(), nl.insts.len() - 2);
    }

    #[test]
    fn layer_netlist_partitions_per_column() {
        let lib = Library::with_macros();
        let spec = LayerSpec {
            cols: 3,
            column: ColumnSpec { p: 4, q: 2, theta: 6 },
        };
        let (nl, _ports) =
            build_layer_netlist(&lib, Flavor::Custom, &spec).unwrap();
        let p = partition(&nl, &lib, 8).unwrap();
        p.validate(&nl).unwrap();
        // One atom per column; the voter reads every column's locks.
        assert_eq!(p.source_atoms, 3);
        assert_eq!(p.shards.len(), 3);
        assert!(!p.tail.is_empty());
        assert!(!p.boundary.is_empty());
    }

    #[test]
    fn region_free_netlist_still_partitions() {
        // Instances in the root region become singleton groups; two
        // independent gates reading only primary inputs are sources.
        let lib = Library::asap7_only();
        let mut b = Builder::new("flat", &lib);
        let x = b.input("x");
        let y = b.input("y");
        let a = b.and2(x, y);
        let o = b.or2(x, y);
        let j = b.xor2(a, o); // reads both → tail
        b.output(j, "j");
        let nl = b.finish().unwrap();
        let p = partition(&nl, &lib, 2).unwrap();
        p.validate(&nl).unwrap();
        assert_eq!(p.source_atoms, 2);
        assert_eq!(p.tail.len(), 1);
        assert_eq!(p.boundary.len(), 2);
    }
}
