//! Elaboration helpers: the RTL-to-gates vocabulary the module builders
//! use (gate constructors, buses, registers, adder/comparator generators).

use crate::cells::{CellKind, Library, MacroKind};
use crate::error::Result;

use super::ir::{ClockDomain, NetId, Netlist, RegionId};

/// Stateful elaboration context over a [`Netlist`].
pub struct Builder<'l> {
    /// Cell library (both flavours elaborate against the same library; the
    /// std flavour simply never instantiates macro cells).
    pub lib: &'l Library,
    /// Netlist under construction.
    pub nl: Netlist,
    region: RegionId,
}

impl<'l> Builder<'l> {
    /// Start a new design.
    pub fn new(name: &str, lib: &'l Library) -> Self {
        let nl = Netlist::new(name, lib);
        Builder { lib, nl, region: RegionId(0) }
    }

    /// Finish: validate and return the netlist.
    pub fn finish(self) -> Result<Netlist> {
        self.nl.validate(self.lib)?;
        Ok(self.nl)
    }

    // ---- regions -------------------------------------------------------

    /// Enter a child region; returns the previous region for [`Self::pop`].
    pub fn push(&mut self, name: impl Into<String>) -> RegionId {
        let prev = self.region;
        self.region = self.nl.add_region(name, prev);
        prev
    }

    /// Leave the current region.
    pub fn pop(&mut self, prev: RegionId) {
        self.region = prev;
    }

    /// Current region.
    pub fn region(&self) -> RegionId {
        self.region
    }

    // ---- nets ----------------------------------------------------------

    /// Fresh anonymous net.
    pub fn net(&mut self) -> NetId {
        self.nl.new_net()
    }

    /// Fresh named net.
    pub fn named(&mut self, name: impl Into<String>) -> NetId {
        let n = self.nl.new_net();
        self.nl.name_net(n, name);
        n
    }

    /// Fresh primary input.
    pub fn input(&mut self, name: impl Into<String>) -> NetId {
        let n = self.named(name);
        self.nl.inputs.push(n);
        n
    }

    /// Bus of primary inputs `name[0..width)`.
    pub fn input_bus(&mut self, name: &str, width: usize) -> Vec<NetId> {
        (0..width).map(|i| self.input(format!("{name}[{i}]"))).collect()
    }

    /// Mark an existing net as primary output.
    pub fn output(&mut self, net: NetId, name: impl Into<String>) {
        self.nl.name_net(net, name);
        self.nl.outputs.push(net);
    }

    /// Constant nets.
    pub fn zero(&self) -> NetId {
        self.nl.const0
    }
    pub fn one(&self) -> NetId {
        self.nl.const1
    }

    // ---- instances -----------------------------------------------------

    /// Instantiate by [`CellKind`] (first library cell of that kind),
    /// allocating output nets.
    pub fn kind(&mut self, kind: CellKind, ins: &[NetId]) -> Vec<NetId> {
        self.kind_in(kind, ins, ClockDomain::Comb)
    }

    /// Instantiate a sequential cell kind in a clock domain.
    pub fn kind_in(
        &mut self,
        kind: CellKind,
        ins: &[NetId],
        domain: ClockDomain,
    ) -> Vec<NetId> {
        let cell = self.lib.id_of_kind(kind).expect("kind in library");
        let (_, n_out, _) = kind.pins();
        let outs: Vec<NetId> = (0..n_out).map(|_| self.net()).collect();
        self.nl.push_inst(cell, ins, &outs, domain, self.region);
        outs
    }

    /// Instantiate with caller-allocated output nets (needed for
    /// registered feedback: allocate Q first, build next-state logic from
    /// it, then place the flop driving Q).
    pub fn inst_with_outs(
        &mut self,
        kind: CellKind,
        ins: &[NetId],
        outs: &[NetId],
        domain: ClockDomain,
    ) {
        let cell = self.lib.id_of_kind(kind).expect("kind in library");
        self.nl.push_inst(cell, ins, outs, domain, self.region);
    }

    /// Instantiate one of the custom hard macros.
    pub fn macro_cell(
        &mut self,
        m: MacroKind,
        ins: &[NetId],
        domain: ClockDomain,
    ) -> Vec<NetId> {
        self.kind_in(CellKind::Macro(m), ins, domain)
    }

    // ---- combinational vocabulary ---------------------------------------

    pub fn inv(&mut self, a: NetId) -> NetId {
        self.kind(CellKind::Inv, &[a])[0]
    }
    pub fn buf(&mut self, a: NetId) -> NetId {
        self.kind(CellKind::Buf, &[a])[0]
    }
    pub fn and2(&mut self, a: NetId, b: NetId) -> NetId {
        self.kind(CellKind::And2, &[a, b])[0]
    }
    pub fn and3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        self.kind(CellKind::And3, &[a, b, c])[0]
    }
    pub fn or2(&mut self, a: NetId, b: NetId) -> NetId {
        self.kind(CellKind::Or2, &[a, b])[0]
    }
    pub fn or3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        self.kind(CellKind::Or3, &[a, b, c])[0]
    }
    pub fn nand2(&mut self, a: NetId, b: NetId) -> NetId {
        self.kind(CellKind::Nand2, &[a, b])[0]
    }
    pub fn nor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.kind(CellKind::Nor2, &[a, b])[0]
    }
    pub fn xor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.kind(CellKind::Xor2, &[a, b])[0]
    }
    pub fn xnor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.kind(CellKind::Xnor2, &[a, b])[0]
    }
    pub fn xor3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        self.kind(CellKind::Xor3, &[a, b, c])[0]
    }
    pub fn maj3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        self.kind(CellKind::Maj3, &[a, b, c])[0]
    }
    /// `y = s ? d1 : d0` using the 12T standard mux.
    pub fn mux2(&mut self, d0: NetId, d1: NetId, s: NetId) -> NetId {
        self.kind(CellKind::Mux2, &[d0, d1, s])[0]
    }

    /// Wide OR as a balanced tree of OR2/OR3.
    pub fn or_tree(&mut self, nets: &[NetId]) -> NetId {
        match nets.len() {
            0 => self.zero(),
            1 => nets[0],
            2 => self.or2(nets[0], nets[1]),
            3 => self.or3(nets[0], nets[1], nets[2]),
            n => {
                let mid = n / 2;
                let l = self.or_tree(&nets[..mid]);
                let r = self.or_tree(&nets[mid..]);
                self.or2(l, r)
            }
        }
    }

    /// Wide AND as a balanced tree.
    pub fn and_tree(&mut self, nets: &[NetId]) -> NetId {
        match nets.len() {
            0 => self.one(),
            1 => nets[0],
            2 => self.and2(nets[0], nets[1]),
            3 => self.and3(nets[0], nets[1], nets[2]),
            n => {
                let mid = n / 2;
                let l = self.and_tree(&nets[..mid]);
                let r = self.and_tree(&nets[mid..]);
                self.and2(l, r)
            }
        }
    }

    // ---- sequential vocabulary ------------------------------------------

    /// Plain D flop in `domain`.
    pub fn dff(&mut self, d: NetId, domain: ClockDomain) -> NetId {
        self.kind_in(CellKind::Dff, &[d], domain)[0]
    }

    /// D flop with async active-high reset.
    pub fn dff_r(&mut self, d: NetId, rst: NetId, domain: ClockDomain) -> NetId {
        self.kind_in(CellKind::DffR, &[d, rst], domain)[0]
    }

    /// Register bus.
    pub fn reg_bus(&mut self, d: &[NetId], domain: ClockDomain) -> Vec<NetId> {
        d.iter().map(|&n| self.dff(n, domain)).collect()
    }

    // ---- arithmetic generators -------------------------------------------

    /// Full adder from library FA halves (XOR3 sum + MAJ3 carry), as Genus
    /// maps ASAP7 ("Genus synthesizes the adder modules ... with ASAP7
    /// Majority cells").
    pub fn full_adder(&mut self, a: NetId, b: NetId, cin: NetId) -> (NetId, NetId) {
        let sum = self.xor3(a, b, cin);
        let carry = self.maj3(a, b, cin);
        (sum, carry)
    }

    /// Half adder.
    pub fn half_adder(&mut self, a: NetId, b: NetId) -> (NetId, NetId) {
        (self.xor2(a, b), self.and2(a, b))
    }

    /// Ripple-carry adder: `a + b` (equal widths, LSB first); returns
    /// (sum bits, carry out).  "Architectural use of ripple-carry adder
    /// chain propagation provides noticeable optimization" (§II.C).
    pub fn ripple_add(&mut self, a: &[NetId], b: &[NetId]) -> (Vec<NetId>, NetId) {
        assert_eq!(a.len(), b.len());
        let mut carry = self.zero();
        let mut sum = Vec::with_capacity(a.len());
        for i in 0..a.len() {
            let (s, c) = self.full_adder(a[i], b[i], carry);
            sum.push(s);
            carry = c;
        }
        (sum, carry)
    }

    /// Popcount of `bits` as a carry-save adder tree (LSB-first result of
    /// width `ceil(log2(n+1))`).  This is the parallel accumulative
    /// counter's input reduction.
    pub fn popcount(&mut self, bits: &[NetId]) -> Vec<NetId> {
        // Recursive: split, add sub-counts with ripple carry.
        match bits.len() {
            0 => vec![self.zero()],
            1 => vec![bits[0]],
            2 => {
                let (s, c) = self.half_adder(bits[0], bits[1]);
                vec![s, c]
            }
            3 => {
                let (s, c) = self.full_adder(bits[0], bits[1], bits[2]);
                vec![s, c]
            }
            n => {
                let mid = n / 2;
                let mut l = self.popcount(&bits[..mid]);
                let mut r = self.popcount(&bits[mid..]);
                let w = l.len().max(r.len()) ;
                let zero = self.zero();
                l.resize(w, zero);
                r.resize(w, zero);
                let (mut s, c) = self.ripple_add(&l, &r);
                s.push(c);
                s
            }
        }
    }

    /// Unsigned comparator: `a >= b` (equal widths, LSB first), via a
    /// borrow-ripple chain: geq = NOT(borrow_out of a - b).
    pub fn geq(&mut self, a: &[NetId], b: &[NetId]) -> NetId {
        assert_eq!(a.len(), b.len());
        let mut borrow = self.zero();
        for i in 0..a.len() {
            // borrow' = (!a & b) | (!a & borrow) | (b & borrow)
            //         = maj(!a, b, borrow)
            let na = self.inv(a[i]);
            borrow = self.maj3(na, b[i], borrow);
        }
        self.inv(borrow)
    }

    /// Unsigned `a < b` (strict), LSB first.
    pub fn lt(&mut self, a: &[NetId], b: &[NetId]) -> NetId {
        let geq = self.geq(a, b);
        self.inv(geq)
    }

    /// Constant bus for `value` with `width` bits (LSB first).
    pub fn const_bus(&mut self, value: u64, width: usize) -> Vec<NetId> {
        (0..width)
            .map(|i| if (value >> i) & 1 == 1 { self.one() } else { self.zero() })
            .collect()
    }

    /// 3-bit saturating up/down counter next-state logic:
    /// `next = sat(cur + inc - dec)` with inc/dec mutually exclusive in use.
    /// Returns the 3 next-state nets.
    pub fn sat_updown3(
        &mut self,
        cur: &[NetId; 3],
        inc: NetId,
        dec: NetId,
    ) -> [NetId; 3] {
        // increment: cur + 1 (half-adder chain)
        let (i0, c0) = self.half_adder(cur[0], self.one());
        let (i1, c1) = self.half_adder(cur[1], c0);
        let i2 = self.xor2(cur[2], c1);
        let inc_ovf = self.and3(cur[0], cur[1], cur[2]); // cur == 7
        // decrement: cur - 1 (borrow chain)
        let n0 = self.inv(cur[0]);
        let d0 = n0;
        let b0 = n0;
        let d1 = self.xor2(cur[1], b0);
        let nb1 = self.inv(cur[1]);
        let b1 = self.and2(nb1, b0);
        let d2 = self.xor2(cur[2], b1);
        let nz0 = self.or3(cur[0], cur[1], cur[2]); // cur != 0
        // select: inc (not at 7) -> inc value; dec -> dec value, but an
        // asserted inc always blocks dec (matches ref.py's delta = inc-dec
        // semantics: inc&dec cancel, and inc at saturation HOLDS).
        let do_inc0 = self.inv(inc_ovf);
        let do_inc = self.and2(inc, do_inc0);
        let ninc = self.inv(inc);
        let sel_dec = self.and3(dec, nz0, ninc);
        let mut next = [self.zero(); 3];
        let incv = [i0, i1, i2];
        let decv = [d0, d1, d2];
        for k in 0..3 {
            let a = self.mux2(cur[k], incv[k], do_inc);
            next[k] = self.mux2(a, decv[k], sel_dec);
        }
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;

    fn b(lib: &Library) -> Builder<'_> {
        Builder::new("t", lib)
    }

    #[test]
    fn or_and_trees_validate() {
        let lib = Library::asap7_only();
        let mut bd = b(&lib);
        let ins = bd.input_bus("x", 9);
        let o = bd.or_tree(&ins);
        let a = bd.and_tree(&ins);
        bd.output(o, "or");
        bd.output(a, "and");
        bd.finish().unwrap();
    }

    #[test]
    fn popcount_width_is_logarithmic() {
        let lib = Library::asap7_only();
        for n in [1usize, 2, 3, 4, 7, 8, 15, 16, 64] {
            let mut bd = b(&lib);
            let ins = bd.input_bus("x", n);
            let s = bd.popcount(&ins);
            let want = (usize::BITS - n.leading_zeros()) as usize;
            assert!(
                s.len() >= want && s.len() <= want + 1,
                "n={n} width={} want~{want}",
                s.len()
            );
            for (i, &bit) in s.iter().enumerate() {
                bd.output(bit, format!("s[{i}]"));
            }
            bd.finish().unwrap();
        }
    }

    #[test]
    fn adders_and_comparators_validate() {
        let lib = Library::asap7_only();
        let mut bd = b(&lib);
        let a = bd.input_bus("a", 8);
        let c = bd.input_bus("b", 8);
        let (s, co) = bd.ripple_add(&a, &c);
        let ge = bd.geq(&a, &c);
        let lt = bd.lt(&a, &c);
        for (i, &bit) in s.iter().enumerate() {
            bd.output(bit, format!("s[{i}]"));
        }
        bd.output(co, "co");
        bd.output(ge, "ge");
        bd.output(lt, "lt");
        bd.finish().unwrap();
    }

    #[test]
    fn sat_updown_validates() {
        let lib = Library::asap7_only();
        let mut bd = b(&lib);
        let cur_v = bd.input_bus("w", 3);
        let cur = [cur_v[0], cur_v[1], cur_v[2]];
        let inc = bd.input("inc");
        let dec = bd.input("dec");
        let next = bd.sat_updown3(&cur, inc, dec);
        for (i, &n) in next.iter().enumerate() {
            bd.output(n, format!("n[{i}]"));
        }
        bd.finish().unwrap();
    }

    #[test]
    fn regions_nest() {
        let lib = Library::asap7_only();
        let mut bd = b(&lib);
        let prev = bd.push("col0");
        let prev2 = bd.push("syn0");
        let x = bd.input("x");
        let _ = bd.inv(x);
        bd.pop(prev2);
        bd.pop(prev);
        let nl = bd.finish().unwrap();
        let last = nl.insts.last().unwrap();
        assert_eq!(nl.region_path(last.region), "top/col0/syn0");
    }
}
