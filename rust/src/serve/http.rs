//! Minimal HTTP/1.1 reader/writer for the `tnn7 serve` daemon.
//!
//! No dependency budget means no hyper: this is a strict, small subset
//! — one request per connection (`Connection: close`), request line +
//! headers + optional `Content-Length` body, bounded at 1 MiB.  It is
//! deliberately not a general HTTP implementation; it parses exactly
//! what the daemon's API needs and answers everything else with a
//! structured error response.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use crate::error::{Error, Result};

/// Largest request body the daemon accepts.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Largest request head (request line + headers) the daemon accepts.
const MAX_HEAD_BYTES: usize = 16 << 10;

/// A parsed request: method, path, and the (possibly empty) body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Read one request from the stream.  The caller sets read timeouts;
/// malformed or oversized requests return structured errors the
/// connection handler converts into 400 responses.
pub fn read_request(stream: &TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);

    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| Error::runtime("empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| Error::runtime("request line has no path"))?
        .to_string();
    let version = parts
        .next()
        .ok_or_else(|| Error::runtime("request line has no version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(Error::runtime(format!(
            "unsupported protocol `{version}`"
        )));
    }

    let mut content_length = 0usize;
    let mut head_bytes = line.len();
    loop {
        let mut h = String::new();
        let n = reader.read_line(&mut h)?;
        if n == 0 {
            return Err(Error::runtime("connection closed mid-headers"));
        }
        head_bytes += n;
        if head_bytes > MAX_HEAD_BYTES {
            return Err(Error::runtime("request head too large"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse().map_err(|_| {
                        Error::runtime(format!(
                            "bad Content-Length `{}`",
                            value.trim()
                        ))
                    })?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(Error::runtime(format!(
            "request body too large ({content_length} bytes, max \
             {MAX_BODY_BYTES})"
        )));
    }

    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8(body)
        .map_err(|_| Error::runtime("request body is not UTF-8"))?;
    Ok(Request { method, path, body })
}

/// An outgoing response.  The body is `Arc`-shared so deduplicated
/// requests serve the exact same bytes without copying.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    /// Extra headers beyond the always-present Content-Type /
    /// Content-Length / Connection set.
    pub headers: Vec<(String, String)>,
    pub body: Arc<String>,
}

impl Response {
    /// A JSON response with the given status.
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: Arc::new(body.into()),
        }
    }

    /// A structured error body: `{"error": "..."}`.
    pub fn error(status: u16, msg: &str) -> Response {
        let body = crate::runtime::json::Json::obj(vec![(
            "error",
            crate::runtime::json::Json::str(msg),
        )])
        .to_string_pretty();
        Response::json(status, body)
    }

    /// Builder-style extra header.
    pub fn with_header(
        mut self,
        name: impl Into<String>,
        value: impl Into<String>,
    ) -> Response {
        self.headers.push((name.into(), value.into()));
        self
    }

    /// Serialize onto the stream.  Write errors are returned so the
    /// worker can count them, but a closed peer is not a daemon error.
    pub fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\n",
            self.status,
            status_text(self.status)
        );
        head.push_str("Content-Type: application/json\r\n");
        head.push_str(&format!(
            "Content-Length: {}\r\n",
            self.body.len()
        ));
        head.push_str("Connection: close\r\n");
        for (name, value) in &self.headers {
            head.push_str(&format!("{name}: {value}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.write_all(self.body.as_bytes())?;
        stream.flush()
    }
}

/// A one-shot HTTP client for the daemon's own API — what the
/// integration tests and the `serve_throughput` bench drive requests
/// with (no curl dependency inside the test suite).
pub fn fetch(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<FetchedResponse> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    stream.write_all(
        format!(
            "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    let mut raw = String::new();
    BufReader::new(stream).read_to_string(&mut raw)?;
    let (head, resp_body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| Error::runtime("response has no header break"))?;
    let mut lines = head.lines();
    let status_line = lines
        .next()
        .ok_or_else(|| Error::runtime("empty response"))?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            Error::runtime(format!("bad status line `{status_line}`"))
        })?;
    let headers = lines
        .filter_map(|l| {
            l.split_once(':').map(|(n, v)| {
                (n.trim().to_ascii_lowercase(), v.trim().to_string())
            })
        })
        .collect();
    Ok(FetchedResponse {
        status,
        headers,
        body: resp_body.to_string(),
    })
}

/// A response read back by [`fetch`], headers lower-cased.
#[derive(Debug, Clone)]
pub struct FetchedResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl FetchedResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Round-trip a request and response over a real local socket pair.
    #[test]
    fn parses_request_and_writes_response() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let req = read_request(&stream).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/flow");
            assert_eq!(req.body, "{\"a\":1}");
            let mut stream = stream;
            Response::json(200, "{}")
                .with_header("X-Tnn7-Cache", "executed=0 mem=6 disk=0")
                .write_to(&mut stream)
                .unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        // Header-name case must not matter.
        c.write_all(
            b"POST /flow HTTP/1.1\r\ncOnTeNt-LeNgTh: 7\r\n\r\n{\"a\":1}",
        )
        .unwrap();
        let mut reply = String::new();
        c.read_to_string(&mut reply).unwrap();
        t.join().unwrap();
        assert!(reply.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(reply.contains("X-Tnn7-Cache: executed=0 mem=6 disk=0"));
        assert!(reply.contains("Connection: close"));
        assert!(reply.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn rejects_oversized_and_malformed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        for raw in [
            format!(
                "POST /flow HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                MAX_BODY_BYTES + 1
            ),
            "POST /flow HTTP/1.1\r\nContent-Length: nope\r\n\r\n"
                .to_string(),
            "GARBAGE\r\n\r\n".to_string(),
            "GET /x SPDY/3\r\n\r\n".to_string(),
        ] {
            let t = std::thread::spawn({
                let listener = listener.try_clone().unwrap();
                move || {
                    let (stream, _) = listener.accept().unwrap();
                    read_request(&stream).is_err()
                }
            });
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(raw.as_bytes()).unwrap();
            drop(c);
            assert!(t.join().unwrap(), "request should be rejected: {raw:?}");
        }
    }
}
