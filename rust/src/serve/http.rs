//! Minimal HTTP/1.1 reader/writer for the `tnn7 serve` daemon.
//!
//! No dependency budget means no hyper: this is a strict, small subset
//! — one request per connection (`Connection: close`), request line +
//! headers + optional `Content-Length` body, bounded at 1 MiB.  It is
//! deliberately not a general HTTP implementation; it parses exactly
//! what the daemon's API needs and answers everything else with a
//! structured error response.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// Largest request body the daemon accepts.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Largest request head (request line + headers) the daemon accepts.
const MAX_HEAD_BYTES: usize = 16 << 10;

/// Hard wall-clock budget for reading one request.  The per-read
/// socket timeout bounds each syscall; this bounds the whole parse, so
/// a client trickling one header byte per poll (slow-loris) cannot pin
/// a worker for more than this long in total.
pub const REQUEST_DEADLINE: Duration = Duration::from_secs(30);

/// A parsed request: method, path, and the (possibly empty) body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// A request-parse failure carrying the HTTP status the daemon should
/// answer with: 413 for oversized bodies, 408 for a blown request
/// deadline, 400 for everything else.
#[derive(Debug)]
pub struct ParseError {
    pub status: u16,
    pub msg: String,
}

impl ParseError {
    fn bad(msg: impl Into<String>) -> ParseError {
        ParseError { status: 400, msg: msg.into() }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} ({})", self.msg, self.status)
    }
}

/// Map an I/O failure mid-request: timeouts become a 408 so the
/// client can tell "you were too slow" from "you were malformed".
fn io_parse_error(e: std::io::Error) -> ParseError {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => ParseError {
            status: 408,
            msg: "timed out reading request".to_string(),
        },
        _ => ParseError::bad(format!("read failed: {e}")),
    }
}

/// Read one request from the stream.  The caller sets per-read socket
/// timeouts; this function additionally enforces [`REQUEST_DEADLINE`]
/// across the whole parse.  Errors carry the response status
/// (400/408/413) the connection handler should answer with.
pub fn read_request(
    stream: &TcpStream,
) -> std::result::Result<Request, ParseError> {
    let deadline = Instant::now() + REQUEST_DEADLINE;
    let mut reader = BufReader::new(
        stream
            .try_clone()
            .map_err(|e| ParseError::bad(format!("clone failed: {e}")))?,
    );

    let mut line = String::new();
    reader.read_line(&mut line).map_err(io_parse_error)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ParseError::bad("empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| ParseError::bad("request line has no path"))?
        .to_string();
    let version = parts
        .next()
        .ok_or_else(|| ParseError::bad("request line has no version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(ParseError::bad(format!(
            "unsupported protocol `{version}`"
        )));
    }

    let timed_out = || ParseError {
        status: 408,
        msg: "request deadline exceeded".to_string(),
    };
    let mut content_length = 0usize;
    let mut head_bytes = line.len();
    loop {
        if Instant::now() >= deadline {
            return Err(timed_out());
        }
        let mut h = String::new();
        let n = reader.read_line(&mut h).map_err(io_parse_error)?;
        if n == 0 {
            return Err(ParseError::bad("connection closed mid-headers"));
        }
        head_bytes += n;
        if head_bytes > MAX_HEAD_BYTES {
            return Err(ParseError::bad("request head too large"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse().map_err(|_| {
                        ParseError::bad(format!(
                            "bad Content-Length `{}`",
                            value.trim()
                        ))
                    })?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(ParseError {
            status: 413,
            msg: format!(
                "request body too large ({content_length} bytes, max \
                 {MAX_BODY_BYTES})"
            ),
        });
    }

    // Read the body in bounded chunks with the deadline re-checked
    // between reads — a single `read_exact` would let a trickling
    // client stretch one request across many per-read timeouts.
    let mut body = vec![0u8; content_length];
    let mut filled = 0usize;
    while filled < content_length {
        if Instant::now() >= deadline {
            return Err(timed_out());
        }
        let n = reader
            .read(&mut body[filled..])
            .map_err(io_parse_error)?;
        if n == 0 {
            return Err(ParseError::bad("connection closed mid-body"));
        }
        filled += n;
    }
    let body = String::from_utf8(body)
        .map_err(|_| ParseError::bad("request body is not UTF-8"))?;
    Ok(Request { method, path, body })
}

/// An outgoing response.  The body is `Arc`-shared so deduplicated
/// requests serve the exact same bytes without copying.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    /// Extra headers beyond the always-present Content-Type /
    /// Content-Length / Connection set.
    pub headers: Vec<(String, String)>,
    /// Content-Type header value.
    pub content_type: &'static str,
    pub body: Arc<String>,
}

impl Response {
    /// A JSON response with the given status.
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            headers: Vec::new(),
            content_type: "application/json",
            body: Arc::new(body.into()),
        }
    }

    /// A plain-text response (Prometheus exposition format).
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            headers: Vec::new(),
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: Arc::new(body.into()),
        }
    }

    /// A structured error body: `{"error": "..."}`.
    pub fn error(status: u16, msg: &str) -> Response {
        let body = crate::runtime::json::Json::obj(vec![(
            "error",
            crate::runtime::json::Json::str(msg),
        )])
        .to_string_pretty();
        Response::json(status, body)
    }

    /// Builder-style extra header.
    pub fn with_header(
        mut self,
        name: impl Into<String>,
        value: impl Into<String>,
    ) -> Response {
        self.headers.push((name.into(), value.into()));
        self
    }

    /// Serialize onto the stream.  Write errors are returned so the
    /// worker can count them, but a closed peer is not a daemon error.
    pub fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\n",
            self.status,
            status_text(self.status)
        );
        head.push_str(&format!(
            "Content-Type: {}\r\n",
            self.content_type
        ));
        head.push_str(&format!(
            "Content-Length: {}\r\n",
            self.body.len()
        ));
        head.push_str("Connection: close\r\n");
        for (name, value) in &self.headers {
            head.push_str(&format!("{name}: {value}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.write_all(self.body.as_bytes())?;
        stream.flush()
    }
}

/// A one-shot HTTP client for the daemon's own API — what the
/// integration tests and the `serve_throughput` bench drive requests
/// with (no curl dependency inside the test suite).
pub fn fetch(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<FetchedResponse> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    stream.write_all(
        format!(
            "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    let mut raw = String::new();
    BufReader::new(stream).read_to_string(&mut raw)?;
    let (head, resp_body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| Error::runtime("response has no header break"))?;
    let mut lines = head.lines();
    let status_line = lines
        .next()
        .ok_or_else(|| Error::runtime("empty response"))?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            Error::runtime(format!("bad status line `{status_line}`"))
        })?;
    let headers = lines
        .filter_map(|l| {
            l.split_once(':').map(|(n, v)| {
                (n.trim().to_ascii_lowercase(), v.trim().to_string())
            })
        })
        .collect();
    Ok(FetchedResponse {
        status,
        headers,
        body: resp_body.to_string(),
    })
}

/// Bounded retry policy for [`fetch_with_retry`]: exponential backoff
/// with deterministic jitter.  Retries fire on connect/read errors and
/// on 5xx/429 responses; a `Retry-After: N` header from the server
/// overrides the computed backoff (capped at `max_delay_ms`).
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts (first try included).  1 = no retries.
    pub attempts: u32,
    /// Backoff before retry k is `base_delay_ms << (k-1)`, jittered.
    pub base_delay_ms: u64,
    /// Ceiling on any single backoff sleep.
    pub max_delay_ms: u64,
    /// Seed for the deterministic jitter stream, so test runs and
    /// benchmark sweeps reproduce their exact retry timing.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 4,
            base_delay_ms: 50,
            max_delay_ms: 2_000,
            jitter_seed: 0x7ee1,
        }
    }
}

impl RetryPolicy {
    /// The sleep before retry `attempt` (1-based), honoring a server
    /// `Retry-After` (seconds) when one was sent.
    fn delay(&self, attempt: u32, retry_after_s: Option<u64>) -> Duration {
        let backoff = self
            .base_delay_ms
            .saturating_mul(1u64 << (attempt - 1).min(16))
            .min(self.max_delay_ms);
        // xorshift64 over (seed, attempt): full jitter in [0, backoff].
        let mut x = self.jitter_seed ^ (u64::from(attempt) << 32) | 1;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let jittered = if backoff == 0 { 0 } else { x % (backoff + 1) };
        let ms = match retry_after_s {
            Some(s) => s.saturating_mul(1_000).min(self.max_delay_ms),
            None => jittered,
        };
        Duration::from_millis(ms)
    }
}

/// [`fetch`] wrapped in the bounded [`RetryPolicy`]: transient connect
/// failures (daemon still binding, listener backlog) and 5xx/429
/// responses are retried with backoff; any other response returns
/// immediately.  The last attempt's outcome — response or error — is
/// returned as-is, so callers still see the terminal status.
pub fn fetch_with_retry(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
    policy: &RetryPolicy,
) -> Result<FetchedResponse> {
    let attempts = policy.attempts.max(1);
    let mut last_err: Option<Error> = None;
    for attempt in 1..=attempts {
        let retry_after_s = match fetch(addr, method, path, body) {
            Ok(resp) => {
                let transient =
                    resp.status >= 500 || resp.status == 429;
                if !transient || attempt == attempts {
                    return Ok(resp);
                }
                resp.header("retry-after")
                    .and_then(|v| v.trim().parse::<u64>().ok())
            }
            Err(e) => {
                if attempt == attempts {
                    return Err(e);
                }
                last_err = Some(e);
                None
            }
        };
        std::thread::sleep(policy.delay(attempt, retry_after_s));
    }
    // Unreachable: the loop always returns on its final attempt.
    Err(last_err
        .unwrap_or_else(|| Error::runtime("retry budget exhausted")))
}

/// A response read back by [`fetch`], headers lower-cased.
#[derive(Debug, Clone)]
pub struct FetchedResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl FetchedResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Round-trip a request and response over a real local socket pair.
    #[test]
    fn parses_request_and_writes_response() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let req = read_request(&stream).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/flow");
            assert_eq!(req.body, "{\"a\":1}");
            let mut stream = stream;
            Response::json(200, "{}")
                .with_header("X-Tnn7-Cache", "executed=0 mem=6 disk=0")
                .write_to(&mut stream)
                .unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        // Header-name case must not matter.
        c.write_all(
            b"POST /flow HTTP/1.1\r\ncOnTeNt-LeNgTh: 7\r\n\r\n{\"a\":1}",
        )
        .unwrap();
        let mut reply = String::new();
        c.read_to_string(&mut reply).unwrap();
        t.join().unwrap();
        assert!(reply.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(reply.contains("X-Tnn7-Cache: executed=0 mem=6 disk=0"));
        assert!(reply.contains("Connection: close"));
        assert!(reply.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn rejects_oversized_and_malformed_with_statuses() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        for (raw, want_status) in [
            (
                format!(
                    "POST /flow HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                    MAX_BODY_BYTES + 1
                ),
                413,
            ),
            (
                "POST /flow HTTP/1.1\r\nContent-Length: nope\r\n\r\n"
                    .to_string(),
                400,
            ),
            ("GARBAGE\r\n\r\n".to_string(), 400),
            ("GET /x SPDY/3\r\n\r\n".to_string(), 400),
        ] {
            let t = std::thread::spawn({
                let listener = listener.try_clone().unwrap();
                move || {
                    let (stream, _) = listener.accept().unwrap();
                    read_request(&stream).err().map(|e| e.status)
                }
            });
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(raw.as_bytes()).unwrap();
            drop(c);
            assert_eq!(
                t.join().unwrap(),
                Some(want_status),
                "request should be rejected: {raw:?}"
            );
        }
    }

    /// The retry client climbs through a transient 503 (honoring its
    /// Retry-After) and returns the eventual 200.
    #[test]
    fn fetch_retries_through_transient_503() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            for status in [503u16, 200] {
                let (stream, _) = listener.accept().unwrap();
                let _ = read_request(&stream);
                let mut stream = stream;
                let resp = if status == 503 {
                    Response::error(503, "warming up")
                        .with_header("Retry-After", "0")
                } else {
                    Response::json(200, "{\"ok\":true}")
                };
                resp.write_to(&mut stream).unwrap();
            }
        });
        let policy = RetryPolicy {
            attempts: 3,
            base_delay_ms: 1,
            max_delay_ms: 5,
            jitter_seed: 9,
        };
        let resp =
            fetch_with_retry(addr, "GET", "/healthz", "", &policy)
                .unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.body.contains("ok"));
        t.join().unwrap();
    }

    /// Exhausting the budget against a dead address is an error, not a
    /// hang; non-transient statuses return without retries.
    #[test]
    fn fetch_retry_terminal_outcomes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let policy = RetryPolicy {
            attempts: 2,
            base_delay_ms: 1,
            max_delay_ms: 2,
            jitter_seed: 1,
        };
        assert!(
            fetch_with_retry(addr, "GET", "/healthz", "", &policy)
                .is_err()
        );

        // 404 is not transient: exactly one connection is consumed.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = read_request(&stream);
            let mut stream = stream;
            Response::error(404, "nope").write_to(&mut stream).unwrap();
            // A second accept would block forever; the listener drops
            // here, so a retry attempt would fail the test via Err.
        });
        let resp =
            fetch_with_retry(addr, "GET", "/missing", "", &policy)
                .unwrap();
        assert_eq!(resp.status, 404);
        t.join().unwrap();
    }

    /// Backoff is deterministic for a fixed seed and honors
    /// Retry-After over the jittered schedule.
    #[test]
    fn retry_delay_is_deterministic_and_bounded() {
        let policy = RetryPolicy {
            attempts: 4,
            base_delay_ms: 50,
            max_delay_ms: 2_000,
            jitter_seed: 0x7ee1,
        };
        for attempt in 1..=3 {
            let a = policy.delay(attempt, None);
            let b = policy.delay(attempt, None);
            assert_eq!(a, b);
            let cap = policy
                .base_delay_ms
                .saturating_mul(1 << (attempt - 1))
                .min(policy.max_delay_ms);
            assert!(a <= std::time::Duration::from_millis(cap));
        }
        // Retry-After wins, capped at max_delay_ms.
        assert_eq!(
            policy.delay(1, Some(1)),
            std::time::Duration::from_millis(1_000)
        );
        assert_eq!(
            policy.delay(1, Some(3_600)),
            std::time::Duration::from_millis(policy.max_delay_ms)
        );
    }
}
