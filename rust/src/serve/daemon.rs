//! The `tnn7 serve` daemon: a persistent flow service over a bounded
//! worker pool, with content-addressed stage caching and in-flight
//! request deduplication (DESIGN.md §11).
//!
//! Architecture (std-only, no async runtime):
//!
//! ```text
//!  accept thread ──try_send──► bounded queue ──► N worker threads
//!       │ (full ⇒ inline 503 + Retry-After)        │
//!       │                                          ├─ parse + route
//!       └─ polls the shutdown flag                 ├─ dedup map (join
//!                                                  │  identical in-flight
//!                                                  │  queries)
//!                                                  └─ Flow::run_cached
//!                                                     against the shared
//!                                                     StageCache
//! ```
//!
//! Shutdown is graceful by construction: the accept thread stops
//! accepting and drops the queue sender; workers drain every request
//! already queued, then exit when the channel closes.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::TnnConfig;
use crate::data::Dataset;
use crate::error::Result;
use crate::flow::cache::{CacheConfig, StageCache};
use crate::flow::{Flow, FlowContext};
use crate::obs::{Counter, Gauge};
use crate::runtime::json::Json;
use crate::tech::TechRegistry;

use super::api::FlowQuery;
use super::http::{read_request, Request, Response};

/// How often the accept loop polls the shutdown flag.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Per-connection socket read timeout (a stalled client must not pin a
/// worker forever).  [`super::http::REQUEST_DEADLINE`] additionally
/// bounds the whole request parse across reads.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// Per-connection socket write timeout: a client that stops draining
/// its receive window mid-response costs a worker at most this long
/// before the write errors out and the connection is dropped.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Daemon construction parameters (the `[serve]`/`[cache]` config
/// sections plus CLI overrides).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port — the test
    /// and bench idiom).
    pub addr: String,
    /// Worker threads; each runs one request at a time.
    pub threads: usize,
    /// Bounded request queue depth; overflow answers 503 inline.
    pub queue: usize,
    /// Stage-cache sizing (memory tier + optional disk tier).
    pub cache: CacheConfig,
    /// Test hook: hold each *leader* `/flow` request this long before
    /// running the flow, so concurrent duplicates deterministically
    /// pile onto the dedup map.  0 (the default) in production.
    pub debug_flow_delay_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let d = TnnConfig::default();
        ServeConfig {
            addr: d.serve_addr,
            threads: d.serve_threads,
            queue: d.serve_queue,
            cache: CacheConfig::default(),
            debug_flow_delay_ms: 0,
        }
    }
}

impl ServeConfig {
    /// Daemon settings from a parsed config file (`[serve]` and
    /// `[cache]` sections; the daemon always caches, so `cache.dir`
    /// simply adds the disk tier).
    pub fn from_config(cfg: &TnnConfig) -> ServeConfig {
        ServeConfig {
            addr: cfg.serve_addr.clone(),
            threads: cfg.serve_threads,
            queue: cfg.serve_queue,
            cache: CacheConfig {
                mem_entries: cfg.cache_mem_entries,
                dir: if cfg.cache_dir.is_empty() {
                    None
                } else {
                    Some(cfg.cache_dir.clone().into())
                },
            },
            debug_flow_delay_ms: 0,
        }
    }
}

/// One in-flight `/flow` computation followers can join: the leader
/// fills `slot` and broadcasts on `cv`.
struct InFlight {
    slot: Mutex<Option<Response>>,
    cv: Condvar,
}

/// Shared daemon state: tech registry, cache, dedup map, and the
/// per-daemon metrics registry.
///
/// Every counter the daemon exposes lives in `obs` — `/stats` is a
/// JSON *view* over the same registry `/metrics` renders, so the two
/// exposures cannot drift (the pre-registry daemon kept a private
/// duplicate counter set that did).  Hot-path handles are registered
/// once at spawn and shared by the workers.
struct ServerState {
    registry: TechRegistry,
    /// The daemon's metrics registry — the single source of truth for
    /// `/stats` and `/metrics`.  Per-daemon (not the process global),
    /// so concurrent daemons in one test process stay isolated.
    obs: Arc<crate::obs::Registry>,
    cache: StageCache,
    /// Stimulus datasets by (sample count, seed) — generated once,
    /// shared by every worker (mirrors [`FlowContext::new`]).
    datasets: Mutex<HashMap<(usize, u64), Arc<Dataset>>>,
    inflight: Mutex<HashMap<u64, Arc<InFlight>>>,
    shutdown: AtomicBool,
    requests: Arc<Counter>,
    flow_runs: Arc<Counter>,
    errors: Arc<Counter>,
    overloads: Arc<Counter>,
    /// Responses cut off by the write timeout (client stopped reading).
    stalled_writes: Arc<Counter>,
    dedup_joins: Arc<Counter>,
    flow_micros: Arc<Counter>,
    /// Connections accepted but not yet picked up by a worker.
    queue_depth: Arc<Gauge>,
    debug_flow_delay_ms: u64,
}

impl ServerState {
    fn count_engine(&self, query: &FlowQuery) {
        self.obs
            .counter(
                "tnn7_serve_engine_requests_total",
                "Flow requests by requested engine kind (dedup joins \
                 included)",
                &[("engine", query.engine.as_str())],
            )
            .inc();
        let canonical = crate::ir::PassManager::parse(&query.passes)
            .map(|pm| pm.canonical())
            .unwrap_or_else(|_| query.passes.clone());
        self.obs
            .counter(
                "tnn7_serve_pass_requests_total",
                "Flow requests by canonical pass pipeline",
                &[("passes", canonical.as_str())],
            )
            .inc();
    }

    /// Count one routed request against its endpoint and record its
    /// handling latency.
    fn observe_endpoint(&self, path: &str, micros: u64) {
        let endpoint = match path {
            "/flow" | "/stats" | "/healthz" | "/metrics"
            | "/shutdown" => path,
            _ => "other",
        };
        self.obs
            .counter(
                "tnn7_serve_endpoint_requests_total",
                "Requests routed, by endpoint",
                &[("endpoint", endpoint)],
            )
            .inc();
        self.obs
            .histogram(
                "tnn7_serve_request_micros",
                "Request handling latency, microseconds",
                &[("endpoint", endpoint)],
            )
            .observe(micros);
    }

    /// Collapse a labeled counter family into `{label_value: count}`.
    fn label_map(&self, name: &str, label: &str) -> Json {
        Json::Obj(
            self.obs
                .counter_series(name)
                .into_iter()
                .filter_map(|(labels, v)| {
                    labels
                        .into_iter()
                        .find(|(k, _)| k == label)
                        .map(|(_, lv)| (lv, Json::int(v)))
                })
                .collect(),
        )
    }

    /// The `/stats` body, derived entirely from the metrics registry
    /// (plus the two pieces of live state that are not counters: the
    /// in-flight dedup map and the shutdown flag).
    fn stats_json(&self) -> Json {
        let mut stages: std::collections::BTreeMap<String, (u64, u64)> =
            std::collections::BTreeMap::new();
        for (labels, v) in
            self.obs.counter_series("tnn7_flow_stage_runs_total")
        {
            if let Some((_, s)) =
                labels.into_iter().find(|(k, _)| k == "stage")
            {
                stages.entry(s).or_insert((0, 0)).0 = v;
            }
        }
        for (labels, v) in
            self.obs.counter_series("tnn7_flow_stage_micros_total")
        {
            if let Some((_, s)) =
                labels.into_iter().find(|(k, _)| k == "stage")
            {
                stages.entry(s).or_insert((0, 0)).1 = v;
            }
        }
        let stages = Json::Obj(
            stages
                .into_iter()
                .map(|(name, (runs, micros))| {
                    (
                        name,
                        Json::obj(vec![
                            ("runs", Json::int(runs)),
                            ("micros_total", Json::int(micros)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("requests", Json::int(self.requests.get())),
            ("flow_requests", Json::int(self.flow_runs.get())),
            ("errors", Json::int(self.errors.get())),
            ("overloads", Json::int(self.overloads.get())),
            ("stalled_writes", Json::int(self.stalled_writes.get())),
            ("dedup_joins", Json::int(self.dedup_joins.get())),
            ("flow_micros_total", Json::int(self.flow_micros.get())),
            ("stages", stages),
            (
                "engine_requests",
                self.label_map(
                    "tnn7_serve_engine_requests_total",
                    "engine",
                ),
            ),
            (
                "pass_requests",
                self.label_map(
                    "tnn7_serve_pass_requests_total",
                    "passes",
                ),
            ),
            ("cache", self.cache.stats_json()),
            (
                "inflight",
                Json::int(self.inflight.lock().unwrap().len() as u64),
            ),
            (
                "shutting_down",
                Json::Bool(self.shutdown.load(Ordering::SeqCst)),
            ),
        ])
    }
}

/// The daemon entry point: [`Server::spawn`] binds, starts the worker
/// pool, and returns a [`ServerHandle`] for the caller to await.
pub struct Server;

impl Server {
    /// Bind `cfg.addr`, start the accept loop and worker pool, and
    /// return immediately.  The CLI calls this and then
    /// [`ServerHandle::join`]; tests and benches keep the handle to
    /// query the ephemeral port and trigger shutdown.
    pub fn spawn(cfg: ServeConfig) -> Result<ServerHandle> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;

        let obs = Arc::new(crate::obs::Registry::new());
        let state = Arc::new(ServerState {
            registry: TechRegistry::builtin(),
            cache: StageCache::with_registry(cfg.cache.clone(), &obs),
            datasets: Mutex::new(HashMap::new()),
            inflight: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
            requests: obs.counter(
                "tnn7_serve_requests_total",
                "Connections handled by the worker pool",
                &[],
            ),
            flow_runs: obs.counter(
                "tnn7_serve_flow_runs_total",
                "Flow executions run by dedup leaders",
                &[],
            ),
            errors: obs.counter(
                "tnn7_serve_errors_total",
                "Responses with status >= 400",
                &[],
            ),
            overloads: obs.counter(
                "tnn7_serve_overloads_total",
                "Connections refused with 503 (request queue full)",
                &[],
            ),
            stalled_writes: obs.counter(
                "tnn7_serve_stalled_writes_total",
                "Responses cut off by the write timeout",
                &[],
            ),
            dedup_joins: obs.counter(
                "tnn7_serve_dedup_joins_total",
                "Flow requests joined onto an identical in-flight query",
                &[],
            ),
            flow_micros: obs.counter(
                "tnn7_serve_flow_micros_total",
                "Cumulative leader flow wall time, microseconds",
                &[],
            ),
            queue_depth: obs.gauge(
                "tnn7_serve_queue_depth",
                "Accepted connections waiting for a worker",
                &[],
            ),
            obs,
            debug_flow_delay_ms: cfg.debug_flow_delay_ms,
        });

        let (tx, rx) = mpsc::sync_channel::<TcpStream>(cfg.queue.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..cfg.threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let state = Arc::clone(&state);
                std::thread::spawn(move || worker_loop(&rx, &state))
            })
            .collect();
        let accept = {
            let state = Arc::clone(&state);
            std::thread::spawn(move || accept_loop(&listener, tx, &state))
        };
        Ok(ServerHandle { addr, state, accept, workers })
    }
}

/// A running daemon: its bound address and the threads to await.
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
    accept: JoinHandle<()>,
    workers: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown (same effect as `POST /shutdown`): stop
    /// accepting, drain queued work, exit.
    pub fn shutdown(&self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
    }

    /// Block until the accept loop and every worker have exited.
    pub fn join(self) {
        let _ = self.accept.join();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    tx: SyncSender<TcpStream>,
    state: &ServerState,
) {
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                match tx.try_send(stream) {
                    Ok(()) => state.queue_depth.inc(),
                    Err(TrySendError::Full(mut stream)) => {
                        // Bounded-queue overflow: answer on the accept
                        // thread so the client gets a structured 503
                        // instead of an unexplained stall.
                        state.overloads.inc();
                        let _ = Response::error(
                            503,
                            "request queue is full, retry shortly",
                        )
                        .with_header("Retry-After", "1")
                        .write_to(&mut stream);
                    }
                    Err(TrySendError::Disconnected(_)) => break,
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    // Dropping `tx` here closes the queue: workers finish what is
    // already queued, then exit — the graceful drain.
}

fn worker_loop(rx: &Mutex<Receiver<TcpStream>>, state: &ServerState) {
    loop {
        let conn = rx.lock().unwrap().recv();
        match conn {
            Ok(mut stream) => {
                state.queue_depth.dec();
                state.requests.inc();
                let resp = match read_request(&stream) {
                    Ok(req) => {
                        let t0 = Instant::now();
                        let resp = route(state, &req);
                        state.observe_endpoint(
                            &req.path,
                            t0.elapsed().as_micros() as u64,
                        );
                        resp
                    }
                    // Parse errors carry their status: 413 for an
                    // oversized body, 408 for a blown deadline, 400
                    // for malformed requests.
                    Err(e) => Response::error(e.status, &e.msg),
                };
                if resp.status >= 400 {
                    state.errors.inc();
                }
                if let Err(e) = resp.write_to(&mut stream) {
                    use std::io::ErrorKind;
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut
                    ) {
                        // The write timeout fired: a stalled client
                        // was cut off rather than pinning the worker.
                        state.stalled_writes.inc();
                    }
                }
            }
            Err(_) => break, // channel closed: shutdown drain complete
        }
    }
}

fn route(state: &ServerState, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(
            200,
            Json::obj(vec![("status", Json::str("ok"))])
                .to_string_pretty(),
        ),
        ("GET", "/stats") => {
            Response::json(200, state.stats_json().to_string_pretty())
        }
        ("GET", "/metrics") => {
            Response::text(200, state.obs.prometheus_text())
        }
        ("POST", "/shutdown") => {
            state.shutdown.store(true, Ordering::SeqCst);
            Response::json(
                200,
                Json::obj(vec![(
                    "status",
                    Json::str("draining and shutting down"),
                )])
                .to_string_pretty(),
            )
        }
        ("POST", "/flow") => handle_flow(state, &req.body),
        ("GET" | "POST", path) => Response::error(
            404,
            &format!(
                "unknown path `{path}` (POST /flow, GET /stats, \
                 GET /metrics, GET /healthz, POST /shutdown)"
            ),
        ),
        (method, _) => Response::error(
            405,
            &format!("unsupported method `{method}`"),
        ),
    }
}

fn handle_flow(state: &ServerState, body: &str) -> Response {
    let query = match FlowQuery::parse(body, &state.registry) {
        Ok(q) => q,
        Err(e) => return Response::error(400, &e.to_string()),
    };
    state.count_engine(&query);
    let fp = query.fingerprint();

    // Dedup: one leader computes, identical concurrent queries join
    // and receive the exact same response (same body Arc).
    let (inflight, leader) = {
        let mut map = state.inflight.lock().unwrap();
        match map.get(&fp) {
            Some(inf) => (Arc::clone(inf), false),
            None => {
                let inf = Arc::new(InFlight {
                    slot: Mutex::new(None),
                    cv: Condvar::new(),
                });
                map.insert(fp, Arc::clone(&inf));
                (inf, true)
            }
        }
    };

    if !leader {
        state.dedup_joins.inc();
        let mut slot = inflight.slot.lock().unwrap();
        while slot.is_none() {
            slot = inflight.cv.wait(slot).unwrap();
        }
        return slot
            .clone()
            .expect("slot filled before broadcast")
            .with_header("X-Tnn7-Dedup", "joined");
    }

    if state.debug_flow_delay_ms > 0 {
        std::thread::sleep(Duration::from_millis(
            state.debug_flow_delay_ms,
        ));
    }
    // A panicking flow must still wake followers (with a 500), never
    // leave them blocked on the condvar.
    let resp = catch_unwind(AssertUnwindSafe(|| run_flow(state, &query)))
        .unwrap_or_else(|_| {
            Response::error(500, "flow execution panicked")
        });
    {
        let mut slot = inflight.slot.lock().unwrap();
        *slot = Some(resp.clone());
        inflight.cv.notify_all();
    }
    state.inflight.lock().unwrap().remove(&fp);
    resp.with_header("X-Tnn7-Dedup", "leader")
}

fn run_flow(state: &ServerState, query: &FlowQuery) -> Response {
    state.flow_runs.inc();
    let mut sp = crate::obs::span("serve.flow");
    sp.attr("tech", &query.tech);
    sp.attr("engine", &query.engine);
    let cfg = query.config();
    let tech = match state.registry.get(&query.tech) {
        Ok(t) => t,
        Err(e) => return Response::error(500, &e.to_string()),
    };
    let data = {
        let key = (cfg.sim_waves.max(4), cfg.data_seed);
        let mut sets = state.datasets.lock().unwrap();
        Arc::clone(sets.entry(key).or_insert_with(|| {
            Arc::new(Dataset::generate(key.0, key.1))
        }))
    };
    let mut ctx =
        FlowContext::with_tech(query.target(), cfg.clone(), tech, data);
    // Point the flow's stage accounting at this daemon's registry, so
    // per-stage runs/micros land next to the serve counters.
    ctx.obs = Arc::clone(&state.obs);
    let trace = match Flow::measurement_for(&cfg)
        .run_cached(&mut ctx, Some(&state.cache))
    {
        Ok(t) => t,
        Err(e) => return Response::error(500, &e.to_string()),
    };
    state.flow_micros.add(sp.finish_micros() as u64);
    let Some(body) = trace.dump_for("report") else {
        return Response::error(
            500,
            "flow produced no report artifact",
        );
    };
    Response {
        status: 200,
        headers: Vec::new(),
        content_type: "application/json",
        body,
    }
    .with_header("X-Tnn7-Cache", trace.cache_line())
}
