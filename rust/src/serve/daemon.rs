//! The `tnn7 serve` daemon: a persistent flow service over a bounded
//! worker pool, with content-addressed stage caching and in-flight
//! request deduplication (DESIGN.md §11).
//!
//! Architecture (std-only, no async runtime):
//!
//! ```text
//!  accept thread ──try_send──► bounded queue ──► N worker threads
//!       │ (full ⇒ inline 503 + Retry-After)        │
//!       │                                          ├─ parse + route
//!       └─ polls the shutdown flag                 ├─ dedup map (join
//!                                                  │  identical in-flight
//!                                                  │  queries)
//!                                                  └─ Flow::run_cached
//!                                                     against the shared
//!                                                     StageCache
//! ```
//!
//! Shutdown is graceful by construction: the accept thread stops
//! accepting and drops the queue sender; workers drain every request
//! already queued, then exit when the channel closes.

use std::collections::{BTreeMap, HashMap};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::TnnConfig;
use crate::data::Dataset;
use crate::error::Result;
use crate::flow::cache::{CacheConfig, StageCache};
use crate::flow::{Flow, FlowContext};
use crate::runtime::json::Json;
use crate::tech::TechRegistry;

use super::api::FlowQuery;
use super::http::{read_request, Request, Response};

/// How often the accept loop polls the shutdown flag.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Per-connection socket read timeout (a stalled client must not pin a
/// worker forever).  [`super::http::REQUEST_DEADLINE`] additionally
/// bounds the whole request parse across reads.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// Per-connection socket write timeout: a client that stops draining
/// its receive window mid-response costs a worker at most this long
/// before the write errors out and the connection is dropped.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Daemon construction parameters (the `[serve]`/`[cache]` config
/// sections plus CLI overrides).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port — the test
    /// and bench idiom).
    pub addr: String,
    /// Worker threads; each runs one request at a time.
    pub threads: usize,
    /// Bounded request queue depth; overflow answers 503 inline.
    pub queue: usize,
    /// Stage-cache sizing (memory tier + optional disk tier).
    pub cache: CacheConfig,
    /// Test hook: hold each *leader* `/flow` request this long before
    /// running the flow, so concurrent duplicates deterministically
    /// pile onto the dedup map.  0 (the default) in production.
    pub debug_flow_delay_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let d = TnnConfig::default();
        ServeConfig {
            addr: d.serve_addr,
            threads: d.serve_threads,
            queue: d.serve_queue,
            cache: CacheConfig::default(),
            debug_flow_delay_ms: 0,
        }
    }
}

impl ServeConfig {
    /// Daemon settings from a parsed config file (`[serve]` and
    /// `[cache]` sections; the daemon always caches, so `cache.dir`
    /// simply adds the disk tier).
    pub fn from_config(cfg: &TnnConfig) -> ServeConfig {
        ServeConfig {
            addr: cfg.serve_addr.clone(),
            threads: cfg.serve_threads,
            queue: cfg.serve_queue,
            cache: CacheConfig {
                mem_entries: cfg.cache_mem_entries,
                dir: if cfg.cache_dir.is_empty() {
                    None
                } else {
                    Some(cfg.cache_dir.clone().into())
                },
            },
            debug_flow_delay_ms: 0,
        }
    }
}

/// One in-flight `/flow` computation followers can join: the leader
/// fills `slot` and broadcasts on `cv`.
struct InFlight {
    slot: Mutex<Option<Response>>,
    cv: Condvar,
}

/// Shared daemon state: registry, cache, dedup map, counters.
struct ServerState {
    registry: TechRegistry,
    cache: StageCache,
    /// Stimulus datasets by (sample count, seed) — generated once,
    /// shared by every worker (mirrors [`FlowContext::new`]).
    datasets: Mutex<HashMap<(usize, u64), Arc<Dataset>>>,
    inflight: Mutex<HashMap<u64, Arc<InFlight>>>,
    shutdown: AtomicBool,
    requests: AtomicU64,
    flow_requests: AtomicU64,
    errors: AtomicU64,
    overloads: AtomicU64,
    /// Responses cut off by the write timeout (client stopped reading).
    stalled_writes: AtomicU64,
    dedup_joins: AtomicU64,
    flow_micros: AtomicU64,
    /// Per-stage (runs, total µs) aggregates across all requests.
    stage_times: Mutex<BTreeMap<&'static str, (u64, u64)>>,
    /// Requests per requested engine kind (`auto`/`scalar`/`packed`/
    /// `compiled`), counting dedup joins too — what clients asked for.
    engine_requests: Mutex<BTreeMap<String, u64>>,
    /// Requests per canonical pass pipeline (so `all` and the
    /// spelled-out list aggregate into one row).
    pass_requests: Mutex<BTreeMap<String, u64>>,
    debug_flow_delay_ms: u64,
}

impl ServerState {
    fn count_engine(&self, query: &FlowQuery) {
        *self
            .engine_requests
            .lock()
            .unwrap()
            .entry(query.engine.clone())
            .or_insert(0) += 1;
        let canonical = crate::ir::PassManager::parse(&query.passes)
            .map(|pm| pm.canonical())
            .unwrap_or_else(|_| query.passes.clone());
        *self
            .pass_requests
            .lock()
            .unwrap()
            .entry(canonical)
            .or_insert(0) += 1;
    }

    fn stats_json(&self) -> Json {
        let count_map = |m: &Mutex<BTreeMap<String, u64>>| {
            Json::Obj(
                m.lock()
                    .unwrap()
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::int(*v)))
                    .collect(),
            )
        };
        let stages = {
            let times = self.stage_times.lock().unwrap();
            Json::Obj(
                times
                    .iter()
                    .map(|(name, (runs, micros))| {
                        (
                            name.to_string(),
                            Json::obj(vec![
                                ("runs", Json::int(*runs)),
                                ("micros_total", Json::int(*micros)),
                            ]),
                        )
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            (
                "requests",
                Json::int(self.requests.load(Ordering::Relaxed)),
            ),
            (
                "flow_requests",
                Json::int(self.flow_requests.load(Ordering::Relaxed)),
            ),
            ("errors", Json::int(self.errors.load(Ordering::Relaxed))),
            (
                "overloads",
                Json::int(self.overloads.load(Ordering::Relaxed)),
            ),
            (
                "stalled_writes",
                Json::int(self.stalled_writes.load(Ordering::Relaxed)),
            ),
            (
                "dedup_joins",
                Json::int(self.dedup_joins.load(Ordering::Relaxed)),
            ),
            (
                "flow_micros_total",
                Json::int(self.flow_micros.load(Ordering::Relaxed)),
            ),
            ("stages", stages),
            ("engine_requests", count_map(&self.engine_requests)),
            ("pass_requests", count_map(&self.pass_requests)),
            ("cache", self.cache.stats_json()),
            (
                "inflight",
                Json::int(self.inflight.lock().unwrap().len() as u64),
            ),
            (
                "shutting_down",
                Json::Bool(self.shutdown.load(Ordering::SeqCst)),
            ),
        ])
    }
}

/// The daemon entry point: [`Server::spawn`] binds, starts the worker
/// pool, and returns a [`ServerHandle`] for the caller to await.
pub struct Server;

impl Server {
    /// Bind `cfg.addr`, start the accept loop and worker pool, and
    /// return immediately.  The CLI calls this and then
    /// [`ServerHandle::join`]; tests and benches keep the handle to
    /// query the ephemeral port and trigger shutdown.
    pub fn spawn(cfg: ServeConfig) -> Result<ServerHandle> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;

        let state = Arc::new(ServerState {
            registry: TechRegistry::builtin(),
            cache: StageCache::new(cfg.cache.clone()),
            datasets: Mutex::new(HashMap::new()),
            inflight: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
            requests: AtomicU64::new(0),
            flow_requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            overloads: AtomicU64::new(0),
            stalled_writes: AtomicU64::new(0),
            dedup_joins: AtomicU64::new(0),
            flow_micros: AtomicU64::new(0),
            stage_times: Mutex::new(BTreeMap::new()),
            engine_requests: Mutex::new(BTreeMap::new()),
            pass_requests: Mutex::new(BTreeMap::new()),
            debug_flow_delay_ms: cfg.debug_flow_delay_ms,
        });

        let (tx, rx) = mpsc::sync_channel::<TcpStream>(cfg.queue.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..cfg.threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let state = Arc::clone(&state);
                std::thread::spawn(move || worker_loop(&rx, &state))
            })
            .collect();
        let accept = {
            let state = Arc::clone(&state);
            std::thread::spawn(move || accept_loop(&listener, tx, &state))
        };
        Ok(ServerHandle { addr, state, accept, workers })
    }
}

/// A running daemon: its bound address and the threads to await.
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
    accept: JoinHandle<()>,
    workers: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown (same effect as `POST /shutdown`): stop
    /// accepting, drain queued work, exit.
    pub fn shutdown(&self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
    }

    /// Block until the accept loop and every worker have exited.
    pub fn join(self) {
        let _ = self.accept.join();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    tx: SyncSender<TcpStream>,
    state: &ServerState,
) {
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                match tx.try_send(stream) {
                    Ok(()) => {}
                    Err(TrySendError::Full(mut stream)) => {
                        // Bounded-queue overflow: answer on the accept
                        // thread so the client gets a structured 503
                        // instead of an unexplained stall.
                        state.overloads.fetch_add(1, Ordering::Relaxed);
                        let _ = Response::error(
                            503,
                            "request queue is full, retry shortly",
                        )
                        .with_header("Retry-After", "1")
                        .write_to(&mut stream);
                    }
                    Err(TrySendError::Disconnected(_)) => break,
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    // Dropping `tx` here closes the queue: workers finish what is
    // already queued, then exit — the graceful drain.
}

fn worker_loop(rx: &Mutex<Receiver<TcpStream>>, state: &ServerState) {
    loop {
        let conn = rx.lock().unwrap().recv();
        match conn {
            Ok(mut stream) => {
                state.requests.fetch_add(1, Ordering::Relaxed);
                let resp = match read_request(&stream) {
                    Ok(req) => route(state, &req),
                    // Parse errors carry their status: 413 for an
                    // oversized body, 408 for a blown deadline, 400
                    // for malformed requests.
                    Err(e) => Response::error(e.status, &e.msg),
                };
                if resp.status >= 400 {
                    state.errors.fetch_add(1, Ordering::Relaxed);
                }
                if let Err(e) = resp.write_to(&mut stream) {
                    use std::io::ErrorKind;
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut
                    ) {
                        // The write timeout fired: a stalled client
                        // was cut off rather than pinning the worker.
                        state
                            .stalled_writes
                            .fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(_) => break, // channel closed: shutdown drain complete
        }
    }
}

fn route(state: &ServerState, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(
            200,
            Json::obj(vec![("status", Json::str("ok"))])
                .to_string_pretty(),
        ),
        ("GET", "/stats") => {
            Response::json(200, state.stats_json().to_string_pretty())
        }
        ("POST", "/shutdown") => {
            state.shutdown.store(true, Ordering::SeqCst);
            Response::json(
                200,
                Json::obj(vec![(
                    "status",
                    Json::str("draining and shutting down"),
                )])
                .to_string_pretty(),
            )
        }
        ("POST", "/flow") => handle_flow(state, &req.body),
        ("GET" | "POST", path) => Response::error(
            404,
            &format!(
                "unknown path `{path}` (POST /flow, GET /stats, \
                 GET /healthz, POST /shutdown)"
            ),
        ),
        (method, _) => Response::error(
            405,
            &format!("unsupported method `{method}`"),
        ),
    }
}

fn handle_flow(state: &ServerState, body: &str) -> Response {
    let query = match FlowQuery::parse(body, &state.registry) {
        Ok(q) => q,
        Err(e) => return Response::error(400, &e.to_string()),
    };
    state.count_engine(&query);
    let fp = query.fingerprint();

    // Dedup: one leader computes, identical concurrent queries join
    // and receive the exact same response (same body Arc).
    let (inflight, leader) = {
        let mut map = state.inflight.lock().unwrap();
        match map.get(&fp) {
            Some(inf) => (Arc::clone(inf), false),
            None => {
                let inf = Arc::new(InFlight {
                    slot: Mutex::new(None),
                    cv: Condvar::new(),
                });
                map.insert(fp, Arc::clone(&inf));
                (inf, true)
            }
        }
    };

    if !leader {
        state.dedup_joins.fetch_add(1, Ordering::Relaxed);
        let mut slot = inflight.slot.lock().unwrap();
        while slot.is_none() {
            slot = inflight.cv.wait(slot).unwrap();
        }
        return slot
            .clone()
            .expect("slot filled before broadcast")
            .with_header("X-Tnn7-Dedup", "joined");
    }

    if state.debug_flow_delay_ms > 0 {
        std::thread::sleep(Duration::from_millis(
            state.debug_flow_delay_ms,
        ));
    }
    // A panicking flow must still wake followers (with a 500), never
    // leave them blocked on the condvar.
    let resp = catch_unwind(AssertUnwindSafe(|| run_flow(state, &query)))
        .unwrap_or_else(|_| {
            Response::error(500, "flow execution panicked")
        });
    {
        let mut slot = inflight.slot.lock().unwrap();
        *slot = Some(resp.clone());
        inflight.cv.notify_all();
    }
    state.inflight.lock().unwrap().remove(&fp);
    resp.with_header("X-Tnn7-Dedup", "leader")
}

fn run_flow(state: &ServerState, query: &FlowQuery) -> Response {
    state.flow_requests.fetch_add(1, Ordering::Relaxed);
    let t0 = Instant::now();
    let cfg = query.config();
    let tech = match state.registry.get(&query.tech) {
        Ok(t) => t,
        Err(e) => return Response::error(500, &e.to_string()),
    };
    let data = {
        let key = (cfg.sim_waves.max(4), cfg.data_seed);
        let mut sets = state.datasets.lock().unwrap();
        Arc::clone(sets.entry(key).or_insert_with(|| {
            Arc::new(Dataset::generate(key.0, key.1))
        }))
    };
    let mut ctx =
        FlowContext::with_tech(query.target(), cfg.clone(), tech, data);
    let trace = match Flow::measurement_for(&cfg)
        .run_cached(&mut ctx, Some(&state.cache))
    {
        Ok(t) => t,
        Err(e) => return Response::error(500, &e.to_string()),
    };
    {
        let mut times = state.stage_times.lock().unwrap();
        for s in &trace.stages {
            let e = times.entry(s.name).or_insert((0, 0));
            e.0 += 1;
            e.1 += s.micros as u64;
        }
    }
    state
        .flow_micros
        .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
    let Some(body) = trace.dump_for("report") else {
        return Response::error(
            500,
            "flow produced no report artifact",
        );
    };
    Response { status: 200, headers: Vec::new(), body }
        .with_header("X-Tnn7-Cache", trace.cache_line())
}
