//! The daemon's request schema: a [`FlowQuery`] names one design point
//! to measure, in the same vocabulary as the `tnn7 flow` CLI.
//!
//! ```json
//! {"target": "custom", "tech": "asap7-tnn7", "col": "64x8",
//!  "waves": 8, "lanes": 4, "threads": 2,
//!  "engine": "compiled", "passes": "all",
//!  "place": true, "util": 0.7, "aspect": 1.0}
//! ```
//!
//! Parsing is strict: unknown fields are rejected (the same typo
//! safety as the TOML config), and the technology must resolve through
//! the server's built-in registry — a network request can never name a
//! `.lib` filesystem path.
//!
//! [`FlowQuery::fingerprint`] is the canonical identity used for
//! in-flight request deduplication.  It deliberately excludes
//! `lanes`/`threads` (execution details proven not to change measured
//! activity), so two clients asking for the same design point at
//! different parallelism settings share one computation.

use crate::config::TnnConfig;
use crate::error::{Error, Result};
use crate::flow::cache::Fnv;
use crate::flow::{parse_geometry, Geometry, Target};
use crate::netlist::column::ColumnSpec;
use crate::netlist::Flavor;
use crate::runtime::json::Json;
use crate::tech::{BackendId, TechRegistry};

/// One parsed, validated `/flow` request.
#[derive(Debug, Clone)]
pub struct FlowQuery {
    pub flavor: Flavor,
    /// Canonical backend name (post registry resolution).
    pub tech: String,
    pub geometry: Geometry,
    pub waves: usize,
    pub lanes: usize,
    pub threads: usize,
    /// Requested simulation engine (`auto`/`scalar`/`packed`/
    /// `compiled`) — part of the request identity, because the stage
    /// dump records which engine produced it.
    pub engine: String,
    /// Requested IR pass pipeline (compiled engine only; canonical
    /// form is the identity, so `all` aliases the spelled-out list).
    pub passes: String,
    pub place: bool,
    pub util: f64,
    pub aspect: f64,
}

impl FlowQuery {
    /// Parse a request body, resolving and validating the technology
    /// against `registry` (daemon requests are restricted to built-in
    /// backends).
    pub fn parse(body: &str, registry: &TechRegistry) -> Result<FlowQuery> {
        let j = Json::parse(body)
            .map_err(|e| Error::config(format!("bad JSON body: {e}")))?;
        let obj = match &j {
            Json::Obj(m) => m,
            _ => {
                return Err(Error::config(
                    "request body must be a JSON object",
                ))
            }
        };
        const KNOWN: [&str; 12] = [
            "target", "tech", "col", "proto", "waves", "lanes",
            "threads", "engine", "passes", "place", "util", "aspect",
        ];
        for k in obj.keys() {
            if !KNOWN.contains(&k.as_str()) {
                return Err(Error::config(format!(
                    "unknown field `{k}` (known: {})",
                    KNOWN.join(", ")
                )));
            }
        }

        let flavor = match j.field("target")?.as_str()? {
            "std" | "standard" | "baseline" => Flavor::Std,
            "custom" | "gdi" => Flavor::Custom,
            other => {
                return Err(Error::config(format!(
                    "unknown target flavor `{other}` (std|custom)"
                )))
            }
        };

        let tech_req = match j.get("tech") {
            Some(v) => v.as_str()?.to_string(),
            None => BackendId::default().as_str().to_string(),
        };
        // Resolve now: unknown backends fail the request, and the
        // canonical name makes `7nm` and `asap7-tnn7` one identity.
        let tech = registry.get(&tech_req)?.name().to_string();

        let proto = match j.get("proto") {
            Some(Json::Bool(b)) => *b,
            Some(_) => {
                return Err(Error::config("`proto` must be a boolean"))
            }
            None => false,
        };
        let geometry = match (j.get("col"), proto) {
            (Some(_), true) => {
                return Err(Error::config(
                    "`col` and `proto` are mutually exclusive",
                ))
            }
            (Some(v), false) => {
                let (p, q) = parse_geometry(v.as_str()?)?;
                Geometry::Column(ColumnSpec::benchmark(p, q))
            }
            (None, true) => match Target::prototype(flavor).geometry {
                g @ Geometry::Prototype(_) => g,
                _ => unreachable!("prototype target has prototype geometry"),
            },
            (None, false) => {
                Geometry::Column(ColumnSpec::benchmark(64, 8))
            }
        };

        let d = TnnConfig::default();
        let get_count = |key: &str, default: usize| -> Result<usize> {
            match j.get(key) {
                Some(v) => {
                    let n = v.as_usize().map_err(|_| {
                        Error::config(format!(
                            "`{key}` must be a non-negative integer"
                        ))
                    })?;
                    if n == 0 {
                        return Err(Error::config(format!(
                            "`{key}` must be >= 1"
                        )));
                    }
                    Ok(n)
                }
                None => Ok(default),
            }
        };
        let waves = get_count("waves", d.sim_waves)?;
        let lanes = get_count("lanes", d.sim_lanes)?;
        if lanes > 64 {
            return Err(Error::config(format!(
                "`lanes` must be in 1..=64, got {lanes}"
            )));
        }
        let threads = get_count("threads", d.sim_threads)?;

        let engine = match j.get("engine") {
            Some(v) => v.as_str()?.to_string(),
            None => d.sim_engine.clone(),
        };
        let passes = match j.get("passes") {
            Some(v) => v.as_str()?.to_string(),
            None => d.sim_passes.clone(),
        };
        // Reuse the config-load validators so the daemon rejects the
        // exact same tokens the CLI would.
        let probe = TnnConfig {
            sim_engine: engine.clone(),
            sim_passes: passes.clone(),
            ..TnnConfig::default()
        };
        probe.validate_engine()?;
        probe.pass_manager()?;

        let place = match j.get("place") {
            Some(Json::Bool(b)) => *b,
            Some(_) => {
                return Err(Error::config("`place` must be a boolean"))
            }
            None => false,
        };
        let util = match j.get("util") {
            Some(v) => v.as_f64()?,
            None => d.place_util,
        };
        if !(util > 0.0 && util <= 1.0) {
            return Err(Error::config(format!(
                "`util` must be in (0, 1], got {util}"
            )));
        }
        let aspect = match j.get("aspect") {
            Some(v) => v.as_f64()?,
            None => d.place_aspect,
        };
        if !(aspect > 0.0 && aspect.is_finite()) {
            return Err(Error::config(format!(
                "`aspect` must be positive, got {aspect}"
            )));
        }

        Ok(FlowQuery {
            flavor,
            tech,
            geometry,
            waves,
            lanes,
            threads,
            engine,
            passes,
            place,
            util,
            aspect,
        })
    }

    /// The design-point target this query measures.
    pub fn target(&self) -> Target {
        Target {
            flavor: self.flavor,
            tech: BackendId::new(&self.tech),
            geometry: self.geometry,
        }
    }

    /// The measurement config this query implies (defaults for
    /// everything it does not name).
    pub fn config(&self) -> TnnConfig {
        TnnConfig {
            sim_waves: self.waves,
            sim_lanes: self.lanes,
            sim_threads: self.threads,
            sim_engine: self.engine.clone(),
            sim_passes: self.passes.clone(),
            place: self.place,
            place_util: self.util,
            place_aspect: self.aspect,
            ..TnnConfig::default()
        }
    }

    /// Canonical identity for in-flight deduplication.  Excludes
    /// `lanes`/`threads`: they change wall time, never results, so
    /// concurrent duplicates at different parallelism settings join
    /// one computation.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.str("tnn7-serve-v1");
        h.str(match self.flavor {
            Flavor::Std => "std",
            Flavor::Custom => "custom",
        });
        h.str(&self.tech);
        match &self.geometry {
            Geometry::Column(s) => {
                h.u8(0);
                h.usize(s.p);
                h.usize(s.q);
                h.u64(s.theta);
            }
            Geometry::Prototype(_) => h.u8(1),
        }
        h.usize(self.waves);
        // Engine verbatim, passes canonical — mirroring the stage
        // cache's simulate subset, so dedup and cache agree on what
        // counts as "the same request".
        h.str(&self.engine);
        h.str(
            &crate::ir::PassManager::parse(&self.passes)
                .map(|pm| pm.canonical())
                .unwrap_or_else(|_| self.passes.clone()),
        );
        h.u8(self.place as u8);
        h.f64(self.util);
        h.f64(self.aspect);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> TechRegistry {
        TechRegistry::builtin()
    }

    #[test]
    fn parses_full_query() {
        let q = FlowQuery::parse(
            r#"{"target": "custom", "tech": "asap7-baseline",
                "col": "8x4", "waves": 2, "lanes": 4, "threads": 2,
                "place": true, "util": 0.6, "aspect": 2.0}"#,
            &reg(),
        )
        .unwrap();
        assert_eq!(q.flavor, Flavor::Custom);
        assert_eq!(q.tech, "asap7-baseline");
        match q.geometry {
            Geometry::Column(s) => {
                assert_eq!((s.p, s.q), (8, 4));
                assert_eq!(s.theta, ColumnSpec::benchmark(8, 4).theta);
            }
            _ => panic!("expected column geometry"),
        }
        assert_eq!((q.waves, q.lanes, q.threads), (2, 4, 2));
        assert_eq!(q.engine, "auto");
        assert_eq!(q.passes, "all");
        assert!(q.place);
        let cfg = q.config();
        assert_eq!(cfg.sim_waves, 2);
        assert!((cfg.place_util - 0.6).abs() < 1e-12);
        assert_eq!(q.target().describe(), "custom:asap7-baseline 8x4");
    }

    #[test]
    fn defaults_match_cli_defaults() {
        let q =
            FlowQuery::parse(r#"{"target": "std"}"#, &reg()).unwrap();
        let d = TnnConfig::default();
        assert_eq!(q.waves, d.sim_waves);
        assert_eq!(q.lanes, d.sim_lanes);
        assert!(!q.place);
        assert_eq!(q.tech, crate::tech::ASAP7_TNN7);
        match q.geometry {
            Geometry::Column(s) => assert_eq!((s.p, s.q), (64, 8)),
            _ => panic!("expected default 64x8 column"),
        }
    }

    #[test]
    fn rejects_bad_queries() {
        let r = reg();
        // Unknown field (typo safety).
        assert!(FlowQuery::parse(
            r#"{"target": "std", "wavez": 2}"#,
            &r
        )
        .is_err());
        // Unregistered backend, including filesystem paths.
        assert!(FlowQuery::parse(
            r#"{"target": "std", "tech": "out/evil.lib"}"#,
            &r
        )
        .is_err());
        // col and proto at once.
        assert!(FlowQuery::parse(
            r#"{"target": "std", "col": "8x4", "proto": true}"#,
            &r
        )
        .is_err());
        // Range errors.
        assert!(
            FlowQuery::parse(r#"{"target": "std", "waves": 0}"#, &r)
                .is_err()
        );
        assert!(
            FlowQuery::parse(r#"{"target": "std", "lanes": 65}"#, &r)
                .is_err()
        );
        assert!(
            FlowQuery::parse(r#"{"target": "std", "util": 1.5}"#, &r)
                .is_err()
        );
        // Engine/pass tokens are validated like the CLI validates
        // them.
        assert!(FlowQuery::parse(
            r#"{"target": "std", "engine": "warp-drive"}"#,
            &r
        )
        .is_err());
        assert!(FlowQuery::parse(
            r#"{"target": "std", "passes": "fold,fold"}"#,
            &r
        )
        .is_err());
        let q = FlowQuery::parse(
            r#"{"target": "std", "engine": "compiled",
                "passes": "fold,dce"}"#,
            &r,
        )
        .unwrap();
        assert_eq!(q.engine, "compiled");
        assert_eq!(q.passes, "fold,dce");
        assert_eq!(q.config().sim_engine, "compiled");
        // Not an object / not JSON.
        assert!(FlowQuery::parse("[1,2]", &r).is_err());
        assert!(FlowQuery::parse("not json", &r).is_err());
        assert!(FlowQuery::parse(r#"{"target": "vhdl"}"#, &r).is_err());
    }

    #[test]
    fn fingerprint_ignores_lanes_and_threads_only() {
        let r = reg();
        let base = FlowQuery::parse(
            r#"{"target": "std", "col": "8x4", "waves": 2}"#,
            &r,
        )
        .unwrap();
        let parallel = FlowQuery::parse(
            r#"{"target": "std", "col": "8x4", "waves": 2,
                "lanes": 8, "threads": 4}"#,
            &r,
        )
        .unwrap();
        assert_eq!(base.fingerprint(), parallel.fingerprint());

        for different in [
            r#"{"target": "custom", "col": "8x4", "waves": 2}"#,
            r#"{"target": "std", "col": "8x5", "waves": 2}"#,
            r#"{"target": "std", "col": "8x4", "waves": 3}"#,
            r#"{"target": "std", "col": "8x4", "waves": 2, "place": true}"#,
            r#"{"target": "std", "col": "8x4", "waves": 2,
                "tech": "n45-projected"}"#,
            r#"{"target": "std", "proto": true, "waves": 2}"#,
            r#"{"target": "std", "col": "8x4", "waves": 2,
                "engine": "compiled"}"#,
            r#"{"target": "std", "col": "8x4", "waves": 2,
                "passes": "fold,dce"}"#,
        ] {
            let q = FlowQuery::parse(different, &r).unwrap();
            assert_ne!(
                base.fingerprint(),
                q.fingerprint(),
                "{different} must not alias the base query"
            );
        }

        // Canonical tech aliases share one identity.
        let alias = FlowQuery::parse(
            r#"{"target": "std", "col": "8x4", "waves": 2, "tech": "7nm"}"#,
            &r,
        )
        .unwrap();
        assert_eq!(base.fingerprint(), alias.fingerprint());

        // The pass pipeline hashes in canonical form: `all` and the
        // spelled-out full pipeline are one identity.
        let spelled = FlowQuery::parse(
            r#"{"target": "std", "col": "8x4", "waves": 2,
                "passes": "fold,dce,coalesce,resched"}"#,
            &r,
        )
        .unwrap();
        assert_eq!(base.fingerprint(), spelled.fingerprint());
    }
}
