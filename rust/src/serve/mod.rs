//! Flow-as-a-service: the `tnn7 serve` daemon (DESIGN.md §11).
//!
//! The flow pipeline ([`crate::flow`]) is a library; this module makes
//! it a persistent service.  A daemon process keeps the characterized
//! technology backends, stimulus datasets, and — crucially — the
//! content-addressed stage cache ([`crate::flow::cache`]) warm across
//! requests, so interactive design-space exploration pays elaboration
//! and simulation once per distinct design point instead of once per
//! invocation.
//!
//! Everything is hand-rolled on `std::net` (no new dependencies):
//!
//! * [`http`] — a strict HTTP/1.1 subset: one request per connection,
//!   bounded body (413 beyond the limit), a wall-clock request
//!   deadline (408 — per-read socket timeouts alone cannot stop a
//!   trickling client), structured error responses, and a retrying
//!   fetch client with capped, seeded-jitter exponential backoff.
//! * [`api`] — the [`FlowQuery`] request schema with typo-safe
//!   parsing and the canonical dedup fingerprint.
//! * [`daemon`] — the [`Server`]: nonblocking accept loop, bounded
//!   queue with inline 503 overload responses, worker-thread pool,
//!   in-flight deduplication, `/stats` counters, graceful drain on
//!   shutdown.
//!
//! ## HTTP API
//!
//! | Route            | Meaning                                        |
//! |------------------|------------------------------------------------|
//! | `POST /flow`     | Measure a design point; body = [`FlowQuery`]   |
//! | `GET /stats`     | Request/cache/stage-timing counters            |
//! | `GET /healthz`   | Liveness probe                                 |
//! | `POST /shutdown` | Drain queued work and exit                     |
//!
//! `/flow` responses carry the report-stage dump verbatim as the body
//! (byte-identical whether computed or replayed from cache) plus two
//! diagnostic headers: `X-Tnn7-Cache: executed=N mem=N disk=N` (how the
//! pipeline was satisfied) and `X-Tnn7-Dedup: leader|joined` (whether
//! this request computed or joined an identical in-flight one).

pub mod api;
pub mod daemon;
pub mod http;

pub use api::FlowQuery;
pub use daemon::{ServeConfig, Server, ServerHandle};
