//! Energy-Delay Product (Table II's fourth column).
//!
//! `EDP = E · T = (P · T) · T` with the paper's units: power in mW,
//! computation time in ns → EDP in nJ·ns
//! (mW·ns² = 1e-3 J/s · 1e-18 s² = 1e-21 J·s = 1e-9 nJ · 1e-9 ns... the
//! paper's Table II numbers confirm: 2.54 mW × 24.14 ns × 24.14 ns
//! = 1.48 nJ·ns).

/// EDP in nJ·ns from power (mW) and computation time (ns).
pub fn edp_nj_ns(power_mw: f64, time_ns: f64) -> f64 {
    power_mw * 1e-3 * time_ns * time_ns * 1e-9 / 1e-18 * 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_table2_arithmetic() {
        // Std row: 2.54 mW, 24.14 ns -> 1.48 nJ-ns.
        let e = edp_nj_ns(2.54, 24.14);
        assert!((e - 1.48).abs() < 0.01, "{e}");
        // Custom row: 1.69 mW, 19.15 ns -> 0.62 nJ-ns.
        let e = edp_nj_ns(1.69, 19.15);
        assert!((e - 0.62).abs() < 0.01, "{e}");
    }

    #[test]
    fn edp_is_quadratic_in_delay() {
        let base = edp_nj_ns(1.0, 10.0);
        assert!((edp_nj_ns(1.0, 20.0) / base - 4.0).abs() < 1e-9);
    }
}
