//! 45nm ↔ 7nm technology-scaling comparison (§III.B/III.C).
//!
//! The paper compares its 7nm results against the 45nm numbers of [2]
//! (Tables IV and VI there).  The only 45nm datapoints quoted verbatim in
//! this paper are the 1024x16 column ("1.65 mm², 7.96 mW and 42.3 ns")
//! and the prototype ratios ("power ... almost 60x lesser, whereas area
//! and computation time reduce by almost 14x and 2x").  This module
//! records those anchors and provides a first-order scaling model
//! (general-purpose, used by the ablation bench) predicting how PPA
//! should move across nodes, so the measured 45nm→7nm ratios can be
//! sanity-checked against theory.

use super::report::ColumnPpa;

/// [2] Table IV, 45nm, standard cells: the 1024x16 column.
pub const COL_1024X16_45NM: ColumnPpa = ColumnPpa {
    power_uw: 7960.0,
    time_ns: 42.3,
    area_mm2: 1.65,
};

/// [2] Table VI, 45nm prototype — reconstructed from this paper's quoted
/// ratios vs its own 7nm std-cell prototype row (60x power, 14x area,
/// 2x time against 2.54 mW / 2.36 mm² / 24.14 ns).
pub const PROTOTYPE_45NM: ColumnPpa = ColumnPpa {
    power_uw: 152_400.0,
    time_ns: 48.3,
    area_mm2: 33.0,
};

/// First-order node-scaling model (constant-field flavoured, with the
/// leakage/wire non-idealities real nodes exhibit).
#[derive(Debug, Clone, Copy)]
pub struct NodeScaling {
    /// Feature-size ratio s = L_old / L_new (45/7 ≈ 6.43).
    pub s: f64,
    /// Supply ratio V_old / V_new (1.0V / 0.7V).
    pub v: f64,
}

impl NodeScaling {
    /// 45nm (1.0 V) → ASAP7 (0.7 V).
    pub fn n45_to_7() -> Self {
        NodeScaling { s: 45.0 / 7.0, v: 1.0 / 0.7 }
    }

    /// Ideal area shrink factor (s²) — real designs achieve less because
    /// SRAM/analog/wire-limited blocks shrink slower.
    pub fn area_factor(&self) -> f64 {
        self.s * self.s
    }

    /// Dynamic-power factor per gate at iso-frequency: C·V² → (1/s)·(1/v²).
    /// Whole-design power additionally drops with the area factor's
    /// capacitance reduction; combined: ~s·v².
    pub fn power_factor(&self) -> f64 {
        self.s * self.v * self.v
    }

    /// Gate-delay factor (~s·v at constant field; finFETs do better at
    /// low V, predictive models worse — first order only).
    pub fn delay_factor(&self) -> f64 {
        (self.s * self.v).sqrt()
    }

    /// Predicted 7nm PPA from a 45nm point.
    pub fn predict(&self, p45: &ColumnPpa) -> ColumnPpa {
        ColumnPpa {
            power_uw: p45.power_uw / self.power_factor(),
            time_ns: p45.time_ns / self.delay_factor(),
            area_mm2: p45.area_mm2 / self.area_factor(),
        }
    }
}

/// Ratios (45nm / 7nm) for a measured 7nm point vs a 45nm anchor.
pub fn ratios(p45: &ColumnPpa, p7: &ColumnPpa) -> (f64, f64, f64) {
    (
        p45.power_uw / p7.power_uw,
        p45.time_ns / p7.time_ns,
        p45.area_mm2 / p7.area_mm2,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_ratios_vs_custom_7nm() {
        // Paper §III.B: the custom 1024x16 at 7nm (73.73 uW, 29.49 ns,
        // 0.079 mm²) vs 45nm: "close to two orders of magnitude
        // improvement in power and area".
        let p7 = ColumnPpa { power_uw: 73.73, time_ns: 29.49, area_mm2: 0.079 };
        let (rp, rt, ra) = ratios(&COL_1024X16_45NM, &p7);
        assert!(rp > 100.0 && rp < 120.0, "power ratio {rp}");
        assert!(ra > 15.0 && ra < 25.0, "area ratio {ra}");
        assert!(rt > 1.2 && rt < 2.0, "time ratio {rt}");
    }

    #[test]
    fn scaling_model_is_monotone_and_plausible() {
        let m = NodeScaling::n45_to_7();
        assert!(m.area_factor() > 30.0 && m.area_factor() < 50.0);
        assert!(m.power_factor() > 10.0 && m.power_factor() < 16.0);
        assert!(m.delay_factor() > 2.0 && m.delay_factor() < 4.0);
        let p = m.predict(&COL_1024X16_45NM);
        assert!(p.power_uw < COL_1024X16_45NM.power_uw);
        assert!(p.area_mm2 < COL_1024X16_45NM.area_mm2);
    }

    #[test]
    fn prototype_anchor_consistent_with_quoted_ratios() {
        let std7 = ColumnPpa { power_uw: 2540.0, time_ns: 24.14, area_mm2: 2.36 };
        let (rp, rt, ra) = ratios(&PROTOTYPE_45NM, &std7);
        assert!((rp - 60.0).abs() < 1.0);
        assert!((rt - 2.0).abs() < 0.1);
        assert!((ra - 14.0).abs() < 0.1);
    }
}
