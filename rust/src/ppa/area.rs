//! Placement-model area analysis.
//!
//! `die area = Σ cell area / UTILIZATION` — the standard post-placement
//! roll-up.  Cell areas come from the characterized library (transistor
//! count × diffusion-sharing discount × the calibrated area constant);
//! utilization is applied uniformly to both flavours (DESIGN.md §5).

use crate::cells::{Library, TechParams};
use crate::netlist::ir::Census;
use crate::netlist::Netlist;

use super::UTILIZATION;

/// Area result.
#[derive(Debug, Clone, Copy)]
pub struct AreaReport {
    /// Σ placed cell area (µm²).
    pub cell_um2: f64,
    /// Die area after utilization (mm²).
    pub die_mm2: f64,
}

/// Relative (unit-scale) aggregate for calibration.
pub fn relative(nl: &Netlist, lib: &Library) -> f64 {
    nl.insts
        .iter()
        .map(|i| lib.cell(i.cell).rel_area)
        .sum::<f64>()
        / UTILIZATION
}

/// Absolute area of a netlist.
pub fn analyze(nl: &Netlist, lib: &Library, tech: &TechParams) -> AreaReport {
    let cell_um2: f64 = nl
        .insts
        .iter()
        .map(|i| tech.area_um2(lib.cell(i.cell)))
        .sum();
    AreaReport { cell_um2, die_mm2: cell_um2 / UTILIZATION * 1e-6 }
}

/// Area from a (possibly scaled) census — the hierarchical roll-up path
/// used for layers and the Fig. 19 prototype.
pub fn from_census(census: &Census, lib: &Library, tech: &TechParams) -> AreaReport {
    let cell_um2: f64 = census
        .per_cell
        .iter()
        .enumerate()
        .map(|(c, &n)| n as f64 * tech.area_um2(lib.cell(c)))
        .sum();
    AreaReport { cell_um2, die_mm2: cell_um2 / UTILIZATION * 1e-6 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::column::{build_column, ColumnSpec};
    use crate::netlist::Flavor;

    #[test]
    fn census_roll_up_matches_flat_analysis() {
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let spec = ColumnSpec { p: 8, q: 4, theta: 10 };
        let (nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let flat = analyze(&nl, &lib, &tech);
        let census = nl.census(&lib);
        let rolled = from_census(&census, &lib, &tech);
        assert!((flat.die_mm2 - rolled.die_mm2).abs() < 1e-12);
        let x10 = from_census(&census.scaled(10), &lib, &tech);
        assert!((x10.die_mm2 - 10.0 * flat.die_mm2).abs() < 1e-9);
    }

    #[test]
    fn custom_column_smaller_than_std() {
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let spec = ColumnSpec::benchmark(64, 8);
        let (s, _) = build_column(&lib, Flavor::Std, &spec).unwrap();
        let (c, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let sa = analyze(&s, &lib, &tech).die_mm2;
        let ca = analyze(&c, &lib, &tech).die_mm2;
        assert!(ca < sa, "custom {ca} !< std {sa}");
    }

    #[test]
    fn area_grows_with_column_size() {
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let mut last = 0.0;
        for (p, q) in [(8, 4), (64, 8), (128, 10)] {
            let spec = ColumnSpec::benchmark(p, q);
            let (nl, _) = build_column(&lib, Flavor::Std, &spec).unwrap();
            let a = analyze(&nl, &lib, &tech).die_mm2;
            assert!(a > last);
            last = a;
        }
    }
}
