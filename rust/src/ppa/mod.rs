//! Post-layout PPA analysis — the Innovus/Tempus/Voltus analogue.
//!
//! Rolls a netlist + switching activity + the characterized library up
//! into the paper's three reported metrics (power, computation time,
//! area) plus EDP:
//!
//! * [`timing`] — static timing analysis over cell arcs → minimum clock
//!   period → per-wave computation time (Table I/II "Computation Time").
//! * [`power`] — activity-based dynamic power + leakage (Table "Power").
//! * [`area`] — placement model: Σ cell area / utilization ("Area").
//! * [`edp`] — energy-delay product (Table II).
//! * [`report`] — the paper-style result rows and pretty-printing.
//! * [`scaling`] — the 45nm ([2] Tables IV/VI) comparison model.

pub mod area;
pub mod edp;
pub mod power;
pub mod report;
pub mod scaling;
pub mod timing;

pub use report::{ColumnPpa, PpaRow};

/// Unit cycles per computational wave: T_STEPS compute cycles + one STDP
/// evaluation cycle + one gamma-reset cycle (see sim::testbench).
pub const WAVE_CYCLES: u64 = crate::arch::T_STEPS as u64 + 2;

/// Placement utilization (cell area / die area) used by the area model.
/// 7nm digital blocks place at 60–75%; 0.68 is applied uniformly to both
/// flavours so Table ratios are utilization-independent.
pub const UTILIZATION: f64 = 0.68;

/// Clock-tree energy per sequential commit, as a fraction of the cell's
/// switching energy (clock pin + local buffer share).
pub const CLOCK_PIN_FRAC: f64 = 0.30;
