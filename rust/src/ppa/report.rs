//! Paper-style PPA rows and table rendering.
//!
//! [`ColumnPpa`] is the (power, computation time, area) triple of Table I;
//! [`PpaRow`] adds labels and EDP for Table II.  `render_*` produce the
//! exact row/column structure the paper prints, so bench output can be
//! compared side-by-side with the published tables.

use std::fmt::Write as _;

use super::edp::edp_nj_ns;

/// One measured design point (the paper's metric triple).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ColumnPpa {
    pub power_uw: f64,
    pub time_ns: f64,
    pub area_mm2: f64,
}

impl ColumnPpa {
    /// Scale power and area by a block count (synaptic scaling roll-up);
    /// computation time is per-wave and does not scale with replication.
    pub fn scaled(&self, k: f64) -> ColumnPpa {
        ColumnPpa {
            power_uw: self.power_uw * k,
            time_ns: self.time_ns,
            area_mm2: self.area_mm2 * k,
        }
    }

    /// Combine two blocks operating concurrently (prototype layers): power
    /// and area add; a full wave must traverse the slower pipeline stage.
    pub fn compose_parallel(&self, other: &ColumnPpa) -> ColumnPpa {
        ColumnPpa {
            power_uw: self.power_uw + other.power_uw,
            time_ns: self.time_ns.max(other.time_ns),
            area_mm2: self.area_mm2 + other.area_mm2,
        }
    }

    /// EDP in nJ·ns (power converted to mW).
    pub fn edp_nj_ns(&self) -> f64 {
        edp_nj_ns(self.power_uw * 1e-3, self.time_ns)
    }
}

/// A labeled result row.
#[derive(Debug, Clone)]
pub struct PpaRow {
    pub flavor: &'static str,
    pub label: String,
    pub ppa: ColumnPpa,
    /// Paper value for side-by-side comparison, if known.
    pub paper: Option<ColumnPpa>,
}

/// Render Table-I style rows (power µW / time ns / area mm²).
pub fn render_table1(rows: &[PpaRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<22} {:>9} | {:>10} {:>10} | {:>10} {:>10} | {:>11} {:>11}",
        "", "Column", "Power(uW)", "paper", "Time(ns)", "paper", "Area(mm2)", "paper"
    );
    let _ = writeln!(s, "{}", "-".repeat(104));
    for r in rows {
        let p = r.paper;
        let fmt = |v: Option<f64>| match v {
            Some(x) => format!("{x:.3}"),
            None => "-".to_string(),
        };
        let _ = writeln!(
            s,
            "{:<22} {:>9} | {:>10.3} {:>10} | {:>10.2} {:>10} | {:>11.4} {:>11}",
            r.flavor,
            r.label,
            r.ppa.power_uw,
            fmt(p.map(|p| p.power_uw)),
            r.ppa.time_ns,
            fmt(p.map(|p| p.time_ns)),
            r.ppa.area_mm2,
            fmt(p.map(|p| p.area_mm2)),
        );
    }
    s
}

/// Render Table-II style rows (power mW / time ns / area mm² / EDP nJ·ns).
pub fn render_table2(rows: &[PpaRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<22} | {:>10} {:>10} | {:>9} {:>9} | {:>10} {:>10} | {:>10} {:>10}",
        "", "Power(mW)", "paper", "Time(ns)", "paper", "Area(mm2)", "paper", "EDP(nJ-ns)", "paper"
    );
    let _ = writeln!(s, "{}", "-".repeat(112));
    for r in rows {
        let fmt = |v: Option<f64>, d: usize| match v {
            Some(x) => format!("{x:.d$}"),
            None => "-".to_string(),
        };
        let p = r.paper;
        let _ = writeln!(
            s,
            "{:<22} | {:>10.2} {:>10} | {:>9.2} {:>9} | {:>10.2} {:>10} | {:>10.2} {:>10}",
            r.flavor,
            r.ppa.power_uw * 1e-3,
            fmt(p.map(|p| p.power_uw * 1e-3), 2),
            r.ppa.time_ns,
            fmt(p.map(|p| p.time_ns), 2),
            r.ppa.area_mm2,
            fmt(p.map(|p| p.area_mm2), 2),
            r.ppa.edp_nj_ns(),
            fmt(p.map(|p| p.edp_nj_ns()), 2),
        );
    }
    s
}

/// Ratio line ("custom consumes X% less power ...") used by the benches.
pub fn improvement_line(std: &ColumnPpa, custom: &ColumnPpa) -> String {
    format!(
        "custom vs std: power {:+.1}%  time {:+.1}%  area {:+.1}%  edp {:+.1}%",
        (custom.power_uw / std.power_uw - 1.0) * 100.0,
        (custom.time_ns / std.time_ns - 1.0) * 100.0,
        (custom.area_mm2 / std.area_mm2 - 1.0) * 100.0,
        (custom.edp_nj_ns() / std.edp_nj_ns() - 1.0) * 100.0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const STD: ColumnPpa =
        ColumnPpa { power_uw: 3.89, time_ns: 26.92, area_mm2: 0.004 };
    const CUS: ColumnPpa =
        ColumnPpa { power_uw: 2.73, time_ns: 20.59, area_mm2: 0.003 };

    #[test]
    fn scaling_and_composition() {
        let x = STD.scaled(625.0);
        assert!((x.power_uw - 3.89 * 625.0).abs() < 1e-9);
        assert!((x.time_ns - STD.time_ns).abs() < 1e-12);
        let y = x.compose_parallel(&CUS.scaled(625.0));
        assert!(y.area_mm2 > x.area_mm2);
        assert!((y.time_ns - x.time_ns).abs() < 1e-12);
    }

    #[test]
    fn renders_contain_all_fields() {
        let rows = vec![
            PpaRow {
                flavor: "Standard Cell-Based",
                label: "64x8".into(),
                ppa: STD,
                paper: Some(STD),
            },
            PpaRow {
                flavor: "Custom Macro-Based",
                label: "64x8".into(),
                ppa: CUS,
                paper: None,
            },
        ];
        let t1 = render_table1(&rows);
        assert!(t1.contains("64x8") && t1.contains("3.890"));
        let t2 = render_table2(&rows);
        assert!(t2.contains("EDP"));
        let line = improvement_line(&STD, &CUS);
        assert!(line.contains("power -29.8%"));
    }
}
