//! Static timing analysis: longest combinational path → minimum clock
//! period → per-wave computation time.
//!
//! Single-corner STA over the worst-arc cell delays of the characterized
//! library: arrival times propagate through the levelized netlist using
//! the same combinational-sensitivity rules as the simulator; the minimum
//! clock period is the worst (arrival at a sequential data input + setup),
//! also checking primary outputs.  The paper's "computation time" per
//! gamma cycle is then `WAVE_CYCLES × T_clk` (17 unit cycles: 15 RNL
//! compute + STDP evaluate + gamma reset).

use crate::cells::{Library, TechParams};
use crate::error::Result;
use crate::netlist::Netlist;
use crate::sim::eval::comb_deps;
use crate::sim::simulator::levelize;

use super::WAVE_CYCLES;

/// STA result.
#[derive(Debug, Clone)]
pub struct TimingReport {
    /// Worst data arrival at any sequential input + setup (ps).
    pub min_clock_ps: f64,
    /// Computation time for one gamma wave (ns).
    pub wave_ns: f64,
    /// Instance index ending the critical path.
    pub crit_endpoint: usize,
    /// Number of instances on levels (sanity).
    pub n_instances: usize,
}

/// Run STA on `nl` with zero wire delay (the pre-placement estimate).
pub fn analyze(nl: &Netlist, lib: &Library, tech: &TechParams) -> Result<TimingReport> {
    analyze_impl(nl, lib, tech, None)
}

/// Wire-aware STA: `wire_ps[net]` is added to the arrival of every net
/// after its driving cell (the Elmore-style term the physical-design
/// model extracts from a placement — [`crate::phys::wire`]).  With an
/// all-zero vector this is exactly [`analyze`].
pub fn analyze_with_wire(
    nl: &Netlist,
    lib: &Library,
    tech: &TechParams,
    wire_ps: &[f64],
) -> Result<TimingReport> {
    assert_eq!(
        wire_ps.len(),
        nl.n_nets(),
        "one wire delay per net required"
    );
    analyze_impl(nl, lib, tech, Some(wire_ps))
}

fn analyze_impl(
    nl: &Netlist,
    lib: &Library,
    tech: &TechParams,
    wire_ps: Option<&[f64]>,
) -> Result<TimingReport> {
    let order = levelize(nl, lib)?;
    let mut arrival = vec![0.0f64; nl.n_nets()];
    // Pass 1: propagate arrivals in level order (primary inputs at t=0,
    // sequential outputs launch at their clk->q delay).
    for &oi in &order {
        let i = oi as usize;
        let inst = &nl.insts[i];
        let cell = lib.cell(inst.cell);
        let deps = comb_deps(cell.kind);
        // Arrival at the cell = max over comb-sensitive inputs.
        let mut t_in = 0.0f64;
        for (pin, &n) in nl.inst_ins(i).iter().enumerate() {
            if deps >> pin & 1 == 1 {
                t_in = t_in.max(arrival[n.0 as usize]);
            }
        }
        let t_out = t_in + tech.delay_ps(cell);
        for &o in nl.inst_outs(i) {
            arrival[o.0 as usize] = t_out
                + wire_ps.map_or(0.0, |w| w[o.0 as usize]);
        }
    }
    // Pass 2: sequential endpoints.  Levelization orders seq cells as
    // *sources*, so data-pin arrivals are only final after pass 1.
    let mut worst = 0.0f64;
    let mut endpoint = 0usize;
    for (i, inst) in nl.insts.iter().enumerate() {
        let cell = lib.cell(inst.cell);
        if !cell.kind.is_sequential() {
            continue;
        }
        let deps = comb_deps(cell.kind);
        let setup = tech.setup_ps(cell);
        for (pin, &n) in nl.inst_ins(i).iter().enumerate() {
            if deps >> pin & 1 == 0 {
                let slack_req = arrival[n.0 as usize] + setup;
                if slack_req > worst {
                    worst = slack_req;
                    endpoint = i;
                }
            }
        }
        let _ = inst;
    }
    // Primary outputs are endpoints too.
    for &o in &nl.outputs {
        if arrival[o.0 as usize] > worst {
            worst = arrival[o.0 as usize];
        }
    }
    Ok(TimingReport {
        min_clock_ps: worst,
        wave_ns: worst * WAVE_CYCLES as f64 * 1e-3,
        crit_endpoint: endpoint,
        n_instances: order.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;
    use crate::netlist::{Builder, ClockDomain};

    #[test]
    fn chain_delay_adds_up() {
        let lib = Library::asap7_only();
        let tech = TechParams::unit(); // delays in FO4 units
        let mut b = Builder::new("c", &lib);
        let x = b.input("x");
        let mut n = x;
        for _ in 0..5 {
            n = b.inv(n);
        }
        let q = b.dff(n, ClockDomain::Aclk);
        b.output(q, "q");
        let nl = b.finish().unwrap();
        let r = analyze(&nl, &lib, &tech).unwrap();
        // 5 inverters * 0.60 + DFF setup 1.20 = 4.2 FO4-units.
        assert!((r.min_clock_ps - (5.0 * 0.60 + 1.20)).abs() < 1e-9);
    }

    #[test]
    fn dff_breaks_paths() {
        // in -> 10 invs -> DFF -> 2 invs -> out: critical path is the
        // 10-inv segment, not 12.
        let lib = Library::asap7_only();
        let tech = TechParams::unit();
        let mut b = Builder::new("c", &lib);
        let x = b.input("x");
        let mut n = x;
        for _ in 0..10 {
            n = b.inv(n);
        }
        let q = b.dff(n, ClockDomain::Aclk);
        let mut m = q;
        for _ in 0..2 {
            m = b.inv(m);
        }
        b.output(m, "y");
        let nl = b.finish().unwrap();
        let r = analyze(&nl, &lib, &tech).unwrap();
        let seg1 = 10.0 * 0.60 + 1.20;
        // segment 2 = clk->q (1.80) + 2 invs = 3.0 < seg1 = 7.2
        assert!((r.min_clock_ps - seg1).abs() < 1e-9);
    }

    #[test]
    fn bigger_column_has_longer_critical_path() {
        // The Table-I delay shape: computation time grows with p.
        use crate::netlist::column::{build_column, ColumnSpec};
        use crate::netlist::Flavor;
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let mut last = 0.0;
        for p in [8usize, 32, 128] {
            let spec = ColumnSpec::benchmark(p, 4);
            let (nl, _) = build_column(&lib, Flavor::Std, &spec).unwrap();
            let r = analyze(&nl, &lib, &tech).unwrap();
            assert!(
                r.min_clock_ps > last,
                "p={p}: {} !> {last}",
                r.min_clock_ps
            );
            last = r.min_clock_ps;
        }
    }

    #[test]
    fn zero_wire_matches_plain_analysis() {
        use crate::netlist::column::{build_column, ColumnSpec};
        use crate::netlist::Flavor;
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let spec = ColumnSpec::benchmark(8, 4);
        let (nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let dry = analyze(&nl, &lib, &tech).unwrap();
        let zero = vec![0.0f64; nl.n_nets()];
        let wet = analyze_with_wire(&nl, &lib, &tech, &zero).unwrap();
        assert_eq!(dry.min_clock_ps, wet.min_clock_ps);
        assert_eq!(dry.crit_endpoint, wet.crit_endpoint);
        // Uniform positive wire delay can only lengthen the path.
        let ones = vec![1.0f64; nl.n_nets()];
        let slow = analyze_with_wire(&nl, &lib, &tech, &ones).unwrap();
        assert!(slow.min_clock_ps > dry.min_clock_ps);
    }

    #[test]
    fn custom_flavour_is_faster() {
        use crate::netlist::column::{build_column, ColumnSpec};
        use crate::netlist::Flavor;
        let lib = Library::with_macros();
        let tech = TechParams::calibrated();
        let spec = ColumnSpec::benchmark(64, 8);
        let (s, _) = build_column(&lib, Flavor::Std, &spec).unwrap();
        let (c, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        let rs = analyze(&s, &lib, &tech).unwrap();
        let rc = analyze(&c, &lib, &tech).unwrap();
        assert!(rc.min_clock_ps < rs.min_clock_ps);
    }
}
