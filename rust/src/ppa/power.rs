//! Activity-based power analysis.
//!
//! `P = P_dyn + P_leak` with
//! `P_dyn = (Σ_i toggles_i · E_i  +  Σ_seq commits_i · CLOCK_PIN_FRAC · E_i) / T_sim`
//! where `E_i` is the characterized per-toggle switching energy of the
//! driving cell and `T_sim = cycles × T_clk` the simulated wall time, and
//! `P_leak = Σ_i leak_i`.  This mirrors what Voltus computes from a
//! VCD + Liberty pair.

use crate::cells::{Library, TechParams};
use crate::netlist::Netlist;
use crate::sim::Activity;

use super::CLOCK_PIN_FRAC;

/// Power result in µW, with the split the paper's flow would report.
#[derive(Debug, Clone, Copy)]
pub struct PowerReport {
    pub dynamic_uw: f64,
    pub clock_uw: f64,
    pub leakage_uw: f64,
    /// Wire switching power, attributed by the physical-design model
    /// ([`crate::phys::ppa_hooks::wire_power_uw`]).  Zero unless the
    /// flow ran its `place` stage — the census-only path has no wire
    /// information.
    pub wire_uw: f64,
}

impl PowerReport {
    /// Total power in µW.
    pub fn total_uw(&self) -> f64 {
        self.dynamic_uw + self.clock_uw + self.leakage_uw + self.wire_uw
    }
}

/// Relative (unit-scale) energy/leak aggregates, used by calibration.
#[derive(Debug, Clone, Copy)]
pub struct RelPower {
    /// Σ toggles·rel_energy per second of simulated time at T_clk=1ps
    /// — multiply by `energy_per_unit` to get power.
    pub energy_rate: f64,
    /// Σ rel_leak.
    pub leak: f64,
}

/// Compute the relative aggregates from a finished simulation.
///
/// `clock_ps` is the clock period the design runs at (from STA).
pub fn relative(
    nl: &Netlist,
    lib: &Library,
    act: &Activity,
    clock_ps: f64,
) -> RelPower {
    assert!(act.cycles > 0, "simulate before computing power");
    let t_sim_s = act.cycles as f64 * clock_ps * 1e-12;
    let mut toggle_energy = 0.0f64; // rel units
    let mut leak = 0.0f64;
    for (i, inst) in nl.insts.iter().enumerate() {
        let cell = lib.cell(inst.cell);
        toggle_energy += act.toggles[i] as f64 * cell.rel_energy;
        toggle_energy +=
            act.clock_ticks[i] as f64 * CLOCK_PIN_FRAC * cell.rel_energy;
        leak += cell.rel_leak;
    }
    RelPower { energy_rate: toggle_energy / t_sim_s, leak }
}

/// Absolute power from activity + technology constants.
pub fn analyze(
    nl: &Netlist,
    lib: &Library,
    tech: &TechParams,
    act: &Activity,
    clock_ps: f64,
) -> PowerReport {
    assert!(act.cycles > 0, "simulate before computing power");
    let t_sim_s = act.cycles as f64 * clock_ps * 1e-12;
    let mut dyn_fj = 0.0f64;
    let mut clk_fj = 0.0f64;
    let mut leak_nw = 0.0f64;
    for (i, inst) in nl.insts.iter().enumerate() {
        let cell = lib.cell(inst.cell);
        dyn_fj += act.toggles[i] as f64 * tech.energy_fj(cell);
        clk_fj += act.clock_ticks[i] as f64
            * CLOCK_PIN_FRAC
            * tech.energy_fj(cell);
        leak_nw += tech.leak_nw(cell);
    }
    // fJ / s = 1e-15 W; report µW (1e-6 W): factor 1e-9.
    PowerReport {
        dynamic_uw: dyn_fj * 1e-9 / t_sim_s,
        clock_uw: clk_fj * 1e-9 / t_sim_s,
        leakage_uw: leak_nw * 1e-3,
        wire_uw: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;
    use crate::netlist::Builder;
    use crate::sim::Simulator;

    fn toggler(lib: &Library) -> Netlist {
        let mut b = Builder::new("t", lib);
        let x = b.input("x");
        let y = b.inv(x);
        b.output(y, "y");
        b.finish().unwrap()
    }

    #[test]
    fn power_scales_with_activity() {
        let lib = Library::asap7_only();
        let nl = toggler(&lib);
        let tech = TechParams::calibrated();
        // Fast toggling.
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for i in 0..100 {
            sim.tick(&[(nl.inputs[0], i % 2 == 0)], false);
        }
        let p_fast = analyze(&nl, &lib, &tech, &sim.activity, 1000.0);
        // Slow toggling (1/10th).
        let mut sim2 = Simulator::new(&nl, &lib).unwrap();
        for i in 0..100 {
            sim2.tick(&[(nl.inputs[0], (i / 10) % 2 == 0)], false);
        }
        let p_slow = analyze(&nl, &lib, &tech, &sim2.activity, 1000.0);
        assert!(p_fast.dynamic_uw > 5.0 * p_slow.dynamic_uw);
        // Leakage identical regardless of activity.
        assert!((p_fast.leakage_uw - p_slow.leakage_uw).abs() < 1e-12);
    }

    #[test]
    fn faster_clock_means_more_power() {
        let lib = Library::asap7_only();
        let nl = toggler(&lib);
        let tech = TechParams::calibrated();
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for i in 0..50 {
            sim.tick(&[(nl.inputs[0], i % 2 == 0)], false);
        }
        let p1 = analyze(&nl, &lib, &tech, &sim.activity, 1000.0);
        let p2 = analyze(&nl, &lib, &tech, &sim.activity, 500.0);
        assert!((p2.dynamic_uw / p1.dynamic_uw - 2.0).abs() < 1e-9);
    }

    #[test]
    fn relative_matches_absolute_under_unit_scales() {
        let lib = Library::asap7_only();
        let nl = toggler(&lib);
        let mut sim = Simulator::new(&nl, &lib).unwrap();
        for i in 0..64 {
            sim.tick(&[(nl.inputs[0], i % 3 == 0)], false);
        }
        let rel = relative(&nl, &lib, &sim.activity, 700.0);
        let tech = TechParams {
            area_per_unit_um2: 1.0,
            energy_per_unit_fj: 1.0,
            leak_per_unit_nw: 1.0,
            fo4_ps: 1.0,
        };
        let abs = analyze(&nl, &lib, &tech, &sim.activity, 700.0);
        let rel_uw = rel.energy_rate * 1e-9 + rel.leak * 1e-3;
        assert!((rel_uw - abs.total_uw()).abs() / rel_uw < 1e-9);
    }
}
