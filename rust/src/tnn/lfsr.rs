//! 16-bit Fibonacci LFSR — the hardware Bernoulli-random-variable source.
//!
//! The RTL's BRV generator is a maximal-length 16-bit LFSR (taps
//! 16,15,13,4 → polynomial x^16 + x^15 + x^13 + x^4 + 1, period 65535).
//! The SAME stream drives all three execution paths — golden model,
//! gate-level testbench, and the HLO pipeline (rust generates the `rand`
//! input tensors) — so learned weights agree bit-for-bit everywhere.

/// Maximal-length 16-bit Fibonacci LFSR.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lfsr16 {
    state: u16,
}

impl Lfsr16 {
    /// Seed the LFSR (0 is mapped to 1: the all-zero state is absorbing).
    pub fn new(seed: u16) -> Self {
        Lfsr16 { state: if seed == 0 { 1 } else { seed } }
    }

    /// Advance one step and return the new 16-bit state.
    pub fn next_u16(&mut self) -> u16 {
        let s = self.state;
        let bit = (s >> 15) ^ (s >> 14) ^ (s >> 12) ^ (s >> 3);
        self.state = (s << 1) | (bit & 1);
        self.state
    }

    /// A (r_case, r_stab) draw pair for one synapse update.
    pub fn draw_pair(&mut self) -> (u16, u16) {
        (self.next_u16(), self.next_u16())
    }

    /// Fill `out` with uniform u16 draws (as i32, matching the HLO input
    /// dtype).
    pub fn fill_i32(&mut self, out: &mut [i32]) {
        for v in out.iter_mut() {
            *v = i32::from(self.next_u16());
        }
    }

    /// Current state (testing).
    pub fn state(&self) -> u16 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn period_is_maximal() {
        let mut l = Lfsr16::new(0xACE1);
        let start = l.state();
        let mut n = 0u32;
        loop {
            l.next_u16();
            n += 1;
            if l.state() == start {
                break;
            }
            assert!(n <= 65535, "period too long");
        }
        assert_eq!(n, 65535);
    }

    #[test]
    fn zero_seed_is_fixed_up() {
        let mut l = Lfsr16::new(0);
        assert_ne!(l.state(), 0);
        for _ in 0..100 {
            assert_ne!(l.next_u16(), 0u16.wrapping_sub(0) & 0);
        }
    }

    #[test]
    fn distribution_is_roughly_uniform() {
        // Mean of 16-bit draws over the full period ≈ 32768.
        let mut l = Lfsr16::new(1);
        let mut sum = 0u64;
        for _ in 0..65535 {
            sum += u64::from(l.next_u16());
        }
        let mean = sum as f64 / 65535.0;
        assert!((mean - 32768.0).abs() < 300.0, "mean {mean}");
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Lfsr16::new(42);
        let mut b = Lfsr16::new(42);
        for _ in 0..1000 {
            assert_eq!(a.draw_pair(), b.draw_pair());
        }
    }
}
