//! Sensory front-end: on/off-center filtering + 3-bit temporal encoding.
//!
//! Follows [2]'s MNIST pipeline: each pixel is passed through an
//! on-center and an off-center difference-of-Gaussians-style filter
//! (approximated by center-minus-surround on a 3×3 neighbourhood), and
//! the filter response is encoded as a spike *time* in [0, 8): strong
//! response → early spike, sub-threshold → no spike (INF).  Each layer-1
//! column sees a receptive field of 4×4 pixels × 2 polarities = 32
//! inputs; 25×25 = 625 overlapping receptive fields tile the 28×28 image.

use crate::arch::T_IN;

use super::INF;

/// Image side (MNIST-like).
pub const IMG: usize = 28;
/// Receptive-field side.
pub const RF: usize = 4;
/// Receptive fields per image side (stride 1): 28 - 4 + 1 = 25.
pub const GRID: usize = IMG - RF + 1;
/// Layer-1 columns (= 625, the Fig. 19 prototype).
pub const N_COLS: usize = GRID * GRID;
/// Inputs per layer-1 column (4x4 RF × on/off polarity = 32).
pub const COL_INPUTS: usize = RF * RF * 2;

/// Center-surround filter responses: `(on, off)` images, values in
/// [-1, 1] (positive = center brighter / darker than surround).
pub fn center_surround(img: &[f32]) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(img.len(), IMG * IMG);
    let mut on = vec![0.0f32; IMG * IMG];
    let mut off = vec![0.0f32; IMG * IMG];
    for y in 0..IMG {
        for x in 0..IMG {
            let c = img[y * IMG + x];
            let mut sum = 0.0f32;
            let mut n = 0.0f32;
            for dy in -1i32..=1 {
                for dx in -1i32..=1 {
                    if dy == 0 && dx == 0 {
                        continue;
                    }
                    let (ny, nx) = (y as i32 + dy, x as i32 + dx);
                    if ny >= 0 && ny < IMG as i32 && nx >= 0 && nx < IMG as i32 {
                        sum += img[ny as usize * IMG + nx as usize];
                        n += 1.0;
                    }
                }
            }
            let surround = sum / n;
            let resp = c - surround;
            on[y * IMG + x] = resp.clamp(-1.0, 1.0);
            off[y * IMG + x] = (-resp).clamp(-1.0, 1.0);
        }
    }
    (on, off)
}

/// Encode a filter response into a 3-bit spike time: response ≥
/// `threshold` spikes, stronger earlier; below threshold → INF.
pub fn encode_response(resp: f32, threshold: f32) -> i32 {
    if resp < threshold {
        return INF;
    }
    // Map [threshold, 1] onto [T_IN-1, 0]: strongest -> t=0.
    let norm = ((resp - threshold) / (1.0 - threshold)).clamp(0.0, 1.0);
    let t = ((1.0 - norm) * (T_IN - 1) as f32).round() as i32;
    t.clamp(0, T_IN - 1)
}

/// Full image → per-column spike vectors: `out[col][COL_INPUTS]`.
///
/// Input ordering within a column: the 16 on-center pixels of the RF
/// (row-major), then the 16 off-center pixels.
pub fn encode_image(img: &[f32], threshold: f32) -> Vec<Vec<i32>> {
    let (on, off) = center_surround(img);
    let mut cols = Vec::with_capacity(N_COLS);
    for gy in 0..GRID {
        for gx in 0..GRID {
            let mut s = Vec::with_capacity(COL_INPUTS);
            for py in 0..RF {
                for px in 0..RF {
                    let idx = (gy + py) * IMG + (gx + px);
                    s.push(encode_response(on[idx], threshold));
                }
            }
            for py in 0..RF {
                for px in 0..RF {
                    let idx = (gy + py) * IMG + (gx + px);
                    s.push(encode_response(off[idx], threshold));
                }
            }
            cols.push(s);
        }
    }
    cols
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_prototype() {
        assert_eq!(N_COLS, 625);
        assert_eq!(COL_INPUTS, 32);
    }

    #[test]
    fn encode_maps_strength_to_time_monotonically() {
        let thr = 0.05;
        let mut last = T_IN;
        for r in [0.05f32, 0.2, 0.5, 0.8, 1.0] {
            let t = encode_response(r, thr);
            assert!(t <= last, "stronger response must not spike later");
            last = t;
        }
        assert_eq!(encode_response(0.0, thr), INF);
        assert_eq!(encode_response(1.0, thr), 0);
    }

    #[test]
    fn flat_image_produces_no_spikes() {
        let img = vec![0.5f32; IMG * IMG];
        let cols = encode_image(&img, 0.05);
        assert_eq!(cols.len(), N_COLS);
        assert!(cols.iter().all(|c| c.iter().all(|&s| s == INF)));
    }

    #[test]
    fn edge_activates_on_and_off_cells() {
        // Vertical step edge: bright left, dark right.
        let mut img = vec![0.0f32; IMG * IMG];
        for y in 0..IMG {
            for x in 0..14 {
                img[y * IMG + x] = 1.0;
            }
        }
        let (on, off) = center_surround(&img);
        // On-response positive just left of the edge, off just right.
        let y = 14;
        assert!(on[y * IMG + 13] > 0.0);
        assert!(off[y * IMG + 14] > 0.0);
        let cols = encode_image(&img, 0.05);
        let spikes: usize = cols
            .iter()
            .map(|c| c.iter().filter(|&&s| s != INF).count())
            .sum();
        assert!(spikes > 100, "edges must spike ({spikes})");
    }
}
