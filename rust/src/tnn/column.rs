//! Golden column forward pass: RNL SRM0 neurons + 1-WTA.
//!
//! Mirrors `ref.column_fwd` exactly (integer semantics, INF sentinel,
//! lowest-index tie-break).  Weights are row-major `w[j*q + i]` for
//! synapse j → neuron i, matching both the HLO layout and the netlist
//! testbench ordering.

use crate::arch::{T_STEPS, W_MAX};

use super::INF;

/// Column forward for one sample.
///
/// `s[p]` input spike times (INF = none), `w[p*q]` weights in `[0,7]`
/// row-major, `theta` >= 1.  Returns `(pre, post)` spike-time vectors of
/// length q.
pub fn column_fwd(s: &[i32], w: &[i32], q: usize, theta: i32) -> (Vec<i32>, Vec<i32>) {
    let p = s.len();
    debug_assert_eq!(w.len(), p * q);
    let mut pre = vec![INF; q];
    for t in 0..T_STEPS {
        for i in 0..q {
            if pre[i] != INF {
                continue;
            }
            let mut rho = 0i64;
            for j in 0..p {
                let sj = s[j];
                if sj == INF {
                    continue;
                }
                let ramp = (t + 1 - sj).max(0);
                rho += i64::from(ramp.min(w[j * q + i]).min(W_MAX));
            }
            if rho >= i64::from(theta) {
                pre[i] = t;
            }
        }
    }
    // 1-WTA: earliest spike, lowest index on ties.
    let mut post = vec![INF; q];
    let mut winner = None;
    for (i, &t) in pre.iter().enumerate() {
        if t != INF {
            match winner {
                None => winner = Some((i, t)),
                Some((_, bt)) if t < bt => winner = Some((i, t)),
                _ => {}
            }
        }
    }
    if let Some((i, t)) = winner {
        post[i] = t;
    }
    (pre, post)
}

/// Stateful golden column: weights + geometry (used by the gate-level
/// equivalence testbench and the behavioral network).
#[derive(Debug, Clone)]
pub struct ColumnState {
    pub p: usize,
    pub q: usize,
    pub theta: i32,
    /// Row-major weights `w[j*q + i]`.
    pub weights: Vec<i32>,
}

impl ColumnState {
    /// All-zero weights (the hardware reset state).
    pub fn new(p: usize, q: usize, theta: i32) -> Self {
        ColumnState { p, q, theta, weights: vec![0; p * q] }
    }

    /// Uniform initial weights.
    pub fn with_weight(p: usize, q: usize, theta: i32, w0: i32) -> Self {
        ColumnState { p, q, theta, weights: vec![w0; p * q] }
    }

    /// Forward one sample.
    pub fn forward(&self, s: &[i32]) -> (Vec<i32>, Vec<i32>) {
        column_fwd(s, &self.weights, self.q, self.theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_input_no_spike() {
        let s = vec![INF; 8];
        let w = vec![7; 8 * 4];
        let (pre, post) = column_fwd(&s, &w, 4, 1);
        assert!(pre.iter().all(|&t| t == INF));
        assert!(post.iter().all(|&t| t == INF));
    }

    #[test]
    fn immediate_fire_at_t0() {
        // 4 inputs at t=0 with w=1 give rho(0)=4.
        let s = vec![0; 4];
        let w = vec![1; 4 * 1];
        let (pre, _) = column_fwd(&s, &w, 1, 4);
        assert_eq!(pre[0], 0);
    }

    #[test]
    fn ramp_accumulates_over_time() {
        // 1 input at t=0, w=7, theta=5 -> fires at t=4 (rho(t)=t+1).
        let s = vec![0];
        let w = vec![7];
        let (pre, _) = column_fwd(&s, &w, 1, 5);
        assert_eq!(pre[0], 4);
    }

    #[test]
    fn wta_keeps_earliest_lowest_index() {
        // neuron 1 fires earlier than neuron 0.
        let s = vec![0, 0];
        // w[j*q+i]: neuron0 gets w=1, neuron1 gets w=7 (fires faster
        // with theta=4: rho_1(t) = 2(t+1) -> t=1; rho_0 = 2 -> never).
        let w = vec![1, 7, 1, 7];
        let (pre, post) = column_fwd(&s, &w, 2, 4);
        assert_eq!(pre[1], 1);
        assert_eq!(post[1], 1);
        assert_eq!(post[0], INF);
    }

    #[test]
    fn tie_breaks_low_index() {
        let s = vec![0, 0];
        let w = vec![7, 7, 7, 7];
        let (pre, post) = column_fwd(&s, &w, 2, 4);
        assert_eq!(pre[0], pre[1]);
        assert_ne!(post[0], INF);
        assert_eq!(post[1], INF);
    }

    #[test]
    fn late_spikes_delay_firing() {
        let mut last = -1;
        for s0 in 0..8 {
            let (pre, _) = column_fwd(&[s0], &[7], 1, 3);
            assert!(pre[0] > last);
            last = pre[0];
        }
    }
}
