//! Golden behavioral TNN model.
//!
//! A direct rust mirror of `python/compile/kernels/ref.py` — the
//! architectural semantics every other layer of the stack is tested
//! against: the gate-level netlists (via [`crate::sim::testbench`]), the
//! AOT-compiled HLO executables (via [`crate::runtime`] integration
//! tests), and the training pipeline's cross-check mode.
//!
//! * [`lfsr`] — the 16-bit LFSR BRV source shared by all layers.
//! * [`column`] — RNL column forward (SRM0 neurons + 1-WTA).
//! * [`stdp`] — the four-case stochastic STDP rule with stabilization.
//! * [`encoding`] — on/off-center filtering + 3-bit temporal encoding.
//! * [`network`] — the 2-layer prototype with voting classification.

pub mod column;
pub mod encoding;
pub mod lfsr;
pub mod network;
pub mod stdp;

pub use column::{column_fwd, ColumnState};
pub use lfsr::Lfsr16;
pub use stdp::{stdp_step, StdpParams};

/// "No spike" sentinel, identical to `ref.INF`.
pub const INF: i32 = crate::arch::INF;
