//! The 2-layer prototype as a behavioral network + voting classifier.
//!
//! Layer 1: 625 columns (32→12) over the encoded receptive fields.
//! Layer 2: 625 columns (12→10), column c fed by layer-1 column c's
//! post-WTA output (rebased into the input window, as `model.rebase_times`
//! does).  Classification follows [2]: layer-2 neuron activity votes for
//! classes; the neuron→class mapping is calibrated by label
//! co-occurrence after unsupervised STDP training.

use crate::arch::T_IN;

use super::column::ColumnState;
use super::lfsr::Lfsr16;
use super::stdp::{stdp_step, StdpParams};
use super::INF;

/// One layer: per-column weights + shared geometry.
#[derive(Debug, Clone)]
pub struct Layer {
    pub columns: Vec<ColumnState>,
}

impl Layer {
    /// `cols` columns of p×q at threshold theta, weights initialized to w0.
    pub fn new(cols: usize, p: usize, q: usize, theta: i32, w0: i32) -> Self {
        Layer {
            columns: (0..cols)
                .map(|_| ColumnState::with_weight(p, q, theta, w0))
                .collect(),
        }
    }

    /// Forward all columns: `s[col][p]` → (pre, post) `[col][q]`.
    pub fn forward(&self, s: &[Vec<i32>]) -> (Vec<Vec<i32>>, Vec<Vec<i32>>) {
        let mut pre = Vec::with_capacity(self.columns.len());
        let mut post = Vec::with_capacity(self.columns.len());
        for (c, col) in self.columns.iter().enumerate() {
            let (a, b) = col.forward(&s[c]);
            pre.push(a);
            post.push(b);
        }
        (pre, post)
    }

    /// One STDP update across all columns (one sample), drawing BRVs from
    /// `lfsr` in column-major synapse order — the same order the
    /// coordinator fills the HLO `rand` tensor in.
    pub fn learn(
        &mut self,
        s: &[Vec<i32>],
        post: &[Vec<i32>],
        params: &StdpParams,
        lfsr: &mut Lfsr16,
    ) {
        for (c, col) in self.columns.iter_mut().enumerate() {
            let n = col.p * col.q;
            let rand: Vec<(u16, u16)> =
                (0..n).map(|_| lfsr.draw_pair()).collect();
            stdp_step(&s[c], &post[c], &mut col.weights, &rand, params);
        }
    }
}

/// Rebase post-WTA times into the next layer's input window
/// (mirror of `model.rebase_times`).
pub fn rebase(post: &[Vec<i32>]) -> Vec<Vec<i32>> {
    post.iter()
        .map(|col| {
            col.iter()
                .map(|&t| if t == INF { INF } else { t.clamp(0, T_IN - 1) })
                .collect()
        })
        .collect()
}

/// The full 2-layer behavioral prototype.
#[derive(Debug, Clone)]
pub struct Network {
    pub l1: Layer,
    pub l2: Layer,
    /// Vote weight of (column, neuron) → class, calibrated on labels.
    pub class_map: Vec<Vec<[f32; 10]>>,
}

impl Network {
    /// The Fig. 19 geometry with standard initial weights.
    pub fn prototype(theta1: i32, theta2: i32, w0: i32) -> Self {
        let l1 = Layer::new(super::encoding::N_COLS, 32, 12, theta1, w0);
        let l2 = Layer::new(super::encoding::N_COLS, 12, 10, theta2, w0);
        let class_map =
            vec![vec![[0.0; 10]; 10]; super::encoding::N_COLS];
        Network { l1, l2, class_map }
    }

    /// Forward an encoded sample through both layers; returns layer-2
    /// post-WTA times `[col][10]`.
    pub fn forward(&self, s1: &[Vec<i32>]) -> Vec<Vec<i32>> {
        let (_, post1) = self.l1.forward(s1);
        let s2 = rebase(&post1);
        let (_, post2) = self.l2.forward(&s2);
        post2
    }

    /// Accumulate label co-occurrence for the vote calibration.
    pub fn calibrate(&mut self, post2: &[Vec<i32>], label: usize) {
        for (c, col) in post2.iter().enumerate() {
            for (i, &t) in col.iter().enumerate() {
                if t != INF {
                    self.class_map[c][i][label] += 1.0;
                }
            }
        }
    }

    /// Classify from layer-2 spikes using the calibrated map: each firing
    /// (column, neuron) votes its class distribution, earlier spikes
    /// weighted higher.
    pub fn classify(&self, post2: &[Vec<i32>]) -> usize {
        let mut votes = [0.0f32; 10];
        for (c, col) in post2.iter().enumerate() {
            for (i, &t) in col.iter().enumerate() {
                if t == INF {
                    continue;
                }
                let w = 1.0 / (1.0 + t as f32);
                let m = &self.class_map[c][i];
                let total: f32 = m.iter().sum();
                if total > 0.0 {
                    for k in 0..10 {
                        votes[k] += w * m[k] / total;
                    }
                }
            }
        }
        votes
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(k, _)| k)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rebase_clamps_and_preserves_inf() {
        let post = vec![vec![0, 5, 9, 14, INF]];
        let got = rebase(&post);
        assert_eq!(got[0], vec![0, 5, 7, 7, INF]);
    }

    #[test]
    fn layer_forward_shapes() {
        let layer = Layer::new(3, 8, 4, 6, 3);
        let s = vec![vec![0i32; 8]; 3];
        let (pre, post) = layer.forward(&s);
        assert_eq!(pre.len(), 3);
        assert_eq!(pre[0].len(), 4);
        // WTA: at most one post spike per column.
        for col in &post {
            assert!(col.iter().filter(|&&t| t != INF).count() <= 1);
        }
    }

    #[test]
    fn learning_changes_weights_deterministically() {
        let mut a = Layer::new(2, 8, 4, 6, 3);
        let mut b = a.clone();
        let s = vec![vec![0i32; 8]; 2];
        let params = StdpParams::default_training();
        let (_, post) = a.forward(&s);
        let mut l1 = Lfsr16::new(99);
        let mut l2 = Lfsr16::new(99);
        a.learn(&s, &post, &params, &mut l1);
        b.learn(&s, &post, &params, &mut l2);
        assert_eq!(a.columns[0].weights, b.columns[0].weights);
        assert_ne!(a.columns[0].weights, vec![3; 32], "weights moved");
    }

    #[test]
    fn classifier_learns_a_trivial_mapping() {
        let mut net = Network::prototype(16, 4, 4);
        // Fake calibration: column 0 neuron 0 always fires with class 7.
        let mut post2 = vec![vec![INF; 10]; super::super::encoding::N_COLS];
        post2[0][0] = 1;
        net.calibrate(&post2, 7);
        net.calibrate(&post2, 7);
        assert_eq!(net.classify(&post2), 7);
    }
}
