//! Golden STDP: the four-case stochastic rule with weight-indexed
//! stabilization — a bit-exact mirror of `ref.stdp_step`.

use crate::arch::{N_PARAMS, RAND_SCALE, W_MAX};

use super::INF;

/// STDP probabilities as 16-bit fixed-point thresholds (r < thr fires).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StdpParams {
    pub mu_capture: i32,
    pub mu_backoff: i32,
    pub mu_search: i32,
    pub stab_up: [i32; 8],
    pub stab_dn: [i32; 8],
}

impl StdpParams {
    /// From probabilities in [0,1] (mirrors `ref.pack_params`).
    pub fn from_probs(
        mu_capture: f64,
        mu_backoff: f64,
        mu_search: f64,
        stab_up: [f64; 8],
        stab_dn: [f64; 8],
    ) -> Self {
        let t = |p: f64| (p * f64::from(RAND_SCALE)).round() as i32;
        StdpParams {
            mu_capture: t(mu_capture),
            mu_backoff: t(mu_backoff),
            mu_search: t(mu_search),
            stab_up: stab_up.map(t),
            stab_dn: stab_dn.map(t),
        }
    }

    /// The training configuration used by the MNIST prototype: strong
    /// capture, moderate backoff, weak search; stabilization slows updates
    /// as weights approach the rails (the 8:1 mux table of Fig. 9).
    pub fn default_training() -> Self {
        StdpParams::from_probs(
            0.9,
            0.5,
            0.05,
            [1.0, 1.0, 0.75, 0.5, 0.5, 0.25, 0.25, 0.125],
            [0.125, 0.25, 0.25, 0.5, 0.5, 0.75, 1.0, 1.0],
        )
    }

    /// Flatten to the HLO params vector (layout of `ref.pack_params`).
    pub fn to_vec(&self) -> Vec<i32> {
        let mut v = Vec::with_capacity(N_PARAMS);
        v.extend_from_slice(&[self.mu_capture, self.mu_backoff, self.mu_search]);
        v.extend_from_slice(&self.stab_up);
        v.extend_from_slice(&self.stab_dn);
        v
    }
}

/// Per-synapse BRV draws for one sample: `(r_case, r_stab)` in [0, 2^16).
pub type RandPair = (u16, u16);

/// One STDP update step over a column (one sample).
///
/// `s[p]` input times, `o[q]` post-WTA output times, `w[p*q]` row-major
/// weights (updated in place), `rand[p*q]` per-synapse draw pairs.
pub fn stdp_step(
    s: &[i32],
    o: &[i32],
    w: &mut [i32],
    rand: &[RandPair],
    params: &StdpParams,
) {
    let p = s.len();
    let q = o.len();
    debug_assert_eq!(w.len(), p * q);
    debug_assert_eq!(rand.len(), p * q);
    for j in 0..p {
        let x = s[j] != INF;
        for i in 0..q {
            let syn = j * q + i;
            let y = o[i] != INF;
            let sle = s[j] <= o[i];
            let (r_case, r_stab) = rand[syn];
            let (r_case, r_stab) = (i32::from(r_case), i32::from(r_stab));
            let wv = w[syn].clamp(0, 7) as usize;
            let su = params.stab_up[wv];
            let sd = params.stab_dn[wv];

            let capture =
                x && y && sle && r_case < params.mu_capture && r_stab < su;
            let backoff =
                x && y && !sle && r_case < params.mu_backoff && r_stab < sd;
            let search = x && !y && r_case < params.mu_search;
            let minus = !x && y && r_case < params.mu_backoff && r_stab < sd;

            let delta = i32::from(capture || search) - i32::from(backoff || minus);
            w[syn] = (w[syn] + delta).clamp(0, W_MAX);
        }
    }
}

/// The 19 BRV lanes the gate-level testbench drives for one synapse, in
/// [`crate::netlist::column::BRV_PER_SYN`] order:
/// `[b_capture, b_backoff, b_search, stab_up[0..8], stab_dn[0..8]]`.
pub fn brv_lanes(rand: RandPair, params: &StdpParams) -> [bool; 19] {
    let (r_case, r_stab) = (i32::from(rand.0), i32::from(rand.1));
    let mut lanes = [false; 19];
    lanes[0] = r_case < params.mu_capture;
    lanes[1] = r_case < params.mu_backoff;
    lanes[2] = r_case < params.mu_search;
    for k in 0..8 {
        lanes[3 + k] = r_stab < params.stab_up[k];
        lanes[11 + k] = r_stab < params.stab_dn[k];
    }
    lanes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_on() -> StdpParams {
        StdpParams::from_probs(1.0, 1.0, 1.0, [1.0; 8], [1.0; 8])
    }

    #[test]
    fn capture_increments() {
        let mut w = vec![3];
        stdp_step(&[0], &[5], &mut w, &[(0, 0)], &all_on());
        assert_eq!(w[0], 4);
    }

    #[test]
    fn backoff_decrements() {
        let mut w = vec![3];
        stdp_step(&[5], &[2], &mut w, &[(0, 0)], &all_on());
        assert_eq!(w[0], 2);
    }

    #[test]
    fn search_increments_without_output() {
        let mut w = vec![3];
        stdp_step(&[2], &[INF], &mut w, &[(0, 0)], &all_on());
        assert_eq!(w[0], 4);
    }

    #[test]
    fn minus_decrements_without_input() {
        let mut w = vec![3];
        stdp_step(&[INF], &[2], &mut w, &[(0, 0)], &all_on());
        assert_eq!(w[0], 2);
    }

    #[test]
    fn no_spikes_no_change() {
        let mut w = vec![3];
        stdp_step(&[INF], &[INF], &mut w, &[(0, 0)], &all_on());
        assert_eq!(w[0], 3);
    }

    #[test]
    fn saturation_both_rails() {
        let mut w = vec![7, 0];
        // synapse 0: capture at 7 (stays); synapse 1 (same input row,
        // second neuron): minus? construct q=2: o=[5, 2], s=[0].
        stdp_step(&[0], &[5, 0], &mut w, &[(0, 0), (0, 0)], &all_on());
        assert_eq!(w[0], 7);
        // s=0 <= o=0: capture -> 1
        assert_eq!(w[1], 1);
    }

    #[test]
    fn thresholds_gate_probabilistically() {
        let p = StdpParams::from_probs(0.5, 0.0, 0.0, [1.0; 8], [1.0; 8]);
        // r_case = 0x7FFF < 0.5*65536 = 32768 -> fires.
        let mut w = vec![3];
        stdp_step(&[0], &[5], &mut w, &[(0x7FFF, 0)], &p);
        assert_eq!(w[0], 4);
        // r_case = 0x8000 = 32768 not < 32768 -> holds.
        let mut w = vec![3];
        stdp_step(&[0], &[5], &mut w, &[(0x8000, 0)], &p);
        assert_eq!(w[0], 3);
    }

    #[test]
    fn brv_lanes_consistent_with_step() {
        // lane semantics: selected stab lane by weight must reproduce the
        // step's decision.
        let params = StdpParams::default_training();
        let mut lfsr = super::super::Lfsr16::new(7);
        for _ in 0..200 {
            let pair = lfsr.draw_pair();
            let lanes = brv_lanes(pair, &params);
            for wv in 0..8usize {
                let su = i32::from(pair.1) < params.stab_up[wv];
                assert_eq!(lanes[3 + wv], su);
            }
        }
    }

    #[test]
    fn params_roundtrip_vec() {
        let p = StdpParams::default_training();
        let v = p.to_vec();
        assert_eq!(v.len(), N_PARAMS);
        assert_eq!(v[0], p.mu_capture);
        assert_eq!(v[18], p.stab_dn[7]);
    }
}
