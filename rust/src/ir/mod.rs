//! Word-level netlist IR: the compile step between an elaborated
//! [`Netlist`] and the tape engine in [`crate::sim::compiled`].
//!
//! [`lower`] turns every instance into exactly one *op*: simple
//! combinational cells become [`Body::Gate`] ops over the closed opcode
//! set [`Gate`] (derived from the single-source truth tables in
//! [`crate::sim::tables`]); wide macros and the combinational face of
//! sequential cells become [`Body::Wide`] ops evaluated through the
//! packed kernels; sequential commits are recorded separately as
//! [`SeqOp`]s.  Slots are netlist net ids — the IR never renumbers, so
//! values, faults and activity stay addressable by `NetId`/instance
//! exactly as in the interpreters.
//!
//! The optimization passes in [`passes`] rewrite the op list while
//! preserving *observable* semantics bit-for-bit: every net value
//! between ticks, every spike/weight, and the per-instance
//! toggle/clock-tick activity counters (DESIGN.md §14).  Constant
//! folding specializes consumers of tie-rooted constant cones,
//! dead-cell elimination retires constant ops into a one-shot prologue
//! (with the same first-tick toggle credit the interpreters produce),
//! coalescing fuses fanout-free producers into their single consumer
//! (both outputs still written, both instances still credited), and
//! rescheduling sorts ops within a level for locality.

pub mod passes;

pub use passes::{PassId, PassManager, PassStats};

use crate::cells::{CellKind, Library};
use crate::error::Result;
use crate::netlist::{ClockDomain, Netlist};
use crate::sim::eval::comb_deps;
use crate::sim::simulator::{comb_levels, plan};
use crate::sim::tables::{gate_for, Gate};

/// Operand capacity of a [`Body::Gate`] op (`Nand4`).
pub const MAX_GATE_INS: usize = 4;
/// Input capacity of a [`Body::Wide`] op (`StabilizeFunc` has 11).
pub const MAX_WIDE_INS: usize = 11;
/// Output capacity of any op (`StdpCaseGen`/`SpikeGen` have 4).
pub const MAX_OUTS: usize = 4;
/// Input capacity of a [`SeqOp`] (no sequential cell reads more than 2).
pub const MAX_SEQ_INS: usize = 2;

/// A simple-gate op: one opcode, up to four operand slots, one output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GateOp {
    /// Opcode (its arity says how many `ins` are live).
    pub g: Gate,
    /// Operand slots (net ids), unused entries zero.
    pub ins: [u32; MAX_GATE_INS],
    /// Output slot.
    pub out: u32,
    /// Source instance (activity attribution).
    pub inst: u32,
}

impl GateOp {
    /// Live operand slots.
    pub fn ins(&self) -> &[u32] {
        &self.ins[..self.g.n_ins()]
    }
}

/// A wide op: macro or sequential-cell combinational evaluation through
/// [`crate::sim::eval::eval_comb_packed`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WideOp {
    /// Cell kind (drives the packed kernel dispatch).
    pub kind: CellKind,
    /// Input pin count.
    pub n_ins: u8,
    /// Output pin count.
    pub n_outs: u8,
    /// State bit count (0 for pure macros).
    pub n_state: u8,
    /// Input slots in pin order.
    pub ins: [u32; MAX_WIDE_INS],
    /// Output slots in pin order.
    pub outs: [u32; MAX_OUTS],
    /// State word offset (valid when `n_state > 0`).
    pub state_off: u32,
    /// Source instance.
    pub inst: u32,
}

/// Op body: what one evaluation-phase step computes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Body {
    /// One simple gate.
    Gate(GateOp),
    /// A fanout-free producer fused into its single consumer: the first
    /// gate executes and writes its output slot, then the second (which
    /// may read it).  Both writes count toggles against their own
    /// instances, so fusion is invisible to activity accounting.
    Fused(GateOp, GateOp),
    /// A wide macro / sequential-Q evaluation.
    Wide(WideOp),
}

/// One comb-phase op at its topological level.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IrOp {
    /// Combinational depth (ops execute in ascending level order).
    pub level: u32,
    /// What to compute.
    pub body: Body,
}

impl IrOp {
    /// Slots whose *combinational* change must re-trigger this op
    /// (the quiescence-gating dependency set).
    pub fn dep_slots(&self, out: &mut Vec<u32>) {
        out.clear();
        match &self.body {
            Body::Gate(g) => out.extend_from_slice(g.ins()),
            Body::Fused(a, b) => {
                out.extend_from_slice(a.ins());
                for &s in b.ins() {
                    if s != a.out {
                        out.push(s);
                    }
                }
            }
            Body::Wide(w) => {
                let deps = comb_deps(w.kind);
                for (i, &s) in w.ins[..w.n_ins as usize].iter().enumerate() {
                    if deps >> i & 1 == 1 {
                        out.push(s);
                    }
                }
            }
        }
    }

    /// Slots every input pin reads (comb or not) — the primary-input
    /// relevance filter.
    pub fn read_slots(&self, out: &mut Vec<u32>) {
        out.clear();
        match &self.body {
            Body::Gate(g) => out.extend_from_slice(g.ins()),
            Body::Fused(a, b) => {
                out.extend_from_slice(a.ins());
                out.extend_from_slice(b.ins());
            }
            Body::Wide(w) => out.extend_from_slice(&w.ins[..w.n_ins as usize]),
        }
    }

    /// Output slots this op writes, with their owning instances.
    pub fn out_slots(&self, out: &mut Vec<(u32, u32)>) {
        out.clear();
        match &self.body {
            Body::Gate(g) => out.push((g.out, g.inst)),
            Body::Fused(a, b) => {
                out.push((a.out, a.inst));
                out.push((b.out, b.inst));
            }
            Body::Wide(w) => {
                for &s in &w.outs[..w.n_outs as usize] {
                    out.push((s, w.inst));
                }
            }
        }
    }
}

/// A sequential commit record (executed after the comb phase settles,
/// in the instance's clock domain).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SeqOp {
    /// Cell kind (drives `next_state_packed`).
    pub kind: CellKind,
    /// Source instance.
    pub inst: u32,
    /// Input slots in pin order.
    pub ins: [u32; MAX_SEQ_INS],
    /// Input pin count.
    pub n_ins: u8,
    /// State word offset.
    pub state_off: u32,
    /// State bit count.
    pub n_state: u8,
    /// Commit domain (`Aclk` every tick, `Gclk` on gamma edges).
    pub domain: ClockDomain,
    /// Level of the instance's comb op (re-armed when state changes).
    pub level: u32,
}

/// A constant cell retired by dead-cell elimination: its slot is
/// written once per reset by the engine prologue, crediting the same
/// first-tick toggles the interpreters count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConstCell {
    /// Output slot.
    pub slot: u32,
    /// Constant value.
    pub value: bool,
    /// Source instance (toggle attribution).
    pub inst: u32,
}

/// The word-level IR of one netlist.
#[derive(Debug, Clone)]
pub struct WordIr {
    /// Slot count (== `Netlist::n_nets`; slots are net ids).
    pub n_slots: usize,
    /// Instance count (activity arrays).
    pub n_insts: usize,
    /// Comb-phase ops, ascending level, stable within a level.
    pub ops: Vec<IrOp>,
    /// Level count (`max level + 1`).
    pub n_levels: usize,
    /// Sequential commit records.
    pub seqs: Vec<SeqOp>,
    /// Constant cells retired into the reset prologue.
    pub consts: Vec<ConstCell>,
    /// Per slot: `true` when a forced fault on the slot could no longer
    /// propagate as in the interpreters — its producer was retired into
    /// the reset prologue (dce) or its constant value was substituted
    /// into specialized consumers that no longer read it (fold).
    /// Engines must refuse static faults and glitches on such slots and
    /// the caller falls back to an interpreter (DESIGN.md §14).
    pub folded: Vec<bool>,
    /// Total packed state words.
    pub total_state: usize,
    /// Per instance: state word offset (dense, from the eval plan).
    pub state_off: Vec<u32>,
    /// Per instance: state bit count.
    pub state_bits: Vec<u8>,
}

impl WordIr {
    /// Comb-phase op count (the quantity passes reduce).
    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// True when a static fault or glitch on `net` could no longer be
    /// forced faithfully by the tape (producer retired into the
    /// prologue, or consumers specialized against its constant value).
    pub fn fault_site_lost(&self, net: usize) -> bool {
        self.folded[net]
    }

    /// Re-sort ops by `(level, original position)` — callers mutate
    /// levels (coalescing) and rely on this to restore invariants.
    fn resort(&mut self) {
        self.ops.sort_by_key(|op| op.level);
        self.n_levels = self
            .ops
            .iter()
            .map(|op| op.level as usize + 1)
            .max()
            .unwrap_or(0)
            .max(self.seqs.iter().map(|s| s.level as usize + 1).max().unwrap_or(0));
    }
}

/// Lower an elaborated netlist to the unoptimized word-level IR.
///
/// One op per instance, at the instance's combinational level, in a
/// deterministic `(level, instance)` order — the same schedule the
/// interpreters evaluate, so the unoptimized IR is trivially
/// bit-identical to them.
pub fn lower(nl: &Netlist, lib: &Library) -> Result<WordIr> {
    let levels = comb_levels(nl, lib)?;
    let p = plan(nl, lib)?;
    let n_insts = nl.insts.len();
    let mut ops = Vec::with_capacity(n_insts);
    let mut seqs = Vec::new();
    let mut state_bits = vec![0u8; n_insts];
    for i in 0..n_insts {
        let kind = lib.cell(nl.insts[i].cell).kind;
        let (n_in, n_out, n_state) = kind.pins();
        let ins = nl.inst_ins(i);
        let outs = nl.inst_outs(i);
        state_bits[i] = n_state as u8;
        if n_state > 0 {
            debug_assert!(n_in <= MAX_SEQ_INS);
            let mut sin = [0u32; MAX_SEQ_INS];
            for (k, &n) in ins.iter().enumerate() {
                sin[k] = n.0;
            }
            seqs.push(SeqOp {
                kind,
                inst: i as u32,
                ins: sin,
                n_ins: n_in as u8,
                state_off: p.state_off[i],
                n_state: n_state as u8,
                domain: nl.insts[i].domain,
                level: levels[i],
            });
        }
        let body = match gate_for(kind) {
            Some(g) if n_state == 0 => {
                debug_assert_eq!(n_out, 1);
                let mut gin = [0u32; MAX_GATE_INS];
                for (k, &n) in ins.iter().enumerate() {
                    gin[k] = n.0;
                }
                Body::Gate(GateOp { g, ins: gin, out: outs[0].0, inst: i as u32 })
            }
            _ => {
                debug_assert!(n_in <= MAX_WIDE_INS && n_out <= MAX_OUTS);
                let mut win = [0u32; MAX_WIDE_INS];
                for (k, &n) in ins.iter().enumerate() {
                    win[k] = n.0;
                }
                let mut wout = [0u32; MAX_OUTS];
                for (k, &n) in outs.iter().enumerate() {
                    wout[k] = n.0;
                }
                Body::Wide(WideOp {
                    kind,
                    n_ins: n_in as u8,
                    n_outs: n_out as u8,
                    n_state: n_state as u8,
                    ins: win,
                    outs: wout,
                    state_off: p.state_off[i],
                    inst: i as u32,
                })
            }
        };
        ops.push(IrOp { level: levels[i], body });
    }
    let mut ir = WordIr {
        n_slots: nl.n_nets(),
        n_insts,
        ops,
        n_levels: 0,
        seqs,
        consts: Vec::new(),
        folded: vec![false; nl.n_nets()],
        total_state: p.total_state as usize,
        state_off: p.state_off,
        state_bits,
    };
    ir.resort();
    Ok(ir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;
    use crate::netlist::column::{build_column, ColumnSpec};
    use crate::netlist::Flavor;

    fn column() -> (Library, Netlist) {
        let lib = Library::with_macros();
        let spec = ColumnSpec { p: 4, q: 2, theta: 6 };
        let (nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        (lib, nl)
    }

    #[test]
    fn lowering_covers_every_instance_once() {
        let (lib, nl) = column();
        let ir = lower(&nl, &lib).unwrap();
        assert_eq!(ir.n_ops(), nl.insts.len());
        assert_eq!(ir.n_slots, nl.n_nets());
        let mut seen = vec![0usize; nl.insts.len()];
        let mut outs = Vec::new();
        for op in &ir.ops {
            op.out_slots(&mut outs);
            match &op.body {
                Body::Gate(g) => seen[g.inst as usize] += 1,
                Body::Fused(..) => unreachable!("no fusion at lowering"),
                Body::Wide(w) => seen[w.inst as usize] += 1,
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        // Every sequential instance also has a commit record.
        let n_seq = (0..nl.insts.len())
            .filter(|&i| lib.cell(nl.insts[i].cell).kind.pins().2 > 0)
            .count();
        assert_eq!(ir.seqs.len(), n_seq);
    }

    #[test]
    fn levels_are_ascending_and_deps_precede_ops() {
        let (lib, nl) = column();
        let ir = lower(&nl, &lib).unwrap();
        let mut lvl = 0;
        for op in &ir.ops {
            assert!(op.level >= lvl);
            lvl = op.level;
        }
        // A comb dependency must be written at a strictly lower level
        // (or be a primary input / seq-state slot).
        let mut writer_level = vec![u32::MAX; ir.n_slots];
        let mut outs = Vec::new();
        for op in &ir.ops {
            op.out_slots(&mut outs);
            for &(s, _) in &outs {
                writer_level[s as usize] = op.level;
            }
        }
        let mut deps = Vec::new();
        for op in &ir.ops {
            op.dep_slots(&mut deps);
            for &d in &deps {
                let wl = writer_level[d as usize];
                assert!(
                    wl == u32::MAX || wl < op.level,
                    "dep slot {d} written at level {wl} >= {}",
                    op.level
                );
            }
        }
    }
}
