//! Named, ordered, individually-toggleable IR optimization passes.
//!
//! Every pass preserves observable semantics bit-for-bit — net values
//! between ticks, spikes/weights, *and* per-instance activity counters
//! — on every lane/thread count (the per-pass proptests in
//! `tests/ir_passes.rs` enforce this against the packed interpreter):
//!
//! * **fold** — tie/const folding: propagates tie-rooted constants
//!   through simple gates by truth-table cofactoring over the closed
//!   opcode set ([`crate::sim::tables`]).  Ops are *specialized*, never
//!   removed, so every write site survives — but a specialized consumer
//!   no longer reads the constant slot, so a fault forced onto that
//!   slot could not reach it any more; every substituted source slot is
//!   therefore flagged as a lost fault site
//!   ([`WordIr::fault_site_lost`]) and engines refuse overlays touching
//!   it.
//! * **dce** — dead-cell elimination: retires ops that compute a
//!   constant into the engine's one-shot reset prologue.  The prologue
//!   credits the producing instance the same first-tick toggles the
//!   interpreters count (constant cones settle on the first tick after
//!   reset there too).  Cells whose output genuinely toggles are never
//!   removed, even when unread — their activity is observable.
//! * **coalesce** — fanout-free gate coalescing: a simple gate whose
//!   output is read by exactly one pin of exactly one other simple
//!   gate is fused into that consumer under a cost model
//!   ([`FUSE_MAX_INS`]).  Both outputs are still written and credited,
//!   so values, faults and activity are unchanged; the fused pair just
//!   evaluates back-to-back with one scheduling step.
//! * **resched** — level re-scheduling: sorts ops *within* each level
//!   (levels are dependency-free internally) by opcode and operand
//!   locality, improving branch-prediction and cache behavior of the
//!   tape loop.  Pure reordering of independent ops — exact by
//!   construction.

use crate::error::{Error, Result};
use crate::sim::tables::{from_truth, reduce, Gate};

use super::{Body, ConstCell, GateOp, WordIr, MAX_GATE_INS};

/// Coalescing cost model: fuse only when the pair reads at most this
/// many operand slots in total.  Keeps a fused op at one cache line of
/// slot indices (Inv/Buf into anything, 2-input into up-to-3-input).
pub const FUSE_MAX_INS: usize = 5;

/// A pass name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassId {
    /// Tie/const folding.
    Fold,
    /// Dead-cell elimination.
    Dce,
    /// Fanout-free gate coalescing.
    Coalesce,
    /// Within-level re-scheduling.
    Resched,
}

impl PassId {
    /// Every pass, in the canonical `all` order.
    pub const ALL: [PassId; 4] =
        [PassId::Fold, PassId::Dce, PassId::Coalesce, PassId::Resched];

    /// Stable token used in configs, CLI flags, cache keys and reports.
    pub fn label(self) -> &'static str {
        match self {
            PassId::Fold => "fold",
            PassId::Dce => "dce",
            PassId::Coalesce => "coalesce",
            PassId::Resched => "resched",
        }
    }

    /// Parse a pass token (the inverse of [`PassId::label`]).
    pub fn parse(tok: &str) -> Result<PassId> {
        match tok {
            "fold" => Ok(PassId::Fold),
            "dce" => Ok(PassId::Dce),
            "coalesce" => Ok(PassId::Coalesce),
            "resched" => Ok(PassId::Resched),
            other => Err(Error::config(format!(
                "unknown pass `{other}` (expected one of fold, dce, \
                 coalesce, resched, or `all` / `none`)"
            ))),
        }
    }
}

/// What one pass did to the op list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PassStats {
    /// Pass token.
    pub pass: &'static str,
    /// Comb-phase op count before the pass.
    pub ops_before: usize,
    /// Comb-phase op count after the pass.
    pub ops_after: usize,
    /// Pass-specific rewrite count: specialized ops (fold), retired
    /// cells (dce), fused pairs (coalesce), reordered ops (resched).
    pub rewritten: usize,
}

/// An ordered, validated pass pipeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PassManager {
    seq: Vec<PassId>,
}

impl PassManager {
    /// The full pipeline (`fold,dce,coalesce,resched`).
    pub fn all() -> PassManager {
        PassManager { seq: PassId::ALL.to_vec() }
    }

    /// The empty pipeline (unoptimized IR).
    pub fn none() -> PassManager {
        PassManager { seq: Vec::new() }
    }

    /// Parse a pipeline spec: `all`, `none`, or a comma-separated
    /// ordered list of pass names (duplicates rejected).
    pub fn parse(spec: &str) -> Result<PassManager> {
        match spec.trim() {
            "all" => Ok(PassManager::all()),
            "none" => Ok(PassManager::none()),
            "" => Err(Error::config(
                "empty pass pipeline (use `all` or `none`)".to_string(),
            )),
            list => {
                let mut seq = Vec::new();
                for tok in list.split(',') {
                    let id = PassId::parse(tok.trim())?;
                    if seq.contains(&id) {
                        return Err(Error::config(format!(
                            "duplicate pass `{}` in pipeline",
                            id.label()
                        )));
                    }
                    seq.push(id);
                }
                Ok(PassManager { seq })
            }
        }
    }

    /// Canonical spec string (stable across parses; cache-key input).
    pub fn canonical(&self) -> String {
        if self.seq.is_empty() {
            "none".to_string()
        } else {
            self.seq
                .iter()
                .map(|p| p.label())
                .collect::<Vec<_>>()
                .join(",")
        }
    }

    /// The ordered pass list.
    pub fn passes(&self) -> &[PassId] {
        &self.seq
    }

    /// This pipeline with one pass removed (the sharded backend drops
    /// `coalesce`: fusion must not cross partition boundaries).
    pub fn without(&self, id: PassId) -> PassManager {
        PassManager {
            seq: self.seq.iter().copied().filter(|&p| p != id).collect(),
        }
    }

    /// Run the pipeline in order, returning per-pass statistics.
    pub fn run(&self, ir: &mut WordIr) -> Vec<PassStats> {
        let mut stats = Vec::with_capacity(self.seq.len());
        for &id in &self.seq {
            let ops_before = ir.n_ops();
            let rewritten = match id {
                PassId::Fold => fold(ir),
                PassId::Dce => dce(ir),
                PassId::Coalesce => coalesce(ir),
                PassId::Resched => resched(ir),
            };
            stats.push(PassStats {
                pass: id.label(),
                ops_before,
                ops_after: ir.n_ops(),
                rewritten,
            });
        }
        stats
    }
}

/// Is this gate a constant producer?
fn const_value(g: Gate) -> Option<bool> {
    match g {
        Gate::Const0 => Some(false),
        Gate::Const1 => Some(true),
        _ => None,
    }
}

/// Tie/const folding — specialize simple gates against the constant
/// slots reaching them.  Ops are processed in level order so constants
/// propagate through whole tie-rooted cones in one sweep.  Wide and
/// sequential ops are never folded (state keeps their outputs live),
/// and lookup failures keep the original op — both are safe fallbacks.
///
/// Every constant slot actually substituted into a rewrite is flagged
/// in `WordIr::folded`: its specialized consumers no longer read it, so
/// a fault forced there would silently stop propagating.  Flagging
/// makes engines reject such overlays instead (DESIGN.md §14).
fn fold(ir: &mut WordIr) -> usize {
    let n_slots = ir.n_slots;
    let WordIr { ops, consts, folded, .. } = ir;
    let mut cv: Vec<Option<bool>> = vec![None; n_slots];
    for c in consts.iter() {
        cv[c.slot as usize] = Some(c.value);
    }
    let mut rewritten = 0;
    let mut used: Vec<u32> = Vec::new();
    for op in ops.iter_mut() {
        let g = match &mut op.body {
            Body::Gate(g) => g,
            _ => continue,
        };
        if let Some(v) = const_value(g.g) {
            cv[g.out as usize] = Some(v);
            continue;
        }
        if !g.ins().iter().any(|&s| cv[s as usize].is_some()) {
            continue;
        }
        let mut t = g.g.truth();
        let mut ins: Vec<u32> = g.ins().to_vec();
        used.clear();
        while let Some(p) =
            ins.iter().position(|&s| cv[s as usize].is_some())
        {
            t = t.cofactor(p, cv[ins[p] as usize].unwrap());
            used.push(ins[p]);
            ins.remove(p);
        }
        t = reduce(t, &mut ins);
        if let Some((ng, perm)) = from_truth(&t) {
            let mut nins = [0u32; MAX_GATE_INS];
            for (k, &p) in perm.iter().take(ng.n_ins()).enumerate() {
                nins[k] = ins[p];
            }
            g.g = ng;
            g.ins = nins;
            rewritten += 1;
            for &s in &used {
                folded[s as usize] = true;
            }
            if let Some(v) = const_value(ng) {
                cv[g.out as usize] = Some(v);
            }
        }
    }
    rewritten
}

/// Dead-cell elimination — retire constant ops into the reset
/// prologue.  Only `Const0`/`Const1` gate ops qualify: anything whose
/// output can toggle stays, because its toggles are observable.
fn dce(ir: &mut WordIr) -> usize {
    let mut removed = 0;
    let consts = &mut ir.consts;
    let folded = &mut ir.folded;
    ir.ops.retain(|op| {
        let g = match &op.body {
            Body::Gate(g) => g,
            _ => return true,
        };
        match const_value(g.g) {
            Some(value) => {
                consts.push(ConstCell { slot: g.out, value, inst: g.inst });
                folded[g.out as usize] = true;
                removed += 1;
                false
            }
            None => true,
        }
    });
    removed
}

/// Fanout-free gate coalescing — fuse a simple gate read by exactly
/// one pin of exactly one other simple gate into that consumer, when
/// the pair's total operand count fits the cost model.  The producer's
/// write moves to the consumer's level (still inside the same tick's
/// settle, before anything can observe it — slots are only read
/// between ticks or by this very consumer).
fn coalesce(ir: &mut WordIr) -> usize {
    let n = ir.ops.len();
    let mut reads = vec![0u32; ir.n_slots];
    let mut reader_op = vec![u32::MAX; ir.n_slots];
    let mut buf = Vec::new();
    for (oi, op) in ir.ops.iter().enumerate() {
        op.read_slots(&mut buf);
        for &s in &buf {
            reads[s as usize] += 1;
            reader_op[s as usize] = oi as u32;
        }
    }
    // Sequential commit reads block fusion of their producer: the
    // consumer must be a comb op, not a state commit.
    for s in &ir.seqs {
        for &slot in &s.ins[..s.n_ins as usize] {
            reads[slot as usize] += 1;
            reader_op[slot as usize] = u32::MAX;
        }
    }
    let mut removed = vec![false; n];
    let mut fused = 0;
    for oi in 0..n {
        let g = match &ir.ops[oi].body {
            Body::Gate(g) => *g,
            _ => continue,
        };
        if const_value(g.g).is_some() {
            continue; // dce's job; fusing a constant wins nothing
        }
        if reads[g.out as usize] != 1 {
            continue;
        }
        let ci = reader_op[g.out as usize];
        if ci == u32::MAX || removed[ci as usize] {
            continue;
        }
        let h = match &ir.ops[ci as usize].body {
            Body::Gate(h) => *h,
            _ => continue,
        };
        if g.g.n_ins() + h.g.n_ins() > FUSE_MAX_INS {
            continue;
        }
        let level = ir.ops[ci as usize].level;
        ir.ops[ci as usize].body = Body::Fused(g, h);
        ir.ops[ci as usize].level = level;
        removed[oi] = true;
        fused += 1;
    }
    if fused > 0 {
        let mut keep = removed.iter().map(|&r| !r);
        ir.ops.retain(|_| keep.next().unwrap());
    }
    fused
}

/// Within-level re-scheduling — stable-sort each level's ops by body
/// shape, opcode and first operand slot.  Groups identical opcodes for
/// branch prediction and walks operands in roughly ascending slot
/// order for cache locality.
fn resched(ir: &mut WordIr) -> usize {
    fn key(op: &super::IrOp) -> (u8, u8, u32) {
        match &op.body {
            Body::Gate(g) => (0, g.g as u8, g.ins[0]),
            Body::Fused(a, _) => (1, a.g as u8, a.ins[0]),
            Body::Wide(w) => (2, w.n_ins, w.ins[0]),
        }
    }
    let mut moved = 0;
    let mut s = 0;
    while s < ir.ops.len() {
        let lvl = ir.ops[s].level;
        let mut e = s;
        while e < ir.ops.len() && ir.ops[e].level == lvl {
            e += 1;
        }
        let before: Vec<(u8, u8, u32)> = ir.ops[s..e].iter().map(key).collect();
        ir.ops[s..e].sort_by_key(key);
        for (i, op) in ir.ops[s..e].iter().enumerate() {
            if key(op) != before[i] {
                moved += 1;
            }
        }
        s = e;
    }
    moved
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Library;
    use crate::ir::lower;
    use crate::netlist::column::{build_column, ColumnSpec};
    use crate::netlist::{Flavor, NetId};

    fn column_ir() -> WordIr {
        let lib = Library::with_macros();
        let spec = ColumnSpec { p: 4, q: 2, theta: 6 };
        let (nl, _) = build_column(&lib, Flavor::Custom, &spec).unwrap();
        lower(&nl, &lib).unwrap()
    }

    #[test]
    fn parse_accepts_all_none_and_ordered_lists() {
        assert_eq!(PassManager::parse("all").unwrap().canonical(), "fold,dce,coalesce,resched");
        assert_eq!(PassManager::parse("none").unwrap().canonical(), "none");
        assert_eq!(
            PassManager::parse(" dce , fold ").unwrap().canonical(),
            "dce,fold"
        );
        assert!(PassManager::parse("fold,fold").is_err());
        assert!(PassManager::parse("inline").is_err());
        assert!(PassManager::parse("").is_err());
    }

    #[test]
    fn without_drops_exactly_one_pass() {
        let pm = PassManager::all().without(PassId::Coalesce);
        assert_eq!(pm.canonical(), "fold,dce,resched");
    }

    #[test]
    fn full_pipeline_reduces_ops_and_reports_stats() {
        let mut ir = column_ir();
        let before = ir.n_ops();
        let stats = PassManager::all().run(&mut ir);
        assert_eq!(stats.len(), 4);
        for s in &stats {
            assert!(s.ops_after <= s.ops_before, "{}", s.pass);
        }
        // The column has tie fanout: dce must retire at least the tie
        // cells themselves, and coalescing must find fanout-free pairs.
        let dce = stats.iter().find(|s| s.pass == "dce").unwrap();
        assert!(dce.rewritten >= 2, "ties retired: {}", dce.rewritten);
        assert!(ir.n_ops() < before);
        assert_eq!(ir.consts.len(), dce.rewritten);
        // Retired slots are flagged as lost fault sites.
        for c in &ir.consts {
            assert!(ir.fault_site_lost(c.slot as usize));
        }
    }

    #[test]
    fn fold_specializes_but_never_removes() {
        let mut ir = column_ir();
        let before = ir.n_ops();
        let stats = PassManager::parse("fold").unwrap().run(&mut ir);
        assert_eq!(ir.n_ops(), before);
        assert!(stats[0].rewritten > 0);
        assert!(ir.consts.is_empty());
        // Substituted constant slots (the ties at least) are flagged:
        // their specialized consumers no longer read them, so a fault
        // forced there could not propagate.
        assert!(ir.folded.iter().any(|&f| f));
        // But every op still exists and every slot is still written:
        // no flag on a slot a surviving op writes *and* others read.
        let mut outs = Vec::new();
        let mut writers = vec![false; ir.n_slots];
        for op in &ir.ops {
            op.out_slots(&mut outs);
            for &(s, _) in &outs {
                writers[s as usize] = true;
            }
        }
        for c in &ir.consts {
            writers[c.slot as usize] = true;
        }
        for (s, &f) in ir.folded.iter().enumerate() {
            if f {
                assert!(writers[s], "flagged slot {s} lost its writer");
            }
        }
    }

    #[test]
    fn coalesce_respects_the_cost_model() {
        let mut ir = column_ir();
        PassManager::parse("coalesce").unwrap().run(&mut ir);
        for op in &ir.ops {
            if let Body::Fused(a, b) = &op.body {
                assert!(a.g.n_ins() + b.g.n_ins() <= FUSE_MAX_INS);
                // The internal net stays written (site preservation).
                assert_ne!(a.out, b.out);
            }
        }
    }

    #[test]
    fn seq_fed_producers_are_never_fused() {
        let mut ir = column_ir();
        let seq_ins: Vec<u32> = ir
            .seqs
            .iter()
            .flat_map(|s| s.ins[..s.n_ins as usize].to_vec())
            .collect();
        PassManager::parse("coalesce").unwrap().run(&mut ir);
        let mut outs = Vec::new();
        for op in &ir.ops {
            if let Body::Fused(a, _) = &op.body {
                assert!(
                    !seq_ins.contains(&a.out),
                    "fused producer feeds a sequential commit"
                );
            }
            op.out_slots(&mut outs);
        }
    }

    #[test]
    fn resched_keeps_levels_and_op_multiset() {
        let mut ir = column_ir();
        let mut before: Vec<(u32, String)> = ir
            .ops
            .iter()
            .map(|op| (op.level, format!("{:?}", op.body)))
            .collect();
        PassManager::parse("resched").unwrap().run(&mut ir);
        let mut after: Vec<(u32, String)> = ir
            .ops
            .iter()
            .map(|op| (op.level, format!("{:?}", op.body)))
            .collect();
        before.sort();
        after.sort();
        assert_eq!(before, after);
        let mut lvl = 0;
        for op in &ir.ops {
            assert!(op.level >= lvl);
            lvl = op.level;
        }
    }

    #[test]
    fn net_ids_stay_stable_through_the_pipeline() {
        let mut ir = column_ir();
        let n_slots = ir.n_slots;
        PassManager::all().run(&mut ir);
        let mut buf = Vec::new();
        for op in &ir.ops {
            op.read_slots(&mut buf);
            for &s in &buf {
                assert!((s as usize) < n_slots);
            }
        }
        let _ = NetId(0); // slots are net ids by construction
    }
}
